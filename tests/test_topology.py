"""N-tier topology stack (core/topology.py + the multi-threshold planner):
closed form vs brute force, exact T=2 backward compatibility, boundary-
vector policies/stores/simulator, and mixed-depth fleets."""
import math

import numpy as np
import pytest

from repro.core import costs, placement, shp, simulator, tiers, topology
from repro.streams import StreamEngine, StreamSpec, planner


def random_ntier_model(rng, t):
    n = int(rng.integers(2_000, 200_000))
    k = int(rng.integers(1, max(2, n // 10)))
    specs = tuple(
        topology.TierSpec(
            costs.TierCosts(f"t{i}", *(10.0 ** rng.uniform(-8, -3, 3))),
            xfer_in_per_gb=float(10.0 ** rng.uniform(-7, -3)),
            xfer_out_per_gb=float(10.0 ** rng.uniform(-6, -2)))
        for i in range(t))
    wl = costs.WorkloadSpec(n_docs=n, k=k,
                            doc_gb=float(rng.uniform(1e-4, 1.0)),
                            window_months=float(rng.uniform(0.03, 3.0)))
    return topology.TierTopology(tiers=specs).cost_model(wl)


# ---------------------------------------------------------------------------
# T=2 backward compatibility: the N-tier path reproduces the paper exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [costs.case_study_1, costs.case_study_2])
def test_as_ntier_cost_vectors_bit_identical(case):
    cm = case()
    nt = cm.as_ntier()
    assert nt.t == 2
    np.testing.assert_array_equal(nt.cw, [cm.cw_a, cm.cw_b])
    np.testing.assert_array_equal(nt.cr, [cm.cr_a, cm.cr_b])
    np.testing.assert_array_equal(nt.cs, [cm.cs_a, cm.cs_b])
    assert nt.cs_max == cm.cs_max
    assert float(nt.migration_per_boundary[0]) == cm.migration_per_doc


@pytest.mark.parametrize("case", [costs.case_study_1, costs.case_study_2])
def test_case_studies_identical_through_ntier_path(case):
    """The acceptance bar: same chosen strategy, same printed totals, and
    per-strategy costs matching at every valid r."""
    cm = case()
    nt = cm.as_ntier()
    legacy = shp.plan_placement(cm)
    npl = shp.plan_placement(nt)
    assert isinstance(npl, shp.NTierPlacementPlan)
    assert npl.strategy == legacy.strategy
    assert f"{npl.total:.2f}" == f"{legacy.best.total:.2f}"
    assert math.isclose(npl.total, legacy.best.total, rel_tol=1e-9)
    assert math.isclose(npl.boundaries[0], legacy.r, rel_tol=1e-9)
    n = cm.workload.n_docs
    for r in [cm.workload.k + 1.0, n / 3, n / 2, n - 1.0]:
        two = shp.cost_no_migration(cm, r).total
        gen = shp.cost_ntier_no_migration(nt, (r,)).total
        assert math.isclose(two, gen, rel_tol=1e-12), (r, two, gen)
        two = shp.cost_with_migration(cm, r).total
        gen = shp.cost_ntier_migration(nt, (r,)).total
        assert math.isclose(two, gen, rel_tol=1e-12), (r, two, gen)


def test_ntier_policy_from_plan_matches_two_tier_policy():
    for case in (costs.case_study_1, costs.case_study_2):
        cm = case()
        pol2 = placement.optimal_policy(cm)
        poln = placement.optimal_policy(cm.as_ntier())
        assert poln.n_tiers == 2
        assert math.isclose(poln.boundaries[0], pol2.r, rel_tol=1e-9)
        assert poln.migrate_at_r == pol2.migrate_at_r


# ---------------------------------------------------------------------------
# N-tier correctness: closed form vs brute-force grid search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,seed,count", [(2, 0, 20), (3, 1, 60), (4, 2, 40)])
def test_closed_form_matches_brute_force(t, seed, count):
    """>= 100 random 3- and 4-tier models in total (plus T=2 sanity): the
    DP optimum must never lose to the grid, and must match it within grid
    resolution."""
    rng = np.random.default_rng(seed)
    for trial in range(count):
        m = random_ntier_model(rng, t)
        plan = shp.plan_placement_ntier(m)
        bt, bb, bm = shp.brute_force_plan_ntier(m, grid=48)
        assert np.isfinite(plan.total)
        assert plan.total <= bt * (1 + 1e-9) + 1e-12, \
            (t, trial, plan.total, bt, plan.strategy)
        assert abs(plan.total - bt) <= 2e-2 * abs(bt) + 1e-12, \
            (t, trial, plan.total, bt)
        assert all(b1 <= b2 for b1, b2 in
                   zip(plan.boundaries, plan.boundaries[1:]))


def test_duplicate_tier_collapses_to_two_tier_plan():
    """A topology with a duplicated middle tier must plan no worse than the
    two-tier topology it degenerates to, without inf/nan."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        m2 = random_ntier_model(rng, 2)
        a, b = m2.topology.tiers
        m3 = topology.TierTopology(tiers=(a, a, b)).cost_model(m2.workload)
        p2 = shp.plan_placement_ntier(m2)
        p3 = shp.plan_placement_ntier(m3)
        assert np.isfinite(p3.total)
        assert p3.total <= p2.total * (1 + 1e-9) + 1e-12


def test_plan_ntier_batch_matches_scalar():
    rng = np.random.default_rng(11)
    models = [random_ntier_model(rng, 3) for _ in range(32)]
    tot, bounds, mig, strats = shp.plan_ntier_batch(models)
    for i, m in enumerate(models):
        p = shp.plan_placement_ntier(m)
        assert strats[i] == p.strategy
        np.testing.assert_allclose(tot[i], p.total, rtol=1e-9)
        np.testing.assert_allclose(bounds[i], p.boundaries, rtol=1e-9,
                                   atol=1e-9)
        assert bool(mig[i]) == p.migrate


def test_efs_s3_glacier_produces_three_tier_migration_plan():
    topo = topology.aws_efs_s3_glacier()
    wl = costs.WorkloadSpec(n_docs=int(1e8), k=int(1e5), doc_gb=1e-3,
                            window_months=3.0)
    plan = shp.plan_placement_ntier(topo.cost_model(wl))
    assert plan.migrate and plan.strategy == "ntier_migration"
    widths = np.diff([0.0, *plan.boundaries, wl.n_docs])
    assert np.all(widths > 0)  # all three tiers genuinely used


def test_s3_lifecycle_gate_collapses_ia_tier():
    """Standard -> Standard-IA -> Glacier-IR: IA's per-request touch cost
    always outweighs its rental edge, so the optimal cascade skips it —
    the N-tier validity gate collapsing a degenerate tier."""
    topo = topology.aws_s3_tiering()
    wl = costs.WorkloadSpec(n_docs=int(1e8), k=int(1e5), doc_gb=1e-3,
                            window_months=3.0)
    plan = shp.plan_placement_ntier(topo.cost_model(wl))
    widths = np.diff([0.0, *plan.boundaries, wl.n_docs])
    assert widths[1] == 0.0  # IA never used
    assert plan.migrate  # but Standard -> Glacier still cascades


# ---------------------------------------------------------------------------
# Boundary-vector Policy
# ---------------------------------------------------------------------------

def test_policy_boundary_vector_semantics():
    pol = placement.Policy(boundaries=(4.0, 9.0))
    assert pol.n_tiers == 3
    assert [pol.tier_of(i) for i in (0, 3, 4, 8, 9, 100)] == [0, 0, 1, 1, 2, 2]
    assert pol.r == 4.0  # two-tier shim: the first boundary
    assert pol.migration_indices() == ()
    mig = placement.Policy(boundaries=(4.5, 9.0), migrate_at_r=True)
    assert mig.migration_indices() == (5, 9)
    assert mig.migration_index() == 5
    legacy = placement.Policy(r=7.0)
    assert legacy.boundaries == (7.0,)
    assert legacy.tier_of(6) == placement.TIER_A
    assert legacy.tier_of(7) == placement.TIER_B
    with pytest.raises(ValueError):
        placement.Policy(boundaries=(5.0, 3.0))
    with pytest.raises(ValueError):
        placement.Policy()


# ---------------------------------------------------------------------------
# Three-tier TieredStore cascade
# ---------------------------------------------------------------------------

def test_tiered_store_three_tier_cascade(tmp_path):
    import jax.numpy as jnp
    pol = placement.Policy(boundaries=(3.0, 6.0), migrate_at_r=True)
    store = tiers.TieredStore(
        pol, tiers.HotTier(k=8, payload_shape=(2,), dtype=jnp.float32),
        tiers.ColdTier(), tiers.ColdTier(directory=str(tmp_path)))
    assert store.n_tiers == 3 and store.ledger.n_tiers == 3
    for i in range(3):
        assert store.write(i, jnp.full((2,), float(i))) == 0
    assert store.maybe_migrate(2) == 0  # before the first boundary
    assert store.maybe_migrate(3) == 3  # tier 0 -> tier 1
    assert [store.tier_index_of(i) for i in range(3)] == [1, 1, 1]
    assert store.write(4, jnp.full((2,), 4.0)) == 1  # floor lifts placement
    assert store.maybe_migrate(6) == 4  # tier 1 -> tier 2
    assert [store.tier_index_of(i) for i in (0, 1, 2, 4)] == [2, 2, 2, 2]
    assert store.write(7, jnp.full((2,), 7.0)) == 2
    assert store.ledger.migrations == 7
    # tier 1: 3 cascade hops + direct write of doc 4; tier 2: 4 hops + doc 7
    assert store.ledger.writes.tolist() == [3, 3 + 1, 4 + 1]
    got = store.read_all([0, 4, 7])
    np.testing.assert_allclose(np.asarray(got[4]), 4.0)


def test_tiered_store_coincident_boundaries_skip_empty_tier():
    import jax.numpy as jnp
    pol = placement.Policy(boundaries=(2.0, 2.0), migrate_at_r=True)
    store = tiers.TieredStore(
        pol, tiers.HotTier(k=4, payload_shape=(1,), dtype=jnp.float32),
        tiers.ColdTier(), tiers.ColdTier())
    store.write(0, jnp.zeros((1,)))
    store.write(1, jnp.zeros((1,)))
    # both boundaries fire at i=2: docs hop 0 -> 2 directly, skipping the
    # zero-width middle tier (one charged hop each, matching the planner)
    assert store.maybe_migrate(2) == 2
    assert store.tier_index_of(0) == 2
    assert store.ledger.migrations == 2
    assert store.ledger.writes.tolist() == [2, 0, 2]
    assert store.ledger.reads.tolist() == [2, 0, 0]


# ---------------------------------------------------------------------------
# Simulator: 3-tier reconciliation against the analytic expectations
# ---------------------------------------------------------------------------

def three_tier_sim_model(n=30_000, k=300):
    topo = topology.aws_s3_tiering()
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-3, window_months=6.0)
    return topo.cost_model(wl)


def test_simulator_three_tier_writes_match_analytic_per_tier():
    m = three_tier_sim_model()
    n, k = m.workload.n_docs, m.workload.k
    bounds = (0.08 * n, 0.2 * n)
    pol = placement.Policy(boundaries=bounds)
    rng = np.random.default_rng(17)
    writes = np.zeros(3)
    trials = 6
    for _ in range(trials):
        res = simulator.simulate(simulator.random_rank_trace(n, rng), k, pol, m)
        writes += res.writes_per_tier
    writes /= trials
    edges = np.array([0.0, *bounds, float(n)])
    exact = np.diff(np.where(edges > 0,
                             shp.expected_cum_writes(edges - 1.0, k), 0.0))
    np.testing.assert_allclose(writes, exact, rtol=0.08)


def test_simulator_three_tier_migration_cost_reconciles():
    m = three_tier_sim_model()
    n, k = m.workload.n_docs, m.workload.k
    bounds = (0.08 * n, 0.2 * n)
    pol = placement.Policy(boundaries=bounds, migrate_at_r=True)
    rng = np.random.default_rng(23)
    totals = []
    for _ in range(4):
        res = simulator.simulate(simulator.random_rank_trace(n, rng), k,
                                 pol, m)
        # each cascade moves the (full) reservoir: K hops per boundary
        np.testing.assert_array_equal(res.migrated_per_boundary, [k, k])
        assert res.reads_per_tier.tolist()[:2] == [0, 0]  # all reads last tier
        totals.append(res.cost_total - res.cost_reads)  # eq. 20 convention
    expected = shp.cost_ntier_migration(m, bounds, exact=True).total
    assert abs(np.mean(totals) - expected) / expected < 0.12


def test_simulator_rejects_policy_deeper_than_cost_model():
    m = costs.case_study_1()
    pol = placement.Policy(boundaries=(10.0, 20.0))
    with pytest.raises(ValueError):
        simulator.simulate(np.arange(100.0), 5, pol, m)


# ---------------------------------------------------------------------------
# Mixed-depth fleets: engine + meter vs independent simulator replays
# ---------------------------------------------------------------------------

def test_engine_mixed_two_and_three_tier_matches_simulator():
    rng = np.random.default_rng(42)
    docs, k = 64, 4
    specs = [
        StreamSpec(stream_id=0, k=k, r=float(docs / 3)),
        StreamSpec(stream_id=1, k=k, boundaries=(16.0, 40.0), migrate=True),
        StreamSpec(stream_id=2, k=k, boundaries=(10.0, 30.0)),
        StreamSpec(stream_id=3, k=k, r=float(docs / 2), migrate=True),
    ]
    eng = StreamEngine(specs)
    traces = np.stack([simulator.random_rank_trace(docs, rng)
                       for _ in specs]).astype(np.float32)
    for t in range(docs):
        eng.ingest([s.stream_id for s in specs], traces[:, t],
                   [t] * len(specs))
    eng.finalize()
    for i, s in enumerate(specs):
        pol = placement.Policy(boundaries=s.explicit_boundaries(),
                               migrate_at_r=s.migrate)
        sim = simulator.simulate(traces[i].astype(np.float64), k, pol)
        led = eng.meter.ledger(eng.stream_row(s.stream_id))
        t_sim = sim.writes_per_tier.shape[0]
        assert led.writes[:t_sim].tolist() == sim.writes_per_tier.tolist()
        assert led.writes[t_sim:].sum() == 0
        assert led.reads[:t_sim].tolist() == sim.reads_per_tier.tolist()
        assert led.migrations == sim.migrated


def test_engine_placements_boundary_vectors():
    """Per-slot tier assignment with per-stream boundary vectors, including
    the meter's +inf padding for shallower streams."""
    import jax.numpy as jnp
    from repro.streams import engine
    state = engine.init(2, 4)
    state, _ = engine.update(
        state, jnp.array([[4.0, 3.0, 2.0, 1.0]] * 2, jnp.float32),
        jnp.array([[0, 5, 10, 15]] * 2, jnp.int32))
    b = jnp.array([[6.0, 12.0], [8.0, jnp.inf]], jnp.float32)
    tiers_out = np.asarray(engine.placements(state, b))
    by_id = [dict(zip(np.asarray(state.ids[r]).tolist(), tiers_out[r]))
             for r in range(2)]
    assert [by_id[0][i] for i in (0, 5, 10, 15)] == [0, 0, 1, 2]
    assert [by_id[1][i] for i in (0, 5, 10, 15)] == [0, 0, 1, 1]  # inf pad
    # scalar per-stream r still works
    scalar = np.asarray(engine.placements(state, jnp.array([6.0, 11.0])))
    by_id0 = dict(zip(np.asarray(state.ids[0]).tolist(), scalar[0]))
    assert [by_id0[i] for i in (0, 5, 10, 15)] == [0, 0, 1, 1]


def test_meter_three_tier_static_accounting():
    docs = 9
    eng = StreamEngine([StreamSpec(stream_id=0, k=2, boundaries=(3.0, 6.0))])
    for t in range(docs):  # ascending scores: every doc writes
        eng.ingest([0], [float(t)], [t])
    eng.finalize()
    led = eng.meter.ledger(0)
    assert led.writes.tolist() == [3, 3, 3]
    # evicted docs 0..6: three lived in tier 0, three in tier 1, one in 2
    assert led.deletes.tolist() == [3, 3, 1]
    assert led.reads.tolist() == [0, 0, 2]  # survivors 7, 8


def test_plan_fleet_mixed_agrees_with_scalar_planners():
    rng = np.random.default_rng(3)
    models = []
    for i in range(24):
        if i % 3 == 0:
            models.append(random_ntier_model(rng, 3))
        elif i % 3 == 1:
            models.append(random_ntier_model(rng, 4))
        else:
            n = int(rng.integers(2_000, 100_000))
            wl = costs.WorkloadSpec(n_docs=n,
                                    k=int(rng.integers(1, n // 10)),
                                    doc_gb=1.0, window_months=1.0)
            models.append(costs.TwoTierCostModel(
                tier_a=costs.TierCosts("a", *(rng.uniform(1e-8, 1e-3, 3))),
                tier_b=costs.TierCosts("b", *(rng.uniform(1e-8, 1e-3, 3))),
                workload=wl))
    plan = planner.plan_fleet_mixed(models)
    assert plan.m == len(models)
    hist = plan.strategy_histogram()
    assert sum(hist.values()) == len(models)
    for i, cm in enumerate(models):
        ref = shp.plan_placement(cm)
        if isinstance(cm, costs.TwoTierCostModel):
            assert plan.strategy(i) == ref.strategy
            np.testing.assert_allclose(plan.totals[i], ref.best.total,
                                       rtol=1e-9)
            assert len(plan.boundaries[i]) == 1
        else:
            assert plan.strategy(i) == ref.strategy
            np.testing.assert_allclose(plan.totals[i], ref.total, rtol=1e-9)
            np.testing.assert_allclose(plan.boundaries[i], ref.boundaries,
                                       rtol=1e-9, atol=1e-9)
        pol = plan.policy(i)
        assert pol.migrate_at_r == plan.migrate(i)


def test_engine_planned_mixed_fleet_runs_end_to_end():
    docs, k = 96, 4
    specs = []
    for i in range(6):
        if i % 2 == 0:
            cm = costs.hbm_host_preset(n_docs=docs, k=k, doc_gb=1e-5,
                                       window_seconds=60.0 * (1 + i))
        else:
            cm = topology.hbm_dram_disk_preset(n_docs=docs, k=k, doc_gb=1e-5,
                                               window_seconds=60.0 * (1 + i))
        specs.append(StreamSpec(stream_id=i, k=k, cost_model=cm))
    eng = StreamEngine(specs)
    assert eng.plan is not None and eng.plan.m == 6
    rng = np.random.default_rng(5)
    traces = np.stack([simulator.random_rank_trace(docs, rng)
                       for _ in specs]).astype(np.float32)
    for t in range(docs):
        eng.ingest(np.arange(6), traces[:, t], np.full(6, t))
    survivors = eng.finalize()
    for i in range(6):
        pol = eng.plan.policy(i)
        sim = simulator.simulate(traces[i].astype(np.float64), k, pol)
        np.testing.assert_array_equal(survivors[i], sim.survivor_ids)
        led = eng.meter.ledger(eng.stream_row(i))
        t_sim = sim.writes_per_tier.shape[0]
        assert led.writes[:t_sim].tolist() == sim.writes_per_tier.tolist()
        assert led.migrations == sim.migrated
