"""Property tests for the jit streaming reservoir (core/topk.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import topk


def oracle_topk(scores: np.ndarray, k: int):
    """Exact top-k with earlier-index tie-break."""
    order = np.lexsort((np.arange(len(scores)), -scores))
    return set(order[:k].tolist())


def run_stream(scores: np.ndarray, k: int, batch: int):
    state = topk.init(k)
    upd = jax.jit(topk.update)
    wrote = np.zeros(len(scores), dtype=bool)
    for off in range(0, len(scores), batch):
        sl = slice(off, min(off + batch, len(scores)))
        ids = jnp.arange(sl.start, sl.stop, dtype=jnp.int32)
        state, w = upd(state, jnp.asarray(scores[sl], jnp.float32), ids)
        wrote[sl] = np.asarray(w)
    return state, wrote


@given(
    st.lists(st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                       allow_subnormal=False,  # XLA CPU flushes subnormals
                       width=32), min_size=3, max_size=120, unique=True),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=17),
)
@settings(max_examples=40, deadline=None)
def test_reservoir_equals_oracle(scores, k, batch):
    scores = np.asarray(scores, dtype=np.float32)
    if k >= len(scores):
        k = len(scores) - 1
    state, wrote = run_stream(scores, k, batch)
    got = set(int(i) for i in np.asarray(state.ids) if i >= 0)
    assert got == oracle_topk(scores, k)
    # every final member must have triggered a write when it arrived
    for i in got:
        assert wrote[i]
    assert int(state.seen) == len(scores)
    # state scores sorted descending
    s = np.asarray(state.scores)
    assert np.all(np.diff(s[~np.isinf(s)]) <= 0)


@given(st.integers(min_value=2, max_value=64), st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_write_mask_matches_per_element_rule(n, seed):
    """wrote[i] ⟺ doc i ranks in top-k of docs 0..i — with batch=1 this is
    the paper's eq. 9/10 event exactly."""
    rng = np.random.default_rng(seed)
    scores = rng.permutation(n).astype(np.float32)
    k = max(1, n // 4)
    _, wrote = run_stream(scores, k, batch=1)
    for i in range(n):
        rank = int(np.sum(scores[: i + 1] > scores[i]))
        assert wrote[i] == (rank < k)


def test_merge_equals_single_stream():
    rng = np.random.default_rng(0)
    scores = rng.standard_normal(200).astype(np.float32)
    k = 16
    full, _ = run_stream(scores, k, batch=10)
    # split across two "shards"
    a = topk.init(k)
    b = topk.init(k)
    upd = jax.jit(topk.update)
    a, _ = upd(a, jnp.asarray(scores[:100]), jnp.arange(0, 100, dtype=jnp.int32))
    b, _ = upd(b, jnp.asarray(scores[100:]), jnp.arange(100, 200, dtype=jnp.int32))
    merged = topk.merge(a, b)
    np.testing.assert_array_equal(np.sort(np.asarray(merged.ids)),
                                  np.sort(np.asarray(full.ids)))
    assert int(merged.seen) == 200


def test_tie_break_prefers_earlier_doc():
    state = topk.init(2)
    s = jnp.array([1.0, 1.0, 1.0], jnp.float32)
    state, wrote = topk.update(state, s, jnp.array([0, 1, 2], jnp.int32))
    assert set(np.asarray(state.ids).tolist()) == {0, 1}
    assert list(np.asarray(wrote)) == [True, True, False]


@pytest.mark.parametrize("batch", [1, 32])
def test_expected_writes_statistics_match_analytic(batch):
    """Monte-Carlo over random permutations ≈ the analytic write law:
    eq. 11/12 for batch=1, the batched generalization otherwise."""
    from repro.core import shp
    rng = np.random.default_rng(42)
    n, k, trials = 400, 8, 200
    totals = []
    for _ in range(trials):
        scores = rng.permutation(n).astype(np.float32)
        _, wrote = run_stream(scores, k, batch=batch)
        totals.append(wrote.sum())
    analytic = float(shp.expected_cum_writes_batched(n - 1, k, batch))
    if batch == 1:
        assert abs(analytic - float(shp.expected_cum_writes(n - 1, k))) < 1e-9
    mc = np.mean(totals)
    se = np.std(totals) / np.sqrt(trials)
    assert abs(mc - analytic) < 4 * se + 0.5, (mc, analytic, se)


def test_tier_of_threshold():
    ids = jnp.array([0, 5, 10, 99], jnp.int32)
    t = topk.tier_of(ids, r=10)
    assert list(np.asarray(t)) == [0, 0, 1, 1]
