"""Trip-count-aware HLO analyzer: the roofline's foundation.

Verifies (a) XLA cost_analysis really does count scan bodies once (the bug
we correct), and (b) our analyzer multiplies by the trip count."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_parse

N_STEPS = 8
DIM = 256
DOT_FLOPS = 2 * DIM ** 3  # one (256,256)x(256,256) matmul


def _scanned():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def fn(x):
        y, _ = jax.lax.scan(body, x, None, length=N_STEPS)
        return y.sum()

    x = jnp.zeros((DIM, DIM), jnp.float32)
    return jax.jit(fn).lower(x).compile()


def test_xla_cost_analysis_counts_loop_once():
    c = _scanned()
    ca = c.cost_analysis()  # dict since jax 0.4.35; list of dicts before
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float((ca or {}).get("flops", 0))
    assert flops < 1.5 * DOT_FLOPS  # ~1 iteration, not 8


def test_analyzer_multiplies_by_trip_count():
    c = _scanned()
    cost = hlo_parse.analyze(c.as_text(), n_chips=1)
    assert cost.flops >= 0.9 * N_STEPS * DOT_FLOPS, cost.flops
    assert cost.flops <= 3.0 * N_STEPS * DOT_FLOPS  # fwd only, some slack
    assert cost.unparsed_whiles == 0
    assert cost.bytes > 0


def test_shape_bytes():
    assert hlo_parse.shape_bytes("bf16[6,64,128]{2,1,0}") == 6 * 64 * 128 * 2
    assert hlo_parse.shape_bytes("(s32[], f32[8,8]{1,0})") == 4 + 256
    assert hlo_parse.shape_bytes("token[]") == 0


def test_collective_accounting():
    text = """
ENTRY %main (p: bf16[16,512]) -> bf16[16,512] {
  %p = bf16[16,512]{1,0} parameter(0)
  ROOT %ar = bf16[16,512]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    cost = hlo_parse.analyze(text, n_chips=4)
    nbytes = 16 * 512 * 2
    assert cost.collective_bytes["all-reduce"] == nbytes
    # ring all-reduce: 2*(n-1)/n * S
    assert abs(cost.collective_link_bytes - 2 * 3 / 4 * nbytes) < 1e-6
