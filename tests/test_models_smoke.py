"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step (loss + grads) on CPU; output shapes and finiteness.
The FULL configs are exercised only via the dry-run (abstract, no alloc).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.synthetic import make_batch
from repro.models import lm

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=24, global_batch=2, kind="train")

ALL_ARCHS = configs.list_archs()


def _smoke_cfg(arch):
    return configs.get_config(arch, reduced=True)


def _smoke_batch(cfg):
    b = make_batch(cfg, SMOKE_SHAPE, seed=0, step=0)
    return jax.tree.map(jnp.asarray, b)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = _smoke_cfg(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = jax.jit(lambda p, b: lm.forward(p, cfg, b))(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_grads_finite(arch):
    cfg = _smoke_cfg(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    batch = _smoke_batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: lm.lm_loss(q, cfg, b), has_aux=True)(p)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert bool(jnp.isfinite(loss))
    assert metrics["per_example_nll"].shape == (batch["tokens"].shape[0],)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # at least one non-zero grad per major subtree
    total = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert total > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_loss_is_near_uniform_at_init(arch):
    """Sanity: random init ⇒ per-token NLL ≈ ln(vocab) (within a factor)."""
    cfg = _smoke_cfg(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    batch = _smoke_batch(cfg)
    _, metrics = lm.lm_loss(params, cfg, batch)
    expected = np.log(cfg.vocab_size)
    assert 0.3 * expected < float(metrics["loss"]) < 3.0 * expected
