"""Elastic data pipeline guarantees (determinism / elasticity / resume)."""
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.pipeline import ShardInfo, StreamLoader

SHAPE = ShapeConfig("t", seq_len=16, global_batch=8, kind="train")


def _loader(rank=0, size=1, seed=0):
    cfg = configs.get_config("llama3.2-1b", reduced=True)
    return StreamLoader(cfg, SHAPE, seed=seed, shard=ShardInfo(rank, size))


def test_determinism_same_step():
    a = _loader().batch_for_step(3)
    b = _loader().batch_for_step(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["example_ids"], b["example_ids"])


def test_elastic_repartition_preserves_global_stream():
    """Union of per-rank batches must be identical for dp=2 and dp=4."""
    def union(size):
        rows = {}
        for r in range(size):
            b = _loader(rank=r, size=size).batch_for_step(5)
            for i, eid in enumerate(b["example_ids"]):
                rows[int(eid)] = b["tokens"][i]
        return rows
    u2, u4 = union(2), union(4)
    assert set(u2) == set(u4)
    for eid in u2:
        np.testing.assert_array_equal(u2[eid], u4[eid])


def test_steps_are_disjoint():
    ids0 = _loader().example_ids(0)
    ids1 = _loader().example_ids(1)
    assert set(ids0).isdisjoint(ids1)


def test_resume_mid_stream():
    full = [_loader().batch_for_step(s)["tokens"] for s in range(4)]
    resumed = [_loader().batch_for_step(s)["tokens"] for s in range(2, 4)]
    np.testing.assert_array_equal(full[2], resumed[0])
    np.testing.assert_array_equal(full[3], resumed[1])


def test_seed_changes_stream():
    a = _loader(seed=0).batch_for_step(0)["tokens"]
    b = _loader(seed=1).batch_for_step(0)["tokens"]
    assert not np.array_equal(a, b)
