"""Analytic model (core/shp.py) vs brute force and vs the paper's numbers."""
import itertools
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import costs, shp


# ---------------------------------------------------------------------------
# eqs. 5/9/10 + 6/11/12: write probabilities and expected cumulative writes
# ---------------------------------------------------------------------------

def brute_force_expected_writes(n: int, k: int) -> float:
    """Average writes over ALL permutations of n ranked docs (exact)."""
    total = 0
    count = 0
    for perm in itertools.permutations(range(n)):
        writes = 0
        for i in range(n):
            # doc i writes iff it's in the top-k of perm[:i+1]
            if sorted(perm[: i + 1], reverse=True).index(perm[i]) < k:
                writes += 1
        total += writes
        count += 1
    return total / count


@pytest.mark.parametrize("n,k", [(5, 1), (6, 2), (6, 3), (7, 2)])
def test_expected_writes_matches_brute_force(n, k):
    analytic = float(shp.expected_cum_writes(n - 1, k))
    brute = brute_force_expected_writes(n, k)
    assert math.isclose(analytic, brute, rel_tol=1e-12), (analytic, brute)


def test_p_write_formula():
    i = np.arange(20)
    p = shp.p_write(i, k=3)
    assert np.all(p[:3] == 1.0)  # eq. 9: first K always write
    np.testing.assert_allclose(p[3:], 3.0 / (i[3:] + 1.0))  # eq. 10


def test_harmonic_exact_and_asymptotic_agree():
    # crossover at 1e6; check continuity across the boundary region
    n = np.array([1000, 999_999, 1_000_001, 10_000_000], dtype=np.float64)
    h = shp.harmonic(n)
    ref = [np.log(x) + shp.EULER_GAMMA + 1 / (2 * x) for x in n]
    np.testing.assert_allclose(h, ref, rtol=1e-6)
    assert math.isclose(float(shp.harmonic(5)), 1 + 1 / 2 + 1 / 3 + 1 / 4 + 1 / 5,
                        rel_tol=1e-12)


def test_algo_b_k1_harmonic_writes():
    # eqs. 6-7: E[#writes] = H_N ≈ ln N + 0.57722
    n = 100_000
    exact = float(shp.expected_cum_writes(n - 1, 1))
    assert math.isclose(exact, math.log(n) + 0.57722, rel_tol=1e-4)


def test_classic_shp_constants():
    assert math.isclose(shp.classic_r_optimal(1000), 1000 / math.e)
    assert math.isclose(shp.classic_p_best(), 1 / math.e)
    assert shp.classic_expected_writes() == 1.0


def test_writes_split_sums_to_total():
    n, k = 10**6, 100
    for r in [150, 1000, 12345, n // 2, n - 1]:
        wa, wb = shp.expected_writes_split(n, k, r, exact=True)
        total = float(shp.expected_cum_writes(n - 1, k))
        assert math.isclose(wa + wb, total, rel_tol=1e-9)


# ---------------------------------------------------------------------------
# eqs. 17/21: closed-form r* equals the numeric argmin of the cost curve
# ---------------------------------------------------------------------------

cost_strategy = st.floats(min_value=1e-8, max_value=1e-3, allow_nan=False)


@st.composite
def valid_cost_models(draw, migrate: bool):
    """Random cost structures for which eq. 22 holds (K < r* < N)."""
    n, k = 100_000, 100
    cw_a = draw(cost_strategy)
    cw_b = draw(cost_strategy)
    other_a = draw(cost_strategy)
    other_b = draw(cost_strategy)
    tier_a = costs.TierCosts("a", put_per_doc=cw_a,
                             get_per_doc=0.0 if migrate else other_a,
                             storage_per_gb_month=other_a if migrate else 0.0)
    tier_b = costs.TierCosts("b", put_per_doc=cw_b,
                             get_per_doc=0.0 if migrate else other_b,
                             storage_per_gb_month=other_b if migrate else 0.0)
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1.0, window_months=1.0)
    return costs.TwoTierCostModel(tier_a=tier_a, tier_b=tier_b, workload=wl)


@given(valid_cost_models(migrate=False))
@settings(max_examples=60, deadline=None)
def test_r_opt_no_migration_is_argmin(cm):
    r = shp.r_optimal_no_migration(cm)
    if not shp.r_is_valid(cm, r):
        return  # eq. 22 gate — plan_placement falls back; nothing to check here
    n = cm.workload.n_docs
    rs = np.linspace(cm.workload.k + 1, n - 1, 4001)
    curve = [shp.cost_no_migration(cm, float(x)).total for x in rs]
    num_opt = rs[int(np.argmin(curve))]
    best = shp.cost_no_migration(cm, r).total
    assert best <= min(curve) + 1e-9 * abs(min(curve)) or abs(num_opt - r) / n < 2e-3


@given(valid_cost_models(migrate=True))
@settings(max_examples=60, deadline=None)
def test_r_opt_migration_is_argmin(cm):
    r = shp.r_optimal_migration(cm)
    if not shp.r_is_valid(cm, r):
        return
    n = cm.workload.n_docs
    rs = np.linspace(cm.workload.k + 1, n - 1, 4001)
    curve = [shp.cost_with_migration(cm, float(x)).total for x in rs]
    assert shp.cost_with_migration(cm, r).total <= min(curve) + 1e-9 * abs(min(curve)) \
        or abs(rs[int(np.argmin(curve))] - r) / n < 2e-3


def test_plan_placement_picks_cheapest():
    for cm in (costs.case_study_1(), costs.case_study_2()):
        plan = shp.plan_placement(cm)
        totals = [c.total for c in plan.candidates]
        assert plan.best.total == min(totals)
        assert len(plan.candidates) >= 2


# ---------------------------------------------------------------------------
# Paper Tables I & II (the reproduction targets; see DESIGN.md §1.1/§9)
# ---------------------------------------------------------------------------

def test_case_study_1_reproduces_paper():
    cm = costs.case_study_1()
    r = shp.r_optimal_no_migration(cm)
    assert abs(r / cm.workload.n_docs - 0.41233169) < 5e-4  # paper's r*/N
    assert abs(shp.cost_no_migration(cm, r).total - 35.19) < 0.02
    assert abs(shp.cost_single_tier(cm, "a").total - 37.20) < 0.01
    # migration strategy evaluated at the same r (paper Table I row).
    # Eq. 20 excludes the final read; the paper's 49.29 sits between the
    # with-read (49.286) and without-read (49.250) conventions — see DESIGN §1.1.
    assert abs(shp.cost_with_migration(cm, 0.41233169 * cm.workload.n_docs).total
               - 49.29) < 0.05


def test_case_study_2_reproduces_paper():
    cm = costs.case_study_2()
    r = shp.r_optimal_migration(cm)
    assert abs(r / cm.workload.n_docs - 0.078) < 1e-3
    assert abs(shp.cost_with_migration(cm, r).total - 142.82) < 2.1  # eq. 20 (±1.4%)
    assert abs(shp.cost_single_tier(cm, "a").total - 350.00) < 1e-6
    # eq. 17 is invalid here (EFS transactions are free) → gate must trip
    assert not shp.r_is_valid(cm, shp.r_optimal_no_migration(cm))


def test_cost_curve_minimum_at_r_opt():
    cm = costs.case_study_1()
    curve = shp.cost_curve(cm, migrate=False, num=2048)
    r_opt = shp.r_optimal_no_migration(cm) / cm.workload.n_docs
    num_min = curve[np.argmin(curve[:, 1]), 0]
    assert abs(num_min - r_opt) < 2e-3
