"""Pallas flash attention vs the jnp oracle: shape/dtype/mask sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops, ref


def _mk(b, sq, skv, h, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, h, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, h, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,skv,h,hd", [
    (1, 128, 128, 2, 64),
    (2, 256, 256, 1, 32),
    (1, 100, 100, 2, 64),   # padded tails
    (1, 64, 192, 2, 32),    # cross lengths (q is the suffix)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_causal(b, sq, skv, h, hd, dtype):
    q, k, v = _mk(b, sq, skv, h, hd, dtype)
    out_k = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    out_r = ref.flash_attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    q, k, v = _mk(1, 128, 128, 2, 32, jnp.float32)
    out_k = ops.flash_attention(q, k, v, causal=True, window=window,
                                block_q=32, block_k=32)
    out_r = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_non_causal():
    q, k, v = _mk(1, 64, 64, 2, 32, jnp.float32)
    out_k = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    out_r = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention_path():
    """Same math as models.attention.grouped_attention (expanded heads)."""
    from repro.models import attention as A
    q, k, v = _mk(2, 64, 64, 4, 32, jnp.float32, seed=7)
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (2, 64))
    out_model = A.grouped_attention(q, k, v, pos, pos, causal=True, window=0)
    out_kernel = ops.flash_attention(q, k, v, causal=True, block_q=32,
                                     block_k=32)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               rtol=2e-5, atol=2e-5)
