"""End-to-end system behaviour: training converges, resumes deterministically,
curation reconciles with the analytic SHP model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.core import placement, shp, tiers
from repro.data.curation import TopKCurator
from repro.data.pipeline import StreamLoader
from repro.runtime import steps as steps_mod
from repro.runtime import train_loop

SHAPE = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")


def _cfg():
    return configs.get_config("llama3.2-1b", reduced=True)


def test_loss_decreases():
    cfg = _cfg()
    loader = StreamLoader(cfg, SHAPE, seed=0)
    rep = train_loop.run(cfg, loader, loop=train_loop.LoopConfig(
        total_steps=30, ckpt_every=1000, lr=3e-3))
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.2, (first, last)


def test_resume_is_deterministic(tmp_path):
    cfg = _cfg()
    loop = train_loop.LoopConfig(total_steps=8, ckpt_every=4, lr=1e-3)
    # uninterrupted run
    loader = StreamLoader(cfg, SHAPE, seed=1)
    rep_a = train_loop.run(cfg, loader, loop=loop,
                           ckpt=CheckpointManager(str(tmp_path / "a")))
    # interrupted at 4, then resumed
    mgr_b = CheckpointManager(str(tmp_path / "b"))
    rep_b1 = train_loop.run(cfg, loader, loop=train_loop.LoopConfig(
        total_steps=4, ckpt_every=4, lr=1e-3), ckpt=mgr_b)
    rep_b2 = train_loop.run(cfg, loader, loop=loop, ckpt=mgr_b)
    assert rep_b2.resumed_from == 4
    wa = rep_a.final_state.params["embed"]
    wb = rep_b2.final_state.params["embed"]
    np.testing.assert_allclose(np.asarray(wa), np.asarray(wb), rtol=1e-6)


def test_reservoir_in_train_state_tracks_hardest_examples():
    cfg = _cfg()
    loader = StreamLoader(cfg, SHAPE, seed=2)
    state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(0),
                                       reservoir_k=16)
    step_fn = jax.jit(lambda s, b: steps_mod.train_step(s, b, cfg))
    for step in range(6):
        batch = jax.tree.map(jnp.asarray, loader.batch_for_step(step))
        state, metrics = step_fn(state, batch)
    ids = np.asarray(state.reservoir.ids)
    assert (ids >= 0).sum() == 16  # full after 48 examples
    assert int(state.reservoir.seen) == 48


def test_curation_reconciles_with_analytic_model():
    """Host curator ledger ≈ eq. 11/12 writes; survivors = exact top-K."""
    cfg = _cfg()
    k = 12
    rng = np.random.default_rng(0)
    n = 600
    scores = rng.permutation(n).astype(np.float64)
    pol = placement.Policy(r=n // 3, migrate_at_r=False)
    store = tiers.TieredStore(pol, tiers.HotTier(k, (4,)), tiers.ColdTier())
    cur = TopKCurator(k, store, policy=pol)
    payloads = np.arange(n * 4, dtype=np.float32).reshape(n, 4)
    b = 25
    for off in range(0, n, b):
        ids = np.arange(off, off + b)
        cur.observe_batch(ids, scores[off:off + b], payloads[off:off + b])
    # exact top-k survivors
    expect = set(np.argsort(-scores)[:k].tolist())
    assert set(cur.survivor_ids().tolist()) == expect
    final = cur.finalize()
    for doc, arr in final.items():
        np.testing.assert_array_equal(arr, payloads[doc])
    # per-element (batch order preserved) writes ≈ analytic within 35%
    analytic = float(shp.expected_cum_writes(n - 1, k))
    assert abs(cur.stats.writes - analytic) / analytic < 0.35
    # ledger consistency: writes split across tiers by policy threshold
    assert cur.stats.writes == int(store.ledger.writes.sum())


def test_straggler_detection():
    import time as _t
    cfg = _cfg()
    loader = StreamLoader(cfg, SHAPE, seed=3)
    slow = {"n": 0}
    orig = train_loop.time.time
    rep = train_loop.run(cfg, loader, loop=train_loop.LoopConfig(
        total_steps=12, ckpt_every=1000, straggler_factor=50.0))
    assert rep.straggler_steps == 0  # uniform CPU steps — no false positives
