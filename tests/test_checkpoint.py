"""Checkpoint manager: atomic roundtrip, retention, tiering, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.placement import Policy


def make_state(x: float):
    return {"w": jnp.full((4, 3), x), "opt": {"m": jnp.full((2,), x * 2)},
            "step": jnp.asarray(int(x), jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state(3.0)
    mgr.save(state, step=3, metric=0.5, blocking=True)
    restored = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 state, restored)


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(make_state(1.0), step=1, metric=1.0)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_latest_and_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_latest=10)
    for s in (1, 2, 3):
        mgr.save(make_state(float(s)), step=s, metric=float(s), blocking=True)
    assert mgr.latest_step() == 3
    st = mgr.restore(make_state(0.0), step=2)
    assert float(st["w"][0, 0]) == 2.0


def test_retention_keeps_latest_and_best(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_latest=1, keep_best=2,
                            metric_mode="min")
    metrics = {1: 5.0, 2: 0.1, 3: 4.0, 4: 0.2, 5: 9.0}
    for s, m in metrics.items():
        mgr.save(make_state(float(s)), step=s, metric=m, blocking=True)
    steps = {m["step"] for m, _ in mgr._all_ckpts()}
    assert 5 in steps  # latest
    assert 2 in steps and 4 in steps  # two best by metric
    assert 1 not in steps and 3 not in steps


def test_tier_placement_by_policy(tmp_path):
    hot = tmp_path / "hot"
    cold = tmp_path / "cold"
    # first 2 saves to tier A (hot), the rest to tier B (cold)
    mgr = CheckpointManager(str(hot), cold_directory=str(cold),
                            keep_latest=10, policy=Policy(r=2))
    for s in range(4):
        mgr.save(make_state(float(s)), step=s, metric=1.0, blocking=True)
    hot_names = {d for d in os.listdir(hot) if d.startswith("ckpt_")}
    cold_names = {d for d in os.listdir(cold) if d.startswith("ckpt_")}
    assert len(hot_names) == 2 and len(cold_names) == 2


def test_torn_save_is_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(make_state(1.0), step=1, metric=1.0, blocking=True)
    # simulate a torn save: directory without manifest
    os.makedirs(tmp_path / "ckpt_00000009")
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# format v2: checksums, generation lineage, manifest extra
# ---------------------------------------------------------------------------

def test_corrupt_leaf_detected(tmp_path):
    """Restore verifies every leaf against its manifest sha256: a
    flipped byte raises instead of silently resuming from garbage."""
    from repro.checkpoint.manager import CheckpointCorruptError
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(make_state(2.0), step=2, blocking=True)
    leaf = tmp_path / "ckpt_00000002" / "leaf_00000.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(make_state(0.0))
    # verify=False is the explicit escape hatch (forensics)
    mgr.restore(make_state(0.0), verify=False)


def test_generation_monotone_across_restarts(tmp_path):
    """The generation counter resumes from disk, so lineage stays
    totally ordered across crash/restore cycles even when steps repeat."""
    mgr = CheckpointManager(str(tmp_path), keep_latest=10)
    g1 = mgr.save(make_state(1.0), step=1, blocking=True)
    g2 = mgr.save(make_state(2.0), step=2, blocking=True)
    assert g2 > g1
    mgr2 = CheckpointManager(str(tmp_path), keep_latest=10)  # "restart"
    assert mgr2.generation() == g2
    g3 = mgr2.save(make_state(9.0), step=2, blocking=True)  # re-save step
    assert g3 > g2
    assert mgr2.manifest(2)["generation"] == g3


def test_manifest_extra_roundtrip(tmp_path):
    """Variable-length host state (event logs, outage bookkeeping) rides
    the manifest's ``extra`` and comes back JSON-identical."""
    mgr = CheckpointManager(str(tmp_path))
    extra = {"events": [{"row": 1, "bounds": [4.0, 9.0]}],
             "failed_tiers": {"1": 3}}
    mgr.save(make_state(1.0), step=1, blocking=True, extra=extra)
    assert mgr.manifest()["extra"] == json.loads(json.dumps(extra))
    assert "extra" not in mgr.manifest(1) or \
        mgr.manifest(1)["extra"]["failed_tiers"] == {"1": 3}


def test_torn_async_save_keeps_previous(tmp_path):
    """A .tmp directory left by a torn async write is never listed as a
    checkpoint; the previous committed one still restores."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(make_state(1.0), step=1, blocking=True)
    os.makedirs(tmp_path / "ckpt_00000005.tmp")
    (tmp_path / "ckpt_00000005.tmp" / "leaf_00000.npy").write_bytes(b"torn")
    assert mgr.latest_step() == 1
    st = mgr.restore(make_state(0.0))
    assert float(st["w"][0, 0]) == 1.0
