"""Constraint-aware planning stack (core/constraints.py + the constrained
solver in core/shp.py + fleet threading): bit-exact degradation to the
unconstrained closed form, brute-force feasible-grid agreement on random
constrained 3/4-tier models, capacity clamping / SLO semantics, fleet-shared
capacity water-filling, occupancy metering, and minimum-storage-duration
billing."""
import math

import numpy as np
import pytest

from repro.core import costs, placement, shp, simulator, topology
from repro.core.constraints import (ConstraintSet, ReadLatencySLO,
                                    TierCapacity, expected_read_latency,
                                    peak_occupancy)
from repro.streams import StreamEngine, StreamSpec, planner, waterfill


def random_ntier_model(rng, t, with_latency=True):
    n = int(rng.integers(2_000, 200_000))
    k = int(rng.integers(1, max(2, n // 10)))
    specs = tuple(
        topology.TierSpec(
            costs.TierCosts(f"t{i}", *(10.0 ** rng.uniform(-8, -3, 3))),
            xfer_in_per_gb=float(10.0 ** rng.uniform(-7, -3)),
            xfer_out_per_gb=float(10.0 ** rng.uniform(-6, -2)),
            read_latency_s=(float(10.0 ** rng.uniform(-3, 2))
                            if with_latency else 0.0))
        for i in range(t))
    wl = costs.WorkloadSpec(n_docs=n, k=k,
                            doc_gb=float(rng.uniform(1e-4, 1.0)),
                            window_months=float(rng.uniform(0.03, 3.0)))
    return topology.TierTopology(tiers=specs).cost_model(wl)


def random_constraints(rng, cm):
    t, k = cm.t, cm.workload.k
    cons = [TierCapacity(int(rng.integers(0, t)),
                         float(k * rng.uniform(0.1, 2.0)))]
    if rng.uniform() < 0.4:
        lo = max(float(np.min(cm.read_latency)), 1e-6)
        hi = float(np.max(cm.read_latency)) + 1e-6
        cons.append(ReadLatencySLO(float(
            10.0 ** rng.uniform(np.log10(lo), np.log10(hi)))))
    return ConstraintSet(*cons)


# ---------------------------------------------------------------------------
# Degradation: empty / trivial constraints reproduce the closed form exactly
# ---------------------------------------------------------------------------

def test_empty_constraint_set_bit_identical():
    rng = np.random.default_rng(0)
    for t in (2, 3, 4):
        for _ in range(10):
            m = random_ntier_model(rng, t)
            p0 = shp.plan_placement_ntier(m)
            p1 = shp.plan_placement_ntier(m, constraints=ConstraintSet())
            assert p0.total == p1.total  # bit-identical, not isclose
            assert p0.boundaries == p1.boundaries
            assert p0.migrate == p1.migrate and p0.strategy == p1.strategy


def test_forced_constrained_path_trivial_constraints_bit_identical():
    """The resource-augmented machinery itself (not just the dispatch)
    must reproduce the unconstrained DP when every mask is trivial."""
    rng = np.random.default_rng(1)
    for t in (2, 3, 4):
        m_models = [random_ntier_model(rng, t) for _ in range(16)]
        cw = np.stack([m.cw for m in m_models])
        cr = np.stack([m.cr for m in m_models])
        cs = np.stack([m.cs for m in m_models])
        n = np.array([float(m.workload.n_docs) for m in m_models])
        k = np.array([float(m.workload.k) for m in m_models])
        rpw = np.ones(len(m_models))
        a = shp.plan_ntier_arrays(cw, cr, cs, n, k, rpw)
        b = shp.plan_ntier_arrays(cw, cr, cs, n, k, rpw,
                                  force_constrained=True)
        np.testing.assert_array_equal(a["total"], b["total"])
        np.testing.assert_array_equal(a["bounds"], b["bounds"])
        np.testing.assert_array_equal(a["migrate"], b["migrate"])


def test_t2_case_studies_unchanged_under_empty_constraints():
    for case in (costs.case_study_1, costs.case_study_2):
        cm = case()
        legacy = shp.plan_placement(cm)
        via_cons = shp.plan_placement(cm, constraints=ConstraintSet())
        assert isinstance(legacy, shp.PlacementPlan)
        assert math.isclose(via_cons.total if hasattr(via_cons, "total")
                            else via_cons.best.total, legacy.best.total,
                            rel_tol=1e-12)


# ---------------------------------------------------------------------------
# Brute-force feasible-grid agreement (the acceptance bar: >= 100 models)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,seed,count", [(3, 11, 60), (4, 12, 60)])
def test_constrained_matches_brute_force_feasible_grid(t, seed, count):
    rng = np.random.default_rng(seed)
    checked = infeasible = 0
    for trial in range(count):
        m = random_ntier_model(rng, t)
        cset = random_constraints(rng, m)
        plan = shp.plan_placement_ntier(m, constraints=cset)
        bt, bb, bm = shp.brute_force_plan_ntier(m, grid=48,
                                                constraints=cset)
        if not plan.feasible:
            infeasible += 1
            assert not np.isfinite(bt), (trial, bt, bb, bm)
            continue
        checked += 1
        # the plan the DP returns must be genuinely feasible ...
        assert cset.feasible(m, plan.boundaries, plan.migrate), \
            (trial, plan.boundaries, plan.migrate)
        # ... and never lose to any feasible grid point
        assert plan.total <= bt * (1 + 1e-9) + 1e-12, \
            (trial, plan.total, bt, plan.strategy, bm)
        # the grid can only beat the closed form by grid resolution
        assert abs(plan.total - bt) <= 2e-2 * abs(bt) + 1e-12, \
            (trial, plan.total, bt)
    assert checked >= count * 0.8  # the generator rarely lands infeasible


def test_deep_hierarchy_quantized_resource_dp():
    """5-tier models have 4 boundary steps — past _ENUM_MAX_STEPS — so an
    active SLO routes through the quantized resource-augmented DP. The
    conservative rounding must keep every returned plan genuinely
    feasible, within shouting distance of the feasible grid."""
    rng = np.random.default_rng(61)
    checked = 0
    for trial in range(10):
        m = random_ntier_model(rng, 5)
        k = m.workload.k
        lo = max(float(np.min(m.read_latency)), 1e-6)
        hi = float(np.max(m.read_latency)) + 1e-6
        cset = ConstraintSet(
            TierCapacity(int(rng.integers(0, 5)),
                         float(k * rng.uniform(0.2, 2.0))),
            ReadLatencySLO(float(10.0 ** rng.uniform(np.log10(lo),
                                                     np.log10(hi)))))
        plan = shp.plan_placement_ntier(m, constraints=cset)
        bt, _, _ = shp.brute_force_plan_ntier(m, grid=24, constraints=cset)
        if not plan.feasible:
            assert not np.isfinite(bt)
            continue
        checked += 1
        assert cset.feasible(m, plan.boundaries, plan.migrate), (trial,)
        if np.isfinite(bt):
            # quantization is conservative: the DP may concede a little
            # to the exact grid, but must stay in the same ballpark
            assert plan.total <= bt * 1.15 + 1e-12, (trial, plan.total, bt)


def test_infeasible_constraints_reported_not_planned():
    m = random_ntier_model(np.random.default_rng(5), 3, with_latency=True)
    # every tier capped below K -> nothing can hold the reservoir
    cset = ConstraintSet(*[TierCapacity(t, m.workload.k * 0.3)
                           for t in range(3)])
    plan = shp.plan_placement_ntier(m, constraints=cset)
    assert not plan.feasible and plan.strategy == "infeasible"
    assert not np.isfinite(plan.total)
    with pytest.raises(ValueError):
        placement.from_plan(plan)
    bt, _, _ = shp.brute_force_plan_ntier(m, constraints=cset)
    assert not np.isfinite(bt)


# ---------------------------------------------------------------------------
# Constraint semantics: capacity clamps, SLO walks off slow tiers
# ---------------------------------------------------------------------------

def nvme_s3_model(n=int(1e7), k=int(1e5)):
    nvme = costs.TierCosts("nvme", 0.0, 0.0, 0.01)
    s3 = costs.TierCosts("s3", 0.005 / 1000, 0.0004 / 1000, 0.023)
    topo = topology.TierTopology(tiers=(
        topology.TierSpec(nvme, xfer_out_per_gb=0.2, read_latency_s=1e-4),
        topology.TierSpec(s3, xfer_in_per_gb=0.02, read_latency_s=0.02)))
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-4, window_months=1.0)
    return topo.cost_model(wl)


def test_hot_capacity_below_k_forces_early_demotion():
    m = nvme_s3_model()
    k = m.workload.k
    free = shp.plan_placement_ntier(m)
    assert free.boundaries[0] > k  # unconstrained holds the reservoir hot
    cap0 = k // 20
    plan = shp.plan_placement_ntier(
        m, constraints=ConstraintSet(TierCapacity(0, cap0)))
    assert plan.feasible and not plan.migrate
    assert plan.boundaries[0] == pytest.approx(cap0)
    occ = peak_occupancy(plan.boundaries, m.workload.n_docs, k, plan.migrate)
    assert occ[0] <= cap0 * (1 + 1e-9)
    assert plan.total >= free.total  # constraints never help


def test_capacity_below_k_walks_cascade_off_the_capped_tier():
    """The cascade holds the whole reservoir in every used tier
    (boundaries gated to [K, N)), so a capacity below K on the hot tier
    forces any surviving migration plan to skip that tier entirely —
    its segment collapses to zero width and its occupancy to zero."""
    topo = topology.aws_efs_s3_glacier()
    wl = costs.WorkloadSpec(n_docs=int(1e8), k=int(1e5), doc_gb=1e-3,
                            window_months=3.0)
    m = topo.cost_model(wl)
    base = shp.plan_placement_ntier(m)
    assert base.migrate  # baseline: cascade wins
    assert base.boundaries[0] > 0  # and genuinely uses the EFS tier
    cap = wl.k // 2
    plan = shp.plan_placement_ntier(
        m, constraints=ConstraintSet(TierCapacity(0, cap)))
    assert plan.feasible
    occ = peak_occupancy(plan.boundaries, wl.n_docs, wl.k, plan.migrate)
    assert occ[0] <= cap * (1 + 1e-9)
    if plan.migrate:
        assert plan.boundaries[0] == 0.0  # tier 0 skipped by the cascade


def test_slo_forces_planner_off_cheapest_tier():
    topo = topology.aws_archive_tiering()
    wl = costs.WorkloadSpec(n_docs=int(1e7), k=int(1e5), doc_gb=1e-3,
                            window_months=6.0)
    m = topo.cost_model(wl)
    free = shp.plan_placement_ntier(m)
    lat_free = expected_read_latency(free.boundaries, wl.n_docs,
                                     m.read_latency, free.migrate)
    assert lat_free > 3600.0  # unconstrained parks survivors in Glacier
    for slo in (3600.0, 60.0):
        plan = shp.plan_placement_ntier(
            m, constraints=ConstraintSet(ReadLatencySLO(slo)))
        assert plan.feasible
        lat = expected_read_latency(plan.boundaries, wl.n_docs,
                                    m.read_latency, plan.migrate)
        assert lat <= slo * (1 + 1e-9)
        assert plan.total >= free.total


def test_constraint_protocol_generic_type_used_by_verifier():
    """Any object with feasible(cm, bounds, migrate) plugs into the
    feasible-grid verifier."""

    class NoMigration:
        def feasible(self, cm, bounds, migrate):
            return not migrate

    topo = topology.aws_efs_s3_glacier()
    wl = costs.WorkloadSpec(n_docs=int(1e8), k=int(1e5), doc_gb=1e-3,
                            window_months=3.0)
    m = topo.cost_model(wl)
    bt_free, _, bm_free = shp.brute_force_plan_ntier(m)
    assert bm_free
    bt, _, bm = shp.brute_force_plan_ntier(
        m, constraints=ConstraintSet(NoMigration()))
    assert not bm and bt >= bt_free


# ---------------------------------------------------------------------------
# Fleet threading: plan_fleet masks, water-filling, no oversubscription
# ---------------------------------------------------------------------------

def test_plan_fleet_constrained_matches_scalar_constrained():
    rng = np.random.default_rng(21)
    models = []
    for _ in range(24):
        n = int(rng.integers(2_000, 100_000))
        wl = costs.WorkloadSpec(n_docs=n, k=int(rng.integers(1, n // 10)),
                                doc_gb=1.0, window_months=1.0)
        models.append(costs.TwoTierCostModel(
            tier_a=costs.TierCosts("a", *(rng.uniform(1e-8, 1e-3, 3))),
            tier_b=costs.TierCosts("b", *(rng.uniform(1e-8, 1e-3, 3))),
            workload=wl))
    cset = ConstraintSet(TierCapacity(0, 50.0))
    plan = planner.plan_fleet(models, constraints=cset)
    assert plan.feasible is not None
    for i, cm in enumerate(models):
        ref = shp.plan_placement(cm, constraints=cset)
        if not plan.feasible[i]:
            assert not ref.feasible
            continue
        np.testing.assert_allclose(plan.r[i], ref.boundaries[0],
                                   rtol=1e-9, atol=1e-9)
        occ = peak_occupancy((plan.r[i],), cm.workload.n_docs,
                             cm.workload.k, plan.migrate(i))
        assert occ[0] <= 50.0 * (1 + 1e-9)


def test_waterfill_conserves_budget_and_caps():
    rng = np.random.default_rng(2)
    for _ in range(50):
        d = rng.uniform(0.0, 100.0, size=rng.integers(1, 40))
        budget = float(rng.uniform(0.0, 1.2 * d.sum()))
        g = waterfill(d, budget)
        assert np.all(g <= d + 1e-9)
        if d.sum() <= budget:
            np.testing.assert_allclose(g, d)
        else:
            assert abs(g.sum() - budget) < 1e-6 * max(budget, 1.0)
            # binding streams share one water level
            lam = g[g < d - 1e-9]
            if lam.size:
                np.testing.assert_allclose(lam, lam[0], rtol=1e-9)


def test_fleet_shared_capacity_never_oversubscribes():
    rng = np.random.default_rng(23)
    for trial in range(6):
        models = [random_ntier_model(rng, int(rng.integers(2, 4)),
                                     with_latency=False)
                  for _ in range(10)]
        total_k = sum(m.workload.k for m in models)
        budget = float(total_k * rng.uniform(0.1, 0.6))
        cset = ConstraintSet(TierCapacity(0, budget, shared=True))
        plan = planner.plan_fleet_mixed(models, constraints=cset)
        occ = sum(
            peak_occupancy(plan.boundaries[i], m.workload.n_docs,
                           m.workload.k, plan.migrate(i))[0]
            for i, m in enumerate(models) if plan.feasible(i))
        assert occ <= budget * (1 + 1e-9), (trial, occ, budget)


def test_engine_rejects_infeasible_constrained_fleet():
    m = nvme_s3_model(n=4_000, k=64)
    cset = ConstraintSet(TierCapacity(0, 10.0), TierCapacity(1, 10.0))
    with pytest.raises(ValueError, match="no feasible plan"):
        StreamEngine([StreamSpec(stream_id=0, k=64, cost_model=m)],
                     constraints=cset)


def test_shared_capacity_rejected_outside_waterfill_path():
    m = nvme_s3_model(n=4_000, k=64)
    shared = ConstraintSet(TierCapacity(0, 5.0, shared=True))
    with pytest.raises(ValueError, match="plan_fleet_mixed"):
        planner.plan_fleet([costs.case_study_1()], constraints=shared)
    with pytest.raises(ValueError, match="fleet-wide"):
        planner.plan_fleet_mixed([m, m], constraints=[shared, shared])


def test_two_shared_tiers_neither_oversubscribes():
    """Re-planning for the second shared tier must not push the first
    back over its budget (binding streams are frozen at their granted
    usage of already-balanced tiers)."""
    rng = np.random.default_rng(53)
    for trial in range(4):
        models = [random_ntier_model(rng, 3, with_latency=False)
                  for _ in range(8)]
        total_k = sum(m.workload.k for m in models)
        c0 = float(total_k * rng.uniform(0.1, 0.4))
        c1 = float(total_k * rng.uniform(0.1, 0.4))
        plan = planner.plan_fleet_mixed(models, constraints=ConstraintSet(
            TierCapacity(0, c0, shared=True),
            TierCapacity(1, c1, shared=True)))
        for tier, budget in ((0, c0), (1, c1)):
            occ = sum(peak_occupancy(plan.boundaries[i],
                                     m.workload.n_docs, m.workload.k,
                                     plan.migrate(i))[tier]
                      for i, m in enumerate(models) if plan.feasible(i))
            assert occ <= budget * (1 + 1e-9), (trial, tier, occ, budget)


def test_byte_capacity_checked_with_doc_gb():
    docs, k = 32, 4
    eng = StreamEngine([StreamSpec(stream_id=0, k=k, r=float(docs))])
    for t in range(docs):
        eng.ingest([0], [float(t)], [t])
    eng.finalize()
    byte_cap = ConstraintSet(TierCapacity(0, max_bytes=2 * 1e9 * 1e-3))
    with pytest.raises(ValueError, match="doc_gb"):
        eng.check_constraints(byte_cap)
    # 4 docs x 1MB resident > 2MB budget -> flagged; 1KB docs fit
    assert not eng.check_constraints(byte_cap, doc_gb=1e-3)["ok"]
    assert eng.check_constraints(byte_cap, doc_gb=1e-6)["ok"]


def test_topology_declared_caps_survive_explicit_constraint_sets():
    """Adding an unrelated constraint must not drop a topology-declared
    capacity; an explicit TierCapacity on that tier overrides it."""
    nvme = costs.TierCosts("nvme", 0.0, 0.0, 0.01)
    s3 = costs.TierCosts("s3", 0.005 / 1000, 0.0004 / 1000, 0.023)
    cap0 = 5_000.0
    topo = topology.TierTopology(tiers=(
        topology.TierSpec(nvme, xfer_out_per_gb=0.2, read_latency_s=1e-4,
                          capacity_docs=cap0),
        topology.TierSpec(s3, xfer_in_per_gb=0.02, read_latency_s=0.02)))
    wl = costs.WorkloadSpec(n_docs=int(1e7), k=int(1e5), doc_gb=1e-4,
                            window_months=1.0)
    m = topo.cost_model(wl)
    # a non-binding SLO must keep the declared C_0 enforced
    slo_only = shp.plan_placement_ntier(
        m, constraints=ConstraintSet(ReadLatencySLO(1e9)))
    assert slo_only.boundaries[0] <= cap0 * (1 + 1e-9)
    # explicit inf on tier 0 lifts the declaration (the what-if baseline)
    lifted = shp.plan_placement_ntier(
        m, constraints=ConstraintSet(TierCapacity(0, math.inf)))
    assert lifted.boundaries[0] > wl.k


def test_brute_force_enforces_topology_declared_caps():
    """The verifier must share the planner's ground truth: a topology
    declaring a hot-tier capacity constrains the feasible grid even with
    no explicit ConstraintSet."""
    m = topology.hbm_dram_disk_preset(n_docs=50_000, k=1_000, doc_gb=1e-5,
                                      window_seconds=600.0,
                                      hbm_capacity_docs=50.0)
    plan = shp.plan_placement_ntier(m)
    bt, bb, bm = shp.brute_force_plan_ntier(m, grid=32)
    occ = peak_occupancy(bb, m.workload.n_docs, m.workload.k, bm)
    assert occ[0] <= 50.0 * (1 + 1e-9)
    assert plan.total <= bt * (1 + 1e-9) + 1e-12


def test_engine_reconciliation_enforces_topology_caps():
    """Topology-declared capacities reach check_constraints through the
    engine's cost models even when the explicit set only carries an SLO."""
    docs, k = 48, 6
    m = topology.hbm_dram_disk_preset(n_docs=docs, k=k, doc_gb=1e-5,
                                      window_seconds=60.0,
                                      hbm_capacity_docs=2.0)
    eng = StreamEngine([StreamSpec(stream_id=0, k=k, cost_model=m)],
                       constraints=ConstraintSet(ReadLatencySLO(1e9)))
    # execute a policy that keeps everything hot, violating the declared cap
    eng2 = StreamEngine([StreamSpec(stream_id=0, k=k, r=float(docs))])
    for t in range(docs):
        eng2.ingest([0], [float(t)], [t])
    eng2.finalize()
    # wire the capacity-declaring model onto the violating run's rows
    eng2._model_of_row[0] = m
    report = eng2.check_constraints(ConstraintSet(ReadLatencySLO(1e9)))
    assert not report["ok"] and report["capacity_violations"][0, 0]
    # the planned engine keeps the declared cap feasible at planning time
    occ = peak_occupancy(eng.meter.boundaries[0][:m.t - 1],
                         docs, k, bool(eng.meter.migrate[0]))
    assert occ[0] <= 2.0 * (1 + 1e-9)


def test_two_tier_slo_rejected_without_latency_metadata():
    with pytest.raises(ValueError, match="read latencies"):
        shp.plan_placement(costs.case_study_1(),
                           constraints=ConstraintSet(ReadLatencySLO(1.0)))


def test_shared_caps_rejected_by_single_stream_planner():
    m = nvme_s3_model(n=4_000, k=64)
    with pytest.raises(ValueError, match="plan_fleet_mixed"):
        shp.plan_placement_ntier(
            m, constraints=ConstraintSet(TierCapacity(0, 5.0, shared=True)))


def test_plan_fleet_rejects_byte_capacities():
    with pytest.raises(ValueError, match="document sizes"):
        planner.plan_fleet([costs.case_study_1()],
                           constraints=ConstraintSet(
                               TierCapacity(0, max_bytes=1e9)))


def test_plan_placement_rejects_exact_with_constraints():
    with pytest.raises(ValueError, match="exact"):
        shp.plan_placement(costs.case_study_1(), exact=True,
                           constraints=ConstraintSet(TierCapacity(0, 10.0)))


def test_meter_shared_byte_budget_checked():
    docs, k = 32, 4
    eng = StreamEngine([StreamSpec(stream_id=0, k=k, r=float(docs))])
    for t in range(docs):
        eng.ingest([0], [float(t)], [t])
    eng.finalize()
    shared = ConstraintSet(TierCapacity(0, max_bytes=2 * 1e9 * 1e-3,
                                        shared=True))
    with pytest.raises(ValueError, match="doc_gb"):
        eng.check_constraints(shared)
    bad = eng.check_constraints(shared, doc_gb=1e-3)  # 4 MB used > 2 MB
    assert not bad["ok"] and "excess_bytes" in bad["shared_violations"][0]
    assert eng.check_constraints(shared, doc_gb=1e-6)["ok"]


def test_plan_fleet_mixed_unconstrained_path_unchanged():
    rng = np.random.default_rng(3)
    models = [random_ntier_model(rng, 3, with_latency=False)
              for _ in range(8)]
    a = planner.plan_fleet_mixed(models)
    b = planner.plan_fleet_mixed(models, constraints=ConstraintSet())
    np.testing.assert_array_equal(a.totals, b.totals)
    assert a.boundaries == b.boundaries


# ---------------------------------------------------------------------------
# Metering: occupancy high-water marks and SLO checks at reconciliation
# ---------------------------------------------------------------------------

def test_meter_occupancy_hwm_matches_simulator():
    rng = np.random.default_rng(31)
    docs, k = 80, 6
    specs = [
        StreamSpec(stream_id=0, k=k, r=float(docs / 3)),
        StreamSpec(stream_id=1, k=k, boundaries=(20.0, 50.0), migrate=True),
        StreamSpec(stream_id=2, k=k, boundaries=(10.0, 40.0)),
    ]
    eng = StreamEngine(specs)
    traces = np.stack([simulator.random_rank_trace(docs, rng)
                       for _ in specs]).astype(np.float32)
    for t in range(docs):
        eng.ingest([s.stream_id for s in specs], traces[:, t],
                   [t] * len(specs))
    eng.finalize()
    for i, s in enumerate(specs):
        pol = placement.Policy(boundaries=s.explicit_boundaries(),
                               migrate_at_r=s.migrate)
        sim = simulator.simulate(traces[i].astype(np.float64), k, pol)
        row = eng.stream_row(s.stream_id)
        t_sim = sim.occupancy_hwm_per_tier.shape[0]
        assert eng.meter.occupancy_hwm[row, :t_sim].tolist() == \
            sim.occupancy_hwm_per_tier.tolist(), (i,)
        assert eng.meter.occupancy_hwm[row, t_sim:].sum() == 0


def test_meter_check_constraints_flags_violations():
    docs, k = 32, 4
    eng = StreamEngine([StreamSpec(stream_id=0, k=k, r=float(docs))])
    for t in range(docs):  # ascending: everything writes, all hot
        eng.ingest([0], [float(t)], [t])
    eng.finalize()
    ok = eng.check_constraints(ConstraintSet(TierCapacity(0, k)),
                               latencies=[1e-4, 0.02])
    assert ok["ok"]
    bad = eng.check_constraints(ConstraintSet(TierCapacity(0, k - 1)))
    assert not bad["ok"] and bad["capacity_violations"][0, 0]
    slo = eng.check_constraints(ConstraintSet(ReadLatencySLO(1e-6)),
                                latencies=[1e-4, 0.02])
    assert not slo["ok"] and slo["slo_violations"][0]


def test_simulator_constraint_report():
    m = nvme_s3_model(n=4_000, k=64)
    pol = placement.Policy(r=800.0)
    res = simulator.simulate(
        simulator.random_rank_trace(4_000, np.random.default_rng(7)),
        64, pol, m)
    assert res.occupancy_hwm_per_tier[0] == 64  # deterministic: b > K
    good = res.check_constraints(ConstraintSet(TierCapacity(0, 64)), m)
    assert good["ok"]
    bad = res.check_constraints(ConstraintSet(TierCapacity(0, 63)), m)
    assert not bad["ok"] and bad["capacity_violations"][0]
    assert res.read_latency_mean > 0.0


# ---------------------------------------------------------------------------
# Minimum-storage-duration billing (S3-IA 30d / Glacier 90d)
# ---------------------------------------------------------------------------

def min_storage_model(min_days, window_months=0.5, n=6_000, k=96):
    hot = costs.TierCosts("hot", 1e-6, 1e-6, 0.02)
    cold = costs.TierCosts("cold", 2e-6, 2e-6, 0.004,
                           min_storage_days=min_days)
    topo = topology.TierTopology(tiers=(topology.TierSpec(hot),
                                        topology.TierSpec(cold)))
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-2,
                            window_months=window_months)
    return topo.cost_model(wl)


def test_min_storage_days_zero_is_bit_identical():
    a, b = min_storage_model(0.0), min_storage_model(90.0)
    # the analytic rental floors at the minimum duration for short windows
    np.testing.assert_array_equal(a.cs[:1], b.cs[:1])
    assert b.cs[1] == pytest.approx(a.cs[1] * (3.0 / 0.5))
    np.testing.assert_array_equal(a.min_storage_months, [0.0, 0.0])
    np.testing.assert_array_equal(b.min_storage_months, [0.0, 3.0])


def test_min_storage_billed_in_simulator():
    rng = np.random.default_rng(41)
    trace = simulator.random_rank_trace(6_000, rng)
    pol = placement.Policy(r=1_000.0)
    free = simulator.simulate(trace, 96, pol, min_storage_model(0.0))
    billed = simulator.simulate(trace, 96, pol, min_storage_model(90.0))
    # identical transactions, strictly more storage: every cold stay is
    # topped up to 3 months (the window itself is only 0.5 months)
    np.testing.assert_array_equal(free.writes_per_tier,
                                  billed.writes_per_tier)
    assert billed.cost_storage > free.cost_storage
    cold_stays = billed.writes_per_tier[1]
    rate = min_storage_model(90.0).storage_per_doc_month[1]
    np.testing.assert_allclose(billed.doc_months_per_tier[1],
                               cold_stays * 3.0, rtol=1e-9)
    assert billed.cost_storage == pytest.approx(
        float(billed.doc_months_per_tier @
              min_storage_model(90.0).storage_per_doc_month))
    assert rate > 0


def test_min_storage_steers_planner_away_for_short_windows():
    """With a 0.5-month window, a 90-day minimum makes the cold tier's
    effective rental 6x — the planner must never prefer it more than the
    un-floored model does."""
    free = shp.plan_placement_ntier(min_storage_model(0.0))
    floored = shp.plan_placement_ntier(min_storage_model(90.0))
    assert floored.total >= free.total - 1e-12


# ---------------------------------------------------------------------------
# Hypothesis properties (seeded sweep fallback, repo convention)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_trivial_constraints_bit_match(seed, t):
    rng = np.random.default_rng(seed)
    m = random_ntier_model(rng, t)
    base = shp.plan_placement_ntier(m)
    trivial = ConstraintSet(TierCapacity(0, np.inf),
                            TierCapacity(t - 1, np.inf))
    via = shp.plan_placement_ntier(m, constraints=trivial)
    assert via.total == base.total
    assert via.boundaries == base.boundaries
    assert via.migrate == base.migrate
    bt, _, _ = shp.brute_force_plan_ntier(m, constraints=trivial)
    assert via.total <= bt * (1 + 1e-9) + 1e-12


def check_shared_capacity_property(seed):
    rng = np.random.default_rng(seed)
    models = [random_ntier_model(rng, int(rng.integers(2, 4)),
                                 with_latency=False) for _ in range(6)]
    budget = float(sum(m.workload.k for m in models)
                   * rng.uniform(0.05, 0.8))
    plan = planner.plan_fleet_mixed(
        models, constraints=ConstraintSet(TierCapacity(0, budget,
                                                       shared=True)))
    occ = sum(peak_occupancy(plan.boundaries[i], m.workload.n_docs,
                             m.workload.k, plan.migrate(i))[0]
              for i, m in enumerate(models) if plan.feasible(i))
    assert occ <= budget * (1 + 1e-9)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([3, 4]))
    @settings(max_examples=30, deadline=None)
    def test_infinite_capacity_bit_matches_property(seed, t):
        check_trivial_constraints_bit_match(seed, t)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_shared_capacity_never_oversubscribes_property(seed):
        check_shared_capacity_property(seed)
else:
    def test_infinite_capacity_bit_matches_property():
        for seed in range(20):
            check_trivial_constraints_bit_match(seed, 3 + seed % 2)

    def test_shared_capacity_never_oversubscribes_property():
        for seed in range(8):
            check_shared_capacity_property(seed)
