"""repro.resilience — crash-consistent checkpointing, fault injection,
and tier-outage degradation.

The acceptance bar is bitwise: kill the run at an arbitrary chunk,
restore the last checkpoint onto a freshly built engine, resume — final
reservoirs, every meter ledger, and the f64-priced cost ledgers must
equal the uninterrupted run's, on exact and logmem backends. Delivery
faults (transients, duplicates, reordering) must be absorbed by the
at-least-once delivery / exactly-once application guard, NaN/Inf scores
by the step's quarantine, and a tier outage must evacuate the failed
tier through the constrained re-solve without burn-alert false fires."""
import numpy as np
import pytest

from repro.obs import Observability, ObsConfig
from repro.obs import metrics as obs_metrics
from repro.online import DriftConfig, ReplanConfig
from repro.resilience import (DeviceLossError, FaultyChunkSource,
                              FleetCheckpointer, TierOutage,
                              TransientDeliveryError, fleet_restore,
                              fleet_snapshot, ingest_with_faults,
                              run_with_recovery)
from repro.resilience.faults import fetch_with_retry
from repro.streams import StreamEngine, StreamSpec

W = 8  # docs per stream per chunk


def _specs(backend="mixed"):
    """Small heterogeneous fleet: three 3-tier exact streams plus (for
    ``mixed``) one logmem stream — two buckets, both reservoir kinds."""
    specs = [StreamSpec(stream_id=i, k=8, boundaries=(16.0, 64.0))
             for i in range(3)]
    if backend == "mixed":
        specs.append(StreamSpec(stream_id=10, k=16, r=32.0,
                                engine="logmem"))
    elif backend == "logmem":
        specs = [StreamSpec(stream_id=i, k=16, r=32.0, engine="logmem")
                 for i in range(3)]
    return specs


def _build(backend="mixed", obs=False):
    return StreamEngine(_specs(backend),
                        obs=Observability(ObsConfig()) if obs else None)


def _chunk_maker(engine, seed=1000):
    """ingest_dense-shaped chunks as a pure function of the index."""
    buckets = [(b.m,) for b in engine.buckets]

    def make_chunk(i):
        r = np.random.default_rng(seed + i)
        dense = []
        for (m,) in buckets:
            s = r.random((m, W)).astype(np.float32)
            ids = np.tile(np.arange(i * W, (i + 1) * W, dtype=np.int32),
                          (m, 1))
            dense.append((s, ids))
        return dense
    return make_chunk


def _assert_same_finals(ref, eng):
    s_ref, s_eng = ref.finalize(), eng.finalize()
    assert set(s_ref) == set(s_eng)
    for sid in s_ref:
        np.testing.assert_array_equal(s_ref[sid], s_eng[sid])
    d_ref, d_eng = ref.meter.state_dict(), eng.meter.state_dict()
    assert set(d_ref) == set(d_eng)
    for key in d_ref:
        np.testing.assert_array_equal(d_ref[key], d_eng[key],
                                      err_msg=f"meter.{key}")


# ---------------------------------------------------------------------------
# snapshot / checkpoint: kill-and-restore is bitwise invisible
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["exact", "mixed", "logmem"])
def test_snapshot_restore_roundtrip_bitwise(backend):
    """fleet_snapshot → fleet_restore into a fresh engine, then resume:
    finals bitwise equal to the uninterrupted run."""
    ref, eng = _build(backend), _build(backend)
    make_chunk = _chunk_maker(ref)
    for i in range(10):
        ref.ingest_dense(make_chunk(i))
    for i in range(6):
        eng.ingest_dense(make_chunk(i))
    tree, meta = fleet_snapshot(eng)
    eng2 = _build(backend)
    fleet_restore(eng2, tree, meta)
    assert eng2.chunks_ingested == 6
    for i in range(6, 10):
        eng2.ingest_dense(make_chunk(i))
    _assert_same_finals(ref, eng2)


@pytest.mark.parametrize("kill_at", [1, 4, 9])
def test_checkpoint_kill_restore_resume_bitwise(tmp_path, kill_at):
    """Checkpoints ride chunk boundaries; dying at ANY chunk and
    restoring the latest committed checkpoint resumes to bitwise-equal
    finals (the cursor names the next chunk to redeliver)."""
    ref = _build()
    make_chunk = _chunk_maker(ref)
    for i in range(10):
        ref.ingest_dense(make_chunk(i))

    eng = _build()
    ck = FleetCheckpointer(str(tmp_path), every=2, blocking=True)
    eng.attach_checkpointer(ck)
    for i in range(kill_at):
        eng.ingest_dense(make_chunk(i))
    del eng  # the crash

    eng2 = _build()
    ck2 = FleetCheckpointer(str(tmp_path), every=2)
    if kill_at < 2:  # no checkpoint committed yet — cold start
        with pytest.raises(FileNotFoundError):
            ck2.restore(eng2)
        cursor = 0
    else:
        gen = ck2.restore(eng2)
        assert gen >= 1
        cursor = eng2.chunks_ingested
        assert cursor == (kill_at // 2) * 2
    for i in range(cursor, 10):
        eng2.ingest_dense(make_chunk(i))
    _assert_same_finals(ref, eng2)


def test_checkpoint_full_obs_replan_roundtrip(tmp_path):
    """Full-fat engine (metrics + residual monitor + cost ledgers +
    drift/replan state): restore mid-run and resume — replan events,
    cost attribution, and the obs snapshot all land bitwise."""
    from repro.core import costs as core_costs
    rng = np.random.default_rng(7)
    m, n, k, batch = 4, 1024, 16, 64
    cm = core_costs.hbm_host_preset(n_docs=n, k=k, doc_gb=1e-4,
                                    window_seconds=60.0)
    traces = rng.standard_normal((m, n)).astype(np.float32)
    traces[:, n // 4:] += 6.0  # drift so the replanner actually fires

    def build():
        specs = [StreamSpec(stream_id=i, k=k, cost_model=cm)
                 for i in range(m)]
        return StreamEngine(
            specs, obs=Observability(ObsConfig(costs=True)),
            replan=ReplanConfig(drift=DriftConfig(alpha=0.05)))

    def chunk(i):
        sids = np.repeat(np.arange(m), batch)
        dids = np.tile(np.arange(i * batch, (i + 1) * batch), m)
        return sids, traces[:, i * batch:(i + 1) * batch].reshape(-1), dids

    n_chunks = n // batch
    ref = build()
    for i in range(n_chunks):
        ref.ingest(*chunk(i))
    assert len(ref.replan_events) > 0

    eng = build()
    ck = FleetCheckpointer(str(tmp_path), every=3, blocking=True)
    eng.attach_checkpointer(ck)
    for i in range(10):
        eng.ingest(*chunk(i))
    eng2 = build()
    FleetCheckpointer(str(tmp_path)).restore(eng2)
    assert eng2.chunks_ingested == 9
    for i in range(9, n_chunks):
        eng2.ingest(*chunk(i))

    _assert_same_finals(ref, eng2)
    assert len(ref.replan_events) == len(eng2.replan_events)
    for a, b in zip(ref.replan_events, eng2.replan_events):
        assert a.stream_id == b.stream_id and a.position == b.position
        np.testing.assert_array_equal(np.asarray(a.new_bounds),
                                      np.asarray(b.new_bounds))
    sa, sb = ref.cost_summary(), eng2.cost_summary()
    for key in ("total", "planned", "regret"):
        np.testing.assert_array_equal(sa[key], sb[key])
    oa, ob = ref.obs_snapshot(), eng2.obs_snapshot()
    assert oa["engine"] == ob["engine"]
    assert oa["meter"] == ob["meter"]


def test_restore_rejects_mismatched_fleet(tmp_path):
    eng = _build("exact")
    make_chunk = _chunk_maker(eng)
    eng.ingest_dense(make_chunk(0))
    tree, meta = fleet_snapshot(eng)
    other = _build("mixed")  # different fleet shape
    with pytest.raises(ValueError, match="does not match"):
        fleet_restore(other, tree, meta)


def test_obs_snapshot_reports_resilience(tmp_path):
    eng = _build()
    ck = FleetCheckpointer(str(tmp_path), every=1, blocking=True)
    eng.attach_checkpointer(ck)
    eng.ingest_dense(_chunk_maker(eng)(0))
    res = eng.obs_snapshot()["resilience"]
    assert res["chunks_ingested"] == 1
    assert res["checkpoint"]["checkpoints_written"] == 1
    assert res["checkpoint"]["latest_step"] == 1
    assert res["failed_tiers"] == []


# ---------------------------------------------------------------------------
# fault injection: at-least-once delivery, exactly-once application
# ---------------------------------------------------------------------------

def test_faulty_delivery_exactly_once():
    """Transients + duplicates + reordering: the guard drops and buffers
    so each chunk applies exactly once — finals bitwise equal a clean
    run, and the harness actually saw every fault kind."""
    ref = _build()
    make_chunk = _chunk_maker(ref)
    for i in range(12):
        ref.ingest_dense(make_chunk(i))

    eng = _build()
    src = FaultyChunkSource(make_chunk, 12, seed=3, transient_rate=0.4,
                            duplicate_rate=0.5, reorder_rate=0.5)
    stats = ingest_with_faults(eng, src, sleep_scale=0.0)
    assert stats["chunks_applied"] == 12
    assert src.failures_injected > 0 and stats["delivery_retries"] > 0
    assert src.duplicates_injected > 0
    assert stats["redeliveries_dropped"] >= src.duplicates_injected
    _assert_same_finals(ref, eng)


def test_fetch_with_retry_backoff_exhausts():
    make = lambda i: []  # noqa: E731 — never reached
    src = FaultyChunkSource(make, 4, seed=5, transient_rate=1.0,
                            max_transient=3)
    # enough attempts: the capped failure count always clears
    fetch_with_retry(src, 0, max_attempts=4, sleep_scale=0.0)
    src2 = FaultyChunkSource(make, 4, seed=5, transient_rate=1.0,
                             max_transient=3)
    with pytest.raises(TransientDeliveryError):
        fetch_with_retry(src2, 0, max_attempts=2, sleep_scale=0.0)


def test_device_loss_recovery_bitwise(tmp_path):
    """Simulated device loss mid-stream: rebuild, restore the last
    checkpoint, replay — the redelivery guard absorbs the replayed
    prefix and the finals are bitwise the uninterrupted run's."""
    ref = _build()
    make_chunk = _chunk_maker(ref)
    for i in range(10):
        ref.ingest_dense(make_chunk(i))

    ck = FleetCheckpointer(str(tmp_path), every=2, blocking=True)
    src = FaultyChunkSource(make_chunk, 10, seed=3, transient_rate=0.3,
                            duplicate_rate=0.3, reorder_rate=0.3,
                            device_loss_at=7)
    eng, stats = run_with_recovery(lambda: _build(), src, ck,
                                   sleep_scale=0.0)
    assert stats["restarts"] == 1
    assert stats["chunks_applied"] >= 10  # pre-crash progress + replay
    _assert_same_finals(ref, eng)


def test_device_loss_without_checkpoint_raises(tmp_path):
    eng = _build()
    make_chunk = _chunk_maker(eng)
    src = FaultyChunkSource(make_chunk, 6, seed=0, device_loss_at=2,
                            max_transient=0)
    with pytest.raises(DeviceLossError):
        ingest_with_faults(eng, src, sleep_scale=0.0)


# ---------------------------------------------------------------------------
# NaN/Inf quarantine (kernel + jitted step regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["exact", "logmem"])
def test_nan_scores_quarantined(backend):
    """A NaN/Inf-laced delivery is bitwise a delivery where those slots
    were never sent (demoted to pad), except the quarantine counter —
    non-finite scores must never reach a reservoir or the meter."""
    ref = _build(backend, obs=True)
    eng = _build(backend, obs=True)
    make_chunk = _chunk_maker(ref)
    n_bad = 0
    for i in range(6):
        clean = make_chunk(i)
        laced, blanked = [], []
        r = np.random.default_rng(9000 + i)
        for s, ids in clean:
            s_l, ids_b = s.copy(), ids.copy()
            s_b = s.copy()
            if i % 2 == 0:  # lace every other chunk
                row = int(r.integers(0, s.shape[0]))
                col = int(r.integers(0, s.shape[1]))
                s_l[row, col] = np.nan if i % 4 == 0 else np.inf
                s_b[row, col] = -np.inf
                ids_b[row, col] = -1
                n_bad += 1
            laced.append((s_l, ids))
            blanked.append((s_b, ids_b))
        ref.ingest_dense(blanked)
        eng.ingest_dense(laced)
    assert n_bad > 0
    snap = eng.obs_snapshot()["engine"]
    assert snap["scores_quarantined"] == n_bad
    assert ref.obs_snapshot()["engine"]["scores_quarantined"] == 0
    s_ref, s_eng = ref.finalize(), eng.finalize()
    for sid in s_ref:
        np.testing.assert_array_equal(s_ref[sid], s_eng[sid])
    for key, val in ref.meter.state_dict().items():
        np.testing.assert_array_equal(val, eng.meter.state_dict()[key],
                                      err_msg=f"meter.{key}")


def test_all_finite_input_not_perturbed():
    """The quarantine path is inert on clean data: counter stays zero
    and finals match an engine without the obs layer entirely."""
    plain, obs_eng = _build(obs=False), _build(obs=True)
    make_chunk = _chunk_maker(plain)
    for i in range(5):
        plain.ingest_dense(make_chunk(i))
        obs_eng.ingest_dense(make_chunk(i))
    assert obs_eng.obs_snapshot()["engine"]["scores_quarantined"] == 0
    _assert_same_finals(plain, obs_eng)


def test_faulty_source_laces_and_engine_survives():
    """End-to-end: seeded NaN lacing through the fault source, engine
    quarantines — survivors all finite, counter matches the injection."""
    eng = _build(obs=True)
    make_chunk = _chunk_maker(eng)
    src = FaultyChunkSource(make_chunk, 8, seed=11, nan_rate=0.75,
                            nan_docs=2)
    ingest_with_faults(eng, src, sleep_scale=0.0)
    assert src.nan_injected > 0
    assert (eng.obs_snapshot()["engine"]["scores_quarantined"]
            == src.nan_injected)
    for sid, scores in eng.finalize().items():
        assert np.isfinite(np.asarray(scores)).all() or scores.size == 0


# ---------------------------------------------------------------------------
# tier outage: masked feasible set, evacuation, hysteresis, burn grace
# ---------------------------------------------------------------------------

def _outage_engine():
    """3-tier exact streams with cost attribution on (so the outage's
    burn suppression and planned-credit paths are exercised)."""
    specs = [StreamSpec(stream_id=i, k=8, boundaries=(16.0, 64.0))
             for i in range(3)]
    return StreamEngine(specs, obs=Observability(ObsConfig(costs=True)))


def test_tier_outage_evacuates_and_recovers():
    eng = _outage_engine()
    make_chunk = _chunk_maker(eng)
    for i in range(4):
        eng.ingest_dense(make_chunk(i))
    assert eng.meter.occupancy[:, 1].sum() > 0  # tier 1 is populated
    summary = eng.tier_outage(1)
    assert summary["rows_evacuated"] > 0
    assert eng.meter.occupancy[:, 1].sum() == 0  # evacuated
    assert eng._excluded_tier_set() == frozenset({1})
    # double declaration is idempotent
    again = eng.tier_outage(1)
    assert again.get("already_failed")
    # ingest through the outage: nothing lands on the failed tier
    for i in range(4, 7):
        eng.ingest_dense(make_chunk(i))
    assert eng.meter.occupancy[:, 1].sum() == 0
    eng.tier_recover(1, hysteresis=2)
    assert eng._excluded_tier_set() == frozenset({1})  # flap damping
    for i in range(7, 10):
        eng.ingest_dense(make_chunk(i))
    assert eng._excluded_tier_set() == frozenset()
    res = eng.obs_snapshot()["resilience"]
    assert res["tier_outages"] == 1 and res["failed_tiers"] == []


def test_tier_outage_no_burn_false_fire():
    """The evacuation bill is planned spend, not tenant overspend: the
    burn-rate alert must not fire on the outage's relocation costs."""
    eng = _outage_engine()
    make_chunk = _chunk_maker(eng)
    for i in range(4):
        eng.ingest_dense(make_chunk(i))
    summary = eng.tier_outage(1, burn_grace=8)
    mon = eng._cost_monitor
    evac = np.zeros(eng.m, bool)
    evac[summary["rows"]] = True
    assert (mon.burn_suppressed_until[evac] > mon.steps).all()
    assert summary["bill"] >= 0.0
    for i in range(4, 10):
        eng.ingest_dense(make_chunk(i))
    assert not mon.burn_alerted[evac].any()
    # the bill was credited to planned spend → no phantom regret
    summ = eng.cost_summary()
    assert np.isfinite(summ["regret"]).all()


def test_tier_outage_context_manager():
    eng = _outage_engine()
    make_chunk = _chunk_maker(eng)
    for i in range(3):
        eng.ingest_dense(make_chunk(i))
    with TierOutage(eng, tier=1, hysteresis=1) as out:
        assert out.summary["rows_evacuated"] > 0
        assert 1 in eng._failed_tiers
    assert 1 not in eng._failed_tiers  # recovered on exit
    # recovery applies even when the body raises
    eng2 = _outage_engine()
    for i in range(3):
        eng2.ingest_dense(_chunk_maker(eng2)(i))
    with pytest.raises(RuntimeError, match="drill"):
        with TierOutage(eng2, tier=1):
            raise RuntimeError("drill gone wrong")
    assert 1 not in eng2._failed_tiers


def test_tier_outage_validates_tier():
    eng = _outage_engine()
    eng.ingest_dense(_chunk_maker(eng)(0))
    with pytest.raises(ValueError):
        eng.tier_outage(99)
    with pytest.raises(ValueError):
        eng.tier_recover(1)  # not failed


def test_outage_state_survives_checkpoint(tmp_path):
    """An outage declared before the crash is still masking the tier
    after restore — recovery state is part of the checkpoint."""
    eng = _outage_engine()
    make_chunk = _chunk_maker(eng)
    for i in range(4):
        eng.ingest_dense(make_chunk(i))
    eng.tier_outage(1)
    tree, meta = fleet_snapshot(eng)
    eng2 = _outage_engine()
    fleet_restore(eng2, tree, meta)
    assert eng2._excluded_tier_set() == frozenset({1})
    assert eng2._tier_outages == 1
    for i in range(4, 6):
        eng2.ingest_dense(make_chunk(i))
    assert eng2.meter.occupancy[:, 1].sum() == 0
