"""repro.streams.logmem — the O(log K) reservoir backend: the fused
admission kernel vs its oracles, the threshold-update invariants, the
competitive-ratio trace harness, pad inertness through both call sites
of ``router.blank_dense``, mixed exact/logmem fleets, and the
law-slack-widened drift/residual channels."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.logmem_update import ops as lm_ops
from repro.kernels.logmem_update import ref as lm_ref
from repro.obs import Observability, ObsConfig
from repro.online import DriftConfig, drift
from repro.streams import StreamEngine, StreamSpec, engine, logmem, \
    metering, router


# ---------------------------------------------------------------------------
# kernel parity: pallas (interpret off-TPU) vs jnp ref vs numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,bn", [(1, 128, 128), (3, 500, 128),
                                    (8, 1024, 512), (5, 777, 256)])
def test_logmem_admit_matches_ref_and_oracle(m, n, bn):
    rng = np.random.default_rng(m * 1000 + n)
    scores = rng.standard_normal((m, n)).astype(np.float32)
    ids = np.tile(np.arange(n, dtype=np.int32), (m, 1))
    ids[rng.random((m, n)) < 0.1] = lm_ops.PAD_ID  # scattered pads
    tau = rng.uniform(-1, 1, m).astype(np.float32)
    tau[0] = -np.inf  # cold stream: every live doc admits
    out_k = lm_ops.logmem_admit(jnp.asarray(scores), jnp.asarray(ids),
                                jnp.asarray(tau), block_n=bn,
                                use_pallas=True)
    out_r = lm_ops.logmem_admit(jnp.asarray(scores), jnp.asarray(ids),
                                jnp.asarray(tau), block_n=bn,
                                use_pallas=False)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mask, acounts, lcounts, tmax = (np.asarray(x) for x in out_k)
    live = ids >= 0
    hit = live & (scores > tau[:, None])
    np.testing.assert_array_equal(mask.astype(bool), hit)
    np.testing.assert_array_equal(acounts.sum(1), hit.sum(1))
    np.testing.assert_array_equal(lcounts.sum(1), live.sum(1))
    row_max = np.where(live.any(1),
                       np.where(live, scores, -np.inf).max(1), -np.inf)
    np.testing.assert_allclose(tmax.max(1), row_max)


def test_logmem_admit_gates_on_ids_not_score_sentinel():
    """Unlike batched_topk's unfull-reservoir convention, the logmem scan
    must keep pads inert even under a -inf threshold AND even if a pad
    column carries a finite score (the id is the ground truth)."""
    scores = jnp.array([[5.0, 1.0, 7.0, 2.0]], jnp.float32)
    ids = jnp.array([[0, -1, 1, -1]], jnp.int32)
    tau = jnp.array([-jnp.inf], jnp.float32)
    mask, acounts, lcounts, _ = lm_ops.logmem_admit(scores, ids, tau,
                                                    block_n=128)
    np.testing.assert_array_equal(np.asarray(mask)[0], [1, 0, 1, 0])
    assert int(np.asarray(acounts).sum()) == 2
    assert int(np.asarray(lcounts).sum()) == 2


# ---------------------------------------------------------------------------
# update law: admit-all pre-K, crossing-chunk budget, floor invariants
# ---------------------------------------------------------------------------

def test_logmem_update_admits_everything_before_k():
    k, m, w = 16, 2, 8
    st = logmem.init(m)
    sc = jnp.asarray(np.random.default_rng(0)
                     .standard_normal((m, w)).astype(np.float32))
    ids = jnp.tile(jnp.arange(w, dtype=jnp.int32), (m, 1))
    st, wrote = logmem.update(st, sc, ids, k, use_pallas=False)
    assert np.asarray(wrote).all()  # t <= K: reservoir-fill phase
    np.testing.assert_array_equal(np.asarray(st.seen), [w, w])
    np.testing.assert_array_equal(np.asarray(st.admits), [w, w])
    assert np.isneginf(np.asarray(st.tau)).all()  # still cold


def test_logmem_crossing_chunk_admits_the_chunk_law_budget():
    """The chunk that crosses t = K has no threshold yet; it must admit
    exactly the hypergeometric chunk-law mean (top-B by score), keeping
    the admit counts on the closed-form write law."""
    k, m, w = 16, 3, 24
    rng = np.random.default_rng(1)
    st = logmem.init(m)
    sc0 = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    st, _ = logmem.update(st, sc0, jnp.tile(jnp.arange(k, dtype=jnp.int32),
                                            (m, 1)), k, use_pallas=False)
    sc1 = jnp.asarray(rng.standard_normal((m, w)).astype(np.float32))
    ids1 = jnp.tile(jnp.arange(k, k + w, dtype=jnp.int32), (m, 1))
    st, wrote = logmem.update(st, sc1, ids1, k, use_pallas=False)
    t = k + w
    budget = round(min(t, k) * w / t)
    np.testing.assert_array_equal(np.asarray(wrote).sum(1),
                                  np.full(m, budget))
    # the admitted set is the chunk's top-B by score
    wr = np.asarray(wrote)
    s1 = np.asarray(sc1)
    for row in range(m):
        top = np.sort(s1[row])[-budget:]
        np.testing.assert_allclose(np.sort(s1[row][wr[row]]), top)


def test_logmem_floor_monotone_tau_above_floor_and_phase_ledger():
    k, m, chunk, n = 32, 4, 128, 8192
    rng = np.random.default_rng(2)
    st = logmem.init(m)
    prev_floor = np.asarray(st.tau_floor).copy()
    prev_phase = np.asarray(st.phase).copy()
    for start in range(0, n, chunk):
        sc = jnp.asarray(rng.standard_normal((m, chunk)).astype(np.float32))
        ids = jnp.tile(jnp.arange(start, start + chunk, dtype=jnp.int32),
                       (m, 1))
        st, _ = logmem.update(st, sc, ids, k, use_pallas=False)
        floor = np.asarray(st.tau_floor)
        phase = np.asarray(st.phase)
        assert (floor >= prev_floor).all() | np.isneginf(prev_floor).all()
        assert (phase >= prev_phase).all()
        assert (np.asarray(st.tau) >= floor).all()
        prev_floor, prev_phase = floor, phase
    # the phase ledger partitions the admit total (O(log K) diagnostics)
    np.testing.assert_array_equal(np.asarray(st.phase_admits).sum(1),
                                  np.asarray(st.admits))
    assert (np.asarray(st.phase) >= 0).all()
    assert np.isfinite(np.asarray(st.tau)).all()


# ---------------------------------------------------------------------------
# pad inertness through both call sites of router.blank_dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["exact", "logmem"])
def test_blank_dense_rows_are_inert_in_both_backends(backend):
    """The shard-padding call site (`engine._stage_batches`) appends whole
    ``blank_dense`` rows; an all-pad chunk must leave either backend's
    state bitwise untouched and report no writes."""
    m, k, w = 3, 8, 16
    ps, pi = router.blank_dense(m, w)
    assert (pi == router.PAD_ID).all() and np.isneginf(ps).all()
    if backend == "logmem":
        st = logmem.init(m)
        # advance past cold start so tau is live (pads must still be inert
        # under a finite threshold)
        rng = np.random.default_rng(3)
        for c in range(4):
            sc = jnp.asarray(rng.standard_normal((m, w)).astype(np.float32))
            ids = jnp.tile(jnp.arange(c * w, (c + 1) * w, dtype=jnp.int32),
                           (m, 1))
            st, _ = logmem.update(st, sc, ids, k, use_pallas=False)
        st2, wrote = logmem.update(st, jnp.asarray(ps), jnp.asarray(pi), k,
                                   use_pallas=False)
    else:
        st = engine.init(m, k)
        st, _ = engine.update(st, jnp.asarray(
            np.random.default_rng(3).standard_normal((m, w))
            .astype(np.float32)),
            jnp.tile(jnp.arange(w, dtype=jnp.int32), (m, 1)))
        st2, wrote = engine.update(st, jnp.asarray(ps), jnp.asarray(pi))
    assert not np.asarray(wrote).any()
    for a, b in zip(st, st2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_router_route_pads_match_blank_dense():
    """The router call site: ``route`` scatters live docs into a
    ``blank_dense`` canvas, so its pad entries must be exactly the shared
    sentinel pair (one filler, one inertness contract)."""
    rt = router.StreamRouter(router.bucket_streams(
        {0: 4, 1: 4}, {0: "exact", 1: "logmem"}))
    routed = rt.route([0, 1, 0, 1, 0, 1], [1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                      [0, 0, 1, 1, 2, 2])
    assert len(routed) == 2  # same K, different engine => distinct buckets
    for bi in range(2):
        ds, di = routed[bi]
        assert ds.shape == (1, 4)  # 3 docs -> pow2 pad to 4
        ps, pi = router.blank_dense(*ds.shape)
        np.testing.assert_array_equal(ds[:, 3:], ps[:, 3:])
        np.testing.assert_array_equal(di[:, 3:], pi[:, 3:])


# ---------------------------------------------------------------------------
# trace harness: 1 - c/sqrt(K) competitive ratio + write-law admits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,chunk", [(256, 128), (1024, 512)])
def test_trace_competitive_ratio_within_guarantee(k, chunk):
    rng = np.random.default_rng(k)
    n = 16 * k
    traces = rng.standard_normal((3, n)).astype(np.float32)
    out = logmem.trace_competitive_ratio(traces, k, chunk)
    slack = logmem.law_slack(k)
    assert out["min_ratio"] >= 1.0 - slack  # ratio >= 1 - c/sqrt(K)
    assert out["max_c"] <= logmem.LAW_SLACK_C
    assert np.abs(out["admit_ratio"] - 1.0).max() <= 3.0 * slack
    assert out["bytes_per_stream"] * 8.0 <= out["exact_bytes_per_stream"]


def test_logmem_memory_is_o_log_k():
    st = logmem.init(8)
    bps = logmem.state_bytes_per_stream(st)
    # K-independent state: the acceptance floor is >= 8x at K = 4096 and
    # grows linearly with K from there
    assert logmem.exact_bytes_per_stream(4096) / bps >= 8.0
    assert logmem.exact_bytes_per_stream(65536) / bps >= 128.0
    assert logmem.state_bytes_per_stream(logmem.init(64)) == bps


# ---------------------------------------------------------------------------
# mixed exact/logmem fleets through the StreamEngine
# ---------------------------------------------------------------------------

def _mixed_fleet(docs=192, batch=8, seed=5):
    rng = np.random.default_rng(seed)
    specs = [StreamSpec(stream_id=i, k=4, r=float(docs / 2))
             for i in range(6)]
    specs += [StreamSpec(stream_id=100 + i, k=64, r=float(docs / 2),
                         engine="logmem") for i in range(5)]
    traces = rng.standard_normal((len(specs), docs)).astype(np.float32)
    return specs, traces, rng


def _ingest_mixed(eng, specs, traces, batch, rng, only_sids=None):
    sids = np.array([s.stream_id for s in specs])
    keep = (np.isin(sids, list(only_sids)) if only_sids is not None
            else np.ones(sids.size, bool))
    m, docs = traces.shape
    for t in range(0, docs, batch):
        ms = np.repeat(sids[keep], batch)
        md = np.tile(np.arange(t, t + batch), int(keep.sum()))
        sc = traces[keep, t:t + batch].reshape(-1)
        perm = rng.permutation(ms.size)
        eng.ingest(ms[perm], sc[perm], md[perm])


def test_mixed_engine_fleet_exact_bucket_unchanged():
    """Adding logmem tenants to a fleet must not perturb the exact
    streams: their survivors are bitwise those of an exact-only replay,
    and the logmem rows land on their own contract (empty survivors, no
    deletes, occupancy == cumulative writes)."""
    specs, traces, rng = _mixed_fleet()
    exact_sids = {s.stream_id for s in specs if s.engine == "exact"}
    mixed = StreamEngine(specs, obs=Observability(ObsConfig()))
    alone = StreamEngine([s for s in specs if s.engine == "exact"])
    rng2 = np.random.default_rng(5)
    _ingest_mixed(mixed, specs, traces, 8, rng)
    _ingest_mixed(alone, specs, traces, 8,
                  np.random.default_rng(5), only_sids=exact_sids)
    s_mixed, s_alone = mixed.finalize(), alone.finalize()
    for sid in exact_sids:
        np.testing.assert_array_equal(s_mixed[sid], s_alone[sid])
    bars = mixed.thresholds()
    for s in specs:
        row = mixed.stream_row(s.stream_id)
        if s.engine == "logmem":
            assert s_mixed[s.stream_id].size == 0
            assert mixed.meter.deletes[row].sum() == 0
            assert (mixed.meter.writes[row].sum()
                    == mixed.meter.occupancy[row].sum())
            assert np.isfinite(bars[s.stream_id])  # past cold start
        assert mixed.meter.observed[row] == traces.shape[1]
    snap = mixed.obs_snapshot()
    assert snap["fleet"]["logmem_streams"] == 5
    # slack-widened write-law residual: z stays O(1) on an undrifted fleet
    assert snap["residuals"]["writes"]["max_abs_z"] < 4.0
    assert snap["residuals"]["alerts"]["alerted"] == 0
    # logmem tiers absent from the device-side finalize assignment
    assert set(mixed.finalize_tiers()) == exact_sids


def test_logmem_spec_validation():
    with pytest.raises(ValueError, match="migration cascade"):
        StreamEngine([StreamSpec(stream_id=0, k=8, r=4.0, engine="logmem",
                                 migrate=True)])
    with pytest.raises(ValueError, match="unknown engine"):
        StreamEngine([StreamSpec(stream_id=0, k=8, r=4.0, engine="approx")])


def test_meter_apply_boundaries_logmem_swaps_without_ids():
    meter = metering.FleetMeter([4, 4], boundaries=[(2.0,), (2.0,)],
                                logmem=[False, True])
    # logmem row: boundary-vector swap only, nothing relocatable
    assert meter.apply_boundaries(1, (3.0,), None) == 0
    assert meter.boundaries[1, 0] == 3.0
    assert meter.relocations[1] == 0
    # exact row: resident ids are required to re-tier
    with pytest.raises(ValueError, match="state_ids required"):
        meter.apply_boundaries(0, (3.0,), None)


# ---------------------------------------------------------------------------
# slack-widened alert channels: null FPR and drifted detection
# ---------------------------------------------------------------------------

def _logmem_engine(m, k, replan=False):
    specs = [StreamSpec(stream_id=i, k=k, r=float(2 * k), engine="logmem")
             for i in range(m)]
    kw = {}
    if replan:
        from repro.online import ReplanConfig
        kw["replan"] = ReplanConfig(drift=DriftConfig(alpha=0.05))
    return StreamEngine(specs, obs=Observability(ObsConfig()), **kw)


def _dense_chunks(eng, traces, chunk):
    m, n = traces.shape
    for start in range(0, n, chunk):
        ids = np.tile(np.arange(start, start + chunk, dtype=np.int32),
                      (m, 1))
        eng.ingest_dense([(traces[:, start:start + chunk], ids)])


def test_residual_monitor_null_fpr_on_undrifted_logmem_fleet():
    m, k, n, chunk = 8, 256, 4096, 256
    rng = np.random.default_rng(6)
    eng = _logmem_engine(m, k, replan=True)
    traces = rng.standard_normal((m, n)).astype(np.float32)
    _dense_chunks(eng, traces, chunk)
    # i.u.d. arrivals: neither the residual monitor nor the device drift
    # detector may fire through the slack-widened thresholds
    assert eng._residuals.alerted.sum() == 0
    assert eng.residual_alerts() == {}
    assert max(eng.drift_scores().values()) < 1.0
    assert eng.replan_events == []
    z = eng._residuals.write_z()
    assert np.abs(z["z"]).max() < 4.0


def test_residual_monitor_fires_on_drifted_logmem_fleet():
    """A monotone-increasing score trace beats any committed threshold:
    admits blow past the write law and the slack-widened residual channel
    must still alert (drift stays visible through the slack)."""
    m, k, n, chunk = 4, 256, 4096, 256
    rng = np.random.default_rng(7)
    eng = _logmem_engine(m, k)
    drifted = (np.arange(n, dtype=np.float32)[None, :] * 0.01
               + rng.standard_normal((m, n)).astype(np.float32) * 0.1)
    _dense_chunks(eng, drifted, chunk)
    assert eng._residuals.alerted.all()
    assert len(eng.residual_alerts()) == m


def test_drift_detector_slack_absorbs_law_bias_but_not_drift():
    """Unit check of the detector's slack term: a write sequence biased
    by exactly the logmem tolerance stays quiet under slack=law_slack
    but fires at slack=0; an 8x rate drift fires through the slack."""
    k, chunk, steps = 256, 256, 24
    slack = logmem.law_slack(k)
    cfg = DriftConfig(alpha=0.01)
    st_slack, st_zero, st_drift = (drift.init(1) for _ in range(3))
    seen = 0
    for _ in range(steps):
        before, seen = seen, seen + chunk
        mean, _ = drift.chunk_law(jnp.asarray([float(before)]),
                                  jnp.asarray([float(seen)]), float(k))
        biased = mean * (1.0 + slack)
        st_slack = drift.update(st_slack, biased, jnp.asarray([seen]),
                                float(k), cfg, slack=slack)
        st_zero = drift.update(st_zero, biased, jnp.asarray([seen]),
                               float(k), cfg, slack=0.0)
        st_drift = drift.update(st_drift, mean * 8.0, jnp.asarray([seen]),
                                float(k), cfg, slack=slack)
    assert not bool(np.asarray(st_slack.fired)[0])
    assert bool(np.asarray(st_zero.fired)[0])
    assert bool(np.asarray(st_drift.fired)[0])


def test_occupancy_residual_law_switches_for_logmem_rows():
    """occupancy_residuals must reference the per-tier write-law deltas
    for logmem rows (occupancy == cumulative writes, no deletes), not the
    exact backend's peak-occupancy law."""
    from repro.obs import residuals as res_mod
    m, k, n, chunk = 4, 64, 2048, 128
    eng = _logmem_engine(m, k)
    rng = np.random.default_rng(8)
    _dense_chunks(eng, rng.standard_normal((m, n)).astype(np.float32), chunk)
    occ = res_mod.occupancy_residuals(eng.meter, batch=chunk)
    assert np.isfinite(occ["normalized"]).all()
    # realized storage grows past K (never deletes) yet tracks the law
    assert (occ["realized"].sum(1) > k).all()
    assert np.abs(occ["normalized"]).max() < 3.0 * logmem.law_slack(k) + 0.15
    row = eng.stream_row(0)
    exp = res_mod.expected_tier_writes(eng.meter.boundaries[row], n, k,
                                       batch=chunk)
    np.testing.assert_allclose(occ["expected"][row], exp)
