"""kernels.tier_assign — the finalize-time (M, T) tier-assignment kernel
vs its jnp oracle (bit-match on random boundary vectors, padded streams,
degenerate collapsed tiers, cascade floors) and vs the host meter's tier
attribution through the engine's ``finalize_tiers``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.kernels.tier_assign import ops, quantize_boundaries, ref, tier_assign
from repro.streams.engine import StreamEngine, StreamSpec


def _random_case(rng, m, k, b, frac_pad=0.2, degenerate=False):
    ids = rng.integers(0, 100_000, (m, k)).astype(np.int32)
    pad = rng.random((m, k)) < frac_pad
    ids[pad] = -1
    bounds = np.sort(rng.uniform(0, 100_000, (m, b)), axis=1)
    if degenerate:
        # collapse middle tiers: coincident boundaries and +inf padding
        bounds[:, 1:] = bounds[:, :1]
        bounds[m // 2:, -1] = np.inf
    floor = rng.integers(0, b + 1, m).astype(np.int32)
    return ids, bounds, floor


@pytest.mark.parametrize("m,k,b,block_k", [
    (1, 128, 1, 128), (5, 64, 2, 32), (16, 33, 3, 16), (3, 7, 4, 128),
])
def test_pallas_bit_matches_ref(m, k, b, block_k):
    rng = np.random.default_rng(m * 100 + k)
    ids, bounds, floor = _random_case(rng, m, k, b)
    tp, cp = tier_assign(ids, bounds, floor, block_k=block_k)
    tr, cr = tier_assign(ids, bounds, floor, block_k=block_k,
                         use_pallas=False)
    np.testing.assert_array_equal(np.asarray(tp), np.asarray(tr))
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cr))


def test_degenerate_collapsed_tiers_and_inf_padding():
    rng = np.random.default_rng(0)
    ids, bounds, floor = _random_case(rng, 8, 32, 3, degenerate=True)
    tp, cp = tier_assign(ids, bounds, floor)
    tr, cr = tier_assign(ids, bounds, floor, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(tp), np.asarray(tr))
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cr))
    # +inf boundaries are unreachable: no id lands past them
    t = np.asarray(tp)
    assert t[8 // 2:, :].max() <= 3  # floor can still lift to b
    # all-padding row assigns nothing
    ids[0, :] = -1
    tp2, cp2 = tier_assign(ids, bounds, floor)
    assert np.all(np.asarray(tp2)[0] == -1)
    assert np.asarray(cp2)[0].sum() == 0


def test_matches_host_float_comparison_law():
    """int32 quantization (ceil) must reproduce the meter's float64
    ``id >= b`` exactly, including fractional boundaries."""
    ids = np.array([[4, 5, 6, 7, -1]], np.int32)
    bounds = np.array([[5.3, 6.0]])
    tp, _ = tier_assign(ids, bounds)
    host = (ids[:, :, None].astype(np.float64) >= bounds[:, None, :]).sum(-1)
    host = np.where(ids >= 0, host, -1)
    np.testing.assert_array_equal(np.asarray(tp), host)
    np.testing.assert_array_equal(
        quantize_boundaries(np.array([[5.3, 6.0, np.inf]]))[0],
        np.array([6, 6, np.iinfo(np.int32).max], np.int32))


def test_counts_accumulate_across_tiles():
    rng = np.random.default_rng(1)
    m, k = 4, 512  # several 128-wide tiles per stream
    ids, bounds, floor = _random_case(rng, m, k, 2)
    tp, cp = tier_assign(ids, bounds, floor, block_k=128)
    t = np.asarray(tp)
    for tier in range(3):
        np.testing.assert_array_equal(np.asarray(cp)[:, tier],
                                      (t == tier).sum(1))
    assert np.asarray(cp).sum() == (ids >= 0).sum()


def test_engine_finalize_tiers_matches_meter():
    """The device-side bucketed assignment must agree with the host
    meter's final-read tier attribution."""
    rng = np.random.default_rng(3)
    n, m = 512, 6
    wl = costs.WorkloadSpec(n_docs=n, k=8, doc_gb=1e-4, window_months=0.1)
    hot = costs.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                          storage_per_gb_month=0.05)
    cold = costs.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                           storage_per_gb_month=0.02)
    cm = costs.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)
    specs = [StreamSpec(stream_id=i, k=8, cost_model=cm) for i in range(m)]
    engine = StreamEngine(specs)
    for t0 in range(0, n, 64):
        sids = np.repeat(np.arange(m), 64)
        dids = np.tile(np.arange(t0, t0 + 64), m)
        scores = rng.standard_normal(m * 64)
        engine.ingest(sids, scores, dids)
    engine.finalize()
    assigned = engine.finalize_tiers()
    for sid, out in assigned.items():
        row = engine.stream_row(sid)
        ids = out["ids"]
        valid = ids >= 0
        host_tier = engine.meter._effective_tier(
            np.array([row]), ids[None, :])[0]
        np.testing.assert_array_equal(out["tiers"][valid], host_tier[valid])
        # counts row reconciles with the meter's final read scatter
        np.testing.assert_array_equal(
            out["counts"], engine.meter.reads[row])


def test_ops_module_reexports():
    assert ops.tier_assign is tier_assign
    assert ref.tier_assign is not None
