"""repro.parallel — fleet-axis sharding. Sharded-vs-single-device bit
identity for the engine step (reservoir + metrics + drift state, incl.
mixed exact/logmem fleets with padded logmem buckets), the
candidate-grid solve, and the online suffix re-solve; the cross-shard
water-filling never-oversubscribes property; sharded metrics
aggregation; double-buffered ingest equality. Mesh tests skip unless
jax sees >=2 devices (CI forces 8 via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); a subprocess
smoke keeps one forced-mesh path alive in plain single-device runs."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import constraints as cons, costs, shp_jax, simulator
from repro.obs import Observability, ObsConfig
from repro.obs import jits as obs_jits
from repro.obs import metrics as obs_metrics
from repro.online import DriftConfig, ReplanConfig, replan_device
from repro.parallel import fleet
from repro.streams import StreamEngine, StreamSpec, planner

needs_mesh = pytest.mark.skipif(
    jax.local_device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh():
    return fleet.fleet_mesh(min(jax.local_device_count(), 8))


def _two_tier_model(n=2048, k=16):
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-4, window_months=0.5)
    hot = costs.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                          storage_per_gb_month=0.05)
    cold = costs.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                           storage_per_gb_month=0.02)
    return costs.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)


def _mixed_ingest(engines, specs, traces, batch, rng):
    sids = np.array([s.stream_id for s in specs])
    m, docs = traces.shape
    for t in range(0, docs, batch):
        mixed_sids = np.repeat(sids, batch)
        mixed_dids = np.tile(np.arange(t, t + batch), m)
        mixed_scores = traces[:, t:t + batch].reshape(-1)
        perm = rng.permutation(mixed_sids.size)
        for e in engines:
            e.ingest(mixed_sids[perm], mixed_scores[perm],
                     mixed_dids[perm])


def _assert_engines_identical(ref, shd):
    s_ref, s_shd = ref.finalize(), shd.finalize()
    assert set(s_ref) == set(s_shd)
    for sid in s_ref:
        np.testing.assert_array_equal(s_ref[sid], s_shd[sid])
    for field in ("observed", "writes", "deletes", "reads", "boundaries"):
        np.testing.assert_array_equal(getattr(ref.meter, field),
                                      getattr(shd.meter, field))
    o_ref, o_shd = ref.obs_snapshot(), shd.obs_snapshot()
    assert o_ref["engine"] == o_shd["engine"]
    assert o_ref["meter"] == o_shd["meter"]


# ---------------------------------------------------------------------------
# engine step: sharded == single-device, bitwise
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("m", [5, 16, 33])
def test_engine_sharded_bit_identity(m):
    """Heterogeneous fleet (two K-buckets, M not a multiple of the shard
    count) through shuffled mixed batches: survivors, every meter
    ledger, and the aggregated device metrics must be bitwise equal to
    the unsharded engine's."""
    mesh = _mesh()
    rng = np.random.default_rng(100 + m)

    def build(mesh):
        specs = [StreamSpec(stream_id=100 + i, k=(4 if i % 2 else 8),
                            r=24.0) for i in range(m)]
        obs = Observability(ObsConfig())
        return StreamEngine(specs, obs=obs, mesh=mesh), specs

    ref, specs = build(None)
    shd, _ = build(mesh)
    traces = rng.standard_normal((m, 48)).astype(np.float32)
    _mixed_ingest([ref, shd], specs, traces, batch=6, rng=rng)
    _assert_engines_identical(ref, shd)


@needs_mesh
@pytest.mark.parametrize("m", [6, 13])
def test_engine_sharded_logmem_bit_identity(m):
    """Mixed exact + logmem fleet (M not a multiple of the shard count,
    so the logmem bucket gets blank_dense pad rows): survivors, meter
    ledgers, obs snapshots, and the logmem acceptance thresholds must be
    bitwise equal to the unsharded engine's — and the pad rows must stay
    inert through the threshold-update path."""
    mesh = _mesh()
    rng = np.random.default_rng(200 + m)

    def build(mesh):
        specs = [StreamSpec(stream_id=i, k=32, r=48.0, engine="logmem")
                 if i % 3 == 2 else StreamSpec(stream_id=i, k=4, r=48.0)
                 for i in range(m)]
        obs = Observability(ObsConfig())
        return StreamEngine(specs, obs=obs, mesh=mesh), specs

    ref, specs = build(None)
    shd, _ = build(mesh)
    traces = rng.standard_normal((m, 96)).astype(np.float32)
    _mixed_ingest([ref, shd], specs, traces, batch=8, rng=rng)
    _assert_engines_identical(ref, shd)
    assert ref.thresholds() == shd.thresholds()
    lm = [bi for bi, b in enumerate(shd.buckets) if b.engine == "logmem"]
    assert len(lm) == 1
    pads = np.asarray(shd._states[lm[0]].seen)[shd.buckets[lm[0]].m:]
    assert (pads == 0).all()


@needs_mesh
def test_engine_sharded_replan_bit_identity():
    """Online re-planning under the mesh: drift state rides sharded
    through the step, the suffix re-solve dispatches per shard, and the
    resulting events/boundaries are bitwise those of the plain path."""
    mesh = _mesh()
    rng = np.random.default_rng(7)
    m, n, k, batch = 5, 2048, 16, 64
    cm = _two_tier_model(n=n, k=k)
    traces = np.stack([
        simulator.drifted_rank_trace(n, rng, [(512, 8.0)])
        for _ in range(m)]).astype(np.float32)

    def build(mesh):
        specs = [StreamSpec(stream_id=i, k=k, cost_model=cm)
                 for i in range(m)]
        eng = StreamEngine(
            specs, obs=Observability(ObsConfig()), mesh=mesh,
            replan=ReplanConfig(drift=DriftConfig(alpha=0.05)))
        return eng, specs

    ref, specs = build(None)
    shd, _ = build(mesh)
    np.testing.assert_array_equal(ref.meter.boundaries,
                                  shd.meter.boundaries)
    _mixed_ingest([ref, shd], specs, traces, batch=batch, rng=rng)
    assert len(ref.replan_events) == len(shd.replan_events) > 0
    for a, b in zip(ref.replan_events, shd.replan_events):
        assert a.stream_id == b.stream_id and a.position == b.position
        assert a.applied == b.applied
        np.testing.assert_array_equal(np.asarray(a.new_bounds),
                                      np.asarray(b.new_bounds))
    _assert_engines_identical(ref, shd)


@needs_mesh
def test_ingest_chunks_double_buffered_equals_sequential():
    """The donated double-buffered ingest loop lands the same fleet
    state as chunk-at-a-time ``ingest_dense`` on the plain engine."""
    mesh = _mesh()
    rng = np.random.default_rng(3)
    m, k, w, chunks = 12, 8, 16, 6

    def build(mesh):
        specs = [StreamSpec(stream_id=i, k=k, r=40.0) for i in range(m)]
        return StreamEngine(specs, obs=Observability(ObsConfig()),
                            mesh=mesh), specs

    ref, _ = build(None)
    shd, _ = build(mesh)
    dense = []
    for c in range(chunks):
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(c * w, (c + 1) * w, dtype=np.int32),
                      (m, 1))
        dense.append([(sc, ids)])
    for batches in dense:
        ref.ingest_dense(batches)
    assert shd.ingest_chunks(iter(dense)) == chunks
    _assert_engines_identical(ref, shd)


# ---------------------------------------------------------------------------
# planner entry points: sharded == single-device, bitwise
# ---------------------------------------------------------------------------

def _plan_inputs(rng, m, t=3):
    cw = rng.uniform(0.5, 2.0, (m, t))
    cr = rng.uniform(0.1, 1.0, (m, t))
    cs = rng.uniform(0.01, 0.2, (m, t))
    n = rng.integers(50, 400, m).astype(np.float64)
    k = rng.integers(2, 16, m).astype(np.float64)
    rpw = rng.uniform(0.5, 4.0, m)
    return cw, cr, cs, n, k, rpw


@needs_mesh
@pytest.mark.parametrize("m", [7, 64, 1000])
@pytest.mark.parametrize("constrained", [False, True])
def test_plan_sharded_bit_identity(m, constrained):
    mesh = _mesh()
    rng = np.random.default_rng(m)
    cw, cr, cs, n, k, rpw = _plan_inputs(rng, m)
    kw = {}
    if constrained:
        cap = np.full((m, 3), np.inf)
        cap[:, 0] = rng.uniform(20, 80, m)
        slo = np.full(m, np.inf)
        slo[::3] = rng.uniform(0.5, 2.0, len(slo[::3]))
        kw = dict(cap=cap, lat=rng.uniform(0.1, 1.0, (m, 3)), slo=slo)
    ref = shp_jax.plan_ntier_arrays_jax(cw, cr, cs, n, k, rpw, **kw)
    with fleet.use_fleet_mesh(mesh):
        out = shp_jax.plan_ntier_arrays_jax(cw, cr, cs, n, k, rpw, **kw)
    np.testing.assert_array_equal(ref["total"], out["total"])
    np.testing.assert_array_equal(ref["bounds"], out["bounds"])
    np.testing.assert_array_equal(ref["migrate"], out["migrate"])


@needs_mesh
def test_replan_device_sharded_bit_identity():
    mesh = _mesh()
    rng = np.random.default_rng(2)
    r = 11
    cw, cr, cs, n, k, rpw = _plan_inputs(rng, r)
    cap = np.full((r, 3), np.inf)
    cap[:, 0] = rng.uniform(20, 80, r)
    lat = rng.uniform(0.1, 1.0, (r, 3))
    slo = np.full(r, np.inf)
    n0 = np.minimum(n * 0.5, n - 1)
    rho = rng.uniform(0.5, 1.5, r)
    b0 = np.sort(rng.uniform(0, 1, (r, 2)), axis=1) * n[:, None]
    args = (cw, cr, cs, n, k, rpw, cap, lat, slo, n0, rho, b0)
    ref = replan_device.solve_group(*args)
    with fleet.use_fleet_mesh(mesh):
        out = replan_device.solve_group(*args)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# cross-shard water-filling
# ---------------------------------------------------------------------------

def check_waterfill_never_oversubscribes(seed):
    rng = np.random.default_rng(seed)
    mesh = _mesh()
    m = int(rng.integers(1, 60))
    desired = rng.uniform(0.0, 50.0, m)
    desired[rng.random(m) < 0.2] = 0.0  # zero-desire rows draw nothing
    budget = float(desired.sum() * rng.uniform(0.1, 1.4))
    grants = fleet.waterfill_sharded(desired, budget, mesh)
    assert grants.shape == (m,)
    assert (grants <= desired + 1e-9).all()
    assert grants.sum() <= budget * (1 + 1e-12) + 1e-9
    if desired.sum() <= budget:
        np.testing.assert_allclose(grants, desired, rtol=1e-9)
    # and it agrees with the exact host algorithm to solver tolerance
    exact = cons.waterfill_grants(desired, budget)
    np.testing.assert_allclose(grants, exact, rtol=1e-7, atol=1e-7)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @needs_mesh
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_waterfill_never_oversubscribes_property(seed):
        check_waterfill_never_oversubscribes(seed)
else:
    @needs_mesh
    def test_waterfill_never_oversubscribes_property():
        for seed in range(12):
            check_waterfill_never_oversubscribes(seed)


@needs_mesh
def test_planner_waterfill_dispatches_to_mesh():
    mesh = _mesh()
    desired = np.array([10.0, 0.0, 30.0, 5.0])
    host = planner.waterfill(desired, 20.0)
    shd = planner.waterfill(desired, 20.0, mesh=mesh)
    np.testing.assert_allclose(host, shd, rtol=1e-9, atol=1e-9)
    assert float(shd.sum()) <= 20.0 * (1 + 1e-12)


# ---------------------------------------------------------------------------
# sharded metrics layout (no mesh required)
# ---------------------------------------------------------------------------

def test_metrics_sharded_snapshot_aggregates():
    """A (D, 8) sharded MetricsState snapshots to fleet-global numbers:
    counts sum across shards, CHUNKS and the drift high-water take the
    max (every shard bumps CHUNKS once per chunk)."""
    ms = obs_metrics.init(shards=3)
    assert ms.sharded
    counts = np.zeros((3, obs_metrics.N_SLOTS), np.int32)
    counts[:, obs_metrics.DOCS] = [10, 20, 30]
    counts[:, obs_metrics.CHUNKS] = [4, 4, 4]
    counts[:, obs_metrics.DRIFT_FIRED] = [1, 0, 2]
    ms = ms._replace(counts=counts,
                     drift_score_max=np.array([0.5, 2.0, 1.0],
                                              np.float32))
    snap = obs_metrics.snapshot(ms)
    assert snap["docs"] == 60
    assert snap["chunks"] == 4
    assert snap["drift_fired"] == 3
    assert snap["drift_score_max"] == 2.0
    # shard_local / shard_pack round-trip the per-shard layout
    local = obs_metrics.shard_local(ms)
    assert local.counts.shape == (obs_metrics.N_SLOTS,)
    packed = obs_metrics.shard_pack(local)
    assert np.asarray(packed.counts).shape == (1, obs_metrics.N_SLOTS)


def test_mesh_key_shapes():
    assert obs_jits.mesh_key(None) == ()
    if jax.local_device_count() >= 2:
        mesh = _mesh()
        key = obs_jits.mesh_key(mesh)
        assert key == (("fleet", fleet.n_shards(mesh)),)


# ---------------------------------------------------------------------------
# checkpoint resharding: a snapshot restores onto ANY mesh size, bitwise
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("direction", ["up", "down"])
def test_checkpoint_reshard_bit_identity(tmp_path, direction):
    """A checkpoint written on 1 device restores onto the mesh ("up")
    and a mesh checkpoint restores onto 1 device ("down"), then resumes
    to finals bitwise equal to an uninterrupted single-device run —
    snapshot strips shard padding, restore re-pads to the target
    engine's multiple, and the canonical metrics counters re-aggregate
    exactly. Mixed exact + logmem fleet, M not a shard multiple."""
    from repro.resilience import FleetCheckpointer
    mesh = _mesh()
    src_mesh, dst_mesh = (None, mesh) if direction == "up" else (mesh, None)
    m, batch, n_chunks, cut = 7, 6, 12, 7
    rng = np.random.default_rng(900)
    traces = rng.standard_normal((m, batch * n_chunks)).astype(np.float32)

    def build(mesh):
        specs = [StreamSpec(stream_id=i, k=32, r=48.0, engine="logmem")
                 if i % 3 == 2 else StreamSpec(stream_id=i, k=4, r=48.0)
                 for i in range(m)]
        return StreamEngine(specs, obs=Observability(ObsConfig()),
                            mesh=mesh)

    def feed(engine, t):
        perm = np.random.default_rng(7000 + t).permutation(m * batch)
        sids = np.repeat(np.arange(m), batch)[perm]
        dids = np.tile(np.arange(t * batch, (t + 1) * batch), m)[perm]
        scores = traces[:, t * batch:(t + 1) * batch].reshape(-1)[perm]
        engine.ingest(sids, scores, dids)

    ref = build(None)
    for t in range(n_chunks):
        feed(ref, t)

    src = build(src_mesh)
    for t in range(cut):
        feed(src, t)
    ck = FleetCheckpointer(str(tmp_path), every=0)
    ck.save(src, blocking=True)

    dst = build(dst_mesh)
    FleetCheckpointer(str(tmp_path)).restore(dst)
    assert dst.chunks_ingested == cut
    for t in range(cut, n_chunks):
        feed(dst, t)
    _assert_engines_identical(ref, dst)


# ---------------------------------------------------------------------------
# forced-mesh subprocess smoke (runs even on 1-device hosts)
# ---------------------------------------------------------------------------

_SMOKE = """
import numpy as np
from repro.parallel import fleet
from repro.streams import StreamEngine, StreamSpec
mesh = fleet.fleet_mesh(2)
assert mesh is not None and fleet.n_shards(mesh) == 2
desired = np.array([4.0, 0.0, 9.0])
g = fleet.waterfill_sharded(desired, 6.0, mesh)
assert g.sum() <= 6.0 * (1 + 1e-12)
specs = [StreamSpec(stream_id=i, k=2, r=8.0) for i in range(3)]
ref = StreamEngine(specs)
shd = StreamEngine([StreamSpec(stream_id=i, k=2, r=8.0)
                    for i in range(3)], mesh=mesh)
rng = np.random.default_rng(0)
for t in range(4):
    sc = rng.standard_normal(3).astype(np.float32)
    ref.ingest(np.arange(3), sc, np.full(3, t))
    shd.ingest(np.arange(3), sc, np.full(3, t))
a, b = ref.finalize(), shd.finalize()
for sid in a:
    np.testing.assert_array_equal(a[sid], b[sid])
print("SMOKE-OK")
"""


def test_forced_mesh_subprocess_smoke():
    """One end-to-end sharded pass under a forced 2-device CPU mesh, so
    plain single-device test runs still exercise the mesh code path."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in (env.get("PYTHONPATH"),) if p]
        + [os.path.join(os.path.dirname(__file__), "..", "src")])
    out = subprocess.run([sys.executable, "-c", _SMOKE], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SMOKE-OK" in out.stdout
