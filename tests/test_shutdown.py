"""Graceful shutdown: SIGTERM against the serving example must drain —
finish the in-flight batch, write a final blocking checkpoint at the
ingest cursor, flush the obs artifacts, and exit 0."""
import os
import signal
import subprocess
import sys
import time

import pytest


def test_sigterm_drains_and_checkpoints(tmp_path):
    ckpt = tmp_path / "ckpt"
    obs = tmp_path / "obs"
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in (env.get("PYTHONPATH"),) if p]
        + [os.path.join(root, "src")])
    proc = subprocess.Popen(
        [sys.executable, os.path.join(root, "examples", "serve_topk.py"),
         "--tenants", "2", "--requests", "32", "--batch", "4",
         "--obs-hold", "120", "--ckpt-dir", str(ckpt),
         "--ckpt-every", "1", "--obs-out", str(obs)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    try:
        # wait until the serving loop has committed a checkpoint (the
        # loop is live and the handler is installed), then interrupt it
        deadline = time.time() + 240
        while time.time() < deadline:
            if ckpt.is_dir() and any(
                    d.startswith("ckpt_") for d in os.listdir(ckpt)):
                break
            if proc.poll() is not None:
                pytest.fail("server exited early:\n"
                            + proc.communicate()[0][-2000:])
            time.sleep(0.5)
        else:
            pytest.fail("no checkpoint appeared before the deadline")
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, out[-2000:]
    assert "graceful shutdown on SIGTERM" in out
    assert "final checkpoint: generation" in out
    # the drain flushed the obs artifacts and the final checkpoint
    assert (obs / "metrics.json").exists(), out[-2000:]
    names = sorted(d for d in os.listdir(ckpt) if d.startswith("ckpt_"))
    assert names, out[-2000:]
