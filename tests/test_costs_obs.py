"""Cost-attribution observability (repro.obs.costs).

The contract under test, layer by layer:

* carrying the device ``CostState`` ledger through the jitted step must
  not perturb the computation (bit-identity with costs off);
* the ledger's integer (stream, tier) counts must reconcile bit-exactly
  with the host meter, and — at W=1, where the engine's chunk timing
  equals the simulator's per-doc timing — with the trace-driven
  simulator's priced write/read components (storage to fp tolerance:
  same integer doc-steps, host-priced in one f64 dot product each side);
* the sharded ledger must drain to the same global counts as the
  single-device run (row-independent accumulation);
* the ``CostMonitor`` cost-residual / budget burn-rate channels hold
  their false-positive budget on null (undrifted) fleets, and catch a
  genuine overspend (drift into an expensive tier) fast enough to drive
  a cost-triggered re-plan that lowers the realized-cost slope.
"""
import numpy as np
import pytest

import jax

from repro.core import constraints as cons, costs as cc, simulator
from repro.obs import Observability, ObsConfig
from repro.obs import costs as costs_mod
from repro.online import DriftConfig, ReplanConfig, evaluate
from repro.streams.engine import StreamEngine, StreamSpec

needs_mesh = pytest.mark.skipif(
    jax.local_device_count() < 2,
    reason="needs a multi-device mesh (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _w1_fleet(n=512, k=8, m=3, seed=0, engines=None):
    """Per-doc (W=1) ingest: engine chunk timing == simulator timing."""
    cm = cc.hbm_host_preset(n_docs=n, k=k, doc_gb=1e-4, window_seconds=60.0)
    rng = np.random.default_rng(seed)
    traces = [simulator.random_rank_trace(n, rng) for _ in range(m)]
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm,
                        engine=engines[i] if engines else "exact")
             for i in range(m)]
    return cm, traces, specs


def _run_w1(traces, specs, mesh=None):
    m, n = len(traces), len(traces[0])
    obs = Observability(ObsConfig(costs=True))
    eng = StreamEngine(specs, obs=obs, mesh=mesh)
    for pos in range(n):
        eng.ingest(np.arange(m),
                   np.array([t[pos] for t in traces], np.float32),
                   np.full(m, pos, np.int64))
    eng.finalize()
    return eng


# ---------------------------------------------------------------------------
# bit-identity
# ---------------------------------------------------------------------------

def test_costs_off_and_on_bit_identical_output():
    """Folding the CostState into the step must not change survivors,
    reservoir state, or the meter ledger — the cost accumulators only
    read values the step already materializes."""
    rng = np.random.default_rng(11)
    n, m, k = 2048, 5, 16
    traces = rng.standard_normal((m, n)).astype(np.float32)
    specs = [StreamSpec(stream_id=i, k=k, r=600.0) for i in range(m)]

    def run(obs):
        eng = StreamEngine(specs, obs=obs)
        sids = np.arange(m)
        for t0 in range(0, n, 64):
            eng.ingest(np.repeat(sids, 64),
                       traces[:, t0:t0 + 64].reshape(-1),
                       np.tile(np.arange(t0, t0 + 64), m))
        return eng, eng.finalize()

    e_off, s_off = run(Observability(ObsConfig()))
    e_on, s_on = run(Observability(ObsConfig(costs=True)))
    assert sorted(s_off) == sorted(s_on)
    for sid in s_off:
        np.testing.assert_array_equal(s_off[sid], s_on[sid])
    np.testing.assert_array_equal(e_off.meter.writes, e_on.meter.writes)
    np.testing.assert_array_equal(e_off.meter.deletes, e_on.meter.deletes)
    for b_off, b_on in zip(e_off._states, e_on._states):
        np.testing.assert_array_equal(np.asarray(b_off.ids),
                                      np.asarray(b_on.ids))
        np.testing.assert_array_equal(np.asarray(b_off.scores),
                                      np.asarray(b_on.scores))


# ---------------------------------------------------------------------------
# ledger reconciliation: device == meter == simulator
# ---------------------------------------------------------------------------

def test_cost_ledger_reconciles_with_simulator_at_w1():
    """Exact engine, one doc per ingest: the device ledger's integer
    counts equal the meter's, and the host-priced realized costs equal
    the trace-driven simulator's bill — writes and reads bit-exactly
    (identical integers through identical f64 dot products), storage to
    fp tolerance of the identical integer doc-step rental."""
    n, k = 512, 8
    cm, traces, specs = _w1_fleet(n=n, k=k, m=3, seed=0)
    eng = _run_w1(traces, specs)
    summ = eng.cost_summary()
    dev = summ["device"]
    np.testing.assert_array_equal(dev["writes"], eng.meter.writes)
    np.testing.assert_array_equal(dev["deletes"], eng.meter.deletes)
    np.testing.assert_array_equal(dev["resident_steps"],
                                  eng.meter.doc_steps)
    nt = cm if isinstance(cm, cc.NTierCostModel) else cm.as_ntier()
    slot = nt.workload.window_months / n
    depth = int(np.isfinite(eng.meter.boundaries[0]).sum())
    for i, t in enumerate(traces):
        res = evaluate.realized(t, k, cm,
                                tuple(eng.meter.boundaries[i][:depth]))
        np.testing.assert_array_equal(res.writes_per_tier,
                                      eng.meter.writes[i])
        dm = np.rint(res.doc_months_per_tier / slot).astype(np.int64)
        np.testing.assert_array_equal(dm, dev["resident_steps"][i])
        assert res.cost_writes == summ["writes"][i]
        assert res.cost_reads == summ["reads"][i]
        assert np.isclose(res.cost_storage, summ["storage"][i], rtol=1e-9)
        assert np.isclose(res.cost_total, summ["total"][i], rtol=1e-9)


def test_logmem_ledger_reconciles_with_meter_at_w1():
    """Logmem rows store no ids, so the ledger counts cumulative writes
    as occupancy — exactly the meter's convention; device must equal
    meter on writes, zero deletes, and the doc-step rental integral."""
    cm, traces, specs = _w1_fleet(n=512, k=16, m=4, seed=2,
                                  engines=["logmem"] * 4)
    eng = _run_w1(traces, specs)
    dev = costs_mod.device_counts(eng)
    np.testing.assert_array_equal(dev["writes"], eng.meter.writes)
    assert int(dev["deletes"].sum()) == 0
    np.testing.assert_array_equal(dev["resident_steps"],
                                  eng.meter.doc_steps)


@needs_mesh
def test_sharded_cost_ledger_matches_unsharded():
    """The per-row CostState shards with the fleet axis; draining the
    sharded ledger must give the same global counts, and the same
    priced snapshot, as the single-device run — on a mixed exact/logmem
    fleet."""
    from repro.parallel import fleet
    n, k, m = 512, 16, 8
    cm, traces, _ = _w1_fleet(n=n, k=k, m=m, seed=1)
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm,
                        engine="logmem" if i % 2 else "exact")
             for i in range(m)]
    e1 = _run_w1(traces, specs)
    e2 = _run_w1(traces, specs,
                 mesh=fleet.fleet_mesh(min(jax.local_device_count(), 8)))
    d1, d2 = costs_mod.device_counts(e1), costs_mod.device_counts(e2)
    for name in d1:
        np.testing.assert_array_equal(d1[name], d2[name])
    np.testing.assert_array_equal(d1["writes"], e1.meter.writes)
    np.testing.assert_array_equal(d1["resident_steps"], e1.meter.doc_steps)
    assert e1.obs_snapshot()["costs"] == e2.obs_snapshot()["costs"]


# ---------------------------------------------------------------------------
# CostMonitor: null FPR and the overspend -> re-plan chain
# ---------------------------------------------------------------------------

def _cost_null_fpr(seed: int, alpha: float, m: int = 48) -> float:
    """Fraction of null (i.u.d.) priced streams either cost channel
    (residual or budget burn) flags across a full window, engine-fed."""
    n, k = 4096, 16
    cm = cc.hbm_host_preset(n_docs=n, k=k, doc_gb=1e-4, window_seconds=60.0)
    rng = np.random.default_rng(seed)
    traces = np.stack([simulator.random_rank_trace(n, rng)
                       for _ in range(m)])
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm) for i in range(m)]
    obs = Observability(ObsConfig(costs=True, cost_alpha=alpha))
    eng = StreamEngine(specs, obs=obs)
    sids = np.arange(m)
    for t0 in range(0, n, 64):
        eng.ingest(np.repeat(sids, 64), traces[:, t0:t0 + 64].reshape(-1),
                   np.tile(np.arange(t0, t0 + 64), m))
    mon = eng._cost_monitor
    return float((mon.alerted | mon.burn_alerted).mean())


@pytest.mark.parametrize("seed,alpha", [(0, 0.05), (1, 0.01)])
def test_cost_monitor_null_fpr(seed, alpha):
    assert _cost_null_fpr(seed, alpha) <= alpha


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    @settings(max_examples=5, deadline=None)
    def test_cost_monitor_null_fpr_property(seed):
        assert _cost_null_fpr(seed, 0.05) <= 0.05


def test_budget_burn_drives_replan_and_bends_cost_curve():
    """The acceptance chain: tenants drift into an expensive-write cold
    tier, the budget burn-rate rule fires, the alert (not the near-blind
    drift detector) triggers the suffix re-solve, and the post-re-plan
    realized-cost slope drops below the pre-re-plan slope."""
    m, n, k, drift_at, chunk = 4, 12000, 64, 3000, 64
    wl = cc.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-4, window_months=0.5)
    hot = cc.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                       storage_per_gb_month=0.05)
    cold = cc.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                        storage_per_gb_month=0.02)
    cm = cc.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)
    rng = np.random.default_rng(7)
    drifted = np.array([i < m // 2 for i in range(m)])
    traces = np.stack([
        simulator.drifted_rank_trace(n, rng, [(drift_at, 8.0)])
        if drifted[i] else simulator.random_rank_trace(n, rng)
        for i in range(m)])
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm) for i in range(m)]
    obs = Observability(ObsConfig(costs=True, cost_trigger=True,
                                  cost_alpha=0.01))
    eng = StreamEngine(
        specs, obs=obs,
        constraints=cons.ConstraintSet(cons.TierCapacity(0, 4 * k)),
        replan=ReplanConfig(drift=DriftConfig(alpha=1e-9)))
    sids = np.arange(m)
    realized = []
    for t0 in range(0, n, chunk):
        c = min(chunk, n - t0)
        eng.ingest(np.repeat(sids, c), traces[:, t0:t0 + c].reshape(-1),
                   np.tile(t0 + np.arange(c), m))
        realized.append(eng._cost_monitor.realized_total[drifted].sum())
    eng.finalize()
    realized = np.asarray(realized)

    events = obs.tracer.events
    fired = [e["attrs"] for e in events
             if e["name"] in ("cost_alert", "budget_burn")]
    assert any(drifted[a["row"]] for a in fired), \
        "no cost/burn alert on a drifted stream"
    applied = [e["attrs"] for e in events
               if e["name"] == "replan_decision"
               and e["attrs"]["cost_triggered"] and e["attrs"]["applied"]]
    assert applied, "no applied re-plan was cost-triggered"
    rc = min(min(a["position"] for a in applied) // chunk,
             len(realized) - 3)
    dc = drift_at // chunk
    pre = (realized[rc] - realized[dc]) / max(rc - dc, 1)
    post = (realized[-1] - realized[rc + 1]) / max(len(realized) - rc - 2, 1)
    assert post < pre, (pre, post)
    # alerts surface through the public API with their channel
    kinds = {v["kind"] for v in eng.cost_alerts().values()}
    assert kinds <= {"residual", "burn"} and kinds


def test_expected_cost_trajectory_matches_simulator_mean():
    """The closed-form planned write+storage trajectory tracks the
    realized i.u.d. bill: terminal value within a few sigma (Monte Carlo
    over seeds would be exact; one seed stays within 15%)."""
    n, k = 512, 8
    cm, traces, specs = _w1_fleet(n=n, k=k, m=3, seed=4)
    eng = _run_w1(traces, specs)
    nt = cm if isinstance(cm, cc.NTierCostModel) else cm.as_ntier()
    pricing = costs_mod.stream_pricing(eng)
    depth = int(np.isfinite(eng.meter.boundaries[0]).sum())
    traj = costs_mod.expected_cost_trajectory(
        eng.meter.boundaries[0][:depth], n, k,
        pricing["cw"][0], pricing["step_rate"][0])
    assert traj.shape == (n,)
    assert np.all(np.diff(traj) >= -1e-12)  # cumulative, non-decreasing
    summ = eng.cost_summary()
    realized_ws = summ["writes"] + summ["storage"]
    assert np.isclose(traj[-1], np.mean(realized_ws), rtol=0.15)


def test_cost_monitor_snapshot_and_export_shape():
    """The costs block is scalars-only (Prometheus-exportable) and the
    counter leaves are typed counters in the exposition."""
    from repro.obs import export
    cm, traces, specs = _w1_fleet(n=256, k=8, m=2, seed=3)
    eng = _run_w1(traces, specs)
    obs = eng._obs
    snap = eng.obs_snapshot()["costs"]
    for group in ("realized", "regret", "device", "alerts"):
        assert all(np.isscalar(v) or isinstance(v, (int, float))
                   for v in snap[group].values()), group
    text = obs.prometheus()
    assert ("# TYPE repro_obs_engines_engine0_costs_device_resident_steps "
            "counter") in text
    assert "costs_realized_total" in text
