"""Degenerate cost models through both two-tier planners (the scalar
``shp.plan_placement`` and the vectorized ``streams.planner.plan_fleet``):
zero write-cost deltas, zero storage-rate deltas, and zero read deltas must
take the ``_safe_div`` / NaN-gate paths identically — finite totals, no
inf/nan, same chosen strategy. Plus a scalar-vs-fleet-vs-brute-force
property on random cost grids (hypothesis when available, a seeded sweep
otherwise)."""
import math

import numpy as np
import pytest

from repro.core import costs, shp
from repro.streams import planner


def make_model(cw_a, cw_b, cr_a, cr_b, cs_a, cs_b,
               n=100_000, k=100) -> costs.TwoTierCostModel:
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1.0, window_months=1.0)
    return costs.TwoTierCostModel(
        tier_a=costs.TierCosts("a", cw_a, cr_a, cs_a),
        tier_b=costs.TierCosts("b", cw_b, cr_b, cs_b), workload=wl)


def assert_scalar_fleet_agree(cm):
    sp = shp.plan_placement(cm)
    fp = planner.plan_fleet([cm])
    assert np.isfinite(sp.best.total)
    assert np.isfinite(fp.best_total[0])
    assert sp.strategy == fp.strategy(0)
    np.testing.assert_allclose(fp.best_total[0], sp.best.total, rtol=1e-12)
    pol_s = fp.policy(0)
    assert np.isfinite(pol_s.r)
    return sp, fp


def brute_min_over_candidates(cm, num=2001):
    """Numeric reference: the same four gated candidate families, interior
    curves swept over an r grid."""
    vals = [shp.cost_single_tier(cm, "a").total,
            shp.cost_single_tier(cm, "b").total]
    wl = cm.workload
    rs = np.linspace(wl.k + 1.0, wl.n_docs - 1.0, num)
    if shp.r_is_valid(cm, shp.r_optimal_no_migration(cm)):
        vals.append(min(shp.cost_no_migration(cm, float(r)).total
                        for r in rs))
    if shp.r_is_valid(cm, shp.r_optimal_migration(cm)):
        vals.append(min(shp.cost_with_migration(cm, float(r)).total
                        for r in rs))
    return min(vals)


# ---------------------------------------------------------------------------
# _safe_div regressions: every zero-delta degeneracy
# ---------------------------------------------------------------------------

def test_equal_write_costs_gate_no_nan():
    """cw_A == cw_B: both stationary points are 0/den — the gate must trip
    in both planners without emitting inf/nan totals."""
    cm = make_model(1e-5, 1e-5, 1e-6, 1e-4, 2e-4, 1e-6)
    sp, fp = assert_scalar_fleet_agree(cm)
    assert np.isinf(fp.totals[0, 2]) and np.isinf(fp.totals[0, 3])
    assert sp.strategy in ("all_tier_a", "all_tier_b")


def test_zero_storage_rate_delta_no_nan():
    """cs_A == cs_B: eq. 21's denominator vanishes → _safe_div NaN → the
    migration candidate is gated, identically in both planners."""
    cm = make_model(1e-6, 5e-5, 2e-4, 1e-6, 5e-5, 5e-5)
    sp, fp = assert_scalar_fleet_agree(cm)
    assert math.isnan(shp.r_optimal_migration(cm))
    assert math.isnan(fp.r_migration[0])
    assert np.isinf(fp.totals[0, 3])
    # the no-migration candidate is still live (r*/N ~ 0.25)
    assert np.isfinite(fp.totals[0, 2])


def test_zero_read_delta_no_nan():
    """cr_A == cr_B: eq. 17's denominator vanishes → no-migration gated."""
    cm = make_model(1e-6, 5e-5, 3e-5, 3e-5, 2e-4, 1e-6)
    sp, fp = assert_scalar_fleet_agree(cm)
    assert math.isnan(shp.r_optimal_no_migration(cm))
    assert math.isnan(fp.r_no_migration[0])
    assert np.isinf(fp.totals[0, 2])
    # the migration candidate is still live (r*/N ~ 0.25)
    assert np.isfinite(fp.totals[0, 3])


def test_fully_symmetric_tiers_no_nan():
    cm = make_model(*([2e-5] * 6))
    sp, fp = assert_scalar_fleet_agree(cm)
    assert np.isfinite(sp.best.total)
    assert np.isinf(fp.totals[0, 2]) and np.isinf(fp.totals[0, 3])


def test_degenerate_models_agree_with_brute_force():
    for cm in [make_model(1e-5, 1e-5, 1e-6, 1e-4, 2e-4, 1e-6),
               make_model(1e-6, 1e-4, 1e-4, 1e-6, 5e-5, 5e-5),
               make_model(1e-6, 1e-4, 3e-5, 3e-5, 1e-4, 1e-6)]:
        sp = shp.plan_placement(cm)
        brute = brute_min_over_candidates(cm, num=801)
        assert sp.best.total <= brute * (1 + 1e-9)


# ---------------------------------------------------------------------------
# scalar vs fleet vs brute force on random cost grids
# ---------------------------------------------------------------------------

def check_grid(cw_a, cw_b, cr_a, cr_b, cs_a, cs_b):
    cm = make_model(cw_a, cw_b, cr_a, cr_b, cs_a, cs_b)
    sp, fp = assert_scalar_fleet_agree(cm)
    brute = brute_min_over_candidates(cm)
    assert sp.best.total <= brute * (1 + 1e-9), (sp.best.total, brute)
    # the brute grid can only beat the closed form by grid resolution
    assert sp.best.total >= brute * (1 - 1e-3) - 1e-12


def test_random_cost_grids_seeded_sweep():
    """Runs everywhere (no hypothesis): random grids with deliberate
    zero-delta degeneracies mixed in."""
    rng = np.random.default_rng(19)
    for trial in range(120):
        v = 10.0 ** rng.uniform(-8, -3, 6)
        if trial % 4 == 1:
            v[1] = v[0]  # cw delta == 0
        if trial % 4 == 2:
            v[5] = v[4]  # cs delta == 0
        if trial % 4 == 3:
            v[3] = v[2]  # cr delta == 0
        check_grid(*v)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    cost_floats = st.floats(min_value=1e-8, max_value=1e-3,
                            allow_nan=False, allow_infinity=False)

    @given(cw_a=cost_floats, cw_b=cost_floats, cr_a=cost_floats,
           cr_b=cost_floats, cs_a=cost_floats, cs_b=cost_floats,
           tie=st.sampled_from(["none", "cw", "cr", "cs"]))
    @settings(max_examples=80, deadline=None)
    def test_scalar_fleet_brute_property(cw_a, cw_b, cr_a, cr_b,
                                         cs_a, cs_b, tie):
        if tie == "cw":
            cw_b = cw_a
        elif tie == "cr":
            cr_b = cr_a
        elif tie == "cs":
            cs_b = cs_a
        check_grid(cw_a, cw_b, cr_a, cr_b, cs_a, cs_b)
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(requirements-dev)")
    def test_scalar_fleet_brute_property():
        pass
