"""repro.obs — the telemetry layer's contracts: obs-off bit-identity of
the jitted engine step, device counters reconciling exactly against the
host meter ledger, the ResidualMonitor alert channel (null FPR bounded
by alpha; fires at or before the in-step CUSUM on the drifted
acceptance fleet), model-referenced reconcile residuals on mixed-depth
fleets, the structured constraint-violation report, jit-cache probes
(zero recompiles on identical re-solves), and the tracer / Prometheus
export formats."""
import json

import numpy as np
import pytest

from repro.core import constraints as cons, costs, shp, simulator
from repro.obs import (Observability, ObsConfig, export, jits, timers,
                       trace)
from repro.obs.residuals import ResidualMonitor
from repro.online import DriftConfig, ReplanConfig, evaluate
from repro.streams import engine as seng
from repro.streams.engine import StreamEngine, StreamSpec


# ---------------------------------------------------------------------------
# scenario helpers
# ---------------------------------------------------------------------------

def _two_tier_model(n=12000, k=64):
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-4, window_months=0.5)
    hot = costs.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                          storage_per_gb_month=0.05)
    cold = costs.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                           storage_per_gb_month=0.02)
    return costs.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)


def _drifted_fleet(m=6, n=12000, k=64, drift_at=3000, mult=8.0, seed=5):
    rng = np.random.default_rng(seed)
    cm = _two_tier_model(n=n, k=k)
    traces = np.stack([simulator.drifted_rank_trace(n, rng,
                                                    [(drift_at, mult)])
                       for _ in range(m)])
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm) for i in range(m)]
    cset = cons.ConstraintSet(cons.TierCapacity(0, 4 * k))
    return traces, specs, cset


def _run(traces, specs, cset=None, obs=None, alpha=0.05, chunk=64):
    return evaluate.run_fleet(
        traces, specs, replan=ReplanConfig(drift=DriftConfig(alpha=alpha)),
        chunk=chunk, constraints=cset, obs=obs)


# ---------------------------------------------------------------------------
# bit-identity + device counters
# ---------------------------------------------------------------------------

def test_obs_off_and_on_bit_identical_output():
    """The telemetry layer must not perturb the computation: survivors,
    reservoir state, and the meter ledger are bit-equal with obs on/off
    (metrics off traces the exact pre-obs step; metrics on only adds
    counter reductions)."""
    rng = np.random.default_rng(11)
    n, m, k = 2048, 5, 16
    traces = rng.standard_normal((m, n)).astype(np.float32)
    specs = [StreamSpec(stream_id=i, k=k, r=600.0) for i in range(m)]

    def run(obs):
        eng = StreamEngine(specs, obs=obs)
        sids = np.arange(m)
        for t0 in range(0, n, 64):
            eng.ingest(np.repeat(sids, 64),
                       traces[:, t0:t0 + 64].reshape(-1),
                       np.tile(np.arange(t0, t0 + 64), m))
        surv = eng.finalize()
        return eng, surv

    e_off, s_off = run(None)
    e_on, s_on = run(Observability(ObsConfig()))
    assert sorted(s_off) == sorted(s_on)
    for sid in s_off:
        np.testing.assert_array_equal(s_off[sid], s_on[sid])
    np.testing.assert_array_equal(e_off.meter.writes, e_on.meter.writes)
    np.testing.assert_array_equal(e_off.meter.observed, e_on.meter.observed)
    for b_off, b_on in zip(e_off._states, e_on._states):
        np.testing.assert_array_equal(np.asarray(b_off.ids),
                                      np.asarray(b_on.ids))
        np.testing.assert_array_equal(np.asarray(b_off.scores),
                                      np.asarray(b_on.scores))


def test_device_counters_reconcile_with_meter():
    """The MetricsState counters drained from the device must equal the
    host meter's ledger exactly — same events, counted on both sides."""
    rng = np.random.default_rng(3)
    n, m, k = 4096, 4, 16
    traces = rng.standard_normal((m, n)).astype(np.float32)
    specs = [StreamSpec(stream_id=i, k=k, r=1200.0) for i in range(m)]
    obs = Observability(ObsConfig())
    eng = StreamEngine(specs, obs=obs)
    sids = np.arange(m)
    for t0 in range(0, n, 64):
        eng.ingest(np.repeat(sids, 64), traces[:, t0:t0 + 64].reshape(-1),
                   np.tile(np.arange(t0, t0 + 64), m))
    snap = eng.obs_snapshot()
    em = snap["engine"]
    assert em["docs"] == int(eng.meter.observed.sum()) == n * m
    assert em["admits"] == int(eng.meter.writes.sum())
    assert em["evictions"] == int(eng.meter.deletes.sum())
    assert em["chunks"] == n // 64
    assert em["bar_candidates"] == em["docs"]
    # every admitted doc passed the bar; pass rate bounded by admits
    assert em["bar_passes"] >= em["admits"]
    assert 0.0 < em["filter_pass_rate"] < 1.0


# ---------------------------------------------------------------------------
# residual alert channel
# ---------------------------------------------------------------------------

def _monitor_null_fpr(seed: int, alpha: float, m: int = 128) -> float:
    """Fraction of null (i.u.d.) streams the ResidualMonitor flags across
    a full window, fed from the engine's batched update — the mirror of
    test_online's detector ``_null_fpr``."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    n, k, w = 4096, 16, 64
    mon = ResidualMonitor(np.full(m, k, np.float64), alpha=alpha)
    state = seng.init(m, k)
    traces = rng.standard_normal((m, n)).astype(np.float32)
    writes = np.zeros(m)
    for c0 in range(0, n, w):
        sc = jnp.asarray(traces[:, c0:c0 + w])
        ids = jnp.tile(jnp.arange(c0, c0 + w, dtype=jnp.int32), (m, 1))
        state, wrote = seng.update(state, sc, ids)
        writes += np.asarray(wrote).sum(1)
        mon.update(np.asarray(state.seen), writes)
    return float(mon.alerted.mean())


@pytest.mark.parametrize("seed,alpha", [(0, 0.05), (1, 0.01)])
def test_residual_monitor_null_fpr(seed, alpha):
    assert _monitor_null_fpr(seed, alpha) <= alpha


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    @settings(max_examples=5, deadline=None)
    def test_residual_monitor_null_fpr_property(seed):
        assert _monitor_null_fpr(seed, 0.05) <= 0.05


def test_residual_alerts_at_or_before_cusum_on_acceptance_fleet():
    """On the PR-4 drifted acceptance fleet the obs residual channel must
    flag >=90% of the detector-detected streams at or before the CUSUM
    detection index (its excursion statistic equals the detector's, so in
    practice it ties every stream)."""
    traces, specs, cset = _drifted_fleet()
    obs = Observability(ObsConfig(residual_alpha=0.05))
    eng = _run(traces, specs, cset, obs=obs)
    alerts = eng.residual_alerts()
    detected = {}
    for ev in eng.replan_events:
        detected.setdefault(ev.stream_id, ev.position)
    assert detected, "acceptance fleet must trigger detections"
    won = sum(1 for sid, pos in detected.items()
              if alerts.get(sid) is not None and alerts[sid] <= pos)
    assert won / len(detected) >= 0.9
    # the alert events are on the trace timeline too
    names = [e["name"] for e in obs.tracer.events]
    assert "residual_alert" in names and "replan_decision" in names


def test_reconcile_residuals_mixed_depth_drifted_fleet():
    """FleetMeter.reconcile + the monitor's write-law z on a mixed-depth
    fleet (2- and 3-tier streams) where half the streams drift 8x:
    undrifted residuals stay near zero, drifted ones are large and
    positive (the burst admits more than the stationary law expects)."""
    rng = np.random.default_rng(7)
    n, k, m, chunk = 6400, 32, 6, 64
    drifted = np.array([False, True, False, True, False, True])
    traces = np.stack([
        simulator.drifted_rank_trace(n, rng, [(1600, 8.0)]) if d
        else rng.standard_normal(n).astype(np.float64)
        for d in drifted])
    specs = []
    for i in range(m):
        if i % 2 == 0:  # mixed tier depth: alternate 2- and 3-tier
            specs.append(StreamSpec(stream_id=i, k=k, r=0.29 * n))
        else:
            specs.append(StreamSpec(stream_id=i, k=k,
                                    boundaries=(0.2 * n, 0.6 * n)))
    obs = Observability(ObsConfig(residual_alpha=0.05))
    eng = StreamEngine(specs, obs=obs)
    sids = np.arange(m)
    for t0 in range(0, n, chunk):
        eng.ingest(np.repeat(sids, chunk),
                   traces[:, t0:t0 + chunk].reshape(-1),
                   np.tile(np.arange(t0, t0 + chunk), m))
    rec = eng.meter.reconcile(batch=chunk)
    z = eng._residuals.write_z()["z"]
    # undrifted: single-sample rel err is noisy but centered; z is tight
    assert float(np.abs(rec["rel_err"][~drifted]).mean()) < 0.2
    assert float(np.abs(z[~drifted]).max()) < 3.5
    # drifted: admissions far above the stationary law, positive sign
    assert bool(np.all(rec["rel_err"][drifted] > 0.3))
    assert bool(np.all(z[drifted] > 5.0))
    # the alert channel caught every drifted stream and no undrifted one
    alerted_rows = {eng.stream_row(s) for s in eng.residual_alerts()}
    assert alerted_rows == set(np.flatnonzero(drifted))


def test_residual_trigger_feeds_replanner():
    """With ``residual_trigger`` the alert channel rows are unioned into
    the re-plan trigger; on the acceptance fleet (where the statistics
    tie) the closed loop still replans every drifted stream and the
    decisions are annotated on the event log."""
    traces, specs, cset = _drifted_fleet(m=4)
    obs = Observability(ObsConfig(residual_alpha=0.05,
                                  residual_trigger=True))
    eng = _run(traces, specs, cset, obs=obs)
    applied = {e.stream_id for e in eng.replan_events if e.applied}
    assert applied == set(range(4))
    decisions = [e for e in obs.tracer.events
                 if e["name"] == "replan_decision"]
    assert decisions and all("residual_triggered" in d["attrs"]
                             for d in decisions)


# ---------------------------------------------------------------------------
# structured constraint report
# ---------------------------------------------------------------------------

def test_check_constraints_structured_report_and_events():
    """An over-capacity hot tier yields a structured violation entry
    (stream, tier, kind, signed margin) and an event on the obs log."""
    rng = np.random.default_rng(2)
    n, m, k = 1024, 3, 16
    traces = rng.standard_normal((m, n)).astype(np.float32)
    specs = [StreamSpec(stream_id=i, k=k, r=float(n)) for i in range(m)]
    obs = Observability(ObsConfig())
    eng = StreamEngine(specs, obs=obs)
    sids = np.arange(m)
    for t0 in range(0, n, 64):
        eng.ingest(np.repeat(sids, 64), traces[:, t0:t0 + 64].reshape(-1),
                   np.tile(np.arange(t0, t0 + 64), m))
    eng.finalize()
    # r = n puts every resident hot; cap hot at k/2 -> must violate
    report = eng.check_constraints(
        cons.ConstraintSet(cons.TierCapacity(0, k // 2)))
    assert not report["ok"]
    v = report["violations"][0]
    assert v["kind"] == "capacity" and v["tier"] == 0
    assert v["stream_id"] in set(range(m))
    assert v["measured"] > v["limit"]
    assert v["margin"] == pytest.approx(v["measured"] - v["limit"])
    ev = [e for e in obs.tracer.events
          if e["name"] == "constraint_violation"]
    assert len(ev) == len(report["violations"])
    assert ev[0]["attrs"]["kind"] == "capacity"


# ---------------------------------------------------------------------------
# jit probes, tracer, export, timers
# ---------------------------------------------------------------------------

def test_jit_probe_zero_recompiles_on_identical_solve():
    """Repeating an identical fleet solve must be a 100% jit-cache hit:
    the probe's miss counter stays flat across the second call."""
    rng = np.random.default_rng(0)
    m, t = 64, 3
    args = (10.0 ** rng.uniform(-8, -3, (m, t)),
            10.0 ** rng.uniform(-8, -3, (m, t)),
            10.0 ** rng.uniform(-8, -3, (m, t)),
            rng.integers(10_000, 50_000, m).astype(np.float64),
            np.full(m, 64.0), np.ones(m))
    shp.plan_ntier_arrays(*args)
    p = jits.probe("shp_jax.plan").snapshot()
    assert p["calls"] >= 1
    before = p["misses"]
    shp.plan_ntier_arrays(*args)
    after = jits.probe("shp_jax.plan").snapshot()
    assert after["misses"] == before
    assert after["calls"] >= p["calls"] + 1
    assert after["by_key"], "per-signature tallies must be kept"


def test_replan_probe_tracks_solver():
    traces, specs, cset = _drifted_fleet(m=3)
    before = jits.probe("replan_device.solve").snapshot()["calls"]
    _run(traces, specs, cset)
    after = jits.probe("replan_device.solve").snapshot()
    assert after["calls"] > before, "replans must route through the probe"


def test_tracer_schema_and_jsonl_roundtrip(tmp_path):
    tr = trace.Tracer(None)
    with tr.span("outer", m=4) as attrs:
        attrs["extra"] = np.int64(7)
        tr.emit("point", x=1.5)
    path = tr.write(str(tmp_path / "events.jsonl"))
    recs = [json.loads(line) for line in open(path)]
    assert [r["name"] for r in recs] == ["point", "outer"]
    for r in recs:
        assert r["v"] == 1 and set(r) >= {"kind", "name", "ts", "attrs"}
    outer = recs[1]
    assert outer["kind"] == "span" and outer["dur_s"] >= 0.0
    assert outer["attrs"] == {"m": 4, "extra": 7}


def test_prometheus_exposition_format():
    snap = {"engines": {"engine0": {"engine": {"docs": 12, "rate": 0.5},
                                    "tiers": [3, 4]}},
            "skip": "strings are not exported"}
    text = export.to_prometheus(snap, prefix="t")
    lines = text.splitlines()
    # monotone transaction counts expose as counters with HELP text;
    # everything else stays a gauge
    assert "# TYPE t_engines_engine0_engine_docs counter" in lines
    assert "# HELP t_engines_engine0_engine_docs " \
        "documents ingested (padding excluded)" in lines
    assert "# TYPE t_engines_engine0_engine_rate gauge" in lines
    assert "t_engines_engine0_engine_docs 12" in lines
    assert 't_engines_engine0_tiers{idx="0"} 3' in lines
    assert not any("skip" in ln for ln in lines)
    # HELP precedes TYPE for every annotated metric, and the format is
    # deterministic (a second render is byte-identical)
    for i, ln in enumerate(lines):
        if ln.startswith("# TYPE") and i > 0 and \
                lines[i - 1].startswith("# HELP"):
            assert lines[i - 1].split()[2] == ln.split()[2]
    assert export.to_prometheus(snap, prefix="t") == text


def test_timers_disciplines():
    import jax.numpy as jnp
    us = timers.time_jax(lambda x: x + 1, jnp.zeros(8), reps=3)
    assert us > 0.0
    sec = timers.time_best(lambda: sum(range(100)), repeats=2)
    assert sec >= 0.0
    with timers.span("s") as sp:
        pass
    assert sp.dur_s >= 0.0
