"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.entropy_scores import ops as ent_ops
from repro.kernels.entropy_scores import ref as ent_ref
from repro.kernels.topk_filter import ops as tf_ops
from repro.kernels.topk_filter import ref as tf_ref
from repro.core import topk as topk_mod


# ---------------------------------------------------------------------------
# entropy_scores
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,v", [(1, 128), (3, 300), (8, 2048), (5, 5000),
                                 (16, 32000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_entropy_nll_matches_ref(b, v, dtype):
    rng = np.random.default_rng(b * 1000 + v)
    logits = jnp.asarray(rng.standard_normal((b, v)) * 3, dtype)
    labels = jnp.asarray(rng.integers(0, v, size=b), jnp.int32)
    ent_k, nll_k = ent_ops.entropy_nll(logits, labels, block_b=4, block_v=512)
    ent_r, nll_r = ent_ref.entropy_nll(logits, labels)
    np.testing.assert_allclose(np.asarray(ent_k), np.asarray(ent_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(nll_k), np.asarray(nll_r),
                               rtol=2e-5, atol=2e-5)


def test_entropy_extremes():
    # peaked distribution → entropy ≈ 0; uniform → ln V
    v = 1024
    peaked = jnp.zeros((1, v)).at[0, 3].set(100.0)
    uniform = jnp.zeros((2, v))
    ent_p, nll_p = ent_ops.entropy_nll(peaked, jnp.array([3], jnp.int32))
    ent_u, _ = ent_ops.entropy_nll(uniform, jnp.array([0, 1], jnp.int32))
    assert float(ent_p[0]) < 1e-3
    assert abs(float(nll_p[0])) < 1e-3
    np.testing.assert_allclose(np.asarray(ent_u), np.log(v), rtol=1e-5)


def test_entropy_kernel_vs_model_loss_path():
    """The scorer used in lm_loss must agree with the kernel composition."""
    rng = np.random.default_rng(0)
    b, s, v = 2, 5, 700
    logits = jnp.asarray(rng.standard_normal((b, s, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    from repro.core import interestingness as itf
    nll_k = itf.nll_score(logits, labels, use_kernel=True)
    nll_r = itf.nll_score(logits, labels, use_kernel=False)
    np.testing.assert_allclose(np.asarray(nll_k), np.asarray(nll_r),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# topk_filter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,bn", [(128, 128), (4096, 1024), (5000, 512),
                                  (100_000, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_filter_matches_ref(n, bn, dtype):
    rng = np.random.default_rng(n)
    scores = jnp.asarray(rng.standard_normal(n), dtype)
    thr = jnp.float32(0.5)
    mask_k, counts_k, tmax_k = tf_ops.topk_filter(scores, thr, block_n=bn)
    pad = (-n) % min(bn, n)
    sp = jnp.pad(scores.astype(jnp.float32), ((0, pad),),
                 constant_values=tf_ops.NEG_BIG)
    mask_r, counts_r, tmax_r = tf_ref.topk_filter(sp, thr, min(bn, n))
    np.testing.assert_array_equal(np.asarray(mask_k), np.asarray(mask_r[:n]))
    np.testing.assert_array_equal(np.asarray(counts_k), np.asarray(counts_r))
    np.testing.assert_allclose(np.asarray(tmax_k), np.asarray(tmax_r))


def test_filter_then_merge_equals_plain_update():
    rng = np.random.default_rng(7)
    k = 32
    state_a = topk_mod.init(k)
    state_b = topk_mod.init(k)
    for step in range(5):
        scores = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        ids = jnp.arange(step * 1000, (step + 1) * 1000, dtype=jnp.int32)
        state_a, _ = topk_mod.update(state_a, scores, ids)
        state_b = tf_ops.filter_then_merge(state_b, scores, ids, block_n=256)
        if isinstance(state_b, tuple) and not hasattr(state_b, "scores"):
            state_b = state_b[0]
    np.testing.assert_array_equal(np.sort(np.asarray(state_a.ids)),
                                  np.sort(np.asarray(state_b.ids)))


def test_topk_filter_all_below_threshold():
    scores = jnp.full((512,), -5.0, jnp.float32)
    mask, counts, tmax = tf_ops.topk_filter(scores, jnp.float32(0.0),
                                            block_n=128)
    assert int(jnp.sum(mask)) == 0
    assert int(jnp.sum(counts)) == 0
