"""int8 error-feedback gradient compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5 exports it at top level
    from jax import shard_map
except (ImportError, AttributeError):
    from jax.experimental.shard_map import shard_map

from repro.parallel import collectives as coll


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 0.01, jnp.float32)
    q, scale = coll.quantize_int8(x)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-12


def test_error_feedback_is_unbiased_over_time():
    """Σ of dequantized outputs + final residual == Σ of raw inputs
    (telescoping property of error feedback)."""
    rng = np.random.default_rng(1)
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P
    # single device: psum is identity — isolates the EF algebra
    f = jax.jit(shard_map(
        lambda a, b: coll.compressed_psum(a, "pod", b), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P())))

    err = jnp.zeros((64,), jnp.float32)
    total_in = np.zeros(64)
    total_out = np.zeros(64)
    for t in range(50):
        x = jnp.asarray(rng.standard_normal(64) * (0.1 + t * 0.01), jnp.float32)
        out, err = f(x, err)
        total_in += np.asarray(x)
        total_out += np.asarray(out)
    residual = np.asarray(err)
    np.testing.assert_allclose(total_out + residual, total_in,
                               rtol=1e-4, atol=1e-4)


def test_compression_reduces_payload_bytes():
    x = jnp.zeros((1024,), jnp.float32)
    q, _ = coll.quantize_int8(x)
    assert q.dtype == jnp.int8
    assert q.nbytes * 4 == x.nbytes
