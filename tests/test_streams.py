"""repro.streams — batched fleet engine vs M independent single-stream
replays, the 2-D batched_topk kernel vs its oracle, the vectorized planner
vs per-stream plan_placement, plus reservoir regression/algebra coverage
that must run without hypothesis installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs, placement, shp, simulator, topk
from repro.kernels.batched_topk import ops as btk_ops
from repro.kernels.batched_topk import ref as btk_ref
from repro.streams import StreamEngine, StreamSpec, engine, planner, router


# ---------------------------------------------------------------------------
# core.topk regressions (satellites: wrote-mask collision, merge algebra)
# ---------------------------------------------------------------------------

def test_update_id_collision_with_resident_does_not_report_write():
    state = topk.init(3)
    state, wrote = topk.update(state, jnp.array([5.0, 4.0, 3.0]),
                               jnp.array([0, 1, 2], jnp.int32))
    assert list(np.asarray(wrote)) == [True, True, True]
    # id 1 is resident; a colliding batch id must not report a write even
    # though id 1 remains in the reservoir (the old isin-based mask did)
    state2, wrote2 = topk.update(state, jnp.array([1.0, 10.0]),
                                 jnp.array([1, 7], jnp.int32))
    assert list(np.asarray(wrote2)) == [False, True]
    ids = sorted(np.asarray(state2.ids).tolist())
    assert ids == [0, 1, 7]  # no duplicate id 1


def test_update_id_collision_never_duplicates_slot():
    state = topk.init(4)
    state, _ = topk.update(state, jnp.array([2.0, 1.0]),
                           jnp.array([10, 11], jnp.int32))
    # re-observe id 10 with a huge score while the reservoir is unfull:
    # first observation wins, no duplicate, no write
    state, wrote = topk.update(state, jnp.array([99.0]),
                               jnp.array([10], jnp.int32))
    assert not bool(wrote[0])
    ids = np.asarray(state.ids)
    assert np.sum(ids == 10) == 1
    assert float(state.scores[ids.tolist().index(10)]) == 2.0


def _random_state(rng, k, lo, hi):
    n = hi - lo
    state = topk.init(k)
    state, _ = topk.update(
        state, jnp.asarray(rng.standard_normal(n), jnp.float32),
        jnp.arange(lo, hi, dtype=jnp.int32))
    return state


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_merge_commutative_and_associative(seed):
    rng = np.random.default_rng(seed)
    k = 8
    a = _random_state(rng, k, 0, 40)
    b = _random_state(rng, k, 40, 60)
    c = _random_state(rng, k, 60, 110)
    ab = topk.merge(a, b)
    ba = topk.merge(b, a)
    np.testing.assert_array_equal(np.asarray(ab.ids), np.asarray(ba.ids))
    np.testing.assert_array_equal(np.asarray(ab.scores), np.asarray(ba.scores))
    left = topk.merge(topk.merge(a, b), c)
    right = topk.merge(a, topk.merge(b, c))
    np.testing.assert_array_equal(np.asarray(left.ids), np.asarray(right.ids))
    np.testing.assert_array_equal(np.asarray(left.scores),
                                  np.asarray(right.scores))
    assert int(left.seen) == int(right.seen) == 110


# ---------------------------------------------------------------------------
# batched_topk kernel vs oracle (interpret mode off-TPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,bn", [(1, 128, 128), (3, 500, 128),
                                    (8, 1024, 512), (16, 4096, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_topk_matches_ref(m, n, bn, dtype):
    rng = np.random.default_rng(m * 10_000 + n)
    scores = jnp.asarray(rng.standard_normal((m, n)), dtype)
    thr = jnp.asarray(rng.uniform(-1, 1, m), jnp.float32)
    thr = thr.at[0].set(-jnp.inf)  # unfull-reservoir bar
    mask_k, counts_k, tmax_k = btk_ops.batched_topk_filter(
        scores, thr, block_n=bn)
    bn_eff = min(bn, max(n, 128))
    pad = (-n) % bn_eff
    sp = jnp.pad(scores.astype(jnp.float32), ((0, 0), (0, pad)),
                 constant_values=btk_ops.NEG_BIG)
    mask_r, counts_r, tmax_r = btk_ref.batched_topk_filter(sp, thr, bn_eff)
    np.testing.assert_array_equal(np.asarray(mask_k),
                                  np.asarray(mask_r[:, :n]))
    np.testing.assert_array_equal(np.asarray(counts_k), np.asarray(counts_r))
    np.testing.assert_allclose(np.asarray(tmax_k), np.asarray(tmax_r))


def test_batched_topk_per_stream_bars_differ():
    scores = jnp.tile(jnp.arange(8, dtype=jnp.float32), (3, 1))
    thr = jnp.asarray([-jnp.inf, 3.5, 100.0], jnp.float32)
    mask, counts, _ = btk_ops.batched_topk_filter(scores, thr, block_n=128)
    assert int(mask[0].sum()) == 8
    assert int(mask[1].sum()) == 4
    assert int(mask[2].sum()) == 0


# ---------------------------------------------------------------------------
# batched engine
# ---------------------------------------------------------------------------

def test_batched_update_equals_independent_single_streams():
    rng = np.random.default_rng(3)
    m, k, w = 8, 8, 16
    bstate = engine.init(m, k)
    singles = [topk.init(k) for _ in range(m)]
    for step in range(5):
        sc = rng.standard_normal((m, w)).astype(np.float32)
        ids = np.tile(np.arange(step * w, (step + 1) * w, dtype=np.int32),
                      (m, 1))
        bstate, bwrote = engine.update(bstate, jnp.asarray(sc),
                                       jnp.asarray(ids))
        for i in range(m):
            singles[i], swrote = topk.update(singles[i],
                                             jnp.asarray(sc[i]),
                                             jnp.asarray(ids[i]))
            np.testing.assert_array_equal(np.asarray(bwrote[i]),
                                          np.asarray(swrote))
            np.testing.assert_array_equal(np.asarray(bstate.ids[i]),
                                          np.asarray(singles[i].ids))
            np.testing.assert_array_equal(np.asarray(bstate.scores[i]),
                                          np.asarray(singles[i].scores))


@pytest.mark.parametrize("use_pallas", [True, False])
def test_filtered_update_drops_resident_reobservation(use_pallas):
    """A re-observed resident id above the bar must not occupy a survivor
    slot that a fresh candidate (admitted by plain update) should get."""
    st_plain = engine.init(1, 4)
    st_filt = engine.init(1, 4)
    sc0 = jnp.array([[4.0, 3.0, 2.0, 1.0]], jnp.float32)
    ids0 = jnp.array([[0, 1, 2, 3]], jnp.int32)
    st_plain, _ = engine.update(st_plain, sc0, ids0)
    st_filt, _ = engine.filtered_update(st_filt, sc0, ids0, block_n=128,
                                        use_pallas=use_pallas)
    sc1 = jnp.array([[100.0, 9.0, 8.0, 7.0, 6.0]], jnp.float32)
    ids1 = jnp.array([[0, 10, 11, 12, 13]], jnp.int32)  # id 0 is resident
    st_plain, w_plain = engine.update(st_plain, sc1, ids1)
    st_filt, w_filt = engine.filtered_update(st_filt, sc1, ids1, block_n=128,
                                             use_pallas=use_pallas)
    np.testing.assert_array_equal(np.sort(np.asarray(st_plain.ids), 1),
                                  np.sort(np.asarray(st_filt.ids), 1))
    np.testing.assert_array_equal(np.asarray(w_plain), np.asarray(w_filt))


@pytest.mark.parametrize("use_pallas", [True, False])
def test_filtered_update_equals_plain_update(use_pallas):
    rng = np.random.default_rng(4)
    m, k, w = 6, 16, 256
    st_plain = engine.init(m, k)
    st_filt = engine.init(m, k)
    for step in range(3):
        sc = jnp.asarray(rng.standard_normal((m, w)), jnp.float32)
        ids = jnp.tile(jnp.arange(step * w, (step + 1) * w, dtype=jnp.int32),
                       (m, 1))
        st_plain, w_plain = engine.update(st_plain, sc, ids)
        st_filt, w_filt = engine.filtered_update(st_filt, sc, ids,
                                                 block_n=128,
                                                 use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(w_plain),
                                      np.asarray(w_filt))
        np.testing.assert_array_equal(np.sort(np.asarray(st_plain.ids), 1),
                                      np.sort(np.asarray(st_filt.ids), 1))


def test_engine_bit_matches_simulator_replays():
    """The acceptance property at test scale: heterogeneous fleet through
    shuffled mixed batches == M independent core.simulator replays."""
    rng = np.random.default_rng(5)
    m, docs, batch = 48, 96, 8
    ks = [2, 4, 8] * (m // 3)
    specs = [StreamSpec(stream_id=1000 + i, k=ks[i], r=float(docs / 3))
             for i in range(m)]
    eng = StreamEngine(specs)
    traces = np.stack([simulator.random_rank_trace(docs, rng)
                       for _ in range(m)]).astype(np.float32)
    sids = np.array([s.stream_id for s in specs])
    for t in range(0, docs, batch):
        mixed_sids = np.repeat(sids, batch)
        mixed_dids = np.tile(np.arange(t, t + batch), m)
        mixed_scores = traces[:, t:t + batch].reshape(-1)
        perm = rng.permutation(mixed_sids.size)
        eng.ingest(mixed_sids[perm], mixed_scores[perm], mixed_dids[perm])
    survivors = eng.finalize()
    for i, spec in enumerate(specs):
        sim = simulator.simulate(traces[i].astype(np.float64), spec.k,
                                 placement.Policy(r=float(docs / 3)))
        np.testing.assert_array_equal(survivors[spec.stream_id],
                                      sim.survivor_ids)


def test_engine_kernel_filter_matches_plain_on_tied_scores():
    """Quantized scores produce ties; shuffled ingest through the
    kernel-filtered engine must still match the exact path (the router
    id-orders each row so lax.top_k's positional tie-break equals the
    merge's lowest-id tie-break)."""
    rng = np.random.default_rng(11)
    m, k, docs, batch = 3, 3, 24, 4
    specs_a = [StreamSpec(stream_id=i, k=k, r=float(docs)) for i in range(m)]
    specs_b = [StreamSpec(stream_id=i, k=k, r=float(docs)) for i in range(m)]
    plain = StreamEngine(specs_a)
    kern = StreamEngine(specs_b, use_kernel_filter=True)
    traces = rng.integers(0, 4, (m, docs)).astype(np.float32)  # heavy ties
    for t in range(0, docs, batch):
        sids = np.repeat(np.arange(m), batch)
        dids = np.tile(np.arange(t, t + batch), m)
        sc = traces[:, t:t + batch].reshape(-1)
        perm = rng.permutation(sids.size)
        plain.ingest(sids[perm], sc[perm], dids[perm])
        kern.ingest(sids[perm], sc[perm], dids[perm])
    sp, sk = plain.survivors(), kern.survivors()
    for i in range(m):
        np.testing.assert_array_equal(sp[i], sk[i])


def test_engine_batch1_write_counts_match_simulator():
    """With W=1 the batched engine's write mask is the paper's per-doc
    eq. 9/10 event — totals must equal the exact simulator replay."""
    rng = np.random.default_rng(6)
    m, docs = 12, 64
    specs = [StreamSpec(stream_id=i, k=4, r=float(docs)) for i in range(m)]
    eng = StreamEngine(specs)
    traces = np.stack([simulator.random_rank_trace(docs, rng)
                       for _ in range(m)]).astype(np.float32)
    for t in range(docs):
        eng.ingest(np.arange(m), traces[:, t], np.full(m, t))
    for i in range(m):
        sim = simulator.simulate(traces[i].astype(np.float64), 4,
                                 placement.all_tier_a(docs))
        row = eng.stream_row(i)
        assert eng.meter.writes[row].sum() == sim.cum_writes[-1]
        assert eng.meter.deletes[row].sum() == sim.evictions


def test_engine_metering_tiers_and_reads():
    docs = 8
    specs = [StreamSpec(stream_id=0, k=2, r=4.0)]
    eng = StreamEngine(specs)
    # per-doc ingest of ascending scores: every doc writes, each (after the
    # first two) evicting the then-weakest member
    for t in range(docs):
        eng.ingest([0], [float(t)], [t])
    eng.finalize()
    led = eng.meter.ledger(0)
    # docs 0..3 land in tier A (index < r=4), 4..7 in tier B
    assert led.writes.tolist() == [4, 4]
    # evicted docs are 0..5: four lived in tier A, two in tier B
    assert led.deletes.tolist() == [4, 2]
    # survivors are docs 6, 7 — both tier B
    assert led.reads.tolist() == [0, 2]
    assert led.writes.sum() - led.deletes.sum() == 2


def test_engine_migrating_stream_matches_simulator_accounting():
    """A stream planned with Algorithm C + migration: per-doc replay must
    agree with core.simulator on writes per tier, migrated count, and the
    final read coming entirely from tier B."""
    rng = np.random.default_rng(9)
    docs, k, r = 64, 4, 24.0
    trace = simulator.random_rank_trace(docs, rng).astype(np.float32)
    eng = StreamEngine([StreamSpec(stream_id=0, k=k, r=r, migrate=True)])
    for t in range(docs):
        eng.ingest([0], [trace[t]], [t])
    eng.finalize()
    sim = simulator.simulate(trace.astype(np.float64), k,
                             placement.Policy(r=r, migrate_at_r=True))
    led = eng.meter.ledger(0)
    assert led.writes.tolist() == sim.writes_per_tier.tolist()
    assert led.migrations == sim.migrated
    assert led.reads.tolist() == sim.reads_per_tier.tolist()
    assert led.reads.tolist()[0] == 0  # everything reads from B post-mig


def test_engine_single_batch_uses_batch_boundary_write_law():
    # the whole window in ONE batch ⇒ only the final top-K ever write
    # (shp.expected_cum_writes_batched with batch = N), and they write at
    # the placement of their own doc index
    eng = StreamEngine([StreamSpec(stream_id=0, k=2, r=4.0)])
    eng.ingest(np.zeros(8, np.int64), np.arange(8, dtype=np.float32),
               np.arange(8))
    led = eng.meter.ledger(0)
    assert led.writes.tolist() == [0, 2]  # docs 6, 7 → tier B
    assert led.deletes.tolist() == [0, 0]


def test_engine_rejects_bad_specs():
    with pytest.raises(ValueError):
        StreamEngine([])
    with pytest.raises(ValueError):
        StreamEngine([StreamSpec(stream_id=0, k=2), ])  # no r, no cost model
    with pytest.raises(ValueError):
        StreamEngine([StreamSpec(stream_id=0, k=2, r=1.0),
                      StreamSpec(stream_id=0, k=4, r=1.0)])


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

def test_router_roundtrip_preserves_per_stream_order():
    rng = np.random.default_rng(7)
    buckets = router.bucket_streams({10: 2, 11: 4, 12: 2, 13: 4})
    assert [b.k for b in buckets] == [2, 4]
    rt = router.StreamRouter(buckets)
    sids = np.repeat([10, 11, 12, 13], 5)
    dids = np.tile(np.arange(5), 4)
    scores = rng.standard_normal(20).astype(np.float32)
    # rows come out in doc-id (stream) order, shuffled ingest or not
    perm = rng.permutation(20)
    for order in (np.arange(20), perm):
        routed = rt.route(sids[order], scores[order], dids[order])
        for bi, bucket in enumerate(buckets):
            dense_s, dense_i = routed[bi]
            assert dense_s.shape == (2, 8)  # 5 docs → pow2 pad to 8
            for row, sid in enumerate(bucket.stream_ids):
                sel = sids == sid
                np.testing.assert_array_equal(dense_i[row, :5], dids[sel])
                np.testing.assert_array_equal(dense_s[row, :5], scores[sel])
                assert np.all(dense_i[row, 5:] == router.PAD_ID)
                assert np.all(np.isneginf(dense_s[row, 5:]))


def test_router_rejects_unknown_stream():
    rt = router.StreamRouter(router.bucket_streams({1: 2}))
    with pytest.raises(KeyError):
        rt.route([1, 99], [0.0, 0.0], [0, 0])


def test_router_rejects_within_batch_duplicate_doc():
    # same (stream, doc) twice in one batch would occupy two reservoir
    # slots and double-count writes — must be rejected, not corrupted
    eng = StreamEngine([StreamSpec(stream_id=0, k=4, r=8.0)])
    with pytest.raises(ValueError, match="duplicate"):
        eng.ingest([0, 0, 0], [1.0, 1.0, 0.5], [5, 5, 6])
    # same doc id on different streams is fine
    rt = router.StreamRouter(router.bucket_streams({1: 2, 2: 2}))
    rt.route([1, 2], [0.0, 0.0], [5, 5])


def test_reconcile_ignores_idle_streams():
    eng = StreamEngine([StreamSpec(stream_id=0, k=2, r=8.0),
                        StreamSpec(stream_id=1, k=2, r=8.0)])
    eng.ingest([0, 0, 0], [3.0, 1.0, 2.0], [0, 1, 2])  # stream 1 idle
    rec = eng.meter.reconcile()
    assert rec["expected"][eng.stream_row(1)] == 0.0
    assert rec["rel_err"][eng.stream_row(1)] == 0.0


# ---------------------------------------------------------------------------
# planner vs per-stream shp.plan_placement (satellite coverage)
# ---------------------------------------------------------------------------

def _random_models(rng, count):
    models = []
    for _ in range(count):
        n = int(rng.integers(1_000, 1_000_000))
        k = int(rng.integers(1, max(2, n // 10)))
        tier_a = costs.TierCosts("a", *(float(x) for x in
                                        rng.uniform(1e-8, 1e-3, 3)))
        tier_b = costs.TierCosts("b", *(float(x) for x in
                                        rng.uniform(1e-8, 1e-3, 3)))
        wl = costs.WorkloadSpec(n_docs=n, k=k,
                                doc_gb=float(rng.uniform(0.1, 2.0)),
                                window_months=float(rng.uniform(0.1, 3.0)))
        models.append(costs.TwoTierCostModel(tier_a=tier_a, tier_b=tier_b,
                                             workload=wl))
    return models


def test_plan_fleet_agrees_with_per_stream_plan_placement():
    rng = np.random.default_rng(8)
    models = _random_models(rng, 200)
    plan = planner.plan_fleet(models)
    saw = set()
    for i, cm in enumerate(models):
        ref = shp.plan_placement(cm)
        assert ref.strategy == plan.strategy(i), i
        np.testing.assert_allclose(plan.best_total[i], ref.best.total,
                                   rtol=1e-9)
        np.testing.assert_allclose(plan.r[i], ref.r, rtol=1e-9, atol=1e-12)
        saw.add(ref.strategy)
    assert len(saw) >= 2  # the sweep actually exercises several strategies


def test_plan_fleet_case_studies_match_scalar_planner():
    models = [costs.case_study_1(), costs.case_study_2()]
    plan = planner.plan_fleet(models)
    for i, cm in enumerate(models):
        ref = shp.plan_placement(cm)
        assert plan.strategy(i) == ref.strategy
        np.testing.assert_allclose(plan.best_total[i], ref.best.total,
                                   rtol=1e-12)
        pol = plan.policy(i)
        ref_pol = placement.from_plan(ref)
        assert pol.migrate_at_r == ref_pol.migrate_at_r
        np.testing.assert_allclose(pol.r, ref_pol.r, rtol=1e-9)


def test_plan_fleet_validity_gate_matches_scalar():
    # cw_a > cw_b flips the second-order condition: two-tier must be gated
    tier_a = costs.TierCosts("a", 1e-3, 1e-5, 0.0)
    tier_b = costs.TierCosts("b", 1e-6, 1e-3, 0.0)
    wl = costs.WorkloadSpec(n_docs=10_000, k=10, doc_gb=1.0,
                            window_months=1.0)
    cm = costs.TwoTierCostModel(tier_a=tier_a, tier_b=tier_b, workload=wl)
    plan = planner.plan_fleet([cm])
    assert np.isinf(plan.totals[0, 2]) and np.isinf(plan.totals[0, 3])
    assert plan.strategy(0) == shp.plan_placement(cm).strategy
