"""Serving correctness: prefill + step-by-step decode must reproduce the
full-sequence forward logits, for every mixer family (GQA, sliding-window,
MLA-absorbed, SSD, hybrid, cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.synthetic import make_batch
from repro.models import lm

SHAPE = ShapeConfig("decode_smoke", seq_len=20, global_batch=2, kind="train")

ARCHS = ["llama3.2-1b", "starcoder2-3b", "deepseek-v2-236b", "mamba2-2.7b",
         "hymba-1.5b", "whisper-base", "pixtral-12b", "grok-1-314b"]


def _setup(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, SHAPE, seed=3, step=0))
    return cfg, params, batch


def _cache_len(cfg, total):
    w = cfg.max_window
    return min(w, total) if w > 0 else total


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg, params, batch = _setup(arch)
    tokens = batch["tokens"]
    b, s = tokens.shape
    full_logits, _ = jax.jit(lambda p, bt: lm.forward(p, cfg, bt))(params, batch)

    t0 = s // 2
    kv_len = _cache_len(cfg, s + 1)
    enc_len = batch["frames"].shape[1] if cfg.is_encoder_decoder else 0
    cache = lm.init_cache(cfg, b, kv_len, enc_len=enc_len)
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :t0]
    logits_p, cache = jax.jit(
        lambda p, bt, c: lm.prefill(p, cfg, bt, c))(params, pre_batch, cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, t0 - 1]),
                               rtol=2e-3, atol=2e-4)

    step = jax.jit(lambda p, tok, c: lm.decode_step(p, cfg, tok, c))
    for t in range(t0, s):
        logits_d, cache = step(params, tokens[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=3e-4,
            err_msg=f"{arch}: mismatch at decode position {t}")


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b", "hymba-1.5b"])
def test_causality(arch):
    """Perturbing a future token must not change past logits."""
    cfg, params, batch = _setup(arch)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    cut = s // 2
    logits_a, _ = lm.forward(params, cfg, batch)
    batch2 = dict(batch)
    batch2["tokens"] = tokens.at[:, cut + 1:].set(
        (tokens[:, cut + 1:] + 17) % cfg.vocab_size)
    logits_b, _ = lm.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(logits_a[:, : cut + 1]),
                               np.asarray(logits_b[:, : cut + 1]),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_limits_context():
    """With window W, logits at position t must ignore tokens ≤ t−W."""
    cfg = configs.get_config("starcoder2-3b", reduced=True)  # window = 8
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, SHAPE, seed=5, step=0))
    tokens = batch["tokens"]
    logits_a, _ = lm.forward(params, cfg, batch)
    # change token 0; positions ≥ 8+depth*... must be unaffected at layer-1
    # receptive field = n_layers * window; with 2 layers × window 8 ⇒ pos ≥ 16
    batch2 = dict(batch)
    batch2["tokens"] = tokens.at[:, 0].set((tokens[:, 0] + 3) % cfg.vocab_size)
    logits_b, _ = lm.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(logits_a[:, 17:]),
                               np.asarray(logits_b[:, 17:]), rtol=1e-5, atol=1e-5)
    # ...but nearby positions DO see it
    assert not np.allclose(np.asarray(logits_a[:, 1]), np.asarray(logits_b[:, 1]))
