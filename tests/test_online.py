"""repro.online — drift detection (null false-positive property +
power), constrained suffix re-planning, admission negotiation, and the
closed-loop engine acceptance scenario (re-planned fleet beats the static
plan and lands within 10% of the drift-aware oracle on a drifted trace;
leaves the plan bit-identical on an undrifted one)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constraints as cons, costs, shp, simulator, topology
from repro.core.placement import Policy
from repro.online import (AdmissionController, DriftConfig, ReplanConfig,
                          drift, evaluate)
from repro.online.replan import Replanner, relocation_bill, suffix_cost
from repro.streams import engine as seng
from repro.streams.engine import StreamSpec


# ---------------------------------------------------------------------------
# scenario helpers
# ---------------------------------------------------------------------------

def _two_tier_model(n=12000, k=64):
    """Interior no-migration crossover (r*/N ~ 0.29): hot tier write-cheap
    / read-expensive, cold tier the reverse — the paper's Algorithm C
    shape, where a write-rate burst moves r* outward."""
    wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-4, window_months=0.5)
    hot = costs.TierCosts("hot", put_per_doc=1e-6, get_per_doc=2.7e-4,
                          storage_per_gb_month=0.05)
    cold = costs.TierCosts("cold", put_per_doc=8e-5, get_per_doc=1e-6,
                           storage_per_gb_month=0.02)
    return costs.TwoTierCostModel(tier_a=hot, tier_b=cold, workload=wl)


def _null_fpr(seed: int, alpha: float, m: int = 128) -> float:
    """Fraction of i.u.d. (null) streams the detector flags across a full
    window — the exact joint entry process, via the batched engine
    update."""
    rng = np.random.default_rng(seed)
    n, k, w = 4096, 16, 64
    est = drift.DriftEstimator(m, k=k, cfg=DriftConfig(alpha=alpha))
    state = seng.init(m, k)
    traces = rng.standard_normal((m, n)).astype(np.float32)
    for c0 in range(0, n, w):
        sc = jnp.asarray(traces[:, c0:c0 + w])
        ids = jnp.tile(jnp.arange(c0, c0 + w, dtype=jnp.int32), (m, 1))
        state, wrote = seng.update(state, sc, ids)
        est.observe(np.asarray(wrote).sum(1), np.asarray(state.seen))
    return float(np.asarray(est.state.fired).mean())


# ---------------------------------------------------------------------------
# drift detector: chunk law, null FPR, power
# ---------------------------------------------------------------------------

def test_chunk_law_matches_brute_force():
    rng = np.random.default_rng(0)
    k, a, b = 8, 100, 164
    mean, var = drift.chunk_law(np.array([a]), np.array([b]),
                                np.array([k], np.float32))
    # brute force: top-K of b exchangeable docs, count in last b-a slots
    counts = []
    for _ in range(4000):
        top = rng.choice(b, size=k, replace=False)
        counts.append(int(np.sum(top >= a)))
    counts = np.asarray(counts)
    assert abs(float(mean[0]) - counts.mean()) < 0.1
    assert abs(float(var[0]) - counts.var()) < 0.15


def test_chunk_law_unfull_reservoir_writes_everything():
    mean, var = drift.chunk_law(np.array([0.0]), np.array([12.0]),
                                np.array([16.0]))
    assert float(mean[0]) == 12.0 and float(var[0]) == 0.0


@pytest.mark.parametrize("seed", [0, 1])
def test_null_false_positive_rate_below_alpha(seed):
    """Satellite: under the null i.u.d. model the detection probability
    stays below the configured alpha (the Bernstein/Bonferroni budget is
    deliberately conservative — empirically it is far below)."""
    alpha = 0.05
    assert _null_fpr(seed, alpha) <= alpha


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    @settings(max_examples=5, deadline=None)
    def test_null_fpr_property(seed):
        """Satellite (hypothesis form): over random seeds, P(detect) under
        the null never exceeds the configured alpha."""
        assert _null_fpr(seed, 0.05, m=64) <= 0.05
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(requirements-dev)")
    def test_null_fpr_property():
        pass


def test_detects_injected_drift_and_estimates_rate():
    """A 6x mid-window record-rate burst must fire, with the anchored
    rho-hat in the right ballpark and the anchor near the onset."""
    rng = np.random.default_rng(3)
    m, n, k, w = 16, 8000, 64, 64
    drift_at = 3000
    est = drift.DriftEstimator(m, k=k, cfg=DriftConfig(alpha=0.05))
    state = seng.init(m, k)
    traces = np.stack([simulator.drifted_rank_trace(n, rng,
                                                    [(drift_at, 6.0)])
                       for _ in range(m)]).astype(np.float32)
    fired_at = np.full(m, -1)
    rho_at_fire = np.full(m, np.nan)
    anchor_at_fire = np.full(m, np.nan)
    for c0 in range(0, n, w):
        sc = jnp.asarray(traces[:, c0:c0 + w])
        ids = jnp.tile(jnp.arange(c0, c0 + w, dtype=jnp.int32), (m, 1))
        state, wrote = seng.update(state, sc, ids)
        fired = est.observe(np.asarray(wrote).sum(1), np.asarray(state.seen))
        fresh = (fired_at < 0) & fired
        rho_at_fire = np.where(fresh, est.rho_hat(), rho_at_fire)
        anchor_at_fire = np.where(
            fresh, np.asarray(drift.anchor_seen(est.state)), anchor_at_fire)
        fired_at = np.where(fresh, c0 + w, fired_at)
    assert (fired_at > 0).mean() >= 0.9  # nearly every stream detects
    detected = fired_at[fired_at > 0]
    assert (detected > drift_at).all()  # no pre-onset detection here
    assert np.median(detected) < drift_at + 1500  # and promptly
    # at detection time the anchored estimate sees the burst magnitude
    rho = rho_at_fire[fired_at > 0]
    assert (rho > 2.0).mean() > 0.8  # direction + rough magnitude
    # the excursion anchor is a (possibly early) lower bound of the onset
    anchors = anchor_at_fire[fired_at > 0]
    assert np.all(anchors <= fired_at[fired_at > 0])


def test_reset_where_clears_only_masked_rows():
    est = drift.DriftEstimator(3, k=8)
    est.observe(np.array([8, 8, 8]), np.array([64, 64, 64]))
    est.observe(np.array([8, 0, 3]), np.array([128, 128, 128]))
    before = np.asarray(est.state.dev).copy()
    est.reset(np.array([True, False, False]))
    after = np.asarray(est.state.dev)
    assert after[0] == 0.0
    np.testing.assert_array_equal(after[1:], before[1:])


# ---------------------------------------------------------------------------
# replanner: suffix solve, relocation bill, constraints
# ---------------------------------------------------------------------------

def test_replan_null_rate_keeps_boundaries_bit_identical():
    cm = _two_tier_model()
    plan = shp.plan_placement(cm)
    rp = Replanner([cm.as_ntier()])
    dec = rp.replan([0], [6000.0], [1.0], [(plan.r,)], [False])
    assert not dec.applied[0]
    assert dec.new_bounds[0] == (plan.r,)


def test_replan_pushes_boundary_out_under_write_burst():
    cm = _two_tier_model()
    plan = shp.plan_placement(cm)
    rp = Replanner([cm.as_ntier()])
    dec = rp.replan([0], [3400.0], [6.0], [(plan.r,)], [False])
    assert dec.applied[0]
    assert dec.new_bounds[0][0] > plan.r
    assert dec.suffix_cost_new[0] < dec.suffix_cost_old[0]


def test_replan_skips_migrating_streams():
    cm = _two_tier_model()
    rp = Replanner([cm.as_ntier()])
    dec = rp.replan([0], [3400.0], [6.0], [(2000.0,)], [True])
    assert not dec.applied[0]
    assert dec.new_bounds[0] == (2000.0,)


def test_relocation_bill_prices_promotions_per_hop():
    cm = _two_tier_model().as_ntier()
    cwr = cm.cw[None, :]
    crr = cm.cr[None, :]
    n0, k = 4000.0, 64.0
    # push the single boundary from 2000 to 5000: residents in
    # [2000, 4000) promote from tier 1 to tier 0 at cr_1 + cw_0
    bill, moves = relocation_bill(np.array([[2000.0]]), np.array([[5000.0]]),
                                  np.array([n0]), np.array([k]), crr, cwr)
    dens = k / n0
    expect_moves = dens * 2000.0
    assert np.isclose(moves[0], expect_moves)
    assert np.isclose(bill[0], expect_moves * (cm.cr[1] + cm.cw[0]))


def test_replan_allow_moves_false_freezes_crossed_boundaries():
    cm = _two_tier_model()
    rp = Replanner([cm.as_ntier()],
                   config=ReplanConfig(allow_moves=False))
    # boundary already crossed (2000 < n0=4000): without moves the only
    # legal deltas keep it fixed, so any new plan must preserve it
    dec = rp.replan([0], [4000.0], [6.0], [(2000.0,)], [False])
    assert dec.new_bounds[0] == (2000.0,)


def test_replan_suffix_cost_monotone_sanity():
    """The solver's chosen bounds must beat (or tie) both endpoints of
    the sweep under its own suffix-cost law."""
    cm = _two_tier_model().as_ntier()
    rp = Replanner([cm])
    n0, rho = 3400.0, 6.0
    dec = rp.replan([0], [n0], [rho], [(3524.0,)], [False])
    args = (cm.cw[None, :], cm.cr[None, :], cm.cs[None, :],
            np.array([float(cm.workload.n_docs)]),
            np.array([float(cm.workload.k)]),
            np.array([cm.workload.reads_per_window]),
            np.array([n0]), np.array([rho]))
    chosen = suffix_cost(*args, np.array([list(dec.new_bounds[0])]))
    for probe in (n0, 8000.0, 12000.0):
        probed = suffix_cost(*args, np.array([[probe]]))
        assert chosen[0] <= probed[0] + 1e-12


def test_constrained_replan_respects_capacity():
    """A hot-tier capacity below the unconstrained suffix optimum must
    clamp the re-planned boundary to the feasible frontier."""
    cm = _two_tier_model().as_ntier()
    n, k = cm.workload.n_docs, cm.workload.k
    free = Replanner([cm]).replan([0], [3400.0], [6.0], [(3524.0,)],
                                  [False])
    assert free.applied[0]
    b_free = free.new_bounds[0][0]
    cap0 = 0.5 * k  # first tier holds only K/2 docs
    cset = cons.ConstraintSet(cons.TierCapacity(0, cap0))
    dec = Replanner([cm], constraints=cset).replan(
        [0], [3400.0], [6.0], [(3524.0,)], [False])
    if dec.applied[0]:
        occ = cons.peak_occupancy(dec.new_bounds[0], n, k, False)
        assert occ[0] <= cap0 * (1 + 1e-9)
        assert dec.new_bounds[0][0] <= b_free


def test_constrained_replan_reports_infeasible():
    cm = _two_tier_model().as_ntier()
    cset = cons.ConstraintSet(cons.TierCapacity(0, 1.0),
                              cons.TierCapacity(1, 1.0))
    dec = Replanner([cm], constraints=cset).replan(
        [0], [3400.0], [6.0], [(3524.0,)], [False])
    assert not dec.feasible[0]
    assert not dec.applied[0]


def test_replan_hwm_conditions_occupancy_on_observed_prefix():
    """A capacity peak the meter already witnessed cannot be un-rung:
    the suffix-conditioned occupancy (peak_occupancy_suffix) marks the
    re-solved plan infeasible, handing the tenant to admission."""
    cm = _two_tier_model().as_ntier()
    k = cm.workload.k
    cset = cons.ConstraintSet(cons.TierCapacity(0, 0.5 * k))
    rp = Replanner([cm], constraints=cset)
    dec = rp.replan([0], [3400.0], [6.0], [(3524.0,)], [False],
                    hwm=np.array([[float(k), 0.0]]))
    assert not dec.feasible[0] and not dec.applied[0]
    assert dec.suffix_occupancy[0][0] >= k
    dec2 = rp.replan([0], [3400.0], [6.0], [(3524.0,)], [False],
                     hwm=np.array([[0.0, 0.0]]))
    assert dec2.feasible[0]
    assert dec2.suffix_occupancy[0] is not None


def test_detector_keeps_testing_past_the_bonferroni_budget():
    """Beyond max_checks the per-check budget decays instead of going
    permanently blind — a late, strong drift must still fire."""
    cfg = DriftConfig(alpha=0.05, max_checks=4)
    est = drift.DriftEstimator(1, k=32, cfg=cfg)
    seen = 0.0
    for _ in range(12):  # 12 null-ish chunks, 3x the budget
        seen += 64.0
        mean, _ = drift.chunk_law(np.array([seen - 64.0]),
                                  np.array([seen]), np.array([32.0]))
        est.observe(np.asarray(mean), np.array([seen]))
    assert not est.state.fired[0]
    for _ in range(8):  # then a hard burst
        seen += 64.0
        est.observe(np.array([40.0]), np.array([seen]))
    assert bool(est.state.fired[0])


def test_engine_negotiates_admission_for_infeasible_resolves():
    """Wiring: an infeasible suffix re-solve produces an advisory
    admission event with the tenant's negotiated next-window terms."""
    cm = _two_tier_model(n=2048, k=16)
    cset = cons.ConstraintSet(cons.TierCapacity(0, 8.0),
                              cons.TierCapacity(1, 8.0))
    # planning would be infeasible under cset; build unconstrained and
    # attach the squeezed set to the replanner directly
    engine = seng.StreamEngine(
        [StreamSpec(stream_id=0, k=16, cost_model=cm)],
        replan=ReplanConfig())
    engine._replanner = Replanner([cm.as_ntier()], constraints=cset,
                                  config=ReplanConfig())
    engine._negotiate_admission(0, 100)
    assert len(engine.admission_events) == 1
    ev = engine.admission_events[0]
    assert ev.stream_id == 0 and ev.position == 100
    assert ev.decision.negotiated or not ev.decision.admitted


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def _slo_squeezed_model(k=512):
    """Archive hierarchy + tight SLO: reads must come from the hot tier,
    whose capacity is below K — infeasible as requested, feasible at a
    smaller K."""
    topo = topology.aws_archive_tiering()
    hot_cap = k // 4
    topo = topo.replace(tiers=(
        topo.tiers[0].__class__(topo.tiers[0].costs,
                                capacity_docs=hot_cap,
                                read_latency_s=topo.tiers[0].read_latency_s),
        topo.tiers[1],
    ))
    wl = costs.WorkloadSpec(n_docs=200_000, k=k, doc_gb=1e-3,
                            window_months=1.0)
    return topo.cost_model(wl), hot_cap


def test_admission_feasible_passes_through():
    cm = _two_tier_model().as_ntier()
    dec = AdmissionController(cons.ConstraintSet()).admit(cm)
    assert dec.admitted and not dec.negotiated
    assert dec.k == cm.workload.k and dec.n_docs == cm.workload.n_docs


def test_admission_negotiates_k_instead_of_rejecting():
    cm, hot_cap = _slo_squeezed_model()
    cset = cons.ConstraintSet(cons.ReadLatencySLO(60.0))
    assert shp.plan_placement_ntier(cm, constraints=cset).feasible is False
    dec = AdmissionController(cset).admit(cm)
    assert dec.admitted and dec.negotiated
    assert dec.k < cm.workload.k
    assert dec.plan.feasible
    # the negotiated terms really are feasible under the constraint set
    wl = cm.workload
    import dataclasses
    cm2 = cm.replace(workload=dataclasses.replace(wl, k=dec.k,
                                                  n_docs=dec.n_docs))
    assert shp.plan_placement_ntier(cm2, constraints=cset).feasible


def test_admission_rejects_the_hopeless():
    cm = _two_tier_model().as_ntier()
    cset = cons.ConstraintSet(cons.TierCapacity(0, 0.0),
                              cons.TierCapacity(1, 0.0))
    dec = AdmissionController(cset).admit(cm)
    assert not dec.admitted
    assert dec.plan is None


# ---------------------------------------------------------------------------
# closed loop: engine acceptance scenario
# ---------------------------------------------------------------------------

def test_undrifted_fleet_keeps_plan_bit_identical():
    """No drift => no events, boundaries bit-identical to the a-priori
    plan, and survivors still bit-match the simulator replays."""
    rng = np.random.default_rng(7)
    cm = _two_tier_model(n=2048, k=16)
    m = 4
    specs = [StreamSpec(stream_id=i, k=16, cost_model=cm) for i in range(m)]
    traces = np.stack([simulator.random_rank_trace(2048, rng)
                       for _ in range(m)])
    probe = seng.StreamEngine(specs)
    before = probe.meter.boundaries.copy()
    engine = evaluate.run_fleet(traces, specs, replan=ReplanConfig(),
                                chunk=64)
    assert engine.replan_events == []
    np.testing.assert_array_equal(engine.meter.boundaries, before)
    assert int(engine.meter.relocations.sum()) == 0


def test_drifted_fleet_beats_static_and_tracks_oracle():
    """The headline acceptance criterion: on an 8x mid-window record-rate
    burst the closed loop must beat the static a-priori plan, land within
    10% of the hindsight drift-aware oracle, and reconcile with zero
    constraint violations."""
    rng = np.random.default_rng(5)
    n, k, m = 12000, 64, 6
    drift_at = 3000
    cm = _two_tier_model(n=n, k=k)
    cset = cons.ConstraintSet(cons.TierCapacity(0, 4 * k))  # generous
    traces = np.stack([simulator.drifted_rank_trace(n, rng,
                                                    [(drift_at, 8.0)])
                       for _ in range(m)])
    specs = [StreamSpec(stream_id=i, k=k, cost_model=cm) for i in range(m)]
    ev = evaluate.evaluate_fleet(
        traces, specs, replan=ReplanConfig(drift=DriftConfig(alpha=0.05)),
        drift_at=drift_at, chunk=64, constraints=cset, oracle_grid=10,
        drift_schedule=[(drift_at, 8.0)])
    assert sum(e.applied for e in ev.engine.replan_events) >= 1
    assert ev.fleet_replanned < ev.fleet_static
    assert ev.fleet_replanned <= 1.10 * ev.fleet_oracle
    report = ev.engine.check_constraints()
    assert report["ok"]


def test_mixed_depth_fleet_replans_without_breaking_invariants():
    """Satellite regression: a fleet mixing 2- and 3-tier tenants
    (plan_fleet_mixed path) re-plans under drift while preserving the
    sorted-desc reservoir invariant, non-decreasing boundary rows, and
    bit-identical survivors vs independent simulator replays."""
    rng = np.random.default_rng(11)
    n, k, m = 6000, 32, 6
    drift_at = 1500
    two = _two_tier_model(n=n, k=k)
    three = topology.hbm_dram_disk_preset(
        n_docs=n, k=k, doc_gb=1e-5, window_seconds=600.0)
    specs = []
    for i in range(m):
        cm = two if i % 2 == 0 else three
        specs.append(StreamSpec(stream_id=i, k=k, cost_model=cm))
    traces = np.stack([simulator.drifted_rank_trace(n, rng,
                                                    [(drift_at, 8.0)])
                       for _ in range(m)])
    engine = evaluate.run_fleet(
        traces, specs, replan=ReplanConfig(drift=DriftConfig(alpha=0.05)),
        chunk=64)
    # boundary rows stay non-decreasing after every applied delta
    fin = np.where(np.isfinite(engine.meter.boundaries),
                   engine.meter.boundaries, np.inf)
    assert np.all(np.diff(fin, axis=1) >= 0)
    # reservoirs untouched: sorted-desc scores, survivors bit-match
    for st in engine.states():
        sc = np.asarray(st.scores)
        assert np.all(np.diff(sc, axis=1) <= 0)
    survivors = engine.survivors()
    for i in range(m):
        sim = simulator.simulate(traces[i], k,
                                 Policy(boundaries=(float(n),)))
        np.testing.assert_array_equal(survivors[i], sim.survivor_ids)
    # three-tier rows were eligible: at least one event somewhere
    assert isinstance(engine.replan_events, list)


# ---------------------------------------------------------------------------
# drifted-trace generator
# ---------------------------------------------------------------------------

def test_drift_weights_schedule():
    w = simulator.drift_weights(10, [(4, 3.0), (7, 0.5)])
    np.testing.assert_array_equal(w[:4], 1.0)
    np.testing.assert_array_equal(w[4:7], 3.0)
    np.testing.assert_array_equal(w[7:], 0.5)
    with pytest.raises(ValueError):
        simulator.drift_weights(10, [(2, -1.0)])


def test_drifted_trace_elevates_entry_rate():
    """Empirical record rate after the onset must exceed the null K/t
    law by roughly the configured multiplier."""
    rng = np.random.default_rng(2)
    n, k, mult, at = 4000, 32, 6.0, 2000
    extra = []
    for _ in range(8):
        tr = simulator.drifted_rank_trace(n, rng, [(at, mult)])
        res = simulator.simulate(tr, k, Policy(boundaries=(float(n),)))
        post = res.cum_writes[-1] - res.cum_writes[at - 1]
        extra.append(post)
    null_post = k * np.log(n / at)  # eq. 12 over the suffix
    drift_post = k * np.log((at + mult * (n - at)) / at)
    observed = np.mean(extra)
    assert observed > 1.5 * null_post
    assert abs(observed - drift_post) / drift_post < 0.35


def test_simulator_boundary_schedule_relocates_and_bills():
    rng = np.random.default_rng(4)
    n, k = 2000, 16
    cm = _two_tier_model(n=n, k=k)
    tr = simulator.random_rank_trace(n, rng)
    base = Policy(boundaries=(500.0,))
    plain = simulator.simulate(tr, k, base, cost_model=cm)
    moved = simulator.simulate(tr, k, base, cost_model=cm,
                               boundary_schedule=[(1000, (1500.0,))])
    assert moved.relocated > 0
    assert moved.cost_migration > plain.cost_migration
    # survivor set is placement-independent
    np.testing.assert_array_equal(plain.survivor_ids, moved.survivor_ids)
    with pytest.raises(ValueError):
        simulator.simulate(tr, k, Policy(boundaries=(500.0,),
                                         migrate_at_r=True),
                           boundary_schedule=[(1000, (700.0,))])
