"""Device-resident constrained planner (core/shp_jax.py +
kernels/plan_solve) and device suffix re-solve (online/replan_device.py):
oracle agreement on random constrained 2/3/4-tier models (including
infeasible streams returning +inf), brute-force never-lose checks,
Pallas-kernel-vs-jnp-reference equality, the documented float64/x64
policy (the solver scopes its own x64 — ambient ``jax_enable_x64`` off
is the CI default — and float32 is the TPU mode with documented
degradation), and the online re-planner's device-vs-NumPy decisions."""
import numpy as np
import pytest

import jax

from repro.core import constraints as constraints_mod
from repro.core import costs, shp, shp_jax, topology
from repro.core.constraints import ConstraintSet, ReadLatencySLO, TierCapacity

# The f64 device path mirrors the NumPy oracle's arithmetic op for op;
# residual divergence is transcendental (log) codegen and XLA fma
# contraction, amplified only under cancellation in the separable-term
# sums. This is the documented bit-match band (see README).
F64_RTOL = 1e-11


def _rand_batch(rng, m, t):
    n = rng.integers(2_000, 1_000_000, m).astype(np.float64)
    k = np.maximum(1, (n * rng.uniform(0.001, 0.1, m))).astype(np.float64)
    r = lambda s: 10.0 ** rng.uniform(-8, -3, s)
    return r((m, t)), r((m, t)), r((m, t)), n, k, np.ones(m)


def _rand_constraints(rng, m, t, k, lat_levels=True):
    cap = np.full((m, t), np.inf)
    cap[:, 0] = np.where(rng.random(m) < 0.8,
                         k * rng.uniform(0.05, 2.0, m), np.inf)
    if t > 2:
        cap[:, 1] = np.where(rng.random(m) < 0.5,
                             k * rng.uniform(0.2, 1.5, m), np.inf)
    cap[:, -1] = np.where(rng.random(m) < 0.2,
                          k * rng.uniform(0.05, 0.5, m), np.inf)
    lat = 10.0 ** rng.uniform(-3, 2, (m, t))
    lat.sort(axis=1)
    slo = np.where(rng.random(m) < 0.6,
                   10.0 ** rng.uniform(
                       np.log10(np.maximum(lat[:, 0], 1e-6)),
                       np.log10(lat[:, -1] + 1e-6)),
                   np.inf)
    return cap, lat, slo


def _eval_plan(args, bounds, mig):
    """The f64 plan objective at given (bounds, migrate) — the planner's
    conventions (most-expensive-used-tier rental / cascade fees)."""
    cw, cr, cs, n, k, rpw = args
    m, t = cw.shape
    edges = np.concatenate([np.zeros((m, 1)), bounds, n[:, None]], 1)
    w = shp._w_approx(edges, k[:, None])
    wseg = np.diff(w, axis=1)
    frac = np.diff(edges, axis=1) / n[:, None]
    writes = (wseg * cw).sum(1)
    reads = rpw * k * (frac * cr).sum(1)
    used = frac > 0
    tot_nm = writes + reads + k * np.max(np.where(used, cs, -np.inf), 1)
    stor_mg = k * (frac * cs).sum(1)
    fee = np.zeros(m)
    prev = np.zeros(m, np.int64)
    usedm = np.concatenate([frac[:, :-1] > 0, np.ones((m, 1), bool)], 1)
    seen = np.logical_or.accumulate(usedm, 1)[:, :-1]
    crossing = usedm[:, 1:] & seen
    rows = np.arange(m)
    for ti in range(1, t):
        hop = crossing[:, ti - 1]
        fee = fee + np.where(hop, cr[rows, prev] + cw[:, ti], 0.0)
        prev = np.where(usedm[:, ti], ti, prev)
    return np.where(mig, writes + stor_mg + k * fee, tot_nm)


# ---------------------------------------------------------------------------
# Oracle agreement (float64, the verification mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,seed", [(2, 0), (3, 1), (4, 2)])
def test_device_f64_matches_numpy_oracle_unconstrained(t, seed):
    rng = np.random.default_rng(seed)
    args = _rand_batch(rng, 400, t)
    a = shp.plan_ntier_arrays(*args, backend="numpy")
    b = shp_jax.plan_ntier_arrays_jax(*args, precision="float64")
    np.testing.assert_allclose(b["total"], a["total"], rtol=F64_RTOL)
    assert (a["migrate"] == b["migrate"]).all()
    # the device plan, re-evaluated under the oracle objective, is
    # exactly as cheap (bounds may differ only on equal-cost ties)
    re_ev = _eval_plan(args, b["bounds"], b["migrate"])
    np.testing.assert_allclose(re_ev, a["total"], rtol=F64_RTOL)


@pytest.mark.parametrize("t,seed", [(2, 10), (3, 11), (4, 12)])
def test_device_f64_matches_numpy_oracle_constrained(t, seed):
    rng = np.random.default_rng(seed)
    args = _rand_batch(rng, 400, t)
    cap, lat, slo = _rand_constraints(rng, 400, t, args[4])
    a = shp.plan_ntier_arrays(*args, cap=cap, lat=lat, slo=slo,
                              backend="numpy")
    b = shp_jax.plan_ntier_arrays_jax(*args, cap=cap, lat=lat, slo=slo,
                                      precision="float64")
    feas = np.isfinite(a["total"])
    # infeasible streams return +inf on both backends, bounds zeroed
    assert (feas == np.isfinite(b["total"])).all()
    assert feas.sum() > 50 and (~feas).sum() > 5  # both regimes exercised
    assert (b["bounds"][~feas] == 0.0).all()
    np.testing.assert_allclose(b["total"][feas], a["total"][feas],
                               rtol=F64_RTOL)
    assert (a["migrate"] == b["migrate"]).all()


def test_device_backend_dispatch_and_override():
    rng = np.random.default_rng(3)
    args = _rand_batch(rng, 128, 3)
    auto = shp.plan_ntier_arrays(*args)  # M >= 64, t <= 4 -> device
    dev = shp.plan_ntier_arrays(*args, backend="jax")
    np.testing.assert_array_equal(auto["total"], dev["total"])
    prev = shp.set_planner_backend("numpy")
    try:
        host = shp.plan_ntier_arrays(*args)
    finally:
        shp.set_planner_backend(prev)
    # the unconstrained device default is float32: reported totals carry
    # f32 accuracy, the plans themselves are oracle-optimal (re-checked
    # under the f64 objective)
    np.testing.assert_allclose(dev["total"], host["total"], rtol=5e-3)
    re_ev = _eval_plan(args, dev["bounds"], dev["migrate"])
    np.testing.assert_allclose(re_ev, host["total"], rtol=1e-5)
    # deep hierarchies fall back to the NumPy oracle under "auto"...
    args5 = _rand_batch(rng, 64, 5)
    out5 = shp.plan_ntier_arrays(*args5)
    assert np.isfinite(out5["total"]).all()
    # ...and raise when the device backend is forced
    with pytest.raises(shp_jax.DeviceSolverUnavailable):
        shp.plan_ntier_arrays(*args5, backend="jax")


def test_device_never_loses_to_brute_force_feasible_grid():
    """The device plan (f64) on single constrained models must match the
    same never-lose bar the NumPy solver holds against the brute-force
    feasible grid."""
    rng = np.random.default_rng(21)
    checked = 0
    for trial in range(40):
        t = int(rng.integers(3, 5))
        n = int(rng.integers(2_000, 200_000))
        k = int(rng.integers(1, max(2, n // 10)))
        specs = tuple(
            topology.TierSpec(
                costs.TierCosts(f"t{i}", *(10.0 ** rng.uniform(-8, -3, 3))),
                read_latency_s=float(10.0 ** rng.uniform(-3, 2)))
            for i in range(t))
        wl = costs.WorkloadSpec(n_docs=n, k=k, doc_gb=1e-3,
                                window_months=1.0)
        cm = topology.TierTopology(tiers=specs).cost_model(wl)
        cons = [TierCapacity(int(rng.integers(0, t)),
                             float(k * rng.uniform(0.1, 2.0)))]
        if rng.uniform() < 0.4:
            cons.append(ReadLatencySLO(float(np.median(cm.read_latency))))
        cset = ConstraintSet(*cons)
        cap, lat, slo, _ = shp.resolve_constraints(cm, cset)
        out = shp_jax.plan_ntier_arrays_jax(
            cm.cw[None], cm.cr[None], cm.cs[None],
            np.array([float(n)]), np.array([float(k)]),
            np.array([wl.reads_per_window]), cap=cap[None], lat=lat[None],
            slo=np.array([slo]), precision="float64")
        bt, bb, bm = shp.brute_force_plan_ntier(cm, grid=32,
                                                constraints=cset)
        if not np.isfinite(out["total"][0]):
            assert not np.isfinite(bt)
            continue
        checked += 1
        assert out["total"][0] <= bt * (1 + 1e-9) + 1e-12, \
            (trial, out["total"][0], bt)
        assert cset.feasible(cm, tuple(out["bounds"][0]),
                             bool(out["migrate"][0]))
    assert checked >= 25


# ---------------------------------------------------------------------------
# Pallas kernel vs jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("constrained", [False, True])
def test_pallas_kernel_matches_jnp_reference(constrained):
    """The Pallas reduction (interpret mode off-TPU) and the jnp
    reference must pick identical plans — same grids, same masks, same
    first-minimum precedence."""
    rng = np.random.default_rng(7)
    m = 48
    args = _rand_batch(rng, m, 3)
    kw = {}
    if constrained:
        cap, lat, slo = _rand_constraints(rng, m, 3, args[4])
        kw = dict(cap=cap, lat=lat, slo=slo)
    ref = shp_jax.plan_ntier_arrays_jax(*args, precision="float64",
                                        use_pallas=False, **kw)
    pal = shp_jax.plan_ntier_arrays_jax(*args, precision="float64",
                                        use_pallas=True, **kw)
    feas = np.isfinite(ref["total"])
    assert (feas == np.isfinite(pal["total"])).all()
    np.testing.assert_allclose(pal["total"][feas], ref["total"][feas],
                               rtol=1e-9)
    assert (ref["migrate"] == pal["migrate"]).all()


# ---------------------------------------------------------------------------
# The float64/x64 policy (the satellite's x64-disabled documentation)
# ---------------------------------------------------------------------------

def test_x64_disabled_ambient_config_is_irrelevant():
    """CI (and this suite) runs with ``jax_enable_x64`` off — the
    solver's f64 mode scopes its own x64 context, so the ambient flag
    must not matter. This is the documented policy: float64 results do
    not depend on global configuration."""
    assert not jax.config.jax_enable_x64  # the repo never enables it
    rng = np.random.default_rng(5)
    args = _rand_batch(rng, 200, 3)
    a = shp.plan_ntier_arrays(*args, backend="numpy")
    b = shp_jax.plan_ntier_arrays_jax(*args, precision="float64")
    np.testing.assert_allclose(b["total"], a["total"], rtol=F64_RTOL)
    assert b["total"].dtype == np.float64


def test_float32_mode_documented_degradation():
    """precision="float32" (the TPU / x64-less mode, and the shipped
    default for *unconstrained* fleet solves): plans stay essentially
    optimal — re-evaluated under the f64 oracle objective they sit
    within 1e-5 of the oracle optimum — while the *reported* totals
    only carry float32 accuracy (~1e-4 relative). Constrained solves
    default to float64 precisely because float32's cancellation near
    binding constraints loses that guarantee (documented in shp_jax)."""
    rng = np.random.default_rng(6)
    args = _rand_batch(rng, 300, 3)
    a = shp.plan_ntier_arrays(*args, backend="numpy")
    b32 = shp_jax.plan_ntier_arrays_jax(*args, precision="float32")
    re_ev = _eval_plan(args, b32["bounds"], b32["migrate"])
    subopt = (re_ev - a["total"]) / np.abs(a["total"])
    assert subopt.max() < 1e-5
    np.testing.assert_allclose(b32["total"], a["total"], rtol=5e-3)
    # the default precision split: f32 unconstrained, f64 constrained
    assert shp_jax.DEFAULT_PRECISION_UNCONSTRAINED == "float32"
    assert shp_jax.DEFAULT_PRECISION_CONSTRAINED == "float64"
    cap, lat, slo = _rand_constraints(rng, 300, 3, args[4])
    con = shp.plan_ntier_arrays(*args, cap=cap, lat=lat, slo=slo,
                                backend="jax")
    host = shp.plan_ntier_arrays(*args, cap=cap, lat=lat, slo=slo,
                                 backend="numpy")
    feas = np.isfinite(host["total"])
    np.testing.assert_allclose(con["total"][feas], host["total"][feas],
                               rtol=F64_RTOL)  # => the default ran f64


def test_forced_constrained_trivial_matches_unconstrained_device():
    """force_constrained with all-trivial constraints must reproduce the
    unconstrained device solve (the host's bit-identity property)."""
    rng = np.random.default_rng(8)
    args = _rand_batch(rng, 100, 3)
    a = shp_jax.plan_ntier_arrays_jax(*args, precision="float64")
    b = shp_jax.plan_ntier_arrays_jax(*args, precision="float64",
                                      force_constrained=True)
    np.testing.assert_allclose(a["total"], b["total"], rtol=1e-12)
    assert (a["migrate"] == b["migrate"]).all()


# ---------------------------------------------------------------------------
# Online re-planner: device suffix solve vs NumPy oracle
# ---------------------------------------------------------------------------

def _online_models(rng, r, t, with_caps=False):
    from repro.online.replan import Replanner
    models, csets = [], []
    for _ in range(r):
        wl = costs.WorkloadSpec(n_docs=int(rng.integers(5_000, 50_000)),
                                k=int(rng.integers(8, 128)), doc_gb=1e-4,
                                window_months=0.5)
        tiers = []
        put, get, rent = 1e-6, 3e-4, 0.05
        for _ in range(t):
            tiers.append(topology.TierSpec(
                costs.TierCosts("t", put_per_doc=put * rng.uniform(0.8, 1.2),
                                get_per_doc=get * rng.uniform(0.8, 1.2),
                                storage_per_gb_month=rent),
                read_latency_s=float(10.0 ** rng.uniform(-3, 1))))
            put *= 40.0
            get /= 40.0
            rent /= 3.0
        models.append(topology.TierTopology(tiers=tuple(tiers))
                      .cost_model(wl))
        cons = []
        if with_caps and rng.uniform() < 0.8:
            cons.append(TierCapacity(0, float(wl.k * rng.uniform(0.3, 2.0))))
        csets.append(ConstraintSet(*cons))
    return models, csets


@pytest.mark.parametrize("t,with_caps", [(2, False), (3, False), (3, True)])
def test_replan_device_matches_numpy(t, with_caps):
    from repro.online.replan import Replanner
    rng = np.random.default_rng(31 + t)
    r = 48
    models, csets = _online_models(rng, r, t, with_caps)
    kw = dict(constraints=csets) if with_caps else {}
    rp_dev = Replanner(models, **kw)
    rp_np = Replanner(models, backend="numpy", **kw)
    n = np.array([m.workload.n_docs for m in models], np.float64)
    n0 = rng.uniform(0.1, 0.9, r) * n
    rho = rng.uniform(0.3, 8.0, r)
    bounds = [tuple(sorted(rng.uniform(0, n[i], t - 1))) for i in range(r)]
    mig = rng.random(r) < 0.15
    d_np = rp_np.replan(np.arange(r), n0, rho, bounds, mig)
    d_dev = rp_dev.replan(np.arange(r), n0, rho, bounds, mig)
    assert (np.asarray(d_np.considered) == np.asarray(d_dev.considered)).all()
    assert (d_np.applied == d_dev.applied).all()
    assert (d_np.feasible == d_dev.feasible).all()
    cn = np.asarray(d_np.suffix_cost_new)
    cd = np.asarray(d_dev.suffix_cost_new)
    both = np.isfinite(cn) & np.isfinite(cd)
    assert (np.isfinite(cn) == np.isfinite(cd)).all()
    np.testing.assert_allclose(cd[both], cn[both], rtol=1e-10)
    np.testing.assert_allclose(np.asarray(d_dev.suffix_cost_old),
                               np.asarray(d_np.suffix_cost_old),
                               rtol=1e-10, equal_nan=True)
    for a, b in zip(d_np.new_bounds, d_dev.new_bounds):
        np.testing.assert_allclose(np.asarray(b, float),
                                   np.asarray(a, float),
                                   rtol=1e-6, atol=1e-3)


# ---------------------------------------------------------------------------
# Hypothesis property (skipped without the optional dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as hyp_st

    @settings(deadline=None, max_examples=25)
    @given(seed=hyp_st.integers(0, 2 ** 31 - 1),
           t=hyp_st.integers(2, 4),
           constrained=hyp_st.booleans())
    def test_device_matches_oracle_property(seed, t, constrained):
        rng = np.random.default_rng(seed)
        args = _rand_batch(rng, 64, t)
        kw = {}
        if constrained:
            cap, lat, slo = _rand_constraints(rng, 64, t, args[4])
            kw = dict(cap=cap, lat=lat, slo=slo)
        a = shp.plan_ntier_arrays(*args, backend="numpy", **kw)
        b = shp_jax.plan_ntier_arrays_jax(*args, precision="float64", **kw)
        feas = np.isfinite(a["total"])
        assert (feas == np.isfinite(b["total"])).all()
        np.testing.assert_allclose(b["total"][feas], a["total"][feas],
                                   rtol=F64_RTOL)
        assert (a["migrate"] == b["migrate"]).all()
except ImportError:  # pragma: no cover - optional dependency
    pass
