"""EMA-relative scoring restores the SHP write law on trending streams
(the §Training-integration finding + mitigation, beyond paper)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shp, topk
from repro.core.interestingness import ema_relative


from repro.core.interestingness import batch_centered


def run_reservoir(scores_per_batch, k, mode: str):
    state = topk.init(k)
    ema = jnp.zeros((), jnp.float32)
    writes = 0
    for step, batch_scores in enumerate(scores_per_batch):
        s = jnp.asarray(batch_scores, jnp.float32)
        if mode == "ema":
            s, ema = ema_relative(s, ema, jnp.asarray(step))
        elif mode == "centered":
            s = batch_centered(s)
        ids = jnp.arange(step * len(batch_scores),
                         (step + 1) * len(batch_scores), dtype=jnp.int32)
        state, wrote = topk.update(state, s, ids)
        writes += int(wrote.sum())
    return writes, state


def _trending_stream(rng, n_batches=120, b=16, slope=-0.02, noise=1.0):
    """Synthetic training-NLL stream: decreasing trend + i.i.d. noise —
    mimics loss decay, violating the random-order assumption."""
    out = []
    t = 0
    for _ in range(n_batches):
        base = 10.0 + slope * t
        out.append(base + rng.standard_normal(b) * noise)
        t += b
    return out


def test_raw_nll_underwrites_but_detrended_matches_analytic():
    rng = np.random.default_rng(0)
    k = 32
    trials = 5
    raw_w, cen_w, ema_w = [], [], []
    n = None
    for _ in range(trials):
        stream = _trending_stream(rng)
        n = sum(len(s) for s in stream)
        raw_w.append(run_reservoir(stream, k, "raw")[0])
        cen_w.append(run_reservoir(stream, k, "centered")[0])
        ema_w.append(run_reservoir(stream, k, "ema")[0])
    analytic = float(shp.expected_cum_writes(n - 1, k))
    raw, cen, ema = np.mean(raw_w), np.mean(cen_w), np.mean(ema_w)
    # trend biases raw scoring far below the law
    assert raw < 0.6 * analytic, (raw, analytic)
    # batch-mean centering restores the law
    assert abs(cen - analytic) / analytic < 0.15, (cen, analytic)
    # EMA de-trending is in between (lags the trend)
    assert raw < ema, (raw, ema)


def test_detrending_is_noop_on_stationary_stream():
    """On an already-random stream all modes obey the law."""
    rng = np.random.default_rng(3)
    k = 16
    stream = [rng.standard_normal(16) for _ in range(80)]
    n = 80 * 16
    analytic = float(shp.expected_cum_writes(n - 1, k))
    for mode in ("raw", "centered", "ema"):
        w, _ = run_reservoir(stream, k, mode)
        assert abs(w - analytic) / analytic < 0.3, (mode, w, analytic)


def test_train_step_score_mode_wiring():
    """score_mode='nll_relative' updates the EMA in TrainState."""
    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.data.synthetic import make_batch
    from repro.runtime import steps
    cfg = configs.get_config("llama3.2-1b", reduced=True)
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, shape))
    batch["example_ids"] = jnp.arange(4, dtype=jnp.int32)
    st = steps.init_train_state(cfg, jax.random.PRNGKey(0), reservoir_k=8)
    st2, _ = steps.train_step(st, batch, cfg, score_mode="nll_relative")
    assert float(st2.score_ema) != 0.0
    st3, _ = steps.train_step(st, batch, cfg, score_mode="nll")
    assert float(st3.score_ema) == 0.0
