"""Trace-driven simulator vs the analytic model (paper §VIII, Fig. 8)."""
import numpy as np
import pytest

from repro.core import costs, placement, shp, simulator


def test_cum_writes_matches_analytic_random_trace():
    """Fig. 8: cumulative writes on a randomly-ordered trace tracks
    K + K·ln((i+1)/K)."""
    rng = np.random.default_rng(7)
    n, k = 20_000, 100
    trials = 8
    acc = np.zeros(n)
    for _ in range(trials):
        trace = simulator.random_rank_trace(n, rng)
        res = simulator.simulate(trace, k, placement.all_tier_a(n))
        acc += res.cum_writes
    mean_writes = acc / trials
    analytic = shp.expected_cum_writes(np.arange(n), k)
    # relative error at a few checkpoints (sampling noise ~ sqrt(K ln)/trials)
    for i in [k, n // 100, n // 10, n - 1]:
        assert abs(mean_writes[i] - analytic[i]) / analytic[i] < 0.05, i


def test_grn_trace_matches_analytic():
    """The paper's claim: ANY trace whose ranks are randomly ordered obeys
    the same write law — validated with the synthetic GRN entropy trace."""
    rng = np.random.default_rng(3)
    n, k = 20_000, 100
    trace = simulator.grn_entropy_trace(n, rng)
    res = simulator.simulate(trace, k, placement.all_tier_a(n))
    analytic = shp.expected_cum_writes(np.arange(n), k)
    assert abs(res.cum_writes[-1] - analytic[-1]) / analytic[-1] < 0.12


def test_sorted_trace_breaks_assumption():
    """Ascending scores ⇒ every doc is a new best ⇒ N writes (≫ analytic)."""
    n, k = 2_000, 10
    res = simulator.simulate(simulator.sorted_adversarial_trace(n, ascending=True),
                             k, placement.all_tier_a(n))
    assert res.cum_writes[-1] == n
    analytic = float(shp.expected_cum_writes(n - 1, k))
    assert res.cum_writes[-1] > 5 * analytic


def test_simulated_cost_matches_expected_no_migration():
    cm = costs.case_study_1().replace(
        workload=costs.WorkloadSpec(n_docs=30_000, k=300, doc_gb=0.1 / 1000,
                                    window_months=1 / 30))
    r = shp.r_optimal_no_migration(cm)
    pol = placement.Policy(r=r, migrate_at_r=False)
    rng = np.random.default_rng(11)
    sims = [simulator.simulate(simulator.random_rank_trace(cm.workload.n_docs, rng),
                               cm.workload.k, pol, cm, storage_bound=True)
            for _ in range(6)]
    sim_mean = np.mean([s.cost_total for s in sims])
    expected = shp.cost_no_migration(cm, r, exact=True).total
    assert abs(sim_mean - expected) / expected < 0.05


def test_simulated_cost_matches_expected_migration():
    cm = costs.case_study_2().replace(
        workload=costs.WorkloadSpec(n_docs=30_000, k=1_500, doc_gb=1 / 1000,
                                    window_months=7 / 30))
    r = shp.r_optimal_migration(cm)
    pol = placement.Policy(r=r, migrate_at_r=True)
    rng = np.random.default_rng(13)
    sim = simulator.simulate(simulator.random_rank_trace(cm.workload.n_docs, rng),
                             cm.workload.k, pol, cm)
    # eq. 20 (no final read); metered rental vs r/N split are both
    # approximations of each other — compare within 12%
    expected = shp.cost_with_migration(cm, r, exact=True).total
    sim_total = sim.cost_total - sim.cost_reads  # exclude final read, eq. 20
    assert abs(sim_total - expected) / expected < 0.12
    assert sim.migrated > 0


def test_survivors_are_true_topk():
    rng = np.random.default_rng(5)
    n, k = 5_000, 50
    trace = simulator.grn_entropy_trace(n, rng)
    res = simulator.simulate(trace, k, placement.all_tier_b())
    expect = set(np.argsort(-trace)[:k].tolist())
    assert set(res.survivor_ids.tolist()) == expect
    assert res.reads_per_tier[placement.TIER_B] == k


def test_migration_moves_everything_out_of_a():
    n, k = 3_000, 30
    rng = np.random.default_rng(9)
    pol = placement.Policy(r=n // 3, migrate_at_r=True)
    res = simulator.simulate(simulator.random_rank_trace(n, rng), k, pol,
                             costs.case_study_2())
    assert res.reads_per_tier[placement.TIER_A] == 0  # final read all from B
    assert res.migrated <= k
