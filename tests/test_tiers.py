"""TieredStore runtime (core/tiers.py): placement, migration, ledger."""
import jax.numpy as jnp
import numpy as np

from repro.core import placement, tiers


def make_store(r, migrate=False, k=4, shape=(3,), tmp=None):
    pol = placement.Policy(r=r, migrate_at_r=migrate)
    hot = tiers.HotTier(k=k, payload_shape=shape, dtype=jnp.float32)
    cold = tiers.ColdTier(directory=tmp)
    return tiers.TieredStore(pol, hot, cold)


def payload(i, shape=(3,)):
    return jnp.full(shape, float(i), dtype=jnp.float32)


def test_write_respects_policy_threshold():
    store = make_store(r=10)
    assert store.write(3, payload(3)) == placement.TIER_A
    assert store.write(10, payload(10)) == placement.TIER_B
    assert store.tier_index_of(3) == placement.TIER_A
    assert store.tier_index_of(10) == placement.TIER_B
    np.testing.assert_allclose(np.asarray(store.read(3)), 3.0)
    np.testing.assert_allclose(np.asarray(store.read(10)), 10.0)


def test_evict_frees_hot_slot():
    store = make_store(r=100, k=2)
    store.write(0, payload(0))
    store.write(1, payload(1))
    store.evict(0)
    store.write(2, payload(2))  # would raise if slot not freed
    assert store.tier_index_of(0) is None
    assert store.ledger.deletes[placement.TIER_A] == 1


def test_migration_moves_hot_to_cold_and_counts():
    store = make_store(r=5, migrate=True, k=8)
    for i in range(5):
        store.write(i, payload(i))
    moved = store.maybe_migrate(stream_index=5)
    assert moved == 5
    for i in range(5):
        assert store.tier_index_of(i) == placement.TIER_B
        np.testing.assert_allclose(np.asarray(store.read(i)), float(i))
    # post-migration writes land in B regardless of policy
    assert store.write(99, payload(99)) == placement.TIER_B
    assert store.maybe_migrate(6) == 0  # idempotent
    assert store.ledger.migrations == 5


def test_ledger_counts_bytes(tmp_path):
    store = make_store(r=1, tmp=str(tmp_path))
    store.write(0, payload(0))   # -> A (hot)
    store.write(5, payload(5))   # -> B (cold, on disk)
    assert store.ledger.bytes_written[placement.TIER_A] == 12
    assert store.ledger.bytes_written[placement.TIER_B] == 12
    got = store.read_all([0, 5])
    assert set(got) == {0, 5}
    assert store.ledger.reads.sum() == 2


def test_cold_tier_disk_roundtrip(tmp_path):
    cold = tiers.ColdTier(directory=str(tmp_path))
    cold.put(7, jnp.arange(4, dtype=jnp.float32))
    assert 7 in cold
    np.testing.assert_array_equal(cold.get(7), np.arange(4, dtype=np.float32))
    assert cold.doc_ids() == [7]
    cold.delete(7)
    assert 7 not in cold
