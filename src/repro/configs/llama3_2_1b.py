"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, tied embeddings [hf:meta-llama/Llama-3.2-1B]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "llama3.2-1b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", d_model=2048, vocab_size=128256,
        layers=(LayerSpec(count=16, mixer="attn", ffn="dense"),),
        n_heads=32, n_kv_heads=8, head_dim=64, rope_theta=500000.0,
        d_ff=8192, ffn_act="silu_glu", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="attn", ffn="dense"),),
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    )
