"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, sliding window 4096, LayerNorm + biases
[arXiv:2402.19173; hf]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "starcoder2-3b"
WINDOW = 4096


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", d_model=3072, vocab_size=49152,
        layers=(LayerSpec(count=30, mixer="attn", ffn="dense",
                          windows=(WINDOW,) * 30),),
        n_heads=24, n_kv_heads=2, head_dim=128, rope_theta=999999.0,
        d_ff=12288, ffn_act="gelu", ffn_bias=True, qkv_bias=True,
        use_layernorm=True, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="attn", ffn="dense",
                          windows=(8, 8)),),
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    )
