"""Model / shape configuration schema covering all assigned architectures.

A model is a token embedding + a sequence of *layer groups* (each group is a
stack of identical layers run under ``lax.scan``) + final norm + LM head.
Heterogeneous stacks (e.g. DeepSeek's dense first layer before 59 MoE
layers, Whisper's encoder vs decoder) are expressed as multiple groups.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class LayerSpec:
    """One scanned group of identical layers."""

    count: int
    mixer: str = "attn"  # attn | ssm | attn_ssm_parallel | none
    ffn: str = "dense"  # dense | moe | none
    cross_attn: bool = False  # decoder group attending to encoder states
    causal: bool = True
    # per-layer sliding window; 0 = full attention. len must be count (or
    # empty = all full). Mixed windows (hymba) stay scannable because the
    # window enters the kernel as data, not structure.
    windows: Tuple[int, ...] = ()

    def window_list(self) -> Tuple[int, ...]:
        return self.windows if self.windows else (0,) * self.count


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab_size: int
    layers: Tuple[LayerSpec, ...]  # decoder stack
    encoder_layers: Tuple[LayerSpec, ...] = ()  # enc-dec archs only
    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 1e4
    use_rope: bool = True
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0  # grok-style tanh capping (0 = off)
    # ---- MLA (DeepSeek-V2) ----
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # ---- FFN ----
    d_ff: int = 0
    ffn_bias: bool = False
    ffn_act: str = "silu_glu"  # silu_glu | gelu_glu | gelu | silu
    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k_experts: int = 0
    d_ff_expert: int = 0
    moe_group_size: int = 512
    capacity_factor: float = 1.25
    router_scale: bool = True  # normalize top-k router weights to sum to 1
    # ---- SSM (Mamba-2 SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # ---- enc-dec / frontends ----
    decoder_len: int = 0  # fixed decoder length for enc-dec (whisper: 448)
    frontend: str = "none"  # none | audio_frames | vision_patches
    n_patches: int = 0  # vision: patch embeddings blended into the prefix
    use_layernorm: bool = False  # whisper uses LN+bias; others RMSNorm
    learned_pos_embed: bool = False  # whisper decoder
    # ---- misc ----
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0
    param_dtype: str = "float32"
    activation_dtype: str = "float32"
    remat: bool = False  # checkpoint each scanned layer body
    # Megatron-style sequence parallelism: residual stream / norms /
    # remat-saved activations sharded over `model` on the sequence dim;
    # attention & FFN gather/scatter at their boundaries (§Perf iteration 3)
    seq_parallel: bool = False

    # ---- derived ----
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def n_layers(self) -> int:
        return sum(s.count for s in self.layers) + sum(s.count for s in self.encoder_layers)

    @property
    def is_encoder_decoder(self) -> bool:
        return len(self.encoder_layers) > 0

    @property
    def attention_free(self) -> bool:
        return all(s.mixer == "ssm" for s in self.layers + self.encoder_layers)

    @property
    def max_window(self) -> int:
        """Largest sliding window (0 if any layer is full attention)."""
        ws = []
        for s in self.layers:
            ws.extend(s.window_list())
        return 0 if any(w == 0 for w in ws) else max(ws)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_dtypes(self, param, activation) -> "ModelConfig":
        return self.replace(param_dtype=param, activation_dtype=activation)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k needs sub-quadratic attention; enc-dec
    encoder is full-attention over frames (whisper skips long)."""
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            return False, "enc-dec: encoder is quadratic in frames; decoder ctx bounded"
        sub_quadratic = cfg.attention_free or cfg.max_window > 0 or cfg.family == "hybrid"
        if not sub_quadratic:
            return False, "pure full-attention arch — long_500k skipped per assignment"
    return True, ""
