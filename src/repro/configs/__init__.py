"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

from . import (base, command_r_plus_104b, deepseek_v2_236b, grok_1_314b,
               hymba_1_5b, llama3_2_1b, mamba2_2_7b, pixtral_12b,
               starcoder2_3b, whisper_base, yi_9b)
from .base import SHAPES, LayerSpec, ModelConfig, ShapeConfig, supports_shape  # noqa: F401

_MODULES = (
    hymba_1_5b, mamba2_2_7b, deepseek_v2_236b, grok_1_314b, pixtral_12b,
    llama3_2_1b, yi_9b, starcoder2_3b, command_r_plus_104b, whisper_base,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}


def list_archs() -> list[str]:
    return list(ARCHS)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list(ARCHS)}")
    m = ARCHS[arch_id]
    return m.reduced() if reduced else m.full()


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {list(SHAPES)}")
    return SHAPES[name]
