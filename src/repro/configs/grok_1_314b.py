"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2, attention/logit soft-capping
[hf:xai-org/grok-1]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "grok-1-314b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", d_model=6144, vocab_size=131072,
        layers=(LayerSpec(count=64, mixer="attn", ffn="moe"),),
        n_heads=48, n_kv_heads=8, head_dim=128, rope_theta=1e4,
        n_experts=8, top_k_experts=2, d_ff_expert=32768,
        attn_logit_softcap=30.0, logit_softcap=30.0,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="attn", ffn="moe"),),
        n_heads=4, n_kv_heads=2, head_dim=16,
        n_experts=4, top_k_experts=2, d_ff_expert=64, moe_group_size=16,
        capacity_factor=4 / 2,  # dropless at smoke scale (see deepseek note)
    )
