"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "command-r-plus-104b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", d_model=12288, vocab_size=256000,
        layers=(LayerSpec(count=64, mixer="attn", ffn="dense"),),
        n_heads=96, n_kv_heads=8, head_dim=128, rope_theta=75e6,
        d_ff=33792, ffn_act="silu_glu", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="attn", ffn="dense"),),
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    )
