"""mamba2-2.7b [ssm] — 64L d_model=2560 attention-free, SSD state 128,
expand 2 (d_inner=5120, 80 heads of dim 64), vocab=50280
[arXiv:2405.21060]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "mamba2-2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm", d_model=2560, vocab_size=50280,
        layers=(LayerSpec(count=64, mixer="ssm", ffn="none"),),
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_ngroups=1,
        ssm_chunk=128, tie_embeddings=True, use_rope=False,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="ssm", ffn="none"),),
        ssm_state=8, ssm_head_dim=8, ssm_chunk=16,
    )
