"""whisper-base [audio] — 6L encoder + 6L decoder, d_model=512 8H
d_ff=2048 vocab=51865 — enc-dec; conv frontend is a STUB (input_specs
provides precomputed frame embeddings); decoder context 448
[arXiv:2212.04356]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "whisper-base"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio", d_model=512, vocab_size=51865,
        encoder_layers=(LayerSpec(count=6, mixer="attn", ffn="dense",
                                  causal=False),),
        layers=(LayerSpec(count=6, mixer="attn", ffn="dense",
                          cross_attn=True),),
        n_heads=8, n_kv_heads=8, head_dim=64, use_rope=False,
        d_ff=2048, ffn_act="gelu", ffn_bias=True, qkv_bias=True,
        use_layernorm=True, learned_pos_embed=True, decoder_len=448,
        frontend="audio_frames", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        encoder_layers=(LayerSpec(count=2, mixer="attn", ffn="dense",
                                  causal=False),),
        layers=(LayerSpec(count=2, mixer="attn", ffn="dense",
                          cross_attn=True),),
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, decoder_len=16,
    )
