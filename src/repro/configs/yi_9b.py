"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
llama-arch GQA [arXiv:2403.04652; hf]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "yi-9b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense", d_model=4096, vocab_size=64000,
        layers=(LayerSpec(count=48, mixer="attn", ffn="dense"),),
        n_heads=32, n_kv_heads=4, head_dim=128, rope_theta=5e6,
        d_ff=11008, ffn_act="silu_glu",
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="attn", ffn="dense"),),
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    )
