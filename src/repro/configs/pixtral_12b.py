"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo backbone. The vision frontend is a
STUB: input_specs provides precomputed patch embeddings blended into the
sequence prefix [hf:mistralai/Pixtral-12B-2409]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "pixtral-12b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm", d_model=5120, vocab_size=131072,
        layers=(LayerSpec(count=40, mixer="attn", ffn="dense"),),
        n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=1e9,
        d_ff=14336, ffn_act="silu_glu",
        frontend="vision_patches", n_patches=1024,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(LayerSpec(count=2, mixer="attn", ffn="dense"),),
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, n_patches=8,
    )
