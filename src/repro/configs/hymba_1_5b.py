"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer;
3 global-attention layers (first/middle/last), sliding window 1024 elsewhere
[arXiv:2411.13676; hf]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "hymba-1.5b"
SWA = 1024


def _hybrid_groups(swa_counts, swa: int) -> tuple:
    """Global / SWA layers as window-homogeneous groups so rolling caches
    stay small for the SWA layers (lm.group_kv_len): layout is
    global, swa×a, global, swa×b, global (first/middle/last global)."""
    def g(count, window):
        return LayerSpec(count=count, mixer="attn_ssm_parallel", ffn="dense",
                         windows=(window,) * count)
    a, b = swa_counts
    return (g(1, 0), g(a, swa), g(1, 0), g(b, swa), g(1, 0))


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid", d_model=1600, vocab_size=32001,
        layers=_hybrid_groups((14, 15), SWA),
        n_heads=25, n_kv_heads=5, head_dim=64, rope_theta=1e4,
        d_ff=5504, ffn_act="silu_glu",
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    def g(count, window):
        return LayerSpec(count=count, mixer="attn_ssm_parallel", ffn="dense",
                         windows=(window,) * count)
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(g(1, 0), g(1, 8)),
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        ssm_state=8, ssm_head_dim=8, ssm_chunk=16,
    )
