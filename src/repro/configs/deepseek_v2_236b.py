"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA kv_lora=512,
first layer dense (d_ff=12288), 59 MoE layers: 2 shared + 160 routed top-6
experts (d_ff_expert=1536), vocab=102400 [arXiv:2405.04434; hf]."""
from .base import LayerSpec, ModelConfig

ARCH_ID = "deepseek-v2-236b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe", d_model=5120, vocab_size=102400,
        layers=(
            LayerSpec(count=1, mixer="attn", ffn="dense"),
            LayerSpec(count=59, mixer="attn", ffn="moe"),
        ),
        n_heads=128, rope_theta=1e4,
        use_mla=True, kv_lora_rank=512, q_lora_rank=1536,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        d_ff=12288, ffn_act="silu_glu",
        n_experts=160, n_shared_experts=2, top_k_experts=6, d_ff_expert=1536,
    )


def reduced() -> ModelConfig:
    return full().replace(
        d_model=64, vocab_size=256,
        layers=(
            LayerSpec(count=1, mixer="attn", ffn="dense"),
            LayerSpec(count=2, mixer="attn", ffn="moe"),
        ),
        n_heads=4, kv_lora_rank=16, q_lora_rank=24,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        d_ff=128, n_experts=8, n_shared_experts=1, top_k_experts=2,
        d_ff_expert=32, moe_group_size=16,
        # dropless at smoke scale: capacity = group size ⇒ routing output is
        # exactly grouping-invariant (prefill/forward parity tests rely on it)
        capacity_factor=8 / 2,
    )
