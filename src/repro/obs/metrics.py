"""Device-side metric accumulators for the jitted engine step.

``MetricsState`` is a tiny pytree carried through ``StreamEngine``'s
jitted multi-bucket step. Every update is computed from values the step
already materializes (the batch ids, the write mask, the eviction ids,
the pre-update reservoir bar, the drift state) — a handful of extra
scalar reductions fused into the same XLA program, with **zero
additional host syncs**: the counters live on device until ``snapshot``
drains them (one transfer, at chunk boundaries or on demand), and with
metrics disabled the step traces the exact pre-obs computation, so
obs-off output is bit-identical.

The integer counters are packed into ONE ``(8,)`` int32 vector (plus a
float32 scalar for the drift score) so the obs variant adds only two
pytree leaves to the step's signature — per-call dispatch cost on small
fleets is dominated by leaf count, not by the reductions themselves.
Drain and rebase into the host-side accumulator before a window
approaches 2^31 docs (x64 stays off on the hot path).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# slots of the packed counter vector
(DOCS, ADMITS, EVICTIONS, BAR_CANDIDATES, BAR_PASSES, CHUNKS, DRIFT_FIRED,
 SCORES_QUARANTINED) = range(8)
N_SLOTS = 8


class MetricsState(NamedTuple):
    """Fleet-level counters, accumulated on device.

    Under a fleet mesh (``StreamEngine(mesh=...)``) the leaves carry a
    leading shard axis — counts ``(D, 8)``, score ``(D,)`` — split
    across the mesh so each device accumulates its own block inside the
    sharded step with **no collectives on the hot path**; ``snapshot``
    aggregates across shards (integer sums are exact, so fleet-global
    counts are identical to the single-device run's)."""

    counts: jax.Array  # (8,) i32 — or (D, 8) sharded; see slots above
    drift_score_max: jax.Array  # () f32 — or (D,) sharded

    @property
    def sharded(self) -> bool:
        return getattr(self.counts, "ndim", 1) == 2


def init(shards: int = 0) -> MetricsState:
    """``shards > 0`` builds the sharded layout (one counter block per
    mesh device); the caller places it with the fleet row sharding."""
    if shards:
        return MetricsState(counts=jnp.zeros((shards, N_SLOTS), jnp.int32),
                            drift_score_max=jnp.zeros((shards,),
                                                      jnp.float32))
    return MetricsState(counts=jnp.zeros((N_SLOTS,), jnp.int32),
                        drift_score_max=jnp.zeros((), jnp.float32))


def shard_local(ms: MetricsState) -> MetricsState:
    """Inside ``shard_map``: squeeze this shard's (1, 8)/(1,) block to
    the flat single-device layout so every accumulate_* law applies
    unchanged."""
    return MetricsState(counts=ms.counts[0],
                        drift_score_max=ms.drift_score_max[0])


def shard_pack(ms: MetricsState) -> MetricsState:
    """Inverse of ``shard_local``: re-add the leading shard axis before
    the sharded step returns its block."""
    return MetricsState(counts=ms.counts[None],
                        drift_score_max=ms.drift_score_max[None])


def accumulate_bucket(ms: MetricsState, batch_scores, batch_ids, bar,
                      wrote, evicted) -> MetricsState:
    """Fold one bucket's step outputs into the counters (pure; traced
    inside the jitted step). ``bar`` is the pre-update entry bar
    (``state.scores[:, -1]``): the kernel-filter pass rate is the
    fraction of live candidates scoring above it — on unfull reservoirs
    the bar is -inf and every candidate passes, matching the filter."""
    live = batch_ids >= 0
    i32 = jnp.int32
    docs = live.sum(dtype=i32)
    z = jnp.zeros((), i32)
    delta = jnp.stack([
        docs,                                                # DOCS
        wrote.sum(dtype=i32),                                # ADMITS
        (evicted >= 0).sum(dtype=i32),                       # EVICTIONS
        docs,                                                # BAR_CANDIDATES
        (live & (batch_scores > bar[:, None])).sum(dtype=i32),  # BAR_PASSES
        z, z, z])
    return ms._replace(counts=ms.counts + delta)


def accumulate_quarantine(ms: MetricsState, count) -> MetricsState:
    """Count non-finite scores the step swapped out for pad slots before
    they could poison the reservoir compares (NaN fails every compare)."""
    return ms._replace(counts=ms.counts.at[SCORES_QUARANTINED].add(
        jnp.asarray(count, jnp.int32)))


def accumulate_drift(ms: MetricsState, score_max, fired_count
                     ) -> MetricsState:
    """Fold the drift detector's per-step summary (max normalized score,
    latched fire count) into the counters."""
    counts = ms.counts.at[DRIFT_FIRED].set(
        jnp.asarray(fired_count, jnp.int32))
    return MetricsState(
        counts=counts,
        drift_score_max=jnp.maximum(ms.drift_score_max,
                                    jnp.asarray(score_max, jnp.float32)))


def bump_chunk(ms: MetricsState) -> MetricsState:
    return ms._replace(counts=ms.counts.at[CHUNKS].add(1))


def snapshot(ms: MetricsState) -> dict:
    """Drain the device counters to host scalars (the only sync point).

    Sharded states are aggregated here — the cross-shard sum (max for
    the drift score; every shard bumps CHUNKS once per step, so chunks
    take one shard's count) runs on device before the single transfer,
    so ``obs_snapshot``/Prometheus always report *fleet-global* counts,
    never one shard's block."""
    import numpy as np
    if ms.sharded:
        chunks = int(np.asarray(ms.counts[:, CHUNKS].max()))
        c = np.asarray(ms.counts.sum(axis=0))
        score = float(np.asarray(ms.drift_score_max.max()))
    else:
        c = np.asarray(ms.counts)
        chunks = int(c[CHUNKS])
        score = float(np.asarray(ms.drift_score_max))
    cand, passes = int(c[BAR_CANDIDATES]), int(c[BAR_PASSES])
    return {
        "docs": int(c[DOCS]),
        "admits": int(c[ADMITS]),
        "evictions": int(c[EVICTIONS]),
        "bar_candidates": cand,
        "bar_passes": passes,
        "filter_pass_rate": passes / cand if cand else 0.0,
        "chunks": chunks,
        "drift_score_max": score,
        "drift_fired": int(c[DRIFT_FIRED]),
        "scores_quarantined": int(c[SCORES_QUARANTINED]),
    }


def to_canonical(ms: MetricsState):
    """Collapse a (possibly sharded) state to the mesh-independent host
    form ``(counts (8,) i64-safe, score f32)`` used by checkpoints: the
    same aggregation ``snapshot`` reports (integer sums exact; CHUNKS and
    the drift high-water take the cross-shard max)."""
    import numpy as np
    if ms.sharded:
        c = np.asarray(ms.counts).sum(axis=0).astype(np.int32)
        c[CHUNKS] = np.asarray(ms.counts)[:, CHUNKS].max()
        score = np.float32(np.asarray(ms.drift_score_max).max())
    else:
        c = np.asarray(ms.counts).copy()
        score = np.float32(np.asarray(ms.drift_score_max))
    return c, score


def from_canonical(counts, score, shards: int = 0) -> MetricsState:
    """Rebuild a device state from the canonical form onto ``shards``
    mesh devices (0 = flat). The aggregate lands in shard 0's block with
    the rest zeroed, so subsequent accumulation + ``snapshot``'s
    sum/max aggregation reproduce the uninterrupted run's numbers for
    ANY target shard count (pad blocks are inert zeros)."""
    import numpy as np
    counts = np.asarray(counts, np.int32).reshape(N_SLOTS)
    if shards:
        c = np.zeros((shards, N_SLOTS), np.int32)
        c[0] = counts
        s = np.zeros((shards,), np.float32)
        s[0] = score
        return MetricsState(counts=jnp.asarray(c),
                            drift_score_max=jnp.asarray(s))
    return MetricsState(counts=jnp.asarray(counts),
                        drift_score_max=jnp.asarray(score, jnp.float32))
