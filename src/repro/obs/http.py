"""Live serving dashboard: a stdlib HTTP endpoint over ``Observability``.

``serve(obs, port=...)`` starts a daemon ``ThreadingHTTPServer`` that
renders a *fresh* snapshot per request:

* ``GET /metrics``  — Prometheus text exposition (``obs.export``), the
  scrape target: counters (ingested docs, tier writes, resident doc-
  steps, realized spend) are monotone across scrapes of a live engine.
* ``GET /snapshot`` — the full nested snapshot as JSON (the dashboard /
  debugging view).

Snapshots drain the engines' device counters on the request thread —
the same sync ``Observability.snapshot`` always was; the ingest loop
keeps running (host-side state swaps are atomic enough under the GIL
for monitoring reads, which is all an exposition endpoint needs).
No third-party dependencies: the serving stack must not grow a web
framework for two read-only routes.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from . import export


class ObsServer:
    """Handle for a running endpoint: ``.port`` (resolved when asked for
    port 0), ``.url``, and ``.stop()``."""

    def __init__(self, httpd: ThreadingHTTPServer, thread: threading.Thread):
        self._httpd = httpd
        self._thread = thread
        self._stopped = False
        self.port = int(httpd.server_address[1])
        self.url = f"http://127.0.0.1:{self.port}"

    def stop(self) -> None:
        """Drain and close the endpoint. Idempotent — shutdown paths
        (signal handler + normal exit) may both call it."""
        if self._stopped:
            return
        self._stopped = True
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve(obs, port: int = 0, host: str = "127.0.0.1",
          prefix: str = "repro_obs") -> ObsServer:
    """Start serving ``obs`` on ``host:port`` (port 0 = ephemeral);
    returns the ``ObsServer`` handle immediately."""

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server's casing)
            path = self.path.split("?", 1)[0]
            try:
                if path == "/metrics":
                    body = obs.prometheus(prefix=prefix).encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif path == "/snapshot":
                    body = json.dumps(
                        obs.snapshot(), sort_keys=True,
                        default=export._json_default).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404, "try /metrics or /snapshot")
                    return
            except Exception as exc:  # surface, don't kill the server
                self.send_error(500, type(exc).__name__)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # quiet: scrapes are not events
            pass

    httpd = ThreadingHTTPServer((host, port), Handler)
    httpd.daemon_threads = True
    thread = threading.Thread(target=httpd.serve_forever,
                              name="obs-http", daemon=True)
    thread.start()
    return ObsServer(httpd, thread)
