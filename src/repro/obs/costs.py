"""Live cost attribution: device-resident per-stream cost ledgers,
closed-form expected-cost trajectories, regret, and budget burn alerts.

The paper's objective *is* cost — expected write + storage + read +
migration spend under the SHP write/lifetime laws — so the cost layer
follows the same model-referenced discipline as ``obs.residuals``:
realized spend is compared to what the planner's closed forms promised,
and alerts fire on statistically significant deviation, not thresholds
on raw gauges.

Three pieces:

* ``CostState`` — a tiny per-bucket pytree carried through the jitted
  ``StreamEngine`` step (``obs.metrics``'s discipline: every update is a
  few reductions over values the step already materializes, fused into
  the same XLA program, **zero extra host syncs** — drained only at
  ``snapshot``). It counts integer per-(stream, tier) transactions:
  writes, deletes, and ``resident_steps`` (the storage integral —
  post-step occupancy × docs ingested, a doc-step rental meter that at
  chunk width 1 equals the simulator's per-doc doc-month accounting
  exactly). Counts stay i32 on device (x64 is off on the hot path);
  pricing happens on host in f64 at drain time, so identical integers
  priced through identical dot products give bit-equal cost components.
  Drain and rebase before a window approaches 2^31 doc-steps.

* Closed-form **expected-cost trajectory** — the prefix integral of the
  write law (``chunk_law_np`` split across tier widths) plus the
  survivor law's expected occupancy ``E[occ_t(s)] = width_t(s) ·
  min(1, K/s)``, priced by the stream's stacked ``NTierCostModel``
  cw/cs vectors. Logmem tenants (no deletions — occupancy ≡ cumulative
  writes) switch the storage law to the chunk-aware expected per-tier
  writes, and every test threshold is widened by ``law_slack`` × the
  expected cost mass, mirroring the drift detector.

* ``CostMonitor`` — the alert channel: a host-side sequential test on
  the *cost-weighted* write residual (Bernstein bound with per-stream
  increment cap ``max_t cw_t``; whole-window + CUSUM-equivalent
  positive/negative excursions, exactly ``ResidualMonitor``'s state
  machine), plus SRE-style multi-window **budget burn-rate** alerts:
  realized spend over a (long, short) chunk-window pair exceeding
  ``threshold × budget_factor ×`` the planned spend on *both* windows,
  gated by the same Bernstein margin so the combined null
  false-positive rate stays ≤ alpha (property-tested). Alerts can union
  into the re-plan trigger exactly like ``residual_trigger``.

Device-ledger scope: tiers are attributed by each doc's *static*
position tier against the stream's current boundary vector (the leaf is
updated by the host after a re-plan — no recompiles). Migration-cascade
streams lift residents above the static tier; their hop accounting
stays in the host ``FleetMeter`` (``mig_reads``/``mig_writes``), and the
reconciliation guarantees below are stated for non-cascade streams.
"""
from __future__ import annotations

import math
from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .residuals import chunk_law_np


# ---------------------------------------------------------------------------
# device ledger
# ---------------------------------------------------------------------------

class CostState(NamedTuple):
    """Per-bucket device cost ledger (rows = the bucket's streams, padded
    to the shard multiple; pad rows carry +inf bounds and never count).

    ``bounds`` holds the *ceiled* boundary vector in f32: doc ids are
    integers, so ``id >= ceil(b)`` ⟺ ``id >= b``, and ceiled edges are
    exactly representable in f32 (up to 2^24) — the device tier
    attribution is bit-equal to the host meter's f64 comparison."""

    bounds: jax.Array  # (Mb, B) f32 — ceiled boundaries, +inf padded
    writes: jax.Array  # (Mb, T) i32 — admits priced cw at the write tier
    deletes: jax.Array  # (Mb, T) i32 — evictions per (current static) tier
    resident_steps: jax.Array  # (Mb, T) i32 — Σ occupancy × chunk docs


def init_bucket(pad_m: int, boundaries: np.ndarray,
                n_tiers: int) -> CostState:
    """Fresh ledger for one bucket: ``boundaries`` is the meter's
    (m_true, B) f64 block for the bucket's rows; rows past it are
    shard padding (+inf bounds — inert)."""
    b = np.asarray(boundaries, np.float64)
    bounds = np.full((pad_m, b.shape[1]), np.inf, np.float32)
    bounds[: b.shape[0]] = np.ceil(b).astype(np.float32)
    return CostState(
        bounds=jnp.asarray(bounds),
        writes=jnp.zeros((pad_m, n_tiers), jnp.int32),
        deletes=jnp.zeros((pad_m, n_tiers), jnp.int32),
        resident_steps=jnp.zeros((pad_m, n_tiers), jnp.int32))


def set_bucket_bounds(cs: CostState, row: int, bounds_row) -> CostState:
    """Host-side boundary swap after a re-plan: one row of the bounds
    leaf is replaced (ceiled, +inf padded) — a device scatter, no
    recompile (the leaf's shape is unchanged)."""
    b = np.full(cs.bounds.shape[1], np.inf, np.float32)
    vec = np.asarray(bounds_row, np.float64).reshape(-1)
    b[: vec.shape[0]] = np.ceil(vec).astype(np.float32)
    return cs._replace(bounds=cs.bounds.at[row].set(jnp.asarray(b)))


def _tier_of(ids, bounds):
    """(Mb, W) static tier = number of boundaries <= id (ids are
    integer positions; bounds are ceiled, see ``CostState``)."""
    return (ids[:, :, None].astype(jnp.float32)
            >= bounds[:, None, :]).sum(-1).astype(jnp.int32)


def _per_tier(tiers, mask, n_tiers: int):
    """(Mb, T) i32 masked per-tier counts (static small-T loop — T is a
    trace-time constant, so this unrolls into T masked reductions)."""
    return jnp.stack([jnp.sum(mask & (tiers == t), axis=1, dtype=jnp.int32)
                      for t in range(n_tiers)], axis=1)


def accumulate_exact(cs: CostState, batch_ids, wrote, evicted_ids,
                     state_ids) -> CostState:
    """Fold one exact-backend bucket step into the ledger (pure; traced
    inside the jitted step). Occupancy is recomputed from the post-step
    reservoir ids, so ``resident_steps`` accrues occupancy × the chunk's
    live docs — the right-Riemann storage integral, exact vs the
    simulator's per-doc rental at chunk width 1."""
    t = cs.writes.shape[1]
    live = batch_ids >= 0
    dw = _per_tier(_tier_of(batch_ids, cs.bounds), wrote & live, t)
    dd = _per_tier(_tier_of(evicted_ids, cs.bounds), evicted_ids >= 0, t)
    occ = _per_tier(_tier_of(state_ids, cs.bounds), state_ids >= 0, t)
    docs = live.sum(axis=1, dtype=jnp.int32)
    return cs._replace(writes=cs.writes + dw, deletes=cs.deletes + dd,
                       resident_steps=cs.resident_steps
                       + occ * docs[:, None])


def accumulate_logmem(cs: CostState, batch_ids, wrote) -> CostState:
    """Logmem-bucket step: no ids stored and nothing deletes, so
    occupancy ≡ cumulative writes per tier and the storage integral
    accrues the post-step cumulative write counts."""
    t = cs.writes.shape[1]
    live = batch_ids >= 0
    dw = _per_tier(_tier_of(batch_ids, cs.bounds), wrote & live, t)
    writes = cs.writes + dw
    docs = live.sum(axis=1, dtype=jnp.int32)
    return cs._replace(writes=writes,
                       resident_steps=cs.resident_steps
                       + writes * docs[:, None])


# ---------------------------------------------------------------------------
# host pricing (f64, at drain time only)
# ---------------------------------------------------------------------------

def stream_pricing(engine) -> dict:
    """Stacked per-stream pricing vectors from the fleet's cost models:
    ``cw``/``cr`` (M, T) per-doc write/read cost per tier,
    ``step_rate`` (M, T) rental per doc-step (storage rate × the
    stream's window-months-per-doc slot), ``reads_per_window`` (M,) and
    ``n_docs`` (M,). Streams without a cost model price to zero — their
    ledgers still count, but every cost channel is inert."""
    from repro.core.costs import TwoTierCostModel
    m, t = engine.m, engine.meter.n_tiers
    cw = np.zeros((m, t), np.float64)
    cr = np.zeros((m, t), np.float64)
    step_rate = np.zeros((m, t), np.float64)
    rpw = np.zeros(m, np.float64)
    n_docs = np.zeros(m, np.int64)
    has_model = np.zeros(m, bool)
    for row in range(m):
        cm = engine._model_of_row.get(row)
        if cm is None:
            continue
        nt = cm.as_ntier() if isinstance(cm, TwoTierCostModel) else cm
        d = min(nt.t, t)
        cw[row, :d] = nt.cw[:d]
        cr[row, :d] = nt.cr[:d]
        wl = nt.workload
        slot = wl.window_months / wl.n_docs
        step_rate[row, :d] = nt.storage_per_doc_month[:d] * slot
        rpw[row] = wl.reads_per_window
        n_docs[row] = wl.n_docs
        has_model[row] = True
    return {"cw": cw, "cr": cr, "step_rate": step_rate,
            "reads_per_window": rpw, "n_docs": n_docs,
            "has_model": has_model}


def device_counts(engine) -> dict:
    """Drain the per-bucket device ledgers into global (M, T) int64
    arrays (the only sync point — one transfer per leaf per bucket;
    shard padding sliced back off)."""
    t, m = engine.meter.n_tiers, engine.m
    out = {name: np.zeros((m, t), np.int64)
           for name in ("writes", "deletes", "resident_steps")}
    for bi, b in enumerate(engine.buckets):
        cs = engine._cost_states[bi]
        rows = engine._global_rows[bi]
        for name in out:
            out[name][rows] = np.asarray(getattr(cs, name))[: b.m]
    return out


def realized_costs(engine) -> dict:
    """Price the device ledger + the meter's host-side hop counters into
    per-stream realized cost components (the ``SimResult`` convention:
    writes @ cw, final reads @ cr × reads_per_window, doc-steps × the
    per-step rental rate, migration/relocation hops priced
    ``cr_src + cw_dst``)."""
    p = engine._pricing
    dev = device_counts(engine)
    meter = engine.meter
    writes = (dev["writes"] * p["cw"]).sum(1)
    reads = (meter.reads * p["cr"]).sum(1) * p["reads_per_window"]
    storage = (dev["resident_steps"] * p["step_rate"]).sum(1)
    migration = ((meter.mig_reads + meter.reloc_reads) * p["cr"]).sum(1) \
        + ((meter.mig_writes + meter.reloc_writes) * p["cw"]).sum(1)
    return {"writes": writes, "reads": reads, "storage": storage,
            "migration": migration,
            "total": writes + reads + storage + migration,
            "device": dev}


def cost_summary(engine) -> dict:
    """Per-stream realized / planned / regret arrays (the regret meter).

    ``planned`` is the monitor's chunk-aware expected write + storage
    trajectory at the current position, plus the expected final-read
    cost once the stream's reads are metered (finalize). ``regret`` is
    realized − planned; relocation/migration bills count against
    realized only (the plan assumes no mid-window moves)."""
    real = realized_costs(engine)
    mon = engine._cost_monitor
    p = engine._pricing
    meter = engine.meter
    planned = mon.planned_total.copy()
    finalized = meter.reads.sum(1) > 0
    if finalized.any():
        n = np.maximum(p["n_docs"].astype(np.float64), 1.0)
        widths = interval_tier_widths(meter.boundaries,
                                      np.zeros(engine.m), n)
        exp_reads = widths / n[:, None] * meter.ks[:, None]
        planned = planned + np.where(
            finalized,
            (exp_reads * p["cr"]).sum(1) * p["reads_per_window"], 0.0)
    return {**real, "planned": planned,
            "regret": real["total"] - planned}


def snapshot(engine) -> dict:
    """The engine's ``obs_snapshot`` cost section: fleet-level priced
    components, the regret meter, the raw device counter totals, and the
    alert channel state. Deterministic scalars only — bit-identical
    sharded vs unsharded (integer device counts are row-independent)."""
    summ = cost_summary(engine)
    dev = summ["device"]
    out = {
        "realized": {k: float(summ[k].sum())
                     for k in ("writes", "reads", "storage", "migration",
                               "total")},
        "planned_total": float(summ["planned"].sum()),
        "regret": {"fleet": float(summ["regret"].sum()),
                   "max": float(summ["regret"].max()) if engine.m else 0.0},
        "device": {name: int(arr.sum()) for name, arr in dev.items()},
    }
    if engine._cost_monitor is not None:
        out["alerts"] = engine._cost_monitor.snapshot()
    return out


# ---------------------------------------------------------------------------
# the closed-form expected-cost laws
# ---------------------------------------------------------------------------

def interval_tier_widths(bounds, a, b) -> np.ndarray:
    """(M, T) integer counts of doc ids in [a, b) falling in each static
    tier of the (M, B) boundary vectors (+inf padded): tier edges are
    the ceiled boundaries, so this is exact for integer positions."""
    bounds = np.asarray(bounds, np.float64)
    m = bounds.shape[0]
    a = np.broadcast_to(np.asarray(a, np.float64), (m,))
    b = np.broadcast_to(np.asarray(b, np.float64), (m,))
    e = np.ceil(bounds)
    lo = np.concatenate([np.zeros((m, 1)), e], axis=1)
    hi = np.concatenate([e, np.full((m, 1), np.inf)], axis=1)
    return np.clip(np.minimum(hi, b[:, None]) - np.maximum(lo, a[:, None]),
                   0.0, None)


def expected_occupancy(bounds, k, s) -> np.ndarray:
    """(M, T) expected exact-backend occupancy after ``s`` docs: every
    one of the first s docs survives w.p. min(1, K/s) (uniform ranks),
    so E[occ_t(s)] = width_t(0, s) · min(1, K/s) — the survivor law the
    planner's storage integral is built on."""
    k = np.asarray(k, np.float64)
    s = np.asarray(s, np.float64)
    frac = np.minimum(1.0, k / np.maximum(s, 1.0))
    return interval_tier_widths(bounds, 0.0, s) * frac[:, None]


def bernstein_threshold_weighted(var, a_const, cmax) -> np.ndarray:
    """Deviation bound for sums of increments bounded by ``cmax`` (the
    per-stream max per-doc write cost): the unit-bounded Bernstein bound
    of ``residuals.bernstein_threshold_np`` scaled to the cap."""
    var = np.asarray(var, np.float64)
    cmax = np.asarray(cmax, np.float64)
    ac = a_const * cmax
    return ac / 3.0 + np.sqrt(ac * ac / 9.0 + 2.0 * a_const * var)


def expected_cost_trajectory(bounds, n: int, k: int, cw, step_rate,
                             chunk: int = 1, logmem: bool = False
                             ) -> np.ndarray:
    """(C,) planned cumulative write + storage cost for ONE stream after
    each width-``chunk`` ingest step — the closed-form trajectory the
    monitor tests realized spend against (final-read cost lands at
    finalize and is excluded here). ``logmem`` switches the storage law
    to cumulative expected writes (nothing deletes)."""
    bounds = np.asarray(bounds, np.float64).reshape(1, -1)
    cw = np.asarray(cw, np.float64)
    step_rate = np.asarray(step_rate, np.float64)
    edges = np.arange(0, n + chunk, chunk, dtype=np.float64)
    edges[-1] = min(edges[-1], float(n))
    exp_writes = np.zeros(bounds.shape[1] + 1, np.float64)
    total = 0.0
    out = []
    for a, b in zip(edges[:-1], edges[1:]):
        mean, _ = chunk_law_np(np.array([a]), np.array([b]), np.array([k]))
        w = interval_tier_widths(bounds, a, b)[0]
        frac = w / max(b - a, 1.0)
        exp_writes = exp_writes + float(mean[0]) * frac
        occ = (exp_writes if logmem
               else expected_occupancy(bounds, [k], [b])[0])
        total += float(mean[0]) * float(frac @ cw) \
            + float(occ @ step_rate) * (b - a)
        out.append(total)
    return np.asarray(out)


# ---------------------------------------------------------------------------
# the alert channel: cost residuals + budget burn rate
# ---------------------------------------------------------------------------

class CostMonitor:
    """Sequential concentration-bound test on the cost-weighted write
    residual series, plus multi-window budget burn-rate alerts.

    Fed one meter drain per chunk (``update(observed, writes_per_tier,
    doc_steps)`` — cumulative counters, like ``ResidualMonitor``).
    Maintains per stream the cost-weighted write deviation with
    ``ResidualMonitor``'s exact anchor machinery (whole-window +
    positive/negative excursions ≡ CUSUM), with Bernstein thresholds
    scaled to the per-stream increment cap ``max_t cw_t`` and widened by
    ``law_slack`` × the expected cost mass for approximate backends.

    The burn channel keeps a rolling per-chunk spend history; a
    ``(long, short, threshold)`` window pair alerts when realized spend
    exceeds ``threshold × budget_factor × planned`` on BOTH windows AND
    the window's write-cost deviation clears its own Bernstein gate —
    the gate keeps the null false-positive rate of the whole channel
    ≤ alpha (the ratio test alone would fire on planned≈0 noise).

    The total alpha is split uniformly across the 3 + n_pairs channels
    (each threshold exponent ``log(2 · channels · max_checks / alpha)``).
    """

    def __init__(self, ks, boundaries, cw, step_rate, *,
                 alpha: float = 0.01, max_checks: int = 1024,
                 law_slack=None, logmem=None, budget_factor: float = 1.2,
                 burn_windows: Tuple = ((8, 2, 1.5), (32, 8, 1.2))):
        self.k = np.asarray(ks, np.float64)
        m = self.k.shape[0]
        self.bounds = np.array(boundaries, np.float64)
        t = self.bounds.shape[1] + 1
        self.cw = np.asarray(cw, np.float64).reshape(m, t)
        self.step_rate = np.asarray(step_rate, np.float64).reshape(m, t)
        self.cmax = self.cw.max(axis=1)
        self.alpha = float(alpha)
        self.max_checks = int(max_checks)
        self.law_slack = (np.zeros(m, np.float64) if law_slack is None
                          else np.broadcast_to(
                              np.asarray(law_slack, np.float64), (m,)).copy())
        self.logmem = (np.zeros(m, bool) if logmem is None
                       else np.asarray(logmem, bool))
        self.budget_factor = float(budget_factor)
        self.burn_windows = tuple((int(l), int(s), float(r))
                                  for l, s, r in burn_windows)
        channels = 3 + len(self.burn_windows)
        self.a_const = math.log(2.0 * channels * self.max_checks
                                / self.alpha)
        self._hist_len = max([l for l, _, _ in self.burn_windows],
                             default=0)
        # sequential-test state (ResidualMonitor's machine, cost units)
        self.seen = np.zeros(m, np.float64)
        self.writes_pt = np.zeros((m, t), np.float64)
        self.doc_steps_pt = np.zeros((m, t), np.float64)
        self.exp_writes_pt = np.zeros((m, t), np.float64)
        self.dev = np.zeros(m, np.float64)
        self.var = np.zeros(m, np.float64)
        self.min_dev = np.zeros(m, np.float64)
        self.var_at_min = np.zeros(m, np.float64)
        self.max_dev = np.zeros(m, np.float64)
        self.var_at_max = np.zeros(m, np.float64)
        self.exp_since = np.zeros(m, np.float64)
        self.exp_at_min = np.zeros(m, np.float64)
        self.exp_at_max = np.zeros(m, np.float64)
        self.checks = np.zeros(m, np.int64)
        self.steps = 0
        self.alerted = np.zeros(m, bool)
        self.burn_alerted = np.zeros(m, bool)
        self.first_alert_step = np.full(m, -1, np.int64)
        self.first_alert_seen = np.full(m, -1, np.int64)
        self.first_burn_seen = np.full(m, -1, np.int64)
        # tier-outage grace: burn alerts are gated off per stream until
        # this monitor step — a forced evacuation's relocation spend is
        # not tenant overspend
        self.burn_suppressed_until = np.zeros(m, np.int64)
        # whole-run totals (never reset): the regret meter's plan side
        self.realized_total = np.zeros(m, np.float64)
        self.planned_total = np.zeros(m, np.float64)
        self.realized_wcost = np.zeros(m, np.float64)
        self.exp_wcost_total = np.zeros(m, np.float64)
        self.var_total = np.zeros(m, np.float64)
        # rolling per-chunk spend history for the burn windows
        self._hist: List[Tuple[np.ndarray, ...]] = []

    @property
    def m(self) -> int:
        return self.k.shape[0]

    def _extra(self):
        over = np.maximum(self.checks.astype(np.float64) / self.max_checks,
                          1.0)
        return 2.0 * np.log(over)

    def set_bounds(self, row: int, new_bounds) -> None:
        """Swap one stream's boundary vector after an applied re-plan:
        the planned trajectory follows the new placement from the next
        chunk on (residents were relocated, so the survivor law's
        uniform-position argument still prices expected occupancy)."""
        vec = np.asarray(new_bounds, np.float64).reshape(-1)
        self.bounds[row, :] = np.inf
        self.bounds[row, : vec.shape[0]] = vec
        # re-split the accumulated expected writes across the new tiers:
        # the total expected mass is placement-independent, only its
        # tier attribution moves (matching the relocated residents)
        tot = self.exp_writes_pt[row].sum()
        seen = max(self.seen[row], 1.0)
        w = interval_tier_widths(self.bounds[row: row + 1], 0.0, seen)[0]
        self.exp_writes_pt[row] = tot * w / max(w.sum(), 1.0)

    def update(self, observed, writes_per_tier, doc_steps
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Fold one chunk boundary's meter drain (cumulative counters).
        Returns (newly cost-alerted, newly burn-alerted) (M,) masks."""
        b = np.asarray(observed, np.float64)
        w_pt = np.asarray(writes_per_tier, np.float64)
        ds_pt = np.asarray(doc_steps, np.float64)
        active = b > self.seen
        dw = w_pt - self.writes_pt
        dsteps = ds_pt - self.doc_steps_pt
        mean, var_c = chunk_law_np(self.seen, b, self.k)
        width = np.maximum(b - self.seen, 0.0)
        wfrac = interval_tier_widths(self.bounds, self.seen, b) \
            / np.maximum(width, 1.0)[:, None]
        avg_cw = (wfrac * self.cw).sum(1)
        avg_cw2 = (wfrac * self.cw * self.cw).sum(1)
        exp_wcost = np.where(active, mean * avg_cw, 0.0)
        var_cost = np.where(active, var_c * avg_cw2, 0.0)
        real_wcost = np.where(active, (dw * self.cw).sum(1), 0.0)
        d = real_wcost - exp_wcost
        self.exp_writes_pt += np.where(active, mean, 0.0)[:, None] * wfrac
        # storage: realized doc-steps vs the survivor law's expectation
        # at the chunk end (right-Riemann — the device's own accrual)
        occ = np.where(self.logmem[:, None], self.exp_writes_pt,
                       expected_occupancy(self.bounds, self.k, b))
        plan_store = np.where(active,
                              (occ * self.step_rate).sum(1) * width, 0.0)
        real_store = np.where(active,
                              (dsteps * self.step_rate).sum(1), 0.0)
        real_inc = real_wcost + real_store
        plan_inc = exp_wcost + plan_store
        self.realized_total += real_inc
        self.planned_total += plan_inc
        self.realized_wcost += real_wcost
        self.exp_wcost_total += exp_wcost
        self.var_total += var_cost
        self.dev += d
        self.var += var_cost
        self.exp_since += exp_wcost
        self.checks += active
        self.steps += 1
        self._hist.append((real_inc, plan_inc, d.copy(), var_cost,
                           exp_wcost))
        if self._hist_len and len(self._hist) > self._hist_len:
            self._hist.pop(0)
        extra = self._extra()
        a = self.a_const + extra
        whole = np.abs(self.dev) > bernstein_threshold_weighted(
            self.var, a, self.cmax) + self.law_slack * self.exp_since
        pos = (self.dev - self.min_dev) > bernstein_threshold_weighted(
            self.var - self.var_at_min, a, self.cmax) \
            + self.law_slack * (self.exp_since - self.exp_at_min)
        neg = (self.max_dev - self.dev) > bernstein_threshold_weighted(
            self.var - self.var_at_max, a, self.cmax) \
            + self.law_slack * (self.exp_since - self.exp_at_max)
        hit = active & (whole | pos | neg)
        newly = hit & ~self.alerted
        first = newly & (self.first_alert_step < 0)
        self.first_alert_step[first] = self.steps
        self.first_alert_seen[first] = b[first].astype(np.int64)
        self.alerted |= hit
        # the burn channel: both-window overspend + its Bernstein gate
        burn_hit = np.zeros(self.m, bool)
        budget = self.budget_factor
        for long_w, short_w, ratio in self.burn_windows:
            if not self._hist:
                continue
            rl, pl, dl, vl, el = (np.sum([h[i] for h in self._hist[-long_w:]],
                                         axis=0) for i in range(5))
            rs = np.sum([h[0] for h in self._hist[-short_w:]], axis=0)
            ps = np.sum([h[1] for h in self._hist[-short_w:]], axis=0)
            breach = (pl > 0.0) & (rl > ratio * budget * pl) \
                & (rs > ratio * budget * ps)
            gate = dl > bernstein_threshold_weighted(vl, a, self.cmax) \
                + self.law_slack * el
            burn_hit |= active & breach & gate
        # outage-aware gating: rows inside an evacuation grace window
        # never raise burn (the expected-cost trajectory was credited
        # with the forced relocation bill via ``add_planned``)
        burn_hit &= self.steps > self.burn_suppressed_until
        newly_burn = burn_hit & ~self.burn_alerted
        fb = newly_burn & (self.first_burn_seen < 0)
        self.first_burn_seen[fb] = b[fb].astype(np.int64)
        self.burn_alerted |= burn_hit
        # advance the anchors after testing (dev_0 = 0 is a valid anchor)
        lower = self.dev < self.min_dev
        self.min_dev = np.where(lower, self.dev, self.min_dev)
        self.var_at_min = np.where(lower, self.var, self.var_at_min)
        self.exp_at_min = np.where(lower, self.exp_since, self.exp_at_min)
        higher = self.dev > self.max_dev
        self.max_dev = np.where(higher, self.dev, self.max_dev)
        self.var_at_max = np.where(higher, self.var, self.var_at_max)
        self.exp_at_max = np.where(higher, self.exp_since, self.exp_at_max)
        self.seen = np.where(active, b, self.seen)
        self.writes_pt = w_pt.copy()
        self.doc_steps_pt = ds_pt.copy()
        return newly, newly_burn

    def scores(self) -> np.ndarray:
        """(M,) max test statistic over its threshold (≥ 1 ⇒ alert)."""
        a = self.a_const + self._extra()
        whole = np.abs(self.dev) / np.maximum(
            bernstein_threshold_weighted(self.var, a, self.cmax)
            + self.law_slack * self.exp_since, 1e-12)
        pos = (self.dev - self.min_dev) / np.maximum(
            bernstein_threshold_weighted(self.var - self.var_at_min, a,
                                         self.cmax)
            + self.law_slack * (self.exp_since - self.exp_at_min), 1e-12)
        neg = (self.max_dev - self.dev) / np.maximum(
            bernstein_threshold_weighted(self.var - self.var_at_max, a,
                                         self.cmax)
            + self.law_slack * (self.exp_since - self.exp_at_max), 1e-12)
        return np.maximum(whole, np.maximum(pos, neg))

    def burn_ratio(self) -> np.ndarray:
        """(M,) realized / planned spend over the longest burn window
        (1.0 where the window's plan is zero) — the dashboard gauge."""
        out = np.ones(self.m, np.float64)
        if not self._hist or not self.burn_windows:
            return out
        long_w = max(l for l, _, _ in self.burn_windows)
        rl = np.sum([h[0] for h in self._hist[-long_w:]], axis=0)
        pl = np.sum([h[1] for h in self._hist[-long_w:]], axis=0)
        good = pl > 0.0
        out[good] = rl[good] / pl[good]
        return out

    def reset_where(self, mask) -> None:
        """Restart the masked streams' evidence (after a re-plan);
        cumulative baselines and the regret totals are preserved."""
        mask = np.asarray(mask, bool)
        for name in ("dev", "var", "min_dev", "var_at_min", "max_dev",
                     "var_at_max", "exp_since", "exp_at_min", "exp_at_max"):
            getattr(self, name)[mask] = 0.0
        for h in self._hist:
            for arr in h:
                arr[mask] = 0.0
        self.checks[mask] = 0
        self.alerted[mask] = False
        self.burn_alerted[mask] = False

    def suppress_burn(self, mask, steps: int) -> None:
        """Gate the masked streams' burn channel off for ``steps`` more
        monitor steps (chunks).  Used by tier-outage evacuation: the
        forced relocation's spend spike is operator-induced, not tenant
        overspend, so the burn alert must not fire on it."""
        mask = np.asarray(mask, bool)
        until = self.steps + int(steps)
        self.burn_suppressed_until[mask] = np.maximum(
            self.burn_suppressed_until[mask], until)

    def add_planned(self, row: int, amount: float) -> None:
        """Credit one stream's planned trajectory with an out-of-law
        bill (e.g. a forced evacuation's relocation cost) so ``regret``
        does not blame the placement for an operator decision."""
        self.planned_total[row] += float(amount)

    # ---- crash-consistent checkpointing ---------------------------------
    _STATE_ARRAYS = (
        "bounds", "seen", "writes_pt", "doc_steps_pt", "exp_writes_pt",
        "dev", "var", "min_dev", "var_at_min", "max_dev", "var_at_max",
        "exp_since", "exp_at_min", "exp_at_max", "checks", "alerted",
        "burn_alerted", "first_alert_step", "first_alert_seen",
        "first_burn_seen", "burn_suppressed_until", "realized_total",
        "planned_total", "realized_wcost", "exp_wcost_total", "var_total")

    def state_dict(self) -> dict:
        """All mutable state as fresh numpy copies (safe to hand to an
        async checkpoint writer while the engine keeps mutating)."""
        out = {name: getattr(self, name).copy()
               for name in self._STATE_ARRAYS}
        out["steps"] = np.int64(self.steps)
        out["hist"] = (np.stack([np.stack(h) for h in self._hist])
                       if self._hist
                       else np.zeros((0, 5, self.m), np.float64))
        return out

    def load_state(self, state: dict) -> None:
        for name in self._STATE_ARRAYS:
            ref = getattr(self, name)
            arr = np.asarray(state[name]).astype(ref.dtype).reshape(
                ref.shape)
            setattr(self, name, arr.copy())
        self.steps = int(state["steps"])
        hist = np.asarray(state["hist"], np.float64)
        self._hist = [tuple(hist[i, j].copy() for j in range(5))
                      for i in range(hist.shape[0])]

    def cost_z(self) -> dict:
        """(M,) whole-run realized vs expected cost-weighted writes with
        the z-score under the cost-weighted variance budget (law_slack
        folded in as a systematic term, like ``ResidualMonitor``)."""
        resid = self.realized_wcost - self.exp_wcost_total
        var_eff = self.var_total \
            + (self.law_slack * self.exp_wcost_total) ** 2
        z = resid / np.sqrt(np.maximum(var_eff, 1e-24))
        z = np.where(self.seen > 0, z, 0.0)
        return {"realized": self.realized_wcost.copy(),
                "expected": self.exp_wcost_total.copy(),
                "residual": resid, "var": var_eff, "z": z}

    def regret(self) -> np.ndarray:
        """(M,) realized − planned write+storage spend so far."""
        return self.realized_total - self.planned_total

    def snapshot(self) -> dict:
        sc = self.scores()
        br = self.burn_ratio()
        return {"cost_alerted": int(self.alerted.sum()),
                "burn_alerted": int(self.burn_alerted.sum()),
                "max_score": float(sc.max()) if sc.size else 0.0,
                "max_burn_ratio": float(br.max()) if br.size else 0.0,
                "checks": int(self.checks.max()) if self.m else 0,
                "steps": self.steps}
