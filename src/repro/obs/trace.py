"""Structured event tracing: span timeline + JSONL event log.

``Tracer`` records two event kinds into one append-only timeline:

* ``span`` — a named interval (``with tracer.span("ingest"): ...``) with
  wall-clock start and duration, optionally mirrored into the JAX
  profiler timeline as a ``jax.profiler.TraceAnnotation`` so host spans
  line up with device activity in a captured trace;
* ``event`` — a named point record (``tracer.emit("replan", ...)``).

Every record is one JSON object with a stable schema (``SCHEMA``):

    {"v": 1, "kind": "span"|"event", "name": str, "ts": unix seconds,
     "dur_s": float|null, "attrs": {...}}

Records are kept in a bounded in-memory deque (``max_events``, oldest
dropped) and, when ``path`` is given, streamed to a JSONL file as they
complete — a long-running fleet never grows host memory without bound
and never loses the on-disk log to a crash. Attribute values must be
JSON-serializable scalars/lists; numpy scalars are coerced.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Iterable, Optional

SCHEMA = "repro.obs/v1"

try:  # profiler annotations are optional — the tracer works without jax
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax is present in this repo
    _TraceAnnotation = None


def _coerce(v):
    """Make attribute values JSON-clean (numpy scalars/arrays included)."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_coerce(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _coerce(x) for k, x in v.items()}
    item = getattr(v, "item", None)  # numpy / jax scalars
    if item is not None and getattr(v, "ndim", 1) == 0:
        return item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return tolist()
    return repr(v)


class Tracer:
    """Span/event recorder with an optional streaming JSONL sink."""

    def __init__(self, path: Optional[str] = None, *,
                 annotations: bool = False, max_events: int = 100_000):
        self.events: deque = deque(maxlen=max_events)
        self.annotations = annotations and _TraceAnnotation is not None
        self._path = path
        self._fh = None
        self.dropped = 0  # records evicted from the in-memory deque

    # ---- recording ------------------------------------------------------

    def _record(self, rec: dict) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(rec)
        if self._path is not None:
            if self._fh is None:
                self._fh = open(self._path, "a")
            self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
            self._fh.flush()

    def emit(self, name: str, **attrs) -> dict:
        """Record one point event."""
        rec = {"v": 1, "kind": "event", "name": str(name),
               "ts": time.time(), "dur_s": None,
               "attrs": {k: _coerce(v) for k, v in attrs.items()}}
        self._record(rec)
        return rec

    @contextmanager
    def span(self, name: str, **attrs):
        """Record one named interval; yields the (mutable) attrs dict so
        the body can attach results before the span closes."""
        out = {k: _coerce(v) for k, v in attrs.items()}
        ts = time.time()
        t0 = time.perf_counter()
        if self.annotations:
            with _TraceAnnotation(str(name)):
                yield out
        else:
            yield out
        self._record({"v": 1, "kind": "span", "name": str(name), "ts": ts,
                      "dur_s": time.perf_counter() - t0,
                      "attrs": {k: _coerce(v) for k, v in out.items()}})

    # ---- draining -------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> Iterable[dict]:
        return [e for e in self.events
                if e["kind"] == "span" and (name is None or e["name"] == name)]

    def write(self, path: str) -> str:
        """Dump the in-memory timeline to a JSONL file (one record per
        line; independent of the streaming sink)."""
        with open(path, "w") as f:
            for rec in self.events:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return path

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
