"""jit-cache introspection: hit/miss and compile-time counters for the
repo's hot jitted entry points.

A compile storm — e.g. a constraint signature or padded batch size that
varies call-to-call — is invisible from outside: the program just runs
slow. ``JitProbe.track`` wraps a call to a ``jax.jit``-ed function and
reads the function's compiled-signature cache size before and after
(``PjitFunction._cache_size``): growth means this call compiled. The
probe counts calls / hits / misses, accumulates the wall time of missing
calls (compile + first run — the cost the caller actually felt), and
keeps per-key tallies when the caller labels the static signature (the
planner passes ``(t, constrained, capfin, slo_any)``).

Probes live in a module-level registry so instrumentation at the call
site (``core.shp_jax``, ``online.replan_device``) and snapshotting at
the export layer need no shared plumbing. Counters are lock-protected —
the fleet planner chunks solves across a thread pool.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

_REGISTRY: Dict[str, "JitProbe"] = {}
_REGISTRY_LOCK = threading.Lock()


def _cache_size(fn) -> Optional[int]:
    """Compiled-signature count of a jitted callable, or None when the
    runtime doesn't expose it (the probe then degrades to call counts)."""
    getter = getattr(fn, "_cache_size", None)
    if getter is None:
        return None
    try:
        return int(getter())
    except Exception:
        return None


class JitProbe:
    """Hit/miss/compile-time counters for one jitted function."""

    def __init__(self, name: str):
        self.name = name
        self.calls = 0
        self.hits = 0
        self.misses = 0
        self.compile_s = 0.0  # wall time of missing calls (compile + run)
        self.cache_size = 0  # compiled signatures at last tracked call
        self.by_key: Dict[str, Dict[str, float]] = {}
        self._lock = threading.Lock()

    def track(self, fn, *args, key=None, **kwargs):
        """Call ``fn(*args, **kwargs)`` and account whether it compiled.
        ``key`` labels the static signature (per-key tallies)."""
        before = _cache_size(fn)
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        dt = time.perf_counter() - t0
        after = _cache_size(fn)
        missed = (after is not None and before is not None
                  and after > before)
        with self._lock:
            self.calls += 1
            if missed:
                self.misses += 1
                self.compile_s += dt
            else:
                self.hits += 1
            if after is not None:
                self.cache_size = after
            if key is not None:
                kd = self.by_key.setdefault(
                    str(key), {"calls": 0, "misses": 0, "compile_s": 0.0})
                kd["calls"] += 1
                if missed:
                    kd["misses"] += 1
                    kd["compile_s"] += dt
        return out

    def snapshot(self) -> dict:
        with self._lock:
            return {"calls": self.calls, "hits": self.hits,
                    "misses": self.misses,
                    "compile_s": round(self.compile_s, 6),
                    "cache_size": self.cache_size,
                    "by_key": {k: dict(v) for k, v in self.by_key.items()}}

    def reset(self) -> None:
        with self._lock:
            self.calls = self.hits = self.misses = 0
            self.compile_s = 0.0
            self.by_key.clear()


def mesh_key(mesh) -> tuple:
    """Canonical mesh-shape component for probe keys: ``((axis, size),
    ...)`` or ``()`` without a mesh. The sharded planner entry points
    (``shp_jax.plan_sharded``, ``replan_device.solve_sharded``) prefix
    their ``(T, constraint-signature)`` keys with this, so compile
    storms stay attributable per mesh shape."""
    if mesh is None:
        return ()
    return tuple((str(a), int(s))
                 for a, s in zip(mesh.axis_names, mesh.devices.shape))


def probe(name: str) -> JitProbe:
    """Get-or-create the named probe."""
    with _REGISTRY_LOCK:
        p = _REGISTRY.get(name)
        if p is None:
            p = _REGISTRY[name] = JitProbe(name)
        return p


def snapshot() -> Dict[str, dict]:
    """{probe name: counters} for every registered probe."""
    with _REGISTRY_LOCK:
        probes = list(_REGISTRY.values())
    return {p.name: p.snapshot() for p in probes}


def reset() -> None:
    """Zero every probe's counters (the probes stay registered)."""
    with _REGISTRY_LOCK:
        probes = list(_REGISTRY.values())
    for p in probes:
        p.reset()
