"""Metric exposition: Prometheus-style text format and JSON snapshots.

``to_prometheus`` flattens a nested snapshot dict (the output of
``Observability.snapshot``) into the text exposition format: numeric
leaves become ``<prefix>_<path> value`` samples, lists of numbers become
one sample per element with an ``idx`` label (per-tier gauges), and
non-numeric leaves are dropped. Names are sanitized to the metric
charset. Leaves whose terminal path component names a monotone
transaction count are typed ``counter`` (scrapers can rate() them);
everything else stays a ``gauge``, and known metrics carry ``# HELP``
text. The output is deterministic (sorted) so snapshots diff cleanly in
CI artifacts.
"""
from __future__ import annotations

import json
import re
from typing import Iterable, List, Optional, Tuple

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")

# terminal path components that only ever accumulate (device/meter
# transaction counts, event totals): exposed as Prometheus counters
_COUNTER_LEAVES = frozenset({
    "docs", "admits", "evictions", "bar_candidates", "bar_passes",
    "chunks", "drift_fired", "observed", "writes", "reads", "deletes",
    "migrations", "relocations", "resident_steps", "recorded", "dropped",
    "checks", "steps", "hits", "misses", "compiles",
    "scores_quarantined", "chunks_ingested", "checkpoints_written",
    "redeliveries_dropped", "delivery_retries", "tier_outages",
})

# HELP text per terminal path component (kept to the metrics whose
# meaning is not obvious from the name alone)
_HELP = {
    "docs": "documents ingested (padding excluded)",
    "admits": "reservoir admissions (the SHP write law's realization)",
    "evictions": "reservoir evictions",
    "bar_candidates": "candidates tested against the entry bar",
    "bar_passes": "candidates that cleared the entry bar",
    "chunks": "jitted fleet steps executed",
    "drift_fired": "drift-detector firings folded into the device state",
    "observed": "documents observed by the host meter",
    "writes": "tier write transactions",
    "reads": "tier read transactions (final top-K)",
    "deletes": "tier delete transactions",
    "migrations": "documents cascaded across a boundary",
    "relocations": "residents re-tiered by online re-plans",
    "resident_steps": "doc-step storage rental integral (obs.costs)",
    "planned_total": "closed-form expected spend at the current position",
    "regret": "realized minus planned spend",
    "max_burn_ratio": "worst realized/planned spend over the long burn "
                      "window",
    "recorded": "events captured on the obs timeline",
    "dropped": "events dropped past max_events",
    "scores_quarantined": "non-finite scores swapped for pad slots "
                          "before the reservoir compare",
    "chunks_ingested": "chunk boundaries consumed (the ingest cursor)",
    "checkpoints_written": "fleet checkpoints committed (atomic renames)",
    "redeliveries_dropped": "duplicate chunk deliveries skipped by the "
                            "idempotent redelivery guard",
    "delivery_retries": "transient chunk-delivery failures retried",
    "tier_outages": "tier outage declarations (cumulative)",
}


def _clean(name: str) -> str:
    n = _NAME_RE.sub("_", name)
    return n if not n[:1].isdigit() else "_" + n


def _flatten(snap, path: Tuple[str, ...] = ()) -> Iterable[Tuple]:
    if isinstance(snap, dict):
        for k in sorted(snap):
            yield from _flatten(snap[k], path + (str(k),))
    elif isinstance(snap, bool):
        yield path, None, float(snap)
    elif isinstance(snap, (int, float)):
        yield path, None, float(snap)
    elif isinstance(snap, (list, tuple)):
        for i, v in enumerate(snap):
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                yield path, i, float(v)


def _leaf_kind(path: Tuple[str, ...]) -> Tuple[str, Optional[str]]:
    """(type, help) for a flattened path, keyed by its terminal
    component (the leaf name is the semantic unit; the prefix is just
    the engine/section nesting)."""
    leaf = path[-1] if path else ""
    kind = "counter" if leaf in _COUNTER_LEAVES else "gauge"
    return kind, _HELP.get(leaf)


def to_prometheus(snap: dict, prefix: str = "repro_obs") -> str:
    """Render a snapshot dict as Prometheus text exposition."""
    lines: List[str] = []
    seen_names = set()
    for path, idx, val in _flatten(snap):
        name = _clean("_".join((prefix,) + path))
        if name not in seen_names:
            seen_names.add(name)
            kind, help_text = _leaf_kind(path)
            if help_text is not None:
                lines.append(f"# HELP {name} {help_text}")
            lines.append(f"# TYPE {name} {kind}")
        label = f'{{idx="{idx}"}}' if idx is not None else ""
        sval = f"{val:.10g}" if val == val else "NaN"
        lines.append(f"{name}{label} {sval}")
    return "\n".join(lines) + "\n"


def write_snapshot(path: str, snap: dict) -> str:
    """Write a snapshot as deterministic JSON (sorted keys)."""
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True, default=_json_default)
        f.write("\n")
    return path


def _json_default(v):
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", 1) == 0:
        return item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return tolist()
    return repr(v)
