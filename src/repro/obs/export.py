"""Metric exposition: Prometheus-style text format and JSON snapshots.

``to_prometheus`` flattens a nested snapshot dict (the output of
``Observability.snapshot``) into the text exposition format: numeric
leaves become ``<prefix>_<path> value`` samples, lists of numbers become
one sample per element with an ``idx`` label (per-tier gauges), and
non-numeric leaves are dropped. Names are sanitized to the metric
charset. The output is deterministic (sorted) so snapshots diff cleanly
in CI artifacts.
"""
from __future__ import annotations

import json
import re
from typing import Iterable, List, Tuple

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _clean(name: str) -> str:
    n = _NAME_RE.sub("_", name)
    return n if not n[:1].isdigit() else "_" + n


def _flatten(snap, path: Tuple[str, ...] = ()) -> Iterable[Tuple]:
    if isinstance(snap, dict):
        for k in sorted(snap):
            yield from _flatten(snap[k], path + (str(k),))
    elif isinstance(snap, bool):
        yield path, None, float(snap)
    elif isinstance(snap, (int, float)):
        yield path, None, float(snap)
    elif isinstance(snap, (list, tuple)):
        for i, v in enumerate(snap):
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                yield path, i, float(v)


def to_prometheus(snap: dict, prefix: str = "repro_obs") -> str:
    """Render a snapshot dict as Prometheus text exposition."""
    lines: List[str] = []
    seen_names = set()
    for path, idx, val in _flatten(snap):
        name = _clean("_".join((prefix,) + path))
        if name not in seen_names:
            seen_names.add(name)
            lines.append(f"# TYPE {name} gauge")
        label = f'{{idx="{idx}"}}' if idx is not None else ""
        sval = f"{val:.10g}" if val == val else "NaN"
        lines.append(f"{name}{label} {sval}")
    return "\n".join(lines) + "\n"


def write_snapshot(path: str, snap: dict) -> str:
    """Write a snapshot as deterministic JSON (sorted keys)."""
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True, default=_json_default)
        f.write("\n")
    return path


def _json_default(v):
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", 1) == 0:
        return item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return tolist()
    return repr(v)
