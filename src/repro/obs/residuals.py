"""Model-referenced residual metrics: every fleet counter with a
closed-form law is exported as (realized, expected, normalized residual)
instead of a raw gauge.

The paper's point is that this workload class is a-priori predictable:
reservoir writes follow the batched write law
(``shp.expected_cum_writes_batched``, eq. 11/12), per-tier occupancy
follows the occupancy law (``core.constraints.peak_occupancy_arrays``),
and the final read latency is the width-weighted tier mean
(``core.constraints.expected_read_latency``). Residuals against those
laws turn monitoring into a statistically grounded early-warning
channel: a healthy stream's residuals hover near zero, and a drifted
stream's z-score crosses a concentration bound *before* operators could
tell anything from the raw counters.

``ResidualMonitor`` is the alert channel: a host-side sequential test on
the per-chunk write residual series drained from the ``FleetMeter``. It
mirrors the device detector's statistics (``online.drift``) — cumulative
deviation with a Bernstein/Bonferroni bound, plus positive/negative
excursions re-anchored at the running extremum (``dev − min_s dev_s`` is
exactly the CUSUM recursion ``max(0, S + d)``) — but is built purely
from meter counters, spends its whole ``alpha`` on the same three-way
split, and never resets until a re-plan consumes its evidence. With the
same check cadence its excursion statistic and threshold coincide with
the detector's CUSUM, so a residual alert fires at or before the CUSUM
detection, and the combined false-positive rate stays ≤ ``alpha``
(property-tested).
"""
from __future__ import annotations

import math

import numpy as np


# ---------------------------------------------------------------------------
# numpy forms of the laws (host-side: the monitor runs off-device)
# ---------------------------------------------------------------------------

def chunk_law_np(seen_before, seen_after, k):
    """(mean, var) of the null reservoir-entry count for a prefix
    extension a → b (numpy twin of ``online.drift.chunk_law``)."""
    a = np.asarray(seen_before, np.float64)
    b = np.asarray(seen_after, np.float64)
    kf = np.asarray(k, np.float64)
    w = b - a
    kc = np.minimum(b, kf)
    mean = np.where(b > 0, kc * w / np.maximum(b, 1.0), 0.0)
    frac = kc / np.maximum(b, 1.0)
    var = np.where(b > 1,
                   w * frac * (1.0 - frac) * (b - w)
                   / np.maximum(b - 1.0, 1.0), 0.0)
    return mean, var


def bernstein_threshold_np(var, a_const):
    """Deviation bound t with P(|Σ increments| > t) ≤ 2·exp(−a_const)."""
    var = np.asarray(var, np.float64)
    return a_const / 3.0 + np.sqrt(a_const * a_const / 9.0
                                   + 2.0 * a_const * var)


def expected_cum_writes_var_batched(i, k: int, batch: int = 1) -> np.ndarray:
    """Variance budget of the cumulative write law at position(s) ``i``:
    Σ_{j≤i} p_j(1−p_j) with p_j = min(1, K/batch_end(j)) — the
    independent-indicator budget; the true entry indicators are
    negatively associated, so concentration bounds built on it are
    conservative."""
    i = np.asarray(i, np.int64)
    if i.size == 0:
        return np.zeros(i.shape, np.float64)
    hi = int(i.max()) + 1
    j = np.arange(hi, dtype=np.float64)
    batch_end = (np.floor(j / batch) + 1.0) * batch
    p = np.minimum(1.0, float(k) / batch_end)
    cum = np.cumsum(p * (1.0 - p))
    return cum[np.minimum(i, hi - 1)]


# ---------------------------------------------------------------------------
# snapshot residuals (the exported metrics)
# ---------------------------------------------------------------------------

def write_residuals(meter, batch: int = 1) -> dict:
    """(M,) realized vs expected cumulative reservoir writes at each
    stream's current position, with the z-score under the law's variance
    budget. Streams that observed nothing report zeros."""
    expected = meter.expected_writes(batch=batch)
    realized = meter.writes.sum(1).astype(np.float64)
    var = np.zeros(meter.m, np.float64)
    seen = np.maximum(meter.observed, 1)
    for k in np.unique(meter.ks):
        sel = meter.ks == k
        var[sel] = expected_cum_writes_var_batched(seen[sel] - 1, int(k),
                                                   int(batch))
    var = np.where(meter.observed > 0, var, 0.0)
    resid = realized - expected
    z = resid / np.sqrt(np.maximum(var, 1e-12))
    z = np.where(meter.observed > 0, z, 0.0)
    return {"realized": realized, "expected": expected, "residual": resid,
            "var": var, "z": z}


def expected_tier_writes(bounds, n: int, k: int,
                         batch: int = 1) -> np.ndarray:
    """(T,) expected cumulative reservoir writes landing in each tier of
    a static placement after ``n`` docs: Λ(e_{t+1}) − Λ(e_t) with
    Λ(x) = Σ_{j≤x} min(1, K/j) (the write law, batched form when
    ``batch`` > 1) evaluated at the tier edges e = [0, ⌈b_1⌉, …, n].
    This is the occupancy law of a backend that never deletes
    (``streams.logmem`` — admitted docs stay in their write tier until
    window end), where occupancy ≡ cumulative writes."""
    from repro.core import shp
    b = np.asarray(bounds, np.float64)
    edges = np.clip(np.ceil(b), 0.0, float(n))
    edges = np.concatenate([[0.0], edges, [float(n)]])
    edges = np.maximum.accumulate(edges)
    cum = np.zeros(edges.shape[0], np.float64)
    pos = edges.astype(np.int64)
    nz = pos > 0
    if nz.any():
        cum[nz] = shp.expected_cum_writes_batched(pos[nz] - 1, int(k),
                                                  int(batch))
    return np.diff(cum)


def occupancy_residuals(meter, batch: int = 1) -> dict:
    """(M, T) realized occupancy high-water marks vs the occupancy law's
    peak evaluated on the prefix seen so far (tier edges clipped to the
    current position). Cascade (migrating) streams are masked NaN — the
    law models static placements. The normalized residual is relative to
    ``max(expected, 1)`` (occupancy peaks are deterministic O(K) scale,
    not variance-budgeted sums).

    Logmem rows (``meter.logmem``) never report deletes, so their
    occupancy is cumulative writes and the reference law switches to the
    per-tier write-law deltas (``expected_tier_writes``, evaluated at
    ``batch`` — pass the ingest width for a chunk-faithful reference) —
    the residual stays near zero for an undrifted logmem tenant even
    though its storage grows past K."""
    from repro.core.constraints import peak_occupancy_arrays
    bounds = meter.boundaries
    n = np.maximum(meter.observed.astype(np.float64), 1.0)
    k = meter.ks.astype(np.float64)
    expected = peak_occupancy_arrays(
        np.minimum(bounds, n[:, None]), n, k,
        np.zeros(meter.m, bool))
    logmem = np.asarray(getattr(meter, "logmem", np.zeros(meter.m, bool)),
                        bool)
    for i in np.flatnonzero(logmem & (meter.observed > 0)):
        expected[i] = expected_tier_writes(bounds[i],
                                           int(meter.observed[i]),
                                           int(meter.ks[i]), batch)
    realized = meter.occupancy_hwm.astype(np.float64)
    resid = realized - expected
    norm = resid / np.maximum(expected, 1.0)
    mask = meter.migrate | (meter.observed == 0)
    expected = np.where(mask[:, None], np.nan, expected)
    resid = np.where(mask[:, None], np.nan, resid)
    norm = np.where(mask[:, None], np.nan, norm)
    return {"realized": realized, "expected": expected, "residual": resid,
            "normalized": norm}


def latency_residuals(meter, latencies) -> dict:
    """(M,) realized mean per-survivor read latency vs the planner's
    expected read latency under the stream's boundaries. Zero reads (no
    finalize yet) reports NaN expected/residual."""
    from repro.core.constraints import expected_read_latency
    lat = np.broadcast_to(np.asarray(latencies, np.float64),
                          (meter.m, meter.n_tiers))
    realized = meter.read_latency(lat)
    n = np.maximum(meter.observed.astype(np.float64), 1.0)
    expected = np.array([
        expected_read_latency(np.minimum(meter.boundaries[i], n[i]),
                              n[i], lat[i], bool(meter.migrate[i]))
        for i in range(meter.m)])
    has_reads = meter.reads.sum(1) > 0
    expected = np.where(has_reads, expected, np.nan)
    resid = realized - expected
    norm = resid / np.maximum(np.abs(expected), 1e-12)
    return {"realized": realized, "expected": expected, "residual": resid,
            "normalized": norm}


# ---------------------------------------------------------------------------
# the alert channel
# ---------------------------------------------------------------------------

class ResidualMonitor:
    """Sequential concentration-bound test on the write-residual series.

    Fed one meter drain per chunk (``update(observed, cum_writes)``);
    maintains per stream the cumulative deviation, its variance budget,
    and running-extremum anchors whose excursions replicate the CUSUM
    recursion. ``alerted`` latches; ``reset_where`` restarts a stream's
    evidence after a re-plan consumed it (mirroring the detector).

    ``law_slack`` is the (M,) fractional admit-count tolerance of an
    approximate engine backend (``streams.logmem.law_slack`` — zero for
    exact rows): each test's threshold grows by slack × the expected
    mass accumulated since its anchor, exactly mirroring the device
    detector, so an undrifted logmem fleet keeps its null FPR ≤ alpha
    while genuine drift still clears the widened bound.
    """

    def __init__(self, ks, alpha: float = 0.01, max_checks: int = 1024,
                 law_slack=None):
        ks = np.asarray(ks, np.float64)
        m = ks.shape[0]
        self.k = ks
        self.alpha = float(alpha)
        self.max_checks = int(max_checks)
        self.law_slack = (np.zeros(m, np.float64) if law_slack is None
                          else np.broadcast_to(
                              np.asarray(law_slack, np.float64), (m,)).copy())
        # same three-way alpha split as DriftConfig: whole-window gets
        # alpha/2, each excursion side alpha/4 — exponents coincide
        self.a_whole = math.log(4.0 * self.max_checks / self.alpha)
        self.a_exc = math.log(4.0 * self.max_checks / self.alpha)
        self.seen = np.zeros(m, np.float64)
        self.writes = np.zeros(m, np.float64)  # last drained cumulative
        self.dev = np.zeros(m, np.float64)
        self.var = np.zeros(m, np.float64)
        self.min_dev = np.zeros(m, np.float64)  # running min (incl. dev_0=0)
        self.var_at_min = np.zeros(m, np.float64)
        self.max_dev = np.zeros(m, np.float64)
        self.var_at_max = np.zeros(m, np.float64)
        # expected mass since the last reset and at each anchor — the
        # slack terms scale with these (zero for exact rows)
        self.exp_since = np.zeros(m, np.float64)
        self.exp_at_min = np.zeros(m, np.float64)
        self.exp_at_max = np.zeros(m, np.float64)
        self.checks = np.zeros(m, np.int64)
        self.steps = 0  # monitor updates (global chunk index)
        self.alerted = np.zeros(m, bool)
        self.first_alert_step = np.full(m, -1, np.int64)
        self.first_alert_seen = np.full(m, -1, np.int64)
        # whole-run law totals (never reset): the snapshot's chunk-aware
        # expectation — the batched write law evaluated at the actual
        # ingest chunking, which the meter alone cannot reconstruct
        self.exp_total = np.zeros(m, np.float64)
        self.var_total = np.zeros(m, np.float64)

    @property
    def m(self) -> int:
        return self.k.shape[0]

    def _extra(self):
        """Decaying budget extension past max_checks (detector twin)."""
        over = np.maximum(self.checks.astype(np.float64) / self.max_checks,
                          1.0)
        return 2.0 * np.log(over)

    def update(self, observed, cum_writes) -> np.ndarray:
        """Fold one chunk boundary's meter drain: ``observed`` (M,) docs
        seen, ``cum_writes`` (M,) cumulative reservoir writes. Returns
        the (M,) newly-alerted mask."""
        b = np.asarray(observed, np.float64)
        w = np.asarray(cum_writes, np.float64)
        active = b > self.seen
        mean, var_c = chunk_law_np(self.seen, b, self.k)
        d = np.where(active, (w - self.writes) - mean, 0.0)
        var_c = np.where(active, var_c, 0.0)
        self.dev += d
        self.var += var_c
        exp_c = np.where(active, mean, 0.0)
        self.exp_total += exp_c
        self.exp_since += exp_c
        self.var_total += var_c
        self.checks += active
        self.steps += 1
        extra = self._extra()
        # excursion = deviation re-anchored at its running extremum: the
        # CUSUM recursion, with the variance spent since the anchor;
        # law_slack widens each threshold by the expected mass since
        # that anchor (approximate-backend tolerance, zero when exact)
        whole = np.abs(self.dev) > bernstein_threshold_np(
            self.var, self.a_whole + extra) \
            + self.law_slack * self.exp_since
        pos = (self.dev - self.min_dev) > bernstein_threshold_np(
            self.var - self.var_at_min, self.a_exc + extra) \
            + self.law_slack * (self.exp_since - self.exp_at_min)
        neg = (self.max_dev - self.dev) > bernstein_threshold_np(
            self.var - self.var_at_max, self.a_exc + extra) \
            + self.law_slack * (self.exp_since - self.exp_at_max)
        hit = active & (whole | pos | neg)
        newly = hit & ~self.alerted
        # first alert only: evidence resets (``reset_where``) let a stream
        # re-alert, but the detection latency record keeps the earliest
        first = newly & (self.first_alert_step < 0)
        self.first_alert_step[first] = self.steps
        self.first_alert_seen[first] = b[first].astype(np.int64)
        self.alerted |= hit
        # advance the anchors after testing (dev_0 = 0 is a valid anchor)
        lower = self.dev < self.min_dev
        self.min_dev = np.where(lower, self.dev, self.min_dev)
        self.var_at_min = np.where(lower, self.var, self.var_at_min)
        self.exp_at_min = np.where(lower, self.exp_since, self.exp_at_min)
        higher = self.dev > self.max_dev
        self.max_dev = np.where(higher, self.dev, self.max_dev)
        self.var_at_max = np.where(higher, self.var, self.var_at_max)
        self.exp_at_max = np.where(higher, self.exp_since, self.exp_at_max)
        self.seen = np.where(active, b, self.seen)
        self.writes = np.where(active, w, self.writes)
        return newly

    def scores(self) -> np.ndarray:
        """(M,) max test statistic over its threshold (≥ 1 ⇒ alert)."""
        extra = self._extra()
        whole = np.abs(self.dev) / np.maximum(
            bernstein_threshold_np(self.var, self.a_whole + extra)
            + self.law_slack * self.exp_since, 1e-9)
        pos = (self.dev - self.min_dev) / np.maximum(
            bernstein_threshold_np(self.var - self.var_at_min,
                                   self.a_exc + extra)
            + self.law_slack * (self.exp_since - self.exp_at_min), 1e-9)
        neg = (self.max_dev - self.dev) / np.maximum(
            bernstein_threshold_np(self.var - self.var_at_max,
                                   self.a_exc + extra)
            + self.law_slack * (self.exp_since - self.exp_at_max), 1e-9)
        return np.maximum(whole, np.maximum(pos, neg))

    def reset_where(self, mask) -> None:
        """Restart the masked streams' evidence (after a re-plan);
        ``seen``/``writes`` baselines are preserved."""
        mask = np.asarray(mask, bool)
        for name in ("dev", "var", "min_dev", "var_at_min", "max_dev",
                     "var_at_max", "exp_since", "exp_at_min", "exp_at_max"):
            arr = getattr(self, name)
            arr[mask] = 0.0
        self.checks[mask] = 0
        self.alerted[mask] = False

    # ---- crash-consistent checkpointing ---------------------------------

    _STATE_ARRAYS = (
        "seen", "writes", "dev", "var", "min_dev", "var_at_min",
        "max_dev", "var_at_max", "exp_since", "exp_at_min", "exp_at_max",
        "checks", "alerted", "first_alert_step", "first_alert_seen",
        "exp_total", "var_total")

    def state_dict(self) -> dict:
        """All mutable state as fresh numpy copies (safe to hand to an
        async checkpoint writer while the engine keeps updating)."""
        out = {name: getattr(self, name).copy()
               for name in self._STATE_ARRAYS}
        out["steps"] = np.int64(self.steps)
        return out

    def load_state(self, state: dict) -> None:
        for name in self._STATE_ARRAYS:
            ref = getattr(self, name)
            arr = np.asarray(state[name]).astype(ref.dtype).reshape(
                ref.shape)
            setattr(self, name, arr.copy())
        self.steps = int(state["steps"])

    def write_z(self) -> dict:
        """(M,) whole-run realized vs chunk-law expected cumulative
        writes with the z-score — the snapshot's exported residual
        (chunk-aware, unlike the batch-agnostic ``write_residuals``).
        Approximate-backend rows fold their systematic tolerance
        (law_slack × expected)² into the variance so their z stays O(1)
        when the backend tracks the law within its guarantee."""
        resid = self.writes - self.exp_total
        var_eff = self.var_total + (self.law_slack * self.exp_total) ** 2
        z = resid / np.sqrt(np.maximum(var_eff, 1e-12))
        z = np.where(self.seen > 0, z, 0.0)
        return {"realized": self.writes.copy(),
                "expected": self.exp_total.copy(), "residual": resid,
                "var": var_eff, "z": z}

    def snapshot(self) -> dict:
        sc = self.scores()
        return {"alerted": int(self.alerted.sum()),
                "max_score": float(sc.max()) if sc.size else 0.0,
                "checks": int(self.checks.max()) if self.m else 0,
                "steps": self.steps}
