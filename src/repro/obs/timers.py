"""The shared timing API: the two measurement disciplines the repo's
benchmarks hand-rolled, plus a lightweight span helper.

* ``time_jax`` — device-dispatch timing: one warm-up call to compile,
  then ``reps`` back-to-back dispatches with a single
  ``block_until_ready`` on the last result (the steady-state per-call
  latency of a jitted step; compile time excluded). Returns
  microseconds per call — the ``BENCH_*.json`` unit.
* ``time_best`` — host-call timing: best of ``repeats`` full wall-clock
  runs (the right discipline for host-side planners whose first call
  may compile — the best run is the steady state). Returns seconds.
* ``span`` — a ``perf_counter`` interval usable bare (returns an object
  whose ``.dur_s`` is set on exit) or recorded into a ``trace.Tracer``.

``benchmarks/streams_bench.py``, ``benchmarks/planner_bench.py`` and
``online.evaluate`` all measure through this module.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Optional


def time_jax(fn, *args, reps: int = 20, **kwargs) -> float:
    """Steady-state microseconds per call of a jitted callable."""
    import jax
    jax.block_until_ready(fn(*args, **kwargs))  # compile
    t0 = time.perf_counter_ns()
    out = None
    for _ in range(reps):
        out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    return (time.perf_counter_ns() - t0) / 1000.0 / reps


def time_best(fn, repeats: int = 3) -> float:
    """Best-of-``repeats`` wall seconds of a host call."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


class Span:
    """Result object of ``span`` — ``dur_s`` is valid after the block."""

    __slots__ = ("name", "dur_s")

    def __init__(self, name: str):
        self.name = name
        self.dur_s = 0.0


@contextmanager
def span(name: str, tracer=None, **attrs):
    """Time a block; mirrors into ``tracer`` (a ``trace.Tracer``) when
    one is given, so ad-hoc timing and the event timeline share records."""
    if tracer is not None:
        with tracer.span(name, **attrs):
            sp = Span(name)
            t0 = time.perf_counter()
            yield sp
            sp.dur_s = time.perf_counter() - t0
        return
    sp = Span(name)
    t0 = time.perf_counter()
    yield sp
    sp.dur_s = time.perf_counter() - t0


def maybe_span(tracer: Optional[object], name: str, **attrs):
    """``tracer.span(...)`` when a tracer is present, else a bare timed
    span — the call-site idiom for optionally-observed code paths."""
    return span(name, tracer=tracer, **attrs)
