# Model-referenced fleet telemetry (repro.obs): the paper's closed-form
# laws make every fleet counter predictable, so the observability layer
# exports residuals (realized − expected) instead of raw gauges.
#   metrics   — device-side MetricsState pytree carried through the
#               jitted engine step (zero extra host syncs; drained at
#               chunk boundaries)
#   residuals — realized vs closed-form expectation + z-scores for the
#               write/occupancy/latency laws; ResidualMonitor alert
#               channel (concentration-bound, fires at or before CUSUM)
#   costs     — device-side CostState ledger + closed-form expected-cost
#               trajectories, per-tenant regret, and budget burn-rate
#               alerts (CostMonitor)
#   trace     — span/event timeline with a stable JSONL schema and
#               jax.profiler TraceAnnotation integration
#   jits      — jit-cache hit/miss + compile-time probes (shp_jax,
#               replan_device)
#   timers    — the shared benchmark/evaluation timing API
#   export    — Prometheus text exposition + JSON snapshots
"""Fleet observability: configuration and the per-run facade.

``Observability`` is the object callers thread through the system::

    obs = Observability(ObsConfig(events_path="events.jsonl"))
    engine = StreamEngine(specs, obs=obs)
    ...
    snap = obs.snapshot()            # device metrics + residuals + jit
    print(export.to_prometheus(snap))
    obs.write(out_dir)               # metrics.json / metrics.prom / events

It owns the tracer (span timeline + JSONL sink) and gathers, on demand,
the engine's device counters, the meter's ledger aggregates, the
model-referenced residual metrics, and the process-wide jit-cache
probes. The engine never syncs the device counters except inside
``snapshot``.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import List, Optional

from . import export, jits, timers, trace  # noqa: F401
from .trace import Tracer  # noqa: F401


@dataclass(frozen=True)
class ObsConfig:
    """Static observability configuration.

    ``metrics``: carry the device ``MetricsState`` through the jitted
    step. ``residuals``: maintain the ``ResidualMonitor`` alert channel
    (per-chunk host update from the meter drain). ``residual_trigger``:
    feed residual alerts to the ``Replanner`` as an earlier trigger
    (requires the engine's ``replan=`` config; alerts then reset like
    detector evidence). ``costs``: carry the device ``CostState``
    ledger through the jitted step and maintain the ``CostMonitor``
    cost-residual / budget burn-rate alert channel (``obs.costs``).
    ``cost_trigger``: union cost/burn alerts into the re-plan trigger
    exactly like ``residual_trigger``. ``budget_factor``: overspend
    budget — burn alerts require realized > threshold × budget_factor ×
    planned on both windows of a ``burn_windows`` (long, short,
    threshold) pair. ``events_path``: stream the event log to this
    JSONL file. ``profiler_annotations``: mirror spans into the JAX
    profiler timeline. ``trace_ingest``: record a span per ingest chunk
    (point events for replan/admission/violations are always recorded).
    """

    metrics: bool = True
    residuals: bool = True
    residual_alpha: float = 0.01
    residual_max_checks: int = 1024
    residual_trigger: bool = False
    costs: bool = False
    cost_alpha: float = 0.01
    cost_max_checks: int = 1024
    cost_trigger: bool = False
    budget_factor: float = 1.2
    burn_windows: tuple = ((8, 2, 1.5), (32, 8, 1.2))
    events_path: Optional[str] = None
    profiler_annotations: bool = False
    trace_ingest: bool = True
    max_events: int = 100_000


class Observability:
    """Per-run facade: tracer + snapshot/exposition over attached engines."""

    def __init__(self, config: Optional[ObsConfig] = None):
        self.config = config or ObsConfig()
        self.tracer = Tracer(self.config.events_path,
                             annotations=self.config.profiler_annotations,
                             max_events=self.config.max_events)
        self._engines: List[object] = []

    def attach(self, engine) -> None:
        """Called by ``StreamEngine.__init__`` when passed ``obs=``."""
        self._engines.append(engine)

    # ---- snapshots ------------------------------------------------------

    def snapshot(self) -> dict:
        """One nested dict of everything: per-engine device counters,
        meter aggregates, residual metrics, and the process-wide
        jit-cache probe counters."""
        out: dict = {"jit": jits.snapshot(),
                     "events": {"recorded": len(self.tracer.events),
                                "dropped": self.tracer.dropped}}
        engines = {}
        for i, eng in enumerate(self._engines):
            engines[f"engine{i}"] = eng.obs_snapshot()
        out["engines"] = engines
        return out

    def prometheus(self, prefix: str = "repro_obs") -> str:
        return export.to_prometheus(self.snapshot(), prefix=prefix)

    def write(self, out_dir: str) -> dict:
        """Write ``metrics.json``, ``metrics.prom`` and (if not already
        streaming) ``events.jsonl`` under ``out_dir``; returns paths."""
        os.makedirs(out_dir, exist_ok=True)
        snap = self.snapshot()
        paths = {
            "metrics": export.write_snapshot(
                os.path.join(out_dir, "metrics.json"), snap),
        }
        prom = os.path.join(out_dir, "metrics.prom")
        with open(prom, "w") as f:
            f.write(export.to_prometheus(snap))
        paths["prometheus"] = prom
        if self.config.events_path is None:
            paths["events"] = self.tracer.write(
                os.path.join(out_dir, "events.jsonl"))
        else:
            paths["events"] = self.config.events_path
        return paths


def __getattr__(name: str):
    # residuals/metrics import repro.core/jax laws — lazy so importing
    # repro.obs.jits from the planner stack cannot cycle back through it
    if name in ("residuals", "metrics", "costs", "http"):
        import importlib
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(name)
