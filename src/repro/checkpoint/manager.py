"""Fault-tolerant checkpointing with SHP-tiered retention.

* Atomic: leaves as .npy + manifest.json written to a temp dir, renamed on
  completion — a crash mid-save never corrupts the latest checkpoint.
* Async: saves run on a worker thread from host copies (device_get first),
  so the train loop blocks only for the device→host transfer.
* Retention = the paper's workflow: checkpoints are a scored stream
  (validation metric = interestingness), we keep the top-K plus the most
  recent L; tier placement (hot/local vs cold/remote directory) follows the
  SHP policy over checkpoint index.
* Topology-independent: leaves are full (unsharded) arrays, so a restart
  may use a different mesh or dp size.
"""
from __future__ import annotations

import heapq
import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np

from repro.core.placement import Policy, TIER_A


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, cold_directory: Optional[str] = None,
                 keep_latest: int = 2, keep_best: int = 3,
                 policy: Optional[Policy] = None, metric_mode: str = "min"):
        self.dir = directory
        self.cold_dir = cold_directory or os.path.join(directory, "cold")
        os.makedirs(self.dir, exist_ok=True)
        os.makedirs(self.cold_dir, exist_ok=True)
        self.keep_latest = keep_latest
        self.keep_best = keep_best
        self.policy = policy
        self.metric_mode = metric_mode
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._save_index = 0

    # ---------------- paths ----------------
    def _name(self, step: int) -> str:
        return f"ckpt_{step:08d}"

    def _tier_dir(self, save_index: int) -> str:
        if self.policy is None:
            return self.dir
        return self.dir if self.policy.tier_of(save_index) == TIER_A \
            else self.cold_dir

    def _all_ckpts(self):
        out = []
        for root in {self.dir, self.cold_dir}:
            if not os.path.isdir(root):
                continue
            for d in os.listdir(root):
                p = os.path.join(root, d)
                mf = os.path.join(p, "manifest.json")
                if d.startswith("ckpt_") and os.path.exists(mf):
                    try:
                        out.append((json.load(open(mf)), p))
                    except Exception:
                        continue
        return sorted(out, key=lambda t: t[0]["step"])

    # ---------------- save ----------------
    def save(self, state: Any, step: int, metric: float = float("nan"),
             blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        idx = self._save_index
        self._save_index += 1

        def _write():
            target_root = self._tier_dir(idx)
            final = os.path.join(target_root, self._name(step))
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, leaf in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
            manifest = {"step": step, "metric": float(metric),
                        "n_leaves": len(host_leaves), "save_index": idx,
                        "time": time.time()}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if blocking:
            _write()
        else:
            self._pending = self._pool.submit(_write)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # ---------------- retention ----------------
    def _retain(self):
        ckpts = self._all_ckpts()
        if not ckpts:
            return
        latest = {m["step"] for m, _ in ckpts[-self.keep_latest:]}
        sign = 1.0 if self.metric_mode == "max" else -1.0
        scored = [(sign * m.get("metric", float("nan")), m["step"])
                  for m, _ in ckpts if np.isfinite(m.get("metric", np.nan))]
        best = {s for _, s in heapq.nlargest(self.keep_best, scored)}
        for m, path in ckpts:
            if m["step"] not in latest and m["step"] not in best:
                shutil.rmtree(path, ignore_errors=True)

    # ---------------- restore ----------------
    def latest_step(self) -> Optional[int]:
        ckpts = self._all_ckpts()
        return ckpts[-1][0]["step"] if ckpts else None

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        ckpts = self._all_ckpts()
        if not ckpts:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        if step is None:
            manifest, path = ckpts[-1]
        else:
            match = [(m, p) for m, p in ckpts if m["step"] == step]
            if not match:
                raise FileNotFoundError(f"no checkpoint for step {step}")
            manifest, path = match[0]
        leaves, treedef = _flatten(template)
        loaded = []
        for i, ref in enumerate(leaves):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            loaded.append(arr)
        return jax.tree_util.tree_unflatten(treedef, loaded)
