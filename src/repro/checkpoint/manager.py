"""Fault-tolerant checkpointing with SHP-tiered retention.

* Atomic: leaves as .npy + manifest.json written to a temp dir, renamed on
  completion — a crash mid-save never corrupts the latest checkpoint.
* Async: saves run on a worker thread from host copies (device_get first),
  so the train loop blocks only for the device→host transfer.
* Retention = the paper's workflow: checkpoints are a scored stream
  (validation metric = interestingness), we keep the top-K plus the most
  recent L; tier placement (hot/local vs cold/remote directory) follows the
  SHP policy over checkpoint index.
* Topology-independent: leaves are full (unsharded) arrays, so a restart
  may use a different mesh or dp size.
* Crash-consistent (format v2): every leaf carries a sha256 checksum in
  the manifest, verified on restore, and every save stamps a monotone
  *generation* counter that survives restarts — a resumed run keeps
  incrementing where the killed run stopped, so checkpoint lineage is
  totally ordered even across crash/restore cycles.
"""
from __future__ import annotations

import hashlib
import heapq
import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.placement import Policy, TIER_A

FORMAT_VERSION = 2


class CheckpointCorruptError(RuntimeError):
    """A stored leaf fails its manifest checksum."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, *, cold_directory: Optional[str] = None,
                 keep_latest: int = 2, keep_best: int = 3,
                 policy: Optional[Policy] = None, metric_mode: str = "min"):
        self.dir = directory
        self.cold_dir = cold_directory or os.path.join(directory, "cold")
        os.makedirs(self.dir, exist_ok=True)
        os.makedirs(self.cold_dir, exist_ok=True)
        self.keep_latest = keep_latest
        self.keep_best = keep_best
        self.policy = policy
        self.metric_mode = metric_mode
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._save_index = 0
        # resume the generation lineage of whatever already lives on disk
        ckpts = self._all_ckpts()
        self._generation = max(
            (m.get("generation", 0) for m, _ in ckpts), default=0)

    # ---------------- paths ----------------
    def _name(self, step: int) -> str:
        return f"ckpt_{step:08d}"

    def _tier_dir(self, save_index: int) -> str:
        if self.policy is None:
            return self.dir
        return self.dir if self.policy.tier_of(save_index) == TIER_A \
            else self.cold_dir

    def _all_ckpts(self):
        out = []
        for root in {self.dir, self.cold_dir}:
            if not os.path.isdir(root):
                continue
            for d in os.listdir(root):
                p = os.path.join(root, d)
                mf = os.path.join(p, "manifest.json")
                if d.startswith("ckpt_") and os.path.exists(mf):
                    try:
                        out.append((json.load(open(mf)), p))
                    except Exception:
                        continue
        return sorted(out, key=lambda t: t[0]["step"])

    # ---------------- save ----------------
    def save(self, state: Any, step: int, metric: float = float("nan"),
             blocking: bool = False,
             extra: Optional[Dict[str, Any]] = None) -> int:
        """Snapshot ``state`` at ``step``; returns the generation stamped
        on the checkpoint. ``extra`` (JSON-able dict) rides in the
        manifest — host-side scalars/events that are not pytree leaves.
        Non-blocking saves copy to host here and write on the worker
        thread, so compute on the next chunk overlaps the I/O."""
        self.wait()
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        idx = self._save_index
        self._save_index += 1
        self._generation += 1
        gen = self._generation

        def _write():
            target_root = self._tier_dir(idx)
            final = os.path.join(target_root, self._name(step))
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            checksums = []
            for i, leaf in enumerate(host_leaves):
                p = os.path.join(tmp, f"leaf_{i:05d}.npy")
                np.save(p, leaf)
                checksums.append(_file_sha256(p))
            manifest = {"format": FORMAT_VERSION, "step": step,
                        "metric": float(metric),
                        "n_leaves": len(host_leaves), "save_index": idx,
                        "generation": gen, "checksums": checksums,
                        "time": time.time()}
            if extra is not None:
                manifest["extra"] = extra
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if blocking:
            _write()
        else:
            self._pending = self._pool.submit(_write)
        return gen

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # ---------------- retention ----------------
    def _retain(self):
        ckpts = self._all_ckpts()
        if not ckpts:
            return
        latest = {m["step"] for m, _ in ckpts[-self.keep_latest:]}
        sign = 1.0 if self.metric_mode == "max" else -1.0
        scored = [(sign * m.get("metric", float("nan")), m["step"])
                  for m, _ in ckpts if np.isfinite(m.get("metric", np.nan))]
        best = {s for _, s in heapq.nlargest(self.keep_best, scored)}
        for m, path in ckpts:
            if m["step"] not in latest and m["step"] not in best:
                shutil.rmtree(path, ignore_errors=True)

    # ---------------- restore ----------------
    def latest_step(self) -> Optional[int]:
        ckpts = self._all_ckpts()
        return ckpts[-1][0]["step"] if ckpts else None

    def generation(self) -> int:
        """Generation stamped on the most recent save (0 = none yet)."""
        return self._generation

    def _lookup(self, step: Optional[int]):
        ckpts = self._all_ckpts()
        if not ckpts:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        if step is None:
            return ckpts[-1]
        match = [(m, p) for m, p in ckpts if m["step"] == step]
        if not match:
            raise FileNotFoundError(f"no checkpoint for step {step}")
        return match[0]

    def manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The manifest dict of a stored checkpoint (latest by default)."""
        return self._lookup(step)[0]

    def restore(self, template: Any, step: Optional[int] = None,
                verify: bool = True) -> Any:
        manifest, path = self._lookup(step)
        leaves, treedef = _flatten(template)
        if manifest.get("n_leaves") != len(leaves):
            raise ValueError(
                f"checkpoint at {path} has {manifest.get('n_leaves')} "
                f"leaves; template has {len(leaves)}")
        checksums = manifest.get("checksums")
        loaded = []
        for i, ref in enumerate(leaves):
            p = os.path.join(path, f"leaf_{i:05d}.npy")
            if verify and checksums is not None:
                digest = _file_sha256(p)
                if digest != checksums[i]:
                    raise CheckpointCorruptError(
                        f"leaf {i} of {path}: sha256 {digest[:12]}… != "
                        f"manifest {checksums[i][:12]}…")
            arr = np.load(p)
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            loaded.append(arr)
        return jax.tree_util.tree_unflatten(treedef, loaded)
