"""Layer blocks: (mixer → residual) → (optional cross-attn) → (FFN → residual),
pre-norm. One ``block_forward`` serves train / prefill / decode; the cache
entry pytree shape determines behaviour.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import ffn as ffn_mod
from . import ssm as ssm_mod
from .common import apply_norm, norm_params


def block_params(key, spec, cfg, dtype):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {}
    ln = cfg.use_layernorm
    if spec.mixer in ("attn", "attn_ssm_parallel"):
        p["attn"] = (attn.mla_params(ks[0], cfg, dtype) if cfg.use_mla
                     else attn.gqa_params(ks[0], cfg, dtype))
        p["norm_attn"] = norm_params(cfg.d_model, ln, dtype)
    if spec.mixer in ("ssm", "attn_ssm_parallel"):
        p["ssm"] = ssm_mod.ssm_params(ks[1], cfg, dtype)
        p["norm_ssm"] = norm_params(cfg.d_model, ln, dtype)
    if spec.cross_attn:
        p["cross"] = attn.cross_params(ks[2], cfg, dtype)
        p["norm_cross"] = norm_params(cfg.d_model, ln, dtype)
    if spec.ffn == "dense":
        p["ffn"] = ffn_mod.dense_params(ks[3], cfg.d_model, cfg.d_ff,
                                        cfg.ffn_act, cfg.ffn_bias, dtype)
        p["norm_ffn"] = norm_params(cfg.d_model, ln, dtype)
    elif spec.ffn == "moe":
        p["ffn"] = ffn_mod.moe_params(ks[4], cfg, dtype)
        p["norm_ffn"] = norm_params(cfg.d_model, ln, dtype)
    return p


def init_layer_cache(spec, cfg, batch, kv_len, dtype, enc_len=0):
    """Cache entry for ONE layer of this spec (stacked over the group)."""
    c: dict[str, Any] = {}
    if spec.mixer in ("attn", "attn_ssm_parallel"):
        if cfg.use_mla:
            c["mla"] = attn.init_mla_cache(batch, kv_len, cfg, dtype)
        else:
            c["kv"] = attn.init_kv_cache(batch, kv_len, cfg.n_kv_heads,
                                         cfg.head_dim, dtype)
    if spec.mixer in ("ssm", "attn_ssm_parallel"):
        c["ssm"] = ssm_mod.init_ssm_state(batch, cfg, dtype)
    if spec.cross_attn:
        c["cross_k"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
    return c


def _mixer(p, spec, cfg, x, positions, cache, window):
    """Returns (mixer_out, new_cache)."""
    new_cache = dict(cache) if cache is not None else None
    outs = []
    if spec.mixer in ("attn", "attn_ssm_parallel"):
        h = apply_norm(p["norm_attn"], x, cfg.norm_eps, cfg.use_layernorm)
        if cfg.use_mla:
            if cache is None:
                out = attn.mla_forward_expanded(p["attn"], h, positions, cfg,
                                                causal=spec.causal)
            elif x.shape[1] == 1:
                out, mla = attn.mla_forward_absorbed(p["attn"], h, positions,
                                                     cfg, cache["mla"],
                                                     causal=spec.causal)
                new_cache["mla"] = mla
            else:
                # prefill: expanded attention + latent cache write
                ckv, kr = attn._mla_latent(p["attn"], h, positions, cfg)
                mla = cache["mla"]
                w = mla.ckv.shape[1]
                bidx = jnp.arange(h.shape[0])[:, None]
                slots = positions % w
                new_cache["mla"] = attn.MLACache(
                    ckv=mla.ckv.at[bidx, slots].set(ckv),
                    krope=mla.krope.at[bidx, slots].set(kr),
                    pos=mla.pos.at[bidx, slots].set(positions.astype(jnp.int32)))
                out = attn.mla_forward_expanded(p["attn"], h, positions, cfg,
                                                causal=spec.causal)
        else:
            out, kv = attn.gqa_forward(p["attn"], h, positions, cfg,
                                       causal=spec.causal, window=window,
                                       cache=None if cache is None else cache["kv"])
            if cache is not None:
                new_cache["kv"] = kv
        outs.append(out)
    if spec.mixer in ("ssm", "attn_ssm_parallel"):
        h = apply_norm(p["norm_ssm"], x, cfg.norm_eps, cfg.use_layernorm)
        state = cache["ssm"] if cache is not None else None
        out, st = ssm_mod.ssm_forward(p["ssm"], h, cfg, state,
                                      return_state=cache is not None)
        if cache is not None:
            new_cache["ssm"] = st
        outs.append(out)
    if not outs:
        return jnp.zeros_like(x), new_cache
    mix = outs[0] if len(outs) == 1 else 0.5 * (outs[0] + outs[1])
    return mix, new_cache


def _sp(cfg, x):
    """Sequence-parallel residual constraint (identity off-mesh / disabled)."""
    if not cfg.seq_parallel or x.ndim != 3:
        return x
    from repro.parallel import ctx as pctx
    return pctx.shard(x, pctx.BATCH, pctx.MODEL, None)


def block_forward(p, spec, cfg, x, positions, cache=None, window=0,
                  enc_out=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = _sp(cfg, x)
    mix, new_cache = _mixer(p, spec, cfg, x, positions, cache, window)
    x = x + _sp(cfg, mix)
    if spec.cross_attn:
        h = apply_norm(p["norm_cross"], x, cfg.norm_eps, cfg.use_layernorm)
        if cache is not None and "cross_k" in cache:
            k, v = cache["cross_k"], cache["cross_v"]
            enc_pos = jnp.broadcast_to(jnp.arange(k.shape[1], dtype=jnp.int32),
                                       (k.shape[0], k.shape[1]))
            q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
            if "bq" in p["cross"]:
                q = q + p["cross"]["bq"]
            out = attn.attend(q, k, v, positions, enc_pos, causal=False, window=0)
            co = jnp.einsum("bshk,hkd->bsd", out, p["cross"]["wo"])
            if "bo" in p["cross"]:
                co = co + p["cross"]["bo"]
        else:
            co, _ = attn.gqa_forward(p["cross"], h, positions, cfg,
                                     causal=False, window=0, kv_source=enc_out)
        x = x + co
    if spec.ffn == "dense":
        h = apply_norm(p["norm_ffn"], x, cfg.norm_eps, cfg.use_layernorm)
        x = x + _sp(cfg, ffn_mod.dense_forward(p["ffn"], h, cfg.ffn_act))
    elif spec.ffn == "moe":
        h = apply_norm(p["norm_ffn"], x, cfg.norm_eps, cfg.use_layernorm)
        y, aux = ffn_mod.moe_forward(p["ffn"], h, cfg)
        x = x + _sp(cfg, y)
    return x, new_cache, aux
