"""Feed-forward layers: dense (GLU / plain) and Mixture-of-Experts with
GShard-style capacity dispatch (grouped one-hot einsums — the GSPMD-friendly
formulation; groups shard over the data axes, experts over the model axis).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import ACTIVATIONS, init_dense
from repro.parallel import ctx as pctx


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def dense_params(key, d_model, d_ff, act: str, bias: bool, dtype):
    kind, _ = ACTIVATIONS[act]
    ks = jax.random.split(key, 3)
    p = {"w_up": init_dense(ks[0], (d_model, d_ff), (0,), dtype),
         "w_down": init_dense(ks[1], (d_ff, d_model), (0,), dtype)}
    if kind == "glu":
        p["w_gate"] = init_dense(ks[2], (d_model, d_ff), (0,), dtype)
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def dense_forward(p, x, act: str):
    kind, fn = ACTIVATIONS[act]
    h = jnp.einsum("...d,df->...f", x, p["w_up"])
    if h.ndim == 3:
        h = pctx.shard(h, pctx.BATCH, None, pctx.MODEL)
    if "b_up" in p:
        h = h + p["b_up"]
    if kind == "glu":
        h = fn(jnp.einsum("...d,df->...f", x, p["w_gate"])) * h
    else:
        h = fn(h)
    y = jnp.einsum("...f,fd->...d", h, p["w_down"])
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_params(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    kind, _ = ACTIVATIONS["silu_glu"]
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], (d, e), (0,), jnp.float32),
        "w_up": init_dense(ks[1], (e, d, f), (1,), dtype),
        "w_gate": init_dense(ks[2], (e, d, f), (1,), dtype),
        "w_down": init_dense(ks[3], (e, f, d), (1,), dtype),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = dense_params(ks[4], d, cfg.n_shared_experts * f,
                                   "silu_glu", False, dtype)
    return p


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    return max(int(np.ceil(group * top_k * factor / n_experts)), top_k)


def moe_dispatch(router_logits, top_k: int, capacity: int, renorm: bool):
    """router_logits: (G, g, E) → combine (G, g, E, C) float, dispatch = mask.

    Position-in-expert assigned choice-major then token-major (GShard).
    """
    g_, s_, e_ = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)  # (G, g, k)
    if renorm:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    combine = jnp.zeros((g_, s_, e_, capacity), jnp.float32)
    counts = jnp.zeros((g_, e_), jnp.int32)
    for j in range(top_k):
        m = jax.nn.one_hot(experts[:, :, j], e_, dtype=jnp.int32)  # (G,g,E)
        pos = counts[:, None, :] + jnp.cumsum(m, axis=1) - m  # (G,g,E)
        keep = (m > 0) & (pos < capacity)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                                dtype=jnp.float32)  # (G,g,E,C); overflow→0
        combine = combine + pos_oh * (m * keep).astype(jnp.float32)[..., None] \
            * gate_vals[:, :, j][..., None, None]
        counts = counts + m.sum(axis=1)
    return combine


def moe_forward(p, x, cfg):
    """x: (B, S, D) or (T, D). Grouped capacity routing; group size
    cfg.moe_group_size caps the per-chip dispatch footprint (DESIGN §5)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    t = x2.shape[0]
    g = min(cfg.moe_group_size, t)
    n_groups = t // g
    rem = t - n_groups * g
    if rem:  # pad to a whole number of groups (padding tokens route but are dropped)
        x2 = jnp.pad(x2, ((0, g - rem), (0, 0)))
        n_groups += 1
    xg = x2.reshape(n_groups, g, d)
    logits = jnp.einsum("Ggd,de->Gge", xg.astype(jnp.float32), p["router"])
    cap = _capacity(g, cfg.top_k_experts, cfg.n_experts, cfg.capacity_factor)
    combine = moe_dispatch(logits, cfg.top_k_experts, cap, cfg.router_scale)
    dispatch = (combine > 0).astype(x.dtype)
    xe = pctx.shard(jnp.einsum("Ggd,GgEc->GEcd", xg, dispatch),
                    pctx.BATCH, pctx.MODEL, None, None)
    h = jax.nn.silu(jnp.einsum("GEcd,Edf->GEcf", xe, p["w_gate"])) \
        * jnp.einsum("GEcd,Edf->GEcf", xe, p["w_up"])
    ye = jnp.einsum("GEcf,Efd->GEcd", h, p["w_down"])
    y = jnp.einsum("GEcd,GgEc->Ggd", ye, combine.astype(x.dtype))
    y = y.reshape(-1, d)[:t].reshape(orig_shape)
    if "shared" in p:
        y = y + dense_forward(p["shared"], x, "silu_glu")
    aux = load_balance_loss(logits, cfg.top_k_experts)
    return y, aux


def load_balance_loss(router_logits, top_k: int):
    """Switch/GShard auxiliary loss: E · Σ_e f_e · p_e."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    e = probs.shape[-1]
    _, experts = jax.lax.top_k(probs, top_k)
    assign = jax.nn.one_hot(experts, e).sum(-2)  # (..., E)
    f = assign.mean(axis=tuple(range(assign.ndim - 1))) / top_k
    pbar = probs.mean(axis=tuple(range(probs.ndim - 1)))
    return e * jnp.sum(f * pbar)
