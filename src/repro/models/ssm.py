"""Mamba-2 SSD (state-space duality) mixer — chunked training scan and O(1)
decode (arXiv:2405.21060), in pure JAX.

Training uses the block-decomposition: within a chunk the output is a masked
(causal, decay-weighted) quadratic form; across chunks a short ``lax.scan``
carries the (H, hd, N) state. Decode is the diagonal recurrence
``s ← a·s + dt·B⊗x`` per step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import init_dense, rmsnorm
from repro.parallel import ctx as pctx


def ssm_params(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    ng = cfg.ssm_ngroups
    conv_dim = di + 2 * ng * n
    ks = jax.random.split(key, 4)
    return {
        # order: [z (gate), x, B, C, dt]
        "w_in": init_dense(ks[0], (d, 2 * di + 2 * ng * n + h), (0,), dtype),
        "conv_w": init_dense(ks[1], (cfg.ssm_conv_width, conv_dim), (0,), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.zeros((di,), dtype),
        "w_out": init_dense(ks[2], (di, d), (0,), dtype),
    }


def _split_in(p, x, cfg):
    di, h, n, ng = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * ng * n]
    dt = zxbcdt[..., 2 * di + 2 * ng * n:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, state=None):
    """Depthwise causal conv, width K. state: (B, K-1, C) carries history."""
    kw = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (kw - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, K-1+S, C)
    out = sum(full[:, i: i + xbc.shape[1]] * conv_w[i] for i in range(kw))
    out = jax.nn.silu(out + conv_b)
    new_state = full[:, -(kw - 1):] if kw > 1 else pad
    return out, new_state


def _heads(xbc, dt, p, cfg):
    di, h, n, ng = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    hd = cfg.ssm_head_dim
    xh = xbc[..., :di].reshape(xbc.shape[:-1] + (h, hd))
    b = xbc[..., di: di + ng * n].reshape(xbc.shape[:-1] + (ng, n))
    c = xbc[..., di + ng * n:].reshape(xbc.shape[:-1] + (ng, n))
    # broadcast groups over heads
    rep = h // ng
    b = jnp.repeat(b, rep, axis=-2)
    c = jnp.repeat(c, rep, axis=-2)
    xh = pctx.shard(xh, pctx.BATCH, None, pctx.MODEL, None)
    b = pctx.shard(b, pctx.BATCH, None, pctx.MODEL, None)
    c = pctx.shard(c, pctx.BATCH, None, pctx.MODEL, None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    log_decay = dt * a  # (B,S,H)  = log of per-step decay (negative)
    return xh, b, c, dt, log_decay


class SSMState(NamedTuple):
    state: jax.Array  # (B, H, hd, N) float32
    conv: jax.Array  # (B, K-1, conv_dim)


def init_ssm_state(batch, cfg, dtype):
    return SSMState(
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                        jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1,
                        cfg.ssm_d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state),
                       dtype),
    )


def ssd_chunked(xh, b, c, dt, log_decay, chunk: int, init_state=None):
    """Chunked SSD scan. xh: (B,S,H,hd) b,c: (B,S,H,N) dt/log_decay: (B,S,H).
    Returns (y: (B,S,H,hd), final_state: (B,H,hd,N))."""
    bsz, s, h, hd = xh.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // chunk
    # reshape to (B, nc, Q, ...)
    rs = lambda t: t.reshape((bsz, nc, chunk) + t.shape[2:])
    xh, b, c, dt, ld = map(rs, (xh, b, c, dt, log_decay))
    xdt = xh.astype(jnp.float32) * dt[..., None]  # dt-weighted input
    cs = jnp.cumsum(ld, axis=2)  # (B,nc,Q,H) cumulative log decay within chunk
    total = cs[:, :, -1]  # (B,nc,H)
    # --- intra-chunk (quadratic, causal, decay-masked) ---
    # decay[t,s] = exp(cs[t] - cs[s]) for s<=t
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bnqhs,bnkhs->bnqkh", c.astype(jnp.float32),
                    b.astype(jnp.float32))  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnqkh,bnqkh,bnkhd->bnqhd", cb, decay, xdt)
    # --- chunk states: S_n = Σ_s exp(total - cs[s]) · b[s] ⊗ xdt[s] ---
    w_state = jnp.exp(total[:, :, None] - cs)  # (B,nc,Q,H)
    chunk_states = jnp.einsum("bnqh,bnqhs,bnqhd->bnhds", w_state,
                              b.astype(jnp.float32), xdt)  # (B,nc,H,hd,N)
    # --- inter-chunk recurrence ---
    if init_state is None:
        init_state = jnp.zeros((bsz, h, hd, n), jnp.float32)

    def body(carry, xs):
        st_in = carry
        tot, new_state = xs  # (B,H), (B,H,hd,N)
        st_out = jnp.exp(tot)[:, :, None, None] * st_in + new_state
        return st_out, st_in  # emit the state *entering* the chunk

    final, entered = jax.lax.scan(
        body, init_state, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_states, 1, 0)))
    entered = jnp.moveaxis(entered, 0, 1)  # (B,nc,H,hd,N)
    # --- inter-chunk contribution: y[t] += exp(cs[t]) · C[t] · S_entered ---
    y_inter = jnp.einsum("bnqh,bnqhs,bnhds->bnqhd", jnp.exp(cs),
                         c.astype(jnp.float32), entered)
    y = (y_intra + y_inter).reshape(bsz, nc * chunk, h, hd)[:, :s]
    return y, final


def ssm_forward(p, x, cfg, state: SSMState | None = None, *, return_state=False):
    """Full sequence forward. x: (B,S,D). If ``state`` is given it is the
    carried recurrence (decode path uses S=1)."""
    z, xbc, dt = _split_in(p, x, cfg)
    conv_state = state.conv if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xh, b, c, dt, log_decay = _heads(xbc, dt, p, cfg)
    init = state.state if state is not None else None
    if x.shape[1] == 1 and state is not None:
        # O(1) decode: s ← a·s + dt·B⊗x
        a = jnp.exp(log_decay[:, 0])  # (B,H)
        sx = a[:, :, None, None] * state.state + jnp.einsum(
            "bhs,bhd->bhds", b[:, 0].astype(jnp.float32),
            (xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]))
        y = jnp.einsum("bhs,bhds->bhd", c[:, 0].astype(jnp.float32), sx)[:, None]
        final = sx
    else:
        y, final = ssd_chunked(xh, b, c, dt, log_decay, cfg.ssm_chunk, init)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(x.shape[:2] + (cfg.ssm_d_inner,)).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_state:
        return out, SSMState(state=final, conv=new_conv)
    return out, None
