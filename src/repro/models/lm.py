"""Top-level model: embedding → scanned layer groups → norm → LM head.

Covers decoder-only (dense/MoE/SSM/hybrid/VLM) and encoder-decoder (audio)
families behind three entry points:

* ``forward(params, cfg, batch)``        — full-sequence logits (training)
* ``prefill(params, cfg, batch, cache)`` — build caches, return last logits
* ``decode_step(params, cfg, tok, cache)`` — one token with cache

Layers inside a group run under ``lax.scan`` over stacked parameters (flat
HLO regardless of depth) with optional ``jax.checkpoint`` remat.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn_mod
from . import blocks
from repro.parallel import ctx as pctx
from .common import apply_norm, dtype_of, init_dense, norm_params, sinusoidal_pos
from repro.configs.base import LayerSpec, ModelConfig


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _group_params(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    keys = jax.random.split(key, spec.count)
    return jax.vmap(lambda k: blocks.block_params(k, spec, cfg, dtype))(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    n_groups = len(cfg.layers) + len(cfg.encoder_layers)
    ks = jax.random.split(key, n_groups + 4)
    p: dict[str, Any] = {
        "embed": init_dense(ks[0], (cfg.vocab_size, cfg.d_model), (1,), dtype),
        "final_norm": norm_params(cfg.d_model, cfg.use_layernorm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(ks[1], (cfg.d_model, cfg.vocab_size), (0,), dtype)
    if cfg.learned_pos_embed:
        p["pos_embed"] = init_dense(ks[2], (max(cfg.decoder_len, 1), cfg.d_model),
                                    (1,), dtype)
    ki = 4
    if cfg.encoder_layers:
        p["enc"] = [_group_params(ks[ki + i], s, cfg, dtype)
                    for i, s in enumerate(cfg.encoder_layers)]
        ki += len(cfg.encoder_layers)
        p["enc_norm"] = norm_params(cfg.d_model, cfg.use_layernorm, dtype)
    p["dec"] = [_group_params(ks[ki + i], s, cfg, dtype)
                for i, s in enumerate(cfg.layers)]
    return p


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Group scan
# ---------------------------------------------------------------------------

def _scan_group(gp, spec: LayerSpec, cfg: ModelConfig, x, positions,
                cache=None, enc_out=None):
    windows = jnp.asarray(spec.window_list(), jnp.int32)

    def body(carry, xs):
        h = carry
        if cache is None:
            lp, w = xs
            lc = None
        else:
            lp, w, lc = xs
        h, new_lc, aux = blocks.block_forward(lp, spec, cfg, h, positions,
                                              cache=lc, window=w, enc_out=enc_out)
        return h, (new_lc, aux)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (gp, windows) if cache is None else (gp, windows, cache)
    x, (new_cache, aux) = jax.lax.scan(body, x, xs)
    return x, (None if cache is None else new_cache), jnp.sum(aux)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed_tokens(p, cfg, tokens, positions):
    x = p["embed"][tokens]  # (B, S, D)
    if cfg.learned_pos_embed:
        x = x + p["pos_embed"][positions]
    x = pctx.shard(x, pctx.BATCH, None, None)
    return x.astype(dtype_of(cfg.activation_dtype))


def _blend_patches(x, patch_embeds):
    """VLM stub frontend: precomputed patch embeddings replace the first
    n_patches positions of the sequence (prefix-image layout)."""
    npatch = patch_embeds.shape[1]
    return jnp.concatenate([patch_embeds.astype(x.dtype), x[:, npatch:]], axis=1)


def _head(p, cfg, x):
    x = apply_norm(p["final_norm"], x, cfg.norm_eps, cfg.use_layernorm)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    logits = pctx.shard(logits, pctx.BATCH, None, pctx.MODEL)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# Encoder (audio frontend stub: batch carries frame embeddings directly)
# ---------------------------------------------------------------------------

def encode(p, cfg: ModelConfig, frames):
    """frames: (B, S_enc, D) precomputed frame embeddings (frontend stub)."""
    x = frames.astype(dtype_of(cfg.activation_dtype))
    x = x + sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])
    for gp, spec in zip(p["enc"], cfg.encoder_layers):
        x, _, _ = _scan_group(gp, spec, cfg, x, positions)
    return apply_norm(p["enc_norm"], x, cfg.norm_eps, cfg.use_layernorm)


# ---------------------------------------------------------------------------
# Decoder forward (training: no cache)
# ---------------------------------------------------------------------------

def forward(p, cfg: ModelConfig, batch: dict):
    """batch: tokens (B,S) [+ frames (B,S_enc,D) | patch_embeds (B,P,D)].
    Returns (logits (B,S,V) fp32, aux_loss)."""
    tokens = batch["tokens"]
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32),
                                 tokens.shape)
    x = _embed_tokens(p, cfg, tokens, positions)
    if cfg.frontend == "vision_patches":
        x = _blend_patches(x, batch["patch_embeds"])
    enc_out = encode(p, cfg, batch["frames"]) if cfg.is_encoder_decoder else None
    aux_total = jnp.zeros((), jnp.float32)
    for gp, spec in zip(p["dec"], cfg.layers):
        x, _, aux = _scan_group(gp, spec, cfg, x, positions, enc_out=enc_out)
        aux_total = aux_total + aux
    return _head(p, cfg, x), aux_total


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def group_kv_len(spec: LayerSpec, kv_len: int) -> int:
    """Per-group cache depth: a purely sliding-window group only ever needs
    its largest window (rolling cache); any full-attention layer in the
    group forces the full length. Keeping window-homogeneous groups in the
    config (e.g. hymba's 3 global + 29 SWA layers) is what makes long
    contexts cheap (§Perf iteration 1: 512× smaller SWA caches)."""
    ws = spec.window_list()
    if any(w == 0 for w in ws):
        return kv_len
    return min(max(ws), kv_len)


def init_cache(cfg: ModelConfig, batch: int, kv_len: int, enc_len: int = 0):
    """Cache pytree: per-group stacked layer caches + global position."""
    dtype = dtype_of(cfg.activation_dtype)

    def group_cache(spec: LayerSpec):
        gkv = group_kv_len(spec, kv_len)

        def one(_):
            return blocks.init_layer_cache(spec, cfg, batch, gkv, dtype,
                                           enc_len)
        return jax.vmap(one)(jnp.arange(spec.count))

    return {
        "pos": jnp.zeros((), jnp.int32),
        "groups": [group_cache(s) for s in cfg.layers],
    }


def _precompute_cross(p, cfg, cache, enc_out):
    """Fill cross-attention K/V from encoder states (once, at prefill)."""
    for gi, spec in enumerate(cfg.layers):
        if not spec.cross_attn:
            continue
        gp = p["dec"][gi]

        def kv_of(lp):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
            if "bk" in lp["cross"]:
                k = k + lp["cross"]["bk"]
                v = v + lp["cross"]["bv"]
            return k, v

        k, v = jax.vmap(kv_of)(gp)
        cache["groups"][gi]["cross_k"] = k.astype(dtype_of(cfg.activation_dtype))
        cache["groups"][gi]["cross_v"] = v.astype(dtype_of(cfg.activation_dtype))
    return cache


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def prefill(p, cfg: ModelConfig, batch: dict, cache):
    """Run the prompt through the decoder, writing caches.
    Returns (logits of last position (B,V), cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.is_encoder_decoder:
        enc_out = encode(p, cfg, batch["frames"])
        cache = _precompute_cross(p, cfg, cache, enc_out)
    x = _embed_tokens(p, cfg, tokens, positions)
    if cfg.frontend == "vision_patches":
        x = _blend_patches(x, batch["patch_embeds"])
    new_groups = []
    for gp, spec, gc in zip(p["dec"], cfg.layers, cache["groups"]):
        x, gc_new, _ = _scan_group(gp, spec, cfg, x, positions, cache=gc)
        new_groups.append(gc_new)
    logits = _head(p, cfg, x[:, -1:])[:, 0]
    return logits, {"pos": jnp.asarray(s, jnp.int32), "groups": new_groups}


def decode_step(p, cfg: ModelConfig, token, cache):
    """token: (B,) int32. Returns (logits (B,V), cache)."""
    b = token.shape[0]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = _embed_tokens(p, cfg, token[:, None], positions)
    new_groups = []
    for gp, spec, gc in zip(p["dec"], cfg.layers, cache["groups"]):
        x, gc_new, _ = _scan_group(gp, spec, cfg, x, positions, cache=gc)
        new_groups.append(gc_new)
    logits = _head(p, cfg, x)[:, 0]
    return logits, {"pos": pos + 1, "groups": new_groups}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(p, cfg: ModelConfig, batch: dict, aux_weight: float = 0.01):
    """Causal-LM cross-entropy (+ MoE aux). Returns (loss, metrics) where
    metrics carries per-example NLL/entropy — the interestingness hook."""
    logits, aux = forward(p, cfg, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom + aux_weight * aux
    per_example_nll = nll.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    metrics = {
        "loss": nll.sum() / denom,
        "aux_loss": aux,
        "per_example_nll": per_example_nll,
        "tokens": mask.sum(),
    }
    return loss, metrics
