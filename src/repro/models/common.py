"""Shared model ops: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def init_dense(key, shape, in_axes=(0,), dtype=jnp.float32, scale=1.0):
    """Truncated-normal fan-in init; ``in_axes`` are the contracted dims."""
    fan_in = int(np.prod([shape[a] for a in in_axes]))
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_params(d, use_layernorm=False, dtype=jnp.float32):
    p = {"scale": jnp.zeros((d,), dtype)}
    if use_layernorm:
        p = {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return p


def apply_norm(p, x, eps, use_layernorm=False):
    if use_layernorm:
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d_model: int, dtype=jnp.float32):
    """Whisper-style sinusoidal positional embedding table (S, D)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (np.log(10000.0) / max(d_model // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "silu_glu": ("glu", jax.nn.silu),
    "gelu_glu": ("glu", gelu),
    "gelu": ("plain", gelu),
    "silu": ("plain", jax.nn.silu),
}
