from . import attention, blocks, common, ffn, lm, ssm  # noqa: F401
from .lm import (abstract_params, decode_step, forward, init_cache,  # noqa: F401
                 init_params, lm_loss, param_count, prefill)
