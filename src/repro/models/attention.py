"""Attention mixers: GQA (full / sliding-window / chunked-flash), MLA
(DeepSeek-V2, with absorbed-weight decode), and cross-attention.

Memory discipline: anything with long KV (prefill_32k, hymba's global layers
at 500k) routes through ``chunked_attention`` — an online-softmax scan over
KV blocks (flash-attention dataflow in pure JAX; the Pallas analogue would
tile the same loop into VMEM). Caches carry explicit key positions so
rolling (sliding-window) and full caches share one code path.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import apply_rope, init_dense
from repro.parallel import ctx as pctx

BIG_NEG = -2.0e9  # mask value safe in bf16/f32
CHUNK_THRESHOLD = 4096  # KV lengths above this use the chunked path
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def gqa_params(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], (d, h, hd), (0,), dtype),
        "wk": init_dense(ks[1], (d, kv, hd), (0,), dtype),
        "wv": init_dense(ks[2], (d, kv, hd), (0,), dtype),
        "wo": init_dense(ks[3], (h, hd, d), (0, 1), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mla_params(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": init_dense(ks[0], (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim), (0,), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        "wkv_b": init_dense(ks[1], (cfg.kv_lora_rank, h, cfg.qk_nope_head_dim + cfg.v_head_dim), (0,), dtype),
        "wo": init_dense(ks[2], (h, cfg.v_head_dim, d), (0, 1), dtype),
    }
    if cfg.q_lora_rank > 0:
        p["wq_a"] = init_dense(ks[3], (d, cfg.q_lora_rank), (0,), dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), dtype)
        p["wq_b"] = init_dense(ks[4], (cfg.q_lora_rank, h, qk), (0,), dtype)
    else:
        p["wq"] = init_dense(ks[5], (d, h, qk), (0,), dtype)
    return p


def cross_params(key, cfg, dtype):
    """K/V over encoder states + Q over decoder states (whisper cross-attn)."""
    return gqa_params(key, cfg, dtype)


# ---------------------------------------------------------------------------
# Masked softmax attention over grouped heads
# ---------------------------------------------------------------------------

def mask_ok(q_pos, kv_pos, causal: bool, window):
    """(..., Sq, Skv) boolean mask. kv_pos < 0 marks invalid cache slots."""
    dq = q_pos[..., :, None]
    dk = kv_pos[..., None, :]
    ok = dk >= 0
    if causal:
        ok = ok & (dk <= dq)
    w = jnp.asarray(window, jnp.int32)
    ok = ok & jnp.where(w > 0, dk > dq - w, True)
    return ok


def _softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _expand_kv(k, h: int):
    """(B,S,KV,hd) → (B,S,H,hd) by repeating each KV head over its group.

    TP rationale (DESIGN §5): scoring in the grouped (KV,G) layout cannot
    shard when KV < tp, which replicates the whole quadratic attention on
    every model-axis chip. Expanded to H query-heads, the per-head layout
    shards H over `model` whenever H divides — the expansion itself is a
    gather whose output is already sharded, so per-chip KV bytes go DOWN.
    attend() only expands when that condition holds (§Perf iteration 1
    showed unconditional expansion all-gathers the full cache when H is
    NOT divisible — e.g. hymba's 25 heads at 500k context)."""
    kvh = k.shape[2]
    if kvh == h:
        return k
    idx = jnp.arange(h, dtype=jnp.int32) // (h // kvh)
    k = jnp.take(k, idx, axis=2)
    return pctx.shard(k, pctx.BATCH, None, pctx.MODEL, None)


def grouped_attention(q, k, v, q_pos, kv_pos, *, causal, window, softcap=0.0,
                      scale=None):
    """q: (B,Sq,H,hd) — k,v: (B,Skv,KV,hd), KV | H — returns (B,Sq,H,hd_v).
    Dense path; fine for Skv ≤ CHUNK_THRESHOLD."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale or 1.0 / math.sqrt(hd)
    qg = (q * scale).astype(jnp.float32).reshape(b, sq, kvh, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    ok = mask_ok(q_pos, kv_pos, causal, window)  # (B, Sq, Skv)
    logits = jnp.where(ok[:, None, None], logits, BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, v.shape[-1])


def chunked_attention(q, k, v, q_pos, kv_pos, *, causal, window, softcap=0.0,
                      scale=None, chunk=KV_CHUNK):
    """Online-softmax scan over KV chunks: O(Sq·chunk) live memory."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    skv = k.shape[1]
    hdv = v.shape[-1]
    scale = scale or 1.0 / math.sqrt(hd)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = k.shape[1] // chunk
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, kvh, g, hd)
    kc = k.reshape(b, n_chunks, chunk, kvh, hd)
    vc = v.reshape(b, n_chunks, chunk, kvh, hdv)
    pc = kv_pos.reshape(b, n_chunks, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs  # (b, chunk, kvh, hd), (b, chunk)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb.astype(jnp.float32))
        logits = _softcap(logits, softcap)
        ok = mask_ok(q_pos, pb, causal, window)
        logits = jnp.where(ok[:, None, None], logits, BIG_NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), BIG_NEG, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1)  # (b, sq, kvh, g, hdv)
    return out.reshape(b, sq, h, hdv).astype(v.dtype)


def attend(q, k, v, q_pos, kv_pos, *, seq_parallel_q=False, **kw):
    tp = pctx.tp_size()
    h = q.shape[2]
    if seq_parallel_q and tp > 1 and q.shape[1] > 1 and q.shape[1] % tp == 0:
        # sequence-parallel attention: q (and the whole score tensor) stay
        # sharded on the query-sequence dim; K/V are gathered full (they
        # are ~d_kv/d_model of the residual — far cheaper to gather than x,
        # and no score-tensor relayout — §Perf iteration 3b)
        q = pctx.shard(q, pctx.BATCH, pctx.MODEL, None, None)
        k = pctx.shard(k, pctx.BATCH, None, None, None)
        v = pctx.shard(v, pctx.BATCH, None, None, None)
    else:
        # expand KV→H heads only when that lets the score tensor shard over
        # `model`; else the grouped layout keeps replicated KV bytes small
        if tp > 1 and k.shape[2] != h and h % tp == 0:
            k = _expand_kv(k, h)
            v = _expand_kv(v, h)
        # heads that cannot shard over `model` (H % tp != 0) fall back to
        # sequence-sharding the queries
        if tp > 1 and h % tp != 0 and q.shape[1] > 1 and q.shape[1] % tp == 0:
            q = pctx.shard(q, pctx.BATCH, pctx.MODEL, None, None)
    # chunked (flash-dataflow) only when BOTH sides are long: for decode
    # (Sq=1) the dense einsum keeps the KV-sequence sharding intact (no
    # reshape), so GSPMD distributes the softmax over the cache shards —
    # §Perf iteration 2: the chunk-scan's reshape forced replication.
    if q.shape[1] > 1 and k.shape[1] > CHUNK_THRESHOLD:
        return chunked_attention(q, k, v, q_pos, kv_pos, **kw)
    return grouped_attention(q, k, v, q_pos, kv_pos, **kw)


# ---------------------------------------------------------------------------
# KV cache (full or rolling window) — slot = pos % W
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, W, KV, hd)
    v: jax.Array  # (B, W, KV, hd)
    pos: jax.Array  # (B, W) int32 key positions, -1 = empty


def init_kv_cache(batch, w, kvh, hd, dtype):
    return KVCache(
        k=jnp.zeros((batch, w, kvh, hd), dtype),
        v=jnp.zeros((batch, w, kvh, hd), dtype),
        pos=jnp.full((batch, w), -1, jnp.int32),
    )


def cache_write(cache: KVCache, k_new, v_new, positions) -> KVCache:
    """Write S_new entries at ``positions`` (B, S_new) into rolling slots.
    If S_new ≥ W (prefill longer than a rolling window) only the last W
    entries are written — earlier ones would be overwritten anyway, and
    duplicate scatter indices have undefined order."""
    w = cache.k.shape[1]
    if k_new.shape[1] >= w:
        k_new, v_new = k_new[:, -w:], v_new[:, -w:]
        positions = positions[:, -w:]
    slots = positions % w  # (B, S_new)
    bidx = jnp.arange(cache.k.shape[0])[:, None]
    return KVCache(
        k=cache.k.at[bidx, slots].set(k_new),
        v=cache.v.at[bidx, slots].set(v_new),
        pos=cache.pos.at[bidx, slots].set(positions.astype(jnp.int32)),
    )


# ---------------------------------------------------------------------------
# GQA forward (train / prefill / decode in one function)
# ---------------------------------------------------------------------------

def gqa_forward(p, x, positions, cfg, *, causal=True, window=0,
                cache: Optional[KVCache] = None, kv_source=None):
    """x: (B,S,D). positions: (B,S). If ``cache`` is given, new K/V are
    written at ``positions`` and attention runs over the cache (decode /
    prefill). ``kv_source`` overrides the K/V input (cross-attention)."""
    src = x if kv_source is None else kv_source
    sp = cfg.seq_parallel and x.shape[1] > 1
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if not sp:  # head-TP layout; under SP attend() pins the seq layout
        q = pctx.shard(q, pctx.BATCH, None, pctx.MODEL, None)
        k = pctx.shard(k, pctx.BATCH, None, pctx.MODEL, None)
        v = pctx.shard(v, pctx.BATCH, None, pctx.MODEL, None)
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.use_rope and kv_source is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cache is not None and x.shape[1] == 1:
        # decode: attend over the cache
        cache = cache_write(cache, k, v, positions)
        k_all, v_all, kv_pos = cache.k, cache.v, cache.pos
    elif cache is not None:
        # prefill: attend over the FULL prompt K/V (a rolling cache may be
        # shorter than the prompt — intermediate positions still need their
        # complete window), then persist the tail for decode.
        cache = cache_write(cache, k, v, positions)
        k_all, v_all, kv_pos = k, v, positions
    else:
        k_all, v_all = k, v
        if kv_source is None:
            kv_pos = positions
        else:  # cross-attention: keys live on the encoder axis
            kv_pos = jnp.broadcast_to(
                jnp.arange(src.shape[1], dtype=jnp.int32), src.shape[:2])
    out = attend(q, k_all, v_all, positions, kv_pos, causal=causal,
                 window=window, softcap=cfg.attn_logit_softcap,
                 seq_parallel_q=sp)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y, cache


# ---------------------------------------------------------------------------
# MLA forward
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    ckv: jax.Array  # (B, W, kv_lora)
    krope: jax.Array  # (B, W, rope_dim)
    pos: jax.Array  # (B, W)


def init_mla_cache(batch, w, cfg, dtype):
    return MLACache(
        ckv=jnp.zeros((batch, w, cfg.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, w, cfg.qk_rope_head_dim), dtype),
        pos=jnp.full((batch, w), -1, jnp.int32),
    )


def _mla_q(p, x, positions, cfg):
    if "wq_a" in p:
        from .common import rmsnorm
        qa = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if not (cfg.seq_parallel and x.shape[1] > 1):
        q = pctx.shard(q, pctx.BATCH, None, pctx.MODEL, None)
    qn, qr = q[..., : cfg.qk_nope_head_dim], q[..., cfg.qk_nope_head_dim:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _mla_latent(p, x, positions, cfg):
    from .common import rmsnorm
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, kr = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    ckv = rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, kr


# latent→per-head expansion is ~42× inflation — stream latent chunks for
# long prefill. Dense stays at train_4k: the chunk scan's extra copies under
# remat/backward measured WORSE there (deepseek train t_mem 63→114 s).
MLA_CHUNK_THRESHOLD = 4096
MLA_CHUNK = 1024


def _mla_attend_latent_chunked(q, ckv, kr, wkb, positions, cfg, *, causal,
                               scale, chunk=MLA_CHUNK):
    """Flash-MLA dataflow: stream LATENT chunks, expanding each to per-head
    K/V on the fly — the full (H, nope+rope) expansion never hits HBM
    (§Perf: it dominated deepseek prefill traffic at ~2e13 B/chip)."""
    b, s, h, _ = q.shape
    nope = cfg.qk_nope_head_dim
    hdv = cfg.v_head_dim
    pad = (-s) % chunk
    kv_pos = positions
    if pad:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    nc = ckv.shape[1] // chunk
    rs = lambda t: jnp.moveaxis(
        t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)
    qf = q.astype(jnp.float32) * scale

    def body(carry, xs):
        m, l, acc = carry
        ckv_c, kr_c, pos_c = xs  # (b, C, R), (b, C, rope), (b, C)
        kn = jnp.einsum("bcr,rhk->bchk", ckv_c.astype(jnp.float32),
                        wkb[..., :nope].astype(jnp.float32))
        vc = jnp.einsum("bcr,rhk->bchk", ckv_c.astype(jnp.float32),
                        wkb[..., nope:].astype(jnp.float32))
        kr_b = jnp.broadcast_to(kr_c[:, :, None, :].astype(jnp.float32),
                                kn.shape[:3] + (kr_c.shape[-1],))
        kc = jnp.concatenate([kn, kr_b], axis=-1)
        logits = jnp.einsum("bqhd,bchd->bhqc", qf, kc)
        ok = mask_ok(positions, pos_c, causal, 0)
        logits = jnp.where(ok[:, None], logits, BIG_NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqc,bchd->bhqd",
                                                      pexp, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), BIG_NEG, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (rs(ckv), rs(kr), rs(kv_pos)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 2, 1).astype(q.dtype)  # (b, s, h, hdv)


def mla_forward_expanded(p, x, positions, cfg, *, causal=True):
    """Training / prefill form. Short sequences expand latent → per-head
    K/V densely; long sequences stream latent chunks (flash-MLA)."""
    qn, qr = _mla_q(p, x, positions, cfg)
    ckv, kr = _mla_latent(p, x, positions, cfg)
    wkb = p["wkv_b"]  # (lora, H, nope+v)
    q = jnp.concatenate([qn, qr], axis=-1)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    sp = cfg.seq_parallel and x.shape[1] > 1
    if x.shape[1] > MLA_CHUNK_THRESHOLD:
        if sp and pctx.tp_size() > 1 and x.shape[1] % pctx.tp_size() == 0:
            q = pctx.shard(q, pctx.BATCH, pctx.MODEL, None, None)
            ckv = pctx.shard(ckv, pctx.BATCH, None, None)
            kr = pctx.shard(kr, pctx.BATCH, None, None)
        out = _mla_attend_latent_chunked(q, ckv, kr, wkb, positions, cfg,
                                         causal=causal, scale=scale)
    else:
        kn = jnp.einsum("bsr,rhk->bshk", ckv, wkb[..., : cfg.qk_nope_head_dim])
        v = jnp.einsum("bsr,rhk->bshk", ckv, wkb[..., cfg.qk_nope_head_dim:])
        kr_b = jnp.broadcast_to(kr[:, :, None, :],
                                kn.shape[:3] + (kr.shape[-1],))
        k = jnp.concatenate([kn, kr_b], axis=-1)
        out = attend(q, k, v, positions, positions, causal=causal, window=0,
                     scale=scale, seq_parallel_q=sp)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_forward_absorbed(p, x, positions, cfg, cache: MLACache, *, causal=True):
    """Decode form: score queries directly against the latent cache
    (weight absorption — never materializes per-head K/V over the context)."""
    b, s = x.shape[:2]
    h = cfg.n_heads
    qn, qr = _mla_q(p, x, positions, cfg)  # (B,S,H,nope),(B,S,H,rope)
    ckv_new, kr_new = _mla_latent(p, x, positions, cfg)
    w = cache.ckv.shape[1]
    slots = positions % w
    bidx = jnp.arange(b)[:, None]
    cache = MLACache(
        ckv=cache.ckv.at[bidx, slots].set(ckv_new),
        krope=cache.krope.at[bidx, slots].set(kr_new),
        pos=cache.pos.at[bidx, slots].set(positions.astype(jnp.int32)),
    )
    wkb = p["wkv_b"]
    wk = wkb[..., : cfg.qk_nope_head_dim]  # (lora, H, nope)
    wv = wkb[..., cfg.qk_nope_head_dim:]  # (lora, H, v)
    q_lat = jnp.einsum("bshk,rhk->bshr", qn.astype(jnp.float32),
                       wk.astype(jnp.float32))  # (B,S,H,lora)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, cache.ckv.astype(jnp.float32))
              + jnp.einsum("bshk,btk->bhst", qr.astype(jnp.float32),
                           cache.krope.astype(jnp.float32))) * scale
    ok = mask_ok(positions, cache.pos, causal, 0)
    logits = jnp.where(ok[:, None], logits, BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, cache.ckv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", ctx, wv.astype(jnp.float32))
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, cache
