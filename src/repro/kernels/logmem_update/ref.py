"""Pure-jnp oracle for the fused logmem admission scan."""
from __future__ import annotations

import jax.numpy as jnp


def logmem_admit(scores, ids, tau, block_n: int):
    scores = scores.astype(jnp.float32)
    ids = ids.astype(jnp.int32)
    m, n = scores.shape
    n_tiles = n // block_n
    live = ids >= 0
    hit = live & (scores > tau.astype(jnp.float32).reshape(m, 1))
    mask = hit.astype(jnp.int8)
    acounts = hit.reshape(m, n_tiles, block_n).sum(axis=2,
                                                   dtype=jnp.int32)
    lcounts = live.reshape(m, n_tiles, block_n).sum(axis=2,
                                                    dtype=jnp.int32)
    tmax = jnp.where(live, scores, -jnp.inf) \
        .reshape(m, n_tiles, block_n).max(axis=2)
    return mask, acounts, lcounts, tmax
