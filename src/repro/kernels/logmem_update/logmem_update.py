"""Pallas TPU kernel: fused logmem admission scan for M concurrent streams.

The logarithmic-memory engine backend (``repro.streams.logmem``) admits a
doc iff its score beats the stream's acceptance threshold ``tau`` — the
O(log K) analog of the exact reservoir's bar scan. Its hot path touches
every (score, id) pair exactly once: compare against tau, mask out
padding, and reduce the per-tile admit/live counts the threshold-update
epilogue consumes (the live count sets the chunk's target quantile rank
r = round(W·K/t); the admit counts are the write-law evidence the drift
detector tests).

Grid: (M, W/bn) — one program per (stream, tile) pair, same shape as
``batched_topk`` but ids-aware: padding is identified by id < 0 (not by
a score sentinel), so pad columns are inert in every output. Each
program reads one score tile, one id tile and its stream's tau from
VMEM and emits the admit mask plus per-(stream, tile) admit count, live
count and live maximum. Embarrassingly parallel, bandwidth-bound — one
pass over HBM regardless of M.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scores_ref, ids_ref, tau_ref, mask_ref, acount_ref,
            lcount_ref, tmax_ref):
    s = scores_ref[...].astype(jnp.float32)  # (1, bn)
    ids = ids_ref[...]  # (1, bn) int32
    tau = tau_ref[0]  # this stream's acceptance threshold
    live = ids >= 0
    hit = live & (s > tau)
    mask_ref[...] = hit.astype(jnp.int8)
    acount_ref[0, 0] = hit.sum().astype(jnp.int32)
    lcount_ref[0, 0] = live.sum().astype(jnp.int32)
    tmax_ref[0, 0] = jnp.where(live, s, -jnp.inf).max()


def logmem_admit_pallas(scores, ids, tau, *, block_n: int = 512,
                        interpret: bool = False):
    """scores (M, N) float, ids (M, N) int32 (< 0 = padding), tau (M,)
    float32. Returns (mask (M, N) int8, admit_counts (M, N/bn) int32,
    live_counts (M, N/bn) int32, tile_max (M, N/bn) f32 — live maximum,
    -inf on all-pad tiles).
    """
    m, n = scores.shape
    assert n % block_n == 0, (n, block_n)
    n_tiles = n // block_n
    return pl.pallas_call(
        _kernel,
        grid=(m, n_tiles),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n_tiles), jnp.int32),
            jax.ShapeDtypeStruct((m, n_tiles), jnp.int32),
            jax.ShapeDtypeStruct((m, n_tiles), jnp.float32),
        ),
        interpret=interpret,
    )(scores.astype(jnp.float32), ids.astype(jnp.int32),
      tau.astype(jnp.float32).reshape(m))
