"""Public wrapper for the fused logmem admission scan: pad the trailing
axis, run the 2-D kernel (interpret off-TPU), strip the padding.

The composed threshold-update epilogue (chunk order statistic, decayed
fold, phase commit) lives in ``repro.streams.logmem.update`` — the
streams layer sits above kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .logmem_update import logmem_admit_pallas

NEG_BIG = -1e30
PAD_ID = -1


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("block_n", "use_pallas"))
def logmem_admit(scores, ids, tau, *, block_n: int = 512,
                 use_pallas: bool = True):
    """scores (M, N) / ids (M, N) int (< 0 = padding) vs per-stream
    acceptance thresholds tau (M,) → (mask int8 (M, N), admit_counts
    (M, N/bn) int32, live_counts (M, N/bn) int32, tile_max (M, N/bn)
    f32).

    Padding columns (appended here with id = -1) are inert in every
    output: the kernel gates on ids, not on a score sentinel, so even a
    -inf threshold admits no pad — unlike ``batched_topk``, whose
    unfull-reservoir convention counts finite pad sentinels.
    """
    m, n = scores.shape
    bn = min(block_n, max(n, 128))
    pad = (-n) % bn
    sp = jnp.pad(scores.astype(jnp.float32), ((0, 0), (0, pad)),
                 constant_values=NEG_BIG)
    ip = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, pad)),
                 constant_values=PAD_ID)
    thr = tau.astype(jnp.float32)
    if use_pallas:
        mask, acounts, lcounts, tmax = logmem_admit_pallas(
            sp, ip, thr, block_n=bn, interpret=not _on_tpu())
    else:
        mask, acounts, lcounts, tmax = ref.logmem_admit(sp, ip, thr, bn)
    return mask[:, :n], acounts, lcounts, tmax
