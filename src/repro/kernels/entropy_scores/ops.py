"""Jit'd public wrapper for the entropy+NLL kernel: pads to tile multiples,
runs the Pallas kernel (interpret=True off-TPU), slices back."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .entropy_scores import NEG_BIG, entropy_nll_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("block_b", "block_v", "use_pallas"))
def entropy_nll(logits, labels, *, block_b: int = 8, block_v: int = 2048,
                use_pallas: bool = True):
    """logits: (B, V); labels: (B,). Returns (entropy, nll) fp32 (B,)."""
    if not use_pallas:
        return ref.entropy_nll(logits, labels)
    b, v = logits.shape
    bb = min(block_b, max(b, 1))
    bv = min(block_v, max(v, 128))
    pad_b = (-b) % bb
    pad_v = (-v) % bv
    lp = jnp.pad(logits, ((0, pad_b), (0, pad_v)), constant_values=NEG_BIG)
    lab = jnp.pad(labels.astype(jnp.int32), ((0, pad_b),))
    ent, nll = entropy_nll_pallas(lp, lab, block_b=bb, block_v=bv,
                                  interpret=not _on_tpu())
    return ent[:b], nll[:b]
