"""Pure-jnp oracle for the fused entropy+NLL interestingness kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_nll(logits: jax.Array, labels: jax.Array):
    """logits: (B, V) — labels: (B,) int32.

    Returns (entropy (B,), nll (B,)) in fp32:
      entropy = −Σ p·log p  with p = softmax(logits)
      nll     = logsumexp(logits) − logits[label]
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    logp = logits - lse[:, None]
    p = jnp.exp(logp)
    ent = -jnp.sum(p * logp, axis=-1)
    nll = lse - jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32),
                                    axis=-1)[:, 0]
    return ent, nll
