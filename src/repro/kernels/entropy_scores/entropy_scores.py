"""Pallas TPU kernel: fused per-row entropy + NLL over vocab tiles.

The interestingness scorers (paper §IV/§VIII) need per-example predictive
entropy and NLL from (B, V) logits with V up to 256k. Materializing softmax
costs two extra HBM round-trips over B·V; this kernel streams vocab tiles
through VMEM once, carrying flash-style online (max, Σexp, Σexp·logit, gold)
accumulators in scratch.

Grid: (B/bm rows parallel, V/bv vocab tiles sequential-arbitrary).
entropy = lse − (Σ e^{l−M}·l)/S ;  nll = lse − l[label] ;  lse = M + log S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1e30  # finite -inf stand-in (0·NEG_BIG == -0.0, not NaN)


def _kernel(logits_ref, labels_ref, ent_ref, nll_ref,
            m_ref, s_ref, t_ref, g_ref, *, bv: int, n_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    tile = logits_ref[...].astype(jnp.float32)  # (bm, bv)
    labels = labels_ref[...]  # (bm,)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, tile.max(axis=-1))
    alpha = jnp.exp(m_old - m_new)
    e = jnp.exp(tile - m_new[:, None])
    s_ref[...] = s_ref[...] * alpha + e.sum(axis=-1)
    t_ref[...] = t_ref[...] * alpha + (e * tile).sum(axis=-1)
    m_ref[...] = m_new
    # gold logit: one-hot contraction against the global vocab index
    v_global = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1) + j * bv
    hit = (v_global == labels[:, None]).astype(jnp.float32)
    g_ref[...] = g_ref[...] + (tile * hit).sum(axis=-1)

    @pl.when(j == n_v - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(s_ref[...])
        ent_ref[...] = lse - t_ref[...] / s_ref[...]
        nll_ref[...] = lse - g_ref[...]


def entropy_nll_pallas(logits, labels, *, block_b: int = 8,
                       block_v: int = 2048, interpret: bool = False):
    """logits: (B, V) any float dtype — labels: (B,) int32.
    B must divide block_b·k and V divide block_v (ops.py pads)."""
    b, v = logits.shape
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    n_b, n_v = b // block_b, v // block_v
    kernel = functools.partial(_kernel, bv=block_v, n_v=n_v)
    out_shape = (jax.ShapeDtypeStruct((b,), jnp.float32),
                 jax.ShapeDtypeStruct((b,), jnp.float32))
    grid = (n_b, n_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        scratch_shapes=[pltpu.VMEM((block_b,), jnp.float32) for _ in range(4)],
        out_shape=out_shape,
        interpret=interpret,
    )(logits, labels)
