from . import ops, ref  # noqa: F401
from .ops import quantize_boundaries, tier_assign  # noqa: F401
