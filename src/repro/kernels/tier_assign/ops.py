"""Public wrapper for the finalize-time tier assignment: quantize the
float boundary vectors to exact integer thresholds, pad the survivor
axis, run the 2-D kernel (interpret off-TPU), strip the padding.

Boundary quantization: survivor ids are integers, so ``id >= b`` for a
float boundary b is exactly ``id >= ceil(b)`` — the comparison the kernel
runs in int32, bit-matching the float64 host meter without float32
precision hazards at large stream positions. +inf boundaries (the
padding convention for mixed-depth fleets) map to INT32_MAX, which no
doc id reaches.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .tier_assign import tier_assign_pallas

_INT_MAX = np.iinfo(np.int32).max


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def quantize_boundaries(bounds) -> np.ndarray:
    """(M, B) float boundary vectors -> exact int32 thresholds."""
    b = np.asarray(bounds, np.float64)
    return np.where(np.isfinite(b),
                    np.clip(np.ceil(b), 0, _INT_MAX), _INT_MAX
                    ).astype(np.int32)


@partial(jax.jit, static_argnames=("n_tiers", "block_k", "use_pallas"))
def _assign(ids, bounds_int, floor, *, n_tiers, block_k, use_pallas):
    m, k = ids.shape
    bk = min(block_k, max(k, 8))
    pad = (-k) % bk
    idp = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, pad)),
                  constant_values=-1)
    if use_pallas:
        tier, counts = tier_assign_pallas(idp, bounds_int, floor,
                                          n_tiers=n_tiers, block_k=bk,
                                          interpret=not _on_tpu())
    else:
        tier, counts = ref.tier_assign(idp, bounds_int, floor, n_tiers)
    return tier[:, :k], counts


def tier_assign(ids, bounds, floor=None, *, n_tiers: int | None = None,
                block_k: int = 128, use_pallas: bool = True):
    """ids (M, K) int survivor ids (-1 pad) vs per-stream float boundary
    vectors ``bounds`` (M, B; +inf pads shallower streams) and optional
    cascade floors (M,). Returns (tier (M, K) int32 with -1 at padding,
    counts (M, T) int32 survivors per tier)."""
    ids = jnp.asarray(ids, jnp.int32)
    bq = jnp.asarray(quantize_boundaries(bounds))
    t = n_tiers if n_tiers is not None else bq.shape[1] + 1
    if floor is None:
        floor = jnp.zeros((ids.shape[0],), jnp.int32)
    else:
        floor = jnp.asarray(floor, jnp.int32)
    return _assign(ids, bq, floor, n_tiers=int(t), block_k=block_k,
                   use_pallas=use_pallas)
