"""Pure-jnp oracle for the finalize-time tier-assignment kernel."""
from __future__ import annotations

import jax.numpy as jnp


def tier_assign(ids, bounds_int, floor, n_tiers: int):
    ids = ids.astype(jnp.int32)
    valid = ids >= 0
    tier = (ids[:, :, None] >= bounds_int[:, None, :]).sum(-1)
    tier = jnp.maximum(tier.astype(jnp.int32), floor[:, None])
    tier = jnp.minimum(tier, n_tiers - 1)
    tier = jnp.where(valid, tier, -1)
    one_hot = (tier[:, :, None] == jnp.arange(n_tiers)[None, None, :])
    counts = (one_hot & valid[:, :, None]).sum(axis=1).astype(jnp.int32)
    return tier, counts
