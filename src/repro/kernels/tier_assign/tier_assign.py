"""Pallas TPU kernel: finalize-time (M, T) tier assignment of survivor
payload batches.

At window end every stream's K survivors must be read from (or flushed
to) their tiers: doc id i belongs to tier t iff b_t <= i < b_{t+1} under
the stream's boundary vector, lifted to the cascade floor for migrated
streams. The host-side meter does this per stream in numpy; at fleet
scale (M × K survivor payloads) it is one embarrassingly-parallel pass
the finalize path runs on device.

Grid: (M, K/bk) — one program per (stream, survivor-tile) pair. Each
program reads its stream's integer boundary row (precomputed as
``ceil(b)`` so the comparison is exact in int32 — see ``ops``), one id
tile, and the stream's cascade floor; it emits the per-survivor tier and
accumulates the stream's per-tier survivor counts across tiles (the
bucketed-gather offsets for issuing per-tier reads). Padding ids (-1)
assign tier -1 and count nowhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, bounds_ref, floor_ref, tier_ref, counts_ref, *,
            n_tiers: int):
    j = pl.program_id(1)
    ids = ids_ref[...]  # (1, bk) int32
    valid = ids >= 0
    tier = jnp.zeros_like(ids)
    for b in range(bounds_ref.shape[1]):
        tier = tier + (ids >= bounds_ref[0, b]).astype(jnp.int32)
    tier = jnp.maximum(tier, floor_ref[0])
    tier = jnp.minimum(tier, n_tiers - 1)
    tier = jnp.where(valid, tier, -1)
    tier_ref[...] = tier

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    for t in range(n_tiers):
        counts_ref[0, t] += ((tier == t) & valid).sum().astype(jnp.int32)


def tier_assign_pallas(ids, bounds_int, floor, *, n_tiers: int,
                       block_k: int = 128, interpret: bool = False):
    """ids: (M, K) int32 survivor ids (-1 pad); bounds_int: (M, B) int32
    integer boundaries (ceil of the float vector, INT32_MAX pad);
    floor: (M,) int32 cascade floors. Returns (tier (M, K) int32,
    counts (M, n_tiers) int32)."""
    m, k = ids.shape
    assert k % block_k == 0, (k, block_k)
    n_tiles = k // block_k
    import functools
    return pl.pallas_call(
        functools.partial(_kernel, n_tiers=n_tiers),
        grid=(m, n_tiles),
        in_specs=[
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((1, bounds_int.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((1, n_tiers), lambda i, j: (i, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m, n_tiers), jnp.int32),
        ),
        interpret=interpret,
    )(ids, bounds_int, floor)
