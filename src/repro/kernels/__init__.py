# Compute hot-spots of the paper's pipeline, as Pallas TPU kernels
# (pl.pallas_call + BlockSpec VMEM tiling), validated in interpret mode on
# CPU against the ref.py oracles:
#   entropy_scores — fused interestingness scoring (entropy+NLL over vocab tiles)
#   topk_filter    — streaming reservoir threshold scan (Fig. 2/3 inner loop)
#   flash_attention — fused attention (removes the S² HBM score traffic
#                     identified as the dominant train-cell roofline term)
from . import entropy_scores, flash_attention, topk_filter  # noqa: F401
