# Compute hot-spots of the paper's pipeline, as Pallas TPU kernels
# (pl.pallas_call + BlockSpec VMEM tiling), validated in interpret mode on
# CPU against the ref.py oracles:
#   entropy_scores — fused interestingness scoring (entropy+NLL over vocab tiles)
#   topk_filter    — streaming reservoir threshold scan (Fig. 2/3 inner loop)
#   batched_topk   — 2-D (stream, tile) threshold scan for the multi-tenant
#                     fleet engine in repro.streams
#   logmem_update  — fused ids-aware admission scan for the O(log K)
#                     logmem engine backend (streams.logmem)
#   tier_assign    — finalize-time (M, T) tier assignment of survivor
#                     payloads against per-stream boundary vectors
#   plan_solve     — fused masked-objective + joint-argmin reduction for
#                     the device-resident constrained planner (shp_jax)
#   flash_attention — fused attention (removes the S² HBM score traffic
#                     identified as the dominant train-cell roofline term)
from . import (batched_topk, entropy_scores, flash_attention, logmem_update,  # noqa: F401
               plan_solve, tier_assign, topk_filter)
