"""Pallas TPU flash attention (forward): online-softmax over KV tiles held
in VMEM — the fused kernel that removes the S² score traffic identified as
the dominant residual HBM term in the train-cell rooflines (EXPERIMENTS
§Perf cell 3: ~7e12 bytes/chip of f32 score tensors per step).

Dataflow per (batch, head, q-tile) grid cell: stream KV tiles through VMEM,
carry (m, l, acc) in f32 scratch, write one (block_q, hd) output tile.
Causal + sliding-window masking via broadcasted iotas (no mask tensor in
HBM). MXU alignment: block sizes default to 128 multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0e9


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int, sq: int, skv: int):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    qi = pl.program_id(2)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0) \
        + (skv - sq)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    ok = kpos < skv  # tail padding
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    logits = jnp.where(ok, logits, NEG)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_s[...] = l_s[...] * alpha + p.sum(axis=1)
    m_s[...] = m_new
    acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())))

    @pl.when(kj == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q: (B,Sq,H,hd) — k,v: (B,Skv,H,hd). Sq % block_q == 0 and
    Skv % block_k == 0 (ops.py pads). Returns (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_k == 0
    scale = scale or 1.0 / (hd ** 0.5)
    n_q = sq // block_q
    n_k = skv // block_k
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, block_q=block_q,
        block_k=block_k, n_k=n_k, sq=sq, skv=skv)
    # layout: (B, H, S, hd) tiles; grid (B, H, n_q, n_k) with kv innermost
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
