"""Jit'd public wrapper: pads sequences to tile multiples (padded keys are
masked via the in-kernel position check), interpret mode off-TPU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "use_pallas"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    use_pallas: bool = True):
    if not use_pallas:
        return ref.flash_attention(q, k, v, causal=causal, window=window)
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(skv, 8))
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # padded q rows sit at positions >= skv: they attend nothing real but
    # the kernel masks padded KEYS by absolute position, so their outputs
    # are garbage and sliced off here.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk,
                                 interpret=not _on_tpu())
    return out[:, :sq]
