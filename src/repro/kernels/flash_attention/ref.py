"""Pure-jnp oracle for the flash-attention kernel: masked softmax attention
with optional causality and sliding window, fp32 accumulation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None):
    """q: (B,Sq,H,hd) — k,v: (B,Skv,H,hd) — positions are implicit
    (q row i has position i + (Skv − Sq), keys 0..Skv−1)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = scale or 1.0 / (hd ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        ok &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(ok[None, None], logits, -2.0e9)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
