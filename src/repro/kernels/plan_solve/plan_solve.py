"""Pallas TPU kernel: fused masked-objective evaluation + joint argmin for
the device-resident constrained boundary solve.

The batched planner reduces, per stream, a candidate grid of monotone
boundary tuples over S tier subsets to one winner: the feasible tuple of
minimum expected cost. Host-side this is the ``itertools`` enumeration in
``core.shp._solve_constrained_enum``; here the whole reduction is one
kernel pass.

Grid: (M/bm, S) — program (i, s) evaluates one stream block against one
subset. The per-step term rows (bm, J, C) are expanded onto the G monotone
tuples with *static one-hot matmuls* (MXU-friendly: ``onehot[j]`` is the
(C, Gp) 0/1 matrix with ``onehot[j][combos[g, j], g] = 1``), so the
gather becomes a dot product and the per-tuple sum accumulates in step
order — the same adds the jnp reference performs. Feasibility (per-step
candidate masks, pairwise lower bounds, the exact latency budget) is
accumulated as an infeasibility count and lifted to +inf after the sums.
The s axis is sequential and the output block is revisited per subset
(like ``tier_assign``'s per-tier counts): a running first-minimum-wins
min/argmin accumulates across subsets, emitting the joint (S·G) argmin
per stream in one pass, encoded ``s·G + g``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(fs_ref, const_ref, cand_ref, mask_ref, lb_ref, dl_ref, rb_ref,
            onehot_ref, val_ref, idx_ref, *, j_steps: int, g_real: int,
            masked: bool):
    s = pl.program_id(1)
    bm = fs_ref.shape[0]
    gp = onehot_ref.shape[2]
    dtype = fs_ref.dtype
    tot = jnp.zeros((bm, gp), dtype)
    for j in range(j_steps):
        tot = tot + jnp.dot(fs_ref[:, 0, j, :], onehot_ref[j],
                            preferred_element_type=dtype)
    if masked:
        bad = jnp.zeros((bm, gp), dtype)
        for j in range(j_steps):
            bad = bad + jnp.dot(1.0 - mask_ref[:, 0, j, :], onehot_ref[j],
                                preferred_element_type=dtype)
        for j in range(1, j_steps):
            prev = jnp.dot(cand_ref[:, 0, :], onehot_ref[j - 1],
                           preferred_element_type=dtype)
            lbd = jnp.dot(lb_ref[:, 0, j - 1, :], onehot_ref[j],
                          preferred_element_type=dtype)
            bad = bad + (prev < lbd * (1 - 1e-12) - 1e-12).astype(dtype)
        acc = jnp.zeros((bm, gp), dtype)
        for j in range(j_steps):
            acc = acc + jnp.dot(dl_ref[:, 0, j, :], onehot_ref[j],
                                preferred_element_type=dtype)
        budget = (rb_ref[:, 0, 0] + rb_ref[:, 0, 1])[:, None]
        bad = bad + (acc > budget).astype(dtype)
    for p in range(const_ref.shape[2]):
        tot = tot + const_ref[:, 0, p][:, None]
    gi = jax.lax.broadcasted_iota(jnp.int32, (bm, gp), 1)
    infeas = gi >= g_real
    if masked:
        infeas = infeas | (bad > 0)
    tot = jnp.where(infeas, jnp.inf, tot)
    vmin = jnp.min(tot, axis=1)
    amin = jnp.argmin(tot, axis=1).astype(jnp.int32)
    enc = s * g_real + amin

    @pl.when(s == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    upd = vmin < val_ref[:, 0]
    val_ref[:, 0] = jnp.where(upd, vmin, val_ref[:, 0])
    idx_ref[:, 0] = jnp.where(upd, enc, idx_ref[:, 0])


def plan_solve_pallas(fs, const, cand, mask, lb, deltas, rhs_atol, onehot,
                      *, g_real: int, masked: bool, block_m: int = 8,
                      interpret: bool = False):
    """fs (M, S, J, C); const (M, S, P); cand (M, S, C); mask (M, S, J, C)
    in {0, 1}; lb (M, S, max(J-1,1), C); deltas (M, S, J, C);
    rhs_atol (M, S, 2); onehot (J, C, Gp) with the last Gp − g_real
    columns zero (padding). M must be a multiple of ``block_m``.
    Returns (best (M,), idx (M,) int32 = s·G + g)."""
    m, s, j_steps, c = fs.shape
    assert m % block_m == 0, (m, block_m)
    val, idx = pl.pallas_call(
        functools.partial(_kernel, j_steps=j_steps, g_real=g_real,
                          masked=masked),
        grid=(m // block_m, s),
        in_specs=[
            pl.BlockSpec((block_m, 1, j_steps, c), lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((block_m, 1, const.shape[2]),
                         lambda i, t: (i, t, 0)),
            pl.BlockSpec((block_m, 1, c), lambda i, t: (i, t, 0)),
            pl.BlockSpec((block_m, 1, mask.shape[2], c),
                         lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((block_m, 1, lb.shape[2], c),
                         lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((block_m, 1, j_steps, c), lambda i, t: (i, t, 0, 0)),
            pl.BlockSpec((block_m, 1, 2), lambda i, t: (i, t, 0)),
            pl.BlockSpec(onehot.shape, lambda i, t: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, t: (i, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((m, 1), fs.dtype),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ),
        interpret=interpret,
    )(fs, const, cand, mask, lb, deltas, rhs_atol, onehot)
    return val[:, 0], idx[:, 0]
