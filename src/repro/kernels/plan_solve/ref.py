"""Pure-jnp oracle for the fused plan-solve reduction.

Solvers over one tier subset's sorted candidate grid (M, C). Every
reduction is a running strict-< update over static column slices or a
fast ``min`` reduce — the obvious formulations (``jnp.sort``,
``jnp.take`` over a combo table, ``jnp.argmin``, scatter) all lower to
serial scalar loops on XLA CPU and cost 10–50× the arithmetic they
feed; on this backend wall-clock tracks the *operation count*, so the
solvers are written to minimize materialized ops.

* ``dp_arr`` — the monotone running-minimum DP
  (``core.shp._solve_unconstrained``): exact when no pairwise lower
  bound or latency budget couples the boundaries.
* ``tri_arr`` — the exact joint J=2 enumeration
  (``core.shp._solve_constrained_enum``): a static loop over the
  destination candidate; each step is a fused masked reduction over
  the origin prefix slice. The middle-tier capacity law and the
  latency budget are evaluated from the candidate values (the host
  computes them on the grid and gathers — same elementwise ops on the
  same bits, so feasible totals agree bitwise).
* ``single_arr`` — the J=1 case, fully vectorized.
* ``enum_solve`` — the gathered tuple enumeration kept for J=3 (4-tier
  constrained solves are test-scale) and as the Pallas kernel's shape
  contract.

All mirror the host arithmetic: per-step values summed in step order,
masks folded as +inf by the caller (``BoundaryObjective.terms``'s
convention), first-minimum-wins tie-breaks in the host's iteration
order. (``tri_arr`` resolves exact ties between equal-cost tuples
destination-major where the host resolves them origin-major; tied
tuples carry bitwise-equal totals.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_BIG_I = np.int32(2 ** 30)


def first_argmin(x, axis=-1):
    """(min, first index attaining it) without ``jnp.argmin`` (a scalar
    loop on CPU): min + masked-iota min keeps the first-minimum-wins
    tie-break. NaN rows return index 0 with the NaN min, which the
    callers' strict-< folds then discard — the same outcome as the
    host's NaN-discarding comparisons."""
    x = jax.lax.optimization_barrier(x)  # pin one materialization: XLA
    # may otherwise recompute x with different fma contraction in the
    # min- and eq-consumers, so the minimum never "hits" its own value
    vmin = jnp.min(x, axis=axis)
    iota = jnp.arange(x.shape[axis], dtype=jnp.int32)
    shape = [1] * x.ndim
    shape[axis] = x.shape[axis]
    hit = jnp.where(x == jnp.expand_dims(vmin, axis), iota.reshape(shape),
                    _BIG_I)
    amin = jnp.min(hit, axis=axis)
    return vmin, jnp.where(amin == _BIG_I, 0, amin)


def pick_col(x, idx):
    """x[:, idx] per row via a one-hot reduce (dynamic gather is a
    scalar loop on CPU). ``x`` (M, C), ``idx`` (M,) int."""
    onehot = idx[:, None] == jnp.arange(x.shape[1], dtype=idx.dtype)
    zero = jnp.zeros((), x.dtype)
    return jnp.sum(jnp.where(onehot, x, zero), axis=1)


def _cummin_with_arg(g):
    """Column-sliced ``shp._cummin_with_arg`` over (M, C): running
    minima and the column where each was first attained (strict-<
    update, first minimum wins)."""
    c = g.shape[1]
    best = g[:, 0]
    barg = jnp.zeros(best.shape, jnp.int32)
    vals, args = [best], [barg]
    for j in range(1, c):
        col = g[:, j]
        upd = col < best
        best = jnp.where(upd, col, best)
        barg = jnp.where(upd, jnp.int32(j), barg)
        vals.append(best)
        args.append(barg)
    return jnp.stack(vals, axis=1), jnp.stack(args, axis=1)


def dp_arr(fs):
    """Monotone DP over per-step term grids ``fs`` (list of J (M, C)).
    Bitwise the host DP: g_j = f_j + cummin(g_{j-1}). Returns
    (interior (M,), sel list of J (M,) int32 candidate indices)."""
    g = fs[0]
    args = []
    for j in range(1, len(fs)):
        vals, arg = _cummin_with_arg(g)
        args.append(arg)
        g = fs[j] + vals
    interior, best_c = first_argmin(g)
    sel_rev = [best_c]
    for arg in reversed(args):
        best_c = pick_col(arg, best_c)
        sel_rev.append(best_c)
    return interior, list(reversed(sel_rev))


def pair_lb_law(cval, cap_m, kf):
    """Traced ``BoundaryObjective.pair_lower_bound`` evaluated at
    candidate values ``cval``."""
    slack = 1.0 - cap_m / jnp.minimum(cval, kf)
    lb = cval * jnp.maximum(0.0, slack)
    return jnp.where(jnp.isfinite(cap_m) & (cval > 0),
                     jnp.nan_to_num(lb, nan=0.0, posinf=0.0), 0.0)


def value_argmin(f, cand):
    """(min of f, boundary value attaining it) over an *unsorted* grid:
    among minimal-cost candidates the smallest boundary value wins —
    exactly the host's first-index tie-break on its value-sorted grid.
    All-inf (or NaN-poisoned) rows return +inf values, which the
    callers' strict-< folds discard."""
    f = jax.lax.optimization_barrier(f)  # see first_argmin: pin one
    # materialization so the eq-consumer sees the min's exact bits
    vmin = jnp.min(f, axis=1)
    bval = jnp.min(jnp.where(f == vmin[:, None], cand, jnp.inf), axis=1)
    return vmin, bval


def single_arr(f0, cand, *, alpha=None, rhs=None, atol=None):
    """Exact J=1 reduction: masked minimum over the (unsorted) candidate
    grid (the budget, when active, is the per-candidate value test
    δ_0 = α_0·value ≤ rhs + atol). Returns (interior (M,), [bval])."""
    if alpha is not None:
        ok = cand * alpha[0][:, None] <= (rhs + atol)[:, None]
        f0 = jnp.where(ok, f0, jnp.inf)
    interior, bval = value_argmin(f0, cand)
    return interior, [bval]


def tri_arr(f0, f1, cand, *, kf=None, cap_m=None, alpha=None, rhs=None,
            atol=None):
    """Exact J=2 enumeration as a static destination loop over (M, C)
    grids — *unsorted* grids welcome: monotonicity (origin value ≤
    destination value) is enforced as a mask, so the value-pair set
    enumerated is identical to the host's index-monotone tuples over
    the sorted grid. Origins are further filtered by the lower-bound
    law (middle-tier capacity ``cap_m``) and the latency budget
    (δ_j = α_j·value, Σδ ≤ rhs + atol). The winner's interior is
    assembled with the same adds as the host: f0 + f1. Returns
    (interior (M,), sel [c0, c1])."""
    c = cand.shape[1]
    # pin one materialization of the inputs: the origin-recovery pass
    # below matches f0 against the tracked minimum by equality, which
    # only holds if XLA does not recompute f0 with different fma
    # contraction in different consumers (see first_argmin)
    f0, f1, cand = jax.lax.optimization_barrier((f0, f1, cand))
    budget_cap = (rhs + atol) if alpha is not None else None
    best = jnp.full(f0.shape[:1], jnp.inf, f0.dtype)
    bm0 = jnp.full(best.shape, jnp.inf, f0.dtype)
    bv1 = jnp.zeros(best.shape, f0.dtype)
    for c1 in range(c):
        c1v = cand[:, c1]
        feas = cand <= c1v[:, None]
        if cap_m is not None:
            lbd = pair_lb_law(c1v, cap_m, kf) * (1 - 1e-12) - 1e-12
            feas = feas & (cand >= lbd[:, None])
        if alpha is not None:
            acc = cand * alpha[0][:, None] + (c1v * alpha[1])[:, None]
            feas = feas & (acc <= budget_cap[:, None])
        m0 = jnp.min(jnp.where(feas, f0, jnp.inf), axis=1)
        tot = m0 + f1[:, c1]
        upd = tot < best
        best = jnp.where(upd, tot, best)
        bm0 = jnp.where(upd, m0, bm0)
        bv1 = jnp.where(upd, c1v, bv1)
    # recover the winning origin in one pass: re-apply the winner's
    # feasibility at destination bv1 and pick the smallest candidate
    # value attaining the tracked origin minimum bm0
    feas = cand <= bv1[:, None]
    if cap_m is not None:
        lbd = pair_lb_law(bv1, cap_m, kf) * (1 - 1e-12) - 1e-12
        feas = feas & (cand >= lbd[:, None])
    if alpha is not None:
        acc = cand * alpha[0][:, None] + (bv1 * alpha[1])[:, None]
        feas = feas & (acc <= budget_cap[:, None])
    bv0 = jnp.min(jnp.where(feas & (f0 == bm0[:, None]), cand, jnp.inf),
                  axis=1)
    bv0 = jnp.where(jnp.isfinite(bv0), bv0, 0.0)
    return best, [bv0, bv1]


def enum_solve(fs, consts, combos, *, cand, kf=None, pair_caps=None,
               alpha=None, rhs=None, atol=None):
    """Gathered exact enumeration over monotone tuples ``combos``
    (G, J) on stacked (M, S, J, C) tensors — the J = 3 path (test-scale
    fleets) and the shape contract shared with the Pallas kernel.
    ``consts`` are ordered (M, S) addends (+inf = infeasible subset).
    Returns (val (M,), s_idx (M,), sel (M, J))."""
    m, s, j_steps, c = fs.shape
    combos = np.asarray(combos)
    g = combos.shape[0]
    idxs = [jnp.asarray(combos[:, j]) for j in range(j_steps)]
    cvals = [jnp.take(cand, idxs[j], axis=2) for j in range(j_steps)]
    tot = None
    for j in range(j_steps):
        gj = jnp.take(fs[:, :, j, :], idxs[j], axis=2)
        tot = gj if tot is None else tot + gj
    bad = None
    if pair_caps is not None:
        for j in range(1, j_steps):
            cap_m = pair_caps[j - 1]
            if cap_m is None:
                continue
            lbd = pair_lb_law(cvals[j], cap_m[:, :, None],
                              kf[:, None, None])
            viol = cvals[j - 1] < lbd * (1 - 1e-12) - 1e-12
            bad = viol if bad is None else bad | viol
    if alpha is not None:
        acc = None
        for j in range(j_steps):
            dj = cvals[j] * alpha[:, :, j][:, :, None]
            acc = dj if acc is None else acc + dj
        over = acc > (rhs + atol)[:, :, None]
        bad = over if bad is None else bad | over
    for cc in consts:
        tot = tot + cc[:, :, None]
    if bad is not None:
        tot = jnp.where(bad, jnp.inf, tot)
    val, idx = first_argmin(tot.reshape(m, s * g))
    s_idx = idx // g
    sel = jnp.asarray(combos, jnp.int32)[idx % g]
    return val, s_idx, sel
