"""Dispatch wrapper for the fused plan-solve reduction.

``enum_solve``/``dp_solve`` are traceable (call them inside ``jax.jit``):
the combo tables and one-hot expansion matrices are static constants
baked into the program. The Pallas kernel path covers the heavy joint
enumeration (compiled on TPU, interpret elsewhere — correctness only);
the default elsewhere is the pure-jnp reference, which XLA fuses into
the surrounding solver program. The cheap DP reduction always runs as
jnp.

Float policy: the reduction runs in whatever dtype the term tensors
carry — float64 under ``jax.experimental.enable_x64`` (the
oracle-matching CPU path), float32 on TPU where Pallas has no f64
(documented in the README; plans then match the NumPy oracle within
float32 tolerance, not ulps).
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .plan_solve import plan_solve_pallas


@functools.lru_cache(maxsize=None)
def monotone_combos(c: int, j: int) -> np.ndarray:
    """(G, J) int64 — monotone index tuples over a C-candidate grid, in
    ``itertools.combinations_with_replacement`` (lexicographic) order —
    the host enum solver's tuple order, so argmin precedence agrees."""
    return np.asarray(
        list(itertools.combinations_with_replacement(range(c), j)),
        np.int64).reshape(-1, j)


@functools.lru_cache(maxsize=None)
def _onehots(c: int, j: int, gp: int, dtype_name: str) -> np.ndarray:
    combos = monotone_combos(c, j)
    g = combos.shape[0]
    oh = np.zeros((j, c, gp), dtype_name)
    for jj in range(j):
        oh[jj, combos[:, jj], np.arange(g)] = 1.0
    return oh


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - backend probing
        return False


def enum_solve(fs, consts, *, cand, kf=None, pair_caps=None, alpha=None,
               rhs=None, atol=None, masks=None, use_pallas: bool = False,
               block_m: int = 8):
    """Joint masked argmin over one subset run — see ``ref.enum_solve``
    for the contract. ``masks`` (length-J list of (M, S, C) bool or
    None) is only consumed by the Pallas path; the jnp reference
    expects per-candidate masks pre-folded into ``fs`` as +inf (the
    host solver's convention — the Pallas MXU path needs finite terms
    because masked values would turn the one-hot matmul into inf·0).
    Returns (val (M,), s_idx (M,), sel (M, J))."""
    m, s, j_steps, c = fs.shape
    if not use_pallas:
        return ref.enum_solve(fs, consts, monotone_combos(c, j_steps),
                              cand=cand, kf=kf, pair_caps=pair_caps,
                              alpha=alpha, rhs=rhs, atol=atol)
    combos = monotone_combos(c, j_steps)
    g = combos.shape[0]
    gp = -(-g // 128) * 128
    dtype = fs.dtype
    mp = -(-m // block_m) * block_m
    pad_m = mp - m

    def _pad(x):
        return jnp.pad(x, ((0, pad_m),) + ((0, 0),) * (x.ndim - 1))

    masked = (masks is not None or pair_caps is not None
              or alpha is not None)
    mask_grid = jnp.ones((m, s, j_steps, c), dtype)
    if masks is not None:
        mask_grid = jnp.stack(
            [jnp.ones((m, s, c), dtype) if mk is None else mk.astype(dtype)
             for mk in masks], axis=2)
    lb_grid = jnp.zeros((m, s, max(j_steps - 1, 1), c), dtype)
    if pair_caps is not None:
        lbs = []
        for j in range(1, j_steps):
            cap_m = pair_caps[j - 1]
            lbs.append(jnp.zeros((m, s, c), dtype) if cap_m is None
                       else ref.pair_lb_law(cand, cap_m[:, :, None],
                                            kf[:, None, None]))
        lb_grid = jnp.stack(lbs, axis=2)
    dl_grid = jnp.zeros((m, s, j_steps, c), dtype)
    if alpha is not None:
        dl_grid = cand[:, :, None, :] * alpha[:, :, :, None]
        rb = jnp.stack([rhs, atol], axis=2)
    else:
        rb = jnp.stack([jnp.full((m, s), jnp.inf, dtype),
                        jnp.zeros((m, s), dtype)], axis=2)
    const_arr = jnp.stack([jnp.asarray(cc, dtype) for cc in consts], axis=2)
    onehot = jnp.asarray(_onehots(c, j_steps, gp, np.dtype(dtype).name))
    val, idx = plan_solve_pallas(
        _pad(fs), _pad(const_arr), _pad(cand), _pad(mask_grid),
        _pad(lb_grid), _pad(dl_grid), _pad(rb), onehot, g_real=g,
        masked=masked, block_m=block_m, interpret=not on_tpu())
    val, idx = val[:m], idx[:m]
    s_idx = idx // g
    sel = jnp.asarray(combos, jnp.int32)[idx % g]
    return val, s_idx, sel
