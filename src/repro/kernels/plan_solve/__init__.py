"""Fused masked-objective + joint-argmin reduction for the device-resident
constrained N-tier planner (``core.shp_jax``)."""
from .ops import enum_solve, monotone_combos, on_tpu  # noqa: F401
