"""Pure-jnp oracle for the 2-D batched threshold filter kernel."""
from __future__ import annotations

import jax.numpy as jnp


def batched_topk_filter(scores, thresholds, block_n: int):
    scores = scores.astype(jnp.float32)
    m, n = scores.shape
    n_tiles = n // block_n
    thr = thresholds.astype(jnp.float32).reshape(m, 1)
    mask = (scores > thr).astype(jnp.int8)
    tiles = scores.reshape(m, n_tiles, block_n)
    counts = (tiles > thr[:, :, None]).sum(axis=2).astype(jnp.int32)
    tmax = tiles.max(axis=2)
    return mask, counts, tmax
