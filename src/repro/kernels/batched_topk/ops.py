"""Public wrapper for the batched threshold filter: pad the trailing axis,
run the 2-D kernel (interpret off-TPU), strip the padding.

The composed survivor-extraction + exact per-stream merge lives in
``repro.streams.engine.filtered_update`` (streams layer sits above kernels).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .batched_topk import batched_topk_pallas

NEG_BIG = -1e30


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("block_n", "use_pallas"))
def batched_topk_filter(scores, thresholds, *, block_n: int = 512,
                        use_pallas: bool = True):
    """scores (M, N) vs per-stream bars (M,) → (mask int8 (M, N), counts
    (M, N/bn) int32, tile_max (M, N/bn) f32).

    Padding columns are filled with ``NEG_BIG`` (finite): they are stripped
    from ``mask`` but still counted by ``counts`` for streams whose bar is
    below NEG_BIG (i.e. an unfull reservoir, bar = -inf) — same convention
    as the single-stream ``kernels.topk_filter``.
    """
    m, n = scores.shape
    bn = min(block_n, max(n, 128))
    pad = (-n) % bn
    sp = jnp.pad(scores.astype(jnp.float32), ((0, 0), (0, pad)),
                 constant_values=NEG_BIG)
    thr = thresholds.astype(jnp.float32)
    if use_pallas:
        mask, counts, tmax = batched_topk_pallas(
            sp, thr, block_n=bn, interpret=not _on_tpu())
    else:
        mask, counts, tmax = ref.batched_topk_filter(sp, thr, bn)
    return mask[:, :n], counts, tmax
