"""Pallas TPU kernel: 2-D batched threshold filter for M concurrent streams.

The multi-tenant engine (``repro.streams.engine``) maintains one reservoir
per stream. Its hot path is the same scan as ``kernels.topk_filter`` — rank
every arriving candidate against the reservoir "bar" (current K-th score) —
but over a whole fleet at once: scores (M, N) against per-stream bars (M,).
Almost all candidates fail everywhere; the rare survivors go through the
exact per-stream merge.

Grid: (M, N/bn) — one program per (stream, tile) pair. Each program reads
its stream's bar plus one score tile from VMEM and emits the survivor mask
and a per-(stream, tile) count and maximum, so the host-side exact merge
only touches tiles that actually contain survivors. Embarrassingly
parallel, bandwidth-bound — one pass over HBM regardless of M.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scores_ref, thr_ref, mask_ref, count_ref, tmax_ref):
    s = scores_ref[...].astype(jnp.float32)  # (1, bn)
    thr = thr_ref[0]  # this stream's reservoir bar
    hit = s > thr
    mask_ref[...] = hit.astype(jnp.int8)
    count_ref[0, 0] = hit.sum().astype(jnp.int32)
    tmax_ref[0, 0] = s.max()


def batched_topk_pallas(scores, thresholds, *, block_n: int = 512,
                        interpret: bool = False):
    """scores: (M, N) float — thresholds: (M,) float32, one bar per stream.
    Returns (mask (M, N) int8, counts (M, N/bn) int32, tile_max (M, N/bn) f32).
    """
    m, n = scores.shape
    assert n % block_n == 0, (n, block_n)
    n_tiles = n // block_n
    thr = thresholds.astype(jnp.float32).reshape(m)
    return pl.pallas_call(
        _kernel,
        grid=(m, n_tiles),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n_tiles), jnp.int32),
            jax.ShapeDtypeStruct((m, n_tiles), jnp.float32),
        ),
        interpret=interpret,
    )(scores, thr)
