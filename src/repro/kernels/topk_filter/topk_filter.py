"""Pallas TPU kernel: streaming threshold filter for top-K maintenance.

The paper's Fig. 2/3 inner loop ranks every arriving document against the
reservoir. At accelerator scale the hot part is scanning a large score
vector against the current K-th score (the reservoir "bar"): almost all
candidates fail, the rare survivors go through the exact (tiny) merge in
``core.topk``. This kernel is that scan — one pass over HBM, tiled through
VMEM, emitting the survivor mask plus per-tile counts and maxima (the
maxima let the host skip entire tiles on the next refinement pass).

Grid: (N/bn,) — embarrassingly parallel, bandwidth-bound. The 2-D sibling
``repro.kernels.batched_topk`` runs the same scan for M concurrent streams
against per-stream bars (grid (M, N/bn)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scores_ref, thr_ref, mask_ref, count_ref, tmax_ref):
    s = scores_ref[...].astype(jnp.float32)  # (bn,)
    thr = thr_ref[0]
    hit = s > thr
    mask_ref[...] = hit.astype(jnp.int8)
    count_ref[0] = hit.sum().astype(jnp.int32)
    tmax_ref[0] = s.max()


def topk_filter_pallas(scores, threshold, *, block_n: int = 4096,
                       interpret: bool = False):
    """scores: (N,) float — threshold: () float32.
    Returns (mask (N,) int8, counts (N/bn,) int32, tile_max (N/bn,) f32)."""
    n = scores.shape[0]
    assert n % block_n == 0, (n, block_n)
    n_tiles = n // block_n
    thr = jnp.reshape(threshold.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
        ),
        interpret=interpret,
    )(scores, thr)
