"""Pure-jnp oracle for the top-K threshold filter kernel."""
from __future__ import annotations

import jax.numpy as jnp


def topk_filter(scores, threshold, block_n: int):
    scores = scores.astype(jnp.float32)
    n = scores.shape[0]
    n_tiles = n // block_n
    mask = (scores > threshold).astype(jnp.int8)
    tiles = scores.reshape(n_tiles, block_n)
    counts = (tiles > threshold).sum(axis=1).astype(jnp.int32)
    tmax = tiles.max(axis=1)
    return mask, counts, tmax
