"""Public wrapper: pad, run kernel (interpret off-TPU), and the composed
``filter_then_merge`` used by the streaming reservoir at batch scale."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .topk_filter import topk_filter_pallas

NEG_BIG = -1e30


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("block_n", "use_pallas"))
def topk_filter(scores, threshold, *, block_n: int = 4096,
                use_pallas: bool = True):
    """scores (N,) vs scalar threshold → (mask int8 (N,), counts, tile_max).

    NaN scores are demoted to the pad value before the kernel runs: a
    NaN fails every compare (it could never pass the bar anyway) but
    would otherwise poison ``tile_max`` with NaN — callers that want NaN
    *accounted for* rather than dropped quarantine upstream
    (``streams.engine`` counts them as ``scores_quarantined``)."""
    n = scores.shape[0]
    bn = min(block_n, max(n, 128))
    pad = (-n) % bn
    sp = jnp.pad(scores.astype(jnp.float32), ((0, pad),),
                 constant_values=NEG_BIG)
    sp = jnp.where(jnp.isnan(sp), NEG_BIG, sp)
    if use_pallas:
        mask, counts, tmax = topk_filter_pallas(
            sp, jnp.asarray(threshold), block_n=bn, interpret=not _on_tpu())
    else:
        mask, counts, tmax = ref.topk_filter(sp, jnp.asarray(threshold), bn)
    return mask[:n], counts, tmax


def filter_then_merge(state, scores, ids, *, block_n: int = 4096):
    """Batched reservoir update for large score batches: kernel-filter the
    stream against the current bar, then exact-merge only survivors.

    Equivalent to ``core.topk.update`` (tests assert equality) but touches
    each candidate once in VMEM instead of sorting the whole batch.
    """
    from repro.core import topk as topk_mod
    k = state.scores.shape[0]
    thr = state.scores[-1]  # -inf while unfull ⇒ filter passes everything
    mask, counts, _ = topk_filter(scores, thr, block_n=block_n)
    # survivors: at most... all of them in the worst case; bound by k
    # candidates that could enter = top-(k) of the batch above the bar.
    surv_scores = jnp.where(mask > 0, scores, -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(surv_scores, min(k, scores.shape[0]))
    top_ids = jnp.where(jnp.isfinite(top_scores), ids[top_idx], -1)
    return topk_mod.update(state, top_scores,
                           jnp.where(top_ids >= 0, top_ids, -(2**31) + 1))
