"""Per-stream transaction metering for the fleet engine, reconciled
against the analytic per-stream expectations.

Array-of-ledgers layout: one row per stream, so recording a whole bucket's
update is a handful of vectorized scatter-adds instead of M python ledger
objects. ``ledger(i)`` materializes a classic ``tiers.Ledger`` view for one
stream; ``reconcile`` compares actual write counts to the batched write law
(``shp.expected_cum_writes_batched`` — eq. 11/12 when batch = 1).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core import shp
from repro.core.tiers import Ledger

TIER_A, TIER_B = 0, 1


class FleetMeter:
    """Vectorized per-stream ledgers for M streams.

    ``rs[i]`` is stream i's changeover index: a written doc with local
    stream index < r lands in tier A, else tier B (Algorithm C). Streams
    flagged in ``migrate`` bulk-migrate A→B when the stream position
    crosses r (Fig. 3): the meter counts the migrated docs (the
    ``SimResult.migrated`` convention — migration is its own counter, not
    extra reads/writes) and attributes every later delete and every final
    read to tier B.
    """

    def __init__(self, ks: Sequence[int], rs: Sequence[float],
                 migrate: Sequence[bool] | None = None):
        m = len(ks)
        self.ks = np.asarray(ks, np.int64)
        self.rs = np.asarray(rs, np.float64)
        assert self.rs.shape[0] == m
        self.migrate = (np.zeros(m, bool) if migrate is None
                        else np.asarray(migrate, bool))
        self.migrated = np.zeros(m, bool)  # crossed r yet?
        self.observed = np.zeros(m, np.int64)
        self.writes = np.zeros((m, 2), np.int64)
        self.reads = np.zeros((m, 2), np.int64)
        self.deletes = np.zeros((m, 2), np.int64)
        self.migrations = np.zeros(m, np.int64)

    @property
    def m(self) -> int:
        return self.ks.shape[0]

    # ---- recording ------------------------------------------------------

    def record_update(self, stream_rows, doc_ids, wrote,
                      evicted_ids=None, state_ids=None) -> None:
        """Account one engine step for a bucket.

        stream_rows (Mb,): global stream indices of the bucket's rows.
        doc_ids (Mb, W) int: per-stream local doc indices, -1 = padding.
        wrote (Mb, W) bool: reservoir-entry mask from the engine.
        evicted_ids (Mb, K) int, optional: local doc indices evicted by this
        step (-1 = none), for per-tier delete accounting.
        state_ids (Mb, K) int, optional: post-step reservoir ids — needed to
        count the docs that bulk-migrate when a migrating stream crosses r.
        """
        stream_rows = np.asarray(stream_rows, np.int64)
        doc_ids = np.asarray(doc_ids)
        wrote = np.asarray(wrote, bool)
        r = self.rs[stream_rows][:, None]
        in_a = doc_ids < r
        np.add.at(self.observed, stream_rows, (doc_ids >= 0).sum(1))
        # writes: doc index == arrival position, so index < r always means
        # "written before the migration point" — valid with or without it
        np.add.at(self.writes, (stream_rows, TIER_A), (wrote & in_a).sum(1))
        np.add.at(self.writes, (stream_rows, TIER_B), (wrote & ~in_a).sum(1))
        if evicted_ids is not None:
            evicted_ids = np.asarray(evicted_ids)
            ev = evicted_ids >= 0
            # after the bulk migration nothing lives in A anymore
            ev_a = ev & (evicted_ids < r) & ~self.migrated[stream_rows][:, None]
            np.add.at(self.deletes, (stream_rows, TIER_A), ev_a.sum(1))
            np.add.at(self.deletes, (stream_rows, TIER_B), (ev & ~ev_a).sum(1))
        if state_ids is not None:
            self._maybe_migrate(stream_rows, np.asarray(state_ids))

    def _maybe_migrate(self, stream_rows, state_ids) -> None:
        """Trigger the bulk A→B migration for streams whose position just
        crossed r: every reservoir resident with index < r moves (batch
        granularity — with W=1 this matches the simulator exactly)."""
        crossing = (self.migrate[stream_rows] & ~self.migrated[stream_rows]
                    & (self.observed[stream_rows]
                       >= np.ceil(self.rs[stream_rows])))
        if not np.any(crossing):
            return
        rows = stream_rows[crossing]
        resident_a = ((state_ids[crossing] >= 0)
                      & (state_ids[crossing] < self.rs[rows][:, None]))
        np.add.at(self.migrations, rows, resident_a.sum(1))
        self.migrated[rows] = True

    def record_reads(self, stream_rows, doc_ids) -> None:
        """Account the end-of-window top-K read (the consumer side)."""
        stream_rows = np.asarray(stream_rows, np.int64)
        doc_ids = np.asarray(doc_ids)
        if doc_ids.ndim != 2:
            doc_ids = doc_ids.reshape(-1, 1)
        r = self.rs[stream_rows][:, None]
        valid = doc_ids >= 0
        # migrated streams serve the final read entirely from tier B
        in_a = valid & (doc_ids < r) & ~self.migrated[stream_rows][:, None]
        np.add.at(self.reads, (stream_rows, TIER_A), in_a.sum(1))
        np.add.at(self.reads, (stream_rows, TIER_B), (valid & ~in_a).sum(1))

    # ---- reconciliation -------------------------------------------------

    def expected_writes(self, batch: int = 1) -> np.ndarray:
        """(M,) analytic E[total reservoir writes] at each stream's current
        observed length — the batched write law, eq. 11/12 when batch=1.
        Streams that observed nothing expect nothing."""
        out = np.zeros(self.m, np.float64)
        seen = np.maximum(self.observed, 1)
        for k in np.unique(self.ks):
            sel = self.ks == k
            out[sel] = shp.expected_cum_writes_batched(
                seen[sel] - 1, int(k), int(batch))
        return np.where(self.observed > 0, out, 0.0)

    def reconcile(self, batch: int = 1) -> Dict[str, np.ndarray | float]:
        """Actual vs analytic writes per stream. ``mean_rel_err`` is the
        fleet-level sanity number: per-stream counts are single samples of
        the expectation, but averaged over the fleet they concentrate."""
        expected = self.expected_writes(batch=batch)
        actual = self.writes.sum(1).astype(np.float64)
        rel = (actual - expected) / np.maximum(expected, 1e-12)
        return {
            "actual": actual,
            "expected": expected,
            "rel_err": rel,
            "mean_rel_err": float(np.mean(rel)),
            "fleet_actual": float(actual.sum()),
            "fleet_expected": float(expected.sum()),
        }

    # ---- classic per-stream view ---------------------------------------

    def ledger(self, i: int) -> Ledger:
        led = Ledger()
        led.writes = self.writes[i].copy()
        led.reads = self.reads[i].copy()
        led.deletes = self.deletes[i].copy()
        led.migrations = int(self.migrations[i])
        return led
