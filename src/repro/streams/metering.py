"""Per-stream transaction metering for the fleet engine, reconciled
against the analytic per-stream expectations.

Array-of-ledgers layout: one row per stream, so recording a whole bucket's
update is a handful of vectorized scatter-adds instead of M python ledger
objects. Streams may place across heterogeneous tier depths: each stream
carries a non-decreasing boundary vector (padded with +inf up to the
fleet-wide maximum), and all per-tier arrays are (M, T_max). ``ledger(i)``
materializes a classic ``tiers.Ledger`` view for one stream; ``reconcile``
compares actual write counts to the batched write law
(``shp.expected_cum_writes_batched`` — eq. 11/12 when batch = 1).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core import compat, shp
from repro.core.tiers import Ledger


def __getattr__(name: str):
    # the two-tier constants now live in core.compat — keep the legacy
    # module attributes importable through the single deprecation pathway
    if name in ("TIER_A", "TIER_B"):
        compat.deprecated(f"streams.metering.{name}",
                          f"repro.core.compat.{name}")
        return getattr(compat, name)
    raise AttributeError(name)


def _pad_boundaries(boundaries: Sequence[Sequence[float]]) -> np.ndarray:
    """(M, B_max) float64, each row non-decreasing, padded with +inf so
    shallower streams simply never reach the deeper tiers."""
    bmax = max(len(b) for b in boundaries)
    out = np.full((len(boundaries), bmax), np.inf, np.float64)
    for i, bs in enumerate(boundaries):
        bs = tuple(float(b) for b in bs)
        if any(b2 < b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(f"stream {i}: boundaries must be non-decreasing")
        out[i, : len(bs)] = bs
    return out


class FleetMeter:
    """Vectorized per-stream ledgers for M streams.

    ``boundaries[i]`` is stream i's changeover vector: a written doc with
    local stream index in [b_t, b_{t+1}) lands in tier t (Algorithm C;
    the classic two-tier case is a single boundary r). Streams flagged in
    ``migrate`` cascade residents of tier t-1 into tier t when the stream
    position crosses b_t (Fig. 3): the meter counts the migrated docs (the
    ``SimResult.migrated`` convention — migration is its own counter, not
    extra reads/writes) and attributes every later delete and every final
    read to the cascade floor.
    """

    def __init__(self, ks: Sequence[int], rs: Sequence[float] | None = None,
                 migrate: Sequence[bool] | None = None, *,
                 boundaries: Sequence[Sequence[float]] | None = None,
                 logmem: Sequence[bool] | None = None):
        m = len(ks)
        self.ks = np.asarray(ks, np.int64)
        if boundaries is None:
            if rs is None:
                raise ValueError("need rs or boundaries")
            boundaries = [compat.boundaries_from_r(r) for r in rs]
        self.boundaries = _pad_boundaries(boundaries)
        assert self.boundaries.shape[0] == m
        self.n_tiers = self.boundaries.shape[1] + 1
        self.migrate = (np.zeros(m, bool) if migrate is None
                        else np.asarray(migrate, bool))
        # O(log K) logmem backend rows: the engine reports no evictions
        # and no final-read ids for them (it stores no ids), so their
        # occupancy equals cumulative writes and the occupancy residual
        # law switches to the per-tier expected-writes form
        # (obs.residuals); logmem + migrate is rejected by the engine
        self.logmem = (np.zeros(m, bool) if logmem is None
                       else np.asarray(logmem, bool))
        self.floor = np.zeros(m, np.int64)  # highest fired boundary per stream
        self.observed = np.zeros(m, np.int64)
        self.writes = np.zeros((m, self.n_tiers), np.int64)
        self.reads = np.zeros((m, self.n_tiers), np.int64)
        self.deletes = np.zeros((m, self.n_tiers), np.int64)
        self.migrations = np.zeros(m, np.int64)
        self.relocations = np.zeros(m, np.int64)  # docs re-tiered by re-plans
        # per-tier hop accounting for cost attribution: a cascade or
        # re-plan move bills one read at the source tier and one write at
        # the destination (the simulator's ``_move_doc`` convention)
        self.mig_reads = np.zeros((m, self.n_tiers), np.int64)
        self.mig_writes = np.zeros((m, self.n_tiers), np.int64)
        self.reloc_reads = np.zeros((m, self.n_tiers), np.int64)
        self.reloc_writes = np.zeros((m, self.n_tiers), np.int64)
        # the storage rental integral: Σ_steps occupancy × docs ingested
        # that step — at chunk width 1 this equals the simulator's
        # per-doc doc-month accounting exactly (priced by obs.costs)
        self.doc_steps = np.zeros((m, self.n_tiers), np.int64)
        # current residents per tier and the running high-water mark,
        # sampled after each recorded step (exact vs the simulator at W=1)
        self.occupancy = np.zeros((m, self.n_tiers), np.int64)
        self.occupancy_hwm = np.zeros((m, self.n_tiers), np.int64)

    @property
    def m(self) -> int:
        return self.ks.shape[0]

    @property
    def rs(self) -> np.ndarray:
        """(M,) first changeover index per stream (the two-tier view)."""
        return self.boundaries[:, 0]

    @property
    def migrated(self) -> np.ndarray:
        """(M,) whether the first cascade has fired."""
        return self.floor > 0

    # ---- recording ------------------------------------------------------

    def _static_tier(self, stream_rows, doc_ids) -> np.ndarray:
        """Arrival-position tier (no cascade floor): # boundaries <= id."""
        b = self.boundaries[stream_rows]  # (Mb, B)
        return (doc_ids[:, :, None] >= b[:, None, :]).sum(axis=-1)

    def _effective_tier(self, stream_rows, doc_ids) -> np.ndarray:
        """Where the doc lives now: static tier, lifted to the cascade
        floor for streams that migrated."""
        return np.maximum(self._static_tier(stream_rows, doc_ids),
                          self.floor[stream_rows][:, None])

    @staticmethod
    def _scatter(counter, stream_rows, tiers, mask) -> None:
        rows2 = np.broadcast_to(stream_rows[:, None], tiers.shape)
        np.add.at(counter, (rows2[mask], tiers[mask]), 1)

    def record_update(self, stream_rows, doc_ids, wrote,
                      evicted_ids=None, state_ids=None) -> None:
        """Account one engine step for a bucket.

        stream_rows (Mb,): global stream indices of the bucket's rows.
        doc_ids (Mb, W) int: per-stream local doc indices, -1 = padding.
        wrote (Mb, W) bool: reservoir-entry mask from the engine.
        evicted_ids (Mb, K) int, optional: local doc indices evicted by this
        step (-1 = none), for per-tier delete accounting.
        state_ids (Mb, K) int, optional: post-step reservoir ids — needed to
        count the docs that cascade when a migrating stream crosses a
        boundary.
        """
        stream_rows = np.asarray(stream_rows, np.int64)
        doc_ids = np.asarray(doc_ids)
        wrote = np.asarray(wrote, bool)
        np.add.at(self.observed, stream_rows, (doc_ids >= 0).sum(1))
        # writes: doc index == arrival position, so the static tier is the
        # write destination with or without a later cascade
        write_tiers = self._static_tier(stream_rows, doc_ids)
        write_mask = wrote & (doc_ids >= 0)
        self._scatter(self.writes, stream_rows, write_tiers, write_mask)
        self._scatter(self.occupancy, stream_rows, write_tiers, write_mask)
        if evicted_ids is not None:
            evicted_ids = np.asarray(evicted_ids)
            # after a cascade nothing lives below the floor anymore
            ev_tiers = self._effective_tier(stream_rows, evicted_ids)
            ev_mask = evicted_ids >= 0
            self._scatter(self.deletes, stream_rows, ev_tiers, ev_mask)
            rows2 = np.broadcast_to(stream_rows[:, None], ev_tiers.shape)
            np.add.at(self.occupancy, (rows2[ev_mask], ev_tiers[ev_mask]), -1)
        if state_ids is not None:
            self._maybe_migrate(stream_rows, np.asarray(state_ids))
        # accrue the rental integral after the step's moves settled
        self.doc_steps[stream_rows] += (
            self.occupancy[stream_rows]
            * (doc_ids >= 0).sum(1).astype(np.int64)[:, None])
        self.occupancy_hwm[stream_rows] = np.maximum(
            self.occupancy_hwm[stream_rows], self.occupancy[stream_rows])

    def _maybe_migrate(self, stream_rows, state_ids) -> None:
        """Fire every boundary whose position the stream just crossed at
        once: residents hop directly to the highest crossed tier (skipping
        zero-width tiers, like the simulator and ``TieredStore`` — with
        W=1 the counts match the simulator exactly)."""
        b = self.boundaries[stream_rows]  # (Mb, B)
        crossed = np.where(np.isfinite(b),
                           self.observed[stream_rows][:, None] >= np.ceil(b),
                           False)
        target = crossed.sum(axis=1)  # highest crossed boundary per stream
        firing = self.migrate[stream_rows] & (target > self.floor[stream_rows])
        if not np.any(firing):
            return
        rows = stream_rows[firing]
        ids = state_ids[firing]
        tiers = np.maximum(
            (ids[:, :, None] >= self.boundaries[rows][:, None, :]).sum(-1),
            self.floor[rows][:, None])
        resident = (ids >= 0) & (tiers < target[firing][:, None])
        np.add.at(self.migrations, rows, resident.sum(1))
        # hop billing: read each resident out of its source tier, write
        # it into the target (``SimResult.mig_reads/mig_writes``)
        rows2 = np.broadcast_to(rows[:, None], tiers.shape)
        np.add.at(self.mig_reads, (rows2[resident], tiers[resident]), 1)
        np.add.at(self.mig_writes, (rows, target[firing]),
                  resident.sum(1))
        # occupancy: every resident below the target hops into it
        occ = self.occupancy[rows]
        tgt = target[firing]
        below = np.arange(self.n_tiers)[None, :] < tgt[:, None]
        moved = np.where(below, occ, 0).sum(1)
        occ = np.where(below, 0, occ)
        occ[np.arange(rows.shape[0]), tgt] += moved
        self.occupancy[rows] = occ
        self.floor[rows] = target[firing]

    def apply_boundaries(self, row: int, new_bounds, state_ids) -> int:
        """Swap one stream's boundary vector mid-window (online re-plan).

        ``state_ids`` are the stream's current resident doc ids (-1 pads).
        Residents whose static tier changes under the new vector are
        re-tiered in place — counted in ``relocations`` and moved between
        the occupancy counters, so capacity reconciliation keeps seeing
        where documents actually live. Later writes, deletes and the
        final read all follow the new boundaries. Migrating (cascade)
        streams cannot be re-planned (the floor semantics would be
        ambiguous). Returns the number of relocated residents.

        Logmem rows (``state_ids=None``) only swap the boundary vector:
        the backend stores no resident ids, so already-written docs stay
        in the tier they were written to (nothing relocatable) and only
        future writes follow the new placement. Returns 0.
        """
        if self.migrate[row]:
            raise ValueError(f"stream row {row} runs a migration cascade — "
                             "online re-planning only supports static "
                             "placements")
        bs = tuple(float(b) for b in new_bounds)
        if any(b2 < b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError("boundaries must be non-decreasing")
        if len(bs) > self.boundaries.shape[1]:
            raise ValueError(f"stream row {row}: {len(bs)} boundaries "
                             f"exceed the fleet-wide maximum depth "
                             f"{self.boundaries.shape[1]}")
        if state_ids is None:
            if not self.logmem[row]:
                raise ValueError(f"stream row {row}: state_ids required "
                                 "for exact-backend re-planning")
            self.boundaries[row, :] = np.inf
            self.boundaries[row, : len(bs)] = bs
            return 0
        ids = np.asarray(state_ids).reshape(-1)
        ids = ids[ids >= 0]
        old_tiers = (ids[:, None] >= self.boundaries[row][None, :]).sum(1)
        self.boundaries[row, :] = np.inf
        self.boundaries[row, : len(bs)] = bs
        new_tiers = (ids[:, None] >= self.boundaries[row][None, :]).sum(1)
        hop = new_tiers != old_tiers
        moved = int(np.sum(hop))
        self.relocations[row] += moved
        np.add.at(self.reloc_reads[row], old_tiers[hop], 1)
        np.add.at(self.reloc_writes[row], new_tiers[hop], 1)
        occ = np.bincount(new_tiers, minlength=self.n_tiers)
        self.occupancy[row] = occ[: self.n_tiers]
        self.occupancy_hwm[row] = np.maximum(self.occupancy_hwm[row],
                                             self.occupancy[row])
        return moved

    # ---- crash-consistent checkpointing ---------------------------------

    _STATE_ARRAYS = (
        "boundaries", "floor", "observed", "writes", "reads", "deletes",
        "migrations", "relocations", "mig_reads", "mig_writes",
        "reloc_reads", "reloc_writes", "doc_steps", "occupancy",
        "occupancy_hwm")

    def state_dict(self) -> Dict[str, np.ndarray]:
        """All mutable ledgers as fresh numpy copies (safe to hand to an
        async checkpoint writer while the engine keeps recording)."""
        return {name: getattr(self, name).copy()
                for name in self._STATE_ARRAYS}

    def load_state(self, state: Dict[str, np.ndarray]) -> None:
        for name in self._STATE_ARRAYS:
            ref = getattr(self, name)
            arr = np.asarray(state[name]).astype(ref.dtype).reshape(
                ref.shape)
            setattr(self, name, arr.copy())

    def record_reads(self, stream_rows, doc_ids) -> None:
        """Account the end-of-window top-K read (the consumer side)."""
        stream_rows = np.asarray(stream_rows, np.int64)
        doc_ids = np.asarray(doc_ids)
        if doc_ids.ndim != 2:
            doc_ids = doc_ids.reshape(-1, 1)
        # migrated streams serve the final read from the cascade floor up
        self._scatter(self.reads, stream_rows,
                      self._effective_tier(stream_rows, doc_ids),
                      doc_ids >= 0)

    # ---- reconciliation -------------------------------------------------

    def expected_writes(self, batch: int = 1) -> np.ndarray:
        """(M,) analytic E[total reservoir writes] at each stream's current
        observed length — the batched write law, eq. 11/12 when batch=1.
        Streams that observed nothing expect nothing."""
        out = np.zeros(self.m, np.float64)
        seen = np.maximum(self.observed, 1)
        for k in np.unique(self.ks):
            sel = self.ks == k
            out[sel] = shp.expected_cum_writes_batched(
                seen[sel] - 1, int(k), int(batch))
        return np.where(self.observed > 0, out, 0.0)

    def reconcile(self, batch: int = 1) -> Dict[str, np.ndarray | float]:
        """Actual vs analytic writes per stream. ``mean_rel_err`` is the
        fleet-level sanity number: per-stream counts are single samples of
        the expectation, but averaged over the fleet they concentrate."""
        expected = self.expected_writes(batch=batch)
        actual = self.writes.sum(1).astype(np.float64)
        rel = (actual - expected) / np.maximum(expected, 1e-12)
        return {
            "actual": actual,
            "expected": expected,
            "rel_err": rel,
            "mean_rel_err": float(np.mean(rel)),
            "fleet_actual": float(actual.sum()),
            "fleet_expected": float(expected.sum()),
        }

    def read_latency(self, latencies) -> np.ndarray:
        """(M,) realized mean per-survivor read latency: ``latencies`` is
        (T,) or (M, T) per-tier seconds. Streams with no recorded reads
        report 0."""
        lat = np.broadcast_to(np.asarray(latencies, np.float64),
                              (self.m, self.n_tiers))
        total = (self.reads * lat).sum(1)
        count = self.reads.sum(1)
        return np.where(count > 0, total / np.maximum(count, 1), 0.0)

    def check_constraints(self, constraint_set, latencies=None,
                          doc_gb=None, per_stream_caps=None) -> Dict:
        """Reconciliation-time violation report: compare the *metered*
        occupancy high-water marks (and realized read latency, when
        ``latencies`` is given) against a ``core.constraints``
        ``ConstraintSet``. Shared capacities are checked fleet-wide
        (summed over streams); per-stream capacities per stream.
        Byte-denominated capacities need ``doc_gb`` (scalar or (M,)
        per-stream document sizes) to convert — the meter counts
        documents, not bytes. ``per_stream_caps`` ((M, T)) overrides the
        per-stream capacity computation entirely — the engine passes the
        ``effective_capacity`` merge of topology-declared and explicit
        capacities, which the model-less meter cannot derive itself.
        """
        has_bytes = any(
            c.max_bytes is not None
            for c in (constraint_set.capacities
                      + constraint_set.shared_capacities))
        if has_bytes and doc_gb is None and per_stream_caps is None:
            raise ValueError("byte-denominated capacities need doc_gb to "
                             "convert metered document counts")
        if (doc_gb is None
                and any(c.max_bytes is not None
                        for c in constraint_set.shared_capacities)):
            raise ValueError("shared byte budgets need doc_gb to convert "
                             "metered document counts")
        sizes = (np.broadcast_to(np.asarray(doc_gb, np.float64), (self.m,))
                 if doc_gb is not None else None)
        if per_stream_caps is not None:
            cap = np.asarray(per_stream_caps, np.float64)
        elif sizes is None:
            cap = np.broadcast_to(
                constraint_set.capacity_array(self.n_tiers, 0.0),
                (self.m, self.n_tiers))
        else:
            cap = np.stack([constraint_set.capacity_array(self.n_tiers,
                                                          float(g))
                            for g in sizes])
        capacity_violations = self.occupancy_hwm > cap
        shared_violations: Dict = {}
        for c in constraint_set.shared_capacities:
            if c.tier >= self.n_tiers:
                continue
            occ = self.occupancy_hwm[:, c.tier]
            excess = {}
            if occ.sum() > c.max_docs:
                excess["excess_docs"] = float(occ.sum() - c.max_docs)
            if c.max_bytes is not None:
                used = float((occ * sizes).sum()) * 1e9
                if used > c.max_bytes:
                    excess["excess_bytes"] = used - c.max_bytes
            if excess:
                shared_violations[c.tier] = excess
        slo = constraint_set.max_read_latency
        slo_violations = np.zeros(self.m, bool)
        realized_lat = None
        if latencies is not None and np.isfinite(slo):
            realized_lat = self.read_latency(latencies)
            slo_violations = realized_lat > slo
        # structured per-violation report: one dict per (stream, tier)
        # with the measured value, the limit, and the signed margin
        # (measured − limit > 0 ⇔ violated) — the obs event log's record
        violations = []
        for row, tier in zip(*np.nonzero(capacity_violations)):
            violations.append({
                "row": int(row), "tier": int(tier), "kind": "capacity",
                "measured": float(self.occupancy_hwm[row, tier]),
                "limit": float(cap[row, tier]),
                "margin": float(self.occupancy_hwm[row, tier]
                                - cap[row, tier])})
        for tier, excess in shared_violations.items():
            for key, over in excess.items():
                unit = key.split("_", 1)[1]  # docs | bytes
                violations.append({
                    "row": None, "tier": int(tier),
                    "kind": f"shared_capacity_{unit}",
                    "measured": None, "limit": None,
                    "margin": float(over)})
        for row in np.flatnonzero(slo_violations):
            violations.append({
                "row": int(row), "tier": None, "kind": "slo",
                "measured": float(realized_lat[row]), "limit": float(slo),
                "margin": float(realized_lat[row] - slo)})
        return {
            "capacity_violations": capacity_violations,
            "shared_violations": shared_violations,
            "slo_violations": slo_violations,
            "violations": violations,
            "ok": not violations,
        }

    # ---- classic per-stream view ---------------------------------------

    def ledger(self, i: int) -> Ledger:
        led = Ledger.sized(self.n_tiers)
        led.writes = self.writes[i].copy()
        led.reads = self.reads[i].copy()
        led.deletes = self.deletes[i].copy()
        led.migrations = int(self.migrations[i])
        return led
