# Multi-tenant top-K stream fleet: M concurrent streams, each with its own
# K, window length, cost model and tier topology (2- and N-tier streams mix
# freely), advanced inside one jitted step.
#   engine   — batched ReservoirState (leading stream axis) + StreamEngine
#   planner  — vectorized closed-form shp.plan_placement over the fleet
#              (+ plan_fleet_mixed for heterogeneous tier depths and
#              constraint-aware planning with shared-capacity water-filling)
#   router   — mixed-batch → per-K bucket scatter (pads/buckets by K)
#   metering — per-stream ledgers reconciled against the analytic write law
#              (+ occupancy high-water marks and SLO checks)
from . import engine, logmem, metering, planner, router  # noqa: F401
from .engine import BatchedReservoirState, StreamEngine, StreamSpec  # noqa: F401
from .planner import FleetPlan, MixedFleetPlan, plan_fleet, plan_fleet_mixed, waterfill  # noqa: F401
