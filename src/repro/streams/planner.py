"""Vectorized proactive fleet planner — ``core.shp.plan_placement`` over M
heterogeneous cost models in one numpy pass.

The paper's tractability claim is that r* is closed-form per stream
(eq. 17/21 + the eq. 22 validity gate), so a fleet of thousands of tenant
streams can be planned proactively before any document arrives — no
per-stream optimization loop, just array arithmetic over the
struct-of-arrays view of the cost models. ``plan_fleet`` must agree
stream-for-stream with ``shp.plan_placement(cm)`` (tests assert this);
it evaluates the same four candidate strategies in the same precedence
order using the paper's logarithmic approximations.

Fleets may mix tier depths: ``plan_fleet_mixed`` routes each stream's cost
model to the matching vectorized solver (this legacy two-tier pass, or the
multi-threshold ``shp.plan_ntier_arrays`` grouped by tier count) and
returns one uniform per-stream boundary-vector plan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import shp
from repro.core.costs import NTierCostModel, TwoTierCostModel
from repro.core.placement import Policy

# Column order = candidate order in shp.plan_placement (ties resolve the
# same way: first minimum wins).
STRATEGIES = ("all_tier_a", "all_tier_b", "two_tier_no_migration",
              "two_tier_migration")


@dataclass(frozen=True)
class FleetCosts:
    """Struct-of-arrays view of M ``TwoTierCostModel``s (all (M,) float64,
    except ``n``/``k`` which are the workload integers as float)."""

    cw_a: np.ndarray
    cw_b: np.ndarray
    cr_a: np.ndarray
    cr_b: np.ndarray
    cs_a: np.ndarray
    cs_b: np.ndarray
    n: np.ndarray
    k: np.ndarray
    reads_per_window: np.ndarray

    @classmethod
    def from_models(cls, models: Sequence[TwoTierCostModel]) -> "FleetCosts":
        f = lambda attr: np.array([getattr(m, attr) for m in models], np.float64)
        return cls(
            cw_a=f("cw_a"), cw_b=f("cw_b"), cr_a=f("cr_a"), cr_b=f("cr_b"),
            cs_a=f("cs_a"), cs_b=f("cs_b"),
            n=np.array([m.workload.n_docs for m in models], np.float64),
            k=np.array([m.workload.k for m in models], np.float64),
            reads_per_window=np.array(
                [m.workload.reads_per_window for m in models], np.float64),
        )

    @property
    def m(self) -> int:
        return self.cw_a.shape[0]


@dataclass(frozen=True)
class FleetPlan:
    """Per-stream outcome of the vectorized decision procedure."""

    strategy_idx: np.ndarray  # (M,) int — index into STRATEGIES
    r: np.ndarray  # (M,) absolute changeover index of the chosen strategy
    totals: np.ndarray  # (M, 4) expected cost per candidate (+inf if gated)
    r_no_migration: np.ndarray  # (M,) eq. 17 stationary point (may be inf/nan)
    r_migration: np.ndarray  # (M,) eq. 21 stationary point
    n_docs: np.ndarray  # (M,)

    @property
    def m(self) -> int:
        return self.strategy_idx.shape[0]

    def strategy(self, i: int) -> str:
        return STRATEGIES[int(self.strategy_idx[i])]

    def migrate(self, i: int) -> bool:
        return self.strategy(i) == "two_tier_migration"

    @property
    def best_total(self) -> np.ndarray:
        return self.totals[np.arange(self.m), self.strategy_idx]

    def policy(self, i: int) -> Policy:
        """The executable per-stream policy (same mapping as
        ``placement.from_plan``)."""
        s = self.strategy(i)
        if s == "all_tier_a":
            return Policy(r=float(self.n_docs[i]), name="all_a")
        if s == "all_tier_b":
            return Policy(r=0.0, name="all_b")
        if s == "two_tier_no_migration":
            return Policy(r=float(self.r_no_migration[i]), name="algoC_nomig")
        return Policy(r=float(self.r_migration[i]), migrate_at_r=True,
                      name="algoC_mig")

    def strategy_histogram(self) -> dict:
        return {s: int(np.sum(self.strategy_idx == i))
                for i, s in enumerate(STRATEGIES)}


def _safe_div(num: np.ndarray, den: np.ndarray) -> np.ndarray:
    with np.errstate(divide="ignore", invalid="ignore"):
        out = num / den
    return np.where(den == 0.0, np.nan, out)


def plan_fleet(models_or_costs) -> FleetPlan:
    """Plan every stream in the fleet in one vectorized pass.

    Accepts a sequence of ``TwoTierCostModel`` or a prebuilt ``FleetCosts``.
    Uses the paper's approximate (logarithmic) forms, i.e. matches
    ``shp.plan_placement(cm, exact=False)`` per stream.
    """
    fc = (models_or_costs if isinstance(models_or_costs, FleetCosts)
          else FleetCosts.from_models(models_or_costs))
    n, k, rpw = fc.n, fc.k, fc.reads_per_window
    log_n_over_k = np.log(n / k)

    # single-tier candidates (cost_single_tier, approx)
    w_total = k * (1.0 + log_n_over_k)
    tot_a = w_total * fc.cw_a + rpw * k * fc.cr_a + k * fc.cs_a
    tot_b = w_total * fc.cw_b + rpw * k * fc.cr_b + k * fc.cs_b

    # eq. 17 / eq. 21 stationary points + eq. 22 validity gate (incl. the
    # second-order condition cw_A < cw_B — see shp.r_is_valid)
    r_nm = _safe_div(fc.cw_a - fc.cw_b, (fc.cr_b - fc.cr_a) * rpw) * n
    r_mg = _safe_div(fc.cw_a - fc.cw_b, fc.cs_b - fc.cs_a) * n
    second_order = fc.cw_a < fc.cw_b

    def _two_tier(r, migrate):
        valid = (np.isfinite(r) & (k < r) & (r < n) & second_order)
        rs = np.where(valid, r, k + 1.0)  # placeholder keeps logs finite
        wa = k * (1.0 + np.log(rs / k))
        wb = k * (np.log(n) - np.log(rs))
        writes = wa * fc.cw_a + wb * fc.cw_b
        rn = rs / n
        if migrate:
            storage = k * (rn * fc.cs_a + (1.0 - rn) * fc.cs_b)
            total = writes + storage + k * (fc.cr_a + fc.cw_b)
        else:
            reads = rpw * k * (rn * fc.cr_a + (1.0 - rn) * fc.cr_b)
            total = writes + reads + k * np.maximum(fc.cs_a, fc.cs_b)
        return np.where(valid, total, np.inf)

    totals = np.stack(
        [tot_a, tot_b, _two_tier(r_nm, False), _two_tier(r_mg, True)], axis=1)
    idx = np.argmin(totals, axis=1)
    r_chosen = np.select(
        [idx == 0, idx == 1, idx == 2], [n, np.zeros_like(n), r_nm], r_mg)
    return FleetPlan(strategy_idx=idx, r=r_chosen, totals=totals,
                     r_no_migration=r_nm, r_migration=r_mg, n_docs=n)


# ---------------------------------------------------------------------------
# Mixed-depth fleets: two-tier and N-tier cost models side by side
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MixedFleetPlan:
    """Per-stream boundary-vector plans for a fleet mixing tier depths.

    Two-tier streams are planned by the legacy ``plan_fleet`` pass (their
    single boundary is the chosen r); N-tier streams by the vectorized
    multi-threshold solver, grouped by tier count.
    """

    boundaries: Tuple[Tuple[float, ...], ...]
    migrate_flags: np.ndarray  # (M,) bool
    strategies: Tuple[str, ...]
    totals: np.ndarray  # (M,) expected cost of the chosen strategy

    @property
    def m(self) -> int:
        return len(self.boundaries)

    def strategy(self, i: int) -> str:
        return self.strategies[i]

    def migrate(self, i: int) -> bool:
        return bool(self.migrate_flags[i])

    def policy(self, i: int) -> Policy:
        return Policy(boundaries=self.boundaries[i],
                      migrate_at_r=self.migrate(i), name=self.strategies[i])

    def strategy_histogram(self) -> dict:
        out: dict = {}
        for s in self.strategies:
            out[s] = out.get(s, 0) + 1
        return out


def plan_fleet_mixed(models: Sequence[TwoTierCostModel | NTierCostModel]
                     ) -> MixedFleetPlan:
    """Plan a heterogeneous fleet in a handful of vectorized passes: one
    legacy two-tier pass plus one N-tier pass per distinct tier count."""
    m = len(models)
    boundaries: List[Tuple[float, ...]] = [()] * m
    migrate = np.zeros(m, bool)
    strategies: List[str] = [""] * m
    totals = np.zeros(m, np.float64)
    two_idx = [i for i, cm in enumerate(models)
               if isinstance(cm, TwoTierCostModel)]
    if two_idx:
        plan = plan_fleet([models[i] for i in two_idx])
        for j, i in enumerate(two_idx):
            boundaries[i] = (float(plan.r[j]),)
            migrate[i] = plan.migrate(j)
            strategies[i] = plan.strategy(j)
            totals[i] = plan.best_total[j]
    by_t: dict = {}
    for i, cm in enumerate(models):
        if isinstance(cm, NTierCostModel):
            by_t.setdefault(cm.t, []).append(i)
        elif not isinstance(cm, TwoTierCostModel):
            raise TypeError(f"stream {i}: unsupported cost model {type(cm)}")
    for t, idxs in sorted(by_t.items()):
        tot, bounds, mig, strats = shp.plan_ntier_batch(
            [models[i] for i in idxs])
        for j, i in enumerate(idxs):
            boundaries[i] = tuple(float(b) for b in bounds[j])
            migrate[i] = bool(mig[j])
            strategies[i] = strats[j]
            totals[i] = tot[j]
    return MixedFleetPlan(boundaries=tuple(boundaries),
                          migrate_flags=migrate,
                          strategies=tuple(strategies), totals=totals)
