"""Vectorized proactive fleet planner — ``core.shp.plan_placement`` over M
heterogeneous cost models in one numpy pass.

The paper's tractability claim is that r* is closed-form per stream
(eq. 17/21 + the eq. 22 validity gate), so a fleet of thousands of tenant
streams can be planned proactively before any document arrives — no
per-stream optimization loop, just array arithmetic over the
struct-of-arrays view of the cost models. ``plan_fleet`` must agree
stream-for-stream with ``shp.plan_placement(cm)`` (tests assert this);
it evaluates the same four candidate strategies in the same precedence
order using the paper's logarithmic approximations.

Fleets may mix tier depths: ``plan_fleet_mixed`` routes each stream's cost
model to the matching vectorized solver (this legacy two-tier pass, or the
multi-threshold ``shp.plan_ntier_arrays`` grouped by tier count) and
returns one uniform per-stream boundary-vector plan.

Constraints (``core.constraints``) thread through both entry points as
vectorized feasibility masks over the (M, T) boundary batch. Fleet-shared
capacities (``TierCapacity(shared=True)``) are split across tenants by a
water-filling pass (:func:`waterfill`): plan unconstrained, measure each
stream's desired occupancy high-water mark on the shared tier, cap the
binding streams at the common water level λ with Σ min(desired, λ) = C,
and re-plan only those — the fleet then never oversubscribes C.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import constraints as constraints_mod, shp
from repro.core.constraints import ConstraintSet, TierCapacity
from repro.core.costs import NTierCostModel, TwoTierCostModel
from repro.core.placement import Policy

# Column order = candidate order in shp.plan_placement (ties resolve the
# same way: first minimum wins).
STRATEGIES = ("all_tier_a", "all_tier_b", "two_tier_no_migration",
              "two_tier_migration")


@dataclass(frozen=True)
class FleetCosts:
    """Struct-of-arrays view of M ``TwoTierCostModel``s (all (M,) float64,
    except ``n``/``k`` which are the workload integers as float)."""

    cw_a: np.ndarray
    cw_b: np.ndarray
    cr_a: np.ndarray
    cr_b: np.ndarray
    cs_a: np.ndarray
    cs_b: np.ndarray
    n: np.ndarray
    k: np.ndarray
    reads_per_window: np.ndarray

    @classmethod
    def from_models(cls, models: Sequence[TwoTierCostModel]) -> "FleetCosts":
        f = lambda attr: np.array([getattr(m, attr) for m in models], np.float64)
        return cls(
            cw_a=f("cw_a"), cw_b=f("cw_b"), cr_a=f("cr_a"), cr_b=f("cr_b"),
            cs_a=f("cs_a"), cs_b=f("cs_b"),
            n=np.array([m.workload.n_docs for m in models], np.float64),
            k=np.array([m.workload.k for m in models], np.float64),
            reads_per_window=np.array(
                [m.workload.reads_per_window for m in models], np.float64),
        )

    @property
    def m(self) -> int:
        return self.cw_a.shape[0]


@dataclass(frozen=True)
class FleetPlan:
    """Per-stream outcome of the vectorized decision procedure.

    Under constraints the family candidates are planned by the
    constrained N-tier pass: ``r_no_migration``/``r_migration`` then hold
    the *feasibility-clamped* chosen boundary (not the raw eq. 17/21
    stationary points), unchosen family columns of ``totals`` are +inf,
    and ``feasible`` flags streams with any feasible plan at all.
    """

    strategy_idx: np.ndarray  # (M,) int — index into STRATEGIES
    r: np.ndarray  # (M,) absolute changeover index of the chosen strategy
    totals: np.ndarray  # (M, 4) expected cost per candidate (+inf if gated)
    r_no_migration: np.ndarray  # (M,) eq. 17 stationary point (may be inf/nan)
    r_migration: np.ndarray  # (M,) eq. 21 stationary point
    n_docs: np.ndarray  # (M,)
    feasible: Optional[np.ndarray] = None  # (M,) bool (None = unconstrained)

    @property
    def m(self) -> int:
        return self.strategy_idx.shape[0]

    def strategy(self, i: int) -> str:
        return STRATEGIES[int(self.strategy_idx[i])]

    def migrate(self, i: int) -> bool:
        return self.strategy(i) == "two_tier_migration"

    @property
    def best_total(self) -> np.ndarray:
        return self.totals[np.arange(self.m), self.strategy_idx]

    def policy(self, i: int) -> Policy:
        """The executable per-stream policy (same mapping as
        ``placement.from_plan``)."""
        s = self.strategy(i)
        if s == "all_tier_a":
            return Policy(r=float(self.n_docs[i]), name="all_a")
        if s == "all_tier_b":
            return Policy(r=0.0, name="all_b")
        if s == "two_tier_no_migration":
            return Policy(r=float(self.r_no_migration[i]), name="algoC_nomig")
        return Policy(r=float(self.r_migration[i]), migrate_at_r=True,
                      name="algoC_mig")

    def strategy_histogram(self) -> dict:
        return {s: int(np.sum(self.strategy_idx == i))
                for i, s in enumerate(STRATEGIES)}


def _safe_div(num: np.ndarray, den: np.ndarray) -> np.ndarray:
    with np.errstate(divide="ignore", invalid="ignore"):
        out = num / den
    return np.where(den == 0.0, np.nan, out)


def plan_fleet(models_or_costs, constraints: Optional[ConstraintSet] = None,
               lat: Optional[np.ndarray] = None) -> FleetPlan:
    """Plan every stream in the fleet in one vectorized pass.

    Accepts a sequence of ``TwoTierCostModel`` or a prebuilt ``FleetCosts``.
    Uses the paper's approximate (logarithmic) forms, i.e. matches
    ``shp.plan_placement(cm, exact=False)`` per stream.

    A non-empty ``constraints`` routes the fleet through the constrained
    N-tier array pass (the resource-augmented solver with vectorized
    feasibility masks over the (M, 2) boundary batch). ``lat`` supplies
    per-tier read latencies ((2,) or (M, 2)) for ``ReadLatencySLO``
    constraints — the legacy two-tier cost models carry none. Byte-
    denominated capacities need document sizes: plan those fleets via
    ``plan_fleet_mixed`` with full cost models.
    """
    fc = (models_or_costs if isinstance(models_or_costs, FleetCosts)
          else FleetCosts.from_models(models_or_costs))
    if constraints is not None and not constraints.empty:
        if constraints.shared_capacities:
            raise ValueError(
                "fleet-shared capacities need the water-filling pass — "
                "plan via plan_fleet_mixed")
        if any(c.max_bytes is not None for c in constraints.capacities):
            raise ValueError(
                "byte-denominated capacities need document sizes — plan "
                "via plan_fleet_mixed with full cost models")
        return _plan_fleet_constrained(fc, constraints, lat)
    n, k, rpw = fc.n, fc.k, fc.reads_per_window
    log_n_over_k = np.log(n / k)

    # single-tier candidates (cost_single_tier, approx)
    w_total = k * (1.0 + log_n_over_k)
    tot_a = w_total * fc.cw_a + rpw * k * fc.cr_a + k * fc.cs_a
    tot_b = w_total * fc.cw_b + rpw * k * fc.cr_b + k * fc.cs_b

    # eq. 17 / eq. 21 stationary points + eq. 22 validity gate (incl. the
    # second-order condition cw_A < cw_B — see shp.r_is_valid)
    r_nm = _safe_div(fc.cw_a - fc.cw_b, (fc.cr_b - fc.cr_a) * rpw) * n
    r_mg = _safe_div(fc.cw_a - fc.cw_b, fc.cs_b - fc.cs_a) * n
    second_order = fc.cw_a < fc.cw_b

    def _two_tier(r, migrate):
        valid = (np.isfinite(r) & (k < r) & (r < n) & second_order)
        rs = np.where(valid, r, k + 1.0)  # placeholder keeps logs finite
        wa = k * (1.0 + np.log(rs / k))
        wb = k * (np.log(n) - np.log(rs))
        writes = wa * fc.cw_a + wb * fc.cw_b
        rn = rs / n
        if migrate:
            storage = k * (rn * fc.cs_a + (1.0 - rn) * fc.cs_b)
            total = writes + storage + k * (fc.cr_a + fc.cw_b)
        else:
            reads = rpw * k * (rn * fc.cr_a + (1.0 - rn) * fc.cr_b)
            total = writes + reads + k * np.maximum(fc.cs_a, fc.cs_b)
        return np.where(valid, total, np.inf)

    totals = np.stack(
        [tot_a, tot_b, _two_tier(r_nm, False), _two_tier(r_mg, True)], axis=1)
    idx = np.argmin(totals, axis=1)
    r_chosen = np.select(
        [idx == 0, idx == 1, idx == 2], [n, np.zeros_like(n), r_nm], r_mg)
    return FleetPlan(strategy_idx=idx, r=r_chosen, totals=totals,
                     r_no_migration=r_nm, r_migration=r_mg, n_docs=n)


def _plan_fleet_constrained(fc: FleetCosts, cset: ConstraintSet,
                            lat: Optional[np.ndarray]) -> FleetPlan:
    """The constrained two-tier fleet pass: stack the struct-of-arrays
    view into (M, 2) tier columns and run the constrained N-tier solver,
    mapping its boundary-vector plans back onto the four legacy candidate
    strategies."""
    m = fc.m
    cw = np.stack([fc.cw_a, fc.cw_b], axis=1)
    cr = np.stack([fc.cr_a, fc.cr_b], axis=1)
    cs = np.stack([fc.cs_a, fc.cs_b], axis=1)
    # (M, 2) constraint views are broadcast, not materialized: the solver
    # consumes them read-only, so one (2,)/scalar allocation serves the
    # whole fleet instead of three fresh M-row arrays per call
    cap = np.broadcast_to(cset.capacity_array(2, 0.0), (m, 2))
    lat_arr = np.broadcast_to(
        np.zeros(2) if lat is None else np.asarray(lat, np.float64),
        (m, 2))
    slo = np.broadcast_to(np.float64(cset.max_read_latency), (m,))
    out = shp.plan_ntier_arrays(cw, cr, cs, fc.n, fc.k, fc.reads_per_window,
                                cap=cap, lat=lat_arr, slo=slo)
    feasible = np.isfinite(out["total"])
    r = out["bounds"][:, 0]
    mig = out["migrate"]
    # map the boundary plan onto the legacy candidate columns
    single_a = ~mig & (r >= fc.n)
    single_b = ~mig & (r <= 0.0)
    idx = np.select([single_a, single_b, ~mig], [0, 1, 2], 3)
    idx = np.where(feasible, idx, 0)
    totals = np.full((m, 4), np.inf)
    totals[np.arange(m), idx] = np.where(feasible, out["total"], np.inf)
    return FleetPlan(strategy_idx=idx, r=r, totals=totals,
                     r_no_migration=np.where(mig, np.nan, r),
                     r_migration=np.where(mig, r, np.nan), n_docs=fc.n,
                     feasible=feasible)


# ---------------------------------------------------------------------------
# Fleet-shared capacity: the water-filling split
# ---------------------------------------------------------------------------

def waterfill(desired: np.ndarray, budget: float, *,
              mesh=None) -> np.ndarray:
    """Split a shared budget across tenants: each stream gets
    ``min(desired_i, λ)`` with the water level λ chosen so the grants sum
    to the budget (all ``desired`` granted when they already fit).
    Returns the (M,) per-stream caps.

    The exact host law lives in ``core.constraints.waterfill_grants``
    (sort + prefix scan — one host view of the whole fleet). Under a
    fleet mesh the desires stay sharded and λ is found device-side by a
    ``psum`` bisection (``parallel.fleet.waterfill_sharded``) — same
    grants to well below one ulp, and the fleet still never
    oversubscribes the budget (property-tested)."""
    if mesh is not None:
        from repro.parallel import fleet
        if fleet.n_shards(mesh) > 1:
            return fleet.waterfill_sharded(desired, budget, mesh)
    return constraints_mod.waterfill_grants(desired, budget)


# ---------------------------------------------------------------------------
# Mixed-depth fleets: two-tier and N-tier cost models side by side
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MixedFleetPlan:
    """Per-stream boundary-vector plans for a fleet mixing tier depths.

    Two-tier streams are planned by the legacy ``plan_fleet`` pass (their
    single boundary is the chosen r); N-tier streams by the vectorized
    multi-threshold solver, grouped by tier count. Constrained fleets
    route every stream (two-tier included, via ``as_ntier``) through the
    constrained N-tier pass; streams with no feasible plan carry
    strategy ``"infeasible"`` and ``totals = +inf``.
    """

    boundaries: Tuple[Tuple[float, ...], ...]
    migrate_flags: np.ndarray  # (M,) bool
    strategies: Tuple[str, ...]
    totals: np.ndarray  # (M,) expected cost of the chosen strategy

    @property
    def m(self) -> int:
        return len(self.boundaries)

    def strategy(self, i: int) -> str:
        return self.strategies[i]

    def migrate(self, i: int) -> bool:
        return bool(self.migrate_flags[i])

    def feasible(self, i: int) -> bool:
        return bool(np.isfinite(self.totals[i]))

    def policy(self, i: int) -> Policy:
        if not self.feasible(i):
            raise ValueError(f"stream {i} has no feasible plan under its "
                             "constraints")
        return Policy(boundaries=self.boundaries[i],
                      migrate_at_r=self.migrate(i), name=self.strategies[i])

    def strategy_histogram(self) -> dict:
        out: dict = {}
        for s in self.strategies:
            out[s] = out.get(s, 0) + 1
        return out


def _as_ntier_models(models) -> List[NTierCostModel]:
    out = []
    for i, cm in enumerate(models):
        if isinstance(cm, TwoTierCostModel):
            out.append(cm.as_ntier())
        elif isinstance(cm, NTierCostModel):
            out.append(cm)
        else:
            raise TypeError(f"stream {i}: unsupported cost model {type(cm)}")
    return out


def _plan_mixed_ntier(nt_models, csets, boundaries, migrate,
                      strategies, totals, only=None) -> None:
    """One N-tier pass per distinct tier count (constrained when the
    per-stream sets say so), writing the per-stream results in place.
    ``only`` restricts to a subset of stream indices (the unconstrained
    route's N-tier leg, and the water-filling re-plan)."""
    by_t: dict = {}
    idx_iter = range(len(nt_models)) if only is None else only
    for i in idx_iter:
        by_t.setdefault(nt_models[i].t, []).append(i)
    for t, idxs in sorted(by_t.items()):
        tot, bounds, mig, strats = shp.plan_ntier_batch(
            [nt_models[i] for i in idxs],
            constraints=[csets[i] for i in idxs])
        for j, i in enumerate(idxs):
            boundaries[i] = tuple(float(b) for b in bounds[j])
            migrate[i] = bool(mig[j])
            strategies[i] = strats[j]
            totals[i] = tot[j]


def plan_fleet_mixed(models: Sequence[TwoTierCostModel | NTierCostModel],
                     constraints=None, *, mesh=None) -> MixedFleetPlan:
    """Plan a heterogeneous fleet in a handful of vectorized passes: one
    legacy two-tier pass plus one N-tier pass per distinct tier count.

    ``constraints`` is a fleet-wide ``ConstraintSet`` or one per stream.
    Fleet-wide shared capacities (``TierCapacity(shared=True)``) are split
    across tenants by water-filling: plan with the per-stream constraints,
    measure each stream's expected occupancy high-water mark on the shared
    tier, grant ``min(desired, λ)`` with Σ grants = C, and re-plan only
    the binding streams under their grant — the fleet's total expected
    occupancy then never exceeds C (asserted by the property tests).

    ``mesh`` (a ``parallel.fleet`` mesh) makes it the active fleet mesh
    for the duration of the call: the device N-tier solves dispatch per
    shard and the water-filling λ is found by cross-shard ``psum``
    bisection instead of the single-host scan.
    """
    if mesh is not None:
        from repro.parallel import fleet
        if fleet.get_fleet_mesh() is not mesh:
            with fleet.use_fleet_mesh(mesh):
                return plan_fleet_mixed(models, constraints=constraints,
                                        mesh=mesh)
    m = len(models)
    boundaries: List[Tuple[float, ...]] = [()] * m
    migrate = np.zeros(m, bool)
    strategies: List[str] = [""] * m
    totals = np.zeros(m, np.float64)
    shared: Tuple[TierCapacity, ...] = ()
    if constraints is None:
        per_stream = None
    elif isinstance(constraints, ConstraintSet):
        shared = constraints.shared_capacities
        base = ConstraintSet(*(c for c in constraints if c not in shared))
        per_stream = None if (base.empty and not shared) else [base] * m
    else:
        if len(constraints) != m:
            raise ValueError("need one ConstraintSet per stream")
        per_stream = [c if c is not None else ConstraintSet()
                      for c in constraints]
        if any(c.shared_capacities for c in per_stream):
            raise ValueError(
                "shared capacities are fleet-wide — pass one ConstraintSet "
                "for the whole fleet, not per-stream sets")

    if per_stream is None:
        # unconstrained: the original two-pass route (bit-stable)
        two_idx = [i for i, cm in enumerate(models)
                   if isinstance(cm, TwoTierCostModel)]
        if two_idx:
            plan = plan_fleet([models[i] for i in two_idx])
            for j, i in enumerate(two_idx):
                boundaries[i] = (float(plan.r[j]),)
                migrate[i] = plan.migrate(j)
                strategies[i] = plan.strategy(j)
                totals[i] = plan.best_total[j]
        ntier_idx = []
        for i, cm in enumerate(models):
            if isinstance(cm, NTierCostModel):
                ntier_idx.append(i)
            elif not isinstance(cm, TwoTierCostModel):
                raise TypeError(
                    f"stream {i}: unsupported cost model {type(cm)}")
        _plan_mixed_ntier(models, [None] * m, boundaries, migrate,
                          strategies, totals, only=ntier_idx)
        return MixedFleetPlan(boundaries=tuple(boundaries),
                              migrate_flags=migrate,
                              strategies=tuple(strategies), totals=totals)

    nt_models = _as_ntier_models(models)
    csets = list(per_stream)
    _plan_mixed_ntier(nt_models, csets, boundaries, migrate,
                      strategies, totals)
    done_tiers: List[int] = []
    for cap_c in sorted(shared, key=lambda c: c.tier):
        if cap_c.max_bytes is not None:
            raise NotImplementedError(
                "shared capacities are document-denominated; convert byte "
                "budgets per tenant before planning")

        def occupancy_on(tier: int) -> np.ndarray:
            occ = np.zeros(m)
            for i, nt in enumerate(nt_models):
                if tier < nt.t and np.isfinite(totals[i]):
                    occ[i] = constraints_mod.peak_occupancy(
                        boundaries[i], nt.workload.n_docs, nt.workload.k,
                        migrate[i])[tier]
            return occ

        desired = occupancy_on(cap_c.tier)
        if desired.sum() <= cap_c.max_docs:
            done_tiers.append(cap_c.tier)
            continue
        grants = waterfill(desired, cap_c.max_docs, mesh=mesh)
        binding = np.flatnonzero(desired > grants * (1 + 1e-12))
        # freeze the re-planned streams' usage of every already-balanced
        # shared tier at its current level, so re-planning for this tier
        # cannot push an earlier tier back over its budget
        frozen = {t: occupancy_on(t) for t in done_tiers}
        for i in binding:
            extra = [TierCapacity(cap_c.tier, float(grants[i]))]
            extra += [TierCapacity(t, float(frozen[t][i]))
                      for t in done_tiers]
            csets[i] = ConstraintSet(*csets[i], *extra)
        _plan_mixed_ntier(nt_models, csets, boundaries, migrate,
                          strategies, totals, only=list(binding))
        done_tiers.append(cap_c.tier)
    return MixedFleetPlan(boundaries=tuple(boundaries),
                          migrate_flags=migrate,
                          strategies=tuple(strategies), totals=totals)
