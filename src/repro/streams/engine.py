"""Batched multi-tenant top-K stream engine.

One ``jax.jit``-ed step advances M concurrent reservoirs at once: state
carries a leading stream axis (``BatchedReservoirState``), the update is a
vectorized sort-merge over all streams (``jax.vmap`` of ``core.topk``, so
the per-stream semantics — deterministic tie-break, id dedupe, write mask —
are bit-identical to M independent single-stream replays), and the
accelerated path pre-filters candidates with the 2-D Pallas kernel
``kernels.batched_topk`` before the exact merge.

Heterogeneous fleets (per-stream K) are handled by bucketing streams by K
(``streams.router``); ``StreamEngine`` runs every bucket inside one jitted
multi-bucket step, plans placement proactively for the whole fleet
(``streams.planner``) and meters every transaction per stream
(``streams.metering``). Per-stream state is O(K) under the default
``engine="exact"`` backend; huge-K tenants can opt into the O(log K)
``engine="logmem"`` threshold tracker (``streams.logmem``) per
``StreamSpec`` — buckets are keyed by (K, engine), and both backends mix
freely inside one fleet step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk
from repro.core.costs import NTierCostModel, TwoTierCostModel

from . import logmem, metering, planner, router

PAD_ID = router.PAD_ID


class BatchedReservoirState(NamedTuple):
    """M reservoirs stacked on a leading stream axis."""

    scores: jax.Array  # (M, K) float32, each row sorted desc, -inf padded
    ids: jax.Array  # (M, K) int32 per-stream local doc index, -1 padded
    seen: jax.Array  # (M,) int32 — docs observed per stream (padding excluded)


def init(m: int, k: int) -> BatchedReservoirState:
    return BatchedReservoirState(
        scores=jnp.full((m, k), -jnp.inf, dtype=jnp.float32),
        ids=jnp.full((m, k), -1, dtype=jnp.int32),
        seen=jnp.zeros((m,), dtype=jnp.int32),
    )


def _as_single(state: BatchedReservoirState) -> topk.ReservoirState:
    return topk.ReservoirState(scores=state.scores, ids=state.ids,
                               seen=state.seen)


def update(state: BatchedReservoirState, batch_scores: jax.Array,
           batch_ids: jax.Array) -> Tuple[BatchedReservoirState, jax.Array]:
    """Fused update of all M streams: scores/ids (M, W), padding = (-inf, -1).

    Returns (new_state, wrote (M, W) bool). Padding never writes and does
    not advance ``seen``.
    """
    new, wrote = jax.vmap(topk.update)(_as_single(state), batch_scores,
                                       batch_ids)
    seen = state.seen + (batch_ids >= 0).sum(axis=1).astype(state.seen.dtype)
    return BatchedReservoirState(new.scores, new.ids, seen), wrote


def filtered_update(state: BatchedReservoirState, batch_scores: jax.Array,
                    batch_ids: jax.Array, *, block_n: int = 512,
                    use_pallas: bool = True
                    ) -> Tuple[BatchedReservoirState, jax.Array]:
    """Kernel-accelerated update for wide ingest batches: one 2-D Pallas
    scan of all streams' candidates against their reservoir bars, then an
    exact merge over at most K survivors per stream.

    Equivalent to ``update`` when per-stream doc ids arrive in increasing
    order (the stream case — ties then resolve identically); tests assert
    the equality.
    """
    from repro.kernels.batched_topk import ops as btk_ops
    k = state.scores.shape[1]
    w = batch_scores.shape[1]
    bar = state.scores[:, -1]
    mask, _, _ = btk_ops.batched_topk_filter(batch_scores, bar,
                                             block_n=block_n,
                                             use_pallas=use_pallas)
    # re-observed resident ids are dropped by topk.update anyway; mask them
    # out *before* top_k so they cannot occupy a survivor slot that a fresh
    # candidate (which plain ``update`` would admit) should get
    batch_ids = batch_ids.astype(jnp.int32)
    resident = jax.vmap(topk.member)(batch_ids, state.ids)
    keep = (mask > 0) & ~resident
    surv = jnp.where(keep, batch_scores.astype(jnp.float32), -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(surv, min(k, w))
    top_ids = jnp.take_along_axis(batch_ids, top_idx, axis=1)
    top_ids = jnp.where(jnp.isfinite(top_scores), top_ids, PAD_ID)
    new, wrote_top = jax.vmap(topk.update)(_as_single(state), top_scores,
                                           top_ids)
    # scatter the survivors' write mask back to batch positions
    wrote = jnp.zeros(batch_scores.shape, bool)
    rows = jnp.arange(batch_scores.shape[0])[:, None]
    wrote = wrote.at[rows, top_idx].set(wrote_top)
    wrote = wrote & (batch_ids >= 0)
    seen = state.seen + (batch_ids >= 0).sum(axis=1).astype(state.seen.dtype)
    return BatchedReservoirState(new.scores, new.ids, seen), wrote


def merge(a: BatchedReservoirState,
          b: BatchedReservoirState) -> BatchedReservoirState:
    """Row-wise cross-shard reduction (see ``topk.merge``)."""
    new = jax.vmap(topk.merge)(_as_single(a), _as_single(b))
    return BatchedReservoirState(new.scores, new.ids, a.seen + b.seen)


def thresholds(state: BatchedReservoirState) -> jax.Array:
    """(M,) current per-stream entry bars (-inf while unfull)."""
    return state.scores[:, -1]


def placements(state: BatchedReservoirState, r) -> jax.Array:
    """Per-slot tier with per-stream changeovers: ``r`` is (M,) scalar
    boundaries (the two-tier case, via ``topk.tier_of``) or (M, B)
    boundary vectors (tier = number of boundaries <= id). -1 = empty."""
    r = jnp.asarray(r)
    if r.ndim <= 1:
        t = topk.tier_of(state.ids, r.reshape(-1, 1))
    else:
        t = (state.ids[:, :, None] >= r[:, None, :]).sum(-1).astype(jnp.int32)
    return jnp.where(state.ids >= 0, t, -1)


def evicted_ids(old: BatchedReservoirState,
                new: BatchedReservoirState) -> jax.Array:
    """(M, K) local doc ids evicted by the step (-1 = none) — the storage
    the fleet can free (paper §VI)."""
    ev = jax.vmap(topk.evicted)(_as_single(old), _as_single(new))
    return jnp.where(ev, old.ids, PAD_ID)


def _make_step(use_kernel_filter: bool, block_n: int, drift_cfg=None,
               bucket_ks: Tuple[int, ...] = (), update_path: str = "auto",
               with_metrics: bool = False, mesh=None, donate: bool = False,
               bucket_engines: Tuple[str, ...] = (),
               with_costs: bool = False):
    """One jitted step over ALL buckets: states/batches are same-length
    tuples (the pytree structure is static, so the whole fleet advances in
    a single XLA computation). With ``drift_cfg`` (online re-planning) the
    step also advances each bucket's drift-detector state from the chunk's
    write counts — the sequential statistics stay (M,)-batched on device.

    ``bucket_engines`` tags each bucket's backend (empty = all
    ``"exact"``): ``"logmem"`` buckets carry ``logmem.LogmemState``
    pytrees and advance through ``logmem.update`` (threshold-compare
    admission via the ``kernels.logmem_update`` Pallas scan when
    ``use_kernel_filter``); they report no evictions, their metrics bar
    is the active threshold ``tau``, and their drift evidence is tested
    with the backend's ``law_slack`` tolerance folded into the
    thresholds.

    ``update_path`` picks the wide-batch (W >= K) update: "auto" (the
    default) dispatches to ``filtered_update`` — the jnp filter+merge
    beats the fused vmap sort-merge at every fleet size in
    BENCH_streams.json (the sort works on K+W columns; the filter tops
    K survivors out of W then merges K+K) — while "fused" keeps the
    legacy all-sort path. ``use_kernel_filter`` upgrades the filtered
    path's candidate scan to the Pallas kernel. Narrow batches (W < K)
    always take the fused sort-merge, whose one sort is then cheaper.

    With ``with_metrics`` (repro.obs) the step additionally folds a
    device-side ``obs.metrics.MetricsState`` — a few scalar reductions
    over values the step already materializes, fused into the same XLA
    program; when off, ``mstate`` is an empty tuple and the traced
    computation is exactly the pre-obs step (bit-identical outputs).

    With ``with_costs`` (obs.costs) the step also folds each bucket's
    device ``CostState`` ledger — integer per-(stream, tier) write /
    delete / doc-step counts against the stream's boundary vector,
    priced on host only at drain. Same discipline as the metrics state:
    fused reductions over values the step already materializes, and
    ``cstates = ()`` when off leaves the traced computation unchanged.

    With ``mesh`` (a ``parallel.fleet`` mesh) the whole step is
    ``shard_map``-ped over the fleet axis: every leading-M leaf —
    reservoir state, batch, drift state — splits across devices and each
    shard runs the exact single-device program on its rows (every update
    is row-independent, so sharded outputs are bit-identical; tests
    assert it). The metrics state keeps one counter block per shard
    (aggregated at snapshot), so the step stays collective-free.
    ``donate`` builds the double-buffered ingestion variant: the previous
    chunk's state/drift/metrics buffers are donated to XLA, letting the
    outputs reuse them while the next chunk's host→device copy is in
    flight (``StreamEngine.ingest_chunks``).
    """
    if drift_cfg is not None:
        from repro.online import drift as drift_mod
    if with_metrics:
        from repro.obs import metrics as metrics_mod
    if with_costs:
        from repro.obs import costs as costs_mod
    if update_path not in ("auto", "fused"):
        raise ValueError(f"unknown update_path {update_path!r}")

    def step(states, batches, dstates, mstate, cstates):
        if with_metrics and mesh is not None:
            # inside shard_map: squeeze this shard's (1, 8) counter
            # block to the flat layout the accumulate laws expect
            mstate = metrics_mod.shard_local(mstate)
        new_states, wrotes, evs, new_dstates = [], [], [], []
        new_cstates = []
        for bi, (st, (s, i)) in enumerate(zip(states, batches)):
            # quarantine non-finite scores before any compare sees them:
            # NaN fails every comparison (it would never be admitted and
            # never counted) and ±inf corrupts the entry bar / tile max.
            # Both demote to inert pad slots; the count is folded into
            # the metrics state (SCORES_QUARANTINED). With all-finite
            # input the wheres are identity, so outputs are bit-equal to
            # the unsanitized step.
            bad = (i >= 0) & ~jnp.isfinite(s)
            s = jnp.where(bad, -jnp.inf, s)
            i = jnp.where(bad, PAD_ID, i)
            if with_metrics:
                mstate = metrics_mod.accumulate_quarantine(
                    mstate, bad.sum(dtype=jnp.int32))
            if bucket_engines and bucket_engines[bi] == "logmem":
                new, wrote = logmem.update(st, s, i, int(bucket_ks[bi]),
                                           block_n=block_n,
                                           use_pallas=use_kernel_filter)
                # no ids stored → nothing evictable; the meter sees an
                # empty delete set and occupancy = cumulative writes
                ev = jnp.full((s.shape[0], 0), PAD_ID, jnp.int32)
                bar = st.tau
                slack = logmem.law_slack(bucket_ks[bi])
                if with_costs:
                    new_cstates.append(costs_mod.accumulate_logmem(
                        cstates[bi], i, wrote))
            else:
                wide = s.shape[1] >= st.scores.shape[1]
                if wide and (update_path == "auto" or use_kernel_filter):
                    new, wrote = filtered_update(st, s, i, block_n=block_n,
                                                 use_pallas=use_kernel_filter)
                else:
                    new, wrote = update(st, s, i)
                ev = evicted_ids(st, new)
                bar = st.scores[:, -1]
                slack = 0.0
                if with_costs:
                    new_cstates.append(costs_mod.accumulate_exact(
                        cstates[bi], i, wrote, ev, new.ids))
            new_states.append(new)
            wrotes.append(wrote)
            evs.append(ev)
            if drift_cfg is not None:
                new_dstates.append(drift_mod.update(
                    dstates[bi], wrote.sum(axis=1), new.seen,
                    float(bucket_ks[bi]), drift_cfg, slack=slack))
            if with_metrics:
                mstate = metrics_mod.accumulate_bucket(
                    mstate, s, i, bar, wrote, ev)
        if with_metrics:
            if drift_cfg is not None and new_dstates:
                score_max = jnp.asarray(0.0, jnp.float32)
                fired = jnp.asarray(0, jnp.int32)
                for bi, ds in enumerate(new_dstates):
                    sl = (logmem.law_slack(bucket_ks[bi])
                          if bucket_engines and bucket_engines[bi] == "logmem"
                          else 0.0)
                    score_max = jnp.maximum(
                        score_max,
                        drift_mod.scores(ds, drift_cfg, slack=sl).max())
                    fired = fired + ds.fired.sum(dtype=jnp.int32)
                mstate = metrics_mod.accumulate_drift(mstate, score_max,
                                                      fired)
            mstate = metrics_mod.bump_chunk(mstate)
        if with_metrics and mesh is not None:
            mstate = metrics_mod.shard_pack(mstate)
        return tuple(new_states), tuple(wrotes), tuple(evs), \
            tuple(new_dstates), mstate, tuple(new_cstates)

    if mesh is not None:
        from repro.parallel import fleet
        spec = fleet.row_spec()
        step = fleet.shard_map(step, mesh=mesh,
                               in_specs=(spec,) * 5,
                               out_specs=(spec,) * 6,
                               check_rep=False)
    return jax.jit(step, donate_argnums=(0, 2, 3, 4) if donate else ())


# ---------------------------------------------------------------------------
# Fleet orchestration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReplanEvent:
    """One online re-planning decision (``StreamEngine.replan_events``)."""

    stream_id: int
    row: int
    position: int  # docs the stream had observed at decision time
    rho: float  # detector's rate-multiplier estimate
    old_bounds: Tuple[float, ...]
    new_bounds: Tuple[float, ...]
    applied: bool
    feasible: bool  # constrained suffix re-solve found a feasible plan
    suffix_cost_old: float
    suffix_cost_new: float
    move_bill: float  # expected relocation cost priced into the decision
    moved_docs: int  # residents actually re-tiered by the meter


@dataclass(frozen=True)
class AdmissionEvent:
    """Advisory terms for a stream whose constrained suffix re-solve was
    infeasible (``StreamEngine.admission_events``): the negotiated K /
    window apply at the tenant's next window — a live reservoir row
    cannot be resized mid-window."""

    stream_id: int
    row: int
    position: int
    decision: object  # online.admission.AdmissionDecision


@dataclass(frozen=True)
class StreamSpec:
    """One tenant stream: its K, and either an explicit placement — a
    changeover index ``r`` (two-tier) or a ``boundaries`` vector (N-tier),
    with ``migrate`` choosing Algorithm C's cascade at the boundaries — or
    a cost model (two-tier or N-tier topology) for the proactive planner
    to derive both. Streams of different tier depths mix freely in one
    fleet.

    ``engine`` picks the reservoir backend: ``"exact"`` (default) keeps
    the full (K,) score/id rows; ``"logmem"`` keeps O(log K) state
    (``streams.logmem`` — huge-K tenants) at a 1−O(1/√K) admission
    slack. Logmem streams cannot run the migration cascade (no resident
    ids to cascade) — the planner's derived ``migrate`` is forced off
    for them and an explicit ``migrate=True`` is rejected."""

    stream_id: int
    k: int
    cost_model: Optional[TwoTierCostModel | NTierCostModel] = None
    r: Optional[float] = None
    migrate: bool = False
    boundaries: Optional[Tuple[float, ...]] = None
    engine: str = "exact"

    def explicit_boundaries(self) -> Optional[Tuple[float, ...]]:
        if self.boundaries is not None:
            return tuple(float(b) for b in self.boundaries)
        return (float(self.r),) if self.r is not None else None


class StreamEngine:
    """Host-side orchestrator: buckets streams by K, plans placement for
    the whole fleet in one vectorized pass, routes mixed ingest batches,
    advances every bucket inside one jitted step, and meters per-stream
    ledgers against the analytic expectations.

    Usage::

        engine = StreamEngine(specs)
        engine.ingest(stream_ids, scores, doc_ids)   # mixed batch, any order
        survivors = engine.finalize()                # {stream_id: top-K ids}
        engine.meter.reconcile(batch=W)              # vs analytic write law
    """

    def __init__(self, specs: Sequence[StreamSpec], *,
                 use_kernel_filter: bool = False, block_n: int = 512,
                 constraints=None, replan=None, update_path: str = "auto",
                 obs=None, mesh=None):
        if not specs:
            raise ValueError("need at least one stream")
        # fleet-axis sharding (parallel.fleet): with a >=2-device mesh
        # every per-bucket state splits row-wise across devices, the
        # jitted step runs shard_map-ped, and the planner entry points
        # below dispatch per shard; a 1-device mesh is the plain path
        self._shards = 1
        if mesh is not None:
            from repro.parallel import fleet
            self._shards = fleet.n_shards(mesh)
            if self._shards < 2:
                mesh, self._shards = None, 1
        self.mesh = mesh
        by_id = {s.stream_id: s for s in specs}
        if len(by_id) != len(specs):
            raise ValueError("duplicate stream ids")
        for s in specs:
            if s.engine not in ("exact", "logmem"):
                raise ValueError(f"stream {s.stream_id}: unknown engine "
                                 f"{s.engine!r} (exact|logmem)")
            if s.engine == "logmem" and s.migrate:
                raise ValueError(
                    f"stream {s.stream_id}: engine='logmem' stores no "
                    "resident ids — the migration cascade needs the exact "
                    "backend")
        self.buckets = router.bucket_streams(
            {s.stream_id: s.k for s in specs},
            {s.stream_id: s.engine for s in specs})
        self.router = router.StreamRouter(self.buckets)
        self.constraints = constraints
        # observability (repro.obs): device metric pytree in the step,
        # residual alert channel off the meter drain, span/event timeline
        self._obs = obs
        self._tracer = obs.tracer if obs is not None else None
        if obs is not None:
            obs.attach(self)
        # fleet plan for streams that carry a cost model (2- and N-tier mix)
        planned = [s for s in specs if s.explicit_boundaries() is None]
        if planned:
            if any(s.cost_model is None for s in planned):
                raise ValueError(
                    "each stream needs r, boundaries, or a cost_model")
            if self._tracer is not None:
                with self._tracer.span("plan", streams=len(planned)):
                    plan = planner.plan_fleet_mixed(
                        [s.cost_model for s in planned],
                        constraints=constraints, mesh=mesh)
            else:
                plan = planner.plan_fleet_mixed(
                    [s.cost_model for s in planned],
                    constraints=constraints, mesh=mesh)
            bad = [s.stream_id for i, s in enumerate(planned)
                   if not plan.feasible(i)]
            if bad:
                raise ValueError(
                    f"streams {bad} have no feasible plan under the given "
                    "constraints — relax capacities/SLO or drop the streams")
            b_of = {s.stream_id: plan.boundaries[i]
                    for i, s in enumerate(planned)}
            mig_of = {s.stream_id: plan.migrate(i)
                      for i, s in enumerate(planned)}
            self.plan: Optional[planner.MixedFleetPlan] = plan
        else:
            b_of, mig_of = {}, {}
            self.plan = None
        # global row order = bucket order × row order (the meter's layout)
        self._global_rows: List[np.ndarray] = []
        ks, bounds, migs, logmems = [], [], [], []
        offset = 0
        self._row_of: Dict[int, int] = {}
        self._model_of_row: Dict[int, object] = {}
        for b in self.buckets:
            rows = np.arange(offset, offset + b.m, dtype=np.int64)
            self._global_rows.append(rows)
            for j, sid in enumerate(b.stream_ids):
                self._row_of[sid] = offset + j
                spec = by_id[sid]
                if spec.cost_model is not None:
                    self._model_of_row[offset + j] = spec.cost_model
                ks.append(spec.k)
                logmems.append(spec.engine == "logmem")
                explicit = spec.explicit_boundaries()
                if explicit is not None:
                    bounds.append(explicit)
                    migs.append(spec.migrate)
                elif spec.engine == "logmem":
                    # planner-derived cascades need resident ids; logmem
                    # tenants take the plan's boundaries statically
                    bounds.append(b_of[sid])
                    migs.append(False)
                else:
                    bounds.append(b_of[sid])
                    migs.append(mig_of[sid])
            offset += b.m
        self._sid_of_row = {row: sid for sid, row in self._row_of.items()}
        self.meter = metering.FleetMeter(ks, migrate=migs, boundaries=bounds,
                                         logmem=logmems)
        # sharded buckets pad their row count to a multiple of the shard
        # count; pad rows carry (-inf, -1, seen=0) reservoirs and all-pad
        # batches, which every law (update, drift, metrics) treats as
        # inert — host-facing reads slice back to the true m
        self._pad_m: List[int] = [
            (-(-b.m // self._shards)) * self._shards for b in self.buckets]
        self._states: List = [
            (logmem.init(pm) if b.engine == "logmem" else init(pm, b.k))
            for pm, b in zip(self._pad_m, self.buckets)]
        if mesh is not None:
            from repro.parallel import fleet
            self._states = [fleet.shard_rows(mesh, st)
                            for st in self._states]
        # online re-planning: drift detector inside the jitted step,
        # boundary deltas applied between chunks (repro.online)
        self.replan_config = replan
        self.replan_events: List[ReplanEvent] = []
        self.admission_events: List[AdmissionEvent] = []
        self._drift_states = None
        self._replanner = None
        if replan is not None:
            from repro.online import drift as drift_mod
            from repro.online.replan import Replanner
            cset_arg = constraints
            if isinstance(constraints, (list, tuple)):
                # per-spec constraint lists align with the specs sequence;
                # the replanner indexes by global row
                by_sid = {s.stream_id: c
                          for s, c in zip(specs, constraints)}
                cset_arg = [by_sid[self._sid_of_row[row]]
                            for row in range(self.m)]
            self._replanner = Replanner(
                [self._model_of_row.get(row) for row in range(self.m)],
                constraints=cset_arg, config=replan)
            self._drift_states = [drift_mod.init(pm) for pm in self._pad_m]
            if mesh is not None:
                from repro.parallel import fleet
                self._drift_states = [fleet.shard_rows(mesh, ds)
                                      for ds in self._drift_states]
        self._metrics_state = None
        self._residuals = None
        if obs is not None:
            if obs.config.metrics:
                from repro.obs import metrics as metrics_mod
                self._metrics_state = metrics_mod.init(
                    shards=self._shards if mesh is not None else 0)
                if mesh is not None:
                    from repro.parallel import fleet
                    self._metrics_state = fleet.shard_rows(
                        mesh, self._metrics_state)
            if obs.config.residuals:
                from repro.obs.residuals import ResidualMonitor
                slack_rows = np.where(
                    self.meter.logmem,
                    np.array([logmem.law_slack(int(k))
                              for k in self.meter.ks]), 0.0)
                self._residuals = ResidualMonitor(
                    self.meter.ks, alpha=obs.config.residual_alpha,
                    max_checks=obs.config.residual_max_checks,
                    law_slack=slack_rows)
        # live cost attribution (obs.costs): device CostState ledger in
        # the step, host CostMonitor (cost residuals + budget burn rate)
        # off the meter drain
        self._cost_states = None
        self._cost_monitor = None
        self._pricing = None
        if obs is not None and obs.config.costs:
            from repro.obs import costs as costs_mod
            self._cost_states = [
                costs_mod.init_bucket(pm,
                                      self.meter.boundaries[rows],
                                      self.meter.n_tiers)
                for pm, rows in zip(self._pad_m, self._global_rows)]
            if mesh is not None:
                from repro.parallel import fleet
                self._cost_states = [fleet.shard_rows(mesh, cs)
                                     for cs in self._cost_states]
            self._pricing = costs_mod.stream_pricing(self)
            slack_rows = np.where(
                self.meter.logmem,
                np.array([logmem.law_slack(int(k))
                          for k in self.meter.ks]), 0.0)
            self._cost_monitor = costs_mod.CostMonitor(
                self.meter.ks, self.meter.boundaries,
                self._pricing["cw"], self._pricing["step_rate"],
                alpha=obs.config.cost_alpha,
                max_checks=obs.config.cost_max_checks,
                law_slack=slack_rows, logmem=self.meter.logmem,
                budget_factor=obs.config.budget_factor,
                burn_windows=obs.config.burn_windows)
        self._step_factory = lambda donate: _make_step(
            use_kernel_filter, block_n,
            drift_cfg=None if replan is None else replan.drift,
            bucket_ks=tuple(b.k for b in self.buckets),
            update_path=update_path,
            with_metrics=self._metrics_state is not None,
            mesh=mesh, donate=donate,
            bucket_engines=tuple(b.engine for b in self.buckets),
            with_costs=self._cost_states is not None)
        self._step = self._step_factory(False)
        self._donating_step = None  # built lazily by ingest_chunks
        # resilience (repro.resilience): the ingest cursor is the chunk
        # sequence number — checkpoint step, and the idempotent
        # redelivery guard's high-water mark; a checkpointer attached via
        # ``attach_checkpointer`` is invoked at every chunk boundary
        # (after the host meter drain, before the next dispatch, so the
        # device buffers it snapshots are final and not yet donated)
        self.chunks_ingested = 0
        self._checkpoint = None
        # tier-outage bookkeeping: failed tiers are masked out of the
        # re-planner's feasible set; a recovered tier stays masked for a
        # hysteresis window (flap damping) before plans may use it again
        self._failed_tiers: Dict[int, int] = {}
        self._recovering_tiers: Dict[int, int] = {}
        self._tier_outages = 0

    @property
    def m(self) -> int:
        return sum(b.m for b in self.buckets)

    def stream_row(self, stream_id: int) -> int:
        """Global (meter) row of a stream."""
        return self._row_of[stream_id]

    def ingest(self, stream_ids, scores, doc_ids, *,
               pad_to: Optional[int] = None) -> None:
        """Feed a mixed batch of scored docs — (stream_id, score, local doc
        index) triples in arbitrary order — through one jitted fleet step.

        A doc id may appear at most once per stream per batch (they are
        stream positions); the router rejects within-batch duplicates.
        Re-observations across batches are deduped by the merge itself."""
        if self._tracer is not None and self._obs.config.trace_ingest:
            with self._tracer.span("ingest", docs=int(len(stream_ids))):
                self._ingest(stream_ids, scores, doc_ids, pad_to)
        else:
            self._ingest(stream_ids, scores, doc_ids, pad_to)

    def _ingest(self, stream_ids, scores, doc_ids, pad_to) -> None:
        routed = self.router.route(stream_ids, scores, doc_ids, pad_to=pad_to)
        self._run_chunk(routed)

    def _stage_batches(self, dense) -> tuple:
        """Host dense per-bucket (scores, ids) pairs → device batches:
        plain ``jnp.asarray`` single-device, or row-padded + fleet-
        sharded ``device_put`` under a mesh (the transfer is async, which
        is what ``ingest_chunks`` overlaps with the previous compute)."""
        if self.mesh is None:
            return tuple((jnp.asarray(s), jnp.asarray(i))
                         for s, i in dense)
        from repro.parallel import fleet
        sh = fleet.row_sharding(self.mesh)
        out = []
        for bi, (s, i) in enumerate(dense):
            pad = self._pad_m[bi] - s.shape[0]
            if pad:
                ps, pi = router.blank_dense(pad, s.shape[1])
                s = np.concatenate([s, ps])
                i = np.concatenate([i, pi])
            out.append((jax.device_put(s, sh), jax.device_put(i, sh)))
        return tuple(out)

    def _dispatch(self, batches, donate: bool):
        """Run one (already staged) fleet step and swap in the new
        device states. Returns (wrotes, evs, new_states) for the host
        meter; all three are still in-flight device arrays."""
        dstates = (tuple(self._drift_states)
                   if self._drift_states is not None else ())
        mstate = (self._metrics_state
                  if self._metrics_state is not None else ())
        cstates = (tuple(self._cost_states)
                   if self._cost_states is not None else ())
        if donate:
            if self._donating_step is None:
                self._donating_step = self._step_factory(True)
            step = self._donating_step
        else:
            step = self._step
        new_states, wrotes, evs, new_dstates, mstate, new_cstates = step(
            tuple(self._states), batches, dstates, mstate, cstates)
        self._states = list(new_states)
        if self._metrics_state is not None:
            self._metrics_state = mstate
        if self._drift_states is not None:
            self._drift_states = list(new_dstates)
        if self._cost_states is not None:
            self._cost_states = list(new_cstates)
        return wrotes, evs, new_states

    def _consume(self, dense, wrotes, evs, new_states,
                 meter: bool = True) -> None:
        """Host side of one step: meter the transactions (slicing any
        sharded padding back off), drain residuals, maybe re-plan."""
        if meter:
            for bi in range(len(self.buckets)):
                b = self.buckets[bi]
                mb = b.m
                dense_scores, dense_ids = dense[bi]
                # mirror the device quarantine: docs whose score is
                # non-finite were demoted to pad slots in the step, so
                # the host meter must not count them as observed either
                if not np.isfinite(dense_scores).all():
                    dense_ids = np.where(np.isfinite(dense_scores),
                                         dense_ids, router.PAD_ID)
                # logmem buckets have no resident ids: no cascade check,
                # and their (mb, 0) eviction set scatters nothing
                st_ids = (None if b.engine == "logmem"
                          else np.asarray(new_states[bi].ids)[:mb])
                self.meter.record_update(
                    self._global_rows[bi], dense_ids,
                    np.asarray(wrotes[bi])[:mb],
                    np.asarray(evs[bi])[:mb], st_ids)
        residual_rows = ()
        if meter and self._residuals is not None:
            # chunk-boundary drain: the alert channel tests the meter's
            # cumulative write residual against its concentration bound
            newly = self._residuals.update(self.meter.observed,
                                           self.meter.writes.sum(1))
            if newly.any() and self._tracer is not None:
                sc = self._residuals.scores()
                for row in np.flatnonzero(newly):
                    self._tracer.emit(
                        "residual_alert", stream_id=self._sid_of_row[row],
                        row=int(row), position=int(self.meter.observed[row]),
                        score=float(sc[row]),
                        step=int(self._residuals.steps))
            if (self._obs.config.residual_trigger
                    and self._drift_states is not None):
                residual_rows = tuple(
                    int(r) for r in np.flatnonzero(self._residuals.alerted))
        cost_rows = ()
        if meter and self._cost_monitor is not None:
            # the cost channel runs off the same meter drain: realized
            # spend vs the closed-form expected-cost trajectory
            newly_cost, newly_burn = self._cost_monitor.update(
                self.meter.observed, self.meter.writes,
                self.meter.doc_steps)
            if self._tracer is not None and newly_cost.any():
                sc = self._cost_monitor.scores()
                for row in np.flatnonzero(newly_cost):
                    self._tracer.emit(
                        "cost_alert", stream_id=self._sid_of_row[row],
                        row=int(row),
                        position=int(self.meter.observed[row]),
                        score=float(sc[row]),
                        step=int(self._cost_monitor.steps))
            if self._tracer is not None and newly_burn.any():
                br = self._cost_monitor.burn_ratio()
                for row in np.flatnonzero(newly_burn):
                    self._tracer.emit(
                        "budget_burn", stream_id=self._sid_of_row[row],
                        row=int(row),
                        position=int(self.meter.observed[row]),
                        burn_ratio=float(br[row]),
                        realized=float(
                            self._cost_monitor.realized_total[row]),
                        planned=float(
                            self._cost_monitor.planned_total[row]),
                        step=int(self._cost_monitor.steps))
            if (self._obs.config.cost_trigger
                    and self._drift_states is not None):
                cost_rows = tuple(int(r) for r in np.flatnonzero(
                    self._cost_monitor.alerted
                    | self._cost_monitor.burn_alerted))
        if meter and self._drift_states is not None:
            self._maybe_replan(residual_rows, cost_rows)

    def _run_chunk(self, dense, *, meter: bool = True,
                   donate: bool = False) -> None:
        batches = self._stage_batches(dense)
        wrotes, evs, new_states = self._dispatch(batches, donate)
        self._consume(dense, wrotes, evs, new_states, meter=meter)
        self._chunk_boundary()

    def _chunk_boundary(self) -> None:
        """Advance the ingest cursor and fire the chunk-boundary
        checkpoint hook (device buffers are final here and the next
        chunk has not been dispatched, so a snapshot is consistent and
        its device→host copies cannot race a donation)."""
        self.chunks_ingested += 1
        if self._checkpoint is not None:
            self._checkpoint.on_chunk(self)

    def attach_checkpointer(self, checkpointer) -> None:
        """Install a chunk-boundary checkpoint hook (an object with
        ``on_chunk(engine)`` — see ``resilience.FleetCheckpointer``)."""
        if not hasattr(checkpointer, "on_chunk"):
            raise TypeError("checkpointer needs an on_chunk(engine) hook")
        self._checkpoint = checkpointer

    def ingest_dense(self, dense, *, meter: bool = True) -> None:
        """Dense per-bucket ingestion, bypassing the host router: one
        ``(scores (M_b, W), doc_ids (M_b, W))`` pair per bucket, aligned
        with ``self.buckets``, rows ordered by doc id and padded with
        ``(-inf, -1)`` — the layout ``router.route`` would produce. This
        is the million-stream path: at fleet scale the router's host
        scatter dominates, and producers that already emit per-stream
        chunks can feed the jitted step directly.

        ``meter=False`` skips the per-stream host ledgers *and* the
        online re-plan/residual hooks for this chunk (pure-throughput
        mode; the device states and obs counters still advance).
        """
        if len(dense) != len(self.buckets):
            raise ValueError(f"need one (scores, ids) pair per bucket "
                             f"({len(self.buckets)}), got {len(dense)}")
        dense = [(np.asarray(s, np.float32), np.asarray(i, np.int32))
                 for s, i in dense]
        for bi, (s, i) in enumerate(dense):
            if s.shape != i.shape or s.shape[0] != self.buckets[bi].m:
                raise ValueError(
                    f"bucket {bi}: scores {s.shape} / ids {i.shape} do "
                    f"not match the bucket's {self.buckets[bi].m} streams")
        self._run_chunk(dense, meter=meter)

    def ingest_chunks(self, chunks, *, meter: bool = True) -> int:
        """Async double-buffered dense ingestion: consume an iterable of
        ``ingest_dense``-shaped chunk lists, keeping chunk t+1's
        host→device transfer in flight while chunk t computes, and
        donating the previous state/drift/metrics buffers to the step so
        XLA reuses them for the outputs (no steady-state allocation).
        Returns the number of chunks processed."""
        it = iter(chunks)
        nxt = next(it, None)
        staged = self._stage_batches(nxt) if nxt is not None else None
        count = 0
        while staged is not None:
            dense = nxt
            # dispatch is async: the step runs while we stage chunk t+1
            wrotes, evs, new_states = self._dispatch(staged, donate=True)
            nxt = next(it, None)
            staged = self._stage_batches(nxt) if nxt is not None else None
            # host consumption blocks on chunk t's outputs last
            self._consume(dense, wrotes, evs, new_states, meter=meter)
            # chunk-boundary checkpoint: the device→host copies read
            # finished buffers, the npy write runs on the manager's
            # worker thread while chunk t+1 (already staged) computes
            self._chunk_boundary()
            count += 1
        return count

    def _maybe_replan(self, residual_rows: Sequence[int] = (),
                      cost_rows: Sequence[int] = ()) -> None:
        """Between chunks: re-plan the streams whose drift detector fired
        — unioned with the obs residual-alert channel when it is
        configured as an earlier trigger (``ObsConfig.residual_trigger``)
        and with the cost/budget-burn channel under
        ``ObsConfig.cost_trigger`` — apply the boundary deltas to the
        meter (re-tiering residents, with the relocation bill already
        priced into the decision), and reset the consumed detector (and
        residual/cost) evidence."""
        from repro.online import drift as drift_mod
        fired_rows, rhos = [], []
        bucket_of, row_in_bucket = [], []
        extra = set(residual_rows) | set(cost_rows)
        for bi in range(len(self.buckets)):
            ds = self._drift_states[bi]
            fired = np.asarray(ds.fired)[:self.buckets[bi].m]
            rows_b = self._global_rows[bi]
            flag = fired.copy()
            if extra:
                flag |= np.isin(rows_b, list(extra))
            if not flag.any():
                continue
            rho_b = np.asarray(drift_mod.rho_hat(ds,
                                                 self.replan_config.drift))
            for j in np.flatnonzero(flag):
                fired_rows.append(int(rows_b[j]))
                rhos.append(float(rho_b[j]))
                bucket_of.append(bi)
                row_in_bucket.append(int(j))
        if not fired_rows:
            return
        rows = np.asarray(fired_rows, np.int64)
        bounds = []
        for row in rows:
            cm = self._model_of_row.get(row)
            b = self.meter.boundaries[row]
            depth = (cm.t - 1 if hasattr(cm, "t")
                     else int(np.isfinite(b).sum()))
            bounds.append(tuple(b[:depth]))
        exclude = self._excluded_tier_set()
        if self._tracer is not None:
            with self._tracer.span("replan", flagged=len(fired_rows)):
                dec = self._replanner.replan(
                    rows, self.meter.observed[rows], np.asarray(rhos),
                    bounds, self.meter.migrate[rows],
                    hwm=self.meter.occupancy_hwm[rows],
                    exclude_tiers=exclude)
        else:
            dec = self._replanner.replan(rows, self.meter.observed[rows],
                                         np.asarray(rhos), bounds,
                                         self.meter.migrate[rows],
                                         hwm=self.meter.occupancy_hwm[rows],
                                         exclude_tiers=exclude)
        touched_buckets = set()
        for j, row in enumerate(rows):
            if not dec.considered[j]:
                continue  # no model / cascade / window over: nothing to log
            moved = 0
            if not dec.feasible[j]:
                self._negotiate_admission(int(row), int(dec.n_seen[j]))
            if dec.applied[j]:
                bi, jb = bucket_of[j], row_in_bucket[j]
                ids_arg = (None if self.buckets[bi].engine == "logmem"
                           else np.asarray(self._states[bi].ids[jb]))
                moved = self.meter.apply_boundaries(
                    int(row), dec.new_bounds[j], ids_arg)
                touched_buckets.add(bi)
                if self._cost_states is not None:
                    # swap the device ledger's boundary row (a scatter —
                    # no recompile) and the monitor's planned trajectory
                    from repro.obs import costs as costs_mod
                    self._cost_states[bi] = costs_mod.set_bucket_bounds(
                        self._cost_states[bi], jb,
                        self.meter.boundaries[int(row)])
                    self._cost_monitor.set_bounds(
                        int(row), self.meter.boundaries[int(row)])
            self.replan_events.append(ReplanEvent(
                stream_id=self._sid_of_row[int(row)], row=int(row),
                position=int(dec.n_seen[j]), rho=float(dec.rho[j]),
                old_bounds=dec.old_bounds[j], new_bounds=dec.new_bounds[j],
                applied=bool(dec.applied[j]), feasible=bool(dec.feasible[j]),
                suffix_cost_old=float(dec.suffix_cost_old[j]),
                suffix_cost_new=float(dec.suffix_cost_new[j]),
                move_bill=float(dec.move_bill[j]), moved_docs=moved))
            if self._tracer is not None:
                self._tracer.emit(
                    "replan_decision", stream_id=self._sid_of_row[int(row)],
                    row=int(row), position=int(dec.n_seen[j]),
                    rho=float(dec.rho[j]), applied=bool(dec.applied[j]),
                    feasible=bool(dec.feasible[j]), moved_docs=moved,
                    residual_triggered=int(row) in set(residual_rows),
                    cost_triggered=int(row) in set(cost_rows))
        # boundary deltas are placement metadata: the reservoirs themselves
        # must be untouched — every affected bucket keeps the sorted-desc
        # score invariant the merge relies on
        for bi in touched_buckets:
            if self.buckets[bi].engine == "logmem":
                continue  # no reservoir rows to corrupt
            scores = np.asarray(self._states[bi].scores)
            # note -inf pads diff to NaN on unfull rows — only a strictly
            # positive diff is a genuine order violation
            assert not np.any(np.diff(scores, axis=1) > 0), \
                "re-plan corrupted reservoir score order"
        for bi in set(bucket_of):
            mask = np.zeros(self._pad_m[bi], bool)
            mask[[row_in_bucket[j] for j in range(len(rows))
                  if bucket_of[j] == bi]] = True
            self._drift_states[bi] = drift_mod.reset_where(
                self._drift_states[bi], jnp.asarray(mask))
            if self.mesh is not None:
                # the eager where may have gathered — re-pin the fleet layout
                from repro.parallel import fleet
                self._drift_states[bi] = fleet.shard_rows(
                    self.mesh, self._drift_states[bi])
        if self._cost_states is not None and self.mesh is not None:
            # the eager bounds scatter may have gathered — re-pin
            from repro.parallel import fleet
            for bi in touched_buckets:
                self._cost_states[bi] = fleet.shard_rows(
                    self.mesh, self._cost_states[bi])
        if self._residuals is not None:
            # the re-plan consumed this evidence — restart the residual
            # channel for the processed rows, like the detector
            rmask = np.zeros(self.m, bool)
            rmask[rows] = True
            self._residuals.reset_where(rmask)
        if self._cost_monitor is not None:
            cmask = np.zeros(self.m, bool)
            cmask[rows] = True
            self._cost_monitor.reset_where(cmask)

    def _negotiate_admission(self, row: int, position: int) -> None:
        """A constrained suffix re-solve found no feasible plan (or the
        observed occupancy already violates a capacity): negotiate
        next-window terms for the tenant instead of silently dropping the
        event."""
        from repro.online.admission import AdmissionController
        cm = self._model_of_row.get(row)
        if cm is None:
            return
        cset = self._replanner.csets[row]
        decision = AdmissionController(cset).admit(
            cm.as_ntier() if isinstance(cm, TwoTierCostModel) else cm)
        self.admission_events.append(AdmissionEvent(
            stream_id=self._sid_of_row[row], row=row, position=position,
            decision=decision))
        if self._tracer is not None:
            self._tracer.emit("admission", stream_id=self._sid_of_row[row],
                              row=row, position=position,
                              admitted=bool(getattr(decision, "admitted",
                                                    False)))

    # ---- tier-outage graceful degradation -------------------------------

    def _bucket_of(self, row: int) -> Tuple[int, int]:
        """(bucket index, row within bucket) of a global meter row."""
        for bi, rows in enumerate(self._global_rows):
            if rows.size and rows[0] <= row <= rows[-1]:
                return bi, int(row - rows[0])
        raise KeyError(row)

    def _apply_row_bounds(self, row: int, new_bounds) -> int:
        """Apply a new boundary vector to one stream everywhere it
        lives: host meter (re-tiering residents), device cost ledger,
        and the cost monitor's planned trajectory. Returns the number
        of relocated residents."""
        bi, jb = self._bucket_of(row)
        ids_arg = (None if self.buckets[bi].engine == "logmem"
                   else np.asarray(self._states[bi].ids[jb]))
        moved = self.meter.apply_boundaries(row, new_bounds, ids_arg)
        if self._cost_states is not None:
            from repro.obs import costs as costs_mod
            self._cost_states[bi] = costs_mod.set_bucket_bounds(
                self._cost_states[bi], jb, self.meter.boundaries[row])
            self._cost_monitor.set_bounds(row, self.meter.boundaries[row])
        return moved

    def _excluded_tier_set(self) -> frozenset:
        """Tiers no plan may place onto right now: failed tiers, plus
        recovered tiers still inside their hysteresis window (expired
        entries are purged — flap damping)."""
        expired = [t for t, until in self._recovering_tiers.items()
                   if self.chunks_ingested >= until]
        for t in expired:
            del self._recovering_tiers[t]
        return frozenset(self._failed_tiers) | frozenset(
            self._recovering_tiers)

    def tier_outage(self, tier: int, *, burn_grace: int = 8) -> Dict:
        """Declare a storage tier failed: mask it out of every future
        re-plan's feasible set and evacuate affected streams onto the
        surviving tiers now — a forced constrained suffix re-solve for
        streams with a cost model (relocation hop-priced, applied on
        feasibility rather than savings), a geometric boundary merge
        (``core.constraints.evacuation_boundaries``) for the rest.

        The relocation spend spike is operator-induced, so the cost
        channel is kept honest rather than silenced wholesale: the
        evacuation bill is credited to each stream's planned trajectory
        (``CostMonitor.add_planned`` — regret does not blame the
        placement) and budget-burn alerts are suppressed for
        ``burn_grace`` chunks on the evacuated rows only.

        Returns a summary dict; emits ``tier_outage`` (and per-stream
        ``tier_evacuation``) on the obs event log. Idempotent: a tier
        already failed returns ``{"already_failed": True}`` without
        re-evacuating (flap protection on the failure side)."""
        nt = self.meter.n_tiers
        if not 0 <= tier < nt:
            raise ValueError(f"tier {tier} out of range (fleet has {nt} "
                             "tiers)")
        if tier in self._failed_tiers:
            return {"tier": tier, "already_failed": True,
                    "rows_evacuated": 0, "rows": [], "moved_docs": 0,
                    "bill": 0.0, "skipped_rows": [],
                    "infeasible_rows": []}
        # a re-failure during recovery hysteresis folds into the outage
        self._recovering_tiers.pop(tier, None)
        self._failed_tiers[tier] = self.chunks_ingested
        self._tier_outages += 1
        summary = self._evacuate_tier(tier, burn_grace=burn_grace)
        if self._tracer is not None:
            self._tracer.emit(
                "tier_outage", tier=tier, chunk=self.chunks_ingested,
                rows_evacuated=summary["rows_evacuated"],
                moved_docs=summary["moved_docs"], bill=summary["bill"],
                skipped=len(summary["skipped_rows"]),
                infeasible=len(summary["infeasible_rows"]))
        return summary

    def tier_recover(self, tier: int, *, hysteresis: int = 2) -> None:
        """Clear a tier's outage. The tier stays masked from re-plans
        for ``hysteresis`` more chunks (flap damping) before placements
        may use it again; evacuated streams migrate back only through
        the ordinary re-plan channel — there is no forced
        un-evacuation."""
        if tier not in self._failed_tiers:
            raise ValueError(f"tier {tier} is not failed")
        del self._failed_tiers[tier]
        self._recovering_tiers[tier] = self.chunks_ingested + int(hysteresis)
        if self._tracer is not None:
            self._tracer.emit(
                "tier_recovered", tier=tier, chunk=self.chunks_ingested,
                masked_until_chunk=int(self._recovering_tiers[tier]))

    def _evacuate_tier(self, tier: int, *, burn_grace: int) -> Dict:
        """Move every affected stream off a failed tier. Affected =
        the tier exists in the stream's placement AND (residents live
        there now, or future arrivals would land there). Cascade
        (migrating) streams cannot re-tier residents and are skipped,
        as are single-tier streams (no surviving tier to move into) —
        both are reported, not silently dropped."""
        from repro.core import constraints as cons_mod
        meter = self.meter
        b = meter.boundaries
        m = self.m
        observed = meter.observed.astype(np.float64)
        lo = b[:, tier - 1] if tier > 0 else np.zeros(m)
        hi = (b[:, tier] if tier < b.shape[1] else np.full(m, np.inf))
        exists = np.isfinite(lo) if tier > 0 else np.ones(m, bool)
        resident = ((meter.occupancy[:, tier] > 0)
                    if tier < meter.n_tiers else np.zeros(m, bool))
        future = (hi > lo) & (hi > observed)
        affected = exists & (resident | future)
        rr0 = meter.reloc_reads.copy()
        rw0 = meter.reloc_writes.copy()
        evacuated: List[int] = []
        skipped: List[int] = []
        infeasible: List[int] = []
        touched: set = set()
        moved_total = 0
        exclude = self._excluded_tier_set()
        for row in np.flatnonzero(affected):
            row = int(row)
            if meter.migrate[row]:
                skipped.append(row)
                continue
            depth = int(np.isfinite(b[row]).sum())
            if depth == 0:
                skipped.append(row)  # single-tier: nowhere to go
                continue
            old = tuple(float(x) for x in b[row, :depth])
            moved = 0
            applied = False
            if (self._model_of_row.get(row) is not None
                    and self._replanner is not None):
                rho = 1.0
                if self._drift_states is not None:
                    from repro.online import drift as drift_mod
                    bi, jb = self._bucket_of(row)
                    rho = float(np.asarray(drift_mod.rho_hat(
                        self._drift_states[bi],
                        self.replan_config.drift))[jb])
                dec = self._replanner.replan(
                    np.asarray([row], np.int64), meter.observed[[row]],
                    np.asarray([rho]), [old], meter.migrate[[row]],
                    hwm=meter.occupancy_hwm[[row]],
                    exclude_tiers=exclude, force=True)
                if not dec.feasible[0]:
                    # the surviving tiers cannot honor the constraints:
                    # negotiate next-window terms, but still evacuate —
                    # data cannot stay on a dead tier
                    infeasible.append(row)
                    self._negotiate_admission(row,
                                              int(meter.observed[row]))
                if dec.applied[0]:
                    moved = self._apply_row_bounds(row, dec.new_bounds[0])
                    applied = True
            if not applied:
                newb = cons_mod.evacuation_boundaries(old, tier)
                moved = self._apply_row_bounds(row, tuple(newb))
            evacuated.append(row)
            touched.add(self._bucket_of(row)[0])
            moved_total += moved
            if self._tracer is not None:
                self._tracer.emit(
                    "tier_evacuation", stream_id=self._sid_of_row[row],
                    row=row, tier=tier, moved_docs=moved,
                    replanned=applied,
                    position=int(meter.observed[row]))
        bill = 0.0
        bills = np.zeros(m, np.float64)
        if self._pricing is not None:
            d_rr = (meter.reloc_reads - rr0).astype(np.float64)
            d_rw = (meter.reloc_writes - rw0).astype(np.float64)
            bills = (d_rr * self._pricing["cr"]).sum(1) \
                + (d_rw * self._pricing["cw"]).sum(1)
            bill = float(bills.sum())
        if evacuated:
            emask = np.zeros(m, bool)
            emask[evacuated] = True
            # the evacuation consumed whatever evidence the monitors had
            # anchored to the old placement — restart it, like a re-plan
            if self._drift_states is not None:
                from repro.online import drift as drift_mod
                for bi in sorted(touched):
                    rows_b = self._global_rows[bi]
                    bmask = np.zeros(self._pad_m[bi], bool)
                    bmask[[r - int(rows_b[0]) for r in evacuated
                           if rows_b[0] <= r <= rows_b[-1]]] = True
                    self._drift_states[bi] = drift_mod.reset_where(
                        self._drift_states[bi], jnp.asarray(bmask))
                    if self.mesh is not None:
                        from repro.parallel import fleet
                        self._drift_states[bi] = fleet.shard_rows(
                            self.mesh, self._drift_states[bi])
            if self._cost_states is not None and self.mesh is not None:
                from repro.parallel import fleet
                for bi in sorted(touched):
                    self._cost_states[bi] = fleet.shard_rows(
                        self.mesh, self._cost_states[bi])
            if self._residuals is not None:
                self._residuals.reset_where(emask)
            if self._cost_monitor is not None:
                self._cost_monitor.reset_where(emask)
                self._cost_monitor.suppress_burn(emask, burn_grace)
                for row in evacuated:
                    self._cost_monitor.add_planned(row, float(bills[row]))
        return {"tier": tier, "already_failed": False,
                "rows_evacuated": len(evacuated),
                "rows": [int(r) for r in evacuated],
                "moved_docs": int(moved_total), "bill": bill,
                "skipped_rows": skipped, "infeasible_rows": infeasible}

    def drift_scores(self) -> Dict[int, float]:
        """{stream_id: normalized change score} (>= 1 fires; online mode
        only)."""
        from repro.online import drift as drift_mod
        if self._drift_states is None:
            raise ValueError("engine built without replan=")
        out = {}
        for bi, b in enumerate(self.buckets):
            sl = logmem.law_slack(b.k) if b.engine == "logmem" else 0.0
            sc = np.asarray(drift_mod.scores(self._drift_states[bi],
                                             self.replan_config.drift,
                                             slack=sl))
            out.update({sid: float(sc[j])
                        for j, sid in enumerate(b.stream_ids)})
        return out

    def states(self) -> List[BatchedReservoirState]:
        return list(self._states)

    def thresholds(self) -> Dict[int, float]:
        out = {}
        for bi, b in enumerate(self.buckets):
            bar_fn = (logmem.thresholds if b.engine == "logmem"
                      else thresholds)
            bars = np.asarray(bar_fn(self._states[bi]))
            out.update({sid: float(bars[j])
                        for j, sid in enumerate(b.stream_ids)})
        return out

    def survivors(self) -> Dict[int, np.ndarray]:
        """{stream_id: sorted local doc ids currently in the reservoir}.
        Logmem streams store no ids — they report an empty set (their
        admitted docs live in tiered storage, not in device state)."""
        out = {}
        for bi, b in enumerate(self.buckets):
            if b.engine == "logmem":
                for sid in b.stream_ids:
                    out[sid] = np.empty(0, np.int64)
                continue
            ids = np.asarray(self._states[bi].ids)
            for j, sid in enumerate(b.stream_ids):
                v = ids[j]
                out[sid] = np.sort(v[v >= 0]).astype(np.int64)
        return out

    def residual_alerts(self) -> Dict[int, int]:
        """{stream_id: docs observed at first alert} of the obs residual
        channel — directly comparable to ``replan_events[i].position``
        (streams that never alerted are absent; obs mode only)."""
        if self._residuals is None:
            raise ValueError("engine built without obs= (or residuals off)")
        out = {}
        for row in np.flatnonzero(self._residuals.first_alert_seen >= 0):
            out[self._sid_of_row[int(row)]] = int(
                self._residuals.first_alert_seen[row])
        return out

    def obs_snapshot(self) -> Dict:
        """Everything the obs layer exports for this engine: drained
        device counters, meter ledger aggregates (per-tier occupancy
        high-water marks, relocations), and the model-referenced
        residual metrics (realized / expected / z for the write law;
        realized / expected for the occupancy law)."""
        from repro.obs import residuals as res_mod
        out: Dict = {"fleet": {"m": self.m, "buckets": len(self.buckets),
                               "logmem_streams":
                                   int(self.meter.logmem.sum())}}
        if self._metrics_state is not None:
            from repro.obs import metrics as metrics_mod
            out["engine"] = metrics_mod.snapshot(self._metrics_state)
        out["meter"] = {
            "observed": int(self.meter.observed.sum()),
            "writes": int(self.meter.writes.sum()),
            "reads": int(self.meter.reads.sum()),
            "deletes": int(self.meter.deletes.sum()),
            "migrations": int(self.meter.migrations.sum()),
            "relocations": int(self.meter.relocations.sum()),
            "occupancy_hwm": [int(x)
                              for x in self.meter.occupancy_hwm.sum(0)],
        }
        # the monitor's totals evaluate the write law at the actual
        # ingest chunking; without it fall back to the per-doc law
        wr = (self._residuals.write_z() if self._residuals is not None
              else res_mod.write_residuals(self.meter))
        occ = res_mod.occupancy_residuals(self.meter)
        out["residuals"] = {
            "writes": {
                "fleet_realized": float(wr["realized"].sum()),
                "fleet_expected": float(wr["expected"].sum()),
                "max_abs_z": float(np.abs(wr["z"]).max()) if self.m else 0.0,
                "mean_z": float(wr["z"].mean()) if self.m else 0.0,
            },
            "occupancy": {
                "fleet_realized": float(np.nansum(occ["realized"])),
                "fleet_expected": float(np.nansum(occ["expected"])),
                # all-NaN before any metered chunk (pure-throughput mode)
                "max_normalized": float(np.nanmax(np.abs(occ["normalized"])))
                if self.m and not np.isnan(occ["normalized"]).all() else 0.0,
            },
        }
        if self._residuals is not None:
            out["residuals"]["alerts"] = self._residuals.snapshot()
        if self._cost_states is not None:
            from repro.obs import costs as costs_mod
            out["costs"] = costs_mod.snapshot(self)
        out["resilience"] = {
            "chunks_ingested": int(self.chunks_ingested),
            "failed_tiers": sorted(self._failed_tiers),
            "recovering_tiers": sorted(self._recovering_tiers),
            "tier_outages": int(self._tier_outages),
        }
        if (self._checkpoint is not None
                and hasattr(self._checkpoint, "snapshot")):
            out["resilience"]["checkpoint"] = self._checkpoint.snapshot()
        return out

    def cost_summary(self) -> Dict:
        """Per-stream realized / planned / regret cost arrays from the
        device ledger + host monitor (``obs.costs.cost_summary``)."""
        if self._cost_states is None:
            raise ValueError("engine built without obs= (or costs off)")
        from repro.obs import costs as costs_mod
        return costs_mod.cost_summary(self)

    def cost_alerts(self) -> Dict[int, Dict]:
        """{stream_id: {"position", "kind"}} of the cost channel's first
        alert per stream — ``kind`` is "residual" or "burn" (whichever
        fired first; streams that never alerted are absent)."""
        if self._cost_monitor is None:
            raise ValueError("engine built without obs= (or costs off)")
        mon = self._cost_monitor
        out: Dict[int, Dict] = {}
        for row in range(self.m):
            res_at = int(mon.first_alert_seen[row])
            burn_at = int(mon.first_burn_seen[row])
            if res_at < 0 and burn_at < 0:
                continue
            if burn_at < 0 or (0 <= res_at <= burn_at):
                out[self._sid_of_row[row]] = {"position": res_at,
                                              "kind": "residual"}
            else:
                out[self._sid_of_row[row]] = {"position": burn_at,
                                              "kind": "burn"}
        return out

    def _record_final_reads(self) -> None:
        # logmem buckets keep no survivor ids on device — their final
        # top-K read is issued by the storage layer from the admitted
        # set, so the meter cannot attribute it per tier here
        for bi, b in enumerate(self.buckets):
            if b.engine == "logmem":
                continue
            self.meter.record_reads(self._global_rows[bi],
                                    np.asarray(self._states[bi].ids)[:b.m])

    def finalize(self) -> Dict[int, np.ndarray]:
        """End-of-window: meter the final top-K read per stream (tiered by
        each stream's r) and return the survivors. Logmem streams meter
        no reads (no ids on device) and return empty survivor sets."""
        if self._tracer is not None:
            with self._tracer.span("finalize"):
                self._record_final_reads()
                return self.survivors()
        self._record_final_reads()
        return self.survivors()

    def finalize_tiers(self, use_pallas: bool = True) -> Dict[int, Dict]:
        """Device-side finalize-time tier assignment: one 2-D
        ``kernels.tier_assign`` pass per bucket maps every survivor id
        against its stream's boundary vector (and cascade floor) to the
        tier its final read must hit, plus the per-tier survivor counts —
        the bucketed gather for issuing per-tier reads. Bit-matches the
        host meter's tier attribution (asserted in tests).

        Returns {stream_id: {"ids", "tiers", "counts"}}. Logmem streams
        are absent (no survivor ids to assign).
        """
        from repro.kernels import tier_assign as ta
        out: Dict[int, Dict] = {}
        for bi, b in enumerate(self.buckets):
            if b.engine == "logmem":
                continue
            rows = self._global_rows[bi]
            tier, counts = ta.tier_assign(
                self._states[bi].ids[:b.m], self.meter.boundaries[rows],
                self.meter.floor[rows], n_tiers=self.meter.n_tiers,
                use_pallas=use_pallas)
            tier = np.asarray(tier)
            counts = np.asarray(counts)
            ids = np.asarray(self._states[bi].ids)
            for j, sid in enumerate(b.stream_ids):
                out[sid] = {"ids": ids[j], "tiers": tier[j],
                            "counts": counts[j]}
        return out

    def check_constraints(self, constraints=None, latencies=None,
                          doc_gb=None) -> Dict:
        """Reconciliation-time violation report against the engine's (or
        an explicit) ``ConstraintSet``: metered occupancy high-water marks
        vs capacities, realized read latency vs the SLO (see
        ``FleetMeter.check_constraints``). Streams planned from cost
        models are checked against the ``effective_capacity`` merge, so
        topology-declared ``TierSpec.capacity_docs`` are enforced at
        reconciliation exactly as at planning time.

        The report's ``"violations"`` key is the structured per-stream
        list ({stream_id, row, tier, kind, measured, limit, margin});
        with ``obs=`` configured every entry is also emitted on the obs
        event log as a ``constraint_violation`` event."""
        from repro.core.constraints import effective_capacity
        cset = constraints if constraints is not None else self.constraints
        if cset is None:
            raise ValueError("no ConstraintSet given or configured")
        per_stream_caps = None
        if self._model_of_row:
            nt_meter = self.meter.n_tiers
            has_bytes = any(c.max_bytes is not None for c in cset.capacities)
            per_stream_caps = np.empty((self.m, nt_meter))
            sizes = (np.broadcast_to(np.asarray(doc_gb, np.float64),
                                     (self.m,))
                     if doc_gb is not None else None)
            for row in range(self.m):
                cm = self._model_of_row.get(row)
                if cm is not None:
                    nt = (cm.as_ntier()
                          if isinstance(cm, TwoTierCostModel) else cm)
                    cap = np.full(nt_meter, np.inf)
                    cap[:min(nt.t, nt_meter)] = \
                        effective_capacity(cset, nt)[:nt_meter]
                else:
                    if has_bytes and sizes is None:
                        raise ValueError(
                            "byte-denominated capacities need doc_gb for "
                            "streams without a cost model")
                    g = float(sizes[row]) if sizes is not None else 0.0
                    cap = cset.capacity_array(nt_meter, g)
                per_stream_caps[row] = cap
        report = self.meter.check_constraints(cset, latencies=latencies,
                                              doc_gb=doc_gb,
                                              per_stream_caps=per_stream_caps)
        for v in report["violations"]:
            if v["row"] is not None:
                v["stream_id"] = self._sid_of_row[v["row"]]
            if self._tracer is not None:
                self._tracer.emit("constraint_violation", **v)
        return report
