"""Logarithmic-memory reservoir backend: huge-K tenants in O(log K) state.

The exact engine keeps ``(K,)`` score/id rows per stream, which caps
tenants-per-device — at K = 64k a single tenant costs 512 KB of device
state. Following "Optimal k-Secretary with Logarithmic Memory"
(arXiv 2502.09834: 1−O(1/√k)-competitive selection with O(log k)
words), this backend replaces the reservoir with a phase-bucketed
acceptance-threshold tracker:

* Admission is a single threshold compare: a doc enters iff its score
  beats the stream's active threshold ``tau`` (the estimate of the
  running K-th largest score — the same "bar" the exact engine reads
  off ``scores[:, -1]``, but maintained without storing the top K).
* ``tau`` is re-estimated from each ingest chunk's *transient* order
  statistics: the r-th largest of a W-wide chunk at position t targets
  the K/t quantile when r = round(W·K/t). Chunk estimates are folded
  into a decayed accumulator (weights halve per chunk, so the estimate
  tracks the bar as t grows) and committed into a monotone floor at
  phase boundaries — phases are the doubling intervals
  p = ⌊log₂(t/K)⌋, which is what makes the persistent state
  O(log(n/K)): one committed threshold and one admit counter per
  phase, plus seven scalars.
* Before t reaches K every doc is admitted (the exact engine fills its
  reservoir too); the crossing chunk admits its top-B by score, with
  B the hypergeometric chunk-law mean — so admit *counts* stay on the
  closed-form write law E[writes] = Σ min(1, K/j) that the planner,
  drift detector and obs residuals already consume. Measured on
  uniform/normal/lognormal traces the realized competitive ratio is
  ≥ 1 − c/√K with c ≤ ~0.25 and admits within a few percent of the
  law (``trace_competitive_ratio`` quantifies both per trace).

The admission scan (threshold compare + admit mask + per-tile counts)
is the ``kernels.logmem_update`` Pallas kernel — a 2-D (stream, tile)
grid, one HBM pass; the O(M) scalar threshold epilogue (sort of the
chunk, gather of the r-th order statistic, phase commit) runs in jnp
inside the same jitted step.

Contract differences vs the exact backend (documented, test-asserted):

* No ids are stored, so re-observed doc ids are **not** deduped
  (streams are position-indexed; each id arrives once), ``survivors``
  returns an empty id set, and evictions are never reported — storage
  written by a logmem tenant stays until window end (≈ K·ln(n/K) docs
  instead of peaking near K: the device-memory/storage tradeoff).
* Admission follows the write law only up to a 1−O(1/√K) slack;
  ``law_slack(k)`` is the per-chunk fractional budget the drift
  detector and obs residual monitor fold into their thresholds so an
  undrifted logmem fleet stays quiet (null FPR ≤ alpha) while an 8×
  rate drift still fires.
* Chunks too narrow to resolve the K/t quantile (W·K < t/2) fall back
  to law-budgeted admission for that chunk instead of folding a noisy
  estimate; steady-state admission is *uncapped* threshold-compare, so
  drift stays visible in the admit counts.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import router

PAD_ID = router.PAD_ID

# persistent phase buckets: covers windows up to K·2^N_PHASES docs
N_PHASES = 24
# per-chunk EMA decay of the quantile accumulator: recent chunks aim at
# the current K/t quantile, old chunks at stale (larger) ones
DECAY = 0.5
# admit-count slack constant: |admits − law| ≤ LAW_SLACK_C/√K · law holds
# empirically across traces (prototype sweep: bias ≤ 3.3% at K=4096,
# ≤ 1% at K=65536); consumers add slack·expected to their thresholds
LAW_SLACK_C = 4.0


def law_slack(k, c: float = LAW_SLACK_C) -> float:
    """Fractional admit-count slack of the logmem backend at width K —
    the 1−O(1/√K) approximation budget folded into drift/residual
    thresholds (z-score denominators only grow, so null FPR ≤ alpha is
    preserved)."""
    return float(c) / math.sqrt(float(k))


class LogmemState(NamedTuple):
    """M logmem streams stacked on a leading axis — O(log K) per stream
    (7 scalars + 2 per-phase vectors) vs the exact backend's O(K)."""

    seen: jax.Array  # (M,) i32 — docs observed (padding excluded)
    admits: jax.Array  # (M,) i32 — total docs admitted (threshold writes)
    tau: jax.Array  # (M,) f32 — active acceptance threshold (-inf cold)
    tau_floor: jax.Array  # (M,) f32 — monotone floor from committed phases
    q_num: jax.Array  # (M,) f32 — decayed quantile accumulator (numerator)
    q_den: jax.Array  # (M,) f32 — decayed quantile accumulator (weight)
    phase: jax.Array  # (M,) i32 — current phase ⌊log₂(t/K)⌋ (-1 pre-warm)
    phase_tau: jax.Array  # (M, P) f32 — committed threshold per phase
    phase_admits: jax.Array  # (M, P) i32 — admits per phase bucket


def init(m: int, k: int | None = None, phases: int = N_PHASES) -> LogmemState:
    """Fresh state for M streams. ``k`` is accepted for signature parity
    with the exact ``engine.init`` but not stored — the reservoir width
    is a static of the bucket's update, not of the state."""
    del k
    return LogmemState(
        seen=jnp.zeros((m,), jnp.int32),
        admits=jnp.zeros((m,), jnp.int32),
        tau=jnp.full((m,), -jnp.inf, jnp.float32),
        tau_floor=jnp.full((m,), -jnp.inf, jnp.float32),
        q_num=jnp.zeros((m,), jnp.float32),
        q_den=jnp.zeros((m,), jnp.float32),
        phase=jnp.full((m,), -1, jnp.int32),
        phase_tau=jnp.full((m, phases), -jnp.inf, jnp.float32),
        phase_admits=jnp.zeros((m, phases), jnp.int32),
    )


def state_bytes_per_stream(state: LogmemState) -> float:
    """Device bytes per stream of this state (pytree leaves / M)."""
    m = state.seen.shape[0]
    return sum(np.prod(leaf.shape) * leaf.dtype.itemsize
               for leaf in state) / max(m, 1)


def exact_bytes_per_stream(k: int) -> float:
    """Device bytes per stream of the exact backend at width K
    (f32 scores + i32 ids + i32 seen)."""
    return 8.0 * k + 4.0


def update(state: LogmemState, batch_scores: jax.Array,
           batch_ids: jax.Array, k: int, *, block_n: int = 512,
           use_pallas: bool = True) -> Tuple[LogmemState, jax.Array]:
    """Advance M logmem streams by one chunk: scores/ids (M, W), padding
    = (-inf, -1). Returns (new_state, wrote (M, W) bool) — the same
    contract as the exact ``engine.update``, so the engine step, meter,
    drift detector and metrics consume it unchanged.

    The admission scan (compare vs tau, admit mask, per-tile admit/live
    counts) is one ``kernels.logmem_update`` pass; the threshold
    epilogue (chunk sort → r-th order statistic → decayed fold → phase
    commit) is O(M·W log W) jnp in the same jitted program. Live scores
    must be finite (the router guarantees it); pad rows/columns are
    inert.
    """
    from repro.kernels.logmem_update import ops as lm_ops
    m, w = batch_scores.shape
    rows = jnp.arange(m)
    scores = batch_scores.astype(jnp.float32)
    ids = batch_ids.astype(jnp.int32)
    kf = jnp.float32(k)

    mask, acounts, lcounts, _ = lm_ops.logmem_admit(
        scores, ids, state.tau, block_n=block_n, use_pallas=use_pallas)
    live = ids >= 0
    wl = lcounts.sum(axis=1)  # (M,) live docs this chunk
    wl_f = wl.astype(jnp.float32)
    t_after = state.seen + wl
    t_f = t_after.astype(jnp.float32)

    # one descending sort per row serves both the cold-start top-B
    # selection (ranks) and the quantile estimate (r-th largest)
    s_masked = jnp.where(live, scores, -jnp.inf)
    order = jnp.argsort(-s_masked, axis=1)
    sorted_desc = jnp.take_along_axis(s_masked, order, axis=1)
    ranks = jnp.zeros((m, w), jnp.int32).at[rows[:, None], order].set(
        jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None, :], (m, w)))

    admit_all = t_after <= k  # reservoir not yet full: everything enters
    cold = (~admit_all) & jnp.isneginf(state.tau)
    steady = (~admit_all) & ~cold
    # cold/unresolvable fallback: admit the chunk-law mean count (top-B
    # by score), keeping admit counts on the closed-form write law
    budget = jnp.clip(jnp.round(jnp.minimum(t_f, kf) * wl_f
                                / jnp.maximum(t_f, 1.0)),
                      0.0, wl_f).astype(jnp.int32)
    topb = live & (ranks < budget[:, None])
    wrote = jnp.where(admit_all[:, None], live,
                      jnp.where(cold[:, None], topb, mask > 0))

    # quantile estimate: the r-th largest of the chunk targets the K/t
    # quantile when r = round(W·K/t); chunks too narrow to resolve it
    # (r_raw < 1/2) contribute nothing — tau holds, admission for such
    # cold rows stays on the law budget above
    r_raw = wl_f * kf / jnp.maximum(t_f, 1.0)
    resolvable = (~admit_all) & (r_raw >= 0.5) & (wl > 0)
    r = jnp.clip(jnp.round(r_raw), 1.0, jnp.maximum(wl_f, 1.0)) \
        .astype(jnp.int32)
    est = jnp.take_along_axis(sorted_desc, (r - 1)[:, None], axis=1)[:, 0]

    # phase boundary: commit the finished phase's estimate into the
    # monotone floor (the running bar never decreases under i.u.d.
    # arrivals), restart the accumulator
    p = jnp.floor(jnp.log2(jnp.maximum(t_f / kf, 1.0))).astype(jnp.int32)
    boundary = steady & (p > state.phase)
    ratio_old = state.q_num / jnp.maximum(state.q_den, 1e-30)
    commit_ok = boundary & (state.q_den > 0)
    tau_floor = jnp.where(commit_ok,
                          jnp.maximum(state.tau_floor, ratio_old),
                          state.tau_floor)
    q_num = jnp.where(boundary, 0.0, state.q_num)
    q_den = jnp.where(boundary, 0.0, state.q_den)
    phase = jnp.where(boundary, p, state.phase)

    q_num = jnp.where(resolvable, DECAY * q_num + wl_f * est, q_num)
    q_den = jnp.where(resolvable, DECAY * q_den + wl_f, q_den)
    tau = jnp.where(q_den > 0,
                    jnp.maximum(tau_floor, q_num / jnp.maximum(q_den,
                                                               1e-30)),
                    tau_floor)

    # O(log K) diagnostics: the committed threshold of the finished
    # phase, and admits attributed to the (post-commit) current phase
    n_ph = state.phase_tau.shape[1]
    ph_idx = jnp.arange(n_ph, dtype=jnp.int32)[None, :]
    pt_hot = ph_idx == jnp.clip(state.phase, 0, n_ph - 1)[:, None]
    phase_tau = jnp.where(pt_hot & commit_ok[:, None],
                          ratio_old[:, None], state.phase_tau)
    chunk_admits = wrote.sum(axis=1, dtype=jnp.int32)
    pa_hot = ph_idx == jnp.clip(phase, 0, n_ph - 1)[:, None]
    phase_admits = state.phase_admits + \
        pa_hot.astype(jnp.int32) * chunk_admits[:, None]

    return LogmemState(seen=t_after, admits=state.admits + chunk_admits,
                       tau=tau, tau_floor=tau_floor, q_num=q_num,
                       q_den=q_den, phase=phase, phase_tau=phase_tau,
                       phase_admits=phase_admits), wrote


def thresholds(state: LogmemState) -> jax.Array:
    """(M,) active acceptance thresholds — the logmem analog of the
    exact backend's entry bar ``scores[:, -1]`` (-inf while unfull)."""
    return state.tau


def expected_admits(n, k: int) -> np.ndarray:
    """Closed-form E[total admits] after n docs — the same write law
    E[writes] = Σ_{j≤n} min(1, K/j) both backends are metered against
    (eq. 9/10; ``shp.expected_cum_writes_batched`` at batch=1)."""
    from repro.core import shp
    n = np.asarray(n, np.int64)
    out = shp.expected_cum_writes_batched(np.maximum(n, 1) - 1, int(k), 1)
    return np.where(n > 0, out, 0.0)


def trace_competitive_ratio(scores, k: int, chunk: int, *,
                            use_pallas: bool = False,
                            block_n: int = 512) -> Dict:
    """Simulator-trace harness: replay score traces through the jitted
    logmem update and quantify the realized gap vs the exact reservoir.

    ``scores``: (n,) or (M, n) float — one window per row. Returns per
    stream the realized competitive ratio (top-K mass retained by the
    admitted set over the trace's true top-K mass), the constant
    ``c = (1 − ratio)·√K`` of the 1 − c/√K guarantee, and the admit
    count against the closed-form write law. The final (possibly
    partial) chunk is padded with (-inf, -1), so the harness also
    exercises pad inertness.
    """
    arr = np.atleast_2d(np.asarray(scores, np.float32))
    m, n = arr.shape
    if n <= 0 or chunk <= 0:
        raise ValueError("need a non-empty trace and chunk > 0")
    step = jax.jit(lambda st, s, i: update(st, s, i, k,
                                           block_n=block_n,
                                           use_pallas=use_pallas))
    st = init(m)
    admitted = [[] for _ in range(m)]
    for start in range(0, n, chunk):
        sl = arr[:, start:start + chunk]
        wl = sl.shape[1]
        s = np.full((m, chunk), router.PAD_SCORE, np.float32)
        i = np.full((m, chunk), PAD_ID, np.int32)
        s[:, :wl] = sl
        i[:, :wl] = np.arange(start, start + wl, dtype=np.int32)[None, :]
        st, wrote = step(st, jnp.asarray(s), jnp.asarray(i))
        wr = np.asarray(wrote)
        for row in range(m):
            admitted[row].append(sl[row][wr[row, :wl]])
    admits = np.asarray(st.admits, np.int64)
    ratio = np.empty(m, np.float64)
    for row in range(m):
        got = np.concatenate(admitted[row]) if admitted[row] else \
            np.empty(0, np.float32)
        top_all = np.sort(arr[row].astype(np.float64))[-k:].sum()
        top_got = np.sort(got.astype(np.float64))[-min(k, got.size):].sum()
        ratio[row] = top_got / top_all if top_all else 1.0
    law = float(expected_admits(np.asarray([n]), k)[0])
    return {
        "k": k, "n": n, "chunk": chunk,
        "ratio": ratio,
        "c": (1.0 - ratio) * math.sqrt(k),
        "admits": admits,
        "expected_admits": law,
        "admit_ratio": admits / max(law, 1e-12),
        "min_ratio": float(ratio.min()),
        "max_c": float(((1.0 - ratio) * math.sqrt(k)).max()),
        "bytes_per_stream": state_bytes_per_stream(st),
        "exact_bytes_per_stream": exact_bytes_per_stream(k),
    }
