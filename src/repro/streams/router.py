"""Router: scatter a mixed multi-tenant batch of scored docs into dense
per-bucket arrays the batched engine can consume.

Streams are bucketed by K — every stream in a bucket shares one reservoir
width, so the bucket's state is a dense ``(M_bucket, K)`` array and one
vectorized sort-merge updates all of them. A mixed ingest batch
(stream_id, score, doc_id) triples in arbitrary order — is grouped by
bucket, then scattered into ``(M_bucket, W)`` matrices padded with
``(-inf, -1)``; each stream's row is ordered by doc id (= stream
position), which makes routing deterministic and guarantees the
id-increasing order the kernel-filtered engine path needs for its
tie-break to match the exact merge. ``W`` is rounded up to a power of two
to bound the number of distinct shapes the jitted engine step compiles
for.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

PAD_SCORE = -np.inf
PAD_ID = -1


@dataclass(frozen=True)
class Bucket:
    """All streams sharing one reservoir width K *and* one engine
    backend (``"exact"`` O(K) reservoir or ``"logmem"`` O(log K)
    threshold tracker — the per-bucket state pytrees differ, so mixed
    backends cannot share a bucket). ``stream_ids[row]`` maps the
    bucket-local row back to the global stream id."""

    k: int
    stream_ids: Tuple[int, ...]
    engine: str = "exact"

    @property
    def m(self) -> int:
        return len(self.stream_ids)


def bucket_streams(ks: Dict[int, int],
                   engines: Dict[int, str] | None = None) -> List[Bucket]:
    """Group streams (stream_id → K, optionally stream_id → engine) into
    per-(K, engine) buckets, ordered by (K, engine) ascending and rows
    ordered by stream id — deterministic layout."""
    by_key: Dict[Tuple[int, str], List[int]] = {}
    for sid, k in ks.items():
        eng = engines.get(sid, "exact") if engines else "exact"
        by_key.setdefault((int(k), str(eng)), []).append(int(sid))
    return [Bucket(k=k, stream_ids=tuple(sorted(by_key[(k, eng)])),
                   engine=eng)
            for k, eng in sorted(by_key)]


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def blank_dense(m: int, w: int) -> Tuple[np.ndarray, np.ndarray]:
    """(scores (m, w) f32, doc_ids (m, w) i32) of all-pad rows — the one
    inert filler every staging path shares: ``route`` scatters live docs
    into it, and the engine's shard padding appends whole blank rows.
    Every law (update, drift, metrics, meter) treats (PAD_SCORE, PAD_ID)
    entries as absent; tests assert the inertness through both engine
    backends."""
    return (np.full((m, w), PAD_SCORE, np.float32),
            np.full((m, w), PAD_ID, np.int32))


class StreamRouter:
    """Routes mixed batches to bucket-dense matrices (numpy, host-side)."""

    def __init__(self, buckets: Sequence[Bucket]):
        self.buckets = list(buckets)
        sids, bis, rows = [], [], []
        for bi, b in enumerate(self.buckets):
            for row, sid in enumerate(b.stream_ids):
                sids.append(sid)
                bis.append(bi)
                rows.append(row)
        order = np.argsort(sids)
        self._sids = np.asarray(sids, np.int64)[order]
        if np.any(np.diff(self._sids) == 0):
            raise ValueError("duplicate stream id across buckets")
        self._bi = np.asarray(bis, np.int64)[order]
        self._row = np.asarray(rows, np.int64)[order]

    def lookup(self, stream_ids) -> Tuple[np.ndarray, np.ndarray]:
        """stream_ids (S,) → (bucket_index (S,), bucket_row (S,))."""
        stream_ids = np.asarray(stream_ids, np.int64)
        pos = np.searchsorted(self._sids, stream_ids)
        ok = (pos < self._sids.shape[0]) & \
            (self._sids[np.minimum(pos, self._sids.shape[0] - 1)] == stream_ids)
        if not np.all(ok):
            bad = np.unique(stream_ids[~ok])
            raise KeyError(f"unregistered stream ids: {bad[:8].tolist()}")
        return self._bi[pos], self._row[pos]

    def route(self, stream_ids, scores, doc_ids, *, pad_to: int | None = None
              ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Scatter a mixed batch into one dense (scores, doc_ids) pair per
        bucket, aligned with ``self.buckets``.

        Returns ``[(scores (M_b, W_b) f32, doc_ids (M_b, W_b) i32), ...]``
        padded with ``(PAD_SCORE, PAD_ID)``. ``W_b`` = max docs routed to
        any stream of the bucket this batch, rounded up to a power of two
        (or ``pad_to`` if given and larger). Each row is sorted by doc id.
        """
        scores = np.asarray(scores, np.float32).reshape(-1)
        doc_ids = np.asarray(doc_ids, np.int32).reshape(-1)
        bi, row = self.lookup(stream_ids)
        out = []
        for b_idx, bucket in enumerate(self.buckets):
            sel = np.flatnonzero(bi == b_idx)
            rows = row[sel]
            # group by row, then stream order within each row
            order = np.lexsort((doc_ids[sel], rows))
            rs = rows[order]
            ds = doc_ids[sel][order]
            dup = (np.diff(rs) == 0) & (np.diff(ds) == 0)
            if np.any(dup):
                j = int(np.flatnonzero(dup)[0])
                raise ValueError(
                    f"duplicate (stream, doc) in one batch: stream "
                    f"{bucket.stream_ids[rs[j]]} doc {ds[j]} — a doc id may "
                    f"appear once per stream per ingest")
            if rs.size:
                starts = np.r_[0, np.flatnonzero(np.diff(rs)) + 1]
                counts = np.diff(np.r_[starts, rs.size])
                pos = np.arange(rs.size) - np.repeat(starts, counts)
                width = int(counts.max())
            else:
                pos = rs
                width = 0
            w = _next_pow2(max(width, 1))
            if pad_to is not None:
                w = max(w, int(pad_to))
            dense_s, dense_i = blank_dense(bucket.m, w)
            dense_s[rs, pos] = scores[sel][order]
            dense_i[rs, pos] = doc_ids[sel][order]
            out.append((dense_s, dense_i))
        return out
