"""TopKCurator — the paper's workflow embedded in training (DESIGN §2).

The jitted train step already merges per-example interestingness into the
device-side reservoir. The curator is the host-side consumer: it mirrors
the reservoir exactly (same tie-break), executes tier placement for the
retained payloads through a TieredStore, performs the bulk migration at
i = r (Fig. 3), and serves the end-of-window read — while reconciling its
transaction ledger against the analytic expectations.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core import shp
from repro.core.costs import NTierCostModel, TwoTierCostModel
from repro.core.placement import Policy, optimal_policy
from repro.core.tiers import TieredStore


@dataclass
class CurationStats:
    observed: int = 0
    writes: int = 0
    evictions: int = 0
    migrated: int = 0

    def as_dict(self):
        return self.__dict__.copy()


class TopKCurator:
    def __init__(self, k: int, store: TieredStore,
                 cost_model: Optional[TwoTierCostModel | NTierCostModel] = None,
                 policy: Optional[Policy] = None):
        if policy is None:
            if cost_model is None:
                raise ValueError("need cost_model or policy")
            policy = optimal_policy(cost_model)
        self.k = k
        self.store = store
        self.store.policy = policy
        self.policy = policy
        self.cost_model = cost_model
        self._heap: list[tuple[float, int]] = []  # (score, -id): weakest on top
        self.stats = CurationStats()

    @property
    def threshold(self) -> float:
        return self._heap[0][0] if len(self._heap) >= self.k else -np.inf

    def observe_batch(self, ids, scores, payloads) -> CurationStats:
        """ids (B,) int — scores (B,) float — payloads: id-indexable arrays."""
        ids = np.asarray(ids)
        scores = np.asarray(scores, np.float64)
        order = np.argsort(ids)  # stream order within the batch
        for j in order:
            doc = int(ids[j])
            self.stats.observed += 1
            self.store.maybe_migrate(doc)
            entry = (float(scores[j]), -doc)
            if len(self._heap) < self.k:
                accepted = True
            elif entry > self._heap[0]:
                _, neg = heapq.heappop(self._heap)
                self.store.evict(-neg)
                self.stats.evictions += 1
                accepted = True
            else:
                accepted = False
            if accepted:
                heapq.heappush(self._heap, entry)
                self.store.write(doc, payloads[j])
                self.stats.writes += 1
        self.stats.migrated = self.store.ledger.migrations
        return self.stats

    def survivor_ids(self) -> np.ndarray:
        return np.array(sorted(-neg for _, neg in self._heap), dtype=np.int64)

    def finalize(self) -> Dict[int, np.ndarray]:
        """End-of-window read of the top-K payloads (the consumer side)."""
        return self.store.read_all(self.survivor_ids())

    def expected_writes(self) -> float:
        """Analytic prediction for the observed stream position (eq. 11/12)."""
        n = max(self.stats.observed, 1)
        return float(shp.expected_cum_writes(n - 1, self.k))
