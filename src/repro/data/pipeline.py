"""Elastic, deterministic, sharded stream loader.

Every example has a global identity ``id = step·global_batch + slot``; a
worker materializes exactly its slice as a pure function of
(seed, step, dp_rank, dp_size). Properties (tested):

* determinism — same (seed, step) ⇒ same global batch, any worker set;
* elasticity  — changing dp_size re-partitions the SAME global stream
  (union over ranks is invariant), so scale-up/down needs no data replay;
* resumability — restart at step s reproduces the stream from s.

These are the fault-tolerance guarantees the train loop builds on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from .synthetic import make_batch


@dataclass
class ShardInfo:
    dp_rank: int = 0
    dp_size: int = 1


class StreamLoader:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 shard: Optional[ShardInfo] = None,
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.shard = shard or ShardInfo()
        self.global_batch = batch_override or shape.global_batch
        self.seq_len = seq_override or shape.seq_len
        if self.global_batch % self.shard.dp_size:
            raise ValueError("global_batch must divide dp_size")

    def example_ids(self, step: int) -> np.ndarray:
        per = self.global_batch // self.shard.dp_size
        base = step * self.global_batch + self.shard.dp_rank * per
        return np.arange(base, base + per, dtype=np.int64)

    def batch_for_step(self, step: int) -> dict:
        ids = self.example_ids(step)
        out = make_batch(self.cfg, self.shape, seed=self.seed, step=0,
                         indices=ids, seq_len=self.seq_len)
        out["example_ids"] = (ids % (2 ** 31 - 1)).astype(np.int32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_for_step(step)
            step += 1
