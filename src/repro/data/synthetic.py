"""Deterministic synthetic data: batches are a pure function of
(config, global_step, example-index), so any worker can materialize any
slice of the global stream — the property that makes the pipeline elastic
and fault-tolerant (DESIGN.md §5).

Token streams follow a Zipf-ish marginal with a Markov twist so the LM loss
is learnable (quickstart/e2e examples train against it).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _rng_for(seed: int, step: int, index: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step, index)))


def example_tokens(cfg: ModelConfig, seq_len: int, seed: int, step: int,
                   index: int) -> np.ndarray:
    """One example's tokens — pure function of its global identity."""
    rng = _rng_for(seed, step, index)
    v = cfg.vocab_size
    # Zipf marginal over a 256-symbol alphabet embedded in the vocab, with
    # a deterministic successor rule 2/3 of the time (learnable structure).
    base = rng.zipf(1.3, size=seq_len + 1).clip(max=256) - 1
    tok = base.astype(np.int64)
    follow = rng.random(seq_len + 1) < (2.0 / 3.0)
    for i in range(1, seq_len + 1):
        if follow[i]:
            tok[i] = (tok[i - 1] * 31 + 7) % 256
    return (tok % v).astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
               step: int = 0, indices=None, batch: int | None = None,
               seq_len: int | None = None) -> dict:
    """Materialize a batch dict for ``indices`` (global example ids)."""
    b = batch or shape.global_batch
    s = seq_len or shape.seq_len
    if indices is None:
        indices = np.arange(b) + step * b
    dec_len = cfg.decoder_len if cfg.is_encoder_decoder else s
    toks = np.stack([example_tokens(cfg, dec_len, seed, step, int(i))
                     for i in indices])
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if cfg.is_encoder_decoder:
        rng = _rng_for(seed, step, 2**31 - 1)
        out["frames"] = rng.standard_normal(
            (len(indices), s, cfg.d_model)).astype(np.float32)
    if cfg.frontend == "vision_patches":
        rng = _rng_for(seed, step, 2**31 - 2)
        out["patch_embeds"] = rng.standard_normal(
            (len(indices), cfg.n_patches, cfg.d_model)).astype(np.float32)
    return out
