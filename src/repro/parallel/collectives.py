"""Distributed-optimization collectives.

``compressed_psum`` — int8 error-feedback all-reduce for the cross-pod
gradient reduction: pods are connected by the slowest links, and gradients
tolerate aggressive quantization when the residual is fed back (Seide et
al.; 1-bit Adam lineage). Halving/quartering cross-pod bytes moves the
collective roofline term directly (§Perf hillclimb for collective-bound
cells).

Usage (inside shard_map over the 'pod' axis):
    g_avg, err = compressed_psum(g_local, 'pod', error=err)
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x, scale_floor: float = 1e-12):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, scale_floor)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 psum-mean over ``axis_name``.

    Returns (mean, new_error). new_error carries this round's quantization
    residual — add it to next round's input (error feedback keeps the
    long-run bias at zero, so convergence matches fp32 all-reduce).
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q, scale = quantize_int8(xf)
    deq = q.astype(jnp.float32) * scale
    new_error = xf - deq
    # int32 accumulation of int8 payloads; scales are tiny, psum'd in f32.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    sum_scale = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # per-shard scales differ: reconstruct with the mean scale (the error
    # term absorbs the mismatch on the next round)
    mean = total * (sum_scale / n) / n
    return mean.astype(x.dtype), new_error


def tree_compressed_psum(tree, axis_name: str, error_tree=None):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    err_leaves = (jax.tree_util.tree_flatten(error_tree)[0]
                  if error_tree is not None else [None] * len(leaves))
    outs, errs = [], []
    for leaf, err in zip(leaves, err_leaves):
        o, e = compressed_psum(leaf, axis_name, err)
        outs.append(o)
        errs.append(e)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, errs))
