"""Mesh context + activation sharding constraints.

Models stay mesh-agnostic: they call ``shard(x, BATCH, None, MODEL, ...)``
with logical axis markers; if no mesh is active (CPU tests) this is the
identity. Markers resolve to mesh axes only where the dimension divides the
axis size — so KV=8 heads on a 16-way model axis silently fall back to
replicated instead of failing to lower.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH = "@batch"   # data-parallel axes: ('pod','data') when present
MODEL = "@model"   # tensor-parallel axis
SEQ = "@seq"       # sequence-parallel: ('data','model') — long-context B=1
_STATE = threading.local()


def set_mesh(mesh: Optional[Mesh]):
    _STATE.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


class use_mesh:
    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size() -> int:
    """Size of the tensor-parallel axis of the active mesh (1 if none)."""
    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def resolve(mesh: Mesh, marker, dim_size: int):
    """Marker → concrete mesh axes (or None if indivisible/absent)."""
    if marker is None:
        return None
    if marker == BATCH:
        axes = dp_axes(mesh)
    elif marker == MODEL:
        axes = ("model",) if "model" in mesh.axis_names else ()
    elif marker == SEQ:
        axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    else:  # explicit axis name(s)
        axes = (marker,) if isinstance(marker, str) else tuple(marker)
        axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    if dim_size % axis_size(mesh, axes) != 0:
        # try a shrinking prefix (e.g. B=16 on pod×data=32 → data only)
        for cut in range(len(axes) - 1, 0, -1):
            if dim_size % axis_size(mesh, axes[:cut]) == 0:
                return axes[:cut] if len(axes[:cut]) > 1 else axes[0]
        return None
    return axes if len(axes) > 1 else axes[0]


def spec(mesh: Mesh, markers, shape) -> P:
    entries = []
    used: set = set()
    for marker, dim in zip(markers, shape):
        r = resolve(mesh, marker, dim)
        # an axis may appear only once in a PartitionSpec
        raxes = (r,) if isinstance(r, str) else (r or ())
        if r is not None and not (set(raxes) & used):
            used.update(raxes)
            entries.append(r)
        else:
            entries.append(None)
    return P(*entries)


def shard(x, *markers):
    """with_sharding_constraint under the active mesh (identity otherwise)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    assert len(markers) == x.ndim, (markers, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(mesh, markers, x.shape)))


def named(mesh: Mesh, markers, shape) -> NamedSharding:
    return NamedSharding(mesh, spec(mesh, markers, shape))
