from . import ctx, fleet, sharding  # noqa: F401
