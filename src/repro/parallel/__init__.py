from . import ctx, sharding  # noqa: F401
