"""Fleet-axis sharding: the M (stream) dimension laid out across devices.

The paper's tiering laws are per-stream, so every hot-path array in the
repo — reservoir state, drift-detector statistics, planner inputs — is
embarrassingly parallel along its leading M axis. This module owns the
one mesh axis that exploits that: a 1-D ``Mesh`` over the local devices
(``FLEET_AXIS``), ``NamedSharding`` helpers that split leading-axis rows
across it, a thread-local *active fleet mesh* (mirroring ``ctx``'s model
mesh so the planner entry points can pick the sharded dispatch up
ambiently), and the one genuinely cross-shard computation the stack
needs: fleet-shared capacity water-filling, whose water level λ couples
every stream and is found here by a ``psum`` bisection inside
``shard_map`` instead of a single-host sort.

Everything else stays collective-free: a ``shard_map`` of the engine
step / planner solve runs the exact single-device program on each
shard's rows, so sharded outputs are bit-identical to the single-device
run (tests assert this at every fleet size, divisible by the shard
count or not — padding rows are inert by construction).

On CPU-only boxes a multi-device mesh must be *forced* before jax
import: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # newer jax exports shard_map at the top level
    from jax import shard_map  # type: ignore
except ImportError:  # pragma: no cover - version-dependent import path
    from jax.experimental.shard_map import shard_map  # type: ignore

FLEET_AXIS = "fleet"
_STATE = threading.local()


# ---------------------------------------------------------------------------
# Mesh construction + the thread-local active fleet mesh
# ---------------------------------------------------------------------------

def fleet_mesh(devices: Optional[int] = None) -> Optional[Mesh]:
    """A 1-D ``(FLEET_AXIS,)`` mesh over ``devices`` local devices (all of
    them when None). Returns ``None`` when fewer than 2 devices are
    available (or requested) — the callers then keep their single-device
    fallback paths (host thread fan-out, plain jit)."""
    avail = jax.local_device_count()
    d = avail if devices is None else int(devices)
    if d > avail:
        raise ValueError(
            f"fleet mesh needs {d} devices, only {avail} available — on "
            "CPU force them with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=<d> before "
            "jax import")
    if d < 2:
        return None
    return jax.make_mesh((d,), (FLEET_AXIS,))


def n_shards(mesh: Optional[Mesh]) -> int:
    """Fleet-axis size of ``mesh`` (1 for None)."""
    if mesh is None:
        return 1
    return int(mesh.shape[FLEET_AXIS])


def set_fleet_mesh(mesh: Optional[Mesh]) -> None:
    _STATE.mesh = mesh


def get_fleet_mesh() -> Optional[Mesh]:
    """The thread-local active fleet mesh (None = single-device paths).
    ``core.shp_jax`` and ``online.replan_device`` consult this to pick
    the per-shard dispatch without any signature plumbing."""
    return getattr(_STATE, "mesh", None)


class use_fleet_mesh:
    """``with use_fleet_mesh(mesh): ...`` — scoped active fleet mesh."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_fleet_mesh()
        set_fleet_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_fleet_mesh(self.prev)


# ---------------------------------------------------------------------------
# Row (leading-M-axis) sharding helpers
# ---------------------------------------------------------------------------

def row_spec() -> P:
    """Partition spec splitting the leading axis across the fleet (all
    trailing axes replicated) — valid for any rank."""
    return P(FLEET_AXIS)


def row_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, row_spec())


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def pad_rows(m: int, shards: int) -> int:
    """Smallest multiple of ``shards`` >= m (>= shards, so every shard
    owns at least one row)."""
    return max(-(-int(m) // shards), 1) * shards


def shard_rows(mesh: Optional[Mesh], tree):
    """``device_put`` every array leaf of ``tree`` with its leading axis
    split across the fleet (identity without a mesh). Leading dims must
    be multiples of the shard count — pad with inert rows first."""
    if mesh is None:
        return tree
    sh = row_sharding(mesh)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)


# ---------------------------------------------------------------------------
# Cross-shard fleet-shared capacity water-filling
# ---------------------------------------------------------------------------

_WF_ITERS = 96  # f64 bisection: hi/2^96 is far below one ulp of λ


def _waterfill_local(d, budget):
    """Per-shard body: bisection on the scalar water level λ with the
    grant sum reduced across the fleet by ``psum`` each step. The loop
    keeps the invariant Σ min(d, lo) <= budget, so returning
    ``min(d, lo)`` can never oversubscribe the budget (up to the psum's
    own fp summation, ~1 ulp — the property test's tolerance)."""
    total = jax.lax.psum(d.sum(), FLEET_AXIS)
    hi0 = jax.lax.pmax(jnp.max(d, initial=jnp.zeros((), d.dtype)),
                       FLEET_AXIS)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        s = jax.lax.psum(jnp.minimum(d, mid).sum(), FLEET_AXIS)
        ok = s <= budget
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, _ = jax.lax.fori_loop(0, _WF_ITERS, body,
                              (jnp.zeros_like(hi0), hi0))
    grants = jnp.minimum(d, jnp.maximum(lo, 0.0))
    return jnp.where(total <= budget, d, grants)


_WF_CACHE: dict = {}


def _waterfill_fn(mesh: Mesh):
    fn = _WF_CACHE.get(mesh)
    if fn is None:
        fn = _WF_CACHE[mesh] = jax.jit(shard_map(
            _waterfill_local, mesh=mesh,
            in_specs=(row_spec(), P()), out_specs=row_spec(),
            check_rep=False))
    return fn


def waterfill_sharded(desired, budget: float, mesh: Mesh) -> np.ndarray:
    """Device-resident ``streams.planner.waterfill`` for a sharded fleet:
    each stream's desired occupancy stays on its own shard and the common
    water level λ (Σ min(desired, λ) = budget) is found by a 96-step f64
    bisection whose grant sums cross the mesh via ``psum`` — the
    single-host sort/prefix-scan view of the fleet never materializes.

    Returns the (M,) grants, matching the exact host λ to well below one
    ulp (bisecting from below guarantees the fleet never oversubscribes
    ``budget``; when the desires already fit they are granted verbatim).
    """
    from jax.experimental import enable_x64
    d = np.asarray(desired, np.float64).reshape(-1)
    m = d.shape[0]
    shards = n_shards(mesh)
    mp = pad_rows(m, shards)
    dp = np.zeros(mp, np.float64)
    dp[:m] = d  # zero-desire pad rows draw no grant at any λ
    with enable_x64():
        out = _waterfill_fn(mesh)(
            jax.device_put(dp, row_sharding(mesh)),
            jax.device_put(jnp.asarray(float(budget), jnp.float64),
                           replicated(mesh)))
        res = np.asarray(out, np.float64)
    return res[:m]
