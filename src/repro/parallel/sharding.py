"""Parameter / optimizer / cache / batch sharding rules.

Policy (DESIGN.md §5): tensor-parallel (TP) over ``model`` on the feature
axis (attention heads, FFN hidden, experts, vocab); FSDP over ``data`` on
the other large axis — params *and* fp32 AdamW moments are fully
distributed, which is what lets 236B/314B-param archs fit 16 GB/chip.
Activations: batch over ``(pod, data)``; caches follow KV-head TP when the
head count divides, else sequence-sharding.

Rules are (leaf-name → logical markers); markers resolve against the mesh
with divisibility fallback (ctx.resolve), so one rule table serves every
arch × mesh combination.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import ctx
from .ctx import BATCH, MODEL

FSDP = "data"  # parameter-sharding axis
TP = "model"

# leaf name → markers for the *unstacked* param shape (layer-stack dim is
# prepended automatically for grouped params).
_PARAM_RULES: dict[str, tuple] = {
    "embed": (TP, FSDP),
    "lm_head": (FSDP, TP),
    "pos_embed": (None, None),
    # attention
    "wq": (FSDP, TP, None),
    "wk": (FSDP, TP, None),
    "wv": (FSDP, TP, None),
    "wo": (TP, None, FSDP),
    "bq": (TP, None),
    "bk": (TP, None),
    "bv": (TP, None),
    "bo": (None,),
    # MLA
    "wq_a": (FSDP, TP),
    "q_norm": (None,),
    "wq_b": (FSDP, TP, None),
    "wkv_a": (FSDP, None),
    "kv_norm": (None,),
    "wkv_b": (FSDP, TP, None),
    # dense ffn (2D) / moe experts (3D) share names — see _spec_for
    "w_up": (FSDP, TP),
    "w_gate": (FSDP, TP),
    "w_down": (TP, FSDP),
    "b_up": (TP,),
    "b_down": (None,),
    "router": (FSDP, None),
    # ssm
    "w_in": (FSDP, TP),
    "conv_w": (None, None),
    "conv_b": (None,),
    "a_log": (None,),
    "dt_bias": (None,),
    "d_skip": (None,),
    "out_norm": (None,),
    "w_out": (TP, FSDP),
    # norms
    "scale": (None,),
    "bias": (None,),
}

_EXPERT_RULES = {  # 3D (E, D, F) / (E, F, D) variants
    "w_up": (TP, FSDP, None),
    "w_gate": (TP, FSDP, None),
    "w_down": (TP, None, FSDP),
}
_EXPERT_FALLBACK = {  # E doesn't divide 'model' → TP over the hidden dim
    "w_up": (None, FSDP, TP),
    "w_gate": (None, FSDP, TP),
    "w_down": (None, TP, FSDP),
}


def _leaf_name(path) -> str:
    for e in reversed(path):
        if hasattr(e, "key"):
            return str(e.key)
        if hasattr(e, "name"):
            return str(e.name)
    return ""


def _is_stacked(path) -> bool:
    head = path[0]
    return getattr(head, "key", None) in ("dec", "enc")


def _spec_for(mesh: Mesh, path, leaf, fsdp: bool = True) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    stacked = _is_stacked(path)
    core = shape[1:] if stacked else shape
    if name in ("w_up", "w_gate", "w_down") and len(core) == 3:
        tp_size = mesh.shape.get("model", 1)
        rules = _EXPERT_RULES if core[0] % tp_size == 0 else _EXPERT_FALLBACK
        markers = rules[name]
    elif name in _PARAM_RULES:
        markers = _PARAM_RULES[name]
        if len(markers) != len(core):  # e.g. scale under vmap oddities
            markers = (None,) * len(core)
    else:
        markers = (None,) * len(core)
    if not fsdp:
        # decode mode: FSDP weight-gathers cost a full parameter all-gather
        # per generated token (nothing amortizes them) — weights stay
        # TP/EP-sharded only (§Perf iteration: starcoder2 decode_32k)
        markers = tuple(None if m == FSDP else m for m in markers)
    if stacked:
        markers = (None,) + tuple(markers)
    return ctx.spec(mesh, markers, shape)


def param_shardings(mesh: Mesh, params_tree, fsdp: bool = True) -> Any:
    """NamedSharding pytree matching ``params_tree`` (concrete or abstract)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _spec_for(mesh, path, leaf,
                                                         fsdp=fsdp)),
        params_tree)


def opt_shardings(mesh: Mesh, opt_tree) -> Any:
    """AdamW moments mirror their parameter's sharding; step is replicated."""

    def f(path, leaf):
        # paths look like .m.<param path> / .v.<param path> / .step
        if _leaf_name(path) == "step" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        sub = path[1:]  # drop the m/v level
        return NamedSharding(mesh, _spec_for(mesh, sub, leaf))

    return jax.tree_util.tree_map_with_path(f, opt_tree)


# ---------------------------------------------------------------------------
# Batch & cache shardings
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_tree) -> Any:
    def f(leaf):
        markers = (BATCH,) + (None,) * (leaf.ndim - 1)
        return ctx.named(mesh, markers, leaf.shape)
    return jax.tree.map(f, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree) -> Any:
    """Decode caches. Layout (stack, B, W, heads?, dim?) — prefer B over the
    dp axes and heads over `model`; fall back to sharding the sequence (W)
    over whatever remains (long-context B=1 shards W over data×model)."""
    tp = mesh.shape.get("model", 1)
    dp = ctx.axis_size(mesh, ctx.dp_axes(mesh))

    def f(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if leaf.ndim == 0 or name == "pos" and leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "cross_k", "cross_v"):  # (L,B,W,KV,hd)
            kv = shape[3]
            if kv % tp == 0:
                markers = (None, BATCH, None, MODEL, None)
            else:
                markers = (None, BATCH, MODEL, None, None)
            if shape[1] < dp:  # B too small — shard the sequence harder
                markers = (None, None, ctx.SEQ, None, None)
            return ctx.named(mesh, markers, shape)
        if name == "ckv" or name == "krope":  # (L,B,W,R)
            markers = (None, BATCH, MODEL, None)
            if shape[1] < dp:
                markers = (None, None, ctx.SEQ, None)
            return ctx.named(mesh, markers, shape)
        if name == "pos":  # (L,B,W)
            return ctx.named(mesh, (None, BATCH, None), shape)
        if name == "state":  # (L,B,H,hd,N)
            return ctx.named(mesh, (None, BATCH, MODEL, None, None), shape)
        if name == "conv":  # (L,B,K-1,C)
            return ctx.named(mesh, (None, BATCH, None, MODEL), shape)
        markers = (None, BATCH) + (None,) * (leaf.ndim - 2)
        return ctx.named(mesh, markers[: leaf.ndim], shape)

    return jax.tree_util.tree_map_with_path(f, cache_tree)


def replicated(mesh: Mesh, tree) -> Any:
    return jax.tree.map(lambda l: NamedSharding(mesh, P()), tree)
