"""End-to-end evaluation harness for online re-planning: drive the fleet
engine over (possibly drifted) traces, extract the applied boundary
deltas as simulator schedules, and compare realized costs against the
static a-priori plan and a drift-aware ground-truth oracle.

Two ground-truth oracles, both applied at the (known) drift onset:

* ``process_oracle`` — knows the drift *process* (onset + multiplier
  schedule) but not the realization: each candidate suffix boundary
  vector is scored on independent probe traces drawn from the same
  drifted distribution, the winner is then applied to the actual trace.
  This is the fair "drift-aware oracle plan" — a plan cannot know the
  future noise — and the acceptance bar ("re-planned within 10%").
* ``hindsight_oracle`` — additionally knows the realization (sweeps the
  very trace being scored): an unbeatable per-trace lower bound, useful
  for calibration.

The re-planner only sees the detector's evidence, so tracking the
process oracle means the closed loop recovers most of what perfect drift
knowledge would."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import simulator
from repro.core.placement import Policy
from repro.obs import timers
from repro.streams.engine import StreamEngine, StreamSpec


def run_fleet(traces: np.ndarray, specs: Sequence[StreamSpec], *,
              replan=None, chunk: int = 64, constraints=None,
              rng: Optional[np.random.Generator] = None,
              obs=None) -> StreamEngine:
    """Feed per-stream traces (M, N) through a fresh ``StreamEngine`` in
    width-``chunk`` steps (batches shuffled across tenants when ``rng`` is
    given) and finalize. Returns the engine (events, meter, survivors).
    ``obs`` (a ``repro.obs.Observability``) threads the telemetry layer
    through the engine — device metric counters, residual alert channel,
    span timeline."""
    m, n = traces.shape
    engine = StreamEngine(specs, replan=replan, constraints=constraints,
                          obs=obs)
    sids = np.array([s.stream_id for s in specs])
    tracer = obs.tracer if obs is not None else None
    with timers.span("online.run_fleet", tracer=tracer, m=m, n=n,
                     chunk=chunk):
        for t0 in range(0, n, chunk):
            w = min(chunk, n - t0)
            mixed_sids = np.repeat(sids, w)
            mixed_dids = np.tile(np.arange(t0, t0 + w), m)
            mixed_scores = traces[:, t0:t0 + w].reshape(-1)
            if rng is not None:
                perm = rng.permutation(mixed_sids.size)
                mixed_sids, mixed_dids, mixed_scores = (
                    mixed_sids[perm], mixed_dids[perm], mixed_scores[perm])
            engine.ingest(mixed_sids, mixed_scores, mixed_dids)
        engine.finalize()
    return engine


def schedules_from_events(engine: StreamEngine) -> Dict[int, List[Tuple]]:
    """{stream_id: [(position, new_bounds), ...]} of the applied deltas."""
    out: Dict[int, List[Tuple]] = {}
    for ev in engine.replan_events:
        if ev.applied:
            out.setdefault(ev.stream_id, []).append(
                (ev.position, ev.new_bounds))
    return out


def realized(trace, k: int, cm, bounds, migrate: bool = False,
             schedule=None) -> simulator.SimResult:
    """Replay one stream through ``core.simulator`` under a (possibly
    re-scheduled) boundary placement, with metered rental."""
    pol = Policy(boundaries=tuple(float(b) for b in bounds),
                 migrate_at_r=migrate)
    return simulator.simulate(np.asarray(trace, np.float64), k, pol,
                              cost_model=cm, boundary_schedule=schedule)


def _oracle_candidates(n: int, k: int, base_bounds, grid: int):
    vals = np.unique(np.concatenate([
        [0.0, float(n)], np.asarray(base_bounds, np.float64),
        np.geomspace(max(k, 1.0), n, grid)]))
    b = len(base_bounds)
    return [tuple(float(x) for x in combo)
            for combo in itertools.combinations_with_replacement(vals, b)]


def hindsight_oracle(trace, k: int, cm, base_bounds, drift_at: int, *,
                     grid: int = 16) -> Tuple[float, Tuple[float, ...]]:
    """Per-trace lower bound: sweep suffix boundary vectors applied at
    the (known) drift onset on the very trace being scored and keep the
    cheapest realized cost — including the do-nothing option, so it never
    loses to the static plan. Exponential in the boundary count; keep
    ``grid`` small beyond two tiers."""
    best = realized(trace, k, cm, base_bounds).cost_total
    best_bounds = tuple(float(x) for x in base_bounds)
    for combo in _oracle_candidates(trace.shape[0], k, base_bounds, grid):
        cost = realized(trace, k, cm, base_bounds,
                        schedule=[(drift_at, combo)]).cost_total
        if cost < best:
            best, best_bounds = cost, tuple(float(x) for x in combo)
    return best, best_bounds


def process_oracle(trace, k: int, cm, base_bounds, drift_at: int,
                   multipliers, rng: np.random.Generator, *,
                   grid: int = 16, probes: int = 3
                   ) -> Tuple[float, Tuple[float, ...]]:
    """The drift-aware oracle *plan*: knows the drift process (onset +
    multiplier schedule) but not the realization. Candidates (including
    do-nothing) are scored by mean realized cost over ``probes``
    independent traces drawn from the same drifted distribution; the
    winning boundary vector is then applied to the actual trace. Returns
    (realized cost on ``trace``, chosen bounds)."""
    n = trace.shape[0]
    probe_traces = [simulator.drifted_rank_trace(n, rng, multipliers)
                    for _ in range(probes)]
    cands = [tuple(float(x) for x in base_bounds)]
    cands += _oracle_candidates(n, k, base_bounds, grid)
    best_mean, best_bounds = np.inf, cands[0]
    for combo in cands:
        sched = (None if combo == tuple(base_bounds)
                 else [(drift_at, combo)])
        mean = np.mean([realized(t, k, cm, base_bounds,
                                 schedule=sched).cost_total
                        for t in probe_traces])
        if mean < best_mean:
            best_mean, best_bounds = mean, combo
    sched = (None if best_bounds == tuple(base_bounds)
             else [(drift_at, best_bounds)])
    return realized(trace, k, cm, base_bounds,
                    schedule=sched).cost_total, best_bounds


@dataclass
class FleetEvaluation:
    """Per-stream realized costs of the three placements."""

    static_cost: np.ndarray  # (M,)
    replanned_cost: np.ndarray  # (M,)
    oracle_cost: np.ndarray  # (M,) NaN when the oracle sweep was skipped
    schedules: Dict[int, List[Tuple]]
    engine: StreamEngine
    timings: Dict[str, float] = field(default_factory=dict)  # phase seconds

    @property
    def fleet_static(self) -> float:
        return float(self.static_cost.sum())

    @property
    def fleet_replanned(self) -> float:
        return float(self.replanned_cost.sum())

    @property
    def fleet_oracle(self) -> float:
        return float(np.nansum(self.oracle_cost))


def evaluate_fleet(traces: np.ndarray, specs: Sequence[StreamSpec], *,
                   replan, drift_at: Optional[int] = None, chunk: int = 64,
                   constraints=None, oracle_grid: int = 16,
                   drift_schedule=None, oracle_probes: int = 3,
                   rng: Optional[np.random.Generator] = None,
                   obs=None) -> FleetEvaluation:
    """Run the closed loop over the fleet, then score static vs replanned
    realized costs per stream. With ``drift_at`` the oracle column is
    filled too: the process oracle when ``drift_schedule`` (the true
    multiplier schedule) is given, else the per-trace hindsight bound.
    ``specs`` must carry cost models. ``obs`` threads the telemetry
    layer through the run; the phase wall times land in
    ``FleetEvaluation.timings`` (and, with ``obs``, on the span
    timeline)."""
    tracer = obs.tracer if obs is not None else None
    with timers.span("online.evaluate.engine", tracer=tracer) as sp_run:
        engine = run_fleet(traces, specs, replan=replan, chunk=chunk,
                           constraints=constraints, rng=rng, obs=obs)
    m = traces.shape[0]
    schedules = schedules_from_events(engine)
    static_cost = np.zeros(m)
    replanned_cost = np.zeros(m)
    oracle_cost = np.full(m, np.nan)
    with timers.span("online.evaluate.score", tracer=tracer) as sp_score:
        for i, spec in enumerate(specs):
            row = engine.stream_row(spec.stream_id)
            base = tuple(b for b in engine.meter.boundaries[row]
                         if np.isfinite(b))
            # the meter's row holds the *current* (possibly re-planned)
            # boundaries; the a-priori vector is the first event's old
            # bounds
            for ev in engine.replan_events:
                if ev.stream_id == spec.stream_id:
                    base = ev.old_bounds
                    break
            mig = bool(engine.meter.migrate[row])
            static_cost[i] = realized(traces[i], spec.k, spec.cost_model,
                                      base, mig).cost_total
            sched = schedules.get(spec.stream_id)
            replanned_cost[i] = realized(traces[i], spec.k,
                                         spec.cost_model, base, mig,
                                         schedule=sched).cost_total
            if drift_at is not None and not mig:
                if drift_schedule is not None:
                    oracle_cost[i], _ = process_oracle(
                        traces[i], spec.k, spec.cost_model, base, drift_at,
                        drift_schedule,
                        (rng if rng is not None
                         else np.random.default_rng(i)),
                        grid=oracle_grid, probes=oracle_probes)
                else:
                    oracle_cost[i], _ = hindsight_oracle(
                        traces[i], spec.k, spec.cost_model, base, drift_at,
                        grid=oracle_grid)
    return FleetEvaluation(static_cost=static_cost,
                           replanned_cost=replanned_cost,
                           oracle_cost=oracle_cost, schedules=schedules,
                           engine=engine,
                           timings={"engine_s": sp_run.dur_s,
                                    "score_s": sp_score.dur_s})


def regret_table(engine: StreamEngine, traces=None, *,
                 drift_at: Optional[int] = None,
                 grid: int = 8) -> List[Dict]:
    """Per-tenant regret rows from a live engine's cost attribution
    (requires ``ObsConfig(costs=True)``): realized spend from the device
    ledger, the planner's closed-form expected spend, their difference
    (regret vs plan), and — when ``traces`` and ``drift_at`` are given —
    regret vs the per-trace hindsight oracle (``hindsight_oracle``), the
    strongest baseline the paper admits. Cascade streams skip the oracle
    column (the oracle sweeps static re-plans)."""
    summ = engine.cost_summary()
    rows: List[Dict] = []
    for row in range(engine.m):
        sid = engine._sid_of_row[row]
        entry = {"stream_id": sid, "row": row,
                 "realized": float(summ["total"][row]),
                 "planned": float(summ["planned"][row]),
                 "regret": float(summ["regret"][row]),
                 "oracle": float("nan"), "oracle_regret": float("nan")}
        cm = engine._model_of_row.get(row)
        if (traces is not None and drift_at is not None and cm is not None
                and not engine.meter.migrate[row]):
            base = tuple(b for b in engine.meter.boundaries[row]
                         if np.isfinite(b))
            for ev in engine.replan_events:
                if ev.stream_id == sid:
                    base = ev.old_bounds
                    break
            oc, _ = hindsight_oracle(np.asarray(traces[row]),
                                     int(engine.meter.ks[row]), cm, base,
                                     drift_at, grid=grid)
            entry["oracle"] = float(oc)
            entry["oracle_regret"] = entry["realized"] - float(oc)
        rows.append(entry)
    return rows


def format_regret_table(rows: Sequence[Dict]) -> str:
    """Fixed-width text rendering of ``regret_table`` rows (the README /
    example excerpt)."""
    header = (f"{'stream':>6} {'realized':>12} {'planned':>12} "
              f"{'regret':>12} {'vs oracle':>12}")
    lines = [header, "-" * len(header)]
    for r in rows:
        vs = ("-" if np.isnan(r["oracle_regret"])
              else f"{r['oracle_regret']:>12.4e}")
        lines.append(f"{r['stream_id']:>6} {r['realized']:>12.4e} "
                     f"{r['planned']:>12.4e} {r['regret']:>12.4e} {vs:>12}")
    tot_real = sum(r["realized"] for r in rows)
    tot_plan = sum(r["planned"] for r in rows)
    lines.append(f"{'fleet':>6} {tot_real:>12.4e} {tot_plan:>12.4e} "
                 f"{tot_real - tot_plan:>12.4e} {'':>12}")
    return "\n".join(lines)
