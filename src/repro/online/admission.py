"""Admission control: negotiate K or the window length instead of
rejecting a tenant whose constrained plan is infeasible.

The constrained planner (``shp.plan_placement_ntier``) returns
``total = +inf`` when no boundary vector satisfies the tenant's
``ConstraintSet`` — e.g. a hot-tier capacity below K with an SLO that
forbids the cold tier. The paper's stack so far *rejects* such tenants
(``StreamEngine`` raises). ``AdmissionController`` negotiates instead,
exploiting that the feasible set only grows as K shrinks (the occupancy
law ``min(b,K)(1−b_prev/b)`` is non-decreasing in K and the latency law
is K-free): binary-search the largest feasible K' < K, and only if even
``k_floor`` fails, walk the window length N down a geometric grid
(shorter windows change the write/read balance and can re-open the SLO
frontier). The tenant gets back concrete admitted terms plus the
feasible plan, rather than a refusal.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core import shp
from repro.core.constraints import ConstraintSet
from repro.core.costs import NTierCostModel, TwoTierCostModel


@dataclass(frozen=True)
class AdmissionDecision:
    """Admitted terms for one tenant (possibly negotiated down)."""

    admitted: bool
    negotiated: bool
    k: int
    n_docs: int
    original_k: int
    original_n: int
    plan: Optional[shp.NTierPlacementPlan]
    reason: str

    @property
    def boundaries(self):
        return None if self.plan is None else self.plan.boundaries


def _with_terms(cm: NTierCostModel, k: int, n: int) -> NTierCostModel:
    wl = dataclasses.replace(cm.workload, k=k, n_docs=n)
    return cm.replace(workload=wl)


class AdmissionController:
    """Negotiates admission terms against one ``ConstraintSet``.

    ``k_floor``: smallest reservoir width worth serving; ``n_floor_frac``:
    smallest acceptable window as a fraction of the requested one;
    ``n_steps``: geometric window-shrink grid resolution.
    """

    def __init__(self, constraints: Optional[ConstraintSet] = None, *,
                 k_floor: int = 1, n_floor_frac: float = 0.125,
                 n_steps: int = 6):
        self.constraints = (constraints if constraints is not None
                            else ConstraintSet())
        if k_floor < 1:
            raise ValueError("k_floor must be >= 1")
        self.k_floor = int(k_floor)
        self.n_floor_frac = float(n_floor_frac)
        self.n_steps = int(n_steps)

    def _plan(self, cm: NTierCostModel):
        plan = shp.plan_placement_ntier(cm, constraints=self.constraints)
        return plan if plan.feasible else None

    def _largest_feasible_k(self, cm: NTierCostModel, n: int):
        """Binary-search the largest K' in [k_floor, K] with a feasible
        plan at window n (feasibility is monotone non-increasing in K),
        reusing the plan from the winning probe."""
        k0 = cm.workload.k
        hi = min(k0, n - 1)
        lo = min(self.k_floor, hi)
        best = self._plan(_with_terms(cm, lo, n))
        if best is None:
            return None, None
        while lo < hi:
            mid = (lo + hi + 1) // 2
            probe = self._plan(_with_terms(cm, mid, n))
            if probe is not None:
                lo, best = mid, probe
            else:
                hi = mid - 1
        return lo, best

    def admit(self, cm: NTierCostModel | TwoTierCostModel
              ) -> AdmissionDecision:
        """Admit (possibly renegotiating K, then the window) one tenant."""
        if isinstance(cm, TwoTierCostModel):
            cm = cm.as_ntier()
        wl = cm.workload
        plan = self._plan(cm)
        if plan is not None:
            return AdmissionDecision(True, False, wl.k, wl.n_docs, wl.k,
                                     wl.n_docs, plan, "feasible as requested")
        n_grid = [wl.n_docs]
        n_lo = max(int(wl.n_docs * self.n_floor_frac), self.k_floor + 1)
        step = (n_lo / wl.n_docs) ** (1.0 / max(self.n_steps, 1))
        for i in range(1, self.n_steps + 1):
            n_i = max(int(wl.n_docs * step ** i), n_lo)
            if n_i != n_grid[-1]:
                n_grid.append(n_i)
        for n_i in n_grid:
            k_i, plan = self._largest_feasible_k(cm, n_i)
            if plan is not None:
                what = [f"K {wl.k} -> {k_i}"] if k_i != wl.k else []
                if n_i != wl.n_docs:
                    what.append(f"window {wl.n_docs} -> {n_i}")
                return AdmissionDecision(True, True, k_i, n_i, wl.k,
                                         wl.n_docs, plan,
                                         "negotiated " + ", ".join(what))
        return AdmissionDecision(False, False, wl.k, wl.n_docs, wl.k,
                                 wl.n_docs, None,
                                 f"infeasible even at K={self.k_floor}, "
                                 f"window={n_grid[-1]}")
