# Drift-aware online re-planning: closes the loop from metering back into
# the paper's proactive closed-form planner.
#   drift     — sequential entry-rate statistics vs the analytic K/t law,
#               (M,)-batched inside the jitted engine step (Bernstein-
#               bounded detection, CUSUM diagnostics, rho-hat estimate)
#   replan    — constrained BoundaryObjective re-solve over the remaining
#               window suffix (drift-conditioned laws, hop-priced
#               relocation bill, hysteresis)
#   admission — negotiate K / window length for tenants whose constrained
#               plan is infeasible, instead of rejecting them
#   evaluate  — realized-cost harness: engine closed loop vs static plan
#               vs a hindsight drift-aware oracle (core.simulator)
from . import admission, drift, evaluate, replan  # noqa: F401
from .admission import AdmissionController, AdmissionDecision  # noqa: F401
from .drift import DriftConfig, DriftEstimator  # noqa: F401
from .replan import Replanner, ReplanConfig, ReplanDecision  # noqa: F401
