"""Mid-window re-planning: re-run the constrained boundary solve over the
*remaining suffix* of a window once drift is detected.

The a-priori plan (``core.shp``) minimizes the full-window expectation
under the i.u.d. entry law K/(i+1). When the drift detector flags a
stream at position n0 with rate-multiplier estimate ρ, the suffix problem
conditions both laws on the observed prefix:

* entries among the remaining docs follow the weighted-record law
  conditioned on the detector's *instantaneous* observed/expected ratio
  ρ: future entries ``W(b) = K·ln(1 + ρ(b − n0)/n0)`` — the underlying
  drift weight cancels, so ρ is a sufficient statistic and the burst the
  reservoir bar has already absorbed is never double-counted (a
  persistent-multiplier ``ρK/(i+1)`` model would keep planning for it).
  The form stays separable log-piecewise, with eq. 17/21-style
  stationary points in the shifted coordinate ``u = S(b)``;
* the final top-K read weights survivor locations by the same drifted
  density (weight 1 over the seen prefix, ρ over the suffix, normalized
  by ``S_N = n0 + ρ(N − n0)`` — the weighted-record survivor law);
* boundary moves that cross *seen* indices re-tier existing residents:
  each such move is billed per boundary hop like eq. 19
  (promote across boundary j: ``cr_j + cw_{j-1}``; demote:
  ``cr_{j-1} + cw_j``), with residents uniform over the prefix at density
  ``min(n0, K)/n0`` — the migration bill. Moves are separable per
  boundary, so the whole suffix objective still solves on the planner's
  monotone candidate grid (``shp.solve_separable_terms``), including the
  capacity/SLO feasibility structure of a ``ConstraintSet``.

``Replanner.replan`` solves per tier subset (degenerate tiers collapse,
excluded tiers relocate their residents — billed), compares against the
suffix cost of keeping the old boundaries, and applies the delta only
when the expected suffix savings clear the migration bill plus a
hysteresis margin. Migrating (cascade) streams are left untouched: their
cost is dominated by the constant cascade fee and the floor semantics of
a mid-cascade re-plan are ambiguous. Storage keeps the planner's
most-expensive-used-tier bound convention, so old-vs-new suffix costs are
compared like for like.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import constraints as constraints_mod
from repro.core import shp
from repro.core.constraints import ConstraintSet
from repro.core.costs import NTierCostModel, TwoTierCostModel

from .drift import DriftConfig

_MOVE_TOL = 1e-6  # docs — boundary moves below this re-tier nobody


@dataclass(frozen=True)
class ReplanConfig:
    """Online re-planning policy knobs (the engine's ``replan=`` value)."""

    drift: DriftConfig = field(default_factory=DriftConfig)
    min_rel_saving: float = 0.01  # hysteresis: required relative saving
    allow_moves: bool = True  # permit billed resident relocation


@dataclass
class ReplanDecision:
    """Outcome of one re-planning pass over the flagged streams."""

    rows: np.ndarray  # (R,) caller-side stream indices
    n_seen: np.ndarray  # (R,) docs observed at re-plan time
    rho: np.ndarray  # (R,) rate-multiplier estimates used
    old_bounds: List[Tuple[float, ...]]
    new_bounds: List[Tuple[float, ...]]
    applied: np.ndarray  # (R,) bool
    considered: np.ndarray  # (R,) bool — False: structurally skipped
    feasible: np.ndarray  # (R,) bool — constrained suffix solve succeeded
    suffix_cost_old: np.ndarray  # (R,) expected suffix cost, old plan
    suffix_cost_new: np.ndarray  # (R,) expected suffix cost, new plan
    move_bill: np.ndarray  # (R,) expected relocation cost inside new
    expected_moves: np.ndarray  # (R,) expected docs relocated
    suffix_occupancy: List  # per row: (T,) projected suffix peaks, or None

    @property
    def any_applied(self) -> bool:
        return bool(self.applied.any())


def _as_ntier(cm) -> NTierCostModel:
    return cm.as_ntier() if isinstance(cm, TwoTierCostModel) else cm


def _mass(x, anchor, rho, n):
    """Survivor weight mass of [0, x): weight 1 before the (estimated)
    drift onset ``anchor``, ρ after."""
    return (np.minimum(x, anchor) + rho * (np.clip(x, anchor, n) - anchor))


def _w_suffix(x, n0, rho, k):
    """E[reservoir entries among suffix docs [n0, x)]: an unfull
    reservoir admits everything, then ``K·ln(1 + ρ(x − n0)/n0)``.

    This is the weighted-record law conditioned on the *instantaneous*
    observed/expected ratio ρ at n0: if a sustained weight θ produced
    current ratio ρ = θ·n0/S(n0), then S(x) = S(n0) + θ(x − n0) gives
    future entries K·ln(S(x)/S(n0)) = K·ln(1 + ρ(x − n0)/n0) — θ cancels,
    so ρ alone is sufficient and no onset estimate is needed. Reduces to
    the planner's ``W(x) − W(n0)`` at ρ = 1. Broadcasts."""
    x = np.maximum(x, n0)
    head = np.maximum(np.minimum(x, k) - n0, 0.0)
    start = np.maximum(n0, k)
    u = start + rho * (np.maximum(x, start) - start)
    return head + k * np.log(u / start)


def _reloc_terms(c, b0_j, n0, dens, price_up, price_dn, allow_moves):
    """(R, C) expected relocation cost of moving full boundary j from
    ``b0_j`` to each candidate value (hop-priced, residents uniform over
    the seen prefix)."""
    delta = np.clip(c, 0.0, n0[:, None]) - np.clip(b0_j, 0.0, n0)[:, None]
    cost = dens[:, None] * np.where(
        delta > 0, delta * price_up[:, None], -delta * price_dn[:, None])
    if not allow_moves:
        return np.where(np.abs(delta) > _MOVE_TOL, np.inf, 0.0)
    return cost


def _pinned_reloc_const(b0, n0, dens, cr, cw, sa, t, allow_moves):
    """(R,) relocation cost of the boundaries a subset pins: leading
    boundaries (j <= sa[0]) collapse to 0 (demoting the residents below
    them), trailing ones (j > sa[-1]) to N (promoting)."""
    r = b0.shape[0]
    const = np.zeros(r)
    moves = np.zeros(r)
    for j in range(1, sa[0] + 1):
        cnt = dens * np.clip(b0[:, j - 1], 0.0, n0)
        const += cnt * (cr[:, j - 1] + cw[:, j])
        moves += cnt
    for j in range(sa[-1] + 1, t):
        cnt = dens * (n0 - np.clip(b0[:, j - 1], 0.0, n0))
        const += cnt * (cr[:, j] + cw[:, j - 1])
        moves += cnt
    if not allow_moves:
        const = np.where(moves > _MOVE_TOL, np.inf, 0.0)
    return const, moves


def relocation_bill(b0, b_new, n0, k, cr, cw):
    """(bill (R,), moves (R,)) expected relocation cost/count of applying
    boundary vector ``b_new`` over ``b0`` at position ``n0`` — the same
    hop-priced law the solver's terms use, evaluated at one point."""
    b0 = np.asarray(b0, np.float64)
    b_new = np.asarray(b_new, np.float64)
    n0 = np.asarray(n0, np.float64)
    dens = np.minimum(n0, np.asarray(k, np.float64)) / np.maximum(n0, 1.0)
    bill = np.zeros(b0.shape[0])
    moves = np.zeros(b0.shape[0])
    for j in range(1, b0.shape[1] + 1):
        delta = (np.clip(b_new[:, j - 1], 0.0, n0)
                 - np.clip(b0[:, j - 1], 0.0, n0))
        price_up = cr[:, j] + cw[:, j - 1]
        price_dn = cr[:, j - 1] + cw[:, j]
        bill += dens * np.where(delta > 0, delta * price_up,
                                -delta * price_dn)
        moves += dens * np.abs(delta)
    return bill, moves


def suffix_cost(cw, cr, cs, n, k, rpw, n0, rho, bounds) -> np.ndarray:
    """(R,) expected cost of the window suffix under ``bounds`` with no
    relocation: drift-conditioned writes, weighted survivor read, and the
    most-expensive-used-tier rental bound (the planner's convention)."""
    r, t = cw.shape
    edges = np.concatenate([np.zeros((r, 1)),
                            np.asarray(bounds, np.float64),
                            n[:, None]], axis=1)
    wmax = _w_suffix(edges, n0[:, None], rho[:, None], k[:, None])
    writes = ((wmax[:, 1:] - wmax[:, :-1]) * cw).sum(axis=1)
    s_n = n0 + rho * (n - n0)
    mass = _mass(edges, n0[:, None], rho[:, None], n[:, None])
    reads = ((mass[:, 1:] - mass[:, :-1]) * cr).sum(axis=1) \
        * (rpw * k / s_n)
    used = np.diff(edges, axis=1) > 0
    storage = k * np.max(np.where(used, cs, -np.inf), axis=1)
    return writes + reads + storage


class Replanner:
    """Constrained suffix re-solver for a (sub)fleet of cost models.

    ``models[i]`` is stream i's cost model (two-tier models are viewed
    through ``as_ntier``; entries may be None for streams placed
    explicitly — those are never re-planned). ``constraints`` is a
    fleet-wide ``ConstraintSet`` or one per stream; fleet-shared
    capacities are not supported (their water-filled grants live in the
    a-priori fleet plan, not here).
    """

    def __init__(self, models: Sequence, constraints=None,
                 config: Optional[ReplanConfig] = None, backend=None):
        self.models = [None if cm is None else _as_ntier(cm)
                       for cm in models]
        self.config = config if config is not None else ReplanConfig()
        self.backend = backend  # None/"auto" | "jax" | "numpy"
        m = len(self.models)
        if constraints is None or isinstance(constraints, ConstraintSet):
            self.csets = [constraints] * m
        else:
            if len(constraints) != m:
                raise ValueError("need one ConstraintSet per stream")
            self.csets = list(constraints)
        for cset in self.csets:
            if cset is not None and cset.shared_capacities:
                raise NotImplementedError(
                    "fleet-shared capacities re-plan through the a-priori "
                    "water-filling pass, not the online re-planner")
        # constraint resolution and the struct-of-arrays model view are
        # pure in (model, cset): compile once at construction —
        # re-resolving and re-stacking per replan() call dominated the
        # whole suffix re-solve (~2/3 of the wall time)
        self._compiled = [None if cm is None
                          else shp.resolve_constraints(cm, cset)
                          for cm, cset in zip(self.models, self.csets)]
        self._row_pos: Dict[int, int] = {}
        by_t: Dict[int, List[int]] = {}
        for i, cm in enumerate(self.models):
            if cm is not None:
                by_t.setdefault(cm.t, []).append(i)
        self._stacks: Dict[int, dict] = {}
        for t, rows in by_t.items():
            ms = [self.models[i] for i in rows]
            self._stacks[t] = {
                "cw": np.stack([cm.cw for cm in ms]),
                "cr": np.stack([cm.cr for cm in ms]),
                "cs": np.stack([cm.cs for cm in ms]),
                "n": np.array([float(cm.workload.n_docs) for cm in ms]),
                "k": np.array([float(cm.workload.k) for cm in ms]),
                "rpw": np.array([cm.workload.reads_per_window
                                 for cm in ms]),
                "cap": np.stack([self._compiled[i][0] for i in rows]),
                "lat": np.stack([self._compiled[i][1] for i in rows]),
                "slo": np.array([self._compiled[i][2] for i in rows]),
            }
            for pos, i in enumerate(rows):
                self._row_pos[i] = pos
        self._t_of = np.array([0 if cm is None else cm.t
                               for cm in self.models], np.int64)
        self._ndocs_of = np.array(
            [0.0 if cm is None else float(cm.workload.n_docs)
             for cm in self.models])

    # ---- the suffix solve ------------------------------------------------

    def _solve_group(self, idxs, n_seen, rho, b0,
                     exclude_tiers=frozenset()):
        """Re-solve one uniform-tier-count group. Returns (total (R,),
        bounds (R, t-1), cost_old (R,)).

        Dispatches the per-subset suffix solve to the jitted device path
        (``online.replan_device``, the ``kernels.plan_solve`` reduction)
        for hierarchies the exact enumeration covers; the NumPy loop
        below remains the oracle reference (``backend="numpy"``) the
        device path is property-tested against.

        ``exclude_tiers`` (tier-outage degradation) drops every tier
        subset that touches a masked tier, so the chosen plan gives the
        failed tier zero width over the whole window — residents are
        relocated off it by the caller's ``apply_boundaries`` and no
        future doc lands there. The enumeration runs on the NumPy oracle
        path (the device program enumerates the full subset lattice)."""
        cfg = self.config
        exclude_tiers = frozenset(exclude_tiers)
        t = self.models[idxs[0]].t
        r = len(idxs)
        st = self._stacks[t]
        pos = np.asarray([self._row_pos[i] for i in idxs], np.int64)
        cw, cr, cs = st["cw"][pos], st["cr"][pos], st["cs"][pos]
        n, k, rpw = st["n"][pos], st["k"][pos], st["rpw"][pos]
        cap, lat, slo = st["cap"][pos], st["lat"][pos], st["slo"][pos]
        constrained = not constraints_mod.trivial(cap, slo)
        n0 = np.asarray(n_seen, np.float64)
        rho = np.asarray(rho, np.float64)
        backend = self.backend if self.backend is not None else "auto"
        if backend != "numpy" and not exclude_tiers:
            try:
                from . import replan_device
                if replan_device.available(t):
                    total, bounds, cost_old = replan_device.solve_group(
                        cw, cr, cs, n, k, rpw, cap, lat, slo, n0, rho,
                        np.asarray(b0, np.float64),
                        allow_moves=cfg.allow_moves)
                    return total, bounds, cost_old, (cw, cr, n0, k, n,
                                                     cap)
                if backend == "jax":
                    raise ValueError(
                        f"device suffix re-solve unavailable for t={t}")
            except ImportError:
                if backend == "jax":
                    raise
        s_n = n0 + rho * (n - n0)
        dens = np.minimum(n0, k) / np.maximum(n0, 1.0)
        start = np.maximum(n0, k)
        w_n = _w_suffix(n, n0, rho, k)
        best_total = np.full(r, np.inf)
        best_bounds = np.zeros((r, t - 1))
        for sub in shp._tier_subsets(t):
            if exclude_tiers and exclude_tiers.intersection(sub):
                continue  # tier outage: subsets touching a masked tier
            sa = np.asarray(sub)
            ts = sa.shape[0]
            lin = (rpw * k * rho / s_n)[:, None] * cr[:, sa]
            kw = (dict(cap_s=cap[:, sa], lat_s=lat[:, sa], slo=slo)
                  if constrained else {})
            obj = shp.BoundaryObjective(cw_s=rho[:, None] * cw[:, sa],
                                        lin_s=lin, n=n, k=k, **kw)
            ok = obj.subset_feasible()
            reloc_const, _ = _pinned_reloc_const(b0, n0, dens, cr, cw, sa,
                                                 t, cfg.allow_moves)
            const = (w_n * cw[:, sa[-1]]
                     + rpw * k * cr[:, sa[-1]] + reloc_const
                     + k * np.max(cs[:, sa], axis=1))
            if ts == 1:
                interior, sub_bounds = np.zeros(r), np.zeros((r, 0))
            else:
                # stationary points of the drifted write law live in the
                # shifted coordinate u = S(b): map the eq. 17/21-style
                # crossovers back through b = start + (u − start)/ρ
                ustars = shp._crossover_candidates(
                    cw[:, sa], lin, rho * k, np.zeros(r), np.inf)
                extra = [np.clip(n0, 0.0, n)]
                extra += [np.clip(start + (u - start) / rho, 0.0, n)
                          for u in ustars]
                extra += [np.clip(b0[:, j], 0.0, n)
                          for j in range(t - 1)]
                c = np.sort(np.concatenate(
                    [obj.candidates(), np.stack(extra, axis=1)], axis=1),
                    axis=1)
                fs = []
                for s in range(1, ts):
                    u, v = sa[s - 1], sa[s]
                    f = ((cw[:, u] - cw[:, v])[:, None]
                         * _w_suffix(c, n0[:, None], rho[:, None],
                                     k[:, None])
                         + ((cr[:, u] - cr[:, v]) * rpw * k / s_n)[:, None]
                         * _mass(c, n0[:, None], rho[:, None], n[:, None]))
                    for j in range(u + 1, v + 1):
                        f = f + _reloc_terms(
                            c, b0[:, j - 1], n0, dens,
                            cr[:, j] + cw[:, j - 1],
                            cr[:, j - 1] + cw[:, j], cfg.allow_moves)
                    fs.append(f)
                if obj.constrained:
                    base = obj.terms(c)
                    fs = [np.where(np.isfinite(bj), fj, np.inf)
                          for fj, bj in zip(fs, base)]
                interior, sub_bounds = shp.solve_separable_terms(obj, fs, c)
            total = np.where(ok, interior + const, np.inf)
            edges = np.concatenate([np.zeros((r, 1)), sub_bounds,
                                    n[:, None]], 1)
            widths = np.zeros((r, t))
            widths[:, sa] = np.diff(edges, axis=1)
            full = np.cumsum(widths, axis=1)[:, :-1]
            upd = total < best_total
            best_total = np.where(upd, total, best_total)
            best_bounds = np.where(upd[:, None], full, best_bounds)
        cost_old = suffix_cost(cw, cr, cs, n, k, rpw, n0, rho, b0)
        return best_total, best_bounds, cost_old, (cw, cr, n0, k, n, cap)

    def replan(self, rows, n_seen, rho, boundaries, migrate,
               hwm=None, exclude_tiers=frozenset(),
               force: bool = False) -> ReplanDecision:
        """Re-solve the flagged streams. ``rows`` index into the model
        list; ``boundaries[i]`` is each stream's current vector (its own
        tier depth); ``migrate`` flags cascade streams (skipped). ``rho``
        is the detector's *instantaneous* observed/expected entry-rate
        ratio — a sufficient statistic for the conditioned suffix laws
        (the underlying drift weight cancels). ``hwm`` ((R, >=T) metered
        occupancy high-water marks) conditions the occupancy check on the
        observed prefix: the projected suffix peak is
        ``max(analytic, observed)`` (``constraints.peak_occupancy_suffix``
        — a peak already witnessed under drift cannot be un-rung), and a
        re-solved plan whose projected peaks violate the capacities is
        reported infeasible so the caller can hand the tenant to
        admission control.

        ``exclude_tiers`` masks failed tiers out of the feasible subset
        lattice (tier-outage degradation); ``force`` applies every
        feasible re-solve regardless of the hysteresis margin — an
        evacuation is a feasibility decision, not a savings decision, so
        a costlier suffix plan must still be applied."""
        rows = np.asarray(rows, np.int64)
        n_seen = np.asarray(n_seen, np.float64)
        rho = np.asarray(rho, np.float64)
        migrate = np.asarray(migrate, bool)
        r = rows.shape[0]
        old = [tuple(boundaries[i]) for i in range(r)]
        new = list(old)
        applied = np.zeros(r, bool)
        feasible = np.ones(r, bool)
        cost_old = np.full(r, np.nan)
        cost_new = np.full(r, np.nan)
        bill = np.zeros(r)
        moves = np.zeros(r)
        suffix_occ: List = [None] * r
        t_of = self._t_of[rows]
        considered = ((t_of > 0) & ~migrate & (n_seen > 0)
                      & (n_seen < self._ndocs_of[rows]))
        groups: Dict[int, List[int]] = {}
        for j in np.flatnonzero(considered):
            groups.setdefault(int(t_of[j]), []).append(int(j))
        for t, idxs in sorted(groups.items()):
            b0 = np.array([old[j] for j in idxs], np.float64)
            total, bounds, c_old, (cw, cr, n0, k, n, cap) = \
                self._solve_group([rows[j] for j in idxs], n_seen[idxs],
                                  rho[idxs], b0,
                                  exclude_tiers=exclude_tiers)
            g_bill, g_moves = relocation_bill(b0, bounds, n0, k, cr, cw)
            feas = np.isfinite(total)
            occ = None
            if hwm is not None:
                hwm_g = np.zeros((len(idxs), t))
                for gi, j in enumerate(idxs):
                    row_hwm = np.asarray(hwm[j], np.float64)
                    hwm_g[gi, : min(t, row_hwm.shape[0])] = row_hwm[:t]
                occ = constraints_mod.peak_occupancy_suffix(bounds, n, k,
                                                            hwm_g)
                feas = feas & np.all(occ <= cap * (1 + 1e-9), axis=1)
            margin = self.config.min_rel_saving * np.maximum(
                np.abs(c_old), 1e-12)
            apply_g = feas & (force | (total < c_old - margin))
            ii = np.asarray(idxs, np.int64)
            feasible[ii] = feas
            cost_old[ii] = c_old
            cost_new[ii] = total
            ap = np.flatnonzero(apply_g)
            applied[ii[ap]] = True
            bill[ii[ap]] = g_bill[ap]
            moves[ii[ap]] = g_moves[ap]
            if occ is not None:
                for jj, j in enumerate(idxs):
                    suffix_occ[j] = occ[jj]
            blist = bounds.tolist()
            for jj in ap:
                new[idxs[jj]] = tuple(blist[jj])
        return ReplanDecision(rows=rows, n_seen=n_seen, rho=rho,
                              old_bounds=old, new_bounds=new,
                              applied=applied, considered=considered,
                              feasible=feasible,
                              suffix_cost_old=cost_old,
                              suffix_cost_new=cost_new, move_bill=bill,
                              expected_moves=moves,
                              suffix_occupancy=suffix_occ)
