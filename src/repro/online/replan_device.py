"""Device-resident constrained suffix re-solve: the jitted port of
``Replanner._solve_group``'s per-subset boundary optimization.

The host path loops ``shp._tier_subsets`` in Python, building per-subset
candidate grids and drift-conditioned term matrices in NumPy and running
``shp.solve_separable_terms`` — at fleet re-plan scale (hundreds of
drift-flagged tenants between chunks) that host round-trip capped the
``online.resolve_*`` throughput. This module evaluates the same suffix
objective — drift-conditioned write law W(b) = K·ln(1 + ρ(b − n0)/n0),
weighted survivor read mass, hop-priced relocation terms, pinned-boundary
relocation constants — and the same constraint structure (first/last-tier
capacity masks, middle-tier pairwise lower bounds, the exact latency
budget) in one jitted XLA program per (T, constraint-signature,
allow-moves, padded-R) key, reducing with the ``kernels.plan_solve``
solvers (value-pair enumeration / masked minima).

Exactness mirrors ``core.shp_jax``: the host's data-dependent ``np.any``
gates become static jit keys, sums keep the host's order and
association, and first-minimum-wins tie-breaks survive as strict-<
folds (ties between equal-cost tuples may resolve to a different,
equal-cost boundary — see the shp_jax policy note). Always float64
(scoped x64): re-plan decisions feed hysteresis and billing
comparisons, and R is hundreds, not tens of thousands.
"""
from __future__ import annotations

import functools
import itertools

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only without jax
    _HAVE_JAX = False

from repro.core import shp, shp_jax

_MOVE_TOL = 1e-6  # == replan._MOVE_TOL


def available(t: int) -> bool:
    return _HAVE_JAX and 2 <= t <= shp_jax.MAX_DEVICE_TIERS


def _w_suffix(x, n0, rho, k):
    """Traced ``replan._w_suffix`` (drift-conditioned suffix write law)."""
    x = jnp.maximum(x, n0)
    head = jnp.maximum(jnp.minimum(x, k) - n0, 0.0)
    start = jnp.maximum(n0, k)
    u = start + rho * (jnp.maximum(x, start) - start)
    return head + k * jnp.log(u / start)


def _mass(x, anchor, rho, n):
    """Traced ``replan._mass`` (weighted survivor mass of [0, x))."""
    return (jnp.minimum(x, anchor)
            + rho * (jnp.clip(x, anchor, n) - anchor))


def _reloc_cols(c, b0_j, n0, dens, price_up, price_dn, allow_moves):
    """Traced ``replan._reloc_terms`` on grid ``c`` (M, C). With
    ``allow_moves`` False returns (zeros, blocked-mask) instead of the
    host's +inf fold so the caller can fold it once."""
    delta = jnp.clip(c, 0.0, n0[:, None]) - jnp.clip(b0_j, 0.0, n0)[:, None]
    if not allow_moves:
        return None, jnp.abs(delta) > _MOVE_TOL
    cost = dens[:, None] * jnp.where(delta > 0, delta * price_up[:, None],
                                     -delta * price_dn[:, None])
    return cost, None


def _pinned_reloc(b0, n0, dens, cr, cw, sa, t, allow_moves):
    """Traced ``replan._pinned_reloc_const``."""
    const = jnp.zeros_like(n0)
    moves = jnp.zeros_like(n0)
    for j in range(1, sa[0] + 1):
        cnt = dens * jnp.clip(b0[:, j - 1], 0.0, n0)
        const = const + cnt * (cr[:, j - 1] + cw[:, j])
        moves = moves + cnt
    for j in range(sa[-1] + 1, t):
        cnt = dens * (n0 - jnp.clip(b0[:, j - 1], 0.0, n0))
        const = const + cnt * (cr[:, j] + cw[:, j - 1])
        moves = moves + cnt
    if not allow_moves:
        const = jnp.where(moves > _MOVE_TOL, jnp.inf, 0.0)
    return const


def _subset_candidate_cols(sa, cw_obj, lin, kf, nf, lo, hi, constrained,
                           capfin, slo_any, cap, lat, slo):
    """``BoundaryObjective.candidates``'s columns for the suffix
    objective (cw_s = ρ·cw, lin_s = drift-weighted read coefficients),
    under the host's any-finite gates — unsorted column list."""
    ts = len(sa)
    cols = [lo, jnp.minimum(kf, nf), hi]
    cols += shp_jax.crossover_cols(cw_obj, lin, kf, lo, hi)
    if constrained:
        for j in sa:
            if not capfin[j]:
                continue
            cap_j = cap[:, j]
            fin = jnp.isfinite(cap_j)
            cols.append(jnp.clip(jnp.where(fin, cap_j, 0.0), lo, hi))
            tight = nf * (1.0 - cap_j / kf)
            cols.append(jnp.clip(jnp.where(fin, tight, 0.0), lo, hi))
        if slo_any:
            for s, u in itertools.combinations(range(ts), 2):
                dl = lat[:, sa[s]] - lat[:, sa[u]]
                b = nf * (slo - lat[:, sa[u]]) / dl
                b = jnp.where(jnp.isfinite(b), b, 0.0)
                cols.append(jnp.clip(b, lo, hi))
        for i in range(1, ts - 1):
            if capfin[sa[i]]:
                cols += shp_jax.mid_cap_cols(
                    cw_obj[:, i - 1], cw_obj[:, i], cw_obj[:, i + 1],
                    lin[:, i - 1], lin[:, i], lin[:, i + 1],
                    cap[:, sa[i]], kf, lo, hi)
    return cols


def _solve_impl(cw, cr, cs, n, k, rpw, cap, lat, slo, n0, rho, b0, *, t,
                constrained, capfin, slo_any, allow_moves):
    from repro.kernels.plan_solve import ops as solve_ops
    from repro.kernels.plan_solve import ref as solve_ref
    m = cw.shape[0]
    dtype = cw.dtype
    kf, nf = k, n
    s_n = n0 + rho * (n - n0)
    dens = jnp.minimum(n0, k) / jnp.maximum(n0, 1.0)
    start = jnp.maximum(n0, k)
    w_n = _w_suffix(n, n0, rho, k)
    lo = jnp.zeros_like(nf)
    best_val = jnp.full((m,), jnp.inf, dtype)
    best_bounds = [jnp.zeros((m,), dtype) for _ in range(t - 1)]
    for sa in shp._tier_subsets(t):
        ts = len(sa)
        sl = list(sa)
        lin = (rpw * k * rho / s_n)[:, None] * cr[:, sl]
        cw_obj = rho[:, None] * cw[:, sl]
        cap_s = cap[:, sl] if constrained else None
        lat_s = lat[:, sl] if constrained else None
        ok = shp_jax.subset_feasible(m, ts, False, kf, nf, cap_s, lat_s,
                                     slo)
        reloc_const = _pinned_reloc(b0, n0, dens, cr, cw, sa, t,
                                    allow_moves)
        const = (w_n * cw[:, sa[-1]] + rpw * k * cr[:, sa[-1]]
                 + reloc_const + k * jnp.max(cs[:, sl], axis=1))
        if ts == 1:
            total = jnp.where(ok, const, jnp.inf)
            bounds_cols = [nf if j >= sa[0] else jnp.zeros((m,), dtype)
                           for j in range(t - 1)]
        else:
            cols = _subset_candidate_cols(sa, cw_obj, lin, kf, nf, lo, nf,
                                          constrained, capfin, slo_any,
                                          cap, lat, slo)
            ustars = shp_jax.crossover_cols(cw[:, sl], lin, rho * k, lo,
                                            jnp.full_like(nf, jnp.inf))
            cols.append(jnp.clip(n0, 0.0, nf))
            cols += [jnp.clip(start + (u - start) / rho, 0.0, nf)
                     for u in ustars]
            cols += [jnp.clip(b0[:, j], 0.0, nf) for j in range(t - 1)]
            c = jnp.stack(cols, axis=1)
            sub_con = (constrained
                       and (any(capfin[j] for j in sa) or slo_any))

            def build_fs(grid):
                """The drift-conditioned per-step suffix terms on one
                candidate grid: write law + survivor mass + hop-priced
                relocation columns, capacity masks folded as +inf."""
                out = []
                for s in range(1, ts):
                    u, v = sa[s - 1], sa[s]
                    f = ((cw[:, u] - cw[:, v])[:, None]
                         * _w_suffix(grid, n0[:, None], rho[:, None],
                                     k[:, None])
                         + ((cr[:, u] - cr[:, v]) * rpw * k / s_n)[:, None]
                         * _mass(grid, n0[:, None], rho[:, None],
                                 n[:, None]))
                    blocked = None
                    for j in range(u + 1, v + 1):
                        cost, blk = _reloc_cols(
                            grid, b0[:, j - 1], n0, dens,
                            cr[:, j] + cw[:, j - 1],
                            cr[:, j - 1] + cw[:, j], allow_moves)
                        if cost is not None:
                            f = f + cost
                        if blk is not None:
                            blocked = blk if blocked is None else \
                                blocked | blk
                    f = shp_jax._fold_cap_masks(f, grid, s, ts, sa,
                                                sub_con, capfin, cap, kf,
                                                nf)
                    if blocked is not None:
                        f = jnp.where(blocked, jnp.inf, f)
                    out.append(f)
                return out

            fs = build_fs(c)
            kw = {}
            if sub_con and slo_any:
                cmax = jnp.max(c, axis=1)
                alphas, scale = [], None
                for j in range(1, ts):
                    al = (lat[:, sa[j - 1]] - lat[:, sa[j]]) / nf
                    alphas.append(al)
                    sc = jnp.abs(cmax * al)
                    scale = sc if scale is None else scale + sc
                rhs = slo - lat[:, sa[-1]]
                kw = dict(alpha=alphas, rhs=rhs,
                          atol=1e-9 * (jnp.abs(rhs) + scale) + 1e-15)
            if ts == 2:
                interior, bvec = solve_ref.single_arr(fs[0], c, **kw)
            elif ts == 3:
                if sub_con and capfin[sa[1]]:
                    kw.update(kf=kf, cap_m=cap[:, sa[1]])
                interior, bvec = solve_ref.tri_arr(fs[0], fs[1], c, **kw)
            else:  # ts == 4: gathered enumeration on a sorted grid
                c_s = shp_jax.sort_network(
                    [[c[:, i] for i in range(c.shape[1])]])[0]
                fs4 = jnp.stack(build_fs(c_s), 1)[:, None]
                kw4 = {}
                if sub_con and any(capfin[sa[i]] for i in range(1, ts - 1)):
                    kw4["pair_caps"] = [
                        cap[:, sa[j]][:, None]
                        if capfin[sa[j]] else None
                        for j in range(1, ts - 1)]
                    kw4["kf"] = kf
                if kw:
                    kw4.update(alpha=jnp.stack(kw["alpha"], 1)[:, None],
                               rhs=kw["rhs"][:, None],
                               atol=kw["atol"][:, None])
                interior, _, selm = solve_ref.enum_solve(
                    fs4, (jnp.zeros((m, 1), dtype),),
                    solve_ops.monotone_combos(c_s.shape[1], ts - 1),
                    cand=c_s[:, None], **kw4)
                bvec = [solve_ref.pick_col(c_s, selm[:, j])
                        for j in range(ts - 1)]
            total = jnp.where(ok, interior + const, jnp.inf)
            bounds_cols = shp_jax._subset_bounds_cols(sa, t, bvec, nf)
        upd = total < best_val
        best_val = jnp.where(upd, total, best_val)
        best_bounds = [jnp.where(upd, bc, bb)
                       for bc, bb in zip(bounds_cols, best_bounds)]
    # traced mirror of ``replan.suffix_cost`` at the old boundaries —
    # the like-for-like comparison side of the hysteresis decision
    edges = [jnp.zeros_like(nf)] \
        + [b0[:, j] for j in range(t - 1)] + [nf]
    writes = jnp.zeros_like(nf)
    reads = jnp.zeros_like(nf)
    storage = jnp.full_like(nf, -jnp.inf)
    for j in range(t):
        wj = (_w_suffix(edges[j + 1], n0, rho, k)
              - _w_suffix(edges[j], n0, rho, k))
        writes = writes + wj * cw[:, j]
        mj = (_mass(edges[j + 1], n0, rho, n)
              - _mass(edges[j], n0, rho, n))
        reads = reads + mj * cr[:, j]
        used = edges[j + 1] - edges[j] > 0
        storage = jnp.maximum(storage, jnp.where(used, cs[:, j], -jnp.inf))
    cost_old = writes + reads * (rpw * k / s_n) + k * storage
    return best_val, jnp.stack(best_bounds, axis=1), cost_old


@functools.partial(jax.jit if _HAVE_JAX else lambda f, **kw: f,
                   static_argnames=("t", "constrained", "capfin",
                                    "slo_any", "allow_moves"))
def _solve_jit(cw, cr, cs, n, k, rpw, cap, lat, slo, n0, rho, b0, *, t,
               constrained, capfin, slo_any, allow_moves):
    return _solve_impl(cw, cr, cs, n, k, rpw, cap, lat, slo, n0, rho, b0,
                       t=t, constrained=constrained, capfin=capfin,
                       slo_any=slo_any, allow_moves=allow_moves)


@functools.lru_cache(maxsize=None)
def _solve_sharded_fn(mesh, t, constrained, capfin, slo_any, allow_moves):
    """Jitted ``shard_map`` of ``_solve_impl`` over the fleet axis: each
    shard runs the identical single-device suffix re-solve on its rows
    (no collectives), so sharded totals/bounds are bit-identical."""
    from repro.parallel import fleet as fleet_mod
    fn = functools.partial(_solve_impl, t=t, constrained=constrained,
                           capfin=capfin, slo_any=slo_any,
                           allow_moves=allow_moves)
    spec = fleet_mod.row_spec()
    return jax.jit(fleet_mod.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * 12,
        out_specs=(spec, spec, spec), check_rep=False))


def solve_group(cw, cr, cs, n, k, rpw, cap, lat, slo, n0, rho, b0, *,
                allow_moves=True):
    """Device re-solve of one uniform-tier-count drift-flagged group.
    Inputs mirror ``Replanner._solve_group``'s stacked arrays; returns
    (total (R,), bounds (R, t-1), cost_old (R,)) with +inf totals where
    no feasible plan exists. R is padded to a power of two to bound the jit cache."""
    r, t = cw.shape
    from repro.core import constraints as constraints_mod
    constrained = not constraints_mod.trivial(np.asarray(cap),
                                              np.asarray(slo))
    capfin = tuple(bool(np.any(np.isfinite(np.asarray(cap)[:, j])))
                   for j in range(t))
    slo_any = bool(np.any(np.isfinite(np.asarray(slo))))
    # active fleet mesh: split R across shards, each padded to a
    # power-of-two block (same jit-cache bound, one signature per
    # (mesh, per-shard-R) instead of per total R)
    from repro.obs import jits as obs_jits
    from repro.parallel import fleet as fleet_mod
    mesh = fleet_mod.get_fleet_mesh()
    shards = fleet_mod.n_shards(mesh)
    if shards > 1:
        per = 1 << max(-(-r // shards) - 1, 3).bit_length()
        rp = per * shards
    else:
        rp = 1 << max(r - 1, 3).bit_length()

    def _pad(x):
        x = np.asarray(x, np.float64)
        if rp > r:
            x = np.concatenate(
                [x, np.broadcast_to(x[:1], (rp - r,) + x.shape[1:])])
        return x

    args = [_pad(x) for x in (cw, cr, cs, n, k, rpw, cap, lat, slo, n0,
                              rho, b0)]
    # jit-cache probe (repro.obs.jits): one compiled signature per
    # (T, constraint-signature, padded-R) static key
    with enable_x64():
        if shards > 1:
            fn = _solve_sharded_fn(mesh, t, constrained, capfin, slo_any,
                                   bool(allow_moves))
            probe = obs_jits.probe("replan_device.solve_sharded")
            key = (obs_jits.mesh_key(mesh), t, constrained, capfin,
                   slo_any, bool(allow_moves), per)
            sh = fleet_mod.row_sharding(mesh)
            dev = [jax.device_put(a, sh) for a in args]
            total, bounds, cost_old = probe.track(fn, *dev, key=key)
        else:
            probe = obs_jits.probe("replan_device.solve")
            key = (t, constrained, capfin, slo_any, bool(allow_moves), rp)
            total, bounds, cost_old = probe.track(
                _solve_jit, *args, key=key, t=t, constrained=constrained,
                capfin=capfin, slo_any=slo_any,
                allow_moves=bool(allow_moves))
        total = np.asarray(total, np.float64)[:r]
        bounds = np.asarray(bounds, np.float64)[:r]
        cost_old = np.asarray(cost_old, np.float64)[:r]
    return total, bounds, cost_old
