"""Sequential drift detection for the fleet engine — observed
reservoir-entry counts tested against the analytic top-K entry law.

Under the paper's i.u.d. assumption, a merge that extends a stream's
prefix from ``a`` to ``b`` docs admits a hypergeometric number of new
reservoir entries: the top-``min(b, K)`` of ``b`` exchangeable docs are
uniformly located, so the count of them landing in the last ``b − a``
positions has mean ``min(b,K)·(b−a)/b`` (the batched form of eq. 9/10 —
``shp.expected_cum_writes_batched`` summed per chunk) and the matching
hypergeometric variance. Real streams drift: bursty scoring functions
make entries arrive faster (or slower) than the law predicts.

``DriftEstimator`` maintains, per stream and fully batched as (M,) arrays
inside the jitted engine step:

* a cumulative deviation ``dev = Σ (observed − expected)`` and its
  variance budget ``var = Σ Var`` since the last reset, tested each chunk
  against a Bernstein bound calibrated from half the ``alpha`` budget
  (Bonferroni over ``max_checks`` chunk checkpoints) — the GLR-style
  whole-window test, rigorous for onset at the window start;
* one-sided CUSUM excursions ``S± = max(0, S± ± (observed − expected))``
  with their own variance budgets (reset whenever the excursion touches
  zero), tested against the same Bernstein form from the other half of
  the budget — the Page-style test that keeps its power when the drift
  begins mid-window, because each excursion re-anchors at its running
  argmin instead of diluting against the clean prefix;
* exponentially-windowed recent observed/expected totals, whose ratio is
  the re-planner's rate-multiplier estimate ``rho_hat``.

The whole-window test's false-positive bound is exact up to the negative
association of entry indicators (the Bernoulli-sum tail bound applies
conservatively); the excursion test's data-dependent anchor adds scan
multiplicity the Bernstein slack absorbs in practice. The null property
test asserts the *combined* empirical false-positive rate stays below
``alpha``.

Detection is *latched* (``fired`` stays up until ``reset_where``); the
engine re-plans the flagged streams between chunks and resets them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DriftConfig:
    """Static detector configuration (hashable — closed over by the jitted
    engine step)."""

    alpha: float = 0.01  # total false-positive budget per stream-window
    max_checks: int = 1024  # Bonferroni budget: checkpoints at full power
    decay: float = 0.9  # per-chunk decay of the recent-rate window
    rho_min: float = 0.125  # clip range of the rate-multiplier estimate
    rho_max: float = 16.0

    @property
    def bernstein_a(self) -> float:
        """Whole-window test exponent: ln(2·max_checks/(alpha/2)).

        Checkpoints beyond ``max_checks`` keep testing with a
        quadratically decaying per-check budget (exponent grows by
        ``2·ln(checks/max_checks)``), which adds at most ~alpha/2 of
        lifetime false-positive mass instead of going permanently blind
        on long windows."""
        return math.log(4.0 * self.max_checks / self.alpha)

    @property
    def bernstein_a_cusum(self) -> float:
        """Per-side excursion test exponent (alpha/4 each side; same
        decaying extension beyond ``max_checks``)."""
        return math.log(4.0 * self.max_checks / self.alpha)


class DriftState(NamedTuple):
    """Per-stream sequential statistics, one leading (M,) axis."""

    seen: jax.Array  # (M,) f32 — docs observed (the law's prefix length)
    dev: jax.Array  # (M,) f32 — Σ (observed − expected) since reset
    var: jax.Array  # (M,) f32 — Σ chunk variance since reset
    expected: jax.Array  # (M,) f32 — Σ expected entries since reset
    dev_recent: jax.Array  # (M,) f32 — decayed deviation window
    exp_recent: jax.Array  # (M,) f32 — decayed expectation window
    cusum_pos: jax.Array  # (M,) f32 — positive excursion sum
    cusum_pos_var: jax.Array  # (M,) f32 — its variance budget
    cusum_pos_exp: jax.Array  # (M,) f32 — expected entries in excursion
    cusum_pos_seen: jax.Array  # (M,) f32 — docs seen at excursion anchor
    cusum_neg: jax.Array  # (M,) f32
    cusum_neg_var: jax.Array  # (M,) f32
    cusum_neg_exp: jax.Array  # (M,) f32
    cusum_neg_seen: jax.Array  # (M,) f32
    checks: jax.Array  # (M,) i32 — chunk checkpoints consumed
    fired: jax.Array  # (M,) bool — latched detection flag


def init(m: int) -> DriftState:
    z = jnp.zeros((m,), jnp.float32)
    return DriftState(seen=z, dev=z, var=z, expected=z, dev_recent=z,
                      exp_recent=z, cusum_pos=z, cusum_pos_var=z,
                      cusum_pos_exp=z, cusum_pos_seen=z, cusum_neg=z,
                      cusum_neg_var=z, cusum_neg_exp=z, cusum_neg_seen=z,
                      checks=jnp.zeros((m,), jnp.int32),
                      fired=jnp.zeros((m,), bool))


def chunk_law(seen_before, seen_after, k):
    """(mean, var) of the null entry count for a merge extending the
    prefix from ``seen_before`` to ``seen_after`` docs — hypergeometric:
    the top-``min(b,K)`` of b exchangeable docs, sampled by the last
    ``b − a`` positions."""
    a = jnp.asarray(seen_before, jnp.float32)
    b = jnp.asarray(seen_after, jnp.float32)
    kf = jnp.asarray(k, jnp.float32)
    w = b - a
    kc = jnp.minimum(b, kf)
    mean = jnp.where(b > 0, kc * w / jnp.maximum(b, 1.0), 0.0)
    frac = kc / jnp.maximum(b, 1.0)
    var = jnp.where(b > 1,
                    w * frac * (1.0 - frac) * (b - w)
                    / jnp.maximum(b - 1.0, 1.0), 0.0)
    return mean, var


def bernstein_threshold(var, a_const):
    """Deviation bound t with P(|Σ increments| > t) <= 2·exp(−a_const)
    for centered increments bounded by 1 with variance budget ``var``."""
    return a_const / 3.0 + jnp.sqrt(a_const * a_const / 9.0
                                    + 2.0 * a_const * var)


def _budget_overrun(checks, cfg: DriftConfig):
    """Extra threshold exponent past the Bonferroni budget: checkpoints
    j > max_checks spend a per-check budget decaying like
    (max_checks/j)², so testing never stops but the added lifetime
    false-positive mass stays bounded (~alpha/2)."""
    over = jnp.maximum(checks.astype(jnp.float32) / cfg.max_checks, 1.0)
    return 2.0 * jnp.log(over)


def update(state: DriftState, wrote_count, seen_after,
           k, cfg: DriftConfig, slack: float = 0.0) -> DriftState:
    """One chunk of evidence per stream (jit-friendly, (M,) batched).

    ``wrote_count``: reservoir entries this chunk; ``seen_after``: docs
    observed after the merge; ``k``: per-stream (or scalar) reservoir
    width. Streams that observed nothing this chunk are untouched.

    ``slack`` is the fractional admit-count tolerance of an approximate
    engine backend (``streams.logmem.law_slack`` — the 1−O(1/√K)
    budget): each test's threshold grows by ``slack × expected mass``
    accumulated since its anchor, so the backend's systematic law bias
    is absorbed without loosening the null guarantee (thresholds only
    grow; slack = 0 reproduces the exact-backend test bitwise).
    """
    w = jnp.asarray(wrote_count, jnp.float32)
    b = jnp.asarray(seen_after, jnp.float32)
    active = b > state.seen
    mean, var_c = chunk_law(state.seen, b, k)
    mean = jnp.where(active, mean, 0.0)
    var_c = jnp.where(active, var_c, 0.0)
    d = jnp.where(active, w - mean, 0.0)
    dev = state.dev + d
    var = state.var + var_c
    expected = state.expected + mean
    dev_recent = cfg.decay * state.dev_recent + d
    exp_recent = cfg.decay * state.exp_recent + mean
    cusum_pos = jnp.maximum(0.0, state.cusum_pos + d)
    pos_live = cusum_pos > 0.0
    was_pos = state.cusum_pos > 0.0
    cusum_pos_var = jnp.where(pos_live, state.cusum_pos_var + var_c, 0.0)
    cusum_pos_exp = jnp.where(pos_live, state.cusum_pos_exp + mean, 0.0)
    cusum_pos_seen = jnp.where(
        pos_live, jnp.where(was_pos, state.cusum_pos_seen, state.seen), 0.0)
    cusum_neg = jnp.maximum(0.0, state.cusum_neg - d)
    neg_live = cusum_neg > 0.0
    was_neg = state.cusum_neg > 0.0
    cusum_neg_var = jnp.where(neg_live, state.cusum_neg_var + var_c, 0.0)
    cusum_neg_exp = jnp.where(neg_live, state.cusum_neg_exp + mean, 0.0)
    cusum_neg_seen = jnp.where(
        neg_live, jnp.where(was_neg, state.cusum_neg_seen, state.seen), 0.0)
    checks = state.checks + active.astype(jnp.int32)
    extra = _budget_overrun(checks, cfg)
    hit = (jnp.abs(dev) > bernstein_threshold(var, cfg.bernstein_a + extra)
           + slack * expected) \
        | (cusum_pos > bernstein_threshold(cusum_pos_var,
                                           cfg.bernstein_a_cusum + extra)
           + slack * cusum_pos_exp) \
        | (cusum_neg > bernstein_threshold(cusum_neg_var,
                                           cfg.bernstein_a_cusum + extra)
           + slack * cusum_neg_exp)
    fired = state.fired | (active & hit)
    return DriftState(seen=jnp.where(active, b, state.seen), dev=dev,
                      var=var, expected=expected, dev_recent=dev_recent,
                      exp_recent=exp_recent, cusum_pos=cusum_pos,
                      cusum_pos_var=cusum_pos_var,
                      cusum_pos_exp=cusum_pos_exp,
                      cusum_pos_seen=cusum_pos_seen, cusum_neg=cusum_neg,
                      cusum_neg_var=cusum_neg_var,
                      cusum_neg_exp=cusum_neg_exp,
                      cusum_neg_seen=cusum_neg_seen, checks=checks,
                      fired=fired)


def rho_hat(state: DriftState, cfg: DriftConfig) -> jax.Array:
    """(M,) rate-multiplier estimate for the re-planner.

    The re-planner's suffix laws are parametrized by the *instantaneous*
    observed/expected ratio (the drifted weight cancels out of the
    conditioned write law — see ``replan._w_suffix``), so the primary
    estimate is the short decayed recent window. When that window carries
    too little expected mass to be informative (tiny K, sparse chunks)
    the active CUSUM excursion's average ratio stands in. Clipped to the
    configured range."""
    recent = ((state.exp_recent + state.dev_recent)
              / jnp.maximum(state.exp_recent, 1e-6))
    pos_r = 1.0 + state.cusum_pos / jnp.maximum(state.cusum_pos_exp, 1e-6)
    neg_r = 1.0 - state.cusum_neg / jnp.maximum(state.cusum_neg_exp, 1e-6)
    s_pos = state.cusum_pos / jnp.sqrt(jnp.maximum(state.cusum_pos_var,
                                                   1.0))
    s_neg = state.cusum_neg / jnp.sqrt(jnp.maximum(state.cusum_neg_var,
                                                   1.0))
    exc = jnp.where(s_pos >= s_neg, pos_r, neg_r)
    exc = jnp.where(jnp.maximum(s_pos, s_neg) >= 1.0, exc, 1.0)
    rho = jnp.where(state.exp_recent >= 3.0, recent, exc)
    return jnp.clip(rho, cfg.rho_min, cfg.rho_max)


def anchor_seen(state: DriftState) -> jax.Array:
    """(M,) estimated drift-onset position: the dominant excursion's
    anchor (docs seen when it left zero), falling back to the current
    position when neither excursion carries signal. Diagnostic: the
    suffix laws themselves are anchor-free (the instantaneous ratio is a
    sufficient statistic for the conditioned write law)."""
    s_pos = state.cusum_pos / jnp.sqrt(jnp.maximum(state.cusum_pos_var,
                                                   1.0))
    s_neg = state.cusum_neg / jnp.sqrt(jnp.maximum(state.cusum_neg_var,
                                                   1.0))
    anchor = jnp.where(s_pos >= s_neg, state.cusum_pos_seen,
                       state.cusum_neg_seen)
    return jnp.where(jnp.maximum(s_pos, s_neg) >= 1.0, anchor, state.seen)


def scores(state: DriftState, cfg: DriftConfig,
           slack: float = 0.0) -> jax.Array:
    """(M,) normalized change score: the largest of the three test
    statistics over its own threshold — >= 1 means the stream has (or
    would have) fired. ``slack`` widens the thresholds exactly as in
    ``update`` (approximate-backend law tolerance)."""
    extra = _budget_overrun(state.checks, cfg)
    whole = jnp.abs(state.dev) / jnp.maximum(
        bernstein_threshold(state.var, cfg.bernstein_a + extra)
        + slack * state.expected, 1e-9)
    pos = state.cusum_pos / jnp.maximum(
        bernstein_threshold(state.cusum_pos_var,
                            cfg.bernstein_a_cusum + extra)
        + slack * state.cusum_pos_exp, 1e-9)
    neg = state.cusum_neg / jnp.maximum(
        bernstein_threshold(state.cusum_neg_var,
                            cfg.bernstein_a_cusum + extra)
        + slack * state.cusum_neg_exp, 1e-9)
    return jnp.maximum(whole, jnp.maximum(pos, neg))


def reset_where(state: DriftState, mask) -> DriftState:
    """Restart the sequential statistics of the masked streams (after a
    re-plan consumed their evidence); ``seen`` is preserved — the law's
    prefix keeps growing."""
    mask = jnp.asarray(mask, bool)
    z = jnp.zeros_like(state.dev)

    def keep(old, fresh):
        return jnp.where(mask, fresh, old)

    return DriftState(
        seen=state.seen, dev=keep(state.dev, z), var=keep(state.var, z),
        expected=keep(state.expected, z),
        dev_recent=keep(state.dev_recent, z),
        exp_recent=keep(state.exp_recent, z),
        cusum_pos=keep(state.cusum_pos, z),
        cusum_pos_var=keep(state.cusum_pos_var, z),
        cusum_pos_exp=keep(state.cusum_pos_exp, z),
        cusum_pos_seen=keep(state.cusum_pos_seen, z),
        cusum_neg=keep(state.cusum_neg, z),
        cusum_neg_var=keep(state.cusum_neg_var, z),
        cusum_neg_exp=keep(state.cusum_neg_exp, z),
        cusum_neg_seen=keep(state.cusum_neg_seen, z),
        checks=keep(state.checks, jnp.zeros_like(state.checks)),
        fired=keep(state.fired, jnp.zeros_like(state.fired)))


class DriftEstimator:
    """Host-side convenience wrapper: owns a ``DriftState`` and a jitted
    update for one (M,) fleet slice (the engine embeds the pure
    ``update`` inside its own multi-bucket step instead)."""

    def __init__(self, m: int, k, cfg: DriftConfig | None = None):
        self.cfg = cfg if cfg is not None else DriftConfig()
        self.k = jnp.asarray(np.broadcast_to(np.asarray(k), (m,)),
                             jnp.float32)
        self.state = init(m)
        self._update = jax.jit(
            lambda st, w, s: update(st, w, s, self.k, self.cfg))

    def observe(self, wrote_count, seen_after) -> np.ndarray:
        """Feed one chunk; returns the (M,) latched detection flags."""
        self.state = self._update(self.state, jnp.asarray(wrote_count),
                                  jnp.asarray(seen_after))
        return np.asarray(self.state.fired)

    def rho_hat(self) -> np.ndarray:
        return np.asarray(rho_hat(self.state, self.cfg))

    def reset(self, mask) -> None:
        self.state = reset_where(self.state, jnp.asarray(mask))
