from . import adamw, schedules  # noqa: F401
from .adamw import AdamWState  # noqa: F401
