"""AdamW as a plain pytree transformation.

Moments are fp32 regardless of param dtype. Because params are sharded
FSDP×TP (parallel/sharding.py), the moments inherit the same sharding —
optimizer state is fully distributed (ZeRO-style) with no extra machinery.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def apply(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    step = state.step + 1
    if grad_clip and grad_clip > 0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.zeros(())
        scale = 1.0

    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return p_new, AdamWState(step=step, m=m_new, v=v_new), gnorm
