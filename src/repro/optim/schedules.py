"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr, warmup_steps, total_steps, min_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr, warmup_steps, total_steps, decay_frac=0.1,
        min_ratio=0.0):
    """Warmup-stable-decay."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total_steps * (1 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    t = jnp.clip((step - decay_start) / jnp.maximum(total_steps - decay_start, 1),
                 0.0, 1.0)
    dec = peak_lr * (1 - (1 - min_ratio) * t)
    out = jnp.where(step < warmup_steps, warm, peak_lr)
    return jnp.where(step > decay_start, dec, out)
