"""Device-resident batched N-tier constrained planner: the jit/vmap port
of ``core.shp``'s candidate-grid solver.

``shp.plan_ntier_arrays_numpy`` minimizes the separable boundary
objective per tier subset with host-side NumPy: a Python loop over the
2^T subsets, per-subset candidate grids, and a chunked ``itertools``
enumeration for the constrained joint solve. This module materializes
the same finite candidate structure as dense per-subset tensors and
evaluates objective terms, feasibility masks, and the joint argmin in
one jitted XLA program per (T, constraint-signature) key. The heavy
constrained reduction is ``kernels.plan_solve``: a Pallas kernel
(compiled on TPU, 2-D grid over M × subset blocks) or its jnp
reference (fused by XLA elsewhere); unconstrained subsets run the same
monotone running-minimum DP the host uses.

Structure of the port (all decisions the host makes by looking at the
data become *static jit keys* computed on the host before tracing):

* ``capfin`` (per-tier any-finite-capacity) and ``slo_any`` replicate
  the ``np.any``-gates of ``BoundaryObjective.candidates`` /
  ``pair_lower_bound`` / ``budget_deltas``, so the device candidate
  grid has exactly the host's columns and the DP-vs-enumeration
  dispatch is decided per subset exactly as the host decides it.
* candidate columns are *pooled per family*: a crossover, capacity
  corner, or SLO-tight point depends only on the global tier pair, so
  W(b) — the expensive log — is evaluated once per distinct column and
  carried through a vectorized odd-even sorting network into each
  subset's sorted grid (XLA's comparator sort is serial on CPU).
* consecutive subsets with one structural signature stack on an S axis
  and reduce in a single fused pass, preserving the host's
  first-minimum-wins precedence (strict-< running minima in subset
  order: no-migration subsets ascending by size, then cascades).

Float64 / x64 policy (documented in the README): the solver computes
in float64 via the scoped ``jax.experimental.enable_x64`` context
(CPU/GPU default), matching the NumPy oracle to a few ulps — the
residual divergence is transcendental (``log``) codegen and XLA fma
contraction, bounded by ~1e-12 relative on totals; the property tests
pin this. On TPU (or with ``precision="float32"``) the solver runs
float32 — Pallas TPU has no f64 — and matches the oracle only to
float32 tolerance (near-ties may pick a different, equal-cost plan).
"""
from __future__ import annotations

import functools
import itertools

import numpy as np

try:  # keep `core.shp` importable without jax (the NumPy oracle stands)
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only without jax
    _HAVE_JAX = False

from . import constraints as constraints_mod

MAX_DEVICE_TIERS = 4  # the exact joint enumeration (shp._ENUM_MAX_STEPS + 1)
_MIN_PAD = 8  # M is padded to a power of two >= this (bounds jit cache)
_TOL = 1.0 + 1e-12

# Shipped defaults (see the module docstring's float64/x64 policy).
# Unconstrained solves default to float32: measured against the f64
# oracle, the f32 plans are optimal to ~1e-8 relative (only the
# *reported* totals carry float32 accuracy, ~1e-4) and the solve is
# memory-bound, so halving the traffic matters. Constrained solves
# default to float64: float32's catastrophic cancellation in crossover
# candidates near binding capacities/SLOs mis-places plans by up to
# tens of percent and breaks the 1e-9 occupancy-tolerance contracts, so
# f32 is opt-in there (and the TPU default, where Pallas has no f64).
DEFAULT_PRECISION_UNCONSTRAINED = "float32"
DEFAULT_PRECISION_CONSTRAINED = "float64"
_WORKERS = 2  # chunk-parallel host threads (each core streams its own L2)
_POOL = None


def _executor():
    global _POOL
    if _POOL is None:
        from concurrent.futures import ThreadPoolExecutor
        _POOL = ThreadPoolExecutor(_WORKERS)
    return _POOL


class DeviceSolverUnavailable(RuntimeError):
    """Raised when the device solver cannot take this problem (no jax,
    or a hierarchy deeper than the exact enumeration supports) — the
    caller falls back to the NumPy oracle."""


@functools.lru_cache(maxsize=None)
def _groups(t: int):
    """Subset groups in the host solver's precedence order. Each entry is
    (interior, ts, subsets): the no-migration subsets ascending by size,
    then the migration cascades (all ending at tier t-1)."""
    nm = tuple((False, ts, tuple(itertools.combinations(range(t), ts)))
               for ts in range(1, t + 1))
    mg = tuple((True, size + 1,
                tuple(s + (t - 1,)
                      for s in itertools.combinations(range(t - 1), size)))
               for size in range(1, t))
    return nm + mg


@functools.lru_cache(maxsize=None)
def _mid_triples(t: int):
    """Distinct (prev, mid, next) consecutive-tier triples across the
    no-migration subsets — the middle-capacity stationary columns are
    the only candidate columns owned by a triple rather than a pair."""
    seen, out = set(), []
    for interior, ts, subs in _groups(t):
        if interior or ts < 3:
            continue
        for sa in subs:
            for i in range(1, ts - 1):
                tri = (sa[i - 1], sa[i], sa[i + 1])
                if tri not in seen:
                    seen.add(tri)
                    out.append(tri)
    return tuple(out)


# ---------------------------------------------------------------------------
# Traced mirrors of BoundaryObjective's candidate/term/feasibility laws
# ---------------------------------------------------------------------------

def w_approx(b, k):
    """Traced ``shp._w_approx``: W(b) = b below K, K(1 + ln(b/K)) above."""
    safe = jnp.maximum(b, jnp.finfo(b.dtype).tiny)
    return jnp.where(b <= k, b, k * (1.0 + jnp.log(safe / k)))


@functools.lru_cache(maxsize=None)
def _batcher_pairs(n: int):
    """Batcher odd-even mergesort comparator network for n columns
    (virtual +inf tail elements filtered out — they never swap down, so
    dropping their comparators leaves the first n sorted)."""
    if n < 2:
        return ()
    p2 = 1 << (n - 1).bit_length()
    pairs = []
    p = 1
    while p < p2:
        k = p
        while k >= 1:
            for j in range(k % p, p2 - k, 2 * k):
                for i in range(0, min(k, p2 - j - k)):
                    if (i + j) // (p * 2) == (i + j + k) // (p * 2):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return tuple((a, b) for a, b in pairs if b < n)


def sort_network(col_lists):
    """Sort candidate columns ascending by the first list's values via a
    Batcher odd-even merge network, applying the same exchanges to every
    companion list, and stack the results into (M, C) grids — XLA's
    comparator sort is serial on CPU and dominated the solve; the
    network's vectorized selects fuse, and the sorted values are the
    identical multiset (no NaNs by construction)."""
    lists = [list(cols) for cols in col_lists]
    keys = lists[0]
    for a, b in _batcher_pairs(len(keys)):
        keep = keys[a] <= keys[b]
        for cols in lists:
            x, y = cols[a], cols[b]
            cols[a] = jnp.where(keep, x, y)
            cols[b] = jnp.where(keep, y, x)
    return [jnp.stack(cols, axis=1) for cols in lists]


def crossover_cols(cw_s, lin_s, kf, lo, hi):
    """Traced ``shp._crossover_candidates``: the eq. 17/21-style pairwise
    stationary points, one column per tier pair, clipped into [lo, hi]."""
    out = []
    ts = cw_s.shape[1]
    for s, t in itertools.combinations(range(ts), 2):
        b = kf * (cw_s[:, s] - cw_s[:, t]) / (lin_s[:, t] - lin_s[:, s])
        b = jnp.where(jnp.isfinite(b), b, 0.0)
        out.append(jnp.clip(b, lo, hi))
    return out


def mid_cap_cols(cw_p, cw_m, cw_n, lin_p, lin_m, lin_n, cap_m, kf, lo, hi):
    """Traced ``BoundaryObjective._middle_cap_stationary`` for one
    (prev, mid, next) tier triple: 4 columns (log/mixed branch × the
    γ-image), sanitized to ``lo`` where the capacity curve is inactive."""
    active = jnp.isfinite(cap_m) & (cap_m < kf)
    gamma = 1.0 - cap_m / kf
    dcw_p, dcw_d = cw_p - cw_m, cw_m - cw_n
    dlin_p, dlin_d = lin_p - lin_m, lin_m - lin_n
    b_log = -kf * (dcw_p + dcw_d) / (gamma * dlin_p + dlin_d)
    b_mix = -kf * dcw_d / (gamma * (dcw_p + dlin_p) + dlin_d)
    out = []
    for b in (b_log, b_mix):
        b = jnp.where(active & jnp.isfinite(b) & (b > 0), b, 0.0)
        out.append(jnp.clip(b, lo, hi))
        out.append(jnp.clip(b * jnp.where(active, gamma, 0.0), lo, hi))
    return out


def subset_feasible(m, ts, interior, kf, nf, cap_s, lat_s, slo):
    """Traced ``BoundaryObjective.subset_feasible``."""
    if cap_s is None:
        return jnp.ones((m,), bool)
    kmin = jnp.minimum(kf, nf)
    if ts == 1:
        return (kmin <= cap_s[:, 0] * _TOL) & (lat_s[:, 0] <= slo * _TOL)
    if interior:
        return (jnp.all(cap_s * _TOL >= kmin[:, None], axis=1)
                & (lat_s[:, -1] <= slo * _TOL))
    return jnp.ones((m,), bool)


# ---------------------------------------------------------------------------
# Per-family candidate pools
# ---------------------------------------------------------------------------

def _build_pool(t, interior, constrained, capfin, slo_any, cw, lin, cap,
                lat, slo, kf, nf, lo, hi):
    """One family's pooled candidate columns + their W values.

    Every candidate column the host generates per subset is owned by a
    global tier pair/tier/triple, so each distinct column — and the
    expensive W(log) on it — is computed once. Returns (pool (M, P),
    w_pool (M, P), {key: column index})."""
    cols, key_idx = [], {}

    def add(key, col):
        key_idx[key] = len(cols)
        cols.append(col)

    add(("b", 0), lo)
    add(("b", 1), jnp.minimum(kf, nf))
    add(("b", 2), hi)
    for u, v in itertools.combinations(range(t), 2):
        b = kf * (cw[:, u] - cw[:, v]) / (lin[:, v] - lin[:, u])
        b = jnp.where(jnp.isfinite(b), b, 0.0)
        add(("x", u, v), jnp.clip(b, lo, hi))
    if constrained:
        for j in range(t):
            if not capfin[j]:
                continue
            cap_j = cap[:, j]
            fin = jnp.isfinite(cap_j)
            add(("cap", j, 0), jnp.clip(jnp.where(fin, cap_j, 0.0), lo, hi))
            tight = nf * (1.0 - cap_j / kf)
            add(("cap", j, 1), jnp.clip(jnp.where(fin, tight, 0.0), lo, hi))
        if not interior and slo_any:
            for u, v in itertools.combinations(range(t), 2):
                dl = lat[:, u] - lat[:, v]
                b = nf * (slo - lat[:, v]) / dl
                b = jnp.where(jnp.isfinite(b), b, 0.0)
                add(("slo", u, v), jnp.clip(b, lo, hi))
        if not interior:
            for (p, md, nx) in _mid_triples(t):
                if not capfin[md]:
                    continue
                mids = mid_cap_cols(cw[:, p], cw[:, md], cw[:, nx],
                                    lin[:, p], lin[:, md], lin[:, nx],
                                    cap[:, md], kf, lo, hi)
                for q, col in enumerate(mids):
                    add(("mid", p, md, nx, q), col)
    return cols, [w_approx(col, kf) for col in cols], key_idx


def _subset_keys(sa, interior, constrained, capfin, slo_any):
    """The pool columns of one subset's candidate grid — the same
    columns, under the same any-finite gates, the host appends in
    ``BoundaryObjective.candidates``."""
    ts = len(sa)
    keys = [("b", 0), ("b", 1), ("b", 2)]
    keys += [("x", sa[s], sa[t])
             for s, t in itertools.combinations(range(ts), 2)]
    if constrained:
        for j in sa:
            if capfin[j]:
                keys += [("cap", j, 0), ("cap", j, 1)]
        if not interior and slo_any:
            keys += [("slo", sa[s], sa[t])
                     for s, t in itertools.combinations(range(ts), 2)]
        if not interior:
            for i in range(1, ts - 1):
                if capfin[sa[i]]:
                    keys += [("mid", sa[i - 1], sa[i], sa[i + 1], q)
                             for q in range(4)]
    return keys


# ---------------------------------------------------------------------------
# Group assembly + reduction
# ---------------------------------------------------------------------------

def decode_bounds(s_idx, sel, cand_stack, subs, nf, t):
    """Winning (subset row, candidate tuple) -> (M, t-1) full-topology
    boundary vectors: select the winner's grid, gather the boundary
    values, place the widths on the subset's real tier columns, rebuild
    by cumulative sum — the host's edges→widths→cumsum construction.
    Subset selection and width placement are static select chains (S
    and T are tiny; XLA CPU scatter/gather lower to scalar loops)."""
    m = s_idx.shape[0]
    dtype = cand_stack.dtype
    cand_sel = cand_stack[:, 0]
    for i in range(1, len(subs)):
        cand_sel = jnp.where((s_idx == i)[:, None], cand_stack[:, i],
                             cand_sel)
    bvec = jnp.take_along_axis(cand_sel, sel, axis=1)  # (M, J)
    edges = jnp.concatenate(
        [jnp.zeros((m, 1), dtype), bvec, nf[:, None]], axis=1)
    widths = jnp.diff(edges, axis=1)  # (M, ts)
    zero = jnp.zeros((m,), dtype)
    bounds = None
    for i, sa in enumerate(subs):
        wfull = [zero] * t
        for j, tier in enumerate(sa):
            wfull[tier] = wfull[tier] + widths[:, j]
        acc, cum = zero, []
        for tier in range(t - 1):
            acc = acc + wfull[tier]
            cum.append(acc)
        bi = jnp.stack(cum, axis=1)
        bounds = bi if bounds is None else jnp.where(
            (s_idx == i)[:, None], bi, bounds)
    return bounds


def _fold_cap_masks(f, c, j, ts, sa, sub_con, capfin, cap, kf, nf):
    """Fold the first/last-tier capacity masks into step ``j``'s terms
    as +inf on grid ``c`` — ``BoundaryObjective.terms``'s convention."""
    if sub_con and j == 1 and capfin[sa[0]]:
        ok = jnp.minimum(c, kf[:, None]) <= cap[:, sa[0]][:, None] * _TOL
        f = jnp.where(ok, f, jnp.inf)
    if sub_con and j == ts - 1 and capfin[sa[-1]]:
        occ = jnp.minimum(nf, kf)[:, None] * (1.0 - c / nf[:, None])
        ok = occ <= cap[:, sa[-1]][:, None] * _TOL
        f = jnp.where(ok, f, jnp.inf)
    return f


def _subset_grid(sa, interior, pool, w_pool, key_idx, constrained, capfin,
                 slo_any, cw, lin, cap, lat, slo, kf, nf, fold_masks,
                 sort=True):
    """One subset's candidate grid and per-step term grids ((M, C)
    arrays), masks folded as +inf when ``fold_masks`` (the host's
    ``terms`` convention) or kept as (M, C) bools for the Pallas path,
    plus enum metadata. ``sort=False`` skips the comparator network for
    solvers that enforce monotonicity as a value mask."""
    ts = len(sa)
    idxs = [key_idx[key]
            for key in _subset_keys(sa, interior, constrained, capfin,
                                    slo_any)]
    if sort:
        c, w = sort_network([[pool[i] for i in idxs],
                             [w_pool[i] for i in idxs]])
    else:
        c = jnp.stack([pool[i] for i in idxs], axis=1)
        w = jnp.stack([w_pool[i] for i in idxs], axis=1)
    sub_con = (constrained and not interior
               and (any(capfin[j] for j in sa) or slo_any))
    lb_pattern = tuple(constrained and not interior and capfin[sa[i]]
                       for i in range(1, ts - 1))
    budget = sub_con and slo_any
    mode = "enum" if (any(lb_pattern) or budget) else "dp"
    fs, masks = [], []
    for j in range(1, ts):
        u, v = sa[j - 1], sa[j]
        f = ((cw[:, u] - cw[:, v])[:, None] * w
             + (lin[:, u] - lin[:, v])[:, None] * c)
        mk = None
        if sub_con and j == 1 and capfin[sa[0]]:
            mk = jnp.minimum(c, kf[:, None]) <= cap[:, sa[0]][:, None] * _TOL
        if sub_con and j == ts - 1 and capfin[sa[-1]]:
            occ = jnp.minimum(nf, kf)[:, None] * (1.0 - c / nf[:, None])
            l_ok = occ <= cap[:, sa[-1]][:, None] * _TOL
            mk = l_ok if mk is None else mk & l_ok
        if mk is not None and fold_masks:
            f = jnp.where(mk, f, jnp.inf)
            mk = None
        fs.append(f)
        masks.append(mk)
    out = {"sa": sa, "cand": c, "fs": fs, "masks": masks, "mode": mode,
           "lb_pattern": lb_pattern, "budget": budget}
    if budget:
        cmax = jnp.max(c, axis=1)
        alphas, scale = [], None
        for j in range(1, ts):
            al = (lat[:, sa[j - 1]] - lat[:, sa[j]]) / nf
            alphas.append(al)
            sc = jnp.abs(cmax * al)
            scale = sc if scale is None else scale + sc
        rhs = slo - lat[:, sa[-1]]
        out.update(alpha=alphas, rhs=rhs,
                   atol=1e-9 * (jnp.abs(rhs) + scale) + 1e-15)
    return out


def _subset_bounds_cols(sa, t, bvec_cols, nf):
    """Full-topology boundary columns from one subset's chosen boundary
    values — the host's edges→widths→cumsum, as static column sums."""
    zero = jnp.zeros_like(nf)
    edges = [zero] + list(bvec_cols) + [nf]
    widths = [edges[j + 1] - edges[j] for j in range(len(sa))]
    wfull = [zero] * t
    for j, tier in enumerate(sa):
        wfull[tier] = wfull[tier] + widths[j]
    acc, cum = zero, []
    for tier in range(t - 1):
        acc = acc + wfull[tier]
        cum.append(acc)
    return cum


def _plan_impl(cw, cr, cs, n, k, rpw, cap, lat, slo, *, t, constrained,
               capfin, slo_any, use_pallas):
    from repro.kernels.plan_solve import ops as solve_ops
    from repro.kernels.plan_solve import ref as solve_ref
    m = cw.shape[0]
    dtype = cw.dtype
    kf, nf = k, n
    w_n = w_approx(n, k)
    lin_nm = (rpw * k / n)[:, None] * cr
    lin_mg = (k / n)[:, None] * cs
    pools = {}
    for interior in (False, True):
        lin = lin_mg if interior else lin_nm
        lo = jnp.minimum(kf, nf) if interior else jnp.zeros_like(nf)
        hi = jnp.nextafter(nf, jnp.zeros_like(nf)) if interior else nf
        pools[interior] = _build_pool(
            t, interior, constrained, capfin, slo_any, cw, lin, cap, lat,
            slo, kf, nf, lo, hi) + (lin,)

    # every subset contributes (total, bounds columns, static mig flag);
    # the cross-subset winner is one first-minimum argmin at the end,
    # which preserves the host loop's strict-< precedence because
    # candidates are appended in the host's subset order
    cand_totals, cand_bounds, cand_mig = [], [], []

    def fold(val, bounds_cols, interior):
        cand_totals.append(val)
        cand_bounds.append(bounds_cols)
        cand_mig.append(interior)

    def subset_consts(sa, interior, lin):
        ts = len(sa)
        sl = list(sa)
        cap_s = cap[:, sl] if constrained else None
        lat_s = lat[:, sl] if constrained else None
        ok = subset_feasible(m, ts, interior, kf, nf, cap_s, lat_s, slo)
        a = w_n * (cw[:, -1] if interior else cw[:, sa[-1]])
        b = nf * lin[:, -1] if interior else nf * lin[:, sa[-1]]
        if interior:
            fee = jnp.zeros_like(nf)
            for u, v in zip(sa, sa[1:]):
                fee = fee + cr[:, u] + cw[:, v]
            cc = kf * fee
        else:
            cc = kf * jnp.max(cs[:, sl], axis=1)
        return jnp.where(ok, a, jnp.inf), b, cc

    for interior, ts, subs in _groups(t):
        pool, w_pool, key_idx, lin = pools[interior]
        if ts == 1:
            for sa in subs:
                a, b, cc = subset_consts(sa, interior, lin)
                bounds_cols = [nf if j >= sa[0] else jnp.zeros((m,), dtype)
                               for j in range(t - 1)]
                fold(((a + b) + cc), bounds_cols, interior)
            continue
        if use_pallas:
            _pallas_group(solve_ops, subs, ts, interior, pool, w_pool,
                          key_idx, constrained, capfin, slo_any, cw, lin,
                          cap, lat, slo, kf, nf, m, t, dtype, fold,
                          subset_consts)
            continue
        for sa in subs:
            a, b, cc = subset_consts(sa, interior, lin)
            if ts < 4:
                # exact solve on the subset's own grid, unsorted: J=1 is
                # a plain masked minimum, J=2 enumerates (origin ≤
                # destination) value pairs — both cover the host's DP
                # *and* constrained-enum dispatch outcomes exactly
                sub = _subset_grid(sa, interior, pool, w_pool, key_idx,
                                   constrained, capfin, slo_any, cw, lin,
                                   cap, lat, slo, kf, nf, True, sort=False)
                cand = sub["cand"]
                kw = {}
                if sub["budget"]:
                    kw = dict(alpha=sub["alpha"], rhs=sub["rhs"],
                              atol=sub["atol"])
                if ts == 2:
                    interior_val, bvec = solve_ref.single_arr(
                        sub["fs"][0], cand, **kw)
                else:
                    if sub["lb_pattern"][0]:
                        kw.update(kf=kf, cap_m=cap[:, sa[1]])
                    interior_val, bvec = solve_ref.tri_arr(
                        sub["fs"][0], sub["fs"][1], cand, **kw)
            else:  # ts == 4: sorted grid (DP or gathered enumeration)
                sub = _subset_grid(sa, interior, pool, w_pool, key_idx,
                                   constrained, capfin, slo_any, cw, lin,
                                   cap, lat, slo, kf, nf, True)
                cand = sub["cand"]
                if sub["mode"] == "dp":
                    interior_val, sel = solve_ref.dp_arr(sub["fs"])
                else:
                    fs4 = jnp.stack(sub["fs"], 1)[:, None]
                    kw4 = {}
                    if any(sub["lb_pattern"]):
                        kw4["pair_caps"] = [
                            cap[:, sa[j]][:, None]
                            if sub["lb_pattern"][j - 1] else None
                            for j in range(1, ts - 1)]
                        kw4["kf"] = kf
                    if sub["budget"]:
                        kw4.update(
                            alpha=jnp.stack(sub["alpha"], 1)[:, None],
                            rhs=sub["rhs"][:, None],
                            atol=sub["atol"][:, None])
                    interior_val, _, selm = solve_ref.enum_solve(
                        fs4, (jnp.zeros((m, 1), dtype),),
                        solve_ops.monotone_combos(cand.shape[1], ts - 1),
                        cand=cand[:, None], **kw4)
                    sel = [selm[:, j] for j in range(ts - 1)]
                bvec = [solve_ref.pick_col(cand, sj) for sj in sel]
            total = ((interior_val + a) + b) + cc
            fold(total, _subset_bounds_cols(sa, t, bvec, nf), interior)
    best_val, s_idx = solve_ref.first_argmin(jnp.stack(cand_totals, axis=1))
    best_bounds = []
    for j in range(t - 1):
        col = cand_bounds[0][j]
        for i in range(1, len(cand_bounds)):
            col = jnp.where(s_idx == i, cand_bounds[i][j], col)
        best_bounds.append(col)
    # no-migration subsets all precede the cascades, so the migrate flag
    # is one index compare (gathers are scalar loops on CPU)
    first_mig = (cand_mig.index(True) if True in cand_mig
                 else len(cand_mig))
    best_mig = (s_idx >= first_mig) & jnp.isfinite(best_val)
    return best_val, jnp.stack(best_bounds, axis=1), best_mig


def _pallas_group(solve_ops, subs, ts, interior, pool, w_pool, key_idx,
                  constrained, capfin, slo_any, cw, lin, cap, lat, slo,
                  kf, nf, m, t, dtype, fold, subset_consts):
    """TPU path: stack one (family, size) group's subsets — candidate
    grids padded to the group max by duplicating each subset's lowest
    column (value, term AND mask), which keeps grids sorted and cannot
    introduce a tuple the unpadded grid lacked — and reduce with the
    fused Pallas kernel (2-D grid over M × subset blocks, running
    first-minimum argmin)."""
    entries = []
    for sa in subs:
        sub = _subset_grid(sa, interior, pool, w_pool, key_idx,
                           constrained, capfin, slo_any, cw, lin, cap,
                           lat, slo, kf, nf, False)
        sub["consts"] = subset_consts(sa, interior, lin)
        entries.append(sub)
    cmax = max(e["cand"].shape[1] for e in entries)

    def pad_front(x, npad):
        return jnp.concatenate(
            [jnp.repeat(x[:, :1], npad, axis=1), x], axis=1) if npad else x

    for e in entries:
        npad = cmax - e["cand"].shape[1]
        e["cand"] = pad_front(e["cand"], npad)
        e["fs"] = [pad_front(f, npad) for f in e["fs"]]
        e["masks"] = [None if mk is None else pad_front(mk, npad)
                      for mk in e["masks"]]
    fs = jnp.stack([jnp.stack(e["fs"], 1) for e in entries], 1)
    cand = jnp.stack([e["cand"] for e in entries], 1)
    consts = tuple(jnp.stack([e["consts"][p] for e in entries], 1)
                   for p in range(3))
    kw = {}
    if constrained and not interior:
        if ts > 2 and any(any(e["lb_pattern"]) for e in entries):
            kw["pair_caps"] = [
                jnp.stack([cap[:, e["sa"][j]] if e["lb_pattern"][j - 1]
                           else jnp.full((m,), jnp.inf, dtype)
                           for e in entries], 1)
                for j in range(1, ts - 1)]
            kw["kf"] = kf
        if slo_any:
            kw["alpha"] = jnp.stack(
                [jnp.stack(e["alpha"], 1) for e in entries], 1)
            kw["rhs"] = jnp.stack([e["rhs"] for e in entries], 1)
            kw["atol"] = jnp.stack([e["atol"] for e in entries], 1)
        ones = jnp.ones((m, cmax), bool)
        kw["masks"] = [
            jnp.stack([ones if e["masks"][j] is None else e["masks"][j]
                       for e in entries], 1)
            for j in range(ts - 1)]
    val, s_idx, sel = solve_ops.enum_solve(fs, consts, cand=cand,
                                           use_pallas=True, **kw)
    bounds = decode_bounds(s_idx, sel, cand, [e["sa"] for e in entries],
                           nf, t)
    fold(val, [bounds[:, j] for j in range(t - 1)], interior)


@functools.partial(jax.jit if _HAVE_JAX else lambda f, **kw: f,
                   static_argnames=("t", "constrained", "capfin",
                                    "slo_any", "use_pallas"))
def _plan_jit(cw, cr, cs, n, k, rpw, cap, lat, slo, *, t, constrained,
              capfin, slo_any, use_pallas):
    return _plan_impl(cw, cr, cs, n, k, rpw, cap, lat, slo, t=t,
                      constrained=constrained, capfin=capfin,
                      slo_any=slo_any, use_pallas=use_pallas)


def _pad_pow2(m: int) -> int:
    return 1 << max(m - 1, _MIN_PAD - 1).bit_length()


_CHUNK_M = 8192  # fleet chunk: keeps every (chunk,) intermediate in L2
# — the solve is elementwise over streams, and on CPU the unchunked
# 64k-row program ran ~2× slower purely on cache misses


def plan_ntier_arrays_jax(cw, cr, cs, n, k, rpw, *, cap=None, lat=None,
                          slo=None, force_constrained=False,
                          precision=None, use_pallas=None):
    """Device-resident ``shp.plan_ntier_arrays``: same contract, same
    returns, one jitted program per (T, constraint-signature, padded-M)
    key.

    ``precision``: "float64" (default off-TPU; scoped x64,
    oracle-matching to ~1e-12 relative) or "float32" (TPU default —
    Pallas has no f64). ``use_pallas`` defaults to compiled-TPU only;
    elsewhere the jnp reference reduction runs (one fused XLA program,
    no interpret overhead).

    Raises ``DeviceSolverUnavailable`` for hierarchies the exact joint
    enumeration does not cover (T > 4) — callers fall back to the
    NumPy oracle.
    """
    if not _HAVE_JAX:
        raise DeviceSolverUnavailable("jax is not importable")
    cw = np.asarray(cw, np.float64)
    m, t = cw.shape
    if not 2 <= t <= MAX_DEVICE_TIERS:
        raise DeviceSolverUnavailable(
            f"device solver covers 2..{MAX_DEVICE_TIERS} tiers, got {t}")
    if m == 0:
        return {"total": np.zeros(0), "bounds": np.zeros((0, t - 1)),
                "migrate": np.zeros(0, bool)}
    constrained = bool(force_constrained
                       or not constraints_mod.trivial(cap, slo))
    cap_h = (np.full((m, t), np.inf) if cap is None
             else np.asarray(cap, np.float64))
    lat_h = np.zeros((m, t)) if lat is None else np.asarray(lat, np.float64)
    slo_h = (np.full(m, np.inf) if slo is None
             else np.asarray(slo, np.float64))
    # the host's np.any data gates, lifted to static jit keys
    capfin = tuple(bool(np.any(np.isfinite(cap_h[:, j]))) for j in range(t))
    slo_any = bool(np.any(np.isfinite(slo_h)))
    if use_pallas is None:
        from repro.kernels.plan_solve import ops as solve_ops
        use_pallas = solve_ops.on_tpu()
    if precision is None:
        from repro.kernels.plan_solve import ops as solve_ops
        precision = ("float32" if solve_ops.on_tpu()
                     else (DEFAULT_PRECISION_CONSTRAINED if constrained
                           else DEFAULT_PRECISION_UNCONSTRAINED))
    np_dtype = np.float64 if precision == "float64" else np.float32
    chunk = min(_pad_pow2(m), _CHUNK_M)

    args = [np.asarray(x, np_dtype).reshape(m, t) for x in (cw, cr, cs)]
    args += [np.asarray(x, np_dtype).reshape(m) for x in (n, k, rpw)]
    args += [cap_h.astype(np_dtype, copy=False),
             lat_h.astype(np_dtype, copy=False),
             slo_h.astype(np_dtype, copy=False)]

    # active fleet mesh (parallel.fleet): shard the M axis across devices
    # and run the solve per shard — replaces the L2-chunk host thread
    # fan-out below, which stays the single-device fallback
    from repro.parallel import fleet as fleet_mod
    mesh = fleet_mod.get_fleet_mesh()
    if mesh is not None and fleet_mod.n_shards(mesh) > 1:
        return _plan_sharded(args, m, t, mesh, constrained, capfin,
                             slo_any, use_pallas, precision)

    def _chunk_args(lo_i):
        hi_i = min(lo_i + chunk, m)
        part = [a[lo_i:hi_i] for a in args]
        if hi_i - lo_i < chunk:  # pad the tail chunk only (rows ignored)
            part = [np.concatenate(
                [p, np.broadcast_to(p[:1],
                                    (chunk - (hi_i - lo_i),) + p.shape[1:])])
                for p in part]
        return part

    # jit-cache probe (repro.obs.jits): one compiled signature per
    # (T, constraint-signature, padded-M) key — the probe makes compile
    # storms (a signature varying call-to-call) visible as miss counts
    from repro.obs import jits as obs_jits
    _probe = obs_jits.probe("shp_jax.plan")
    _key = (t, constrained, capfin, slo_any, use_pallas, chunk, precision)

    def _solve(lo_i):
        with enable_x64(precision == "float64"):
            out = _probe.track(_plan_jit, *_chunk_args(lo_i), key=_key,
                               t=t, constrained=constrained, capfin=capfin,
                               slo_any=slo_any, use_pallas=use_pallas)
            return [np.asarray(o) for o in out]

    starts = list(range(0, m, chunk))
    if len(starts) > 1:
        outs = list(_executor().map(_solve, starts))
    else:
        outs = [_solve(starts[0])]
    val, bounds, mig = (np.concatenate([o[i] for o in outs])
                        for i in range(3))
    total = np.asarray(val, np.float64)[:m]
    bounds = np.asarray(bounds, np.float64)[:m]
    mig = np.asarray(mig)[:m]
    feas = np.isfinite(total)
    return {"total": total,
            "bounds": np.where(feas[:, None], bounds, 0.0),
            "migrate": mig & feas}


# ---------------------------------------------------------------------------
# Fleet-mesh dispatch: shard_map the M axis instead of thread fan-out
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _plan_sharded_fn(mesh, t, constrained, capfin, slo_any, use_pallas):
    """One jitted ``shard_map`` of ``_plan_impl`` per (mesh, static-key):
    every input splits row-wise along the fleet axis and each shard runs
    the identical single-device program on its rows — no collectives, so
    sharded plans are bit-identical to the fallback path's."""
    from repro.parallel import fleet as fleet_mod
    fn = functools.partial(_plan_impl, t=t, constrained=constrained,
                           capfin=capfin, slo_any=slo_any,
                           use_pallas=use_pallas)
    spec = fleet_mod.row_spec()
    return jax.jit(fleet_mod.shard_map(
        fn, mesh=mesh, in_specs=(spec,) * 9,
        out_specs=(spec, spec, spec), check_rep=False))


def _plan_sharded(args, m, t, mesh, constrained, capfin, slo_any,
                  use_pallas, precision):
    """Mesh path of ``plan_ntier_arrays_jax``: pad M to shards × a
    power-of-two per-shard block (bounding the jit cache exactly like
    the chunked path), stage the inputs row-sharded, and solve all
    shards in one XLA dispatch."""
    from repro.obs import jits as obs_jits
    from repro.parallel import fleet as fleet_mod
    shards = fleet_mod.n_shards(mesh)
    per = _pad_pow2(-(-m // shards))
    mp = per * shards

    def _padr(a):
        if mp > m:
            a = np.concatenate(
                [a, np.broadcast_to(a[:1], (mp - m,) + a.shape[1:])])
        return a

    fn = _plan_sharded_fn(mesh, t, constrained, capfin, slo_any,
                          use_pallas)
    probe = obs_jits.probe("shp_jax.plan_sharded")
    key = (obs_jits.mesh_key(mesh), t, constrained, capfin, slo_any,
           use_pallas, per, precision)
    sh = fleet_mod.row_sharding(mesh)
    with enable_x64(precision == "float64"):
        dev = [jax.device_put(_padr(a), sh) for a in args]
        out = probe.track(fn, *dev, key=key)
        val, bounds, mig = (np.asarray(o) for o in out)
    total = np.asarray(val, np.float64)[:m]
    bounds = np.asarray(bounds, np.float64)[:m]
    mig = np.asarray(mig)[:m]
    feas = np.isfinite(total)
    return {"total": total,
            "bounds": np.where(feas[:, None], bounds, 0.0),
            "migrate": mig & feas}
