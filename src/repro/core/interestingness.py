"""Interestingness functions (paper §IV, §VIII).

The paper requires a cheap online scorer H(d) inducing a ranking; in the
training/serving integration the natural scorers are per-example loss,
predictive entropy (the paper's §VIII uses normalized label entropy of an
SVM), and margin. All scorers map (logits, labels, mask) → (batch,) float32.

The entropy/NLL scorers delegate to the fused Pallas kernel
(`repro.kernels.entropy_scores`) when available, falling back to the pure-jnp
reference — identical semantics, validated in tests.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

Scorer = Callable[..., jax.Array]


def _masked_mean(x: jax.Array, mask: Optional[jax.Array], axis) -> jax.Array:
    if mask is None:
        return jnp.mean(x, axis=axis)
    mask = mask.astype(x.dtype)
    return jnp.sum(x * mask, axis=axis) / jnp.maximum(jnp.sum(mask, axis=axis), 1.0)


def nll_score(logits: jax.Array, labels: jax.Array,
              mask: Optional[jax.Array] = None, use_kernel: bool = True) -> jax.Array:
    """Mean per-token negative log-likelihood per example.

    logits: (B, S, V) — labels: (B, S) int — mask: (B, S) optional.
    Hard examples (high loss) rank as most interesting.
    """
    ent, nll = _entropy_nll(logits, labels, use_kernel)
    return _masked_mean(nll, mask, axis=-1)


def entropy_score(logits: jax.Array, labels: Optional[jax.Array] = None,
                  mask: Optional[jax.Array] = None, use_kernel: bool = True) -> jax.Array:
    """Mean predictive entropy per example — the paper's §VIII scorer
    (uncertain predictions are the interesting ones for HITL reanalysis)."""
    if labels is None:
        labels = jnp.zeros(logits.shape[:-1], dtype=jnp.int32)
    ent, _ = _entropy_nll(logits, labels, use_kernel)
    return _masked_mean(ent, mask, axis=-1)


def margin_score(logits: jax.Array, labels: Optional[jax.Array] = None,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Negative top-1/top-2 margin: small margin = uncertain = interesting."""
    top2 = jax.lax.top_k(logits.astype(jnp.float32), 2)[0]
    margin = top2[..., 0] - top2[..., 1]
    return -_masked_mean(margin, mask, axis=-1)


def random_score(key: jax.Array, batch: int) -> jax.Array:
    """Random ranking — the control matching the classic SHP assumption."""
    return jax.random.uniform(key, (batch,), dtype=jnp.float32)


def _entropy_nll(logits: jax.Array, labels: jax.Array, use_kernel: bool):
    """(entropy, nll) per position, shape = labels.shape."""
    if use_kernel:
        try:
            from repro.kernels.entropy_scores import ops as _ops
            b = logits.shape[:-1]
            v = logits.shape[-1]
            ent, nll = _ops.entropy_nll(logits.reshape(-1, v), labels.reshape(-1))
            return ent.reshape(b), nll.reshape(b)
        except Exception:
            pass
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    logp = logits - lse[..., None]
    p = jnp.exp(logp)
    ent = -jnp.sum(p * logp, axis=-1)
    nll = lse - jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                                    axis=-1)[..., 0]
    return ent, nll


def batch_centered(scores):
    """Subtract the batch mean: removes any per-step trend exactly, so the
    reservoir sees a stationary rank stream (restores eq. 9/10 on training
    NLL streams — see EXPERIMENTS §Training-integration). Loses absolute
    difficulty levels; use ema_relative when those matter."""
    scores = scores.astype(jnp.float32)
    return scores - jnp.mean(scores)


def ema_relative(scores, ema, step, decay: float = 0.9):
    """Re-stationarize a trending score stream (beyond paper; EXPERIMENTS
    §Training-integration finding): training NLL decreases over time, which
    violates the random-order assumption behind eq. 9/10 and biases the
    reservoir toward early documents. Ranking by ``score − EMA(score)``
    removes the trend, restoring the analytic write law.

    Returns (relative_scores, new_ema). ``ema`` is bias-corrected à la Adam,
    so step 0 works from a zero init. jit-friendly.
    """
    scores = scores.astype(jnp.float32)
    new_ema = decay * ema + (1.0 - decay) * jnp.mean(scores)
    t = (step + 1).astype(jnp.float32)
    ema_hat = new_ema / (1.0 - decay ** t)
    return scores - ema_hat, new_ema


SCORERS: dict[str, Scorer] = {
    "nll": nll_score,
    "entropy": entropy_score,
    "margin": margin_score,
}


def get_scorer(name: str) -> Scorer:
    if name not in SCORERS:
        raise KeyError(f"unknown interestingness scorer {name!r}; have {list(SCORERS)}")
    return SCORERS[name]
