"""Single home for the two-tier scalar-``r`` compatibility layer.

PR 2 generalized the stack from the paper's scalar changeover index ``r``
to boundary vectors, leaving small shims (``TIER_A``/``TIER_B`` constants,
``r`` ↔ ``boundaries`` conversions) duplicated across ``core.placement``,
``core.tiers`` and ``streams.metering``. They now live here, with one
deprecation pathway: call :func:`deprecated` from any legacy entry point
and it emits a single ``DeprecationWarning`` per call site naming the
boundary-vector replacement.
"""
from __future__ import annotations

import warnings
from typing import Sequence, Tuple

TIER_A, TIER_B = 0, 1

_WARNED: set = set()


def deprecated(api: str, replacement: str) -> None:
    """Emit one DeprecationWarning per legacy API, pointing at the
    boundary-vector replacement."""
    if api in _WARNED:
        return
    _WARNED.add(api)
    warnings.warn(
        f"{api} is the two-tier scalar-r shim; use {replacement} instead",
        DeprecationWarning, stacklevel=3)


def boundaries_from_r(r: float) -> Tuple[float, ...]:
    """The scalar changeover index as a single-boundary vector."""
    return (float(r),)


def r_from_boundaries(boundaries: Sequence[float]) -> float:
    """The two-tier view of a boundary vector: its first changeover."""
    return float(boundaries[0])


def validate_boundaries(boundaries: Sequence[float],
                        label: str = "boundaries") -> Tuple[float, ...]:
    """Normalize to a non-empty, non-decreasing float tuple."""
    bs = tuple(float(b) for b in boundaries)
    if not bs:
        raise ValueError(f"{label} must be non-empty")
    if any(b2 < b1 for b1, b2 in zip(bs, bs[1:])):
        raise ValueError(f"{label} must be non-decreasing: {bs}")
    return bs
