"""Ordered N-tier storage topologies — the general setting the paper's
two-tier Algorithm C is a special case of.

Because the per-index write expectation E[writes at i] = min(1, K/(i+1))
(eq. 9/10) is non-increasing in i, the optimal assignment of stream indices
to an *ordered* hierarchy of T tiers is a vector of index thresholds
b_1 <= ... <= b_{T-1}: doc i goes to tier t iff b_t <= i < b_{t+1}
(b_0 = 0, b_T = N). Every adjacent-pair crossover has the same closed form
as eq. 17/21, and eq. 22's validity gate becomes "collapse the tiers whose
boundary leaves their segment empty" — solved exactly in
``shp.plan_placement_ntier`` / ``streams.planner.plan_fleet``.

Conventions (generalizing DESIGN.md §1.1):

* Tier 0 is producer-local (write-cheap, holds early / likely-evicted
  docs); tier T-1 is consumer-local (read-cheap, holds likely survivors).
  Write costs should typically increase and storage rates decrease along
  the hierarchy — the planner does not require it (degenerate orders just
  collapse), but only monotone hierarchies produce interior thresholds.
* ``TierSpec`` bundles a tier's raw billing (``costs.TierCosts``) with its
  producer→tier and tier→consumer transfer rates, so the derived
  per-document costs are cw_t = put_t + xfer_in·doc_GB and
  cr_t = get_t + xfer_out·doc_GB (the two-tier convention, per tier).
* Migration between adjacent tiers follows eq. 19 per boundary:
  cr_t + cw_{t+1} per migrated doc (transfer bundled in cr/cw).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import TYPE_CHECKING, Tuple

if TYPE_CHECKING:  # avoid a runtime cycle: costs.py owns NTierCostModel
    from .costs import NTierCostModel, TierCosts, WorkloadSpec


@dataclass(frozen=True)
class TierSpec:
    """One tier of the hierarchy: raw billing plus its transfer rates on
    the write path (producer → tier) and the read path (tier → consumer).

    ``capacity_docs`` declares a per-tier occupancy bound (documents the
    tier can hold at any instant, None = unbounded) that the constrained
    planner picks up by default (``core.constraints``); ``read_latency_s``
    is the tier's expected per-object retrieval latency, consumed by
    ``ReadLatencySLO`` constraints and by reconciliation-time SLO checks.
    """

    costs: "TierCosts"
    xfer_in_per_gb: float = 0.0
    xfer_out_per_gb: float = 0.0
    capacity_docs: float | None = None
    read_latency_s: float = 0.0

    @property
    def name(self) -> str:
        return self.costs.name


@dataclass(frozen=True)
class TierTopology:
    """An ordered tier hierarchy (tier 0 = producer-local / write side,
    tier T-1 = consumer-local / read side)."""

    tiers: Tuple[TierSpec, ...]
    name: str = ""

    def __post_init__(self):
        if len(self.tiers) < 2:
            raise ValueError(f"a topology needs >= 2 tiers, got {len(self.tiers)}")

    def __len__(self) -> int:
        return len(self.tiers)

    @property
    def t(self) -> int:
        return len(self.tiers)

    @property
    def tier_names(self) -> Tuple[str, ...]:
        return tuple(ts.name for ts in self.tiers)

    def cost_model(self, workload: "WorkloadSpec") -> "NTierCostModel":
        from .costs import NTierCostModel
        return NTierCostModel(topology=self, workload=workload)

    def replace(self, **kw) -> "TierTopology":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

def aws_s3_tiering(glacier_retrieval_per_gb: float = 0.03,
                   ia_retrieval_per_gb: float = 0.01) -> TierTopology:
    """S3 Standard → Standard-IA → Glacier Instant Retrieval (us-east-1
    list prices): PUT/GET per-request fees rise and storage rental falls
    down the hierarchy, so the migration variant's eq. 21-style crossovers
    are interior while the no-migration reads get *worse* with depth (the
    eq. 22 gate trips and that family falls back to fewer tiers)."""
    from .costs import TierCosts
    std = TierCosts("s3-standard", put_per_doc=0.005 / 1000,
                    get_per_doc=0.0004 / 1000, storage_per_gb_month=0.023)
    ia = TierCosts("s3-standard-ia", put_per_doc=0.01 / 1000,
                   get_per_doc=0.001 / 1000, storage_per_gb_month=0.0125)
    gir = TierCosts("s3-glacier-ir", put_per_doc=0.02 / 1000,
                    get_per_doc=0.01 / 1000, storage_per_gb_month=0.004)
    return TierTopology(tiers=(
        TierSpec(std, read_latency_s=0.02),
        TierSpec(ia, xfer_out_per_gb=ia_retrieval_per_gb,
                 read_latency_s=0.03),
        TierSpec(gir, xfer_out_per_gb=glacier_retrieval_per_gb,
                 read_latency_s=0.08),
    ), name="aws-s3-tiering")


def aws_efs_s3_glacier(glacier_retrieval_per_gb: float = 0.03) -> TierTopology:
    """Case study 2 extended one tier down: EFS (free transactions, pricey
    rental) → S3 Standard → Glacier Instant Retrieval. Because EFS's touch
    cost is zero and the rental drops ~75x across the hierarchy, all three
    tiers genuinely engage under long-window workloads — the flagship
    3-boundary migration cascade (``benchmarks/paper_tables.table_3tier``).
    """
    from .costs import TierCosts
    efs = TierCosts("aws-efs", put_per_doc=0.0, get_per_doc=0.0,
                    storage_per_gb_month=0.30)
    s3 = TierCosts("aws-s3", put_per_doc=0.000005, get_per_doc=0.000005,
                   storage_per_gb_month=0.023)
    gir = TierCosts("s3-glacier-ir", put_per_doc=0.02 / 1000,
                    get_per_doc=0.01 / 1000, storage_per_gb_month=0.004)
    return TierTopology(tiers=(
        TierSpec(efs, read_latency_s=0.003),
        TierSpec(s3, read_latency_s=0.02),
        TierSpec(gir, xfer_out_per_gb=glacier_retrieval_per_gb,
                 read_latency_s=0.08),
    ), name="aws-efs-s3-glacier")


def aws_archive_tiering(flexible_retrieval_per_gb: float = 0.01,
                        flexible_latency_s: float = 4.0 * 3600,
                        min_storage: bool = False) -> TierTopology:
    """S3 Standard → Glacier Flexible Retrieval (us-east-1 list prices):
    the archive tier rents ~6x cheaper than Standard but serves standard
    retrievals in hours, not milliseconds — the hierarchy where a
    read-path SLO (``constraints.ReadLatencySLO``) genuinely bites and
    forces the planner off the cheapest tier. ``min_storage=True`` adds
    Glacier's 90-day minimum-storage-duration billing."""
    from .costs import TierCosts
    std = TierCosts("s3-standard", put_per_doc=0.005 / 1000,
                    get_per_doc=0.0004 / 1000, storage_per_gb_month=0.023)
    gfr = TierCosts("s3-glacier-flexible", put_per_doc=0.03 / 1000,
                    get_per_doc=0.0004 / 1000, storage_per_gb_month=0.0036,
                    min_storage_days=90.0 if min_storage else 0.0)
    return TierTopology(tiers=(
        TierSpec(std, read_latency_s=0.02),
        TierSpec(gfr, xfer_out_per_gb=flexible_retrieval_per_gb,
                 read_latency_s=flexible_latency_s),
    ), name="aws-archive-tiering")


def hbm_dram_disk_preset(n_docs: int, k: int, doc_gb: float,
                         window_seconds: float,
                         hbm_bw_gbps: float = 819.0,
                         host_link_gbps: float = 32.0,
                         disk_bw_gbps: float = 2.0,
                         hbm_capacity_premium: float = 50.0,
                         hbm_capacity_docs: float | None = None
                         ) -> "NTierCostModel":
    """Hardware-derived 3-tier hierarchy: device HBM → host DRAM → local
    disk/object store, extending ``costs.hbm_host_preset`` one level down.
    "Cost" is seconds of bandwidth occupancy plus a capacity-opportunity
    rental premium that falls two orders of magnitude per level.
    ``hbm_capacity_docs`` declares the device slab's hard slot budget
    (HBM is the one tier that physically cannot oversubscribe); the
    constrained planner then keeps the hot boundary under it."""
    from .costs import DAYS_PER_MONTH, NTierCostModel, TierCosts, WorkloadSpec
    months = window_seconds / (DAYS_PER_MONTH * 24 * 3600)
    hbm = TierCosts("device-hbm", put_per_doc=doc_gb / hbm_bw_gbps,
                    get_per_doc=doc_gb / hbm_bw_gbps,
                    storage_per_gb_month=hbm_capacity_premium)
    dram = TierCosts("host-dram", put_per_doc=doc_gb / host_link_gbps,
                     get_per_doc=doc_gb / host_link_gbps,
                     storage_per_gb_month=hbm_capacity_premium / 100.0)
    disk = TierCosts("local-disk", put_per_doc=doc_gb / disk_bw_gbps,
                     get_per_doc=doc_gb / disk_bw_gbps,
                     storage_per_gb_month=hbm_capacity_premium / 10_000.0)
    topo = TierTopology(tiers=(
        TierSpec(hbm, capacity_docs=hbm_capacity_docs,
                 read_latency_s=doc_gb / hbm_bw_gbps),
        TierSpec(dram, read_latency_s=doc_gb / host_link_gbps),
        TierSpec(disk, read_latency_s=doc_gb / disk_bw_gbps),
    ), name="hbm-dram-disk")
    wl = WorkloadSpec(n_docs=n_docs, k=k, doc_gb=doc_gb, window_months=months)
    return NTierCostModel(topology=topo, workload=wl)
