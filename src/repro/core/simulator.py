"""Trace-driven simulator (paper §VIII, Fig. 8).

Replays an interestingness trace through the exact top-K reservoir and a
placement policy, accounting every transaction, byte moved, and doc-month of
rental. Used to validate the analytic model (tests assert the simulated cost
matches `core.shp` expectations on randomly-ordered traces) and to reproduce
Fig. 8's cumulative-writes comparison.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .costs import TwoTierCostModel
from .placement import Policy, TIER_A, TIER_B


@dataclass
class SimResult:
    n: int
    k: int
    writes_per_tier: np.ndarray  # (2,)
    reads_per_tier: np.ndarray  # (2,) final-read transactions
    migrated: int
    evictions: int
    cum_writes: np.ndarray  # (n,) cumulative reservoir writes after doc i
    doc_months_per_tier: np.ndarray  # (2,) rental actually consumed
    survivor_ids: np.ndarray  # (k,) stream indices of final top-K
    cost_writes: float = 0.0
    cost_reads: float = 0.0
    cost_storage: float = 0.0
    cost_migration: float = 0.0

    @property
    def cost_total(self) -> float:
        return self.cost_writes + self.cost_reads + self.cost_storage + self.cost_migration


def simulate(scores: np.ndarray, k: int, policy: Policy,
             cost_model: Optional[TwoTierCostModel] = None,
             storage_bound: bool = False) -> SimResult:
    """Replay ``scores`` (interestingness trace, one doc per index).

    Exact reservoir semantics: doc i is written iff it ranks in the top-K of
    docs 0..i (ties: earlier doc wins). Eviction frees its rental. If
    ``cost_model`` is given, costs follow its per-doc conventions; with
    ``storage_bound`` the rental is charged as the paper's upper bound
    (K docs · full window · max-rate) instead of metered doc-months.
    """
    scores = np.asarray(scores, dtype=np.float64)
    n = scores.shape[0]
    if not 0 < k < n:
        raise ValueError(f"require 0 < k < n, got k={k} n={n}")

    # min-heap of (score, -index): root = weakest member (ties: latest doc
    # is weakest, i.e. earlier doc wins, matching topk.update's lexsort).
    heap: list[tuple[float, int]] = []
    tier_of_doc: dict[int, int] = {}
    write_index: dict[int, int] = {}
    writes = np.zeros(2, dtype=np.int64)
    reads = np.zeros(2, dtype=np.int64)
    doc_months = np.zeros(2, dtype=np.float64)
    cum_writes = np.zeros(n, dtype=np.int64)
    evictions = 0
    migrated = 0
    mig_at = policy.migration_index()
    wrote_so_far = 0

    wl = cost_model.workload if cost_model is not None else None
    month_per_doc_slot = (wl.window_months / n) if wl is not None else 0.0

    def _charge_rental(doc: int, end_i: int):
        nonlocal doc_months
        t = tier_of_doc[doc]
        doc_months[t] += (end_i - write_index[doc]) * month_per_doc_slot

    for i in range(n):
        if mig_at is not None and i == mig_at:
            # bulk migration A→B of everything currently resident in A
            for doc in list(tier_of_doc):
                if tier_of_doc[doc] == TIER_A:
                    _charge_rental(doc, i)
                    tier_of_doc[doc] = TIER_B
                    write_index[doc] = i
                    migrated += 1
        entry = (scores[i], -i)
        if len(heap) < k:
            accepted = True
        elif entry > heap[0]:
            weakest_score, neg_idx = heapq.heappop(heap)
            evict_doc = -neg_idx
            _charge_rental(evict_doc, i)
            del tier_of_doc[evict_doc]
            del write_index[evict_doc]
            evictions += 1
            accepted = True
        else:
            accepted = False
        if accepted:
            heapq.heappush(heap, entry)
            t = policy.tier_of(i)
            if mig_at is not None and i >= mig_at:
                t = TIER_B
            tier_of_doc[i] = t
            write_index[i] = i
            writes[t] += 1
            wrote_so_far += 1
        cum_writes[i] = wrote_so_far

    survivors = np.array(sorted(-neg for _, neg in heap), dtype=np.int64)
    for doc in tier_of_doc:
        _charge_rental(doc, n)
    for doc in survivors:
        reads[tier_of_doc[int(doc)]] += 1

    res = SimResult(n=n, k=k, writes_per_tier=writes, reads_per_tier=reads,
                    migrated=migrated, evictions=evictions,
                    cum_writes=cum_writes, doc_months_per_tier=doc_months,
                    survivor_ids=survivors)

    if cost_model is not None:
        cm = cost_model
        res.cost_writes = writes[TIER_A] * cm.cw_a + writes[TIER_B] * cm.cw_b
        res.cost_reads = (reads[TIER_A] * cm.cr_a + reads[TIER_B] * cm.cr_b) \
            * wl.reads_per_window
        res.cost_migration = migrated * cm.migration_per_doc
        if storage_bound:
            res.cost_storage = k * cm.cs_max
        else:
            rate_a = cm.tier_a.storage_per_gb_month * wl.doc_gb
            rate_b = cm.tier_b.storage_per_gb_month * wl.doc_gb
            res.cost_storage = doc_months[TIER_A] * rate_a + doc_months[TIER_B] * rate_b
    return res


def random_rank_trace(n: int, rng: np.random.Generator) -> np.ndarray:
    """A trace satisfying the paper's assumption exactly: ranks are a uniform
    random permutation (scores i.u.d.)."""
    return rng.permutation(n).astype(np.float64)


def grn_entropy_trace(n: int, rng: np.random.Generator,
                      interesting_frac: float = 0.15) -> np.ndarray:
    """Synthetic stand-in for the paper's §VIII gene-regulatory-network
    label-entropy trace (Fig. 7): a shuffled mixture of confident
    (low-entropy) and boundary (high-entropy) classifier outputs."""
    n_hi = int(n * interesting_frac)
    p_hi = rng.beta(8, 9, size=n_hi)  # near decision boundary
    p_lo = rng.beta(0.35, 4.5, size=n - n_hi)  # confident
    p = np.clip(np.concatenate([p_hi, p_lo]), 1e-9, 1 - 1e-9)
    ent = -(p * np.log2(p) + (1 - p) * np.log2(1 - p))
    rng.shuffle(ent)
    # entropy ties are common at saturation; jitter breaks them so the trace
    # has a strict ranking (matches the paper's continuous entropies).
    return ent + rng.uniform(0, 1e-9, size=n)


def sorted_adversarial_trace(n: int, ascending: bool = True) -> np.ndarray:
    """Worst/best-case ordered trace — violates the random-order assumption;
    used to document where the analytic model breaks (DESIGN.md §9)."""
    t = np.arange(n, dtype=np.float64)
    return t if ascending else t[::-1].copy()
