"""Trace-driven simulator (paper §VIII, Fig. 8), generalized to N tiers.

Replays an interestingness trace through the exact top-K reservoir and a
placement policy, accounting every transaction, byte moved, and doc-month of
rental. Used to validate the analytic model (tests assert the simulated cost
matches `core.shp` expectations on randomly-ordered traces — per tier for
N-tier topologies) and to reproduce Fig. 8's cumulative-writes comparison.

Constraint-aware additions: per-tier occupancy high-water marks (sampled at
the end of each document step) and the realized per-survivor read latency,
so capacity / SLO violations surface at reconciliation
(``SimResult.check_constraints``), not just at planning time. Tiers with a
minimum storage duration (``TierCosts.min_storage_days``) bill every stay
topped up to the minimum — the S3-IA / Glacier early-delete convention.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional, Union

import numpy as np

from .compat import TIER_A, TIER_B  # noqa: F401  (canonical home: compat)
from .costs import NTierCostModel, TwoTierCostModel
from .placement import Policy


@dataclass
class SimResult:
    n: int
    k: int
    writes_per_tier: np.ndarray  # (T,)
    reads_per_tier: np.ndarray  # (T,) final-read transactions
    migrated: int  # total migration hops across all boundaries
    evictions: int
    cum_writes: np.ndarray  # (n,) cumulative reservoir writes after doc i
    doc_months_per_tier: np.ndarray  # (T,) rental actually consumed
    survivor_ids: np.ndarray  # (k,) stream indices of final top-K
    migrated_per_boundary: np.ndarray = field(
        default_factory=lambda: np.zeros(1, np.int64))  # (T-1,) hops per boundary
    occupancy_hwm_per_tier: np.ndarray = field(
        default_factory=lambda: np.zeros(1, np.int64))  # (T,) peak residents
    relocated: int = 0  # residents moved by mid-window boundary re-plans
    read_latency_mean: float = 0.0  # realized per-survivor read latency (s)
    cost_writes: float = 0.0
    cost_reads: float = 0.0
    cost_storage: float = 0.0
    cost_migration: float = 0.0

    @property
    def cost_total(self) -> float:
        return self.cost_writes + self.cost_reads + self.cost_storage + self.cost_migration

    def check_constraints(self, constraint_set, cost_model) -> dict:
        """Reconciliation-time violation report against a
        ``core.constraints.ConstraintSet``: compares the *realized*
        occupancy high-water marks and read latency with the declared
        capacities / SLO. Returns per-tier boolean masks and an ``ok``
        flag."""
        from .constraints import effective_capacity
        nt = (cost_model.as_ntier()
              if isinstance(cost_model, TwoTierCostModel) else cost_model)
        cap = effective_capacity(constraint_set, nt)
        t = self.occupancy_hwm_per_tier.shape[0]
        capacity_violations = self.occupancy_hwm_per_tier > cap[:t]
        slo = constraint_set.max_read_latency
        slo_violation = bool(self.read_latency_mean > slo)
        return {
            "capacity_violations": capacity_violations,
            "slo_violation": slo_violation,
            "ok": not (capacity_violations.any() or slo_violation),
        }


CostModel = Union[TwoTierCostModel, NTierCostModel]


def simulate(scores: np.ndarray, k: int, policy: Policy,
             cost_model: Optional[CostModel] = None,
             storage_bound: bool = False,
             boundary_schedule: Optional[list] = None) -> SimResult:
    """Replay ``scores`` (interestingness trace, one doc per index).

    Exact reservoir semantics: doc i is written iff it ranks in the top-K of
    docs 0..i (ties: earlier doc wins). Eviction frees its rental. If
    ``cost_model`` is given (two-tier or N-tier), costs follow its per-doc
    conventions; with ``storage_bound`` the rental is charged as the paper's
    upper bound (K docs · full window · max-rate) instead of metered
    doc-months. Migrating policies cascade the residents of tier t-1 into
    tier t when the position crosses boundary t, each hop charged eq. 19.

    ``boundary_schedule`` replays mid-window re-planning (``repro.online``):
    a sorted list of ``(position, boundaries)`` pairs — before processing
    doc ``position`` the placement switches to the new boundary vector,
    residents whose static tier changes are relocated (each move billed
    ``cr_src + cw_dst``, counted in ``SimResult.relocated``), and later
    writes/reads follow the new boundaries. Only non-migrating policies can
    be re-scheduled (the cascade's floor semantics would be ambiguous).
    """
    scores = np.asarray(scores, dtype=np.float64)
    n = scores.shape[0]
    if not 0 < k < n:
        raise ValueError(f"require 0 < k < n, got k={k} n={n}")
    schedule = sorted(boundary_schedule) if boundary_schedule else []
    if schedule and policy.migrate_at_r:
        raise ValueError("boundary_schedule requires a non-migrating policy")

    nt = None
    if cost_model is not None:
        nt = (cost_model.as_ntier() if isinstance(cost_model, TwoTierCostModel)
              else cost_model)
    t_tiers = max(policy.n_tiers, nt.t if nt is not None else 2)
    if nt is not None and nt.t < policy.n_tiers:
        raise ValueError(f"policy places across {policy.n_tiers} tiers but "
                         f"the cost model has {nt.t}")

    # min-heap of (score, -index): root = weakest member (ties: latest doc
    # is weakest, i.e. earlier doc wins, matching topk.update's lexsort).
    heap: list[tuple[float, int]] = []
    tier_of_doc: dict[int, int] = {}
    write_index: dict[int, int] = {}
    writes = np.zeros(t_tiers, dtype=np.int64)
    reads = np.zeros(t_tiers, dtype=np.int64)
    doc_months = np.zeros(t_tiers, dtype=np.float64)
    cum_writes = np.zeros(n, dtype=np.int64)
    migrated_per_boundary = np.zeros(max(t_tiers - 1, 1), dtype=np.int64)
    mig_reads = np.zeros(t_tiers, dtype=np.int64)  # cascade hops out of tier
    mig_writes = np.zeros(t_tiers, dtype=np.int64)  # cascade hops into tier
    occupancy = np.zeros(t_tiers, dtype=np.int64)
    occupancy_hwm = np.zeros(t_tiers, dtype=np.int64)
    evictions = 0
    mig_ats = policy.migration_indices()  # one trigger per boundary, or ()
    floor = 0  # highest fired boundary: writes/residents never go below it
    wrote_so_far = 0

    wl = cost_model.workload if cost_model is not None else None
    month_per_doc_slot = (wl.window_months / n) if wl is not None else 0.0
    min_months = (nt.min_storage_months if nt is not None
                  else np.zeros(t_tiers))

    def _charge_rental(doc: int, end_i: int):
        nonlocal doc_months
        t = tier_of_doc[doc]
        # minimum-storage-duration billing: every stay is topped up
        months = (end_i - write_index[doc]) * month_per_doc_slot
        doc_months[t] += max(months, float(min_months[t]))

    def _move_doc(doc: int, dst: int, i: int) -> int:
        """Hop one resident to tier ``dst`` at position ``i`` (top up its
        rental, re-tier, bill the eq. 19 read+write, shift occupancy);
        returns the source tier so the caller can bump its own counter."""
        src = tier_of_doc[doc]
        _charge_rental(doc, i)
        tier_of_doc[doc] = dst
        write_index[doc] = i
        mig_reads[src] += 1
        mig_writes[dst] += 1
        occupancy[src] -= 1
        occupancy[dst] += 1
        return src

    relocated = 0
    sched_idx = 0
    for i in range(n):
        while sched_idx < len(schedule) and i >= schedule[sched_idx][0]:
            # mid-window re-plan: swap the placement and relocate residents
            # whose static tier changed (billed like an eq. 19 hop)
            policy = Policy(boundaries=tuple(float(b)
                                             for b in schedule[sched_idx][1]),
                            migrate_at_r=False, name=policy.name)
            sched_idx += 1
            for doc in list(tier_of_doc):
                dst = min(policy.tier_of(doc), t_tiers - 1)
                if dst != tier_of_doc[doc]:
                    _move_doc(doc, dst, i)
                    relocated += 1
        if floor < len(mig_ats) and i >= mig_ats[floor]:
            # every boundary the position has crossed fires at once:
            # residents hop *directly* to the highest crossed tier, so
            # zero-width tiers (coincident triggers) are skipped
            dst = floor
            while dst < len(mig_ats) and i >= mig_ats[dst]:
                dst += 1
            for doc in list(tier_of_doc):
                if tier_of_doc[doc] < dst:
                    _move_doc(doc, dst, i)
                    migrated_per_boundary[dst - 1] += 1
            floor = dst
        entry = (scores[i], -i)
        if len(heap) < k:
            accepted = True
        elif entry > heap[0]:
            weakest_score, neg_idx = heapq.heappop(heap)
            evict_doc = -neg_idx
            _charge_rental(evict_doc, i)
            occupancy[tier_of_doc[evict_doc]] -= 1
            del tier_of_doc[evict_doc]
            del write_index[evict_doc]
            evictions += 1
            accepted = True
        else:
            accepted = False
        if accepted:
            heapq.heappush(heap, entry)
            t = min(max(policy.tier_of(i), floor), t_tiers - 1)
            tier_of_doc[i] = t
            write_index[i] = i
            writes[t] += 1
            occupancy[t] += 1
            wrote_so_far += 1
        cum_writes[i] = wrote_so_far
        # occupancy high-water mark, sampled at the end of each doc step
        np.maximum(occupancy_hwm, occupancy, out=occupancy_hwm)

    survivors = np.array(sorted(-neg for _, neg in heap), dtype=np.int64)
    for doc in tier_of_doc:
        _charge_rental(doc, n)
    for doc in survivors:
        reads[tier_of_doc[int(doc)]] += 1

    res = SimResult(n=n, k=k, writes_per_tier=writes, reads_per_tier=reads,
                    migrated=int(migrated_per_boundary.sum()),
                    evictions=evictions, cum_writes=cum_writes,
                    doc_months_per_tier=doc_months, survivor_ids=survivors,
                    migrated_per_boundary=migrated_per_boundary,
                    occupancy_hwm_per_tier=occupancy_hwm,
                    relocated=relocated)

    if nt is not None:
        # the guard above forces t_tiers == nt.t whenever nt is given
        if reads.sum() > 0:
            res.read_latency_mean = (float(reads @ nt.read_latency)
                                     / float(reads.sum()))
        res.cost_writes = float(writes @ nt.cw)
        res.cost_reads = float(reads @ nt.cr) * wl.reads_per_window
        res.cost_migration = float(mig_reads @ nt.cr + mig_writes @ nt.cw)
        if storage_bound:
            res.cost_storage = k * nt.cs_max
        else:
            res.cost_storage = float(doc_months @ nt.storage_per_doc_month)
    return res


def random_rank_trace(n: int, rng: np.random.Generator) -> np.ndarray:
    """A trace satisfying the paper's assumption exactly: ranks are a uniform
    random permutation (scores i.u.d.)."""
    return rng.permutation(n).astype(np.float64)


def drift_weights(n: int, multipliers) -> np.ndarray:
    """(n,) per-index record-rate weights from a piecewise schedule of
    ``(start_index, multiplier)`` change points (implicit ``(0, 1.0)``
    head). Weight ``θ_i`` is the multiplier active at index i."""
    w = np.ones(n, np.float64)
    for start, mult in sorted(multipliers):
        if mult <= 0:
            raise ValueError("rate multipliers must be positive")
        w[int(start):] = float(mult)
    return w


def drifted_rank_trace(n: int, rng: np.random.Generator,
                       multipliers) -> np.ndarray:
    """A trace violating the i.u.d. assumption with *known*, piecewise
    drift: scores follow the weighted-record model (Yang 1975) — doc i
    draws ``score_i = −E_i/θ_i`` with ``E_i ~ Exp(1)``, so the probability
    that doc i beats all earlier docs is exactly ``θ_i / Σ_{j<=i} θ_j``
    and the reservoir-entry rate is ``≈ min(1, K·θ_i/Σ_{j<=i} θ_j)``
    instead of the null ``K/(i+1)`` law. ``multipliers`` is a schedule of
    ``(start_index, multiplier)`` pairs (``drift_weights``); constant
    weight 1 recovers ``random_rank_trace`` in distribution. Ground truth
    for validating ``repro.online``'s drift detection and re-planning.
    """
    theta = drift_weights(n, multipliers)
    return -rng.exponential(size=n) / theta


def grn_entropy_trace(n: int, rng: np.random.Generator,
                      interesting_frac: float = 0.15) -> np.ndarray:
    """Synthetic stand-in for the paper's §VIII gene-regulatory-network
    label-entropy trace (Fig. 7): a shuffled mixture of confident
    (low-entropy) and boundary (high-entropy) classifier outputs."""
    n_hi = int(n * interesting_frac)
    p_hi = rng.beta(8, 9, size=n_hi)  # near decision boundary
    p_lo = rng.beta(0.35, 4.5, size=n - n_hi)  # confident
    p = np.clip(np.concatenate([p_hi, p_lo]), 1e-9, 1 - 1e-9)
    ent = -(p * np.log2(p) + (1 - p) * np.log2(1 - p))
    rng.shuffle(ent)
    # entropy ties are common at saturation; jitter breaks them so the trace
    # has a strict ranking (matches the paper's continuous entropies).
    return ent + rng.uniform(0, 1e-9, size=n)


def sorted_adversarial_trace(n: int, ascending: bool = True) -> np.ndarray:
    """Worst/best-case ordered trace — violates the random-order assumption;
    used to document where the analytic model breaks (DESIGN.md §9)."""
    t = np.arange(n, dtype=np.float64)
    return t if ascending else t[::-1].copy()
