"""Streaming top-K reservoir — jit-compatible, batched, shard-mergeable.

The paper's per-document ``H.insert / indexof`` loop (Fig. 2/3), vectorized
for accelerators: each update merges a batch of scored documents into the
reservoir with one sort. Deterministic tie-break: lower stream index wins.

State is a pytree, so it can live donated inside a jitted train step and be
sharded/merged across data-parallel sub-streams (``merge``).

Multi-tenant variant: ``repro.streams.engine`` stacks M of these states on
a leading stream axis and advances them in one jitted step; the kernel
fast path for the scan is ``repro.kernels.topk_filter`` (one stream) /
``repro.kernels.batched_topk`` (the fleet).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


class ReservoirState(NamedTuple):
    scores: jax.Array  # (K,) float32, sorted descending, -inf padded
    ids: jax.Array  # (K,) int32 global stream index, -1 padded
    seen: jax.Array  # () int32 — total documents observed


def init(k: int) -> ReservoirState:
    return ReservoirState(
        scores=jnp.full((k,), -jnp.inf, dtype=jnp.float32),
        ids=jnp.full((k,), -1, dtype=jnp.int32),
        seen=jnp.zeros((), dtype=jnp.int32),
    )


def member(needles: jax.Array, haystack: jax.Array) -> jax.Array:
    """Boolean membership mask (``needles[i] in haystack``) via
    sort + binary search — O((H+N)·log H) instead of ``jnp.isin``'s
    O(N·H) broadcast compare, which dominates the exact path at huge K
    (a K=65536 eviction scan is 4G compares per stream)."""
    hs = jnp.sort(haystack)
    pos = jnp.clip(jnp.searchsorted(hs, needles), 0, hs.shape[0] - 1)
    return hs[pos] == needles


def _merge_sorted(scores: jax.Array, ids: jax.Array, k: int):
    """Top-k of (scores, ids) with lower-id tie-break; returns sorted desc."""
    # lexsort: primary = -score, secondary = id  → stable deterministic order.
    order = jnp.lexsort((ids, -scores))
    top = order[:k]
    return scores[top], ids[top]


def update(state: ReservoirState, batch_scores: jax.Array,
           batch_ids: jax.Array) -> Tuple[ReservoirState, jax.Array]:
    """Merge a batch into the reservoir.

    Returns (new_state, wrote_mask) where ``wrote_mask[j]`` is True iff batch
    element j entered the reservoir (⇒ one storage write, paper eq. 9/10).
    Batch elements whose id is already resident are dropped — a re-observed
    document neither duplicates its slot nor triggers a storage write.
    Within-batch ids are assumed unique (they are stream indices).
    """
    k = state.scores.shape[0]
    batch_scores = batch_scores.astype(jnp.float32).reshape(-1)
    batch_ids = batch_ids.astype(jnp.int32).reshape(-1)
    resident = member(batch_ids, state.ids)
    cand_scores = jnp.where(resident, -jnp.inf, batch_scores)
    cand_ids = jnp.where(resident, -1, batch_ids)
    all_scores = jnp.concatenate([state.scores, cand_scores])
    all_ids = jnp.concatenate([state.ids, cand_ids])
    order = jnp.lexsort((all_ids, -all_scores))
    top = order[:k]
    # positional membership, not id membership: an id collision with a
    # resident entry must not report a write for the colliding batch element.
    selected = jnp.zeros(all_ids.shape, dtype=bool).at[top].set(True)
    wrote = selected[k:] & (cand_ids >= 0)
    new_state = ReservoirState(
        scores=all_scores[top], ids=all_ids[top],
        seen=state.seen + batch_ids.shape[0],
    )
    return new_state, wrote


def evicted(old: ReservoirState, new: ReservoirState) -> jax.Array:
    """Mask over ``old.ids`` of entries no longer present in ``new`` —
    the documents whose storage can be freed (overwritten, paper §VI)."""
    return (old.ids >= 0) & ~member(old.ids, new.ids)


def merge(a: ReservoirState, b: ReservoirState) -> ReservoirState:
    """Merge two sub-stream reservoirs (cross-shard reduction). Associative
    and commutative up to the deterministic tie-break, so it can be used in
    ``jax.lax`` reductions / psum-style tree merges."""
    k = a.scores.shape[0]
    scores = jnp.concatenate([a.scores, b.scores])
    ids = jnp.concatenate([a.ids, b.ids])
    s, i = _merge_sorted(scores, ids, k)
    return ReservoirState(scores=s, ids=i, seen=a.seen + b.seen)


def threshold(state: ReservoirState) -> jax.Array:
    """Current K-th score (entry bar). -inf while the reservoir is unfull."""
    return state.scores[-1]


def tier_of(ids: jax.Array, r: float | jax.Array) -> jax.Array:
    """Algorithm C placement: tier 0 (A) for stream index < r, else 1 (B)."""
    return (ids >= jnp.asarray(r)).astype(jnp.int32)
