"""Pluggable placement constraints — per-tier capacities and read-path
SLOs layered on top of the paper's unconstrained closed forms.

The paper's planner (eqs. 17/21) assumes every tier has unbounded capacity
and free, instant reads. Production hierarchies break both assumptions:
a hot NVMe/HBM tier holds C_t documents, and archival tiers (Glacier-style)
serve reads with retrieval latencies that a consumer SLO bounds. Following
the stochastic-submodular view of capacity-constrained tiering (Yun et al.
2020) and memory-bounded k-secretary placement (Qiao & Zhang 2025), this
module makes bounded resources first-class:

* ``TierCapacity`` — tier t holds at most C_t documents (or bytes) at any
  instant, measured as the reservoir's expected occupancy high-water mark.
* ``ReadLatencySLO`` — the expected per-survivor read latency at window end
  must not exceed a bound, with per-tier latencies from ``TierSpec``.
* ``ConstraintSet`` — an ordered bundle the planning stack consumes: the
  constrained planner (``shp.plan_ntier_arrays`` with ``cap/lat/slo``),
  the brute-force feasible-grid verifier, the fleet planner's shared-
  capacity water-filling pass, and reconciliation-time violation checks
  (``core.simulator`` / ``streams.metering``) all speak this vocabulary.

Any object implementing the ``Constraint`` protocol (``feasible(cm,
bounds, migrate)``) plugs into the generic feasibility/verification path;
the planner additionally fast-paths the two concrete types into exact
masks and a resource-augmented DP.

Occupancy law (derived from the paper's i.u.d. assumption): at stream
position j the reservoir's members are uniformly distributed over the
prefix, so a static tier spanning [b_t, b_{t+1}) peaks at position
b_{t+1} with expected occupancy ``min(b_{t+1}, K) * (1 - b_t/b_{t+1})``.
Under Algorithm C's cascade the whole reservoir lives in one tier at a
time, so a used tier's peak is ``min(b_{t+1}, K)`` — with the eq. 22 gate
(boundaries in [K, N)) that is exactly K, turning capacities below K into
subset-level infeasibility for the migration family.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Protocol, Tuple, runtime_checkable

import numpy as np


# ---------------------------------------------------------------------------
# Analytic occupancy / latency laws (shared by planner, verifier, meters)
# ---------------------------------------------------------------------------

def peak_occupancy(bounds, n: float, k: float, migrate: bool) -> np.ndarray:
    """(T,) expected occupancy high-water mark per tier for one stream.

    Static (no-migration) tier t over [b_t, b_{t+1}): peak at position
    b_{t+1}, ``min(b_{t+1}, K)·(1 − b_t/b_{t+1})`` (0 for empty tiers).
    Migrating streams hold the whole reservoir in one tier at a time:
    a used tier peaks at ``min(b_{t+1}, K)``; the last tier always at K.
    """
    edges = np.concatenate([[0.0], np.asarray(bounds, np.float64),
                            [float(n)]])
    hi = edges[1:]
    lo = edges[:-1]
    if migrate:
        used = (hi > lo)
        used[-1] = True
        return np.where(used, np.minimum(hi, k), 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        occ = np.minimum(hi, k) * (1.0 - lo / hi)
    return np.where(hi > 0, occ, 0.0)


def peak_occupancy_arrays(bounds: np.ndarray, n: np.ndarray, k: np.ndarray,
                          migrate: np.ndarray) -> np.ndarray:
    """Vectorized ``peak_occupancy``: bounds (M, T-1) → (M, T)."""
    m = bounds.shape[0]
    edges = np.concatenate([np.zeros((m, 1)), np.asarray(bounds, np.float64),
                            np.asarray(n, np.float64)[:, None]], axis=1)
    hi, lo = edges[:, 1:], edges[:, :-1]
    kcol = np.asarray(k, np.float64)[:, None]
    used = hi > lo
    used[:, -1] = True
    occ_mig = np.where(used, np.minimum(hi, kcol), 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        occ_static = np.minimum(hi, kcol) * (1.0 - lo / hi)
    occ_static = np.where(hi > 0, occ_static, 0.0)
    return np.where(np.asarray(migrate, bool)[:, None], occ_mig, occ_static)


def peak_occupancy_suffix(bounds, n, k, observed_hwm) -> np.ndarray:
    """(M, T) expected occupancy high-water mark over the *rest* of the
    window, conditioned on the observed prefix.

    The high-water mark is monotone non-decreasing, so the suffix peak is
    the elementwise max of the analytic static law at the (possibly
    re-planned) boundary vector and the occupancy already witnessed by the
    meter — a re-plan can stop a tier from growing further but can never
    un-ring the bell on a peak that already happened. Used by the online
    re-planner and the mid-window admission negotiation
    (``repro.online``). ``bounds`` (M, T-1), ``observed_hwm`` (M, T).
    """
    bounds = np.atleast_2d(np.asarray(bounds, np.float64))
    m = bounds.shape[0]
    analytic = peak_occupancy_arrays(bounds, np.broadcast_to(n, (m,)),
                                     np.broadcast_to(k, (m,)),
                                     np.zeros(m, bool))
    return np.maximum(analytic, np.asarray(observed_hwm, np.float64))


def evacuation_boundaries(bounds, tier: int, n=None) -> np.ndarray:
    """Collapse ``tier`` to zero width in a boundary vector — the
    tier-outage fallback for streams without a cost model (no analytic
    suffix re-solve is possible, but residents still have to leave).

    Tier ``t`` spans ``[b[t-1], b[t])`` with ``b[-1]=0`` and an implicit
    ``+inf`` above the last boundary. An interior (or first) failed tier
    is merged into the next *colder* tier (``b[tier] ← b[tier-1]``) —
    demotion is the capacity-rich direction. The last tier has no colder
    neighbour: its boundary is pushed past the window end (``n``, or
    ``+inf`` when the stream length is unknown), promoting everything
    into the hotter neighbour. Monotonicity of the vector is preserved
    in both cases."""
    b = np.asarray(bounds, np.float64).copy()
    depth = b.shape[0]
    if tier < 0 or tier > depth:
        raise ValueError(f"tier {tier} out of range for a "
                         f"{depth + 1}-tier placement")
    if depth == 0:
        raise ValueError("single-tier placement has no surviving tier "
                         "to evacuate into")
    if tier < depth:
        b[tier] = 0.0 if tier == 0 else b[tier - 1]
    else:
        b[depth - 1] = np.inf if n is None else float(n)
    return b


def waterfill_grants(desired, budget: float) -> np.ndarray:
    """Water-filling split of a fleet-shared budget: each stream is
    granted ``min(desired_i, λ)`` with the water level λ chosen so the
    grants sum to the budget (everything granted when the desires
    already fit). Exact λ via one sort + prefix scan over the fleet —
    the single-host view. Sharded fleets compute the same λ without
    gathering via ``parallel.fleet.waterfill_sharded`` (psum bisection);
    ``streams.planner.waterfill`` dispatches between the two."""
    d = np.asarray(desired, np.float64)
    if d.sum() <= budget:
        return d.copy()
    order = np.sort(d)
    m = order.shape[0]
    prefix = np.concatenate([[0.0], np.cumsum(order)])
    # smallest j where filling everyone above order[j] to order[j] overflows
    fill_at = prefix[:-1] + order * (m - np.arange(m))
    j = int(np.searchsorted(fill_at, budget, side="right"))
    lam = (budget - prefix[j]) / max(m - j, 1)
    return np.minimum(d, max(lam, 0.0))


def expected_read_latency(bounds, n: float, latencies, migrate: bool) -> float:
    """Expected per-survivor read latency at window end.

    No-migration: survivors are i.u.d. over the stream, so the expectation
    is the tier-width-weighted mean. Migration: the final read is served
    entirely from the last tier (the eq. 20 convention).
    """
    lat = np.asarray(latencies, np.float64)
    if migrate:
        return float(lat[-1])
    edges = np.concatenate([[0.0], np.asarray(bounds, np.float64),
                            [float(n)]])
    frac = np.diff(edges) / float(n)
    return float(frac @ lat)


# ---------------------------------------------------------------------------
# The constraint vocabulary
# ---------------------------------------------------------------------------

@runtime_checkable
class Constraint(Protocol):
    """A pluggable feasibility predicate over a candidate plan.

    ``feasible(cm, bounds, migrate)`` is the generic surface every
    constraint must implement (used by the brute-force verifier and by
    reconciliation); the planner additionally recognizes the concrete
    ``TierCapacity`` / ``ReadLatencySLO`` types and compiles them into
    exact vectorized masks and budget levels.
    """

    def feasible(self, cm, bounds, migrate: bool) -> bool:
        """Does the plan (boundary vector + strategy family) satisfy this
        constraint in expectation under cost model ``cm``?"""
        ...


@dataclass(frozen=True)
class TierCapacity:
    """Tier ``tier`` holds at most ``max_docs`` documents (or ``max_bytes``
    bytes, converted via the workload's document size) at any instant.

    ``shared=True`` makes the budget fleet-wide: the fleet planner splits
    it across tenants with a water-filling pass
    (``streams.planner.waterfill``) instead of granting every stream the
    full C_t.
    """

    tier: int
    max_docs: float = math.inf
    max_bytes: float | None = None
    shared: bool = False

    def docs(self, doc_gb: float) -> float:
        """The capacity in documents, taking the tighter of the doc and
        byte limits (bytes need a positive document size)."""
        cap = float(self.max_docs)
        if self.max_bytes is not None and doc_gb > 0:
            cap = min(cap, self.max_bytes / (doc_gb * 1e9))
        return cap

    def feasible(self, cm, bounds, migrate: bool) -> bool:
        if self.tier >= cm.t:
            return True
        occ = peak_occupancy(bounds, cm.workload.n_docs, cm.workload.k,
                             migrate)
        return occ[self.tier] <= self.docs(cm.workload.doc_gb) * (1 + 1e-9)


@dataclass(frozen=True)
class ReadLatencySLO:
    """The expected per-survivor read latency at window end must not
    exceed ``max_seconds`` (per-tier latencies from
    ``TierSpec.read_latency_s`` via ``NTierCostModel.read_latency``)."""

    max_seconds: float

    def feasible(self, cm, bounds, migrate: bool) -> bool:
        lat = expected_read_latency(bounds, cm.workload.n_docs,
                                    cm.read_latency, migrate)
        return lat <= self.max_seconds * (1 + 1e-9)


@dataclass(frozen=True)
class ConstraintSet:
    """An ordered bundle of constraints the planning stack consumes.

    Empty sets are free: on topologies without capacity declarations
    every planner entry point degrades bit-exactly to the unconstrained
    closed form (asserted in tests). Topology-declared capacities
    (``TierSpec.capacity_docs`` — physical properties of the hierarchy)
    always apply; an explicit ``TierCapacity`` entry *overrides* the
    declaration on its tier (``TierCapacity(t, inf)`` lifts it) — see
    :func:`effective_capacity`.
    """

    constraints: Tuple[Constraint, ...] = ()

    def __init__(self, *constraints):
        if len(constraints) == 1 and isinstance(constraints[0], (tuple, list)):
            constraints = tuple(constraints[0])
        object.__setattr__(self, "constraints", tuple(constraints))

    @classmethod
    def from_topology(cls, topo, slo: float | None = None) -> "ConstraintSet":
        cons = [TierCapacity(tier=t, max_docs=float(ts.capacity_docs))
                for t, ts in enumerate(topo.tiers)
                if ts.capacity_docs is not None]
        if slo is not None:
            cons.append(ReadLatencySLO(slo))
        return cls(*cons)

    def __iter__(self):
        return iter(self.constraints)

    def __len__(self) -> int:
        return len(self.constraints)

    @property
    def empty(self) -> bool:
        return not self.constraints

    # ---- planner-facing compilation -------------------------------------

    @property
    def capacities(self) -> Tuple[TierCapacity, ...]:
        return tuple(c for c in self.constraints
                     if isinstance(c, TierCapacity) and not c.shared)

    @property
    def shared_capacities(self) -> Tuple[TierCapacity, ...]:
        return tuple(c for c in self.constraints
                     if isinstance(c, TierCapacity) and c.shared)

    @property
    def max_read_latency(self) -> float:
        slos = [c.max_seconds for c in self.constraints
                if isinstance(c, ReadLatencySLO)]
        return min(slos) if slos else math.inf

    def capacity_array(self, t: int, doc_gb: float) -> np.ndarray:
        """(T,) per-tier document capacity (inf where unconstrained);
        shared capacities are excluded — the fleet planner splits those."""
        cap = np.full(t, np.inf)
        for c in self.capacities:
            if c.tier < t:
                cap[c.tier] = min(cap[c.tier], c.docs(doc_gb))
        return cap

    def tier_arrays(self, cm) -> Tuple[np.ndarray, np.ndarray, float]:
        """Compile this set's own constraints against one cost model:
        (cap (T,), lat (T,), slo). Topology-declared capacities are NOT
        folded in here — ``effective_capacity`` / ``shp.resolve_constraints``
        merge them with per-tier override semantics."""
        return (self.capacity_array(cm.t, cm.workload.doc_gb),
                np.asarray(cm.read_latency, np.float64),
                self.max_read_latency)

    # ---- generic feasibility (verifier / reconciliation) ----------------

    def feasible(self, cm, bounds, migrate: bool) -> bool:
        return all(c.feasible(cm, bounds, migrate) for c in self.constraints)

    def violations(self, cm, bounds, migrate: bool) -> list:
        return [c for c in self.constraints
                if not c.feasible(cm, bounds, migrate)]


def effective_capacity(cset: "ConstraintSet", cm) -> np.ndarray:
    """(T,) per-tier capacity the stack actually enforces for one model:
    topology-declared capacities (``TierSpec.capacity_docs`` — physical
    properties) always apply, and an explicit ``TierCapacity`` on tier t
    *overrides* the declaration there (``TierCapacity(t, inf)`` lifts it).
    """
    cap = cset.capacity_array(cm.t, cm.workload.doc_gb)
    declared = [c.tier for c in cset.capacities if c.tier < cm.t]
    override = np.isin(np.arange(cm.t), declared)
    return np.where(override, cap, np.minimum(cap, cm.capacity_docs))


EMPTY = ConstraintSet()


def trivial(cap, slo) -> bool:
    """True when the compiled (cap, slo) arrays constrain nothing — the
    planner then takes the unconstrained closed-form path unchanged."""
    cap_trivial = cap is None or not np.any(np.isfinite(np.asarray(cap)))
    slo_trivial = slo is None or not np.any(np.isfinite(np.asarray(slo)))
    return cap_trivial and slo_trivial
