"""Placement policies — Algorithms A/B/C of the paper as executable objects,
generalized to N-tier topologies (``core.topology``).

A policy answers, per stream index, *which tier a reservoir write goes to*,
and whether/when bulk migrations happen. Policies are produced from the
analytic plan (`shp.plan_placement`) — the paper's proactive decision — but
can also be constructed directly for ablations.

The paper's scalar changeover index r is the T=2 special case of a
non-decreasing boundary vector (b_1, ..., b_{T-1}): doc i goes to tier t
iff b_t <= i < b_{t+1}. ``Policy(r=...)`` remains the two-tier constructor.
"""
from __future__ import annotations

import math
from bisect import bisect_right
from dataclasses import dataclass
from typing import Optional, Tuple

from .costs import NTierCostModel, TwoTierCostModel
from . import compat, shp
from .compat import TIER_A, TIER_B  # noqa: F401  (canonical home: compat)


@dataclass(frozen=True)
class Policy:
    """'First b_1 to tier 0, next to tier 1, ...', optional bulk migration
    cascading residents one tier down at each boundary.

    Degenerate cases: b_1 >= N ⇒ all in tier 0; all b = 0 ⇒ everything in
    the last tier (paper eq. 22 fallback for T=2).
    """

    r: Optional[float] = None
    migrate_at_r: bool = False
    name: str = "algoC"
    boundaries: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.boundaries is None:
            if self.r is None:
                raise ValueError("need r or boundaries")
            object.__setattr__(self, "boundaries",
                               compat.boundaries_from_r(self.r))
        else:
            bs = compat.validate_boundaries(self.boundaries)
            object.__setattr__(self, "boundaries", bs)
            if self.r is None:
                object.__setattr__(self, "r", compat.r_from_boundaries(bs))

    @property
    def n_tiers(self) -> int:
        return len(self.boundaries) + 1

    def tier_of(self, index) -> int:
        """Number of boundaries at or below ``index`` (0 = tier A for the
        two-tier case)."""
        return bisect_right(self.boundaries, index)

    def migration_index(self) -> Optional[int]:
        """First migration trigger (the T=2 shim; see migration_indices)."""
        compat.deprecated("Policy.migration_index",
                          "Policy.migration_indices")
        return int(math.ceil(self.boundaries[0])) if self.migrate_at_r else None

    def migration_indices(self) -> Tuple[int, ...]:
        """Stream indices at which boundary t's cascade fires (residents of
        tier t-1 move to tier t); empty when the policy never migrates."""
        if not self.migrate_at_r:
            return ()
        return tuple(int(math.ceil(b)) for b in self.boundaries)


def all_tier_a(n: int) -> Policy:
    return Policy(r=float(n), migrate_at_r=False, name="all_a")


def all_tier_b() -> Policy:
    return Policy(r=0.0, migrate_at_r=False, name="all_b")


def from_plan(plan) -> Policy:
    """Executable policy from a ``shp.PlacementPlan`` (two-tier) or
    ``shp.NTierPlacementPlan`` (multi-threshold)."""
    if isinstance(plan, shp.NTierPlacementPlan):
        if not plan.feasible:
            raise ValueError("no feasible placement under the given "
                             "constraints — relax capacities or the SLO")
        return Policy(boundaries=plan.boundaries, migrate_at_r=plan.migrate,
                      name=plan.strategy)
    s = plan.best.strategy
    if s == "all_tier_a":
        return all_tier_a(plan.n_docs)
    if s == "all_tier_b":
        return all_tier_b()
    if s == "two_tier_no_migration":
        return Policy(r=plan.r_no_migration, migrate_at_r=False, name="algoC_nomig")
    return Policy(r=plan.r_migration, migrate_at_r=True, name="algoC_mig")


def optimal_policy(cm: TwoTierCostModel | NTierCostModel,
                   exact: bool = False, constraints=None) -> Policy:
    """The paper's end-to-end decision: closed-form thresholds, validity
    gate, single-tier fallbacks — all before the stream starts (proactive).
    ``constraints`` (a ``core.constraints.ConstraintSet``) routes through
    the resource-augmented constrained planner."""
    return from_plan(shp.plan_placement(cm, exact=exact,
                                        constraints=constraints))
