"""Placement policies — Algorithms A/B/C of the paper as executable objects.

A policy answers, per stream index, *which tier a reservoir write goes to*,
and whether/when a bulk migration happens. Policies are produced from the
analytic plan (`shp.plan_placement`) — the paper's proactive decision — but
can also be constructed directly for ablations.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from .costs import TwoTierCostModel
from . import shp

TIER_A, TIER_B = 0, 1


@dataclass(frozen=True)
class Policy:
    """'First r to A, the rest to B', optional bulk migration at i = r.

    Degenerate cases: r >= N ⇒ all-A; r <= 0 ⇒ all-B (paper eq. 22 fallback).
    """

    r: float
    migrate_at_r: bool = False
    name: str = "algoC"

    def tier_of(self, index) -> int:
        return TIER_A if index < self.r else TIER_B

    def migration_index(self) -> Optional[int]:
        return int(math.ceil(self.r)) if self.migrate_at_r else None


def all_tier_a(n: int) -> Policy:
    return Policy(r=float(n), migrate_at_r=False, name="all_a")


def all_tier_b() -> Policy:
    return Policy(r=0.0, migrate_at_r=False, name="all_b")


def from_plan(plan: "shp.PlacementPlan") -> Policy:
    s = plan.best.strategy
    if s == "all_tier_a":
        return all_tier_a(plan.n_docs)
    if s == "all_tier_b":
        return all_tier_b()
    if s == "two_tier_no_migration":
        return Policy(r=plan.r_no_migration, migrate_at_r=False, name="algoC_nomig")
    return Policy(r=plan.r_migration, migrate_at_r=True, name="algoC_mig")


def optimal_policy(cm: TwoTierCostModel, exact: bool = False) -> Policy:
    """The paper's end-to-end decision: closed-form r*, validity gate,
    single-tier fallbacks — all before the stream starts (proactive)."""
    return from_plan(shp.plan_placement(cm, exact=exact))
