# The paper's primary contribution — proactive SHP-based hot/cold tier
# placement for top-K stream workloads — plus the runtime that executes it.
from . import costs, interestingness, placement, shp, simulator, tiers, topk  # noqa: F401
from .costs import TierCosts, TwoTierCostModel, WorkloadSpec, case_study_1, case_study_2, hbm_host_preset  # noqa: F401
from .placement import Policy, optimal_policy  # noqa: F401
from .shp import PlacementPlan, plan_placement  # noqa: F401
from .tiers import ColdTier, HotTier, TieredStore  # noqa: F401
