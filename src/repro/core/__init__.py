# The paper's primary contribution — proactive SHP-based hot/cold tier
# placement for top-K stream workloads — plus the runtime that executes it,
# generalized to ordered N-tier topologies (repro.core.topology) and to
# constrained planning under per-tier capacities and read-path SLOs
# (repro.core.constraints).
from . import compat, constraints, costs, interestingness, placement, shp, simulator, tiers, topk, topology  # noqa: F401
from .constraints import Constraint, ConstraintSet, ReadLatencySLO, TierCapacity  # noqa: F401
from .costs import NTierCostModel, TierCosts, TwoTierCostModel, WorkloadSpec, case_study_1, case_study_2, hbm_host_preset  # noqa: F401
from .placement import Policy, optimal_policy  # noqa: F401
from .shp import NTierPlacementPlan, PlacementPlan, plan_placement, plan_placement_ntier  # noqa: F401
from .tiers import ColdTier, HotTier, TieredStore  # noqa: F401
from .topology import TierSpec, TierTopology, aws_archive_tiering, aws_efs_s3_glacier, aws_s3_tiering, hbm_dram_disk_preset  # noqa: F401
