"""Analytic model: the Secretary Hiring Problem adapted to tiered top-K
storage (paper §§V–VII, equations 1–22).

All expectations assume documents arrive in random order with respect to
their interestingness rank (the paper's i.u.d. assumption, validated
trace-driven in §VIII / our ``core.simulator``).

Exact forms use harmonic partial sums; ``*_approx`` forms use the paper's
logarithmic approximations (used by the case-study tables).

``plan_placement`` decides one stream; ``repro.streams.planner.plan_fleet``
is the vectorized fleet version (same candidates, same precedence, numpy
arrays over M heterogeneous cost models).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Literal

import numpy as np

from .costs import TwoTierCostModel

EULER_GAMMA = 0.5772156649015329


# ---------------------------------------------------------------------------
# §V — classic SHP (Algorithm A)
# ---------------------------------------------------------------------------

def classic_r_optimal(n: int) -> float:
    """Eq. 2: observe the first N/e candidates, then take the next best."""
    return n / math.e


def classic_p_best() -> float:
    """Eq. 3."""
    return 1.0 / math.e


def classic_expected_writes() -> float:
    """Eq. 4: hire (write) exactly once."""
    return 1.0


# ---------------------------------------------------------------------------
# §§VI–VII — write/read probabilities under simple overwrite (Algorithms B/C)
# ---------------------------------------------------------------------------

def p_write(i, k: int = 1):
    """Eqs. 5, 9, 10: P(doc at 0-based index ``i`` is in the top-K of the
    first i+1 docs) = min(1, K/(i+1)). Vectorized over ``i``."""
    i = np.asarray(i, dtype=np.float64)
    return np.minimum(1.0, k / (i + 1.0))


def harmonic(n) -> np.ndarray:
    """H_n for integer n >= 0 (H_0 = 0), exact via cumsum for small n,
    asymptotic for large n."""
    n = np.asarray(n, dtype=np.float64)
    small = n < 1e6
    out = np.where(
        n > 0,
        np.log(np.maximum(n, 1.0)) + EULER_GAMMA + 1.0 / (2.0 * np.maximum(n, 1.0))
        - 1.0 / (12.0 * np.maximum(n, 1.0) ** 2),
        0.0,
    )
    if np.any(small & (n > 0)):
        # exact for the small regime
        nmax = int(np.max(np.where(small, n, 0)))
        table = np.concatenate([[0.0], np.cumsum(1.0 / np.arange(1, nmax + 1))])
        idx = np.clip(n.astype(np.int64), 0, nmax)
        out = np.where(small, table[idx], out)
    return out


def expected_cum_writes(i, k: int = 1) -> np.ndarray:
    """Eqs. 6, 11, 12 (exact): E[# writes among docs 0..i]
    = sum_{j<=i} min(1, K/(j+1)) = min(i+1, K) + K·(H_{i+1} − H_K)⁺."""
    i = np.asarray(i, dtype=np.float64)
    n_seen = i + 1.0
    head = np.minimum(n_seen, float(k))
    tail = k * np.maximum(harmonic(n_seen) - harmonic(float(k)), 0.0)
    return head + tail


def expected_cum_writes_approx(i, k: int = 1) -> np.ndarray:
    """Eq. 12 as printed: K + K·ln((i+1)/K)  (for i+1 >= K); eq. 7 for K=1."""
    i = np.asarray(i, dtype=np.float64)
    n_seen = i + 1.0
    return np.where(n_seen <= k, n_seen, k + k * np.log(n_seen / k))


def expected_cum_writes_batched(i, k: int, batch: int) -> np.ndarray:
    """Batched-stream generalization (beyond paper; DESIGN.md §3): when the
    reservoir merges ``batch`` docs at once, doc i is written iff it is in
    the top-K of the stream prefix ending at its *batch boundary*, so
    E[# writes ≤ i] = Σ_j min(1, K / batch_end(j)). batch=1 recovers eq. 11/12.
    """
    i = np.asarray(i, dtype=np.int64)
    imax = int(np.max(i))
    j = np.arange(imax + 1, dtype=np.float64)
    batch_end = (np.floor(j / batch) + 1.0) * batch
    per = np.minimum(1.0, k / batch_end)
    cum = np.cumsum(per)
    return cum[i]


def expected_writes_split(n: int, k: int, r: float, exact: bool = False):
    """Expected number of reservoir writes landing in tier A (stream index
    < r) vs tier B (index >= r), Algorithm C.

    Approx (paper): writes_A = K(1 + ln(r/K)), writes_B = K·ln(N/r).
    """
    r = float(min(max(r, 1.0), n))
    if exact:
        wa = float(expected_cum_writes(r - 1.0, k))
        wtot = float(expected_cum_writes(n - 1.0, k))
        return wa, wtot - wa
    if r <= k:
        wa = r
        wb = (k - r) + k * math.log(n / k) if k < n else 0.0
        # below-K regime: first K docs always write
        wb = (k - r) + k * (math.log(n) - math.log(k))
        return wa, wb
    wa = k * (1.0 + math.log(r / k))
    wb = k * (math.log(n) - math.log(r))
    return wa, wb


# ---------------------------------------------------------------------------
# §VII — expected costs of the two strategies and closed-form r*
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StrategyCost:
    strategy: str
    r_over_n: float
    total: float
    writes_a: float
    writes_b: float
    reads: float
    storage: float
    migration: float

    def breakdown(self) -> dict:
        return {
            "strategy": self.strategy, "r_over_n": self.r_over_n,
            "total": self.total, "writes_a": self.writes_a,
            "writes_b": self.writes_b, "reads": self.reads,
            "storage": self.storage, "migration": self.migration,
        }


def cost_no_migration(cm: TwoTierCostModel, r: float, exact: bool = False) -> StrategyCost:
    """Eqs. 13–16 + most-expensive-tier rental upper bound (DESIGN §1.1)."""
    wl = cm.workload
    n, k = wl.n_docs, wl.k
    r = float(np.clip(r, 1.0, n))
    wa, wb = expected_writes_split(n, k, r, exact=exact)
    writes_a, writes_b = wa * cm.cw_a, wb * cm.cw_b
    rn = r / n
    # eq. 15 (sign-consistent form): survivors are i.u.d. over the stream,
    # those with index < r live in A.
    reads = wl.reads_per_window * k * (rn * cm.cr_a + (1.0 - rn) * cm.cr_b)
    storage = k * cm.cs_max  # bound, constant in r
    total = writes_a + writes_b + reads + storage
    return StrategyCost("two_tier_no_migration", rn, total, writes_a, writes_b,
                        reads, storage, 0.0)


def cost_with_migration(cm: TwoTierCostModel, r: float, exact: bool = False) -> StrategyCost:
    """Eqs. 18–20: all docs migrate A→B at i=r; rental splits r/N; the final
    read is from B only and is *not* part of eq. 20 (paper convention)."""
    wl = cm.workload
    n, k = wl.n_docs, wl.k
    r = float(np.clip(r, 1.0, n))
    wa, wb = expected_writes_split(n, k, r, exact=exact)
    writes_a, writes_b = wa * cm.cw_a, wb * cm.cw_b
    rn = r / n
    storage = k * (rn * cm.cs_a + (1.0 - rn) * cm.cs_b)  # eq. 18
    migration = k * cm.migration_per_doc  # eq. 19, constant in r
    total = writes_a + writes_b + storage + migration  # eq. 20
    return StrategyCost("two_tier_migration", rn, total, writes_a, writes_b,
                        0.0, storage, migration)


def cost_single_tier(cm: TwoTierCostModel, tier: Literal["a", "b"],
                     exact: bool = False) -> StrategyCost:
    wl = cm.workload
    n, k = wl.n_docs, wl.k
    if exact:
        w = float(expected_cum_writes(n - 1.0, k))
    else:
        w = k * (1.0 + math.log(n / k))
    if tier == "a":
        writes, reads, storage = w * cm.cw_a, wl.reads_per_window * k * cm.cr_a, k * cm.cs_a
        return StrategyCost("all_tier_a", 1.0, writes + reads + storage,
                            writes, 0.0, reads, storage, 0.0)
    writes, reads, storage = w * cm.cw_b, wl.reads_per_window * k * cm.cr_b, k * cm.cs_b
    return StrategyCost("all_tier_b", 0.0, writes + reads + storage,
                        0.0, writes, reads, storage, 0.0)


def r_optimal_no_migration(cm: TwoTierCostModel) -> float:
    """Eq. 17: r*/N = (cw_A − cw_B) / (cr_B − cr_A). Returns r (not r/N);
    NaN if the denominator vanishes."""
    num = cm.cw_a - cm.cw_b
    den = (cm.cr_b - cm.cr_a) * cm.workload.reads_per_window
    if den == 0.0:
        return float("nan")
    return (num / den) * cm.workload.n_docs


def r_optimal_migration(cm: TwoTierCostModel) -> float:
    """Eq. 21: r*/N = (cw_A − cw_B) / (cs_B − cs_A)."""
    num = cm.cw_a - cm.cw_b
    den = cm.cs_b - cm.cs_a
    if den == 0.0:
        return float("nan")
    return (num / den) * cm.workload.n_docs


def r_is_valid(cm: TwoTierCostModel, r: float) -> bool:
    """Eq. 22: K < r* < N — plus the second-order condition the paper leaves
    implicit: d²E/dr² = −K(cw_A − cw_B)/r² > 0 requires cw_A < cw_B (tier A
    must be the write-cheap tier, else the stationary point is a *maximum*)."""
    return (math.isfinite(r) and cm.workload.k < r < cm.workload.n_docs
            and cm.cw_a < cm.cw_b)


@dataclass(frozen=True)
class PlacementPlan:
    """Outcome of the paper's decision procedure: the minimum-expected-cost
    strategy among {two-tier no-mig @ r*, two-tier mig @ r*, all-A, all-B}."""

    best: StrategyCost
    candidates: tuple
    r_no_migration: float
    r_migration: float
    n_docs: int

    @property
    def strategy(self) -> str:
        return self.best.strategy

    @property
    def r(self) -> float:
        """Absolute changeover index of the chosen strategy (N for all-A,
        0 for all-B)."""
        return self.best.r_over_n * self.n_docs

    @property
    def migrate(self) -> bool:
        return self.best.strategy == "two_tier_migration"


def plan_placement(cm: TwoTierCostModel, exact: bool = False) -> PlacementPlan:
    """Evaluate every strategy (respecting the eq. 22 validity gate) and pick
    the cheapest — this is the proactive decision made before the stream."""
    cands = [cost_single_tier(cm, "a", exact), cost_single_tier(cm, "b", exact)]
    r_nm = r_optimal_no_migration(cm)
    r_mg = r_optimal_migration(cm)
    if r_is_valid(cm, r_nm):
        cands.append(cost_no_migration(cm, r_nm, exact))
    if r_is_valid(cm, r_mg):
        cands.append(cost_with_migration(cm, r_mg, exact))
    best = min(cands, key=lambda s: s.total)
    return PlacementPlan(best=best, candidates=tuple(cands),
                         r_no_migration=r_nm, r_migration=r_mg,
                         n_docs=cm.workload.n_docs)


def cost_curve(cm: TwoTierCostModel, migrate: bool, num: int = 512) -> np.ndarray:
    """Expected total cost for r swept over (K, N) — Figures 4 & 5.
    Returns array (num, 2) of [r/N, cost]."""
    wl = cm.workload
    rs = np.linspace(max(wl.k + 1, 1), wl.n_docs - 1, num)
    fn = cost_with_migration if migrate else cost_no_migration
    out = np.array([[r / wl.n_docs, fn(cm, float(r)).total] for r in rs])
    return out
