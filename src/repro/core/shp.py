"""Analytic model: the Secretary Hiring Problem adapted to tiered top-K
storage (paper §§V–VII, equations 1–22).

All expectations assume documents arrive in random order with respect to
their interestingness rank (the paper's i.u.d. assumption, validated
trace-driven in §VIII / our ``core.simulator``).

Exact forms use harmonic partial sums; ``*_approx`` forms use the paper's
logarithmic approximations (used by the case-study tables).

``plan_placement`` decides one stream; ``repro.streams.planner.plan_fleet``
is the vectorized fleet version (same candidates, same precedence, numpy
arrays over M heterogeneous cost models).
"""
from __future__ import annotations

import functools
import itertools
import math
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence, Tuple

import numpy as np

from . import constraints as constraints_mod
from .constraints import ConstraintSet, ReadLatencySLO, TierCapacity
from .costs import NTierCostModel, TwoTierCostModel

EULER_GAMMA = 0.5772156649015329


# ---------------------------------------------------------------------------
# §V — classic SHP (Algorithm A)
# ---------------------------------------------------------------------------

def classic_r_optimal(n: int) -> float:
    """Eq. 2: observe the first N/e candidates, then take the next best."""
    return n / math.e


def classic_p_best() -> float:
    """Eq. 3."""
    return 1.0 / math.e


def classic_expected_writes() -> float:
    """Eq. 4: hire (write) exactly once."""
    return 1.0


# ---------------------------------------------------------------------------
# §§VI–VII — write/read probabilities under simple overwrite (Algorithms B/C)
# ---------------------------------------------------------------------------

def p_write(i, k: int = 1):
    """Eqs. 5, 9, 10: P(doc at 0-based index ``i`` is in the top-K of the
    first i+1 docs) = min(1, K/(i+1)). Vectorized over ``i``."""
    i = np.asarray(i, dtype=np.float64)
    return np.minimum(1.0, k / (i + 1.0))


def harmonic(n) -> np.ndarray:
    """H_n for integer n >= 0 (H_0 = 0), exact via cumsum for small n,
    asymptotic for large n."""
    n = np.asarray(n, dtype=np.float64)
    small = n < 1e6
    out = np.where(
        n > 0,
        np.log(np.maximum(n, 1.0)) + EULER_GAMMA + 1.0 / (2.0 * np.maximum(n, 1.0))
        - 1.0 / (12.0 * np.maximum(n, 1.0) ** 2),
        0.0,
    )
    if np.any(small & (n > 0)):
        # exact for the small regime
        nmax = int(np.max(np.where(small, n, 0)))
        table = np.concatenate([[0.0], np.cumsum(1.0 / np.arange(1, nmax + 1))])
        idx = np.clip(n.astype(np.int64), 0, nmax)
        out = np.where(small, table[idx], out)
    return out


def expected_cum_writes(i, k: int = 1) -> np.ndarray:
    """Eqs. 6, 11, 12 (exact): E[# writes among docs 0..i]
    = sum_{j<=i} min(1, K/(j+1)) = min(i+1, K) + K·(H_{i+1} − H_K)⁺."""
    i = np.asarray(i, dtype=np.float64)
    n_seen = i + 1.0
    head = np.minimum(n_seen, float(k))
    tail = k * np.maximum(harmonic(n_seen) - harmonic(float(k)), 0.0)
    return head + tail


def expected_cum_writes_approx(i, k: int = 1) -> np.ndarray:
    """Eq. 12 as printed: K + K·ln((i+1)/K)  (for i+1 >= K); eq. 7 for K=1."""
    i = np.asarray(i, dtype=np.float64)
    n_seen = i + 1.0
    return np.where(n_seen <= k, n_seen, k + k * np.log(n_seen / k))


def expected_cum_writes_batched(i, k: int, batch: int) -> np.ndarray:
    """Batched-stream generalization (beyond paper; DESIGN.md §3): when the
    reservoir merges ``batch`` docs at once, doc i is written iff it is in
    the top-K of the stream prefix ending at its *batch boundary*, so
    E[# writes ≤ i] = Σ_j min(1, K / batch_end(j)). batch=1 recovers eq. 11/12.
    """
    i = np.asarray(i, dtype=np.int64)
    imax = int(np.max(i))
    j = np.arange(imax + 1, dtype=np.float64)
    batch_end = (np.floor(j / batch) + 1.0) * batch
    per = np.minimum(1.0, k / batch_end)
    cum = np.cumsum(per)
    return cum[i]


def expected_writes_split(n: int, k: int, r: float, exact: bool = False):
    """Expected number of reservoir writes landing in tier A (stream index
    < r) vs tier B (index >= r), Algorithm C.

    Approx (paper): writes_A = K(1 + ln(r/K)), writes_B = K·ln(N/r).
    """
    r = float(min(max(r, 1.0), n))
    if exact:
        wa = float(expected_cum_writes(r - 1.0, k))
        wtot = float(expected_cum_writes(n - 1.0, k))
        return wa, wtot - wa
    if r <= k:
        wa = r
        wb = (k - r) + k * math.log(n / k) if k < n else 0.0
        # below-K regime: first K docs always write
        wb = (k - r) + k * (math.log(n) - math.log(k))
        return wa, wb
    wa = k * (1.0 + math.log(r / k))
    wb = k * (math.log(n) - math.log(r))
    return wa, wb


# ---------------------------------------------------------------------------
# §VII — expected costs of the two strategies and closed-form r*
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StrategyCost:
    strategy: str
    r_over_n: float
    total: float
    writes_a: float
    writes_b: float
    reads: float
    storage: float
    migration: float

    def breakdown(self) -> dict:
        return {
            "strategy": self.strategy, "r_over_n": self.r_over_n,
            "total": self.total, "writes_a": self.writes_a,
            "writes_b": self.writes_b, "reads": self.reads,
            "storage": self.storage, "migration": self.migration,
        }


def cost_no_migration(cm: TwoTierCostModel, r: float, exact: bool = False) -> StrategyCost:
    """Eqs. 13–16 + most-expensive-tier rental upper bound (DESIGN §1.1)."""
    wl = cm.workload
    n, k = wl.n_docs, wl.k
    r = float(np.clip(r, 1.0, n))
    wa, wb = expected_writes_split(n, k, r, exact=exact)
    writes_a, writes_b = wa * cm.cw_a, wb * cm.cw_b
    rn = r / n
    # eq. 15 (sign-consistent form): survivors are i.u.d. over the stream,
    # those with index < r live in A.
    reads = wl.reads_per_window * k * (rn * cm.cr_a + (1.0 - rn) * cm.cr_b)
    storage = k * cm.cs_max  # bound, constant in r
    total = writes_a + writes_b + reads + storage
    return StrategyCost("two_tier_no_migration", rn, total, writes_a, writes_b,
                        reads, storage, 0.0)


def cost_with_migration(cm: TwoTierCostModel, r: float, exact: bool = False) -> StrategyCost:
    """Eqs. 18–20: all docs migrate A→B at i=r; rental splits r/N; the final
    read is from B only and is *not* part of eq. 20 (paper convention)."""
    wl = cm.workload
    n, k = wl.n_docs, wl.k
    r = float(np.clip(r, 1.0, n))
    wa, wb = expected_writes_split(n, k, r, exact=exact)
    writes_a, writes_b = wa * cm.cw_a, wb * cm.cw_b
    rn = r / n
    storage = k * (rn * cm.cs_a + (1.0 - rn) * cm.cs_b)  # eq. 18
    migration = k * cm.migration_per_doc  # eq. 19, constant in r
    total = writes_a + writes_b + storage + migration  # eq. 20
    return StrategyCost("two_tier_migration", rn, total, writes_a, writes_b,
                        0.0, storage, migration)


def cost_single_tier(cm: TwoTierCostModel, tier: Literal["a", "b"],
                     exact: bool = False) -> StrategyCost:
    wl = cm.workload
    n, k = wl.n_docs, wl.k
    if exact:
        w = float(expected_cum_writes(n - 1.0, k))
    else:
        w = k * (1.0 + math.log(n / k))
    if tier == "a":
        writes, reads, storage = w * cm.cw_a, wl.reads_per_window * k * cm.cr_a, k * cm.cs_a
        return StrategyCost("all_tier_a", 1.0, writes + reads + storage,
                            writes, 0.0, reads, storage, 0.0)
    writes, reads, storage = w * cm.cw_b, wl.reads_per_window * k * cm.cr_b, k * cm.cs_b
    return StrategyCost("all_tier_b", 0.0, writes + reads + storage,
                        0.0, writes, reads, storage, 0.0)


def r_optimal_no_migration(cm: TwoTierCostModel) -> float:
    """Eq. 17: r*/N = (cw_A − cw_B) / (cr_B − cr_A). Returns r (not r/N);
    NaN if the denominator vanishes."""
    num = cm.cw_a - cm.cw_b
    den = (cm.cr_b - cm.cr_a) * cm.workload.reads_per_window
    if den == 0.0:
        return float("nan")
    return (num / den) * cm.workload.n_docs


def r_optimal_migration(cm: TwoTierCostModel) -> float:
    """Eq. 21: r*/N = (cw_A − cw_B) / (cs_B − cs_A)."""
    num = cm.cw_a - cm.cw_b
    den = cm.cs_b - cm.cs_a
    if den == 0.0:
        return float("nan")
    return (num / den) * cm.workload.n_docs


def r_is_valid(cm: TwoTierCostModel, r: float) -> bool:
    """Eq. 22: K < r* < N — plus the second-order condition the paper leaves
    implicit: d²E/dr² = −K(cw_A − cw_B)/r² > 0 requires cw_A < cw_B (tier A
    must be the write-cheap tier, else the stationary point is a *maximum*)."""
    return (math.isfinite(r) and cm.workload.k < r < cm.workload.n_docs
            and cm.cw_a < cm.cw_b)


@dataclass(frozen=True)
class PlacementPlan:
    """Outcome of the paper's decision procedure: the minimum-expected-cost
    strategy among {two-tier no-mig @ r*, two-tier mig @ r*, all-A, all-B}."""

    best: StrategyCost
    candidates: tuple
    r_no_migration: float
    r_migration: float
    n_docs: int

    @property
    def strategy(self) -> str:
        return self.best.strategy

    @property
    def r(self) -> float:
        """Absolute changeover index of the chosen strategy (N for all-A,
        0 for all-B)."""
        return self.best.r_over_n * self.n_docs

    @property
    def migrate(self) -> bool:
        return self.best.strategy == "two_tier_migration"


def plan_placement(cm, exact: bool = False,
                   constraints: Optional[ConstraintSet] = None):
    """Evaluate every strategy (respecting the eq. 22 validity gate) and pick
    the cheapest — this is the proactive decision made before the stream.

    Accepts a ``TwoTierCostModel`` (returns the paper's ``PlacementPlan``,
    unchanged) or an ``NTierCostModel`` (returns ``NTierPlacementPlan`` via
    the multi-threshold solver). A non-empty ``constraints`` routes
    two-tier models through the constrained N-tier path (returning an
    ``NTierPlacementPlan``)."""
    if isinstance(cm, NTierCostModel):
        return plan_placement_ntier(cm, constraints=constraints)
    if constraints is not None and not constraints.empty:
        if exact:
            raise ValueError("the constrained planner uses the paper's "
                             "approximate (logarithmic) forms — exact=True "
                             "is not supported with constraints")
        if any(isinstance(c, ReadLatencySLO) for c in constraints):
            raise ValueError(
                "two-tier legacy cost models carry no read latencies, so a "
                "ReadLatencySLO would be vacuous — build an NTierCostModel "
                "with TierSpec(read_latency_s=...) instead")
        return plan_placement_ntier(cm.as_ntier(), constraints=constraints)
    cands = [cost_single_tier(cm, "a", exact), cost_single_tier(cm, "b", exact)]
    r_nm = r_optimal_no_migration(cm)
    r_mg = r_optimal_migration(cm)
    if r_is_valid(cm, r_nm):
        cands.append(cost_no_migration(cm, r_nm, exact))
    if r_is_valid(cm, r_mg):
        cands.append(cost_with_migration(cm, r_mg, exact))
    best = min(cands, key=lambda s: s.total)
    return PlacementPlan(best=best, candidates=tuple(cands),
                         r_no_migration=r_nm, r_migration=r_mg,
                         n_docs=cm.workload.n_docs)


# ---------------------------------------------------------------------------
# N-tier generalization (repro.core.topology): the multi-threshold plan
# ---------------------------------------------------------------------------
#
# Doc i goes to tier t iff b_t <= i < b_{t+1} (b_0 = 0, b_T = N). Both
# strategy families have *separable* expected cost in the boundary vector:
#
#   cost(b) = sum_j f_j(b_j) + const,   f_j(b) = (cw_{j-1} - cw_j)·W(b)
#             + (lin_{j-1} - lin_j)·b [+ min(b, K)·(cr_{j-1} + cw_j)]
#
# where W(b) = E[writes among the first b docs] (eq. 12's approximation)
# and lin_t is the per-index linear coefficient (reads_per_window·K/N·cr_t
# for no-migration, K/N·cs_t for migration; the bracketed eq. 19 charge
# only for the migration family). Each f_j is piecewise {linear below K,
# a + c·ln b above K}, so on any interval its minimum sits at an endpoint,
# at the kink b = K, or at the stationary point — which is exactly the
# eq. 17/21 crossover between the two tiers the boundary separates. Under
# the monotonicity constraint b_1 <= ... <= b_{T-1}, boundaries pool into
# groups of equal value whose pooled coefficients telescope to the
# crossover between the *outer* tier pair — i.e. collapsing the degenerate
# tiers in between (the N-tier form of eq. 22's validity gate). Hence the
# finite candidate set {0, K, N} ∪ {crossover(s, t) for all tier pairs}
# contains an exact optimum, found by a tiny monotone DP per stream.
# ``brute_force_plan_ntier`` verifies this against grid search.

MAX_TIERS = 8  # 2^T candidate subsets — plenty for real hierarchies


def _w_approx(b, k):
    """Approximate cumulative write law (eq. 12 as printed): W(b) = b for
    b <= K, else K(1 + ln(b/K)). Vectorized; W(0) = 0."""
    b = np.asarray(b, np.float64)
    k = np.asarray(k, np.float64)
    safe = np.maximum(b, 1e-300)
    return np.where(b <= k, b, k * (1.0 + np.log(safe / k)))


def _cummin_with_arg(g: np.ndarray):
    """Row-wise running minimum of ``g`` (M, C) and the column index where
    each running minimum was first attained."""
    m, c = g.shape
    vals = np.empty_like(g)
    args = np.empty((m, c), np.int64)
    best = g[:, 0].copy()
    barg = np.zeros(m, np.int64)
    for j in range(c):
        upd = g[:, j] < best
        best = np.where(upd, g[:, j], best)
        barg = np.where(upd, j, barg)
        vals[:, j] = best
        args[:, j] = barg
    return vals, args


def _crossover_candidates(cw_s, lin_s, kf, lo, hi):
    """The eq. 17/21-style pairwise-crossover candidate columns shared by
    both strategy families: one stationary point per tier pair, clipped
    into the feasible boundary range."""
    out = []
    ts = cw_s.shape[1]
    for s, t in itertools.combinations(range(ts), 2):
        with np.errstate(divide="ignore", invalid="ignore"):
            b = kf * (cw_s[:, s] - cw_s[:, t]) / (lin_s[:, t] - lin_s[:, s])
        b = np.where(np.isfinite(b), b, 0.0)
        out.append(np.clip(b, lo, hi))
    return out


@dataclass
class BoundaryObjective:
    """One strategy family's separable boundary objective over a tier
    subset, plus the feasibility structure a ``ConstraintSet`` induces.

    The cost side is the same piecewise form the unconstrained planner
    minimizes: per-boundary terms ``f_j(b) = Δcw_j·W(b) + Δlin_j·b`` on a
    finite candidate grid (endpoints, the b=K kink, pairwise crossovers,
    and — when constrained — capacity corners and SLO-tight points). The
    constraint side compiles to three mechanisms the solver understands:

    * per-boundary masks (first/last-tier capacity, folded into the terms
      as +inf),
    * pairwise lower bounds ``b_{j-1} >= lb_j(b_j)`` (middle-tier
      capacity: ``min(b_j,K)(1 − b_{j-1}/b_j) <= C``),
    * a quantized latency budget (the read-path SLO, telescoped to a
      per-boundary consumption ``δ_j(b) = b·(lat_{j-1}−lat_j)/N``).

    With no constraints all three collapse and the solver reduces to the
    unconstrained monotone DP bit-exactly.
    """

    cw_s: np.ndarray  # (M, Ts)
    lin_s: np.ndarray  # (M, Ts)
    n: np.ndarray  # (M,)
    k: np.ndarray  # (M,)
    interior: bool = False  # migration family: boundaries in [K, N)
    cap_s: Optional[np.ndarray] = None  # (M, Ts) per-tier doc capacity
    lat_s: Optional[np.ndarray] = None  # (M, Ts) per-tier read latency
    slo: Optional[np.ndarray] = None  # (M,) expected-read-latency bound
    qmax: int = 48  # latency-budget quantization levels

    def __post_init__(self):
        m, ts = self.cw_s.shape
        self.m, self.ts = m, ts
        self.kf = np.asarray(self.k, np.float64)
        self.nf = np.asarray(self.n, np.float64)
        if self.cap_s is None:
            self.cap_s = np.full((m, ts), np.inf)
        if self.lat_s is None:
            self.lat_s = np.zeros((m, ts))
        if self.slo is None:
            self.slo = np.full(m, np.inf)
        self.lo = np.minimum(self.kf, self.nf) if self.interior \
            else np.zeros(m)
        self.hi = np.nextafter(self.nf, 0.0) if self.interior else self.nf

    @property
    def constrained(self) -> bool:
        return bool(np.any(np.isfinite(self.cap_s))
                    or np.any(np.isfinite(self.slo)))

    def subset_feasible(self) -> np.ndarray:
        """(M,) boundary-free feasibility of this family/subset.

        Single-tier subsets hold the whole reservoir: occupancy K and the
        final read from that tier. The migration family holds the whole
        reservoir in every used tier (boundaries gated to [K, N)), so a
        capacity below K on any used tier — or a last-tier latency above
        the SLO — kills the whole cascade subset.
        """
        kmin = np.minimum(self.kf, self.nf)
        tol = 1.0 + 1e-12
        if self.ts == 1:
            return ((kmin <= self.cap_s[:, 0] * tol)
                    & (self.lat_s[:, 0] <= self.slo * tol))
        if self.interior:
            return (np.all(self.cap_s * tol >= kmin[:, None], axis=1)
                    & (self.lat_s[:, -1] <= self.slo * tol))
        return np.ones(self.m, bool)

    def candidates(self) -> np.ndarray:
        """(M, C) sorted candidate grid: {lo, K, hi} ∪ pairwise crossovers
        ∪ (when constrained) capacity corners and SLO-tight points."""
        lo, hi, kf, nf = self.lo, self.hi, self.kf, self.nf
        cands = [lo, np.minimum(kf, nf), hi]
        cands += _crossover_candidates(self.cw_s, self.lin_s, kf, lo, hi)
        for j in range(self.ts):
            cap_j = self.cap_s[:, j]
            fin = np.isfinite(cap_j)
            if np.any(fin):
                # first-tier corner b = C_j and last-tier corner
                # b = N(1 − C_j/K) — where the capacity masks go tight
                cands.append(np.clip(np.where(fin, cap_j, 0.0), lo, hi))
                with np.errstate(invalid="ignore"):
                    tight = nf * (1.0 - cap_j / kf)
                cands.append(np.clip(np.where(fin, tight, 0.0), lo, hi))
        if np.any(np.isfinite(self.slo)) and not self.interior:
            for s, t in itertools.combinations(range(self.ts), 2):
                dl = self.lat_s[:, s] - self.lat_s[:, t]
                with np.errstate(divide="ignore", invalid="ignore"):
                    b = nf * (self.slo - self.lat_s[:, t]) / dl
                b = np.where(np.isfinite(b), b, 0.0)
                cands.append(np.clip(b, lo, hi))
        if not self.interior:
            cands += self._middle_cap_stationary(lo, hi)
        return np.sort(np.stack(cands, axis=1), axis=1)

    def _middle_cap_stationary(self, lo, hi) -> list:
        """Stationary points along an *active* middle-tier capacity curve.

        When tier ``idx`` (between boundaries idx and idx+1) binds with
        C < K, the feasible frontier is b_idx = γ·b_{idx+1} with
        γ = 1 − C/K (for b_{idx+1} > K). Substituting into the two
        boundary terms gives a 1-D objective whose stationary point is
        closed-form on each W-branch; both it and its γ-image join the
        candidate grid so the enumerated solve stays exact when the
        constraint is active between two interior boundaries.
        """
        out = []
        kf = self.kf
        for idx in range(1, self.ts - 1):
            cap_m = self.cap_s[:, idx]
            active = np.isfinite(cap_m) & (cap_m < kf)
            if not np.any(active):
                continue
            with np.errstate(divide="ignore", invalid="ignore"):
                gamma = 1.0 - cap_m / kf
            dcw_p = self.cw_s[:, idx - 1] - self.cw_s[:, idx]
            dcw_d = self.cw_s[:, idx] - self.cw_s[:, idx + 1]
            dlin_p = self.lin_s[:, idx - 1] - self.lin_s[:, idx]
            dlin_d = self.lin_s[:, idx] - self.lin_s[:, idx + 1]
            with np.errstate(divide="ignore", invalid="ignore"):
                # both boundaries on the log branch (b_prev, b_dest > K)
                b_log = -kf * (dcw_p + dcw_d) / (gamma * dlin_p + dlin_d)
                # prev on the linear branch (b_prev <= K < b_dest)
                b_mix = -kf * dcw_d / (gamma * (dcw_p + dlin_p) + dlin_d)
            for b in (b_log, b_mix):
                b = np.where(active & np.isfinite(b) & (b > 0), b, 0.0)
                out.append(np.clip(b, lo, hi))
                out.append(np.clip(b * np.where(active, gamma, 0.0), lo, hi))
        return out

    def terms(self, c: np.ndarray) -> list:
        """Per-boundary cost terms f_j on grid ``c``, with the first/last
        tier capacity masks folded in as +inf."""
        w = _w_approx(c, self.kf[:, None])
        fs = []
        for j in range(1, self.ts):
            f = ((self.cw_s[:, j - 1] - self.cw_s[:, j])[:, None] * w
                 + (self.lin_s[:, j - 1] - self.lin_s[:, j])[:, None] * c)
            fs.append(f)
        if self.constrained and not self.interior:
            tol = 1.0 + 1e-12
            first_ok = (np.minimum(c, self.kf[:, None])
                        <= self.cap_s[:, 0][:, None] * tol)
            fs[0] = np.where(first_ok, fs[0], np.inf)
            last_occ = (np.minimum(self.nf, self.kf)[:, None]
                        * (1.0 - c / self.nf[:, None]))
            last_ok = last_occ <= self.cap_s[:, -1][:, None] * tol
            fs[-1] = np.where(last_ok, fs[-1], np.inf)
        return fs

    def pair_lower_bound(self, idx: int, c: np.ndarray):
        """Lower bound on boundary ``idx`` given boundary ``idx+1`` = c —
        the middle-tier capacity ``min(c,K)(1 − b_prev/c) <= C`` solved
        for b_prev. None when tier ``idx`` is uncapped (transition is then
        the plain running minimum)."""
        if self.interior:
            return None
        cap_m = self.cap_s[:, idx]
        if not np.any(np.isfinite(cap_m)):
            return None
        with np.errstate(divide="ignore", invalid="ignore"):
            slack = 1.0 - cap_m[:, None] / np.minimum(c, self.kf[:, None])
            lb = c * np.maximum(0.0, slack)
        lb = np.where(np.isfinite(cap_m)[:, None] & (c > 0),
                      np.nan_to_num(lb, nan=0.0, posinf=0.0), 0.0)
        return lb

    def budget_deltas(self, c: np.ndarray):
        """Exact per-boundary latency consumption δ_j(b) = b·(lat_{j-1} −
        lat_j)/N (the telescoped E[read latency] minus the lat_last
        constant) and the per-stream budget Σδ_j must respect:
        rhs = slo − lat_last. None when no SLO is active (or for the
        migration family, whose final read latency is a subset constant).
        """
        if self.interior or not np.any(np.isfinite(self.slo)):
            return None
        deltas = [c * ((self.lat_s[:, j - 1] - self.lat_s[:, j])
                       / self.nf)[:, None]
                  for j in range(1, self.ts)]
        rhs = self.slo - self.lat_s[:, -1]
        return deltas, rhs

    def budget(self, c: np.ndarray):
        """Quantized read-latency budget for the resource-augmented DP
        (used for deep hierarchies, J >= 4 boundaries): per-boundary
        integer consumption levels (conservatively rounded up, so
        DP-feasible implies truly feasible) and per-stream level caps.
        None when no SLO is active or for the migration family (whose
        final read latency is a subset-level constant)."""
        exact = self.budget_deltas(c)
        if exact is None:
            return None
        deltas, rhs_exact = exact
        dmin = [d.min(axis=1) for d in deltas]
        dmax = [d.max(axis=1) for d in deltas]
        total_range = sum(dx - dn for dx, dn in zip(dmax, dmin))
        denom = max(self.qmax - (self.ts - 1), 1)
        step = total_range / denom
        levels = []
        for d, dn in zip(deltas, dmin):
            with np.errstate(divide="ignore", invalid="ignore"):
                lv = np.ceil((d - dn[:, None]) / step[:, None] - 1e-9)
            lv = np.where(step[:, None] > 0, lv, 0.0)
            levels.append(np.clip(lv, 0, self.qmax).astype(np.int64))
        rhs = rhs_exact - sum(dmin)
        with np.errstate(divide="ignore", invalid="ignore"):
            cap_lv = np.floor(rhs / step + 1e-9)
        cap_lv = np.where(step > 0, cap_lv,
                          np.where(rhs >= -1e-12, self.qmax + 1.0, -1.0))
        cap_lv = np.where(np.isfinite(self.slo), cap_lv, self.qmax + 1.0)
        cap_levels = np.clip(cap_lv, -1, self.qmax + 1).astype(np.int64)
        return levels, cap_levels, self.qmax + 2


def _solve_unconstrained(fs, c):
    """The original monotone DP: running minima left to right (first
    minimum wins), backtracked to the optimal boundary vector."""
    m = c.shape[0]
    g = fs[0]
    args = []
    for j in range(1, len(fs)):
        vals, arg = _cummin_with_arg(g)
        args.append(arg)
        g = fs[j] + vals
    rows = np.arange(m)
    best_c = np.argmin(g, axis=1)
    interior = g[rows, best_c]
    idx = [best_c]
    for arg in reversed(args):
        best_c = arg[rows, best_c]
        idx.append(best_c)
    order = np.stack(list(reversed(idx)), axis=1)  # (M, Ts-1)
    bounds = c[rows[:, None], order]
    return interior, bounds


_ENUM_MAX_STEPS = 3  # exact joint solve up to 4-tier topologies
_ENUM_CHUNK_CELLS = 20_000_000  # memory guard for the (M, G) grids


def _solve_constrained_enum(obj: BoundaryObjective, fs, c):
    """Exact constrained solve for shallow hierarchies (J <= 3 boundary
    steps, i.e. up to 4 tiers): enumerate every monotone index tuple over
    the candidate grid and mask infeasible tuples — middle-tier capacity
    as pairwise lower bounds, the read-path SLO as an exact (not
    quantized) budget sum. Because the grid contains the capacity corners
    and SLO-tight points, the feasible optimum of the continuous problem
    is on the grid up to crossover-vs-constraint interactions (verified
    against the brute-force feasible grid). Deeper hierarchies take the
    quantized resource DP instead."""
    m, ncand = c.shape
    nsteps = len(fs)
    combos = np.array(list(itertools.combinations_with_replacement(
        range(ncand), nsteps)), np.int64)  # (G, J) monotone by construction
    g = combos.shape[0]
    lbs = [obj.pair_lower_bound(idx, c) for idx in range(1, nsteps)]
    budget = obj.budget_deltas(c)
    rows = np.arange(m)
    chunk = max(1, _ENUM_CHUNK_CELLS // max(g, 1))
    interior = np.empty(m)
    order = np.empty((m, nsteps), np.int64)
    for s in range(0, m, chunk):
        sl = slice(s, min(s + chunk, m))
        total = fs[0][sl][:, combos[:, 0]]
        for j in range(1, nsteps):
            total = total + fs[j][sl][:, combos[:, j]]
        for idx in range(1, nsteps):
            lb = lbs[idx - 1]
            if lb is None:
                continue
            prev_val = c[sl][:, combos[:, idx - 1]]
            lb_dest = lb[sl][:, combos[:, idx]]
            total = np.where(prev_val >= lb_dest * (1 - 1e-12) - 1e-12,
                             total, np.inf)
        if budget is not None:
            deltas, rhs = budget
            acc = deltas[0][sl][:, combos[:, 0]]
            scale = np.abs(deltas[0][sl]).max(1)
            for j in range(1, nsteps):
                acc = acc + deltas[j][sl][:, combos[:, j]]
                scale = scale + np.abs(deltas[j][sl]).max(1)
            atol = 1e-9 * (np.abs(rhs[sl]) + scale) + 1e-15
            total = np.where(acc <= (rhs[sl] + atol)[:, None], total, np.inf)
        best = np.argmin(total, axis=1)
        interior[sl] = total[np.arange(total.shape[0]), best]
        order[sl] = combos[best]
    bounds = c[rows[:, None], order]
    return interior, bounds


def _solve_resource_dp(obj: BoundaryObjective, fs, c):
    """Resource-augmented DP over (boundary step, candidate, remaining
    latency budget): the constrained replacement for the plain monotone
    DP. Middle-tier capacities enter as pairwise transition bounds,
    the SLO as a quantized budget axis (conservatively rounded, so
    DP-feasible implies truly feasible). With no active constraints this
    reduces term-for-term to ``_solve_unconstrained`` (asserted by the
    bit-match property tests)."""
    m, ncand = c.shape
    nsteps = len(fs)
    budget = obj.budget(c)
    lbs = [obj.pair_lower_bound(idx, c) for idx in range(1, nsteps)]
    if budget is None and all(lb is None for lb in lbs):
        return _solve_unconstrained(fs, c)
    if nsteps <= _ENUM_MAX_STEPS:
        return _solve_constrained_enum(obj, fs, c)
    if budget is None:
        levels = [np.zeros((m, ncand), np.int64)] * nsteps
        cap_levels, q = np.zeros(m, np.int64), 1
    else:
        levels, cap_levels, q = budget
    rows = np.arange(m)
    crange = np.arange(ncand)
    d = np.full((m, ncand, q), np.inf)
    d[rows[:, None], crange[None, :], levels[0]] = fs[0]
    trace = []
    for step in range(1, nsteps):
        lb = lbs[step - 1]
        p = np.empty_like(d)
        amin = np.empty((m, ncand, q), np.int64)
        if lb is None:
            for qi in range(q):
                p[:, :, qi], amin[:, :, qi] = _cummin_with_arg(d[:, :, qi])
        else:
            # first candidate index satisfying b_prev >= lb(c), per (m, c)
            lb_idx = (c[:, None, :] < lb[:, :, None]).sum(-1)
            allow = ((crange[None, None, :] <= crange[None, :, None])
                     & (crange[None, None, :] >= lb_idx[:, :, None]))
            for qi in range(q):
                masked = np.where(allow, d[:, None, :, qi], np.inf)
                amin[:, :, qi] = np.argmin(masked, axis=2)
                p[:, :, qi] = np.take_along_axis(
                    masked, amin[:, :, qi][..., None], 2)[..., 0]
        trace.append(amin)
        lv = levels[step]
        q_src = np.arange(q)[None, None, :] - lv[:, :, None]
        gathered = np.take_along_axis(p, np.clip(q_src, 0, q - 1), axis=2)
        d = np.where(q_src >= 0, gathered, np.inf) + fs[step][:, :, None]
    feas = np.arange(q)[None, None, :] <= cap_levels[:, None, None]
    flat = np.where(feas, d, np.inf).reshape(m, -1)
    best = np.argmin(flat, axis=1)
    interior = flat[rows, best]
    best_c, best_q = best // q, best % q
    idx = [best_c]
    for step in range(nsteps - 1, 0, -1):
        best_q = np.clip(best_q - levels[step][rows, best_c], 0, q - 1)
        best_c = trace[step - 1][rows, best_c, best_q]
        idx.append(best_c)
    order = np.stack(list(reversed(idx)), axis=1)
    bounds = c[rows[:, None], order]
    return interior, bounds


def solve_separable_terms(obj: BoundaryObjective, fs, c):
    """Minimize a *custom* separable objective over the monotone boundary
    grid, under ``obj``'s compiled constraint structure.

    ``fs`` is a list of per-boundary term matrices (M, C) on candidate grid
    ``c`` (M, C) — any separable cost, not necessarily the planner's
    ``Δcw·W + Δlin·b`` form. ``obj`` supplies the feasibility side only:
    pairwise middle-tier capacity bounds, the quantized/exact latency
    budget, and the enum-vs-DP dispatch. This is the entry point the
    online re-planner uses to re-run the constrained boundary solve over
    a window *suffix*, where the cost terms gain drift-conditioned write
    laws and relocation billing that the a-priori objective doesn't have.

    Returns (interior_val (M,), bounds (M, Ts-1)); +inf where no feasible
    monotone vector exists.
    """
    if obj.constrained and not obj.interior:
        return _solve_resource_dp(obj, fs, c)
    return _solve_unconstrained(fs, c)


def _solve_boundaries(cw_s, lin_s, n, k, interior=False, *, cap_s=None,
                      lat_s=None, slo=None):
    """Minimize the separable boundary objective for one strategy family.

    cw_s/lin_s: (M, Ts) per-tier coefficient columns of the (sub)topology;
    n/k: (M,). With ``interior=True`` boundaries are restricted to [K, N)
    — the N-tier form of eq. 22's gate for the migration family, so the
    reservoir is full at every cascade and the last tier is always reached.
    ``cap_s``/``lat_s``/``slo`` activate the constrained solver
    (``BoundaryObjective`` + resource-augmented DP); left at None the
    original unconstrained closed form runs unchanged.

    Returns (interior_val (M,), bounds (M, Ts-1)): the sum of the boundary
    terms at the optimum (+inf where no feasible vector exists) and the
    optimal boundary vector. The caller adds the boundary-independent
    terms W(N)·cw_last + N·lin_last [+ storage bound / eq. 19 charges].
    """
    obj = BoundaryObjective(cw_s=cw_s, lin_s=lin_s, n=n, k=k,
                            interior=interior, cap_s=cap_s, lat_s=lat_s,
                            slo=slo)
    ok = obj.subset_feasible()
    if obj.ts == 1:
        return np.where(ok, 0.0, np.inf), np.zeros((obj.m, 0))
    c = obj.candidates()
    fs = obj.terms(c)
    if obj.constrained and not obj.interior:
        interior_val, bounds = _solve_resource_dp(obj, fs, c)
    else:
        interior_val, bounds = _solve_unconstrained(fs, c)
    return np.where(ok, interior_val, np.inf), bounds


@functools.lru_cache(maxsize=None)
def _tier_subsets(t: int):
    """Non-empty ordered tier subsets, singletons first then ascending by
    size — the first-minimum-wins precedence generalizing the candidate
    order of ``plan_placement``. Cached: the enumeration is pure in ``t``
    and was being recomputed on every ``plan_ntier_arrays`` call."""
    return tuple(s for size in range(1, t + 1)
                 for s in itertools.combinations(range(t), size))


@functools.lru_cache(maxsize=None)
def _cascade_subsets(t: int):
    """Tier subsets a migration cascade can traverse: at least two tiers,
    always ending in the (consumer-local) last tier — skipped middle tiers
    save their eq. 19 hop. Cached like ``_tier_subsets``."""
    return tuple(s + (t - 1,) for size in range(1, t)
                 for s in itertools.combinations(range(t - 1), size))


def _cascade_fee(cr, cw, used_cols):
    """Σ eq. 19 over consecutive used tiers: (M,) from (M, T) cost arrays
    and the ordered used-tier index list."""
    fee = np.zeros(cr.shape[0])
    for u, v in zip(used_cols, used_cols[1:]):
        fee = fee + cr[:, u] + cw[:, v]
    return fee


# Backend for the vectorized N-tier solve: "auto" routes fleets (M >=
# _DEVICE_MIN_M, T <= 4) through the jitted device solver
# (``core.shp_jax`` + the ``kernels.plan_solve`` reduction) and keeps
# small/deep problems on the NumPy oracle below — which remains the
# reference implementation the device path is property-tested against.
_PLANNER_BACKEND = "auto"
_DEVICE_MIN_M = 64


def set_planner_backend(backend: str) -> str:
    """Set the module-wide solve backend ("auto" | "jax" | "numpy");
    returns the previous value. Tests pin "numpy" vs "jax" to compare."""
    global _PLANNER_BACKEND
    if backend not in ("auto", "jax", "numpy"):
        raise ValueError(f"unknown planner backend {backend!r}")
    prev, _PLANNER_BACKEND = _PLANNER_BACKEND, backend
    return prev


def plan_ntier_arrays(cw, cr, cs, n, k, rpw, *, cap=None, lat=None,
                      slo=None, force_constrained=False, backend=None):
    """Vectorized multi-threshold planner over M streams sharing one tier
    count T — dispatches between the jitted device solver and the NumPy
    oracle (same contract; see ``plan_ntier_arrays_numpy`` for the
    model). ``backend`` overrides the module default ("auto")."""
    cw = np.asarray(cw, np.float64)
    m, t = cw.shape
    if t > MAX_TIERS:
        raise ValueError(f"topologies over {MAX_TIERS} tiers not supported")
    b = backend if backend is not None else _PLANNER_BACKEND
    if b == "auto":
        # constrained 4-tier fleets stay on the oracle: their exact joint
        # enumeration is G ~ C^3 tuples per subset, which the host bounds
        # by _ENUM_CHUNK_CELLS but the gathered device path materializes
        # per chunk — device routing there trades a Python loop for
        # multi-GB transients
        con = force_constrained or not constraints_mod.trivial(cap, slo)
        t_max = _ENUM_MAX_STEPS + (0 if con else 1)
        b = "jax" if 2 <= t <= t_max and m >= _DEVICE_MIN_M else "numpy"
    if b == "jax":
        try:
            from . import shp_jax
            return shp_jax.plan_ntier_arrays_jax(
                cw, cr, cs, n, k, rpw, cap=cap, lat=lat, slo=slo,
                force_constrained=force_constrained)
        except shp_jax.DeviceSolverUnavailable:
            if backend == "jax" or _PLANNER_BACKEND == "jax":
                raise
    return plan_ntier_arrays_numpy(cw, cr, cs, n, k, rpw, cap=cap, lat=lat,
                                   slo=slo,
                                   force_constrained=force_constrained)


def plan_ntier_arrays_numpy(cw, cr, cs, n, k, rpw, *, cap=None, lat=None,
                            slo=None, force_constrained=False):
    """Host-side NumPy reference solver (the oracle the device path is
    verified against). cw/cr/cs: (M, T); n/k/rpw: (M,). Returns a dict
    with ``total`` (M,), ``bounds`` (M, T-1) full-topology boundary
    vectors, and ``migrate`` (M,) bool.

    No-migration family: solved per tier subset (degenerate tiers collapse
    to zero width) with the most-expensive-*used*-tier rental bound.
    Migration family: solved per cascade subset (ending at the last,
    consumer-local tier; skipped tiers save their hop) with boundaries
    gated to [K, N) (the eq. 22 gate), eq. 18-style time-split rental, and
    the constant eq. 19 charge K·(cr_u + cw_v) per traversed tier pair;
    the final read is excluded, generalizing eq. 20 — for T=2 this
    objective is exactly the paper's ``cost_with_migration``.

    Constraints enter as vectorized feasibility structure over the (M, T)
    boundary batch: ``cap`` (M, T) per-tier document capacities, ``lat``
    (M, T) per-tier read latencies, ``slo`` (M,) expected-read-latency
    bounds (all optional, +inf = unconstrained). When every entry is
    trivial the unconstrained closed form runs unchanged — bit-exactly —
    unless ``force_constrained`` routes through the resource-augmented DP
    anyway (the bit-match property tests use this). Streams with no
    feasible plan return ``total = +inf``.
    """
    cw = np.asarray(cw, np.float64)
    cr = np.asarray(cr, np.float64)
    cs = np.asarray(cs, np.float64)
    n = np.asarray(n, np.float64)
    k = np.asarray(k, np.float64)
    rpw = np.asarray(rpw, np.float64)
    m, t = cw.shape
    if t > MAX_TIERS:
        raise ValueError(f"topologies over {MAX_TIERS} tiers not supported")
    constrained = force_constrained or not constraints_mod.trivial(cap, slo)
    if constrained:
        cap = (np.full((m, t), np.inf) if cap is None
               else np.asarray(cap, np.float64))
        lat = np.zeros((m, t)) if lat is None else np.asarray(lat, np.float64)
        slo = (np.full(m, np.inf) if slo is None
               else np.asarray(slo, np.float64))
    w_n = _w_approx(n, k)
    best_total = np.full(m, np.inf)
    best_bounds = np.zeros((m, t - 1))
    best_mig = np.zeros(m, bool)
    for sub in _tier_subsets(t):
        sa = np.asarray(sub)
        lin = (rpw * k / n)[:, None] * cr[:, sa]
        kw = (dict(cap_s=cap[:, sa], lat_s=lat[:, sa], slo=slo)
              if constrained else {})
        interior, sub_bounds = _solve_boundaries(cw[:, sa], lin, n, k, **kw)
        total = (interior + w_n * cw[:, sa[-1]] + n * lin[:, -1]
                 + k * np.max(cs[:, sa], axis=1))
        edges = np.concatenate([np.zeros((m, 1)), sub_bounds, n[:, None]], 1)
        widths = np.zeros((m, t))
        widths[:, sa] = np.diff(edges, axis=1)
        full = np.cumsum(widths, axis=1)[:, :-1]
        upd = total < best_total
        best_total = np.where(upd, total, best_total)
        best_bounds = np.where(upd[:, None], full, best_bounds)
    lin_mig = (k / n)[:, None] * cs
    for sub in _cascade_subsets(t):
        sa = np.asarray(sub)
        kw = (dict(cap_s=cap[:, sa], lat_s=lat[:, sa], slo=slo)
              if constrained else {})
        interior, sub_bounds = _solve_boundaries(cw[:, sa], lin_mig[:, sa],
                                                 n, k, interior=True, **kw)
        total = (interior + w_n * cw[:, -1] + n * lin_mig[:, -1]
                 + k * _cascade_fee(cr, cw, sub))
        edges = np.concatenate([np.zeros((m, 1)), sub_bounds, n[:, None]], 1)
        widths = np.zeros((m, t))
        widths[:, sa] = np.diff(edges, axis=1)
        full = np.cumsum(widths, axis=1)[:, :-1]
        upd = total < best_total
        best_total = np.where(upd, total, best_total)
        best_bounds = np.where(upd[:, None], full, best_bounds)
        best_mig = best_mig | upd
    return {"total": best_total, "bounds": best_bounds, "migrate": best_mig}


def ntier_strategy_name(bounds, n: float, t: int, migrate: bool) -> str:
    """Histogram-friendly label: single-tier plans map onto the legacy
    ``all_tier_<letter>`` names; multi-tier plans are
    ``{two,n}_tier_{no_migration,migration}``."""
    prefix = "two_tier" if t == 2 else "ntier"
    if migrate:
        return f"{prefix}_migration"
    edges = np.concatenate([[0.0], np.asarray(bounds, np.float64), [n]])
    used = np.flatnonzero(np.diff(edges) > 0)
    if used.size == 1:
        return f"all_tier_{chr(ord('a') + int(used[0]))}"
    return f"{prefix}_no_migration"


@dataclass(frozen=True)
class NTierStrategyCost:
    """Expected-cost breakdown of one N-tier strategy at given boundaries."""

    strategy: str
    bounds_over_n: tuple
    total: float
    writes_per_tier: tuple
    reads: float
    storage: float
    migration: float

    def breakdown(self) -> dict:
        return {
            "strategy": self.strategy, "bounds_over_n": self.bounds_over_n,
            "total": self.total, "writes_per_tier": self.writes_per_tier,
            "reads": self.reads, "storage": self.storage,
            "migration": self.migration,
        }


def single_tier_bounds(cm: NTierCostModel, tier: int) -> tuple:
    """Boundary vector placing every doc in ``tier``: boundaries at or
    below it sit at 0, those above at N."""
    n = float(cm.workload.n_docs)
    return tuple(0.0 if j < tier else n for j in range(cm.t - 1))


def _edges(cm: NTierCostModel, bounds) -> np.ndarray:
    n = cm.workload.n_docs
    b = np.clip(np.asarray(bounds, np.float64), 0.0, n)
    if b.shape != (cm.t - 1,):
        raise ValueError(f"need {cm.t - 1} boundaries for T={cm.t}, "
                         f"got shape {b.shape}")
    if np.any(np.diff(b) < 0):
        raise ValueError("boundaries must be non-decreasing")
    return np.concatenate([[0.0], b, [float(n)]])


def _segment_writes(cm: NTierCostModel, edges, exact: bool) -> np.ndarray:
    k = cm.workload.k
    if exact:
        w = np.where(edges > 0, expected_cum_writes(edges - 1.0, k), 0.0)
    else:
        w = _w_approx(edges, k)
    return np.diff(w)


def cost_ntier_no_migration(cm: NTierCostModel, bounds,
                            exact: bool = False) -> NTierStrategyCost:
    """Eqs. 13–16 generalized: per-segment writes, survivor reads i.u.d.
    over the stream, most-expensive-used-tier rental bound."""
    wl = cm.workload
    edges = _edges(cm, bounds)
    w_seg = _segment_writes(cm, edges, exact)
    frac = np.diff(edges) / wl.n_docs
    writes = w_seg * cm.cw
    reads = wl.reads_per_window * wl.k * float(frac @ cm.cr)
    storage = wl.k * float(np.max(np.where(frac > 0, cm.cs, -np.inf)))
    total = float(writes.sum() + reads + storage)
    return NTierStrategyCost(
        ntier_strategy_name(edges[1:-1], wl.n_docs, cm.t, False),
        tuple(edges[1:-1] / wl.n_docs), total, tuple(writes), reads,
        storage, 0.0)


def cost_ntier_migration(cm: NTierCostModel, bounds,
                         exact: bool = False) -> NTierStrategyCost:
    """Eqs. 18–20 generalized: residents cascade directly to the next
    *used* tier when the stream crosses its boundary (zero-width tiers are
    skipped, saving their hop; the constant eq. 19 charge K·(cr_u + cw_v)
    applies per traversed pair — the planner gates boundaries to [K, N) so
    the reservoir is full at every cascade), rental follows the write
    pointer's tier time-split, and the final read — served entirely from
    the last tier — is excluded. For T=2 this is exactly
    ``cost_with_migration``."""
    wl = cm.workload
    edges = _edges(cm, bounds)
    w_seg = _segment_writes(cm, edges, exact)
    frac = np.diff(edges) / wl.n_docs
    writes = w_seg * cm.cw
    storage = wl.k * float(frac @ cm.cs)
    used = [t for t in range(cm.t) if frac[t] > 0 or t == cm.t - 1]
    migration = wl.k * float(_cascade_fee(cm.cr[None, :], cm.cw[None, :],
                                          used)[0])
    total = float(writes.sum() + storage + migration)
    return NTierStrategyCost(
        ntier_strategy_name(edges[1:-1], wl.n_docs, cm.t, True),
        tuple(edges[1:-1] / wl.n_docs), total, tuple(writes), 0.0,
        storage, migration)


@dataclass(frozen=True)
class NTierPlacementPlan:
    """Outcome of the N-tier decision procedure: the cheapest of the
    no-migration family (over all tier subsets) and the migration cascade.
    Constrained plans with no feasible boundary vector carry
    ``total = +inf`` (``feasible`` is False)."""

    best: NTierStrategyCost
    boundaries: Tuple[float, ...]
    migrate: bool
    n_docs: int
    t: int

    @property
    def strategy(self) -> str:
        return self.best.strategy

    @property
    def total(self) -> float:
        return self.best.total

    @property
    def feasible(self) -> bool:
        return math.isfinite(self.best.total)

    @property
    def r(self) -> float:
        """First changeover index (the T=2 shim)."""
        return self.boundaries[0]


def resolve_constraints(cm: NTierCostModel,
                        constraints: Optional[ConstraintSet]):
    """(cap (T,), lat (T,), slo, cset): the compiled constraint arrays for
    one model.

    Topology-declared capacities (``TierSpec.capacity_docs`` — physical
    properties of the hierarchy) always apply; an explicit
    ``ConstraintSet`` *overrides per tier*: a ``TierCapacity`` entry on
    tier t replaces the declaration there (so ``TierCapacity(t, inf)``
    explicitly lifts it), and declarations on other tiers persist. SLOs
    come only from the explicit set.
    """
    cset = constraints if constraints is not None else ConstraintSet()
    if cset.shared_capacities:
        raise ValueError(
            "shared capacities are fleet-wide budgets — plan via "
            "plan_fleet_mixed, which splits them by water-filling")
    _, lat, slo = cset.tier_arrays(cm)
    cap = constraints_mod.effective_capacity(cset, cm)
    return cap, lat, slo, cset


def _infeasible_plan(cm: NTierCostModel) -> NTierPlacementPlan:
    sc = NTierStrategyCost("infeasible", tuple([0.0] * (cm.t - 1)),
                           float("inf"), tuple([0.0] * cm.t), 0.0, 0.0, 0.0)
    return NTierPlacementPlan(best=sc, boundaries=tuple([0.0] * (cm.t - 1)),
                              migrate=False, n_docs=cm.workload.n_docs,
                              t=cm.t)


def plan_placement_ntier(cm: NTierCostModel,
                         constraints: Optional[ConstraintSet] = None
                         ) -> NTierPlacementPlan:
    """Single-stream N-tier plan (the M=1 view of ``plan_ntier_arrays``).

    With ``constraints`` (or topology-declared tier capacities) the
    resource-augmented DP plans under per-tier capacities and the
    read-path SLO; an empty/trivial ``ConstraintSet`` reproduces the
    unconstrained plan bit-identically (same code path).
    """
    wl = cm.workload
    cap, lat, slo, _ = resolve_constraints(cm, constraints)
    out = plan_ntier_arrays(cm.cw[None, :], cm.cr[None, :], cm.cs[None, :],
                            np.array([float(wl.n_docs)]),
                            np.array([float(wl.k)]),
                            np.array([wl.reads_per_window]),
                            cap=cap[None, :], lat=lat[None, :],
                            slo=np.array([slo]))
    if not np.isfinite(out["total"][0]):
        return _infeasible_plan(cm)
    bounds = tuple(float(b) for b in out["bounds"][0])
    migrate = bool(out["migrate"][0])
    fn = cost_ntier_migration if migrate else cost_ntier_no_migration
    return NTierPlacementPlan(best=fn(cm, bounds), boundaries=bounds,
                              migrate=migrate, n_docs=wl.n_docs, t=cm.t)


def plan_ntier_batch(models: Sequence[NTierCostModel], constraints=None):
    """Vectorized plan for a batch of N-tier models sharing one T.
    ``constraints`` is a shared ``ConstraintSet`` or one per model.
    Returns (total (M,), bounds (M, T-1), migrate (M,), strategies list)."""
    t = models[0].t
    if any(m.t != t for m in models):
        raise ValueError("plan_ntier_batch needs a uniform tier count")
    cw = np.stack([m.cw for m in models])
    cr = np.stack([m.cr for m in models])
    cs = np.stack([m.cs for m in models])
    n = np.array([float(m.workload.n_docs) for m in models])
    k = np.array([float(m.workload.k) for m in models])
    rpw = np.array([m.workload.reads_per_window for m in models])
    per_model = (constraints if isinstance(constraints, (list, tuple))
                 else [constraints] * len(models))
    compiled = [resolve_constraints(m, c)
                for m, c in zip(models, per_model)]
    cap = np.stack([c[0] for c in compiled])
    lat = np.stack([c[1] for c in compiled])
    slo = np.array([c[2] for c in compiled])
    out = plan_ntier_arrays(cw, cr, cs, n, k, rpw, cap=cap, lat=lat, slo=slo)
    strategies = [("infeasible" if not np.isfinite(out["total"][i])
                   else ntier_strategy_name(out["bounds"][i], n[i], t,
                                            bool(out["migrate"][i])))
                  for i in range(len(models))]
    return out["total"], out["bounds"], out["migrate"], strategies


def brute_force_plan_ntier(cm: NTierCostModel, grid: int = 48,
                           constraints: Optional[ConstraintSet] = None):
    """Ground-truth verifier: grid search over monotone boundary vectors
    for both strategy families (same objectives as the closed form).
    With ``constraints`` the grid becomes a *feasible* grid: expected
    occupancy high-water marks and read latency are evaluated per combo
    and infeasible vectors are masked to +inf (generic constraint types
    fall back to their ``feasible`` predicate row by row).
    Returns (total, bounds tuple, migrate); total is +inf when no grid
    point is feasible."""
    wl = cm.workload
    n, k, t = float(wl.n_docs), float(wl.k), cm.t
    cset = constraints if constraints is not None else ConstraintSet()
    # topology-declared capacities are enforced exactly like the planner's
    # resolve pass, so the verifier's ground truth stays comparable
    cap_r, lat_r, slo_r, _ = resolve_constraints(cm, constraints)
    active = (not cset.empty or np.any(np.isfinite(cap_r))
              or np.isfinite(slo_r))
    cap = lat = None
    slo = np.inf
    extra_vals = []
    if active:
        cap, lat, slo = cap_r, lat_r, slo_r
        for c_t in cap[np.isfinite(cap)]:
            extra_vals += [c_t, n * (1.0 - c_t / k)]
        if np.isfinite(slo):
            for s, u in itertools.combinations(range(t), 2):
                if lat[s] != lat[u]:
                    extra_vals.append(n * (slo - lat[u]) / (lat[s] - lat[u]))
    vals = np.unique(np.clip(np.concatenate([
        [0.0, min(k, n), np.nextafter(n, 0.0), n],
        np.geomspace(1.0, n, grid),
        np.asarray(extra_vals, np.float64)]), 0.0, n))
    combos = np.array(list(
        itertools.combinations_with_replacement(vals, t - 1)))
    edges = np.concatenate([np.zeros((combos.shape[0], 1)), combos,
                            np.full((combos.shape[0], 1), n)], axis=1)
    w_seg = np.diff(_w_approx(edges, k), axis=1)
    frac = np.diff(edges, axis=1) / n
    writes = w_seg @ cm.cw
    # no-migration family
    reads = wl.reads_per_window * k * (frac @ cm.cr)
    cs_used = np.max(np.where(frac > 0, cm.cs[None, :], -np.inf), axis=1)
    tot_nm = writes + reads + k * cs_used
    # migration family: zero-width tiers are skipped (saving their eq. 19
    # hop); every crossing between consecutive *used* tiers is gated to
    # [K, N) (eq. 22), and at least one crossing must happen
    g = combos.shape[0]
    kmin = min(k, n)
    used = np.concatenate([frac[:, :-1] > 0, np.ones((g, 1), bool)], axis=1)
    seen_before = np.logical_or.accumulate(used, axis=1)[:, :-1]
    crossing = used[:, 1:] & seen_before  # (G, T-1)
    gated = (combos >= kmin) & (combos < n)
    valid = np.all(~crossing | gated, axis=1) & crossing.any(axis=1)
    fee = np.zeros(g)
    prev = np.zeros(g, np.int64)
    for t_i in range(1, t):
        hop = crossing[:, t_i - 1]
        fee = fee + np.where(hop, cm.cr[prev] + cm.cw[t_i], 0.0)
        prev = np.where(used[:, t_i], t_i, prev)
    tot_mg = np.where(valid, writes + k * (frac @ cm.cs) + k * fee, np.inf)
    if cap is not None:
        tol = 1.0 + 1e-9
        gn = np.full(g, n)
        gk = np.full(g, k)
        occ_nm = constraints_mod.peak_occupancy_arrays(
            combos, gn, gk, np.zeros(g, bool))
        occ_mg = constraints_mod.peak_occupancy_arrays(
            combos, gn, gk, np.ones(g, bool))
        tot_nm = np.where(np.all(occ_nm <= cap[None, :] * tol, axis=1),
                          tot_nm, np.inf)
        tot_mg = np.where(np.all(occ_mg <= cap[None, :] * tol, axis=1),
                          tot_mg, np.inf)
        if np.isfinite(slo):
            tot_nm = np.where(frac @ lat <= slo * tol, tot_nm, np.inf)
            tot_mg = np.where(lat[-1] <= slo * tol, tot_mg, np.inf)
        generic = [c for c in cset
                   if not isinstance(c, (TierCapacity, ReadLatencySLO))]
        for con in generic:
            for i in range(g):
                if np.isfinite(tot_nm[i]) and \
                        not con.feasible(cm, combos[i], False):
                    tot_nm[i] = np.inf
                if np.isfinite(tot_mg[i]) and \
                        not con.feasible(cm, combos[i], True):
                    tot_mg[i] = np.inf
    i_nm, i_mg = int(np.argmin(tot_nm)), int(np.argmin(tot_mg))
    if not np.isfinite(tot_nm[i_nm]) and not np.isfinite(tot_mg[i_mg]):
        return float("inf"), tuple(np.zeros(t - 1)), False
    if tot_nm[i_nm] <= tot_mg[i_mg]:
        return float(tot_nm[i_nm]), tuple(combos[i_nm]), False
    return float(tot_mg[i_mg]), tuple(combos[i_mg]), True


def cost_curve(cm: TwoTierCostModel, migrate: bool, num: int = 512) -> np.ndarray:
    """Expected total cost for r swept over (K, N) — Figures 4 & 5.
    Returns array (num, 2) of [r/N, cost]."""
    wl = cm.workload
    rs = np.linspace(max(wl.k + 1, 1), wl.n_docs - 1, num)
    fn = cost_with_migration if migrate else cost_no_migration
    out = np.array([[r / wl.n_docs, fn(cm, float(r)).total] for r in rs])
    return out
