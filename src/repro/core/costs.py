"""Cost model for two-tier top-K placement (paper §IV, Tables I & II).

Conventions locked by reproducing the paper's printed totals (DESIGN.md §1.1):

* Per-document write/read costs bundle the inter-site transfer:
    cw_A = put_A + xfer(producer→A)·doc_GB        (A is producer-local → 0 xfer)
    cw_B = put_B + xfer(producer→B)·doc_GB
    cr_A = get_A + xfer(A→consumer)·doc_GB        (remote pull)
    cr_B = get_B                                   (B is consumer-local)
* Storage ("rental") is per-doc per-window: rate · doc_GB · window_months.
* Migration cost per doc follows eq. 19 literally: cr_A + cw_B.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import cached_property
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:
    from .topology import TierTopology

GB_PER_MB = 1.0 / 1000.0  # decimal GB, matching cloud billing
DAYS_PER_MONTH = 30.0


@dataclass(frozen=True)
class TierCosts:
    """Raw billing structure of one storage tier.

    ``min_storage_days`` models lifetime-aware minimum-storage-duration
    charges (S3-IA bills 30 days, Glacier 90): every object written to the
    tier is billed at least that much rental even if deleted or
    transitioned out earlier. ``core.simulator`` tops up each stay to the
    minimum, and ``NTierCostModel.cs`` floors the full-window per-doc
    rental at ``min_storage_days`` for short windows.
    """

    name: str
    put_per_doc: float
    get_per_doc: float
    storage_per_gb_month: float
    min_storage_days: float = 0.0


@dataclass(frozen=True)
class WorkloadSpec:
    """Top-K stream workload parameters (paper §IV)."""

    n_docs: int  # N — stream / window length
    k: int  # K — number of survivors read at window end
    doc_gb: float  # document size in GB
    window_months: float  # stream-window duration in months
    reads_per_window: float = 1.0  # paper's case: one final read

    def __post_init__(self):
        if not (0 < self.k < self.n_docs):
            raise ValueError(f"require 0 < K < N, got K={self.k} N={self.n_docs}")
        if self.doc_gb < 0 or self.window_months < 0:
            raise ValueError("doc_gb / window_months must be non-negative")

    @property
    def n(self) -> int:
        return self.n_docs


@dataclass(frozen=True)
class TwoTierCostModel:
    """Derived per-document costs for Algorithm C ("first r to A, rest to B").

    Tier A is producer-local (write-cheap for early, likely-evicted docs);
    tier B is consumer-local (read-cheap for likely survivors).
    """

    tier_a: TierCosts
    tier_b: TierCosts
    workload: WorkloadSpec
    xfer_producer_to_b_per_gb: float = 0.0
    xfer_a_to_consumer_per_gb: float = 0.0
    xfer_producer_to_a_per_gb: float = 0.0

    # ---- per-document derived costs -------------------------------------
    @property
    def cw_a(self) -> float:
        return self.tier_a.put_per_doc + self.xfer_producer_to_a_per_gb * self.workload.doc_gb

    @property
    def cw_b(self) -> float:
        return self.tier_b.put_per_doc + self.xfer_producer_to_b_per_gb * self.workload.doc_gb

    @property
    def cr_a(self) -> float:
        return self.tier_a.get_per_doc + self.xfer_a_to_consumer_per_gb * self.workload.doc_gb

    @property
    def cr_b(self) -> float:
        return self.tier_b.get_per_doc

    @property
    def cs_a(self) -> float:
        """Per-doc rental in tier A over the full window."""
        return self.tier_a.storage_per_gb_month * self.workload.doc_gb * self.workload.window_months

    @property
    def cs_b(self) -> float:
        return self.tier_b.storage_per_gb_month * self.workload.doc_gb * self.workload.window_months

    @property
    def cs_max(self) -> float:
        """Most-expensive-tier rental — the paper's upper bound for the
        no-migration strategy (rental then constant in r)."""
        return max(self.cs_a, self.cs_b)

    @property
    def migration_per_doc(self) -> float:
        """Eq. 19: read out of A plus write into B."""
        return self.cr_a + self.cw_b

    def replace(self, **kw) -> "TwoTierCostModel":
        return dataclasses.replace(self, **kw)

    def as_ntier(self) -> "NTierCostModel":
        """The exact T=2 view of this model as an ``NTierCostModel``: the
        derived cost vectors are computed with the same arithmetic, so the
        case-study totals reproduce identically through the N-tier path."""
        from .topology import TierSpec, TierTopology
        topo = TierTopology(tiers=(
            TierSpec(self.tier_a,
                     xfer_in_per_gb=self.xfer_producer_to_a_per_gb,
                     xfer_out_per_gb=self.xfer_a_to_consumer_per_gb),
            TierSpec(self.tier_b,
                     xfer_in_per_gb=self.xfer_producer_to_b_per_gb,
                     xfer_out_per_gb=0.0),
        ), name=f"{self.tier_a.name}->{self.tier_b.name}")
        return NTierCostModel(topology=topo, workload=self.workload)


@dataclass(frozen=True)
class NTierCostModel:
    """Derived per-document costs over an ordered ``TierTopology`` —
    the N-tier generalization of ``TwoTierCostModel`` (which is the exact
    T=2 case via :meth:`TwoTierCostModel.as_ntier`).

    All vector properties are ``(T,)`` float64 arrays indexed by tier:
    ``cw``/``cr`` bundle the inter-site transfer exactly like the two-tier
    conventions, ``cs`` is the per-doc full-window rental, and
    ``migration_per_boundary`` is eq. 19 applied per adjacent pair.
    """

    topology: "TierTopology"
    workload: WorkloadSpec

    @property
    def t(self) -> int:
        return self.topology.t

    @property
    def tier_names(self) -> tuple:
        return self.topology.tier_names

    @cached_property
    def cw(self) -> np.ndarray:
        g = self.workload.doc_gb
        return np.array([ts.costs.put_per_doc + ts.xfer_in_per_gb * g
                         for ts in self.topology.tiers], np.float64)

    @cached_property
    def cr(self) -> np.ndarray:
        g = self.workload.doc_gb
        return np.array([ts.costs.get_per_doc + ts.xfer_out_per_gb * g
                         for ts in self.topology.tiers], np.float64)

    @cached_property
    def cs(self) -> np.ndarray:
        """Per-doc rental per tier over the full window, floored at each
        tier's minimum storage duration (a doc resident the whole window
        is still billed at least ``min_storage_days``)."""
        wl = self.workload
        return np.array([ts.costs.storage_per_gb_month * wl.doc_gb
                         * max(wl.window_months,
                               ts.costs.min_storage_days / DAYS_PER_MONTH)
                         for ts in self.topology.tiers], np.float64)

    @cached_property
    def storage_per_doc_month(self) -> np.ndarray:
        """Per-doc-month rental rate per tier (for metered simulation)."""
        return np.array([ts.costs.storage_per_gb_month * self.workload.doc_gb
                         for ts in self.topology.tiers], np.float64)

    @cached_property
    def min_storage_months(self) -> np.ndarray:
        """(T,) minimum billed residency per stay (months); the metered
        simulator tops every stay up to this."""
        return np.array([ts.costs.min_storage_days / DAYS_PER_MONTH
                         for ts in self.topology.tiers], np.float64)

    @cached_property
    def capacity_docs(self) -> np.ndarray:
        """(T,) topology-declared per-tier occupancy bounds (inf where
        undeclared) — picked up by the constrained planner by default."""
        return np.array([np.inf if ts.capacity_docs is None
                         else float(ts.capacity_docs)
                         for ts in self.topology.tiers], np.float64)

    @cached_property
    def read_latency(self) -> np.ndarray:
        """(T,) expected per-object retrieval latency (seconds)."""
        return np.array([ts.read_latency_s for ts in self.topology.tiers],
                        np.float64)

    @property
    def cs_max(self) -> float:
        """Most-expensive-tier rental — the no-migration upper bound."""
        return float(np.max(self.cs))

    @cached_property
    def migration_per_boundary(self) -> np.ndarray:
        """(T-1,) eq. 19 per boundary: read out of tier t, write into t+1."""
        return self.cr[:-1] + self.cw[1:]

    def replace(self, **kw) -> "NTierCostModel":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

def case_study_1() -> TwoTierCostModel:
    """Table I: producer at AWS (A = S3), consumer at Azure (B = Blob GPv1).

    The paper lists a single inter-cloud transfer rate (Azure egress
    0.087/GB, S3 ingress 0); calibration shows its totals use that rate for
    both directions of the AWS↔Azure hop.
    """
    wl = WorkloadSpec(n_docs=int(1e8), k=int(1e6), doc_gb=0.1 * GB_PER_MB,
                      window_months=1.0 / DAYS_PER_MONTH)
    s3 = TierCosts("aws-s3", put_per_doc=0.005 / 1000, get_per_doc=0.0004 / 1000,
                   storage_per_gb_month=0.023)
    azure = TierCosts("azure-blob", put_per_doc=0.00036 / 10000,
                      get_per_doc=0.00036 / 10000, storage_per_gb_month=0.024)
    xcloud = 0.087
    return TwoTierCostModel(tier_a=s3, tier_b=azure, workload=wl,
                            xfer_producer_to_b_per_gb=xcloud,
                            xfer_a_to_consumer_per_gb=xcloud)


def case_study_2() -> TwoTierCostModel:
    """Table II: same cloud; A = EFS (free transactions, pricey rental),
    B = S3 (cheap rental, per-transaction fees)."""
    wl = WorkloadSpec(n_docs=int(1e8), k=int(5e6), doc_gb=1.0 * GB_PER_MB,
                      window_months=7.0 / DAYS_PER_MONTH)
    efs = TierCosts("aws-efs", put_per_doc=0.0, get_per_doc=0.0,
                    storage_per_gb_month=0.30)
    s3 = TierCosts("aws-s3", put_per_doc=0.000005, get_per_doc=0.000005,
                   storage_per_gb_month=0.023)
    return TwoTierCostModel(tier_a=efs, tier_b=s3, workload=wl)


def hbm_host_preset(n_docs: int, k: int, doc_gb: float,
                    window_seconds: float,
                    hbm_bw_gbps: float = 819.0,
                    host_link_gbps: float = 32.0,
                    hbm_capacity_premium: float = 50.0) -> TwoTierCostModel:
    """Hardware-derived preset: tier A = device HBM ring buffer (hot),
    tier B = host DRAM over PCIe/DMA (cold).

    "Cost" here is seconds of bandwidth occupancy (write/read = bytes/BW) and
    an HBM capacity-opportunity rental premium. This adapts the paper's cloud
    economics to the TPU memory hierarchy (DESIGN.md §3): the same closed
    forms then place training-reservoir payloads between HBM and host.
    """
    months = window_seconds / (DAYS_PER_MONTH * 24 * 3600)
    hbm = TierCosts("device-hbm", put_per_doc=doc_gb / hbm_bw_gbps,
                    get_per_doc=doc_gb / hbm_bw_gbps,
                    storage_per_gb_month=hbm_capacity_premium)
    host = TierCosts("host-dram", put_per_doc=doc_gb / host_link_gbps,
                     get_per_doc=doc_gb / host_link_gbps,
                     storage_per_gb_month=hbm_capacity_premium / 100.0)
    wl = WorkloadSpec(n_docs=n_docs, k=k, doc_gb=doc_gb, window_months=months)
    return TwoTierCostModel(tier_a=hbm, tier_b=host, workload=wl)
