"""TieredStore — the runtime that actually holds top-K payloads across an
ordered tier hierarchy (hot device HBM → host DRAM → disk/object store),
placing each write according to a `placement.Policy` (the paper's Fig. 3
loop, §VII, generalized to N tiers).

The ledger records every transaction and byte so real runs can be reconciled
against the analytic expectations (and against `core.simulator`). For a
fleet of tenant streams, `repro.streams.metering.FleetMeter` keeps one
ledger row per stream and reconciles them in one vectorized pass.
"""
from __future__ import annotations

import os
import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .compat import TIER_A, TIER_B  # noqa: F401  (canonical home: compat)
from .placement import Policy


@dataclass
class Ledger:
    """Per-tier transaction counters; index = tier (2 tiers by default)."""

    writes: np.ndarray = field(default_factory=lambda: np.zeros(2, np.int64))
    reads: np.ndarray = field(default_factory=lambda: np.zeros(2, np.int64))
    deletes: np.ndarray = field(default_factory=lambda: np.zeros(2, np.int64))
    migrations: int = 0
    bytes_written: np.ndarray = field(default_factory=lambda: np.zeros(2, np.int64))
    bytes_read: np.ndarray = field(default_factory=lambda: np.zeros(2, np.int64))

    @classmethod
    def sized(cls, n_tiers: int) -> "Ledger":
        z = lambda: np.zeros(n_tiers, np.int64)
        return cls(writes=z(), reads=z(), deletes=z(),
                   bytes_written=z(), bytes_read=z())

    @property
    def n_tiers(self) -> int:
        return self.writes.shape[0]

    def as_dict(self) -> dict:
        return {
            "writes": self.writes.tolist(), "reads": self.reads.tolist(),
            "deletes": self.deletes.tolist(), "migrations": self.migrations,
            "bytes_written": self.bytes_written.tolist(),
            "bytes_read": self.bytes_read.tolist(),
        }


class HotTier:
    """Device-resident slab: K preallocated slots of a fixed payload shape.
    Slot bookkeeping is host-side; payload bytes stay on device."""

    def __init__(self, k: int, payload_shape, dtype=jnp.float32, device=None):
        self.k = k
        self._buf = jnp.zeros((k,) + tuple(payload_shape), dtype=dtype)
        if device is not None:
            self._buf = jax.device_put(self._buf, device)
        self._slot_of: Dict[int, int] = {}
        self._free = list(range(k))

    def put(self, doc_id: int, payload) -> int:
        if doc_id in self._slot_of:
            slot = self._slot_of[doc_id]
        else:
            if not self._free:
                raise RuntimeError("hot tier full — evict before writing")
            slot = self._free.pop()
            self._slot_of[doc_id] = slot
        self._buf = self._buf.at[slot].set(payload)
        return payload_nbytes(payload)

    def get(self, doc_id: int):
        return self._buf[self._slot_of[doc_id]]

    def delete(self, doc_id: int) -> None:
        self._free.append(self._slot_of.pop(doc_id))

    def __contains__(self, doc_id: int) -> bool:
        return doc_id in self._slot_of

    def doc_ids(self):
        return list(self._slot_of)


class ColdTier:
    """Host-resident store: numpy copies keyed by doc id, optionally spilled
    to a directory (object-store stand-in)."""

    def __init__(self, directory: Optional[str] = None):
        self._mem: Dict[int, np.ndarray] = {}
        self._dir = directory
        if directory:
            os.makedirs(directory, exist_ok=True)

    def _path(self, doc_id: int) -> str:
        return os.path.join(self._dir, f"doc_{doc_id}.npy")

    def put(self, doc_id: int, payload) -> int:
        arr = np.asarray(jax.device_get(payload))
        if self._dir:
            np.save(self._path(doc_id), arr)
        else:
            self._mem[doc_id] = arr
        return arr.nbytes

    def get(self, doc_id: int):
        if self._dir:
            return np.load(self._path(doc_id))
        return self._mem[doc_id]

    def delete(self, doc_id: int) -> None:
        if self._dir:
            os.remove(self._path(doc_id))
        else:
            del self._mem[doc_id]

    def __contains__(self, doc_id: int) -> bool:
        if self._dir:
            return os.path.exists(self._path(doc_id))
        return doc_id in self._mem

    def doc_ids(self):
        if self._dir:
            return [int(f[4:-4]) for f in os.listdir(self._dir)
                    if f.startswith("doc_") and f.endswith(".npy")]
        return list(self._mem)


def payload_nbytes(payload) -> int:
    return int(np.prod(payload.shape)) * payload.dtype.itemsize


class TieredStore:
    """N-tier payload store driven by an SHP placement policy.

    Constructed with one backing store per tier, ordered hot → cold
    (``TieredStore(policy, hot, cold)`` is the classic two-tier form;
    pass more stores for deeper hierarchies).

    Usage (inside the consumer-side of a train/serve loop):
        store.write(doc_id, payload)          # tier chosen by policy(doc_id)
        store.evict(doc_id)                   # reservoir overwrote the doc
        store.maybe_migrate(stream_index)     # cascade at each boundary (Fig. 3)
        payloads = store.read_all(ids)        # the final top-K read
    """

    def __init__(self, policy: Policy, *tier_stores):
        if len(tier_stores) < 2:
            raise ValueError("need at least two tier stores (hot, cold)")
        if policy.n_tiers > len(tier_stores):
            raise ValueError(f"policy places across {policy.n_tiers} tiers "
                             f"but only {len(tier_stores)} stores given")
        self.policy = policy
        self.tiers = dict(enumerate(tier_stores))
        self.ledger = Ledger.sized(len(tier_stores))
        self._floor = 0  # highest boundary whose cascade has fired

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    def tier_index_of(self, doc_id: int) -> Optional[int]:
        for t, tier in self.tiers.items():
            if doc_id in tier:
                return t
        return None

    def write(self, doc_id: int, payload) -> int:
        t = max(self.policy.tier_of(doc_id), self._floor)
        t = min(t, self.n_tiers - 1)
        nbytes = self.tiers[t].put(doc_id, payload)
        self.ledger.writes[t] += 1
        self.ledger.bytes_written[t] += nbytes
        return t

    def evict(self, doc_id: int) -> None:
        t = self.tier_index_of(doc_id)
        if t is None:
            return
        self.tiers[t].delete(doc_id)
        self.ledger.deletes[t] += 1

    def _move(self, doc_id: int, src: int, dst: int) -> None:
        payload = self.tiers[src].get(doc_id)
        self.ledger.reads[src] += 1
        self.ledger.bytes_read[src] += payload_nbytes(payload)
        nbytes = self.tiers[dst].put(doc_id, payload)
        self.ledger.writes[dst] += 1
        self.ledger.bytes_written[dst] += nbytes
        self.tiers[src].delete(doc_id)

    def maybe_migrate(self, stream_index: int) -> int:
        """Fire every boundary the stream position has crossed at once:
        residents hop *directly* into the highest crossed tier, so
        zero-width tiers (coincident boundaries) are skipped — matching the
        planner's per-traversed-pair eq. 19 charge."""
        dst = self._floor
        for t, mig_at in enumerate(self.policy.migration_indices(), start=1):
            if t > dst and stream_index >= mig_at:
                dst = t
        if dst == self._floor:
            return 0
        moved = 0
        for src in range(self._floor, dst):
            for doc_id in self.tiers[src].doc_ids():
                self._move(doc_id, src, dst)
                moved += 1
        self._floor = dst
        self.ledger.migrations += moved
        return moved

    def read(self, doc_id: int):
        t = self.tier_index_of(doc_id)
        if t is None:
            raise KeyError(f"doc {doc_id} not stored")
        payload = self.tiers[t].get(doc_id)
        self.ledger.reads[t] += 1
        self.ledger.bytes_read[t] += payload_nbytes(payload)
        return payload

    def read_all(self, doc_ids):
        return {int(d): self.read(int(d)) for d in doc_ids}
