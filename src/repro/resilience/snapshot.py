"""Whole-engine snapshot/restore: the state surface a crash must not lose.

``fleet_snapshot`` captures a ``StreamEngine`` as ``(pytree, meta)``:

* the pytree holds every fixed-shape array — per-bucket reservoir /
  logmem states and drift evidence sliced to the TRUE row count (shard
  padding stripped, so a checkpoint written on one mesh restores onto
  any other), device cost ledgers, the metrics counters collapsed to
  their mesh-independent canonical form, and the host monitors' state
  dicts (meter ledgers, residual and cost monitor evidence) — plus the
  ingest cursor;
* ``meta`` is a JSON-able dict carrying everything variable-length or
  structural: the replan/admission event logs, tier-outage bookkeeping,
  and a fleet fingerprint that restore validates against.

Every leaf is a fresh host copy at snapshot time, so an async checkpoint
write can proceed while the engine mutates on. ``fleet_restore`` is the
exact inverse: it re-pads device rows to the target engine's shard
multiple (pad rows take fresh-init values — inert under every law),
re-pins the fleet sharding, and rebuilds the host monitors, after which
resumed ingestion is bit-identical to the uninterrupted run (asserted in
``tests/test_resilience.py`` on both backends and across mesh sizes).
"""
from __future__ import annotations

from dataclasses import asdict
from typing import Dict, Tuple

import jax
import numpy as np

import jax.numpy as jnp

from repro.streams import engine as engine_mod
from repro.streams import logmem


def _slice_rows(state, m: int):
    """Host copies of a per-bucket device pytree, shard padding cut."""
    return jax.tree_util.tree_map(
        lambda leaf: np.asarray(leaf)[:m].copy(), state)


def _fingerprint(engine) -> Dict:
    return {
        "m": int(engine.m),
        "buckets": [{"k": int(b.k), "m": int(b.m), "engine": b.engine,
                     "stream_ids": [int(s) for s in b.stream_ids]}
                    for b in engine.buckets],
        "n_tiers": int(engine.meter.n_tiers),
    }


def fleet_snapshot(engine) -> Tuple[Dict, Dict]:
    """(pytree, meta) capturing the engine's full mutable state. The
    pytree's structure depends only on the engine's configuration (same
    specs + same obs/replan switches → same leaves), never on the mesh,
    so it doubles as the restore template."""
    device: Dict = {
        "states": [_slice_rows(st, b.m)
                   for st, b in zip(engine._states, engine.buckets)],
    }
    if engine._drift_states is not None:
        device["drift"] = [_slice_rows(ds, b.m)
                           for ds, b in zip(engine._drift_states,
                                            engine.buckets)]
    if engine._metrics_state is not None:
        from repro.obs import metrics as metrics_mod
        counts, score = metrics_mod.to_canonical(engine._metrics_state)
        device["metrics"] = {"counts": counts, "score": score}
    if engine._cost_states is not None:
        device["costs"] = [_slice_rows(cs, b.m)
                           for cs, b in zip(engine._cost_states,
                                            engine.buckets)]
    host: Dict = {"meter": engine.meter.state_dict()}
    if engine._residuals is not None:
        host["residuals"] = engine._residuals.state_dict()
    if engine._cost_monitor is not None:
        host["cost_monitor"] = engine._cost_monitor.state_dict()
    tree = {"device": device, "host": host,
            "cursor": np.int64(engine.chunks_ingested)}
    meta = {
        "fleet": _fingerprint(engine),
        "chunks_ingested": int(engine.chunks_ingested),
        "replan_events": [asdict(e) for e in engine.replan_events],
        # the admission decision's plan object is not JSON-able; the
        # negotiated terms are what downstream consumers act on
        "admission_events": [
            {"stream_id": e.stream_id, "row": e.row,
             "position": e.position,
             "decision": {k: v for k, v in asdict(e.decision).items()
                          if k != "plan"}}
            for e in engine.admission_events],
        "failed_tiers": {str(t): c
                         for t, c in engine._failed_tiers.items()},
        "recovering_tiers": {str(t): c
                             for t, c in engine._recovering_tiers.items()},
        "tier_outages": int(engine._tier_outages),
    }
    return tree, meta


def _restore_bucket(engine, bi: int, restored, fresh):
    """Re-pad one bucket's restored rows to the engine's shard multiple
    (pad rows keep fresh-init values) and re-pin the fleet sharding."""
    m = engine.buckets[bi].m

    def leaf(r, f):
        out = np.asarray(f).copy()
        out[:m] = np.asarray(r)
        return jnp.asarray(out)

    state = jax.tree_util.tree_map(leaf, restored, fresh)
    if engine.mesh is not None:
        from repro.parallel import fleet
        state = fleet.shard_rows(engine.mesh, state)
    return state


def fleet_restore(engine, tree: Dict, meta: Dict) -> None:
    """Load a snapshot into a freshly built engine (same specs and
    obs/replan configuration; ANY mesh size). Mutates the engine in
    place; raises ``ValueError`` on a fleet-shape mismatch."""
    fp = _fingerprint(engine)
    if meta.get("fleet") not in (None, fp):
        raise ValueError(
            f"checkpoint fleet {meta.get('fleet')} does not match the "
            f"target engine {fp} — restore needs an identically "
            "configured fleet (mesh size may differ)")
    device = tree["device"]
    fresh_states = [
        (logmem.init(pm) if b.engine == "logmem"
         else engine_mod.init(pm, b.k))
        for pm, b in zip(engine._pad_m, engine.buckets)]
    engine._states = [
        _restore_bucket(engine, bi, device["states"][bi],
                        jax.tree_util.tree_map(np.asarray,
                                               fresh_states[bi]))
        for bi in range(len(engine.buckets))]
    if engine._drift_states is not None:
        if "drift" not in device:
            raise ValueError("checkpoint has no drift state but the "
                             "engine was built with replan=")
        from repro.online import drift as drift_mod
        fresh = [jax.tree_util.tree_map(np.asarray, drift_mod.init(pm))
                 for pm in engine._pad_m]
        engine._drift_states = [
            _restore_bucket(engine, bi, device["drift"][bi], fresh[bi])
            for bi in range(len(engine.buckets))]
    if engine._metrics_state is not None:
        if "metrics" not in device:
            raise ValueError("checkpoint has no metrics state but the "
                             "engine was built with obs metrics on")
        from repro.obs import metrics as metrics_mod
        ms = metrics_mod.from_canonical(
            np.asarray(device["metrics"]["counts"]),
            np.float32(device["metrics"]["score"]),
            shards=engine._shards if engine.mesh is not None else 0)
        if engine.mesh is not None:
            from repro.parallel import fleet
            ms = fleet.shard_rows(engine.mesh, ms)
        engine._metrics_state = ms
    if engine._cost_states is not None:
        if "costs" not in device:
            raise ValueError("checkpoint has no cost ledgers but the "
                             "engine was built with obs costs on")
        from repro.obs import costs as costs_mod
        fresh = [jax.tree_util.tree_map(
            np.asarray,
            costs_mod.init_bucket(pm,
                                  engine.meter.boundaries[rows],
                                  engine.meter.n_tiers))
            for pm, rows in zip(engine._pad_m, engine._global_rows)]
        engine._cost_states = [
            _restore_bucket(engine, bi, device["costs"][bi], fresh[bi])
            for bi in range(len(engine.buckets))]
    engine.meter.load_state(tree["host"]["meter"])
    if engine._residuals is not None:
        engine._residuals.load_state(tree["host"]["residuals"])
    if engine._cost_monitor is not None:
        engine._cost_monitor.load_state(tree["host"]["cost_monitor"])
    engine.chunks_ingested = int(tree["cursor"])
    engine.replan_events = [
        engine_mod.ReplanEvent(**{
            **e, "old_bounds": tuple(e["old_bounds"]),
            "new_bounds": tuple(e["new_bounds"])})
        for e in meta.get("replan_events", [])]
    engine.admission_events = []
    if meta.get("admission_events"):
        from repro.online.admission import AdmissionDecision
        for e in meta["admission_events"]:
            engine.admission_events.append(engine_mod.AdmissionEvent(
                stream_id=e["stream_id"], row=e["row"],
                position=e["position"],
                decision=AdmissionDecision(plan=None, **e["decision"])))
    engine._failed_tiers = {int(t): int(c)
                            for t, c in meta.get("failed_tiers",
                                                 {}).items()}
    engine._recovering_tiers = {
        int(t): int(c)
        for t, c in meta.get("recovering_tiers", {}).items()}
    engine._tier_outages = int(meta.get("tier_outages", 0))
