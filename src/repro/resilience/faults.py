"""Deterministic seed-driven fault injection for the ingest path.

The harness models the delivery layer between a chunk producer and the
engine: chunks are addressed by a sequence number (the engine's ingest
cursor), deliveries may transiently fail, arrive twice, or arrive out of
order, scores may be laced with NaN/Inf, and the device may "die"
mid-stream. Every fault is a pure function of ``(seed, chunk seq)``, so
any failure is replayable bit-for-bit.

Recovery semantics (documented in the README's fault-tolerance table):
the delivery layer is at-least-once, the engine is exactly-once —
``ingest_with_faults`` drops deliveries below the cursor (idempotent
redelivery guard), buffers deliveries above it (reordering), and applies
each chunk exactly once in sequence order. ``run_with_recovery`` adds
crash recovery: on simulated device loss it rebuilds the engine,
restores the last checkpoint, and replays the schedule — the guard
silently absorbs everything already ingested before the checkpoint.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class TransientDeliveryError(RuntimeError):
    """A chunk delivery failed but is retryable."""


class DeviceLossError(RuntimeError):
    """The (simulated) accelerator died; state must be restored from the
    last checkpoint onto a fresh engine."""


class FaultyChunkSource:
    """Faulty delivery of ``make_chunk(i)`` for ``i in range(n_chunks)``.

    ``make_chunk`` must be a pure function of the chunk index — the
    retry, redelivery, and crash-recovery paths all re-materialize
    chunks from their index. Rates are per-delivery probabilities; all
    randomness derives from ``seed`` alone.

    * ``transient_rate`` — each chunk draws a deterministic number of
      leading failed delivery attempts (geometric, capped at
      ``max_transient`` so retry with enough attempts always succeeds).
    * ``duplicate_rate`` — after a delivery, an already-delivered chunk
      is redelivered (at-least-once delivery).
    * ``reorder_rate`` — adjacent deliveries swap (chunk t+1 arrives
      before chunk t).
    * ``nan_rate`` / ``nan_docs`` — a delivery has ``nan_docs`` of its
      live scores replaced by NaN / +Inf (the engine's quarantine path).
    * ``device_loss_at`` — delivering this seq raises
      ``DeviceLossError`` once (the crash under test).
    """

    def __init__(self, make_chunk: Callable[[int], List], n_chunks: int, *,
                 seed: int = 0, transient_rate: float = 0.0,
                 max_transient: int = 3, duplicate_rate: float = 0.0,
                 reorder_rate: float = 0.0, nan_rate: float = 0.0,
                 nan_docs: int = 1,
                 device_loss_at: Optional[int] = None):
        self._make = make_chunk
        self.n_chunks = int(n_chunks)
        self.seed = int(seed)
        self.transient_rate = float(transient_rate)
        self.max_transient = int(max_transient)
        self.duplicate_rate = float(duplicate_rate)
        self.reorder_rate = float(reorder_rate)
        self.nan_rate = float(nan_rate)
        self.nan_docs = int(nan_docs)
        self.device_loss_at = device_loss_at
        self._loss_fired = False
        # injection stats (what the source DID, vs the harness's stats
        # of what the guard then absorbed)
        self.failures_injected = 0
        self.duplicates_injected = 0
        self.nan_injected = 0

    def _failures(self, seq: int) -> int:
        """Deterministic leading-failure count for chunk ``seq``."""
        r = np.random.default_rng((self.seed, 7919, seq))
        n = 0
        while n < self.max_transient and r.random() < self.transient_rate:
            n += 1
        return n

    def _lace(self, seq: int, chunk: List) -> List:
        """Replace a few live scores with NaN/+Inf (seeded per chunk)."""
        r = np.random.default_rng((self.seed, 104729, seq))
        if self.nan_rate <= 0.0 or r.random() >= self.nan_rate:
            return chunk
        out = []
        laced = 0
        for scores, ids in chunk:
            scores = np.array(scores, np.float32, copy=True)
            live = np.argwhere(np.asarray(ids) >= 0)
            take = min(self.nan_docs - laced, live.shape[0])
            if take > 0:
                pick = live[r.choice(live.shape[0], size=take,
                                     replace=False)]
                vals = np.where(r.random(take) < 0.5, np.nan, np.inf)
                scores[pick[:, 0], pick[:, 1]] = vals.astype(np.float32)
                laced += take
            out.append((scores, ids))
        self.nan_injected += laced
        return out

    def fetch(self, seq: int, attempt: int = 0) -> List:
        """Deliver chunk ``seq`` (``ingest_dense``-shaped). Raises
        ``TransientDeliveryError`` on seeded failed attempts and
        ``DeviceLossError`` once at ``device_loss_at``."""
        if not 0 <= seq < self.n_chunks:
            raise IndexError(f"chunk {seq} outside [0, {self.n_chunks})")
        if (self.device_loss_at is not None and seq == self.device_loss_at
                and not self._loss_fired):
            self._loss_fired = True
            raise DeviceLossError(
                f"simulated device loss delivering chunk {seq}")
        if attempt < self._failures(seq):
            self.failures_injected += 1
            raise TransientDeliveryError(
                f"transient failure {attempt + 1} delivering chunk {seq}")
        return self._lace(seq, self._make(seq))

    def schedule(self) -> List[int]:
        """The seeded delivery order: every chunk at least once, plus
        duplicates, with adjacent reorderings applied."""
        rng = np.random.default_rng((self.seed, 15485863))
        order: List[int] = []
        for seq in range(self.n_chunks):
            order.append(seq)
            if rng.random() < self.duplicate_rate:
                order.append(int(rng.integers(0, seq + 1)))
                self.duplicates_injected += 1
        for i in range(1, len(order)):
            if rng.random() < self.reorder_rate:
                order[i - 1], order[i] = order[i], order[i - 1]
        return order


def fetch_with_retry(source, seq: int, *, max_attempts: int = 6,
                     base_delay: float = 0.05, jitter: float = 0.5,
                     sleep_scale: float = 1.0,
                     rng: Optional[np.random.Generator] = None,
                     stats: Optional[Dict] = None) -> List:
    """Retry a delivery with exponential backoff and jitter: attempt n
    sleeps ``base_delay · 2^n · (1 + jitter·U[0,1)) · sleep_scale``
    (``sleep_scale=0`` for tests). Re-raises after ``max_attempts``."""
    rng = rng if rng is not None else np.random.default_rng(0)
    last: Optional[Exception] = None
    for attempt in range(max_attempts):
        try:
            return source.fetch(seq, attempt)
        except TransientDeliveryError as e:
            last = e
            if stats is not None:
                stats["delivery_retries"] = \
                    stats.get("delivery_retries", 0) + 1
            delay = (base_delay * (2.0 ** attempt)
                     * (1.0 + jitter * float(rng.random())) * sleep_scale)
            if delay > 0:
                time.sleep(delay)
    raise last  # type: ignore[misc]


def ingest_with_faults(engine, source: FaultyChunkSource, *,
                       max_attempts: int = 6, base_delay: float = 0.05,
                       jitter: float = 0.5, sleep_scale: float = 1.0,
                       meter: bool = True,
                       stats: Optional[Dict] = None) -> Dict:
    """Drive an engine through the source's faulty delivery schedule.

    Exactly-once application against at-least-once delivery: deliveries
    below the engine's ingest cursor (or already buffered) are dropped
    by the idempotent redelivery guard; deliveries above it are buffered
    until their predecessors arrive; each chunk is applied exactly once,
    in sequence order. Propagates ``DeviceLossError`` (see
    ``run_with_recovery``). Returns harness stats; pass ``stats`` to
    accumulate into a caller-owned dict that survives a crash mid-run."""
    if stats is None:
        stats = {}
    for key in ("delivery_retries", "redeliveries_dropped",
                "chunks_applied"):
        stats.setdefault(key, 0)
    rng = np.random.default_rng((source.seed, 27644437))
    pending: Dict[int, List] = {}
    for seq in source.schedule():
        if seq < engine.chunks_ingested or seq in pending:
            stats["redeliveries_dropped"] += 1
            continue
        chunk = fetch_with_retry(source, seq, max_attempts=max_attempts,
                                 base_delay=base_delay, jitter=jitter,
                                 sleep_scale=sleep_scale, rng=rng,
                                 stats=stats)
        pending[seq] = chunk
        while engine.chunks_ingested in pending:
            engine.ingest_dense(pending.pop(engine.chunks_ingested),
                                meter=meter)
            stats["chunks_applied"] += 1
    if pending:
        # can only happen if the schedule lost a chunk — a bug, not a fault
        raise RuntimeError(f"undeliverable buffered chunks: "
                           f"{sorted(pending)} at cursor "
                           f"{engine.chunks_ingested}")
    return stats


def run_with_recovery(build_engine: Callable[[], object],
                      source: FaultyChunkSource, checkpointer, *,
                      max_restarts: int = 3, **ingest_kw
                      ) -> Tuple[object, Dict]:
    """Crash-resilient ingest loop: on ``DeviceLossError`` rebuild the
    engine with ``build_engine()``, restore the last checkpoint, and
    replay the delivery schedule — the redelivery guard absorbs every
    chunk the restored cursor already covers, so each chunk still
    applies exactly once. Returns ``(engine, stats)`` with
    ``stats["restarts"]`` counting recoveries."""
    engine = build_engine()
    engine.attach_checkpointer(checkpointer)
    totals: Dict = {"restarts": 0}
    while True:
        try:
            ingest_with_faults(engine, source, stats=totals, **ingest_kw)
            return engine, totals
        except DeviceLossError:
            totals["restarts"] += 1
            if totals["restarts"] > max_restarts:
                raise
            checkpointer.wait()
            engine = build_engine()
            checkpointer.restore(engine)
            engine.attach_checkpointer(checkpointer)
