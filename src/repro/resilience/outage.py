"""Scoped tier-outage helper.

The mechanics live on the engine (``StreamEngine.tier_outage`` /
``tier_recover`` — the outage must consult the replanner, meter, and
cost monitor that the engine owns); this module adds the operator-facing
context manager so a drill or a test reads as one block::

    with TierOutage(engine, tier=1, burn_grace=8) as out:
        ...   # ingest through the outage; tier 1 is masked + evacuated
    # on exit the tier recovers, with hysteresis chunks of flap damping
"""
from __future__ import annotations

from typing import Dict, Optional


class TierOutage:
    """Declare a tier failed on enter, recover it on exit.

    ``summary`` holds the evacuation report (rows evacuated, residents
    moved, the priced relocation bill, and any skipped/infeasible
    rows). Exiting never swallows exceptions, and recovery is applied
    even when the body raises — a crashed drill must not leave the tier
    masked forever."""

    def __init__(self, engine, tier: int, *, burn_grace: int = 8,
                 hysteresis: int = 2):
        self.engine = engine
        self.tier = int(tier)
        self.burn_grace = int(burn_grace)
        self.hysteresis = int(hysteresis)
        self.summary: Optional[Dict] = None

    def __enter__(self) -> "TierOutage":
        self.summary = self.engine.tier_outage(self.tier,
                                               burn_grace=self.burn_grace)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self.tier in self.engine._failed_tiers:
            self.engine.tier_recover(self.tier,
                                     hysteresis=self.hysteresis)
        return False
