"""Chunk-boundary fleet checkpointing on top of ``checkpoint.manager``.

``FleetCheckpointer`` wires ``resilience.snapshot`` into the engine's
``attach_checkpointer`` hook: every ``every``-th chunk boundary it
snapshots the engine (host copies only — cheap) and hands the pytree to
the ``CheckpointManager``'s worker thread, so the npy writes overlap the
next chunk's compute (which ``ingest_chunks`` has already staged). Saves
are atomic (temp dir + rename), checksummed, and stamped with the
manager's monotone generation counter, so a kill -9 at ANY point leaves
the latest committed checkpoint intact and lineage totally ordered
across crash/restore cycles.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.checkpoint.manager import CheckpointManager

from . import snapshot as snapshot_mod


class FleetCheckpointer:
    """Crash-consistency driver for one ``StreamEngine``.

    Usage::

        ckpt = FleetCheckpointer(dir, every=8)
        engine.attach_checkpointer(ckpt)     # saves ride chunk boundaries
        engine.ingest_chunks(chunks)
        ...
        # after a crash, on a freshly built identical engine:
        gen = ckpt.restore(engine)           # cursor tells where to resume

    ``every=0`` disables automatic saves (manual ``save`` only).
    ``blocking`` forces synchronous writes (tests; shutdown paths call
    ``save(engine, blocking=True)`` explicitly).
    """

    def __init__(self, directory: str, *, every: int = 1,
                 keep_latest: int = 2, keep_best: int = 0,
                 blocking: bool = False,
                 manager: Optional[CheckpointManager] = None):
        self.manager = manager if manager is not None else \
            CheckpointManager(directory, keep_latest=keep_latest,
                              keep_best=keep_best)
        self.every = int(every)
        self.blocking = bool(blocking)
        self.written = 0

    def on_chunk(self, engine) -> None:
        """The engine's chunk-boundary hook."""
        if self.every and engine.chunks_ingested % self.every == 0:
            self.save(engine, blocking=self.blocking)

    def save(self, engine, blocking: bool = False) -> int:
        """Snapshot now; returns the stamped generation."""
        tree, meta = snapshot_mod.fleet_snapshot(engine)
        gen = self.manager.save(tree, step=int(engine.chunks_ingested),
                                blocking=blocking or self.blocking,
                                extra=meta)
        self.written += 1
        tracer = getattr(engine, "_tracer", None)
        if tracer is not None:
            tracer.emit("checkpoint", step=int(engine.chunks_ingested),
                        generation=int(gen))
        return gen

    def restore(self, engine, step: Optional[int] = None,
                verify: bool = True) -> int:
        """Load a checkpoint (latest by default) into a freshly built
        identical engine; returns the checkpoint's generation. The
        engine's ``chunks_ingested`` cursor afterwards names the next
        chunk to (re)deliver."""
        self.manager.wait()
        template, _ = snapshot_mod.fleet_snapshot(engine)
        tree = self.manager.restore(template, step=step, verify=verify)
        manifest = self.manager.manifest(step)
        snapshot_mod.fleet_restore(engine, tree,
                                   manifest.get("extra", {}))
        return int(manifest.get("generation", 0))

    def wait(self) -> None:
        """Block until any in-flight async save committed."""
        self.manager.wait()

    def snapshot(self) -> Dict:
        """The obs layer's resilience section for this checkpointer."""
        latest = self.manager.latest_step()
        return {"checkpoints_written": int(self.written),
                "generation": int(self.manager.generation()),
                "latest_step": int(latest) if latest is not None else -1,
                "every": int(self.every)}
