"""Crash consistency and graceful degradation for the fleet engine.

Three pieces, one discipline — every recovery path must reproduce the
uninterrupted run bit-for-bit or say exactly why it cannot:

* ``snapshot`` / ``checkpoint`` — versioned, checksummed, sharding-
  portable snapshot/restore of the full engine state (device reservoirs,
  drift evidence, metric and cost ledgers, host monitors, the ingest
  cursor, and the decision event logs), written at chunk boundaries so
  the npy I/O overlaps the next chunk's compute.
* ``faults`` — deterministic seed-driven fault injection: transient
  chunk-delivery failures with retry/backoff/jitter, duplicate and
  reordered deliveries against the idempotent cursor guard, NaN/Inf
  score lacing, and simulated device loss with restore-from-checkpoint.
* tier outage (``StreamEngine.tier_outage`` / ``outage.TierOutage``) —
  mask a failed tier from the feasible set, evacuate through a forced
  constrained re-solve, and keep the cost channel honest about the bill.
"""
from .checkpoint import FleetCheckpointer
from .faults import (DeviceLossError, FaultyChunkSource,
                     TransientDeliveryError, ingest_with_faults,
                     run_with_recovery)
from .outage import TierOutage
from .snapshot import fleet_restore, fleet_snapshot

__all__ = [
    "FleetCheckpointer", "TierOutage", "fleet_snapshot", "fleet_restore",
    "FaultyChunkSource", "TransientDeliveryError", "DeviceLossError",
    "ingest_with_faults", "run_with_recovery",
]
