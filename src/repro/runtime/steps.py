"""Shared step functions: train / prefill / decode — used by the real
training loop, the serving loop, and the multi-pod dry-run (lowered with
abstract inputs there).

The paper's feature is wired in here: every train step scores each example
(interestingness = per-example NLL) and merges the batch into the SHP top-K
reservoir *inside* jit — the reservoir state is part of the carried train
state, so curation costs one (tiny) top-k merge per step.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import topk as topk_mod
from repro.models import lm
from repro.optim import adamw


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    step: jax.Array  # () int32 global step
    reservoir: topk_mod.ReservoirState  # SHP top-K over example NLL
    score_ema: jax.Array  # () f32 — EMA of mean NLL (relative scoring)


def init_train_state(cfg, key, reservoir_k: int = 1024) -> TrainState:
    params = lm.init_params(cfg, key)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32),
                      reservoir=topk_mod.init(reservoir_k),
                      score_ema=jnp.zeros((), jnp.float32))


def abstract_train_state(cfg, reservoir_k: int = 1024):
    return jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0),
                                                   reservoir_k))


def train_step(state: TrainState, batch: dict, cfg, *, lr: float = 3e-4,
               aux_weight: float = 0.01, grad_clip: float = 1.0,
               microbatches: int = 1, score_mode: str = "nll"):
    """One optimizer step + reservoir merge. batch must carry
    ``example_ids`` (B,) int32 global stream indices for the reservoir.

    ``microbatches > 1`` runs gradient accumulation under ``lax.scan``: the
    remat-saved activation stack shrinks by the microbatch factor (the
    fits-in-HBM lever for the 100B+ train cells, §Perf iteration 3c) at the
    cost of re-streaming weights per microbatch."""
    if microbatches > 1:
        b = batch["tokens"].shape[0]
        assert b % microbatches == 0, (b, microbatches)

        def reshape(x):
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def accum(carry, mb):
            gsum, lsum, nll_parts = carry
            (l, met), g = jax.value_and_grad(
                lambda p: lm.lm_loss(p, cfg, mb, aux_weight), has_aux=True)(
                    state.params)
            gsum = jax.tree.map(lambda a, c: a + c.astype(jnp.float32), gsum, g)
            return (gsum, lsum + l, None), (met["per_example_nll"], met["loss"])

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          state.params)
        (gsum, lsum, _), (nll, losses) = jax.lax.scan(
            accum, (g0, jnp.zeros(()), None), micro)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        loss = lsum / microbatches
        metrics = {"loss": jnp.mean(losses),
                   "aux_loss": jnp.zeros(()),
                   "per_example_nll": nll.reshape(-1),
                   "tokens": jnp.asarray(
                       batch["tokens"].size, jnp.float32)}
    else:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.lm_loss(p, cfg, batch, aux_weight), has_aux=True)(
                state.params)
    params, opt, gnorm = adamw.apply(state.params, grads, state.opt, lr=lr,
                                     grad_clip=grad_clip)
    ids = batch.get("example_ids")
    if ids is None:
        b = batch["tokens"].shape[0]
        ids = state.step * b + jnp.arange(b, dtype=jnp.int32)
    if score_mode == "nll_centered":
        # batch-mean centering fully removes the training-loss trend and
        # restores the SHP write law (EXPERIMENTS §Training-integration:
        # 155-158 writes vs analytic 163, raw NLL 54-81)
        nll = metrics["per_example_nll"]
        scores, score_ema = nll - jnp.mean(nll), state.score_ema
    elif score_mode == "nll_relative":
        # EMA de-trending: keeps absolute difficulty comparable across
        # steps; partially restores the law (≈87%)
        from repro.core.interestingness import ema_relative
        scores, score_ema = ema_relative(metrics["per_example_nll"],
                                         state.score_ema, state.step)
    else:
        scores, score_ema = metrics["per_example_nll"], state.score_ema
    reservoir, wrote = topk_mod.update(state.reservoir, scores, ids)
    out_metrics = {
        "loss": metrics["loss"], "aux_loss": metrics["aux_loss"],
        "grad_norm": gnorm, "tokens": metrics["tokens"],
        "reservoir_writes": wrote.sum(),
        "reservoir_threshold": topk_mod.threshold(reservoir),
        "per_example_nll": metrics["per_example_nll"],
        "wrote_mask": wrote,
    }
    new_state = TrainState(params=params, opt=opt, step=state.step + 1,
                           reservoir=reservoir, score_ema=score_ema)
    return new_state, out_metrics


def prefill_step(params, batch: dict, cache, cfg):
    return lm.prefill(params, cfg, batch, cache)


def decode_step(params, token, cache, cfg):
    return lm.decode_step(params, cfg, token, cache)
