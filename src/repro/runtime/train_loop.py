"""Fault-tolerant training loop.

Failure model (1000+-node deployments): any step may be interrupted
(SIGTERM/preemption), any node may straggle. Mechanisms:

* auto-resume — on start, restore the newest valid checkpoint (atomic
  manifests mean a torn save is never selected);
* preemption — SIGTERM/SIGINT set a flag; the loop checkpoints at the next
  step boundary and exits cleanly;
* straggler watchdog — per-step wall times in a ring buffer; steps slower
  than ``straggler_factor`` × median are logged and counted (on a real
  cluster this feeds the scheduler's replace/restart decision);
* elastic data — the loader is (step, rank, size)-addressable, so resuming
  with a different dp size replays no data and skips none;
* curation — the SHP reservoir/top-K tier placement runs inside the step
  (device) and in the host curator (payload placement).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import StreamLoader
from repro.runtime import steps as steps_mod


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    lr: float = 3e-4
    straggler_factor: float = 3.0
    straggler_window: int = 64


@dataclass
class LoopReport:
    steps_run: int = 0
    resumed_from: Optional[int] = None
    interrupted: bool = False
    straggler_steps: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


def run(cfg, loader: StreamLoader, *, loop: LoopConfig,
        ckpt: Optional[CheckpointManager] = None,
        curator=None, seed: int = 0,
        on_metrics: Optional[Callable[[int, dict], None]] = None) -> LoopReport:
    report = LoopReport()
    state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(seed))
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start_step = int(state.step)
        report.resumed_from = start_step

    stop = {"flag": False}

    def _handler(signum, frame):
        stop["flag"] = True

    old_term = signal.signal(signal.SIGTERM, _handler)
    old_int = signal.signal(signal.SIGINT, _handler)

    step_fn = jax.jit(
        lambda s, b: steps_mod.train_step(s, b, cfg, lr=loop.lr),
        donate_argnums=(0,))

    times: list[float] = []
    try:
        for step in range(start_step, loop.total_steps):
            batch = jax.tree.map(jax.numpy.asarray, loader.batch_for_step(step))
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # also blocks until step done
            dt = time.time() - t0
            times.append(dt)
            if len(times) > loop.straggler_window:
                times.pop(0)
            med = float(np.median(times))
            if len(times) >= 8 and dt > loop.straggler_factor * med:
                report.straggler_steps += 1
            report.steps_run += 1
            report.losses.append(loss)
            report.step_times.append(dt)
            if curator is not None:
                curator.observe_batch(np.asarray(batch["example_ids"]),
                                      np.asarray(metrics["per_example_nll"]),
                                      np.asarray(batch["tokens"]))
            if on_metrics and step % loop.log_every == 0:
                on_metrics(step, {"loss": loss, "step_time": dt,
                                  "median_step_time": med})
            if ckpt is not None and (step + 1) % loop.ckpt_every == 0:
                ckpt.save(state, step + 1, metric=loss)
            if stop["flag"]:
                report.interrupted = True
                break
        if ckpt is not None:
            ckpt.save(state, int(state.step), metric=report.losses[-1]
                      if report.losses else float("nan"), blocking=True)
            ckpt.wait()
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)
    report.final_state = state
    return report
