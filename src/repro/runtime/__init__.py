from . import steps  # noqa: F401
