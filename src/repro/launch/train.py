"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant loop (auto-resume, SIGTERM-safe, straggler
watchdog) with top-K tiered curation for any registered architecture.
On this CPU host use ``--reduced`` (full configs are exercised via the
dry-run); on a real cluster the same entry point runs the full config
under the production mesh.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeConfig
from repro.core import costs, placement, shp, tiers
from repro.data.curation import TopKCurator
from repro.data.pipeline import StreamLoader
from repro.models import param_count
from repro.runtime import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reservoir-k", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    print(f"{args.arch}: {param_count(cfg)/1e6:.1f}M params "
          f"({'reduced' if args.reduced else 'FULL'})")
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    loader = StreamLoader(cfg, shape, seed=0)

    n_docs = args.steps * args.batch
    k = min(args.reservoir_k, max(n_docs // 4, 1))
    args.reservoir_k = k
    cm = costs.hbm_host_preset(n_docs=n_docs, k=k,
                               doc_gb=args.seq * 4 / 1e9,
                               window_seconds=3600.0)
    plan = shp.plan_placement(cm)
    pol = placement.from_plan(plan)
    print(f"SHP curation plan: {plan.strategy} r*/N={plan.best.r_over_n:.3f}")
    dec_len = cfg.decoder_len if cfg.is_encoder_decoder else args.seq
    store = tiers.TieredStore(
        pol, tiers.HotTier(args.reservoir_k, (dec_len,), dtype=jnp.int32),
        tiers.ColdTier())
    curator = TopKCurator(args.reservoir_k, store, policy=pol)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    report = train_loop.run(
        cfg, loader, loop=train_loop.LoopConfig(
            total_steps=args.steps, ckpt_every=max(args.steps // 2, 1),
            log_every=max(args.steps // 10, 1), lr=args.lr),
        ckpt=ckpt, curator=curator,
        on_metrics=lambda s, m: print(f"  step {s} loss {m['loss']:.3f}"))
    print(f"done: loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}; "
          f"curation {curator.stats.as_dict()}")


if __name__ == "__main__":
    main()
