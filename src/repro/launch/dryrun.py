import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) cell
with abstract params/optimizer/cache and explicit NamedShardings, then record
memory_analysis(), cost_analysis() and collective traffic for the roofline.

The two lines above MUST run before any jax import (jax locks the device
count at first init) — this module is the only place the 512 placeholder
devices exist; smoke tests and benchmarks see the real single CPU device.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out artifacts/dryrun]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import supports_shape
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh
from repro.models import lm, param_count
from repro.parallel import ctx as pctx
from repro.parallel import sharding as shd
from repro.runtime import steps
from jax.sharding import NamedSharding, PartitionSpec as P


def _rep(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def train_state_shardings(mesh, state_spec: steps.TrainState):
    ps = shd.param_shardings(mesh, state_spec.params)
    ms = shd.param_shardings(mesh, state_spec.opt.m)
    vs = shd.param_shardings(mesh, state_spec.opt.v)
    opt = type(state_spec.opt)(step=NamedSharding(mesh, P()), m=ms, v=vs)
    return steps.TrainState(params=ps, opt=opt,
                            step=NamedSharding(mesh, P()),
                            reservoir=_rep(mesh, state_spec.reservoir),
                            score_ema=NamedSharding(mesh, P()))


def build_cell(cfg, shape, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    n_chips = mesh.devices.size
    if shape.kind == "train":
        state_spec = specs.train_state_spec(cfg)
        batch_spec = specs.batch_specs(cfg, shape)
        st_sh = train_state_shardings(mesh, state_spec)
        b_sh = shd.batch_shardings(mesh, batch_spec)

        # big models microbatch so the remat-saved stack fits HBM (§Perf).
        # µ=16 was tried for the 200B+ MoE trains and REFUTED: FSDP expert
        # weight re-gathers scale with µ and dominated (EXPERIMENTS §Perf).
        micro = 8 if param_count(cfg) > 5e10 else 1

        def fn(state, batch):
            new_state, metrics = steps.train_step(state, batch, cfg,
                                                  microbatches=micro)
            small = {k: v for k, v in metrics.items()
                     if k in ("loss", "aux_loss", "grad_norm",
                              "reservoir_writes")}
            return new_state, small

        out_sh = (st_sh, _rep(mesh, {"loss": 0, "aux_loss": 0, "grad_norm": 0,
                                     "reservoir_writes": 0}))
        return fn, (state_spec, batch_spec), (st_sh, b_sh), out_sh

    params_spec = lm.abstract_params(cfg)
    p_sh = shd.param_shardings(mesh, params_spec)
    if shape.kind == "prefill":
        batch_spec = specs.batch_specs(cfg, shape)
        b_sh = shd.batch_shardings(mesh, batch_spec)
        kv = specs.cache_len(
            cfg, (cfg.decoder_len + 1) if cfg.is_encoder_decoder else shape.seq_len)
        enc_len = shape.seq_len if cfg.is_encoder_decoder else 0
        cache_spec = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch, kv, enc_len=enc_len))
        c_sh = shd.cache_shardings(mesh, cache_spec)

        def fn(params, batch, cache):
            return steps.prefill_step(params, batch, cache, cfg)

        logits_sh = NamedSharding(mesh, pctx.spec(
            mesh, (pctx.BATCH, pctx.MODEL), (shape.global_batch, cfg.vocab_size)))
        return fn, (params_spec, batch_spec, cache_spec), \
            (p_sh, b_sh, c_sh), (logits_sh, c_sh)

    # decode — weights TP/EP-only (no FSDP) when they fit one model-axis
    # shard (≲20B params): a per-token weight all-gather has nothing to
    # amortize it. Bigger models keep FSDP (weights wouldn't fit HBM). §Perf
    if param_count(cfg) < 2e10:
        p_sh = shd.param_shardings(mesh, params_spec, fsdp=False)
    tok_spec, cache_spec = specs.decode_inputs(cfg, shape)
    c_sh = shd.cache_shardings(mesh, cache_spec)
    t_sh = NamedSharding(mesh, pctx.spec(mesh, (pctx.BATCH,), tok_spec.shape))

    def fn(params, token, cache):
        return steps.decode_step(params, token, cache, cfg)

    logits_sh = NamedSharding(mesh, pctx.spec(
        mesh, (pctx.BATCH, pctx.MODEL), (shape.global_batch, cfg.vocab_size)))
    return fn, (params_spec, tok_spec, cache_spec), \
        (p_sh, t_sh, c_sh), (logits_sh, c_sh)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             verbose: bool = True) -> dict:
    cfg = configs.get_config(arch).with_dtypes("bfloat16", "bfloat16")
    shape = configs.get_shape(shape_name)
    # sequence parallelism pays off when many tokens flow per step — but on
    # the multi-pod mesh the SP layout collides with the MoE dispatch
    # reshape (SPMD "involuntary full remat"), measured 10-50× worse; SP is
    # therefore scoped to dense/SSM archs there (EXPERIMENTS §Perf it. 4)
    use_sp = shape.kind in ("train", "prefill") and \
        (cfg.n_experts == 0 or mesh_kind == "single")
    cfg = cfg.replace(remat=True, seq_parallel=use_sp)
    ok, why = supports_shape(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(mesh.devices.size)
    t0 = time.time()
    with pctx.use_mesh(mesh), mesh:
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0,) if shape.is_train else ())
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    roof = hlo_analysis.roofline_from_compiled(compiled, n_chips)
    n_params = param_count(cfg)
    mf = hlo_analysis.model_flops(cfg, shape, active_param_count(cfg))
    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "n_params": n_params,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "roofline": roof.as_dict(),
        "model_flops": mf,
        "useful_flops_ratio": (mf / n_chips / roof.flops) if roof.flops else None,
    })
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_kind}] compiled in "
              f"{t_compile:.0f}s  chips={n_chips}")
        print("  memory_analysis:", rec["memory"])
        print("  per-chip: flops={:.3e} bytes={:.3e} link_bytes={:.3e}".format(
            roof.flops, roof.hbm_bytes, roof.collective_link_bytes))
        print("  roofline: t_comp={:.2e}s t_mem={:.2e}s t_coll={:.2e}s -> {}".format(
            roof.t_compute, roof.t_memory, roof.t_collective, roof.bottleneck))
    return rec


def active_param_count(cfg) -> int:
    """Active params per token (MoE counts shared + top-k routed only)."""
    total = param_count(cfg)
    if cfg.n_experts == 0:
        return total
    # subtract inactive expert weights
    glu = 3  # w_up, w_gate, w_down
    per_expert = glu * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = sum(s.count for s in cfg.layers if s.ffn == "moe")
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k_experts) * per_expert
    return total - inactive


def _mem_dict(mem) -> dict:
    try:
        return {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        }
    except Exception:
        return {"repr": str(mem)}


def cells(mesh_kind: str, only_arch=None, only_shape=None):
    for arch in configs.list_archs():
        if only_arch and arch != only_arch:
            continue
        for shape_name in configs.SHAPES:
            if only_shape and shape_name != only_shape:
                continue
            yield arch, shape_name, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for mk in mesh_kinds:
        for arch, shape_name, mesh_kind in cells(mk, args.arch, args.shape):
            if not args.all and (args.arch is None or args.shape is None):
                continue
            path = os.path.join(
                args.out, f"{arch}__{shape_name}__{mesh_kind}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue
            try:
                rec = run_cell(arch, shape_name, mesh_kind, args.out)
            except Exception as e:  # record and continue
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
                n_fail += 1
                print(f"[{arch} × {shape_name} × {mesh_kind}] FAILED: {e!r}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"dry-run done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
