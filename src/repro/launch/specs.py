"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins for
every model input / state — weak-type-correct, shardable, no allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.runtime import steps


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training / prefill batch (tokens+labels / tokens)."""
    b = shape.global_batch
    s = shape.seq_len
    dec_len = cfg.decoder_len if cfg.is_encoder_decoder else s
    out = {"tokens": sds((b, dec_len), jnp.int32)}
    if shape.is_train:
        out["labels"] = sds((b, dec_len), jnp.int32)
        out["example_ids"] = sds((b,), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = sds((b, s, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), jnp.float32)
    return out


def cache_len(cfg: ModelConfig, total: int) -> int:
    w = cfg.max_window
    return min(w, total) if w > 0 else total


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """(token_spec, cache_spec) for a serve_step with a seq_len-deep cache."""
    b = shape.global_batch
    if cfg.is_encoder_decoder:
        kv = cache_len(cfg, cfg.decoder_len + 1)
        enc_len = shape.seq_len
    else:
        kv = cache_len(cfg, shape.seq_len)
        enc_len = 0
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, kv, enc_len=enc_len))
    tok = sds((b,), jnp.int32)
    return tok, cache


def train_state_spec(cfg: ModelConfig, reservoir_k: int = 1024):
    return steps.abstract_train_state(cfg, reservoir_k)
