"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``cost_analysis()`` has FLOPs and HBM bytes but no collective traffic, so we
parse the compiled HLO text and sum the output sizes of every collective op,
then convert to per-chip link-bytes with ring-algorithm factors.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16, per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = bf16[16,512]{1,0} all-reduce(...)
#        ROOT %tuple ... (f32[8,16]{...}, bf16[...]) all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def link_bytes_per_chip(self, n_chips: int) -> float:
        """Ring-model per-chip link traffic:
        all-reduce ≈ 2·(n−1)/n · S;  all-gather / reduce-scatter / all-to-all
        / permute ≈ (n−1)/n · S (S = global tensor size).  We use the op's
        *output* size as S and n = total chips (upper bound on the ring)."""
        f = (n_chips - 1) / max(n_chips, 1)
        factors = {"all-reduce": 2.0 * f, "all-gather": f,
                   "reduce-scatter": f, "all-to-all": f,
                   "collective-permute": 1.0}
        return sum(self.bytes_by_kind.get(k, 0) * factors.get(k, 1.0)
                   for k in self.bytes_by_kind) / max(n_chips, 1)

    def as_dict(self) -> dict:
        return {"bytes_by_kind": dict(self.bytes_by_kind),
                "count_by_kind": dict(self.count_by_kind),
                "total_bytes": self.total_bytes}


def collective_stats(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if "(" in line.split("=", 1)[0]:
            pass
        # tuple outputs: sum every shape in the tuple before the op name
        lhs = line.split(kind)[0]
        if "= (" in lhs.replace("=  (", "= ("):
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _TUPLE_SHAPE_RE.findall(lhs.split("=", 1)[1]))
        else:
            nbytes = _shape_bytes(m.group(1), m.group(2))
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + nbytes
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


ICI_LINKS = 2  # bidirectional ring on one torus axis engages 2 links/chip


@dataclass
class Roofline:
    """All quantities are PER CHIP (from the per-partition HLO module,
    while-loop bodies multiplied by trip count — see hlo_parse)."""

    flops: float
    hbm_bytes: float
    collective_link_bytes: float
    n_chips: int
    detail: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_link_bytes / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time (perfect overlap of the three engines)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops, "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_link_bytes_per_chip": self.collective_link_bytes,
            "n_chips": self.n_chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck, "t_bound_s": self.t_bound,
            "detail": self.detail,
        }


def roofline_from_compiled(compiled, n_chips: int) -> Roofline:
    from . import hlo_parse
    cost = hlo_parse.analyze(compiled.as_text(), n_chips)
    raw = dict(compiled.cost_analysis() or {})
    return Roofline(
        flops=cost.flops, hbm_bytes=cost.bytes,
        collective_link_bytes=cost.collective_link_bytes, n_chips=n_chips,
        detail={
            "collective_bytes_by_kind": cost.collective_bytes,
            "collective_counts": cost.collective_counts,
            "unparsed_whiles": cost.unparsed_whiles,
            # raw XLA numbers for reference — loop bodies counted ONCE there
            "xla_cost_analysis_flops": raw.get("flops"),
            "xla_cost_analysis_bytes": raw.get("bytes accessed"),
        })


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6·N_active·D (per step for train; per generated token × batch for
    decode; prefill counts forward-only ⇒ 2·N·D)."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.global_batch  # decode: 1 tok/seq
