"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + decode with top-K (most interesting = highest predictive
entropy) request retention across the tiered store — the paper's workflow
with the serving fleet as producer. Reduced configs on CPU; same entry
point under the production mesh on hardware. ``--tenants M`` switches
retention to the multi-tenant ``repro.streams`` fleet engine (one jitted
step advances all M tenant reservoirs).
"""
from __future__ import annotations

import argparse
import subprocess
import sys


def main():
    # serve_topk.py is the reference implementation; keep a single code path
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">1 = multi-tenant retention via repro.streams")
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable repro.obs telemetry and write the "
                         "metrics.json / metrics.prom / events.jsonl "
                         "artifacts to DIR")
    args, extra = ap.parse_known_args()
    import repro  # noqa: F401 — ensure PYTHONPATH is sane before spawning
    import os
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    script = os.path.join(here, "examples", "serve_topk.py")
    cmd = [sys.executable, script, "--arch", args.arch,
           "--requests", str(args.requests), "--batch", str(args.batch),
           "--tenants", str(args.tenants)]
    if args.obs_out is not None:
        cmd += ["--obs-out", args.obs_out]
    raise SystemExit(subprocess.call(cmd + extra))


if __name__ == "__main__":
    main()
