"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + decode with top-K (most interesting = highest predictive
entropy) request retention across the tiered store — the paper's workflow
with the serving fleet as producer. Reduced configs on CPU; same entry
point under the production mesh on hardware. ``--tenants M`` switches
retention to the multi-tenant ``repro.streams`` fleet engine (one jitted
step advances all M tenant reservoirs); ``--mesh N`` shards that tenant
axis across an N-device mesh (forced CPU devices off-hardware) — the
``--obs-out`` artifacts then carry the cross-shard aggregated counters,
never one shard's block. ``--obs-port`` serves live ``/metrics``
(Prometheus) and ``/snapshot`` (JSON) from the running engine with cost
attribution on (``repro.obs.http``).
"""
from __future__ import annotations

import argparse
import signal
import subprocess
import sys


def main():
    # serve_topk.py is the reference implementation; keep a single code path
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">1 = multi-tenant retention via repro.streams")
    ap.add_argument("--mesh", type=int, default=1,
                    help="shard the tenant fleet across an N-device mesh "
                         "(requires --tenants > 1); forces N CPU devices "
                         "in the child before jax loads")
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable repro.obs telemetry and write the "
                         "metrics.json / metrics.prom / events.jsonl "
                         "artifacts to DIR")
    ap.add_argument("--obs-port", type=int, default=None, metavar="PORT",
                    help="serve live /metrics and /snapshot from the "
                         "running engine (0 = ephemeral port); implies "
                         "obs with cost attribution")
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="crash-consistent fleet checkpointing to DIR "
                         "(repro.resilience; requires --tenants > 1), "
                         "with a final blocking checkpoint on exit and "
                         "on SIGTERM/SIGINT")
    ap.add_argument("--ckpt-every", type=int, default=None, metavar="N",
                    help="checkpoint every N ingested chunks "
                         "(0 = final checkpoint only)")
    args, extra = ap.parse_known_args()
    import repro  # noqa: F401 — ensure PYTHONPATH is sane before spawning
    import os
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    script = os.path.join(here, "examples", "serve_topk.py")
    cmd = [sys.executable, script, "--arch", args.arch,
           "--requests", str(args.requests), "--batch", str(args.batch),
           "--tenants", str(args.tenants)]
    env = dict(os.environ)
    if args.mesh > 1:
        cmd += ["--mesh", str(args.mesh)]
        # the child pre-parses --mesh too, but only appends the flag when
        # absent — setting it here keeps the two in agreement even if the
        # parent environment already forces a different count
        flags = env.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = (
                flags
                + f" --xla_force_host_platform_device_count={args.mesh}"
            ).strip()
    if args.obs_out is not None:
        cmd += ["--obs-out", args.obs_out]
    if args.obs_port is not None:
        cmd += ["--obs-port", str(args.obs_port)]
    if args.ckpt_dir is not None:
        cmd += ["--ckpt-dir", args.ckpt_dir]
    if args.ckpt_every is not None:
        cmd += ["--ckpt-every", str(args.ckpt_every)]
    proc = subprocess.Popen(cmd + extra, env=env)

    # Forward SIGTERM/SIGINT so the child runs its graceful shutdown
    # (final blocking checkpoint + obs drain) instead of dying with us;
    # the exit code below is then the child's graceful one.
    def _forward(signum, frame):
        try:
            proc.send_signal(signum)
        except ProcessLookupError:
            pass

    for s in (signal.SIGTERM, signal.SIGINT):
        signal.signal(s, _forward)
    raise SystemExit(proc.wait())


if __name__ == "__main__":
    main()
