"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + decode with top-K (most interesting = highest predictive
entropy) request retention across the tiered store — the paper's workflow
with the serving fleet as producer. Reduced configs on CPU; same entry
point under the production mesh on hardware. ``--tenants M`` switches
retention to the multi-tenant ``repro.streams`` fleet engine (one jitted
step advances all M tenant reservoirs); ``--mesh N`` shards that tenant
axis across an N-device mesh (forced CPU devices off-hardware) — the
``--obs-out`` artifacts then carry the cross-shard aggregated counters,
never one shard's block. ``--obs-port`` serves live ``/metrics``
(Prometheus) and ``/snapshot`` (JSON) from the running engine with cost
attribution on (``repro.obs.http``).
"""
from __future__ import annotations

import argparse
import subprocess
import sys


def main():
    # serve_topk.py is the reference implementation; keep a single code path
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=1,
                    help=">1 = multi-tenant retention via repro.streams")
    ap.add_argument("--mesh", type=int, default=1,
                    help="shard the tenant fleet across an N-device mesh "
                         "(requires --tenants > 1); forces N CPU devices "
                         "in the child before jax loads")
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable repro.obs telemetry and write the "
                         "metrics.json / metrics.prom / events.jsonl "
                         "artifacts to DIR")
    ap.add_argument("--obs-port", type=int, default=None, metavar="PORT",
                    help="serve live /metrics and /snapshot from the "
                         "running engine (0 = ephemeral port); implies "
                         "obs with cost attribution")
    args, extra = ap.parse_known_args()
    import repro  # noqa: F401 — ensure PYTHONPATH is sane before spawning
    import os
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    script = os.path.join(here, "examples", "serve_topk.py")
    cmd = [sys.executable, script, "--arch", args.arch,
           "--requests", str(args.requests), "--batch", str(args.batch),
           "--tenants", str(args.tenants)]
    env = dict(os.environ)
    if args.mesh > 1:
        cmd += ["--mesh", str(args.mesh)]
        # the child pre-parses --mesh too, but only appends the flag when
        # absent — setting it here keeps the two in agreement even if the
        # parent environment already forces a different count
        flags = env.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            env["XLA_FLAGS"] = (
                flags
                + f" --xla_force_host_platform_device_count={args.mesh}"
            ).strip()
    if args.obs_out is not None:
        cmd += ["--obs-out", args.obs_out]
    if args.obs_port is not None:
        cmd += ["--obs-port", str(args.obs_port)]
    raise SystemExit(subprocess.call(cmd + extra, env=env))


if __name__ == "__main__":
    main()
